"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/values; assert_allclose against ref.py is THE
correctness signal for everything the AOT path bakes into the artifacts.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import decode_attention
from compile.kernels.ffn import ffn
from compile.kernels.predictor_mlp import predictor_mlp

RTOL = ATOL = 3e-5


def _arr(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# decode attention

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 8),
    h=st.sampled_from([1, 2, 4]),
    s_blocks=st.integers(1, 5),
    dh=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(b, h, s_blocks, dh, seed):
    rng = np.random.default_rng(seed)
    s = s_blocks * 128
    q = _arr(rng, b, h, dh)
    k = _arr(rng, b, h, s, dh)
    v = _arr(rng, b, h, s, dh)
    lens = jnp.asarray(rng.integers(1, s + 1, b), jnp.int32)
    out = decode_attention(q, k, v, lens)
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=RTOL, atol=ATOL)


def test_decode_attention_ignores_padding():
    # garbage beyond lens must not affect the output
    rng = np.random.default_rng(0)
    b, h, s, dh = 2, 4, 256, 32
    q = _arr(rng, b, h, dh)
    k = _arr(rng, b, h, s, dh)
    v = _arr(rng, b, h, s, dh)
    lens = jnp.asarray([10, 100], jnp.int32)
    out1 = decode_attention(q, k, v, lens)
    k2 = k.at[:, :, 150:, :].set(1e6)  # poison the padding region
    v2 = v.at[:, :, 150:, :].set(-1e6)
    out2 = decode_attention(q, k2, v2, lens)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=RTOL, atol=ATOL)


def test_decode_attention_len_one():
    rng = np.random.default_rng(1)
    q = _arr(rng, 1, 4, 32)
    k = _arr(rng, 1, 4, 128, 32)
    v = _arr(rng, 1, 4, 128, 32)
    lens = jnp.asarray([1], jnp.int32)
    out = decode_attention(q, k, v, lens)
    # attention over a single position == that position's value
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(v[0, :, 0, :]),
                               rtol=RTOL, atol=ATOL)


def test_decode_attention_rejects_unaligned_s():
    rng = np.random.default_rng(2)
    with pytest.raises(ValueError):
        decode_attention(_arr(rng, 1, 2, 8), _arr(rng, 1, 2, 100, 8),
                         _arr(rng, 1, 2, 100, 8), jnp.asarray([5], jnp.int32))


# ---------------------------------------------------------------------------
# fused FFN

@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 17),
    d=st.sampled_from([32, 128]),
    f=st.sampled_from([64, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ffn_matches_ref(b, d, f, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, b, d)
    w1, b1 = _arr(rng, d, f, scale=0.1), _arr(rng, f, scale=0.01)
    w2, b2 = _arr(rng, f, d, scale=0.1), _arr(rng, d, scale=0.01)
    out = ffn(x, w1, b1, w2, b2)
    want = ref.ffn_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=RTOL, atol=ATOL)


def test_ffn_batch_padding_roundtrip():
    # B not a multiple of the row tile: padding must not leak
    rng = np.random.default_rng(3)
    d, f = 128, 512
    w1, b1 = _arr(rng, d, f, scale=0.1), _arr(rng, f, scale=0.01)
    w2, b2 = _arr(rng, f, d, scale=0.1), _arr(rng, d, scale=0.01)
    x5 = _arr(rng, 5, d)
    out5 = ffn(x5, w1, b1, w2, b2)
    out1 = ffn(x5[2:3], w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(out5[2:3]), np.asarray(out1),
                               rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# predictor MLP

@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 20), seed=st.integers(0, 2**31 - 1))
def test_predictor_mlp_matches_ref(b, seed):
    rng = np.random.default_rng(seed)
    dims = [128, 256, 64, 16, 1]
    ws = [_arr(rng, dims[i], dims[i + 1], scale=0.2) for i in range(4)]
    bs = [_arr(rng, dims[i + 1], scale=0.01) for i in range(4)]
    h = _arr(rng, b, 128)
    out = predictor_mlp(h, ws, bs)
    want = ref.predictor_mlp_ref(h, ws, bs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=RTOL, atol=ATOL)


def test_predictor_mlp_requires_four_layers():
    rng = np.random.default_rng(4)
    ws = [_arr(rng, 8, 8)] * 3
    bs = [_arr(rng, 8)] * 3
    with pytest.raises(ValueError):
        predictor_mlp(_arr(rng, 2, 8), ws, bs)
