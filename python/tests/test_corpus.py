"""Corpus invariants: the synthetic reasoning-trace language must have the
length structure the prediction experiments rely on (tag-dependent
expected length; plan prefix consistent with paragraph count)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.configs import CORPUS, MODEL
from compile.corpus import (expected_length_by_tag, make_prompt,
                            make_response, make_training_batch)


@settings(max_examples=30, deadline=None)
@given(tag=st.integers(0, 15), seed=st.integers(0, 2**31 - 1))
def test_prompt_shape(tag, seed):
    rng = np.random.default_rng(seed)
    p = make_prompt(rng, tag)
    assert p[0] == CORPUS.bos
    assert p[1] == CORPUS.q_byte
    assert p[2] == CORPUS.tag_bytes[tag]
    assert p[-1] == CORPUS.sep_byte
    assert len(p) <= MODEL.max_prompt


@settings(max_examples=30, deadline=None)
@given(tag=st.integers(0, 15), seed=st.integers(0, 2**31 - 1))
def test_response_plan_matches_paragraphs(tag, seed):
    rng = np.random.default_rng(seed)
    r = make_response(rng, tag)
    assert r[-1] == CORPUS.eos
    body = bytes(b for b in r[:-1])
    # plan prefix: "p:" + stars + newline
    assert body.startswith(b"p:")
    stars = body[2:].split(b"\n")[0]
    assert set(stars) <= {ord("*")}
    n_planned = len(stars)
    n_paragraphs = body.count(bytes([CORPUS.step_byte, CORPUS.colon_byte]))
    # truncation can cut paragraphs; otherwise plan == execution
    if len(r) < MODEL.max_seq - 40:
        assert n_paragraphs == n_planned, body


def test_tag_controls_expected_length():
    rng = np.random.default_rng(0)
    mean_len = []
    for tag in [0, 15]:
        lens = [len(make_response(rng, tag, max_len=10_000)) for _ in range(300)]
        mean_len.append(np.mean(lens))
    assert mean_len[1] > 4 * mean_len[0], mean_len
    # matches the analytic expectation within 15%
    analytic = expected_length_by_tag()
    assert abs(mean_len[0] - analytic[0]) / analytic[0] < 0.2
    assert abs(mean_len[1] - analytic[15]) / analytic[15] < 0.2


def test_training_batch_shapes_and_mask():
    rng = np.random.default_rng(1)
    toks, mask = make_training_batch(rng, 4, 256)
    assert toks.shape == (4, 256)
    assert mask.shape == (4, 255)
    assert toks.dtype == np.int32
    # mask covers exactly the populated positions
    for b in range(4):
        n = (toks[b] != 0).sum()
        # allow EOS=0 inside the sequence end
        assert mask[b].sum() >= min(n - 1, 1)
        assert ((toks[b] >= 0) & (toks[b] < 256)).all()
