"""Mini end-to-end build-pipeline test: dataset generation -> split ->
predictor training, on a tiny budget (structure + no-leakage checks; the
full-quality run happens in `make artifacts`)."""

import numpy as np

from compile import model as M
from compile.gen_dataset import generate_requests, split_records, to_arrays
from compile.train_predictor import (target_invert, target_transform,
                                     train_llm_native)


def test_generate_split_train_smoke():
    params = M.init_params(0)  # untrained is fine for structure
    records, req_lengths, _tags = generate_requests(
        params, n_requests=6, seed=1, record_every=16, verbose=False)
    assert len(req_lengths) == 6
    assert all(r["remaining"] >= 0 for r in records)
    assert all(r["remaining"] + r["gen_sofar"] <= 512 for r in records)

    splits = split_records(records, 6, seed=0)
    # request-level split: no request id straddles two splits
    seen = {}
    for name, recs in splits.items():
        for r in recs:
            assert seen.setdefault(r["req"], name) == name, "leakage"

    # tiny training run must reduce validation error vs init
    arrays = {k: to_arrays(v) if v else None for k, v in splits.items()}
    if arrays["train"] is None or arrays["val"] is None:
        return  # degenerate split at this size; structure already checked
    import compile.configs as C
    old_epochs = C.TRAIN.pred_epochs
    object.__setattr__(C.TRAIN, "pred_epochs", 3)
    try:
        pparams, tt = train_llm_native(arrays["train"], arrays["val"])
        assert tt >= 0.0
        for w in pparams["ws"]:
            assert np.isfinite(np.asarray(w)).all()
    finally:
        object.__setattr__(C.TRAIN, "pred_epochs", old_epochs)


def test_target_transform_roundtrip():
    import jax.numpy as jnp
    y = jnp.asarray([0.0, 1.0, 64.0, 500.0])
    t = target_transform(y)
    back = target_invert(t)
    np.testing.assert_allclose(np.asarray(back), np.asarray(y), rtol=1e-6)
