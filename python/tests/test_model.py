"""L2 correctness: star-pico model invariants.

The load-bearing test is prefill/decode/train-forward consistency: the
AOT serving path (prefill once + decode steps with KV cache) must produce
exactly the same logits as the dense training forward.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.configs import MODEL


def _params():
    # module-level cache: init is cheap but jit re-tracing is not
    global _P
    try:
        return _P
    except NameError:
        _P = M.init_params(0)
        return _P


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_decode=st.integers(1, 4))
def test_prefill_then_decode_matches_train_forward(seed, n_decode):
    rng = np.random.default_rng(seed)
    params = _params()
    plen = int(rng.integers(3, 20))
    prompt = [1] + rng.integers(2, 256, plen - 1).tolist()
    nxt = rng.integers(2, 256, n_decode).tolist()

    toks = np.zeros((1, MODEL.max_prompt), np.int32)
    toks[0, :plen] = prompt
    logits_p, kv, _hid = M.prefill(params, jnp.asarray(toks),
                                   jnp.asarray([plen], jnp.int32))

    full = np.array([prompt + nxt], np.int32)
    want = M.lm_forward_train(params, jnp.asarray(full))
    np.testing.assert_allclose(np.asarray(logits_p[0]),
                               np.asarray(want[0, plen - 1]),
                               rtol=2e-4, atol=2e-4)

    # decode in a batch of 2 with a dummy in slot 1
    B = 2
    kvb = jnp.zeros((MODEL.n_layers, 2, B, MODEL.n_heads, MODEL.max_seq,
                     MODEL.head_dim), jnp.float32)
    kvb = kvb.at[:, :, 0:1].set(kv)
    pos = plen
    for i, t in enumerate(nxt):
        logits_d, kvb, _h = M.decode_step(
            params, jnp.asarray([t, 1], jnp.int32),
            jnp.asarray([pos, 0], jnp.int32), kvb, use_kernels=False)
        np.testing.assert_allclose(np.asarray(logits_d[0]),
                                   np.asarray(want[0, plen + i]),
                                   rtol=2e-3, atol=2e-3)
        pos += 1


def test_decode_kernel_and_ref_paths_agree():
    # use_kernels=True (Pallas, the AOT path) vs False (jnp oracle path)
    rng = np.random.default_rng(7)
    params = _params()
    B = 4
    kv = jnp.asarray(rng.standard_normal(
        (MODEL.n_layers, 2, B, MODEL.n_heads, MODEL.max_seq, MODEL.head_dim)
    ) * 0.1, jnp.float32)
    tokens = jnp.asarray(rng.integers(2, 256, B), jnp.int32)
    pos = jnp.asarray([5, 17, 80, 300], jnp.int32)
    l1, kv1, h1 = M.decode_step(params, tokens, pos, kv, use_kernels=True)
    l2, kv2, h2 = M.decode_step(params, tokens, pos, kv, use_kernels=False)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(kv1), np.asarray(kv2), rtol=2e-5, atol=2e-5)


def test_decode_step_writes_kv_at_position_only():
    rng = np.random.default_rng(8)
    params = _params()
    B = 2
    kv = jnp.zeros((MODEL.n_layers, 2, B, MODEL.n_heads, MODEL.max_seq,
                    MODEL.head_dim), jnp.float32)
    tokens = jnp.asarray([65, 66], jnp.int32)
    pos = jnp.asarray([3, 10], jnp.int32)
    _, kv2, _ = M.decode_step(params, tokens, pos, kv, use_kernels=False)
    delta = np.abs(np.asarray(kv2 - kv)).sum(axis=(0, 1, 3, 5))  # [B, S]
    for b, p in enumerate([3, 10]):
        nz = np.nonzero(delta[b])[0]
        assert nz.tolist() == [p], f"slot {b} wrote positions {nz}"
    _ = rng


def test_rope_is_position_sensitive_and_norm_preserving():
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((1, 1, 4, 32)), jnp.float32)
    a = M.rope(x, jnp.asarray([[0]]))
    b = M.rope(x, jnp.asarray([[5]]))
    assert not np.allclose(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(a)), np.linalg.norm(np.asarray(b)),
        rtol=1e-5)


def test_param_order_is_stable_and_complete():
    params = _params()
    order = M.param_order()
    assert order[0] == "emb"
    assert len(order) == len(params)
    lst = M.params_to_list(params)
    back = M.params_from_list(lst)
    for k in params:
        assert np.array_equal(np.asarray(params[k]), np.asarray(back[k]))


def test_predictor_param_roundtrip():
    pp = M.init_predictor_params(0)
    lst = M.predictor_params_to_list(pp)
    assert len(lst) == 8 == len(M.PREDICTOR_PARAM_NAMES)
    back = M.predictor_params_from_list(lst)
    for a, b in zip(pp["ws"], back["ws"]):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_predictor_forward_nonnegative():
    pp = M.init_predictor_params(3)
    rng = np.random.default_rng(11)
    h = jnp.asarray(rng.standard_normal((16, 128)) * 3, jnp.float32)
    y = M.predictor_forward(pp, h)
    assert (np.asarray(y) >= 0).all()
