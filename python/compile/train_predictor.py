"""Train + evaluate the LLM-native length predictor and baselines (paper §4.4).

Produces:
  artifacts/predictor_params.npz   — trained MLP weights (AOT-baked + rust)
  artifacts/predictor_eval.json    — Table 1 / Fig 7 numbers (human)
  artifacts/predictor_eval.tsv     — same numbers, line-oriented (rust)
  artifacts/dataset_stats.txt      — realized length distribution

Table 1 analog: params / training time / MAE / latency(b=1,10) for
  prompt_only (PiA), auxiliary (TetriInfer/mu-Serve), llm_native (ours).
Fig 7 analog: MAE vs generated-tokens for long-output requests, per method.
"""

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .baselines import AuxiliaryPredictor, PromptMeanPredictor
from .configs import MODEL, PREDICTOR, TRAIN
from .gen_dataset import generate_requests, split_records, to_arrays


# ---------------------------------------------------------------------------
# LLM-native MLP training (L1 loss on log1p(remaining), AdamW, early stop)

def _mlp_forward_raw(pparams, hidden):
    x = hidden
    for i, (w, b) in enumerate(zip(pparams["ws"], pparams["bs"])):
        x = x @ w + b
        if i < 3:
            x = jnp.maximum(x, 0.0)
    return x[:, 0]


def target_transform(remaining):
    """Remaining tokens -> regression target (see PredictorConfig)."""
    if PREDICTOR.log_target:
        return jnp.log1p(remaining)
    return remaining / PREDICTOR.scale


def target_invert(y):
    if PREDICTOR.log_target:
        return jnp.expm1(jnp.maximum(y, 0.0))
    return jnp.maximum(y, 0.0) * PREDICTOR.scale


def train_llm_native(train_arrays, val_arrays, verbose=False):
    pparams = M.init_predictor_params(TRAIN.pred_seed)
    lr, bsz = TRAIN.pred_lr, TRAIN.pred_batch
    Xtr = jnp.asarray(train_arrays["hidden"])
    ytr = target_transform(jnp.asarray(train_arrays["remaining"]))
    Xva = jnp.asarray(val_arrays["hidden"])
    yva = target_transform(jnp.asarray(val_arrays["remaining"]))

    def loss_fn(p, X, y):
        return jnp.abs(_mlp_forward_raw(p, X) - y).mean()

    @jax.jit
    def step(p, m, v, t, X, y):
        loss, g = jax.value_and_grad(loss_fn)(p, X, y)
        t = t + 1
        m = jax.tree_util.tree_map(lambda m, g: 0.9 * m + 0.1 * g, m, g)
        v = jax.tree_util.tree_map(lambda v, g: 0.95 * v + 0.05 * g * g, v, g)
        p = jax.tree_util.tree_map(
            lambda p, m, v: p - lr * (m / (1 - 0.9 ** t)) /
            (jnp.sqrt(v / (1 - 0.95 ** t)) + 1e-8) - lr * 1e-4 * p, p, m, v)
        return p, m, v, t, loss

    val_loss = jax.jit(loss_fn)
    m = jax.tree_util.tree_map(jnp.zeros_like, pparams)
    v = jax.tree_util.tree_map(jnp.zeros_like, pparams)
    t = jnp.zeros((), jnp.float32)
    best, best_p, patience = np.inf, pparams, 0
    rng = np.random.default_rng(1)
    n = Xtr.shape[0]
    t0 = time.time()
    p = pparams
    for ep in range(TRAIN.pred_epochs):
        order = rng.permutation(n)
        for s in range(0, n - bsz + 1, bsz):
            idx = order[s : s + bsz]
            p, m, v, t, _ = step(p, m, v, t, Xtr[idx], ytr[idx])
        vl = float(val_loss(p, Xva, yva))
        if verbose:
            print(f"[llm_native] epoch {ep} val L1(log) {vl:.4f}", flush=True)
        if vl < best - 1e-4:
            best, best_p, patience = vl, p, 0
        else:
            patience += 1
            if patience >= TRAIN.pred_patience:
                break
    train_time = time.time() - t0
    return best_p, train_time


class LlmNativePredictor:
    name = "llm_native"

    def __init__(self, pparams, train_time_s):
        self.pparams = pparams
        self.train_time_s = train_time_s

    def predict(self, arrays):
        fwd = jax.jit(_mlp_forward_raw)
        out = []
        X = jnp.asarray(arrays["hidden"])
        for s in range(0, X.shape[0], 2048):
            out.append(np.asarray(target_invert(fwd(self.pparams, X[s : s + 2048]))))
        return np.clip(np.concatenate(out), 0, None)

    def param_count(self):
        return int(sum(np.prod(p.shape)
                       for p in jax.tree_util.tree_leaves(self.pparams)))


class OraclePredictor:
    name = "oracle"
    train_time_s = 0.0

    def predict(self, arrays):
        return arrays["remaining"].astype(np.float64)

    def param_count(self):
        return 0


# ---------------------------------------------------------------------------
# latency measurement (Table 1 right columns)

def measure_latency(fn, reps=50, warmup=5):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e3  # ms


def latency_table(llm_native, auxiliary, lm_params):
    """Per-method prediction latency at batch 1 and 10 (python/jax side;
    the rust bench re-measures llm_native through the PJRT runtime)."""
    out = {}
    rng = np.random.default_rng(0)
    for bsz in (1, 10):
        h = jnp.asarray(rng.standard_normal((bsz, MODEL.d_model)), jnp.float32)
        w = jnp.asarray(rng.integers(0, 256, (bsz, TRAIN.aux_window)), jnp.int32)
        fwd_n = jax.jit(_mlp_forward_raw)
        fwd_n(llm_native.pparams, h).block_until_ready()
        out[f"llm_native_b{bsz}"] = measure_latency(
            lambda: fwd_n(llm_native.pparams, h).block_until_ready())
        from .baselines import _aux_forward
        fwd_a = jax.jit(_aux_forward)
        fwd_a(auxiliary.params, w).block_until_ready()
        out[f"auxiliary_b{bsz}"] = measure_latency(
            lambda: fwd_a(auxiliary.params, w).block_until_ready())
        # PiA analog: one full-LM forward over the context (prompt method
        # re-runs the target model) — cost of one prefill pass.
        toks = jnp.asarray(rng.integers(0, 256, (1, MODEL.max_prompt)), jnp.int32)
        plen = jnp.asarray([MODEL.max_prompt], jnp.int32)
        pre = jax.jit(lambda p, t, l: M.prefill(p, t, l)[0])
        pre(lm_params, toks, plen).block_until_ready()
        per = measure_latency(
            lambda: pre(lm_params, toks, plen).block_until_ready(), reps=20)
        out[f"prompt_only_b{bsz}"] = per * bsz  # sequential per request
    return out


# ---------------------------------------------------------------------------
# evaluation: MAE + Fig 7 buckets

def evaluate(methods, test_arrays, long_threshold=None):
    y = test_arrays["remaining"].astype(np.float64)
    total = test_arrays["remaining"] + test_arrays["gen_sofar"]
    res = {"table1": {}, "fig7": {}}
    for meth in methods:
        pred = meth.predict(test_arrays)
        mae = float(np.mean(np.abs(pred - y)))
        res["table1"][meth.name] = {
            "parameters": meth.param_count(),
            "train_time_s": round(meth.train_time_s, 2),
            "mae": round(mae, 2),
        }
    # Fig 7: long-output requests only (paper: 30-32K of 32K; here the top
    # band of our 512-token scale), MAE bucketed by generated-so-far.
    if long_threshold is None:
        long_threshold = 0.6 * float(total.max())
    sel = total >= long_threshold
    buckets = np.unique(test_arrays["gen_sofar"][sel] // 64)
    for meth in methods:
        pred = meth.predict(test_arrays)
        series = []
        for b in buckets:
            m = sel & (test_arrays["gen_sofar"] // 64 == b)
            if m.sum() >= 5:
                series.append([int(b * 64),
                               round(float(np.mean(np.abs(pred[m] - y[m]))), 2),
                               int(m.sum())])
        res["fig7"][meth.name] = series
    res["fig7_long_threshold"] = float(long_threshold)
    return res


# ---------------------------------------------------------------------------
# main pipeline

def run(lm_params, out_dir="../artifacts", verbose=True):
    t_all = time.time()
    import os
    cache = f"{out_dir}/predictor_dataset.npz"
    if os.path.exists(cache):
        if verbose:
            print(f"[train_predictor] cached dataset: {cache}", flush=True)
        data = np.load(cache)
        req_lengths = data["req_lengths"]
        records = []
        for i in range(len(data["remaining"])):
            records.append({
                "req": int(data["req"][i]), "tag": int(data["tag"][i]),
                "gen_sofar": int(data["gen_sofar"][i]),
                "remaining": int(data["remaining"][i]),
                "hidden": data["hidden"][i], "window": data["window"][i],
            })
    else:
        records, req_lengths, req_tags = generate_requests(lm_params,
                                                           verbose=verbose)
        arrs = to_arrays(records)
        np.savez_compressed(cache, req_lengths=req_lengths, **arrs)
    splits = split_records(records, len(req_lengths))
    arrays = {k: to_arrays(v) for k, v in splits.items()}
    if verbose:
        print(f"[train_predictor] dataset: "
              f"{ {k: len(v) for k, v in splits.items()} }", flush=True)

    pparams, tt = train_llm_native(arrays["train"], arrays["val"],
                                   verbose=verbose)
    llm_native = LlmNativePredictor(pparams, tt)
    auxiliary = AuxiliaryPredictor().fit(arrays["train"], arrays["val"],
                                         verbose=verbose)
    prompt_only = PromptMeanPredictor().fit(arrays["train"])
    oracle = OraclePredictor()

    methods = [prompt_only, auxiliary, llm_native, oracle]
    res = evaluate(methods, arrays["test"])
    res["latency_ms"] = latency_table(llm_native, auxiliary, lm_params)
    res["dataset"] = {
        "n_requests": int(len(req_lengths)),
        "n_samples": int(len(records)),
        "output_len_mean": float(np.mean(req_lengths)),
        "output_len_p50": float(np.percentile(req_lengths, 50)),
        "output_len_p90": float(np.percentile(req_lengths, 90)),
        "output_len_p95": float(np.percentile(req_lengths, 95)),
        "output_len_max": int(req_lengths.max()),
    }
    base = res["table1"]["auxiliary"]["mae"]
    ours = res["table1"]["llm_native"]["mae"]
    res["mae_reduction_vs_auxiliary_pct"] = round(100 * (1 - ours / base), 2)

    # persist
    np.savez(f"{out_dir}/predictor_params.npz",
             **{f"w{i+1}": np.asarray(w) for i, w in enumerate(pparams["ws"])},
             **{f"b{i+1}": np.asarray(b) for i, b in enumerate(pparams["bs"])})
    with open(f"{out_dir}/predictor_eval.json", "w") as f:
        json.dump(res, f, indent=2)
    with open(f"{out_dir}/predictor_eval.tsv", "w") as f:
        for name, row in res["table1"].items():
            f.write(f"table1\t{name}\t{row['parameters']}\t"
                    f"{row['train_time_s']}\t{row['mae']}\n")
        for name, series in res["fig7"].items():
            if not isinstance(series, list):
                continue
            for gen, mae, n in series:
                f.write(f"fig7\t{name}\t{gen}\t{mae}\t{n}\n")
        for k, v in res["latency_ms"].items():
            f.write(f"latency\t{k}\t{round(v, 4)}\n")
        for k, v in res["dataset"].items():
            f.write(f"dataset\t{k}\t{v}\n")
    if verbose:
        print(f"[train_predictor] done in {time.time()-t_all:.0f}s; "
              f"MAE reduction vs auxiliary: "
              f"{res['mae_reduction_vs_auxiliary_pct']}%", flush=True)
    return pparams, res


if __name__ == "__main__":
    from .train_lm import load_params
    lm = load_params("../artifacts/lm_params.npz")
    run(lm)
