"""AOT pipeline: train (cached) + lower every entrypoint to HLO text.

This is the single build-time python entrypoint (`make artifacts`). It:

  1. pre-trains star-pico on the reasoning-trace corpus (cached:
     artifacts/lm_params.npz),
  2. builds the predictor dataset, trains the LLM-native MLP + baselines,
     and writes the Table-1/Fig-7 evaluation (cached:
     artifacts/predictor_{params.npz,eval.json,eval.tsv}),
  3. lowers prefill / decode_step (per batch bucket) / predictor (per
     bucket) to **HLO text** in artifacts/*.hlo.txt,
  4. dumps all parameters as raw f32 .bin files + manifest for the rust
     runtime, and model_meta.txt with every dimension rust needs.

HLO *text* (not `.serialize()`) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids cleanly.
Python never runs again after this — the rust binary is self-contained.
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import MODEL, PREDICTOR, TRAIN


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# entrypoint lowering

def lower_prefill(cfg=MODEL):
    def fn(*args):
        params = M.params_from_list(list(args[:-2]))
        tokens, plen = args[-2], args[-1]
        return M.prefill(params, tokens, plen)

    pspecs = [spec(p.shape) for p in M.params_to_list(M.init_params())]
    args = (*pspecs, spec((1, cfg.max_prompt), jnp.int32),
            spec((1,), jnp.int32))
    return to_hlo_text(jax.jit(fn).lower(*args))


def lower_decode(bucket: int, cfg=MODEL):
    def fn(*args):
        params = M.params_from_list(list(args[:-3]))
        tokens, pos, kv = args[-3], args[-2], args[-1]
        return M.decode_step(params, tokens, pos, kv, use_kernels=True,
                             interpret=True)

    pspecs = [spec(p.shape) for p in M.params_to_list(M.init_params())]
    kv_shape = (cfg.n_layers, 2, bucket, cfg.n_heads, cfg.max_seq,
                cfg.head_dim)
    args = (*pspecs, spec((bucket,), jnp.int32), spec((bucket,), jnp.int32),
            spec(kv_shape))
    return to_hlo_text(jax.jit(fn).lower(*args))


def lower_predictor(bucket: int, pcfg=PREDICTOR):
    def fn(*args):
        pparams = M.predictor_params_from_list(list(args[:-1]))
        hidden = args[-1]
        return (M.predictor_forward(pparams, hidden, interpret=True),)

    init = M.init_predictor_params()
    pspecs = [spec(p.shape) for p in M.predictor_params_to_list(init)]
    args = (*pspecs, spec((bucket, pcfg.d_in)))
    return to_hlo_text(jax.jit(fn).lower(*args))


# ---------------------------------------------------------------------------
# parameter + metadata dump

def dump_params(lm_params, pred_params, out_dir):
    pdir = os.path.join(out_dir, "params")
    os.makedirs(pdir, exist_ok=True)
    manifest = []
    for name, arr in zip(M.param_order(), M.params_to_list(lm_params)):
        a = np.ascontiguousarray(np.asarray(arr, np.float32))
        a.tofile(os.path.join(pdir, f"lm.{name}.bin"))
        manifest.append(("lm." + name, "f32",
                         "x".join(str(d) for d in a.shape)))
    for name, arr in zip(M.PREDICTOR_PARAM_NAMES,
                         M.predictor_params_to_list(pred_params)):
        a = np.ascontiguousarray(np.asarray(arr, np.float32))
        a.tofile(os.path.join(pdir, f"pred.{name}.bin"))
        manifest.append(("pred." + name, "f32",
                         "x".join(str(d) for d in a.shape)))
    with open(os.path.join(pdir, "manifest.txt"), "w") as f:
        for name, dt, shape in manifest:
            f.write(f"{name}\t{dt}\t{shape}\n")


def write_meta(out_dir, cfg=MODEL):
    lines = {
        "vocab": cfg.vocab, "d_model": cfg.d_model,
        "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
        "head_dim": cfg.head_dim, "ffn_dim": cfg.ffn_dim,
        "max_prompt": cfg.max_prompt, "max_seq": cfg.max_seq,
        "max_output": cfg.max_output,
        "decode_buckets": ",".join(str(b) for b in cfg.decode_buckets),
        "predictor_buckets": ",".join(str(b) for b in cfg.predictor_buckets),
        "kv_bytes_per_token": cfg.kv_bytes_per_token(),
        "eos": 0, "bos": 1,
        "predictor_d_in": PREDICTOR.d_in,
    }
    with open(os.path.join(out_dir, "model_meta.txt"), "w") as f:
        for k, v in lines.items():
            f.write(f"{k}={v}\n")


# ---------------------------------------------------------------------------
# pipeline

def ensure_lm(out_dir, verbose=True):
    from .train_lm import load_params, save_params, train
    path = os.path.join(out_dir, "lm_params.npz")
    if os.path.exists(path):
        if verbose:
            print(f"[aot] cached LM params: {path}", flush=True)
        return load_params(path)
    params, losses = train(verbose=verbose)
    save_params(params, path)
    with open(os.path.join(out_dir, "lm_train_loss.txt"), "w") as f:
        for i, l in enumerate(losses):
            f.write(f"{i}\t{l:.5f}\n")
    return params


def ensure_predictor(lm_params, out_dir, verbose=True):
    from .train_predictor import run
    path = os.path.join(out_dir, "predictor_params.npz")
    if os.path.exists(path):
        if verbose:
            print(f"[aot] cached predictor params: {path}", flush=True)
        data = np.load(path)
        return {"ws": [jnp.asarray(data[f"w{i}"]) for i in range(1, 5)],
                "bs": [jnp.asarray(data[f"b{i}"]) for i in range(1, 5)]}
    pparams, _res = run(lm_params, out_dir, verbose=verbose)
    return pparams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-train", action="store_true",
                    help="use freshly-initialized (untrained) weights; "
                         "for CI smoke runs only")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)
    t0 = time.time()

    if args.skip_train:
        lm_params = M.init_params(0)
        pred_params = M.init_predictor_params(0)
    else:
        lm_params = ensure_lm(out)
        pred_params = ensure_predictor(lm_params, out)

    jobs = [("prefill.hlo.txt", lambda: lower_prefill())]
    for b in MODEL.decode_buckets:
        jobs.append((f"decode_b{b}.hlo.txt",
                     lambda b=b: lower_decode(b)))
    for b in MODEL.predictor_buckets:
        jobs.append((f"predictor_b{b}.hlo.txt",
                     lambda b=b: lower_predictor(b)))
    for fname, job in jobs:
        path = os.path.join(out, fname)
        t = time.time()
        text = job()
        with open(path, "w") as f:
            f.write(text)
        print(f"[aot] {fname}: {len(text)/1e6:.2f} MB in "
              f"{time.time()-t:.1f}s", flush=True)

    dump_params(lm_params, pred_params, out)
    write_meta(out)
    print(f"[aot] artifacts complete in {time.time()-t0:.0f}s -> {out}",
          flush=True)


if __name__ == "__main__":
    main()
