"""Pallas kernel for the LLM-native length-predictor MLP (paper Eq. 2).

The whole 4-layer relu MLP runs in a single kernel invocation per row tile:
all weight panels together are ~50 K params (~200 KiB f32), far below VMEM
capacity, so the fused form is strictly better than four separate matmul
dispatches — this is the predictor's entire inference cost story
(paper Table 1: 1.33 ms @ batch 1 for the 8.4 M-param version).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROWS = 8


def _mlp_kernel(h_ref, w1, b1, w2, b2, w3, b3, w4, b4, o_ref):
    x = h_ref[...]
    x = jnp.maximum(x @ w1[...] + b1[...], 0.0)
    x = jnp.maximum(x @ w2[...] + b2[...], 0.0)
    x = jnp.maximum(x @ w3[...] + b3[...], 0.0)
    o_ref[...] = (x @ w4[...] + b4[...]).astype(o_ref.dtype)


def predictor_mlp(h, weights, biases, *, rows: int = DEFAULT_ROWS,
                  interpret: bool = True):
    """4-layer MLP head. h: [B, D] -> [B] remaining-length estimate.

    weights: [w1(D,m1), w2(m1,m2), w3(m2,m3), w4(m3,1)]; biases to match.
    """
    if len(weights) != 4 or len(biases) != 4:
        raise ValueError("predictor MLP is 4 layers (paper Eq. 2)")
    B, D = h.shape
    pad = (-B) % rows
    if pad:
        h = jnp.concatenate([h, jnp.zeros((pad, D), h.dtype)], axis=0)
    nb = h.shape[0] // rows

    in_specs = [pl.BlockSpec((rows, D), lambda i: (i, 0))]
    args = [h]
    for w, b in zip(weights, biases):
        in_specs.append(pl.BlockSpec(w.shape, lambda i: (0, 0)))
        in_specs.append(pl.BlockSpec(b.shape, lambda i: (0,)))
        args.extend([w, b])

    out = pl.pallas_call(
        _mlp_kernel,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h.shape[0], 1), h.dtype),
        interpret=interpret,
    )(*args)
    return out[:B, 0]
