"""Pallas fused transformer-FFN kernel (L1).

Fuses `gelu(x @ w1 + b1) @ w2 + b2` into one kernel so the intermediate
[rows, F] activation never round-trips HBM. Grid tiles the batch rows; the
weight panels are MXU-aligned full blocks (D=128, F=512 are already
multiples of the 128-lane systolic width — DESIGN.md §2).

VMEM per grid step at (ROWS=8, D=128, F=512): w1+w2 512 KiB, x/h/out
~18 KiB — comfortably within budget.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROWS = 8
_GELU_C = 0.7978845608028654  # sqrt(2/pi)


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...]                       # [rows, D]
    h = x @ w1_ref[...] + b1_ref[...]    # [rows, F]
    h = 0.5 * h * (1.0 + jnp.tanh(_GELU_C * (h + 0.044715 * h * h * h)))
    o_ref[...] = (h @ w2_ref[...] + b2_ref[...]).astype(o_ref.dtype)


def ffn(x, w1, b1, w2, b2, *, rows: int = DEFAULT_ROWS, interpret: bool = True):
    """Fused FFN. x: [B, D] -> [B, D]; shapes as in `ref.ffn_ref`.

    B is padded up to a multiple of `rows` internally.
    """
    B, D = x.shape
    F = w1.shape[1]
    pad = (-B) % rows
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, D), x.dtype)], axis=0)
    nb = x.shape[0] // rows

    out = pl.pallas_call(
        _ffn_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D, F), lambda i: (0, 0)),
            pl.BlockSpec((F,), lambda i: (0,)),
            pl.BlockSpec((F, D), lambda i: (0, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], D), x.dtype),
        interpret=interpret,
    )(x, w1, b1, w2, b2)
    return out[:B]
