"""Pallas decode-attention kernel (L1 hot spot).

TPU adaptation of vLLM's paged decode attention (DESIGN.md §2): instead of
one CUDA warp group per sequence reading HBM pages, the grid is
(batch, kv-blocks) and each step streams one [H, BLK, Dh] KV tile through
VMEM, folding it into an online-softmax accumulator held in VMEM scratch.
The sequence axis is the innermost ("arbitrary") grid dimension so the
accumulator for a given batch element is built up across consecutive steps.

VMEM footprint per grid step (B=8 bucket, S=640, H=4, Dh=32, BLK=128):
  k/v tiles 2 * H*BLK*Dh*4 = 128 KiB, q 0.5 KiB, acc/m/l scratch ~17 KiB
  => well under the ~4 MiB budget in DESIGN.md §8.

Run with interpret=True everywhere (CPU PJRT cannot execute Mosaic
custom-calls); the BlockSpec structure is what carries to real TPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 128
NEG_INF = -1e30


def _attn_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref,
                 acc_ref, m_ref, l_ref, *, block_s: int, scale: float):
    """One (batch b, kv-block s) grid step of online-softmax decode attention.

    Refs (as blocked by the BlockSpecs below):
      lens_ref: [B] int32 in SMEM-like memory (full array, index_map -> 0)
      q_ref:    [H, Dh]      this batch element's query
      k_ref/v_ref: [H, block_s, Dh]  the current KV tile
      o_ref:    [H, Dh]      output (written on the last sequence step)
      acc_ref:  [H, Dh] f32 scratch — running numerator
      m_ref,l_ref: [H] f32 scratch — running max / denominator
    """
    b = pl.program_id(0)
    s = pl.program_id(1)
    n_s = pl.num_programs(1)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...]                                   # [H, Dh]
    k = k_ref[...]                                   # [H, BLK, Dh]
    v = v_ref[...]

    scores = jnp.einsum("hd,hsd->hs", q, k) * scale  # [H, BLK]
    valid = (s * block_s + jax.lax.broadcasted_iota(
        jnp.int32, scores.shape, 1)) < lens_ref[b]
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_ref[...]                              # [H]
    m_cur = jnp.maximum(m_prev, scores.max(axis=1))  # [H]
    alpha = jnp.exp(m_prev - m_cur)                  # rescale old accum
    p = jnp.exp(scores - m_cur[:, None])             # [H, BLK]
    # fully-masked tiles contribute ~exp(NEG_INF - m) == 0 — no special case
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.einsum("hs,hsd->hd", p, v)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    m_ref[...] = m_cur

    @pl.when(s == n_s - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                      ).astype(o_ref.dtype)


def decode_attention(q, k, v, lens, *, block_s: int = DEFAULT_BLOCK,
                     interpret: bool = True):
    """Pallas decode attention. Shapes as in `ref.decode_attention_ref`.

    q: [B, H, Dh]; k, v: [B, H, S, Dh]; lens: [B] int32 -> out [B, H, Dh].
    S must be a multiple of block_s (the AOT path pads the KV cache).
    """
    B, H, S, Dh = k.shape
    if S % block_s != 0:
        raise ValueError(f"S={S} not a multiple of block_s={block_s}")
    n_s = S // block_s
    scale = 1.0 / (Dh ** 0.5)

    kernel = functools.partial(_attn_kernel, block_s=block_s, scale=scale)
    grid = (B, n_s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(lens.shape, lambda b, s: (0,)),            # lens: full
            pl.BlockSpec((None, H, Dh), lambda b, s: (b, 0, 0)),    # q
            pl.BlockSpec((None, H, block_s, Dh), lambda b, s: (b, 0, s, 0)),
            pl.BlockSpec((None, H, block_s, Dh), lambda b, s: (b, 0, s, 0)),
        ],
        out_specs=pl.BlockSpec((None, H, Dh), lambda b, s: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((H, Dh), jnp.float32),   # acc
            pltpu.VMEM((H,), jnp.float32),      # m
            pltpu.VMEM((H,), jnp.float32),      # l
        ],
        interpret=interpret,
    )(lens, q, k, v)
