"""Pure-jnp oracles for every Pallas kernel (L1 correctness references).

Each `*_ref` function computes exactly what the corresponding kernel in
`attention.py` / `ffn.py` / `predictor_mlp.py` must produce; pytest +
hypothesis sweep shapes and compare with `assert_allclose`.
"""

import jax.numpy as jnp


def decode_attention_ref(q, k, v, lens, scale=None):
    """Batched single-token decode attention over a padded KV cache.

    q:    [B, H, Dh]      query for the current token of each sequence
    k,v:  [B, H, S, Dh]   padded KV cache (garbage beyond lens)
    lens: [B] int32       valid KV length per sequence (>= 1)
    out:  [B, H, Dh]
    """
    B, H, S, Dh = k.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, q.dtype))
    scores = jnp.einsum("bhd,bhsd->bhs", q, k) * scale
    mask = jnp.arange(S)[None, None, :] < lens[:, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    w = jnp.nan_to_num(jnp.exp(scores - scores.max(-1, keepdims=True)))
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhs,bhsd->bhd", w, v)


def ffn_ref(x, w1, b1, w2, b2):
    """Fused transformer FFN: gelu(x @ w1 + b1) @ w2 + b2.

    x: [B, D], w1: [D, F], w2: [F, D]
    """
    h = x @ w1 + b1
    # tanh-approximation GeLU (matches the kernel exactly)
    h = 0.5 * h * (1.0 + jnp.tanh(0.7978845608028654 * (h + 0.044715 * h**3)))
    return h @ w2 + b2


def predictor_mlp_ref(h, weights, biases):
    """4-layer MLP head (paper Eq. 2): relu chain, scalar output.

    h: [B, D]; weights/biases: lists for each of the 4 layers.
    Returns [B] (squeezed last dim).
    """
    x = h
    for i, (w, b) in enumerate(zip(weights, biases)):
        x = x @ w + b
        if i < len(weights) - 1:
            x = jnp.maximum(x, 0.0)
    return x[:, 0]
