"""L2: star-pico — the small real transformer served by the rust runtime.

Architecture (scaled-down DeepSeek-R1-Distill-Qwen-7B, see DESIGN.md §1):
byte vocab 256, d=128, 4 layers, 4 heads, RoPE, RMSNorm, tied LM head.
The decode hot spots (attention-over-KV, FFN, predictor MLP) are the L1
Pallas kernels in `kernels/`; everything else (projections, norms, rope,
embedding) is plain jnp that XLA fuses.

Two AOT entrypoints (lowered by aot.py, executed from rust):

  prefill(params, tokens[1, Pmax], plen[1])
      -> (logits[1, V], kv[L, 2, 1, H, Smax, Dh], hidden[1, D])

  decode_step(params, tokens[B], pos[B], kv[L, 2, B, H, Smax, Dh])
      -> (logits[B, V], kv', hidden[B, D])

`pos[b]` is the index the new token is written at (== current valid length
of sequence b); sampling happens rust-side on the returned logits.

Params are runtime inputs (not baked constants) so rust uploads them once
as device buffers; order is defined by `param_order()` and mirrored in
artifacts/params/manifest.txt.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .configs import MODEL, PREDICTOR
from .kernels.attention import decode_attention
from .kernels.ffn import ffn
from .kernels import ref as kref

# ---------------------------------------------------------------------------
# parameters

PARAM_NAMES = [
    "emb",        # [V, D]
    "wq", "wk", "wv", "wo",   # [L, D, D]
    "w1", "b1",   # [L, D, F], [L, F]
    "w2", "b2",   # [L, F, D], [L, D]
    "rms1", "rms2",           # [L, D]
    "rms_final",  # [D]
]


def param_order():
    """Stable flattening order for the AOT interface (rust mirrors this)."""
    return list(PARAM_NAMES)


def init_params(seed: int = 0, cfg=MODEL):
    """Deterministic init; pre-training (train_lm.py) refines these."""
    rng = np.random.default_rng(seed)
    D, F, L, V = cfg.d_model, cfg.ffn_dim, cfg.n_layers, cfg.vocab

    def w(*shape, scale=None):
        scale = scale if scale is not None else (shape[-2] ** -0.5)
        return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)

    return {
        "emb": w(V, D, scale=0.02),
        "wq": w(L, D, D), "wk": w(L, D, D), "wv": w(L, D, D),
        "wo": w(L, D, D, scale=(D ** -0.5) / (2 * L) ** 0.5),
        "w1": w(L, D, F), "b1": jnp.zeros((L, F), jnp.float32),
        "w2": w(L, F, D, scale=(F ** -0.5) / (2 * L) ** 0.5),
        "b2": jnp.zeros((L, D), jnp.float32),
        "rms1": jnp.ones((L, D), jnp.float32),
        "rms2": jnp.ones((L, D), jnp.float32),
        "rms_final": jnp.ones((D,), jnp.float32),
    }


def params_to_list(params):
    return [params[n] for n in param_order()]


def params_from_list(lst):
    return dict(zip(param_order(), lst))


# ---------------------------------------------------------------------------
# building blocks

def rmsnorm(x, g, eps=1e-5):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * g


def rope(x, positions, theta=MODEL.rope_theta):
    """Rotary embedding. x: [..., T, H, Dh]; positions: [..., T]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # [..., T, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# prefill (single request, full prompt in one pass — compute-bound phase)

def prefill(params, tokens, plen, cfg=MODEL, interpret=True):
    """tokens: [1, Pmax] int32; plen: [1] int32 (valid prompt length >= 1).

    Returns (logits[1, V] of the *last valid* token, padded KV cache
    [L, 2, 1, H, Smax, Dh], hidden[1, D] of the last valid token).
    Prefill uses the jnp reference attention (one big causal pass — XLA
    fuses this fine); the Pallas kernels own the *decode* hot path.
    """
    del interpret
    L, H, Dh, D = cfg.n_layers, cfg.n_heads, cfg.head_dim, cfg.d_model
    P, S = cfg.max_prompt, cfg.max_seq
    x = params["emb"][tokens[0]]                       # [P, D]
    positions = jnp.arange(P)
    valid = positions < plen[0]

    kv = jnp.zeros((L, 2, 1, H, S, Dh), jnp.float32)
    causal = positions[None, :] <= positions[:, None]  # [P, P]
    mask = causal & valid[None, :]

    for layer in range(L):
        h = rmsnorm(x, params["rms1"][layer])
        q = (h @ params["wq"][layer]).reshape(P, H, Dh)
        k = (h @ params["wk"][layer]).reshape(P, H, Dh)
        v = (h @ params["wv"][layer]).reshape(P, H, Dh)
        q, k = rope(q, positions), rope(k, positions)
        scores = jnp.einsum("thd,shd->hts", q, k) / (Dh ** 0.5)
        scores = jnp.where(mask[None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("hts,shd->thd", w, v).reshape(P, D)
        x = x + attn @ params["wo"][layer]
        h2 = rmsnorm(x, params["rms2"][layer])
        x = x + kref.ffn_ref(h2, params["w1"][layer], params["b1"][layer],
                             params["w2"][layer], params["b2"][layer])
        kv = kv.at[layer, 0, 0, :, :P, :].set(k.transpose(1, 0, 2))
        kv = kv.at[layer, 1, 0, :, :P, :].set(v.transpose(1, 0, 2))

    x = rmsnorm(x, params["rms_final"])                # [P, D]
    last = plen[0] - 1
    hidden = jax.lax.dynamic_slice_in_dim(x, last, 1, axis=0)   # [1, D]
    logits = hidden @ params["emb"].T                  # tied head, [1, V]
    return logits, kv, hidden


# ---------------------------------------------------------------------------
# decode step (batched, memory-bound phase — the Pallas hot path)

def decode_step(params, tokens, pos, kv, cfg=MODEL, interpret=True,
                use_kernels=True):
    """One autoregressive step for a padded batch.

    tokens: [B] int32 (token to process), pos: [B] int32 (its index, i.e.
    current valid length), kv: [L, 2, B, H, Smax, Dh].
    Returns (logits[B, V], updated kv, hidden[B, D]).
    Inactive slots just compute garbage at pos and are ignored rust-side.

    use_kernels=False swaps the L1 Pallas kernels for their jnp oracles —
    numerically identical (tested), used by the build-time dataset
    generator where the Pallas *interpreter* overhead matters.
    """
    L, H, Dh, D = cfg.n_layers, cfg.n_heads, cfg.head_dim, cfg.d_model
    B = tokens.shape[0]
    x = params["emb"][tokens]                          # [B, D]
    lens = pos + 1                                     # KV valid length after write

    bidx = jnp.arange(B)
    for layer in range(L):
        h = rmsnorm(x, params["rms1"][layer])
        q = (h @ params["wq"][layer]).reshape(B, H, Dh)
        k = (h @ params["wk"][layer]).reshape(B, H, Dh)
        v = (h @ params["wv"][layer]).reshape(B, H, Dh)
        q = rope(q[:, None], pos[:, None])[:, 0]       # [B, H, Dh]
        k = rope(k[:, None], pos[:, None])[:, 0]
        # write the new k/v at each sequence's position
        kv = kv.at[layer, 0, bidx, :, pos, :].set(k)
        kv = kv.at[layer, 1, bidx, :, pos, :].set(v)
        if use_kernels:
            attn = decode_attention(q, kv[layer, 0], kv[layer, 1], lens,
                                    interpret=interpret)  # [B,H,Dh] (L1 kernel)
        else:
            attn = kref.decode_attention_ref(q, kv[layer, 0], kv[layer, 1], lens)
        x = x + attn.reshape(B, D) @ params["wo"][layer]
        h2 = rmsnorm(x, params["rms2"][layer])
        if use_kernels:
            x = x + ffn(h2, params["w1"][layer], params["b1"][layer],
                        params["w2"][layer], params["b2"][layer],
                        interpret=interpret)              # (L1 kernel)
        else:
            x = x + kref.ffn_ref(h2, params["w1"][layer], params["b1"][layer],
                                 params["w2"][layer], params["b2"][layer])

    hidden = rmsnorm(x, params["rms_final"])           # [B, D]
    logits = hidden @ params["emb"].T
    return logits, kv, hidden


# ---------------------------------------------------------------------------
# predictor head (paper Eq. 2) — separate entrypoint, run every k iters

def init_predictor_params(seed: int = 0, pcfg=PREDICTOR):
    rng = np.random.default_rng(seed)
    dims = [pcfg.d_in, *pcfg.hidden, 1]
    ws, bs = [], []
    for i in range(4):
        ws.append(jnp.asarray(
            rng.standard_normal((dims[i], dims[i + 1])) * (dims[i] ** -0.5),
            jnp.float32))
        bs.append(jnp.zeros((dims[i + 1],), jnp.float32))
    return {"ws": ws, "bs": bs}


def predictor_forward(pparams, hidden, interpret=True):
    """hidden: [B, D] -> predicted remaining length [B] (token units).

    The MLP regresses log1p(remaining); expm1 restores token units so the
    rust scheduler consumes plain token counts.
    """
    from .kernels.predictor_mlp import predictor_mlp
    y = predictor_mlp(hidden, pparams["ws"], pparams["bs"], interpret=interpret)
    if PREDICTOR.log_target:
        y = jnp.expm1(jnp.maximum(y, 0.0))
    else:
        y = jnp.maximum(y, 0.0) * PREDICTOR.scale
    return y


def predictor_params_to_list(pparams):
    out = []
    for w, b in zip(pparams["ws"], pparams["bs"]):
        out.extend([w, b])
    return out


def predictor_params_from_list(lst):
    return {"ws": [lst[0], lst[2], lst[4], lst[6]],
            "bs": [lst[1], lst[3], lst[5], lst[7]]}


PREDICTOR_PARAM_NAMES = ["pw1", "pb1", "pw2", "pb2", "pw3", "pb3", "pw4", "pb4"]


# ---------------------------------------------------------------------------
# training-mode forward (full-sequence logits; used by train_lm.py)

def lm_forward_train(params, tokens, cfg=MODEL):
    """tokens: [B, T] -> logits [B, T, V]. Dense causal pass, jnp-only
    (training happens once at build time; no pallas needed)."""
    B, T = tokens.shape
    L, H, Dh, D = cfg.n_layers, cfg.n_heads, cfg.head_dim, cfg.d_model
    x = params["emb"][tokens]                          # [B, T, D]
    positions = jnp.arange(T)
    causal = positions[None, :] <= positions[:, None]

    for layer in range(L):
        h = rmsnorm(x, params["rms1"][layer])
        q = (h @ params["wq"][layer]).reshape(B, T, H, Dh)
        k = (h @ params["wk"][layer]).reshape(B, T, H, Dh)
        v = (h @ params["wv"][layer]).reshape(B, T, H, Dh)
        q, k = rope(q, positions[None]), rope(k, positions[None])
        scores = jnp.einsum("bthd,bshd->bhts", q, k) / (Dh ** 0.5)
        scores = jnp.where(causal[None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhts,bshd->bthd", w, v).reshape(B, T, D)
        x = x + attn @ params["wo"][layer]
        h2 = rmsnorm(x, params["rms2"][layer])
        x = x + kref.ffn_ref(h2, params["w1"][layer], params["b1"][layer],
                             params["w2"][layer], params["b2"][layer])

    x = rmsnorm(x, params["rms_final"])
    return x @ params["emb"].T
