"""Predictor supervised-dataset construction (paper §4.4).

Runs the pre-trained star-pico LM over synthetic prompts with temperature
sampling (so realized lengths are stochastic, as in real serving), and
records at fixed decode intervals:

  * the last-layer last-token hidden state  h_t   (LLM-native input)
  * the last `aux_window` raw tokens                (auxiliary-model input)
  * the prompt tag and generated-so-far count
  * the ground-truth remaining length      y_t

Split is at *request* level (70/15/15) so samples from one request never
straddle splits (paper's leakage guard).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .configs import CORPUS, MODEL, TRAIN
from .corpus import make_prompt


@jax.jit
def _gen_step(params, tokens, pos, kv, key, temp):
    logits, kv, hidden = M.decode_step(params, tokens, pos, kv,
                                       use_kernels=False)
    nxt = jax.random.categorical(key, logits / temp, axis=-1)
    return nxt.astype(jnp.int32), kv, hidden


@jax.jit
def _prefill1(params, toks, plen):
    return M.prefill(params, toks, plen)


def generate_requests(params, n_requests=None, seed=None, record_every=None,
                      verbose=True):
    """Returns (records, request_lengths).

    records: list of dicts with keys
      req, tag, gen_sofar, remaining, hidden [D] f32, window [W] int32
    request_lengths: realized output length per request (for workload stats).
    """
    cfg, tcfg = MODEL, TRAIN
    n_requests = n_requests or tcfg.gen_requests
    seed = tcfg.gen_seed if seed is None else seed
    record_every = record_every or tcfg.record_every
    B = tcfg.gen_batch
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)

    records, req_lengths, req_tags = [], [], []
    t0 = time.time()
    for start in range(0, n_requests, B):
        nb = min(B, n_requests - start)
        tags = [int(rng.integers(CORPUS.n_tags)) for _ in range(nb)]
        prompts = [make_prompt(rng, t) for t in tags]

        # prefill each request (B=1 entrypoint, as in the serving path)
        kv = jnp.zeros((cfg.n_layers, 2, B, cfg.n_heads, cfg.max_seq,
                        cfg.head_dim), jnp.float32)
        cur_tok = np.ones(B, np.int32)
        pos = np.zeros(B, np.int32)
        token_hist = [[] for _ in range(B)]
        for i, p in enumerate(prompts):
            toks = np.zeros((1, cfg.max_prompt), np.int32)
            toks[0, : len(p)] = p
            logits, kv1, hidden = _prefill1(params, jnp.asarray(toks),
                                            jnp.asarray([len(p)], jnp.int32))
            kv = kv.at[:, :, i : i + 1].set(kv1)
            key, sk = jax.random.split(key)
            cur_tok[i] = int(jax.random.categorical(
                sk, logits[0] / tcfg.sample_temp))
            pos[i] = len(p)
            token_hist[i] = list(p)

        plens = np.array([len(p) for p in prompts] + [1] * (B - nb))
        done = np.zeros(B, bool)
        done[nb:] = True
        n_gen = np.zeros(B, np.int32)
        # traj[i] = list of (gen_sofar, hidden, window) snapshots
        traj = [[] for _ in range(B)]

        # snapshot at gen_sofar=0 comes from prefill hidden state: record it
        # on the first decode step below (hidden of prefill last token).
        step = 0
        max_steps = cfg.max_output
        while not done.all() and step < max_steps:
            key, sk = jax.random.split(key)
            nxt, kv, hidden = _gen_step(params, jnp.asarray(cur_tok),
                                        jnp.asarray(pos), kv, sk,
                                        jnp.float32(tcfg.sample_temp))
            hidden_np = np.asarray(hidden)
            if step % record_every == 0:
                for i in range(nb):
                    if not done[i]:
                        w = token_hist[i][-tcfg.aux_window:]
                        w = [0] * (tcfg.aux_window - len(w)) + w
                        traj[i].append((int(n_gen[i]), hidden_np[i].copy(),
                                        np.array(w, np.int32)))
            nxt_np = np.asarray(nxt)
            for i in range(nb):
                if done[i]:
                    continue
                token_hist[i].append(int(cur_tok[i]))
                n_gen[i] += 1
                pos[i] += 1
                if int(nxt_np[i]) == CORPUS.eos or \
                        pos[i] >= cfg.max_seq - 1 or \
                        n_gen[i] >= cfg.max_output:
                    done[i] = True
                else:
                    cur_tok[i] = int(nxt_np[i])
            step += 1

        for i in range(nb):
            total = int(n_gen[i])
            req_lengths.append(total)
            req_tags.append(tags[i])
            for gen_sofar, hid, win in traj[i]:
                records.append({
                    "req": start + i, "tag": tags[i],
                    "gen_sofar": gen_sofar,
                    "remaining": total - gen_sofar,
                    "hidden": hid, "window": win,
                })
        if verbose:
            print(f"[gen_dataset] {start+nb}/{n_requests} requests, "
                  f"{len(records)} samples, {time.time()-t0:.0f}s", flush=True)
    return records, np.array(req_lengths), np.array(req_tags)


def split_records(records, n_requests, seed=0):
    """Request-level 70/15/15 split (paper §4.4)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_requests)
    n_tr = int(TRAIN.split_train * n_requests)
    n_va = int(TRAIN.split_val * n_requests)
    tr = set(perm[:n_tr].tolist())
    va = set(perm[n_tr : n_tr + n_va].tolist())
    out = {"train": [], "val": [], "test": []}
    for r in records:
        if r["req"] in tr:
            out["train"].append(r)
        elif r["req"] in va:
            out["val"].append(r)
        else:
            out["test"].append(r)
    return out


def to_arrays(recs):
    return {
        "hidden": np.stack([r["hidden"] for r in recs]).astype(np.float32),
        "window": np.stack([r["window"] for r in recs]).astype(np.int32),
        "remaining": np.array([r["remaining"] for r in recs], np.float32),
        "gen_sofar": np.array([r["gen_sofar"] for r in recs], np.int32),
        "tag": np.array([r["tag"] for r in recs], np.int32),
        "req": np.array([r["req"] for r in recs], np.int32),
    }
