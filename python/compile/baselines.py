"""Baseline generation-length predictors (paper Table 1 analogs).

* `AuxiliaryPredictor` — the TetriInfer / mu-Serve analog: a small
  transformer regressor over a **truncated** window of recent raw tokens.
  The truncation is the defining limitation the paper exploits (opt: 1024,
  bert: 512 tokens); here the window is TRAIN.aux_window tokens against
  sequences that grow to 512+, reproducing the same information loss.
* `PromptMeanPredictor` — the PiA analog: training-free, prompt-only.
  Predicts the corpus-wide mean total length (it never sees generation
  progress), minus tokens generated so far, floored at 0.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from .configs import PREDICTOR, TRAIN


def _aux_init(seed=0, vocab=256, d=None, layers=None, heads=None, window=None):
    d = d or TRAIN.aux_d
    layers = layers or TRAIN.aux_layers
    rng = np.random.default_rng(seed)

    def w(*shape, scale=None):
        scale = scale if scale is not None else (shape[-2] ** -0.5)
        return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)

    window = window or TRAIN.aux_window
    return {
        "emb": w(vocab, d, scale=0.02),
        "pos": w(window, d, scale=0.02),
        "wq": w(layers, d, d), "wk": w(layers, d, d),
        "wv": w(layers, d, d), "wo": w(layers, d, d),
        "w1": w(layers, d, 4 * d), "w2": w(layers, 4 * d, d),
        "head_w": w(d, 1), "head_b": jnp.zeros((1,), jnp.float32),
    }


def _aux_forward(params, windows):
    """windows: [B, W] int32 (0-padded on the left) -> log1p(remaining) [B]."""
    B, W = windows.shape
    layers = params["wq"].shape[0]
    d = params["emb"].shape[1]
    heads = TRAIN.aux_heads
    dh = d // heads
    x = params["emb"][windows] + params["pos"][None]
    idx = jnp.arange(W)
    causal = idx[None, :] <= idx[:, None]
    for l in range(layers):
        q = (x @ params["wq"][l]).reshape(B, W, heads, dh)
        k = (x @ params["wk"][l]).reshape(B, W, heads, dh)
        v = (x @ params["wv"][l]).reshape(B, W, heads, dh)
        s = jnp.einsum("bthd,bshd->bhts", q, k) / (dh ** 0.5)
        s = jnp.where(causal[None, None], s, -1e30)
        a = jax.nn.softmax(s, axis=-1)
        x = x + jnp.einsum("bhts,bshd->bthd", a, v).reshape(B, W, d) \
            @ params["wo"][l]
        h = x @ params["w1"][l]
        x = x + jnp.maximum(h, 0.0) @ params["w2"][l]
    pooled = x.mean(axis=1)
    return (pooled @ params["head_w"] + params["head_b"])[:, 0]


def aux_param_count(params):
    return int(sum(np.prod(p.shape) for p in jax.tree_util.tree_leaves(params)))


class AuxiliaryPredictor:
    """Truncated-context transformer regressor (trained with L1 loss)."""

    name = "auxiliary"

    def __init__(self, seed=0):
        self.params = _aux_init(seed)
        self.train_time_s = 0.0

    def fit(self, train_arrays, val_arrays, epochs=None, verbose=False):
        epochs = epochs or TRAIN.pred_epochs
        lr = TRAIN.pred_lr
        bsz = TRAIN.pred_batch
        def tfm(r):
            if PREDICTOR.log_target:
                return jnp.log1p(r)
            return r / PREDICTOR.scale
        Xtr = jnp.asarray(train_arrays["window"])
        ytr = tfm(jnp.asarray(train_arrays["remaining"]))
        Xva = jnp.asarray(val_arrays["window"])
        yva = tfm(jnp.asarray(val_arrays["remaining"]))

        def loss_fn(p, X, y):
            return jnp.abs(_aux_forward(p, X) - y).mean()

        @jax.jit
        def step(p, m, v, t, X, y):
            loss, g = jax.value_and_grad(loss_fn)(p, X, y)
            t = t + 1
            m = jax.tree_util.tree_map(lambda m, g: 0.9 * m + 0.1 * g, m, g)
            v = jax.tree_util.tree_map(lambda v, g: 0.95 * v + 0.05 * g * g, v, g)
            p = jax.tree_util.tree_map(
                lambda p, m, v: p - lr * (m / (1 - 0.9 ** t)) /
                (jnp.sqrt(v / (1 - 0.95 ** t)) + 1e-8), p, m, v)
            return p, m, v, t, loss

        val_loss = jax.jit(loss_fn)
        m = jax.tree_util.tree_map(jnp.zeros_like, self.params)
        v = jax.tree_util.tree_map(jnp.zeros_like, self.params)
        t = jnp.zeros((), jnp.float32)
        best, best_p, patience = np.inf, self.params, 0
        rng = np.random.default_rng(0)
        n = Xtr.shape[0]
        t0 = time.time()
        p = self.params
        for ep in range(epochs):
            order = rng.permutation(n)
            for s in range(0, n - bsz + 1, bsz):
                idx = order[s : s + bsz]
                p, m, v, t, _ = step(p, m, v, t, Xtr[idx], ytr[idx])
            vl = float(val_loss(p, Xva, yva))
            if verbose:
                print(f"[aux] epoch {ep} val L1(log) {vl:.4f}", flush=True)
            if vl < best - 1e-4:
                best, best_p, patience = vl, p, 0
            else:
                patience += 1
                if patience >= TRAIN.pred_patience:
                    break
        self.params = best_p
        self.train_time_s = time.time() - t0
        return self

    def predict(self, arrays):
        out = []
        X = jnp.asarray(arrays["window"])
        fwd = jax.jit(_aux_forward)
        for s in range(0, X.shape[0], 512):
            y = fwd(self.params, X[s : s + 512])
            if PREDICTOR.log_target:
                y = jnp.expm1(jnp.maximum(y, 0.0))
            else:
                y = jnp.maximum(y, 0.0) * PREDICTOR.scale
            out.append(np.asarray(y))
        return np.clip(np.concatenate(out), 0, None)

    def param_count(self):
        return aux_param_count(self.params)


class PromptMeanPredictor:
    """PiA analog: training-free, prompt-only constant estimate."""

    name = "prompt_only"

    def __init__(self):
        self.mean_total = 0.0
        self.train_time_s = 0.0

    def fit(self, train_arrays, val_arrays=None, **_):
        # "training-free": uses only the corpus-wide average as the LLM's
        # zero-shot guess; no gradient steps (paper: PiA training time 0).
        totals = train_arrays["remaining"] + train_arrays["gen_sofar"]
        self.mean_total = float(np.mean(totals))
        return self

    def predict(self, arrays):
        rem = self.mean_total - arrays["gen_sofar"]
        return np.clip(rem, 0, None)

    def param_count(self):
        return 0
