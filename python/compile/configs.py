"""Model / corpus / training configuration for the star-pico stack.

Single source of truth for every dimension that the AOT artifacts bake in.
`rust/src/runtime/meta.rs` parses the emitted `artifacts/model_meta.txt`,
so anything added here that rust needs must also be written by
`aot.write_meta`.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    """star-pico: the small real transformer served end-to-end.

    A deliberate scale-down of DeepSeek-R1-Distill-Qwen-7B (paper §6.1):
    byte-level vocab, RoPE, RMSNorm, tied LM head. Per-token decode cost is
    a real attention-over-KV + FFN step, which is all the scheduler sees.
    """

    vocab: int = 256          # byte-level tokenizer; 0 = EOS, 1 = BOS
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    head_dim: int = 32        # d_model / n_heads
    ffn_dim: int = 512
    max_prompt: int = 128     # prefill padded length
    max_seq: int = 640        # KV cache capacity per request (prompt+output)
    max_output: int = 512     # generation cap at real-execution scale
    rope_theta: float = 10_000.0

    # decode-batch buckets the AOT path emits executables for
    decode_buckets: tuple = (1, 2, 4, 8)
    predictor_buckets: tuple = (1, 2, 4, 8, 16)

    @property
    def kv_shape_per_req(self):
        # [layers, k/v, heads, max_seq, head_dim]
        return (self.n_layers, 2, self.n_heads, self.max_seq, self.head_dim)

    def kv_bytes_per_token(self) -> int:
        return self.n_layers * 2 * self.n_heads * self.head_dim * 4


@dataclass(frozen=True)
class PredictorConfig:
    """LLM-native remaining-length predictor (paper §4.2, Eq. 2).

    Paper: d=3584 -> 2048 -> 512 -> 64 -> 1 (8.4M params).
    Scaled to star-pico's d=128: 128 -> 256 -> 64 -> 16 -> 1 (~50K params),
    preserving the 4-layer-MLP-on-last-hidden-state architecture.
    """

    d_in: int = 128
    hidden: tuple = (256, 64, 16)
    # target parameterization: raw remaining scaled by `scale` (log1p was
    # tried first but biases token-unit MAE down via Jensen's inequality)
    log_target: bool = False
    scale: float = 64.0


@dataclass(frozen=True)
class CorpusConfig:
    """Synthetic 'reasoning-trace' language (DESIGN.md §1).

    Prompts carry a task tag that determines the *distribution* of the
    number of reasoning paragraphs; realized length is stochastic, so
    prompt-only prediction has an irreducible error while hidden-state /
    continuous prediction can do better — the structure Fig. 7 needs.
    """

    n_tags: int = 16
    tag_bytes: bytes = b"abcdefghijklmnop"
    lam_min: float = 1.0       # Poisson rate of paragraph count, shortest tag
    lam_max: float = 14.0      # ... longest tag
    payload_min: int = 4
    payload_max: int = 16
    par_min: int = 8           # filler bytes per paragraph
    par_max: int = 24
    bos: int = 1
    eos: int = 0
    q_byte: int = ord("Q")
    sep_byte: int = ord("?")
    step_byte: int = ord("s")
    colon_byte: int = ord(":")
    nl_byte: int = ord("\n")
    filler_bytes: bytes = b"etaoinshrdlucmfwyp"


@dataclass(frozen=True)
class TrainConfig:
    # LM pre-training (build time, cached in artifacts/)
    lm_steps: int = 600
    lm_batch: int = 8
    lm_seq: int = 256
    lm_lr: float = 3e-3
    lm_warmup: int = 50
    lm_seed: int = 0

    # predictor dataset generation
    gen_requests: int = 320
    gen_batch: int = 16
    sample_temp: float = 0.9
    record_every: int = 8      # record (hidden, remaining) every N tokens
    gen_seed: int = 7

    # predictor training (paper §4.4: L1 loss, AdamW, early stop)
    pred_epochs: int = 100
    pred_patience: int = 10
    pred_batch: int = 128
    pred_lr: float = 1e-3
    pred_seed: int = 3
    split_train: float = 0.70
    split_val: float = 0.15    # remainder is test

    # auxiliary baseline (TetriInfer/mu-Serve analog): truncated context
    aux_window: int = 48       # tokens of visible context (the limitation)
    aux_d: int = 32
    aux_layers: int = 2
    aux_heads: int = 2


MODEL = ModelConfig()
PREDICTOR = PredictorConfig()
CORPUS = CorpusConfig()
TRAIN = TrainConfig()
