"""Build-time pre-training of star-pico on the reasoning-trace corpus.

The LM must learn the corpus' length structure (tag -> paragraph count,
paragraph shape, EOS placement) so that (a) sampled generations have the
heavy-tailed length distribution the scheduler experiments need, and
(b) its hidden states genuinely encode remaining-length information for
the LLM-native predictor (paper §4).

Runs once via `make artifacts`; cached as artifacts/lm_params.npz.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .configs import MODEL, TRAIN
from .corpus import make_training_batch


def loss_fn(params, tokens, mask):
    logits = M.lm_forward_train(params, tokens)            # [B, T, V]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def adamw_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.01):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                               state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p),
        params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


@jax.jit
def train_step(params, opt, tokens, mask, lr):
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, mask)
    params, opt = adamw_update(params, grads, opt, lr)
    return params, opt, loss


def train(steps=None, verbose=True):
    cfg = TRAIN
    steps = steps or cfg.lm_steps
    rng = np.random.default_rng(cfg.lm_seed)
    params = M.init_params(cfg.lm_seed)
    opt = adamw_init(params)
    t0 = time.time()
    losses = []
    for step in range(steps):
        toks, mask = make_training_batch(rng, cfg.lm_batch, cfg.lm_seq)
        warm = min(1.0, (step + 1) / cfg.lm_warmup)
        decay = 0.5 * (1 + np.cos(np.pi * step / steps))
        lr = cfg.lm_lr * warm * (0.1 + 0.9 * decay)
        params, opt, loss = train_step(params, opt,
                                       jnp.asarray(toks), jnp.asarray(mask),
                                       jnp.float32(lr))
        losses.append(float(loss))
        if verbose and (step % 50 == 0 or step == steps - 1):
            print(f"[train_lm] step {step:4d} loss {float(loss):.4f} "
                  f"lr {lr:.2e} elapsed {time.time()-t0:.0f}s", flush=True)
    return params, losses


def save_params(params, path):
    np.savez(path, **{k: np.asarray(v) for k, v in params.items()})


def load_params(path):
    data = np.load(path)
    return {k: jnp.asarray(data[k]) for k in data.files}


if __name__ == "__main__":
    import sys
    out = sys.argv[1] if len(sys.argv) > 1 else "../artifacts/lm_params.npz"
    params, losses = train()
    save_params(params, out)
    print(f"final loss {losses[-1]:.4f} -> {out}")
