"""Synthetic 'reasoning-trace' corpus (DESIGN.md §1 substitution for ShareGPT).

The language is designed so that generation length has the structure the
paper's prediction experiments rely on:

  * a prompt tag determines the *distribution* of paragraph count
    (expected output length spans ~15x across tags — paper Fig. 1's
    ">16x output variation"),
  * realized length is stochastic (Poisson paragraphs x uniform paragraph
    bodies), so prompt-only prediction has irreducible error,
  * progress is observable mid-generation (paragraph headers "s<i>:"),
    so hidden-state / continuous predictors improve as tokens accumulate
    (paper Fig. 7's falling-MAE curve).

Byte-level tokens; 0 = EOS, 1 = BOS.
"""

import numpy as np

from .configs import CORPUS, MODEL


def make_prompt(rng: np.random.Generator, tag: int, cfg=CORPUS):
    """[BOS 'Q' <tag-byte> <payload> '?'] as a list of ints."""
    payload_len = int(rng.integers(cfg.payload_min, cfg.payload_max + 1))
    payload = rng.integers(ord("a"), ord("z") + 1, payload_len).tolist()
    return [cfg.bos, cfg.q_byte, cfg.tag_bytes[tag], *payload, cfg.sep_byte]


def make_response(rng: np.random.Generator, tag: int, cfg=CORPUS,
                  max_len: int | None = None):
    """Reasoning trace: n~Poisson(lam(tag))+1 paragraphs, then EOS.

    Paragraph headers deliberately carry NO explicit step index: progress
    through the trace is only observable by *counting* paragraphs, which a
    truncated-window auxiliary model cannot do but the generating model's
    own hidden state tracks — the paper's core information asymmetry
    (§4.2). An earlier corpus revision printed "s<i>:" headers and the
    auxiliary baseline could read progress straight off the window,
    erasing the LLM-native advantage.
    """
    lam = cfg.lam_min + (cfg.lam_max - cfg.lam_min) * tag / (cfg.n_tags - 1)
    n_par = int(rng.poisson(lam)) + 1
    out = []
    # CoT-style plan: "p:" + one '*' per planned paragraph. The model
    # learns to (a) sample a plan whose size depends on the prompt tag and
    # (b) follow it — so remaining length is *knowable* from the full
    # context (count stars vs paragraphs emitted), which the hidden state
    # retains but a truncated token window loses once generation moves past
    # the plan. This mirrors real reasoning traces, where the model's early
    # commitment to an approach determines the trace length.
    out.append(ord("p"))
    out.append(cfg.colon_byte)
    out.extend([ord("*")] * n_par)
    out.append(cfg.nl_byte)
    for _i in range(n_par):
        out.append(cfg.step_byte)
        out.append(cfg.colon_byte)
        body_len = int(rng.integers(cfg.par_min, cfg.par_max + 1))
        body = rng.choice(list(cfg.filler_bytes), body_len).tolist()
        out.extend(int(b) for b in body)
        out.append(cfg.nl_byte)
        if max_len is not None and len(out) >= max_len - 1:
            out = out[: max_len - 1]
            break
    out.append(cfg.eos)
    return out


def make_example(rng: np.random.Generator, cfg=CORPUS, model_cfg=MODEL):
    """(prompt, response) pair bounded by the model's sequence budget."""
    tag = int(rng.integers(cfg.n_tags))
    prompt = make_prompt(rng, tag)
    max_resp = model_cfg.max_seq - len(prompt)
    response = make_response(rng, tag, max_len=min(max_resp,
                                                   model_cfg.max_output))
    return tag, prompt, response


def make_training_batch(rng: np.random.Generator, batch: int, seq: int,
                        cfg=CORPUS):
    """Packed next-token-prediction batch.

    Returns tokens [batch, seq] int32 and loss mask [batch, seq-1] f32
    (mask excludes prompt positions? No — LM learns the full distribution
    including prompts; mask only excludes padding).
    """
    toks = np.zeros((batch, seq), np.int32)
    mask = np.zeros((batch, seq - 1), np.float32)
    for b in range(batch):
        tag, prompt, response = make_example(rng)
        seq_toks = (prompt + response)[:seq]
        toks[b, : len(seq_toks)] = seq_toks
        mask[b, : max(len(seq_toks) - 1, 1)] = 1.0
    return toks, mask


def expected_length_by_tag(cfg=CORPUS):
    """Analytic E[response length] per tag — prompt-only oracle baseline."""
    out = []
    avg_par = ((cfg.par_min + cfg.par_max) / 2  # body
               + 1 + 1                          # 's', ':'
               + 1)                             # newline
    for tag in range(cfg.n_tags):
        lam = cfg.lam_min + (cfg.lam_max - cfg.lam_min) * tag / (cfg.n_tags - 1)
        n_par = lam + 1
        plan = 2 + n_par + 1                    # "p:" + stars + newline
        out.append(plan + n_par * avg_par + 1)
    return out
