#!/usr/bin/env bash
# Tier-1 gate (DESIGN.md §9): build + tests + formatting for the rust
# crate. Run from anywhere; exits non-zero on the first failure.
set -euo pipefail
cd "$(dirname "$0")/rust"

cargo build --release
cargo test -q
cargo fmt --check
