#!/usr/bin/env bash
# Tier-1 gate (DESIGN.md §9): build + tests + formatting + lint for the
# rust crate, plus an optional bench smoke gate. Run from anywhere.
#
# Usage:
#   ./ci.sh             build, test, fmt, clippy
#   ./ci.sh --smoke     ... plus run every bench at smoke scale
#                       (STAR_BENCH_SMOKE=1: ≤2k requests, ≤8 instances),
#                       validate every emitted BENCH_*.json, smoke the
#                       `star trace` observability surface (export both
#                       formats + slo-violations), and check the sharded
#                       event core (--shards 2 output must match serial)
#   ./ci.sh --bench NAME  build + run ONE bench (benches/NAME.rs) at smoke
#                       scale and validate its BENCH_*.json — the quick
#                       inner loop while iterating on a single bench
#   ./ci.sh --soak      build + reliability soak: several seeds of the
#                       fault-injection / heterogeneous-fleet scenarios
#                       (degraded_fleet, mixed_gen) with --fail-on-lost,
#                       then the reliability bench + JSON validation —
#                       the scheduled CI soak job's entry point
#   ./ci.sh --no-lint   skip fmt/clippy (CI runs them as a separate job
#                       so lint failures report independently of tests)
#   ./ci.sh --no-analyze  skip the `star analyze` determinism/safety lint
#                       (CI runs it as a separate job, like --no-lint)
#   STAR_BENCH_SMOKE=1 ./ci.sh   same as --smoke
#
# Every step is timed; on failure the script names the failing step
# (build/test/fmt/clippy/analyze/smoke/bench) so CI logs are triageable
# at a glance.
set -uo pipefail
cd "$(dirname "$0")/rust" || exit 1

SMOKE=0
SOAK=0
LINT=1
ANALYZE=1
BENCH_ONLY=""
while [ $# -gt 0 ]; do
  case "$1" in
    --smoke) SMOKE=1 ;;
    --soak) SOAK=1 ;;
    --no-lint) LINT=0 ;;
    --no-analyze) ANALYZE=0 ;;
    --bench)
      if [ $# -lt 2 ]; then
        echo "ci.sh: --bench expects a bench name (see benches/*.rs)" >&2
        exit 2
      fi
      shift
      BENCH_ONLY="$1"
      ;;
    *)
      echo "ci.sh: unknown argument \`$1\` (supported: --smoke, --soak, --bench NAME, --no-lint, --no-analyze)" >&2
      exit 2
      ;;
  esac
  shift
done

if [ -n "$BENCH_ONLY" ] && [ ! -f "benches/$BENCH_ONLY.rs" ]; then
  echo "ci.sh: unknown bench \`$BENCH_ONLY\`; known:" >&2
  for f in benches/*.rs; do echo "  $(basename "$f" .rs)" >&2; done
  exit 2
fi
# any non-empty value other than "0" enables smoke mode — the same rule
# the benches' smoke() helper applies, so the two can never disagree
if [ -n "${STAR_BENCH_SMOKE:-}" ] && [ "${STAR_BENCH_SMOKE}" != "0" ]; then
  SMOKE=1
fi

STEP_NAMES=()
STEP_TIMES=()

print_summary() {
  echo ""
  echo "---- ci.sh step timing ----"
  local i
  for i in "${!STEP_NAMES[@]}"; do
    printf '  %-8s %5ss\n' "${STEP_NAMES[$i]}" "${STEP_TIMES[$i]}"
  done
}

run_step() {
  local name="$1"
  shift
  echo "==> [$name] $*"
  local t0=$SECONDS
  if ! "$@"; then
    local dt=$(( SECONDS - t0 ))
    STEP_NAMES+=("$name"); STEP_TIMES+=("$dt")
    print_summary
    echo "ci.sh: FAILED at step \`$name\` after ${dt}s" >&2
    exit 1
  fi
  local dt=$(( SECONDS - t0 ))
  STEP_NAMES+=("$name"); STEP_TIMES+=("$dt")
}

# Expected bench outputs: the first argument of each BenchJson::new call
# in benches/*.rs. --smoke hands this list to `validate-bench --require`,
# so a bench that is deleted, renamed, or silently stops emitting its
# JSON fails the gate instead of quietly shrinking it. Keep in sync when
# adding a bench (check: grep -A1 'BenchJson::new' benches/*.rs).
EXPECTED_BENCHES="fig2_workload,fig3_imbalance,fig7_continuous,predictor,fig8_costmodel,fig10_end2end,fig11_variance,fig12_traces,fig13_scaling,elastic,prefix_cache,reliability,sim_core,table1_predictor,table3_bins,table4_interval"

# Per-bench smoke logs land here (inside the cargo target dir, so CI can
# upload them as an artifact on failure and `cargo clean` sweeps them).
SMOKE_LOG_DIR="target/smoke-logs"

# Every benches/*.rs at reduced scale; all BENCH_*.json must parse and
# carry schema_version (enforced through the shared writer in
# src/bench/output.rs + `star validate-bench`), and every name in
# EXPECTED_BENCHES must be present.
smoke_gate() {
  rm -f BENCH_*.json
  mkdir -p "$SMOKE_LOG_DIR"
  # derive the list from benches/*.rs so a newly added bench cannot
  # silently escape the gate (an unregistered .rs fails `cargo bench`)
  local benches=()
  local f
  for f in benches/*.rs; do
    benches+=("$(basename "$f" .rs)")
  done
  if [ "${#benches[@]}" -eq 0 ]; then
    echo "smoke: no benches/*.rs found" >&2
    return 1
  fi
  local b
  for b in "${benches[@]}"; do
    echo "==> [smoke] cargo bench --bench $b"
    if ! STAR_BENCH_SMOKE=1 cargo bench --bench "$b" > "$SMOKE_LOG_DIR/$b.log" 2>&1; then
      echo "smoke: bench $b failed; last 40 log lines (full log: rust/$SMOKE_LOG_DIR/$b.log):" >&2
      tail -n 40 "$SMOKE_LOG_DIR/$b.log" >&2
      return 1
    fi
  done
  local files=(BENCH_*.json)
  if [ ! -e "${files[0]}" ]; then
    echo "smoke: no BENCH_*.json emitted" >&2
    return 1
  fi
  ./target/release/star validate-bench --require "$EXPECTED_BENCHES" "${files[@]}"
}

# Observability smoke: a small run through every `star trace` surface.
# Chrome export re-parses through the binary's own JSON parser before it
# prints (self-validating), jsonl must be non-empty, and slo-violations
# must exit 0 whether or not the run violated anything.
obs_gate() {
  local common=(--scenario bursty_mixed --requests 40 --rps 0.5 \
                --kv-capacity 400000 --seed 13)
  echo "==> [obs] star trace export --format chrome"
  if ! ./target/release/star trace export --format chrome "${common[@]}" \
        > "$SMOKE_LOG_DIR/trace_chrome.json"; then
    echo "obs: chrome export failed" >&2
    return 1
  fi
  if [ ! -s "$SMOKE_LOG_DIR/trace_chrome.json" ]; then
    echo "obs: chrome export emitted an empty payload" >&2
    return 1
  fi
  echo "==> [obs] star trace export --format jsonl"
  if ! ./target/release/star trace export --format jsonl "${common[@]}" \
        > "$SMOKE_LOG_DIR/trace.jsonl"; then
    echo "obs: jsonl export failed" >&2
    return 1
  fi
  if [ ! -s "$SMOKE_LOG_DIR/trace.jsonl" ]; then
    echo "obs: jsonl export emitted an empty payload" >&2
    return 1
  fi
  echo "==> [obs] star trace slo-violations"
  if ! ./target/release/star trace slo-violations "${common[@]}" \
        > "$SMOKE_LOG_DIR/trace_slo.txt"; then
    echo "obs: slo-violations failed" >&2
    return 1
  fi
  echo "==> [obs] star trace summarize"
  ./target/release/star trace summarize "${common[@]}"
}

# Sharded-core smoke: one scenario at --shards 2 with the state/rollup
# validator on must print byte-identical output to the serial engine
# (--shards 1, validator off) — the determinism contract of DESIGN.md
# §17 enforced at the CLI surface, not just in unit tests.
shard_gate() {
  local common=(simulate --scenario bursty_mixed --requests 40 --rps 0.5 \
                --kv-capacity 400000 --seed 13)
  echo "==> [shard] star simulate --shards 1 (serial baseline)"
  if ! ./target/release/star "${common[@]}" --shards 1 \
        > "$SMOKE_LOG_DIR/shard_serial.txt"; then
    echo "shard: serial baseline run failed" >&2
    return 1
  fi
  echo "==> [shard] star simulate --shards 2 --validate-state"
  if ! ./target/release/star "${common[@]}" --shards 2 --validate-state \
        > "$SMOKE_LOG_DIR/shard_sharded.txt"; then
    echo "shard: sharded run failed (rollup/state validation?)" >&2
    return 1
  fi
  if ! diff -u "$SMOKE_LOG_DIR/shard_serial.txt" "$SMOKE_LOG_DIR/shard_sharded.txt"; then
    echo "shard: --shards 2 output diverged from the serial engine" >&2
    return 1
  fi
}

# single-bench fast path: build, run it at smoke scale, validate its JSON
single_bench() {
  rm -f BENCH_*.json
  if ! STAR_BENCH_SMOKE=1 cargo bench --bench "$BENCH_ONLY"; then
    return 1
  fi
  local files=(BENCH_*.json)
  if [ ! -e "${files[0]}" ]; then
    echo "bench: $BENCH_ONLY emitted no BENCH_*.json" >&2
    return 1
  fi
  ./target/release/star validate-bench "${files[@]}"
}

# Reliability soak (the scheduled CI job): several seeds of the fault-
# injection and heterogeneous-fleet scenarios must complete with ZERO
# lost requests (`--fail-on-lost` turns any loss into a nonzero exit),
# then the reliability bench runs at smoke scale and its JSON must
# validate. Catches rare-seed crash-path bugs the fixed-seed tier-1
# tests cannot.
soak_gate() {
  local seeds=(11 17 23)
  local scen s
  for scen in degraded_fleet mixed_gen; do
    for s in "${seeds[@]}"; do
      echo "==> [soak] star simulate --scenario $scen --seed $s --requests 600 --fail-on-lost"
      if ! ./target/release/star simulate --scenario "$scen" --seed "$s" \
            --requests 600 --fail-on-lost; then
        echo "soak: scenario \`$scen\` seed $s failed (lost requests or error)" >&2
        return 1
      fi
    done
  done
  rm -f BENCH_*.json
  mkdir -p "$SMOKE_LOG_DIR"
  echo "==> [soak] cargo bench --bench fig_reliability"
  if ! STAR_BENCH_SMOKE=1 cargo bench --bench fig_reliability \
        > "$SMOKE_LOG_DIR/fig_reliability.log" 2>&1; then
    echo "soak: fig_reliability failed; last 40 log lines:" >&2
    tail -n 40 "$SMOKE_LOG_DIR/fig_reliability.log" >&2
    return 1
  fi
  local files=(BENCH_*.json)
  if [ ! -e "${files[0]}" ]; then
    echo "soak: no BENCH_*.json emitted" >&2
    return 1
  fi
  ./target/release/star validate-bench --require reliability "${files[@]}"
}

if [ -n "$BENCH_ONLY" ]; then
  run_step build cargo build --release
  run_step bench single_bench
  print_summary
  echo "ci.sh: bench \`$BENCH_ONLY\` passed"
  exit 0
fi

if [ "$SOAK" = "1" ]; then
  run_step build cargo build --release
  run_step soak soak_gate
  print_summary
  echo "ci.sh: soak gate passed"
  exit 0
fi

run_step build cargo build --release
run_step test cargo test -q

# `star analyze`: the dependency-free determinism/safety lint over src/
# (R1 hash-collections, R2 wall-clock, R3 unsafe, R4 unwrap, R5 event
# coverage, R6 trace-event coverage, R7 shared-mutable statics). Exits
# nonzero on any finding, so the tree stays clean.
if [ "$ANALYZE" = "1" ]; then
  run_step analyze ./target/release/star analyze src
fi

if [ "$LINT" = "1" ]; then
  run_step fmt cargo fmt --check
  # Lint gate: state-layer refactors (ClusterState and friends) must stay
  # clippy-clean. One style allowance: the pervasive config idiom
  # `let mut exp = ExperimentConfig::default(); exp.field = v;` across
  # benches/tests is deliberate. Skipped only when the clippy component is
  # not installed on this toolchain.
  if cargo clippy --version >/dev/null 2>&1; then
    run_step clippy cargo clippy --all-targets -- -D warnings -A clippy::field_reassign_with_default
  else
    echo "ci.sh: cargo-clippy unavailable; lint gate skipped" >&2
  fi
fi

if [ "$SMOKE" = "1" ]; then
  run_step smoke smoke_gate
  mkdir -p "$SMOKE_LOG_DIR"
  run_step obs obs_gate
  run_step shard shard_gate
fi

print_summary
echo "ci.sh: all steps passed"
