#!/usr/bin/env bash
# Tier-1 gate (DESIGN.md §9): build + tests + formatting + lint for the
# rust crate. Run from anywhere; exits non-zero on the first failure.
set -euo pipefail
cd "$(dirname "$0")/rust"

cargo build --release
cargo test -q
cargo fmt --check

# Lint gate: state-layer refactors (ClusterState and friends) must stay
# clippy-clean. One style allowance: the pervasive config idiom
# `let mut exp = ExperimentConfig::default(); exp.field = v;` across
# benches/tests is deliberate. Skipped only when the clippy component is
# not installed on this toolchain.
if cargo clippy --version >/dev/null 2>&1; then
  cargo clippy --all-targets -- -D warnings -A clippy::field_reassign_with_default
else
  echo "ci.sh: cargo-clippy unavailable; lint gate skipped" >&2
fi
