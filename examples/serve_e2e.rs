//! END-TO-END VALIDATION (DESIGN.md): serve a real batched workload
//! through the full three-layer stack — rust coordinator (L3) executing
//! AOT-compiled jax/Pallas artifacts (L2/L1) on PJRT — with the paper's
//! PD-disaggregated topology (1 prefill + 3 decode instances), and report
//! latency/throughput for the vLLM-baseline vs STAR configurations.
//!
//!     make artifacts && cargo run --release --example serve_e2e
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use std::sync::Arc;

use star::metrics::Slo;
use star::runtime::{artifacts_dir, StarRuntime};
use star::serve::{LiveRequest, ServeParams, Server};
use star::workload::{Dataset, TraceGen};

fn main() -> Result<(), star::Error> {
    let dir = artifacts_dir(None)?;
    let rt = Arc::new(StarRuntime::load(&dir)?);
    println!(
        "star-pico loaded on {} ({} params)",
        rt.platform(),
        rt.params.total_elems()
    );

    let n_requests = std::env::var("E2E_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20usize);
    let rps = 1.2;
    // ShareGPT-shaped lengths rescaled to the pico domain; the tail still
    // produces the decode-load imbalance the paper targets.
    let gen = TraceGen::new(Dataset::ShareGpt, rps)
        .pico(rt.meta.max_prompt as u32 - 8, rt.meta.max_output as u32);
    let trace = gen.generate(n_requests, 17);
    let live: Vec<LiveRequest> = trace
        .iter()
        .map(|r| LiveRequest::from_trace(r, rt.meta.max_prompt))
        .collect();
    let slo = Slo {
        ttft_s: 2.0,
        tpot_s: 0.080,
    };

    let configs: Vec<(&str, bool, &str)> = vec![
        ("vLLM (dispatch only)", false, "none"),
        ("STAR w/o prediction", true, "none"),
        ("STAR w/ LLM-native", true, "llm_native"),
        ("STAR Oracle", true, "oracle"),
    ];
    println!(
        "\nserving {n_requests} ShareGPT-shaped requests at {rps} rps on \
         1 prefill + 3 decode instances\n"
    );
    let mut rows = Vec::new();
    for (name, resched, pred) in configs {
        let mut params = ServeParams::default();
        params.exp.cluster.n_prefill = 1;
        params.exp.cluster.n_decode = 3;
        params.exp.cluster.kv_capacity_tokens = 1400; // tight: OOM-able
        params.exp.cluster.max_batch = 8;
        params.exp.cluster.seed = 17;
        params.exp.rescheduler.enabled = resched;
        params.exp.rescheduler.interval_s = 0.25;
        params.exp.predictor = pred.to_string();
        params.exp.dispatch_policy = "current_load".to_string();
        params.max_wall_s = 240.0;

        let server = Server::new(Arc::clone(&rt), params);
        let out = server.run(live.clone())?;
        println!(
            "{name:<22} completed {:>3}/{} | wall {:>6.1}s | thr {:.3} req/s | \
             goodput {:.3} req/s | P99 TPOT {:>7.2} ms | mean exec-var {:>8.2} ms^2 | \
             OOMs {} | migrations {}",
            out.metrics.completed.len(),
            n_requests,
            out.wall_s,
            out.metrics.throughput(),
            out.metrics.goodput(slo),
            out.metrics.p99_tpot_ms(),
            out.exec_var.sample_mean(),
            out.oom_events,
            out.migrations
        );
        rows.push((name, out));
    }

    // headline comparison (paper: goodput x2.63, P99 TPOT -75.1%)
    let base = &rows[0].1;
    let star = &rows[2].1;
    if base.metrics.goodput(slo) > 0.0 {
        println!(
            "\nSTAR w/ prediction vs vLLM baseline: goodput {:.2}x, P99 TPOT {:+.1}%, \
             OOMs {} -> {}",
            star.metrics.goodput(slo) / base.metrics.goodput(slo),
            100.0 * (star.metrics.p99_tpot_ms() / base.metrics.p99_tpot_ms() - 1.0),
            base.oom_events,
            star.oom_events
        );
    }
    Ok(())
}
