//! Quickstart: load the AOT artifacts, run one request end-to-end by hand
//! (prefill -> decode loop -> length prediction), and print what the
//! serving stack does automatically at scale.
//!
//!     make artifacts && cargo run --release --example quickstart

use star::prng::Pcg64;
use star::runtime::{artifacts_dir, StarRuntime};
use star::serve::sample_token;

fn main() -> Result<(), star::Error> {
    // 1. load artifacts (HLO text -> PJRT executables + weights)
    let dir = artifacts_dir(None)?;
    let rt = StarRuntime::load(&dir)?;
    println!(
        "loaded star-pico on {}: d={} layers={} ctx={}",
        rt.platform(),
        rt.meta.d_model,
        rt.meta.n_layers,
        rt.meta.max_seq
    );

    // 2. prefill a prompt in the reasoning-trace language
    //    (tag 'd' = short-ish expected output)
    let prompt = b"\x01Qdhello world?";
    let pre = rt.prefill(prompt)?;
    println!("prefill done: prompt {} tokens", prompt.len());

    // 3. initial remaining-length prediction from the prefill hidden state
    //    (paper Eq. 2: 4-layer MLP on the last token's last hidden state)
    let pred0 = rt.predict_remaining(&pre.hidden)?[0];
    println!("predicted remaining at t=0: {pred0:.0} tokens");

    // 4. autoregressive decode with temperature sampling
    let mut rng = Pcg64::new(42, 0);
    let mut kv = rt.new_kv_buffer(1);
    rt.copy_kv_slot(&pre.kv, 1, 0, &mut kv, 1, 0)?;
    let mut tok = sample_token(&pre.logits, 0.9, &mut rng) as i32;
    let mut pos = prompt.len() as i32;
    let mut text = Vec::new();
    let mut repredictions = Vec::new();
    for step in 0..rt.meta.max_output {
        if tok == rt.meta.eos as i32 {
            break;
        }
        text.push(tok as u8);
        let out = rt.decode_step(1, &[tok], &[pos], &kv)?;
        kv = out.kv;
        // continuous re-prediction every 20 iterations (paper §5.3)
        if step % 20 == 19 {
            let p = rt.predict_remaining(&out.hidden)?[0];
            repredictions.push((step + 1, p));
        }
        tok = sample_token(&out.logits, 0.9, &mut rng) as i32;
        pos += 1;
    }
    println!(
        "generated {} tokens:\n---\n{}\n---",
        text.len(),
        String::from_utf8_lossy(&text)
    );
    println!("continuous predictions along the way (generated -> remaining est):");
    for (at, p) in repredictions {
        println!("  after {at:>4} tokens: {p:>7.1}");
    }
    println!(
        "\nnext: cargo run --release -- serve        (live PD-disaggregated cluster)\n\
         \u{20}      cargo run --release -- simulate     (event-driven cluster sim)"
    );
    Ok(())
}
