//! Continuous length prediction demo (paper §4): generate several
//! requests with different tags, re-predict remaining length every 20
//! tokens through the AOT MLP predictor, and show the estimate converging
//! toward the realized remaining length (the Fig. 7 effect, live).
//!
//!     make artifacts && cargo run --release --example prediction_demo

use star::prng::Pcg64;
use star::runtime::{artifacts_dir, StarRuntime};
use star::serve::sample_token;

fn main() -> Result<(), star::Error> {
    let dir = artifacts_dir(None)?;
    let rt = StarRuntime::load(&dir)?;
    let mut rng = Pcg64::new(123, 0);

    for (tag, name) in [(b'b', "short tag 'b'"), (b'h', "medium tag 'h'"), (b'o', "long tag 'o'")] {
        let prompt = vec![1u8, b'Q', tag, b'd', b'e', b'm', b'o', b'?'];
        let pre = rt.prefill(&prompt)?;
        let mut kv = rt.new_kv_buffer(1);
        rt.copy_kv_slot(&pre.kv, 1, 0, &mut kv, 1, 0)?;
        let mut tok = sample_token(&pre.logits, 0.9, &mut rng) as i32;
        let mut pos = prompt.len() as i32;

        // roll the full generation, recording hidden states every 20 steps
        let mut snapshots = vec![(0u32, pre.hidden.clone())];
        let mut n = 0u32;
        while tok != rt.meta.eos as i32 && n < rt.meta.max_output as u32 {
            let out = rt.decode_step(1, &[tok], &[pos], &kv)?;
            kv = out.kv;
            n += 1;
            pos += 1;
            if n % 20 == 0 {
                snapshots.push((n, out.hidden.clone()));
            }
            tok = sample_token(&out.logits, 0.9, &mut rng) as i32;
        }

        println!("\n{name}: realized output {n} tokens");
        println!("  generated | predicted remaining | true remaining | abs err");
        for (at, hidden) in snapshots {
            let p = rt.predict_remaining(&hidden)?[0] as f64;
            let true_rem = (n - at) as f64;
            println!(
                "  {at:>9} | {p:>19.1} | {true_rem:>14.0} | {:>7.1}",
                (p - true_rem).abs()
            );
        }
    }
    println!(
        "\nthe estimate tightens as tokens accumulate — the continuous-prediction \
         effect the scheduler exploits (paper Fig. 7)"
    );
    Ok(())
}
