//! Large-scale cluster simulation (paper §6.3): a 64-instance decode
//! fleet under ShareGPT load, comparing the four systems, with the same
//! scheduler code the live runtime uses.
//!
//!     cargo run --release --example large_scale_sim [instances] [seconds]

use star::bench::scenarios::{paper_scenarios, run_scenario};
use star::config::ExperimentConfig;
use star::metrics::Slo;
use star::workload::{Dataset, TraceGen};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let size: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let duration: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(600.0);
    // KV-memory-bound equilibrium for our calibrated profile (the
    // paper's "dynamic equilibrium" point for its own hardware)
    let rps = 0.5 * size as f64 / 8.0;

    let mut exp = ExperimentConfig::default();
    exp.cluster.n_prefill = (size / 4).max(1);
    exp.cluster.n_decode = size;
    exp.cluster.dataset = Dataset::ShareGpt;
    exp.cluster.rps = rps;
    exp.cluster.kv_capacity_tokens = 160_000;
    exp.cluster.max_batch = 64;
    exp.cluster.seed = 5;
    exp.predictor_rel_err = star::bench::scenarios::llm_native_rel_err();

    let trace = TraceGen::new(Dataset::ShareGpt, rps).generate_for(duration, 5);
    println!(
        "simulating {} requests over {duration}s on {size} decode instances ({rps:.2} rps)\n",
        trace.len()
    );
    let slo = Slo::default();
    for sc in paper_scenarios() {
        let report = run_scenario(sc, exp.clone(), true, &trace);
        println!("{:<14} {}", sc.name, report.summary(slo));
        println!(
            "{:<14} scheduler: max decision {} us over {} intervals ({} candidates)\n",
            "",
            report.scheduler_stats.max_decision_us,
            report.scheduler_stats.intervals,
            report.scheduler_stats.candidates_evaluated
        );
    }
}
