//! Fig. 7: prediction MAE for long-output requests as a function of how
//! many tokens have been generated — the continuous-prediction payoff.
//! Reads the build-time evaluation (artifacts/predictor_eval.tsv); the
//! series shape (LLM-native MAE falls as context accumulates; truncated
//! auxiliary models flatten or regress) is the paper's Fig. 7 claim.

use std::collections::BTreeMap;

use star::bench::output::{write_skipped, BenchJson};
use star::bench::Table;
use star::runtime::artifacts_dir;

fn main() {
    let dir = match artifacts_dir(None) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("SKIP fig7: {e}");
            write_skipped("fig7_continuous", &format!("artifacts not built: {e}"));
            return;
        }
    };
    let eval = match std::fs::read_to_string(dir.join("predictor_eval.tsv")) {
        Ok(text) => text,
        Err(e) => {
            eprintln!("SKIP fig7: predictor_eval.tsv: {e} (run `make artifacts`)");
            write_skipped("fig7_continuous", &format!("predictor_eval.tsv: {e}"));
            return;
        }
    };

    // method -> (gen_tokens -> mae)
    let mut series: BTreeMap<String, BTreeMap<u64, f64>> = BTreeMap::new();
    for line in eval.lines() {
        let f: Vec<&str> = line.split('\t').collect();
        if f.first() == Some(&"fig7") && f.len() >= 4 {
            series
                .entry(f[1].to_string())
                .or_default()
                .insert(f[2].parse().unwrap_or(0), f[3].parse().unwrap_or(f64::NAN));
        }
    }
    if series.is_empty() {
        eprintln!("no fig7 rows in predictor_eval.tsv");
        write_skipped("fig7_continuous", "no fig7 rows in predictor_eval.tsv");
        return;
    }
    let buckets: Vec<u64> = series
        .values()
        .next()
        .unwrap()
        .keys()
        .copied()
        .collect();
    let mut header: Vec<String> = vec!["generated".into()];
    let methods: Vec<String> = series.keys().cloned().collect();
    header.extend(methods.iter().cloned());
    let hdr_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Fig 7: MAE vs generated tokens, long-output requests (tokens)",
        &hdr_refs,
    );
    for b in &buckets {
        let mut row = vec![b.to_string()];
        for m in &methods {
            row.push(
                series[m]
                    .get(b)
                    .map(|v| format!("{v:.1}"))
                    .unwrap_or_else(|| "-".into()),
            );
        }
        t.row(&row);
    }
    t.print();
    let mut json = BenchJson::new(
        "fig7_continuous",
        "prediction MAE vs generated tokens (continuous-prediction payoff)",
    );
    json.table("mae_vs_generated", &t);
    json.write_or_die();

    // shape checks mirroring the paper's reading of the figure
    for m in &methods {
        if m == "oracle" {
            continue;
        }
        let s = &series[m];
        let first = s.values().next().copied().unwrap_or(f64::NAN);
        let mid = s
            .iter()
            .nth(s.len() / 2)
            .map(|(_, v)| *v)
            .unwrap_or(f64::NAN);
        println!(
            "{m:<14} early MAE {first:>8.1} -> mid-generation MAE {mid:>8.1}  \
             ({})",
            if mid < first {
                "improves with context, as in Fig 7"
            } else {
                "no improvement"
            }
        );
    }
}
