//! Predictor-ablation sweep (beyond the paper's Fig. 7): how much
//! scheduling quality each predictor tier buys under the bursty mixed
//! workload — none vs the paper's binned quantizations (2/4/6) vs the
//! simulated LLM-native predictor at several noise levels vs its
//! `debiased` variant vs the oracle, with rescheduling on. Emits
//! `BENCH_predictor.json` (goodput / tail latency / migration counts per
//! predictor, plus each run's calibration scorecard) through the shared
//! writer, so `ci.sh --smoke`, `ci.sh --bench fig7_predictor`, and
//! `star validate-bench` all pick it up.

use star::bench::output::BenchJson;
use star::bench::scenarios::{scaled, smoke, ScenarioRegistry};
use star::bench::Table;
use star::config::ExperimentConfig;
use star::coordinator::PolicyRegistry;
use star::sim::{SimParams, SimReport, Simulator};
use star::workload::SloByClass;

const SCENARIO: &str = "bursty_mixed";

struct Run {
    label: String,
    report: SimReport,
    slos: SloByClass,
}

fn run_one(label: &str, predictor: &str, rel_err: f64, n: usize, rps: f64) -> Run {
    let mut exp = ExperimentConfig::default();
    exp.cluster.n_prefill = 2;
    exp.cluster.n_decode = 6;
    exp.cluster.kv_capacity_tokens = 96_000;
    exp.cluster.max_batch = 48;
    exp.cluster.rps = rps;
    exp.cluster.seed = 23;
    exp.rescheduler.enabled = true;
    exp.predictor = predictor.to_string();
    exp.predictor_rel_err = rel_err;
    exp.scenario_name = Some(SCENARIO.to_string());
    let spec = ScenarioRegistry::with_builtins()
        .build(SCENARIO, &exp)
        .expect("builtin scenario");
    let slos = spec.slos();
    let trace = spec.generate(n, exp.cluster.seed);
    let params = SimParams {
        exp,
        ..Default::default()
    };
    let report = Simulator::with_scenario(params, trace, &PolicyRegistry::with_builtins())
        .expect("builtin construction")
        .run();
    Run {
        label: label.to_string(),
        report,
        slos,
    }
}

fn main() {
    let n = scaled(800);
    let rps = if smoke() { 0.3 } else { 0.45 };

    // (label, registry name, rel_err) — rel_err only matters for the
    // noise-modelled predictors
    let settings: Vec<(String, &str, f64)> = vec![
        ("none".into(), "none", 0.0),
        ("binned2".into(), "binned2", 0.0),
        ("binned4".into(), "binned4", 0.0),
        ("binned6".into(), "binned6", 0.0),
        ("llm_native rel_err=0.25".into(), "llm_native", 0.25),
        ("llm_native rel_err=0.5".into(), "llm_native", 0.5),
        ("llm_native rel_err=1.0".into(), "llm_native", 1.0),
        ("debiased rel_err=0.5".into(), "debiased", 0.5),
        ("oracle".into(), "oracle", 0.0),
    ];

    let mut json = BenchJson::new(
        "predictor",
        "predictor-ablation sweep under bursty_mixed: none / binned{2,4,6} / \
         llm_native at several rel_err values / debiased / oracle, rescheduling on",
    );
    json.field_str("scenario", SCENARIO);
    json.field_int("requests", n as i64);
    json.field_num("rps", rps);

    let mut t = Table::new(
        "Fig 7 (ablation) - scheduling quality per predictor tier (bursty_mixed)",
        &[
            "predictor",
            "goodput (req/s)",
            "P99 TTFT (ms)",
            "P99 TPOT (ms)",
            "migrations",
            "OOMs",
            "cal. MAE (tokens)",
            "cal. bias (tokens)",
        ],
    );
    let mut goodputs: Vec<(String, f64)> = Vec::new();
    for (label, predictor, rel_err) in &settings {
        let run = run_one(label, predictor, *rel_err, n, rps);
        let m = run.report.metrics();
        let goodput = m.goodput_by_class(&run.slos);
        let cal = run.report.scorecard.total();
        t.row(&[
            run.label.clone(),
            format!("{goodput:.4}"),
            format!("{:.1}", m.p99_ttft_ms()),
            format!("{:.2}", m.p99_tpot_ms()),
            run.report.migrations.to_string(),
            run.report.oom_events.to_string(),
            format!("{:.1}", cal.mae()),
            format!("{:+.1}", cal.bias()),
        ]);
        println!(
            "[{SCENARIO}] {label}: goodput {goodput:.4} req/s, {} migrations, \
             calibration MAE {:.1} tokens (bias {:+.1})",
            run.report.migrations,
            cal.mae(),
            cal.bias()
        );
        let key = label.replace([' ', '=', '.'], "_");
        json.field_num(&format!("goodput_{key}"), goodput);
        json.field_raw(&format!("scorecard_{key}"), &run.report.scorecard.json());
        goodputs.push((run.label, goodput));
    }
    t.print();
    json.table("ablation", &t);
    json.write_or_die();

    let get = |name: &str| {
        goodputs
            .iter()
            .find(|(l, _)| l == name)
            .map(|&(_, g)| g)
            .unwrap_or(f64::NAN)
    };
    println!(
        "claim: goodput should order oracle ({:.4}) >= llm_native ({:.4}) >= \
         none ({:.4}); binned tiers interpolate between none and oracle",
        get("oracle"),
        get("llm_native rel_err=0.25"),
        get("none"),
    );
}
