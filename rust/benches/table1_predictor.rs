//! Table 1: generation-length predictor comparison — parameters, training
//! time and MAE come from the build-time evaluation
//! (artifacts/predictor_eval.tsv, paper §4.4); inference latency of the
//! LLM-native MLP is re-measured HERE through the rust/PJRT request path
//! (the latency that actually matters at serving time), plus the §5.3
//! overhead arithmetic.

use std::collections::HashMap;
use std::time::Instant;

use star::bench::output::{write_skipped, BenchJson};
use star::bench::scenarios::smoke;
use star::bench::Table;
use star::runtime::{artifacts_dir, StarRuntime};

fn main() {
    let dir = match artifacts_dir(None) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("SKIP table1: {e}");
            write_skipped("table1_predictor", &format!("artifacts not built: {e}"));
            return;
        }
    };
    let eval = match std::fs::read_to_string(dir.join("predictor_eval.tsv")) {
        Ok(text) => text,
        Err(e) => {
            eprintln!("SKIP table1: predictor_eval.tsv: {e} (run `make artifacts`)");
            write_skipped("table1_predictor", &format!("predictor_eval.tsv: {e}"));
            return;
        }
    };

    // parse the python-side eval
    let mut table1: Vec<(String, String, String, String)> = Vec::new(); // name, params, train, mae
    let mut latency: HashMap<String, f64> = HashMap::new();
    for line in eval.lines() {
        let f: Vec<&str> = line.split('\t').collect();
        match f.first() {
            Some(&"table1") if f.len() >= 5 => {
                table1.push((
                    f[1].to_string(),
                    f[2].to_string(),
                    f[3].to_string(),
                    f[4].to_string(),
                ));
            }
            Some(&"latency") if f.len() >= 3 => {
                latency.insert(f[1].to_string(), f[2].parse().unwrap_or(f64::NAN));
            }
            _ => {}
        }
    }

    // measure the rust-side LLM-native predictor latency (batch 1 and 10)
    let rt = match StarRuntime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP table1: artifacts load failed: {e}");
            write_skipped("table1_predictor", &format!("artifacts load failed: {e}"));
            return;
        }
    };
    let d = rt.meta.predictor_d_in;
    let reps = if smoke() {
        20
    } else if std::env::var("STAR_BENCH_FAST").is_ok() {
        50
    } else {
        300
    };
    let mut rust_lat = HashMap::new();
    for bsz in [1usize, 10] {
        let hidden = vec![0.1f32; bsz * d];
        rt.predict_remaining(&hidden).unwrap(); // warmup
        let t0 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(rt.predict_remaining(&hidden).unwrap());
        }
        rust_lat.insert(bsz, t0.elapsed().as_secs_f64() / reps as f64 * 1e3);
    }

    let mut t = Table::new(
        "Table 1: prediction method comparison (this testbed)",
        &[
            "Method",
            "Parameters",
            "Train time (s)",
            "MAE (tokens)",
            "Lat b=1 (ms)",
            "Lat b=10 (ms)",
        ],
    );
    fn label(n: &str) -> &str {
        match n {
        "prompt_only" => "PiA-like (prompt)",
        "auxiliary" => "TetriInfer-like (aux)",
        "llm_native" => "LLM-native (ours)",
            other => other,
        }
    }
    for (name, params, train, mae) in &table1 {
        let (l1, l10) = if name == "llm_native" {
            (
                format!("{:.3} (rust)", rust_lat[&1]),
                format!("{:.3} (rust)", rust_lat[&10]),
            )
        } else {
            (
                latency
                    .get(&format!("{name}_b1"))
                    .map(|v| format!("{v:.3}"))
                    .unwrap_or_else(|| "-".into()),
                latency
                    .get(&format!("{name}_b10"))
                    .map(|v| format!("{v:.3}"))
                    .unwrap_or_else(|| "-".into()),
            )
        };
        t.row(&[
            label(name).to_string(),
            params.clone(),
            train.clone(),
            mae.clone(),
            l1,
            l10,
        ]);
    }
    t.print();
    let mut json = BenchJson::new(
        "table1_predictor",
        "prediction-method comparison: params/train/MAE from build-time eval, latency re-measured",
    );
    json.field_int("latency_reps", reps as i64);
    json.table("table1", &t);
    json.write_or_die();

    // paper headline ratios
    let get_mae = |n: &str| {
        table1
            .iter()
            .find(|r| r.0 == n)
            .and_then(|r| r.3.parse::<f64>().ok())
    };
    if let (Some(ours), Some(aux)) = (get_mae("llm_native"), get_mae("auxiliary")) {
        println!(
            "MAE vs best auxiliary baseline: {:+.1}% (paper: -49.42% vs SOTA)",
            100.0 * (ours / aux - 1.0)
        );
    }
    let params = |n: &str| {
        table1
            .iter()
            .find(|r| r.0 == n)
            .and_then(|r| r.1.parse::<f64>().ok())
    };
    if let (Some(ours), Some(aux)) = (params("llm_native"), params("auxiliary")) {
        println!(
            "predictor parameters vs auxiliary: {:.1}% of aux size (paper: -93.28% vs opt-125m)",
            100.0 * ours / aux
        );
    }

    // §5.3 overhead arithmetic on this testbed
    let iter_ms = read_calibrated_iter_ms(&dir).unwrap_or(8.0);
    let pred_ms = rust_lat[&10];
    for k in [1u32, 20, 100] {
        println!(
            "reprediction every {k:>3} iters: overhead {:.2}% of decode time \
             (paper at k=20: 0.38%)",
            100.0 * pred_ms / (iter_ms * k as f64)
        );
    }
}

fn read_calibrated_iter_ms(dir: &std::path::Path) -> Option<f64> {
    let text = std::fs::read_to_string(dir.join("costmodel_cpu.txt")).ok()?;
    let mut base = None;
    let mut per = None;
    for line in text.lines() {
        if let Some(v) = line.strip_prefix("base_s=") {
            base = v.parse::<f64>().ok();
        }
        if let Some(v) = line.strip_prefix("per_token_s=") {
            per = v.parse::<f64>().ok();
        }
    }
    // iteration time at 50% KV occupancy of a 1600-token pico instance
    Some((base? + per? * 800.0) * 1e3)
}
