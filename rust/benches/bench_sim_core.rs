//! `BENCH_sim_core`: simulator state-layer throughput harness — the perf
//! baseline future PRs are held to.
//!
//! Runs Fig. 13-shaped workloads (8/64/256 decode instances, request rate
//! scaled 0.5 rps per 8 instances, ≥50k requests) through both state
//! paths:
//!
//! * **incremental** — policies borrow views from the O(1)-delta
//!   [`ClusterState`] (the production path);
//! * **from_scratch** — a full [`ClusterSnapshot`] is materialized before
//!   every dispatch and scheduler tick
//!   ([`StateMode::RebuildPerDecision`]), reproducing the
//!   pre-incremental cost: O(instances × requests) per decision.
//!
//! A second sweep drives the sharded event core (`[sim] shards`) at
//! 1/2/4/8 shards on the largest fleet (1M requests / 1024 instances at
//! full scale) and reports serial-vs-sharded µs/request; completions must
//! agree across shard counts, so the sweep doubles as a determinism check.
//!
//! Emits `BENCH_sim_core.json` (path override: `STAR_BENCH_OUT`) with
//! wall-clock per simulated request and the speedup per cluster size.
//! `STAR_BENCH_FAST=1` shrinks the run for smoke testing;
//! `STAR_BENCH_BASELINE_REQUESTS=<n>` caps the from-scratch baseline's
//! request count when full scale is impractical (the cap *underestimates*
//! the baseline's per-request cost — the table-scan term grows with the
//! request count — so the reported speedup is a lower bound).
//!
//! [`ClusterState`]: star::coordinator::ClusterState
//! [`ClusterSnapshot`]: star::coordinator::ClusterSnapshot

use std::fmt::Write as _;
use std::time::Instant;

use star::bench::output::BenchJson;
use star::bench::scenarios::smoke;
use star::config::ExperimentConfig;
use star::costmodel::{DecodeCostModel, MigrationCostModel, PrefillCostModel};
use star::sim::{SimParams, Simulator, StateMode};
use star::workload::{Dataset, TraceGen};

struct Measure {
    requests: usize,
    wall_s: f64,
    us_per_request: f64,
    completed: usize,
    failed: usize,
    migrations: u64,
    oom_events: u64,
}

impl Measure {
    fn json(&self) -> String {
        format!(
            "{{\"requests\": {}, \"wall_s\": {:.4}, \"us_per_request\": {:.3}, \
             \"completed\": {}, \"failed\": {}, \"migrations\": {}, \"oom_events\": {}}}",
            self.requests,
            self.wall_s,
            self.us_per_request,
            self.completed,
            self.failed,
            self.migrations,
            self.oom_events,
        )
    }
}

fn run_one(size: usize, n_requests: usize, mode: StateMode) -> Measure {
    run_sharded(size, n_requests, mode, 1)
}

fn run_sharded(size: usize, n_requests: usize, mode: StateMode, shards: usize) -> Measure {
    // fig13 shape: KV memory is the binding resource on the calibrated
    // profile; 0.5 rps per 8 instances reaches the near-capacity dynamic
    // equilibrium (see benches/fig13_scaling.rs)
    let rps = 0.5 * size as f64 / 8.0;
    let mut exp = ExperimentConfig::default();
    exp.cluster.n_prefill = (size / 4).max(1);
    exp.cluster.n_decode = size;
    exp.cluster.dataset = Dataset::ShareGpt;
    exp.cluster.rps = rps;
    exp.cluster.seed = 53;
    exp.cluster.kv_capacity_tokens = 160_000;
    exp.cluster.max_batch = 64;
    exp.predictor = "oracle".to_string();
    exp.rescheduler.enabled = true;
    exp.shards = shards;
    let trace = TraceGen::new(Dataset::ShareGpt, rps).generate(n_requests, 53);
    let horizon = trace.last().map(|r| r.arrival).unwrap_or(0.0);
    let params = SimParams {
        exp,
        decode_cost: DecodeCostModel::paper_h800(),
        prefill_cost: PrefillCostModel::paper_4090d(),
        migration: MigrationCostModel::new_25gbps(128 * 1024),
        // generous: runs end on completion, not on this cap
        max_sim_time: horizon * 10.0 + 100_000.0,
        state_mode: mode,
        ..Default::default()
    };
    let sim = Simulator::new(params, &trace);
    let t0 = Instant::now();
    let report = sim.run();
    let wall_s = t0.elapsed().as_secs_f64();
    Measure {
        requests: n_requests,
        wall_s,
        us_per_request: wall_s * 1e6 / n_requests as f64,
        completed: report.completed.len(),
        failed: report.n_failed,
        migrations: report.migrations,
        oom_events: report.oom_events,
    }
}

fn main() {
    let fast = std::env::var("STAR_BENCH_FAST").is_ok();
    let sizes: &[usize] = if smoke() {
        &[8] // smoke gate: ≤8 instances
    } else if fast {
        &[8, 16]
    } else {
        &[8, 64, 256]
    };
    let n_requests = if smoke() || fast { 2_000 } else { 50_000 };
    let baseline_cap: usize = std::env::var("STAR_BENCH_BASELINE_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(n_requests);

    let mut rows = Vec::new();
    for &size in sizes {
        println!("[bench_sim_core] size {size}: incremental ({n_requests} requests)...");
        let inc = run_one(size, n_requests, StateMode::Incremental);
        println!(
            "[bench_sim_core] size {size}: incremental {:.3} us/req \
             ({:.2}s wall, {} completed, {} migrations)",
            inc.us_per_request, inc.wall_s, inc.completed, inc.migrations
        );
        let base_n = baseline_cap.min(n_requests);
        println!("[bench_sim_core] size {size}: from-scratch baseline ({base_n} requests)...");
        let base = run_one(size, base_n, StateMode::RebuildPerDecision);
        println!(
            "[bench_sim_core] size {size}: from-scratch {:.3} us/req ({:.2}s wall)",
            base.us_per_request, base.wall_s
        );
        let speedup = base.us_per_request / inc.us_per_request.max(1e-9);
        println!("[bench_sim_core] size {size}: speedup {speedup:.1}x");
        rows.push((size, inc, base, speedup));
    }

    // shard sweep: the sharded event core at 1/2/4/8 shards on the largest
    // fleet, serial (shards=1) as the baseline. Completions must agree
    // across shard counts — the sweep doubles as a determinism check.
    let (sweep_size, sweep_requests, shard_counts): (usize, usize, &[usize]) = if smoke() {
        (8, 2_000, &[1, 2])
    } else if fast {
        (64, 20_000, &[1, 2, 4, 8])
    } else {
        (1024, 1_000_000, &[1, 2, 4, 8])
    };
    let mut sweep = Vec::new();
    for &shards in shard_counts {
        println!(
            "[bench_sim_core] shard sweep: {sweep_size} instances, \
             {sweep_requests} requests, {shards} shard(s)..."
        );
        let m = run_sharded(sweep_size, sweep_requests, StateMode::Incremental, shards);
        println!(
            "[bench_sim_core] shards {shards}: {:.3} us/req ({:.2}s wall, {} completed)",
            m.us_per_request, m.wall_s, m.completed
        );
        sweep.push((shards, m));
    }
    let serial_us = sweep[0].1.us_per_request;
    for (shards, m) in &sweep {
        assert_eq!(
            (m.completed, m.failed, m.migrations, m.oom_events),
            (
                sweep[0].1.completed,
                sweep[0].1.failed,
                sweep[0].1.migrations,
                sweep[0].1.oom_events
            ),
            "shards={shards} must replay the serial trajectory"
        );
        println!(
            "[bench_sim_core] shards {shards}: speedup vs serial {:.2}x",
            serial_us / m.us_per_request.max(1e-9)
        );
    }

    let mut results = String::from("[\n");
    for (i, (size, inc, base, speedup)) in rows.iter().enumerate() {
        let _ = write!(
            results,
            "    {{\"instances\": {size}, \"incremental\": {}, \"from_scratch\": {}, \
             \"speedup_us_per_request\": {speedup:.2}}}",
            inc.json(),
            base.json()
        );
        results.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    results.push_str("  ]");

    let mut json = BenchJson::new(
        "sim_core",
        "wall-clock per simulated request: incremental ClusterState views vs \
         from-scratch snapshot rebuild per decision",
    );
    json.field_raw(
        "config",
        "{\"dataset\": \"sharegpt\", \"rps_per_8_instances\": 0.5, \
         \"kv_capacity_tokens\": 160000, \"max_batch\": 64, \"predictor\": \"oracle\", \
         \"dispatch\": \"current_load\", \"reschedule\": \"star\", \"seed\": 53}",
    );
    json.field_raw("results", &results);

    let mut sweep_json = format!(
        "{{\"instances\": {sweep_size}, \"requests\": {sweep_requests}, \"rows\": [\n"
    );
    for (i, (shards, m)) in sweep.iter().enumerate() {
        let _ = write!(
            sweep_json,
            "    {{\"shards\": {shards}, \"measure\": {}, \"speedup_vs_serial\": {:.3}}}",
            m.json(),
            serial_us / m.us_per_request.max(1e-9)
        );
        sweep_json.push_str(if i + 1 < sweep.len() { ",\n" } else { "\n" });
    }
    sweep_json.push_str("  ]}");
    json.field_raw("shard_sweep", &sweep_json);
    // back-compat: STAR_BENCH_OUT overrides the full output path
    match std::env::var("STAR_BENCH_OUT") {
        Ok(out) => {
            std::fs::write(&out, json.render()).expect("write bench output");
            println!("[bench_sim_core] wrote {out}");
        }
        Err(_) => json.write_or_die(),
    }
    println!("{}", json.render());
}
