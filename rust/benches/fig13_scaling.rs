//! Fig. 13: execution-time variance vs cluster size (8..256 decode
//! instances) at 25 Gbps migration bandwidth, request rate scaled
//! linearly (0.3 RPS per 8 instances, paper §6.3). Also validates the
//! §5.2 complexity claim: scheduler decision time < 300 ms at 256
//! instances.

use star::bench::output::BenchJson;
use star::bench::scenarios::{paper_scenarios, run_scenario, smoke};
use star::bench::Table;
use star::config::ExperimentConfig;
use star::workload::{Dataset, TraceGen};

fn main() {
    let fast = std::env::var("STAR_BENCH_FAST").is_ok();
    let sizes: &[usize] = if smoke() {
        &[8] // smoke gate: ≤8 instances
    } else if fast {
        &[8, 16, 32]
    } else {
        &[8, 16, 32, 64, 128, 256]
    };
    let duration = if smoke() {
        60.0
    } else if fast {
        150.0
    } else {
        300.0
    };

    let mut t = Table::new(
        "Fig 13: mean exec-time variance (ms^2) vs cluster size, 25 Gbps",
        &[
            "instances",
            "vLLM",
            "STAR w/o pred",
            "STAR w/ pred",
            "STAR Oracle",
            "sched max (us)",
        ],
    );
    for &size in sizes {
        // paper scales 0.3 rps per 8 instances for *their* H800 throughput;
        // on our calibrated profile the *KV memory* (not compute) is the
        // binding resource; ~0.5 rps per 8 instances reaches the same
        // near-capacity dynamic equilibrium
        let rps = 0.5 * size as f64 / 8.0;
        let mut exp = ExperimentConfig::default();
        exp.cluster.n_prefill = (size / 4).max(1);
        exp.cluster.n_decode = size;
        exp.cluster.dataset = Dataset::ShareGpt;
        exp.cluster.rps = rps;
        exp.cluster.seed = 53;
        exp.cluster.kv_capacity_tokens = 160_000;
        exp.cluster.max_batch = 64;
        exp.predictor_rel_err = star::bench::scenarios::llm_native_rel_err();
        let trace = TraceGen::new(Dataset::ShareGpt, rps).generate_for(duration, 53);

        let mut row = vec![size.to_string()];
        let mut sched_us = 0u64;
        for sc in paper_scenarios() {
            let report = run_scenario(sc, exp.clone(), true, &trace);
            row.push(format!("{:.2}", report.exec_var.sample_mean()));
            sched_us = sched_us.max(report.scheduler_stats.max_decision_us);
        }
        row.push(sched_us.to_string());
        t.row(&row);
        println!(
            "size {size}: {} requests over {duration}s at {rps:.2} rps",
            trace.len()
        );
    }
    t.print();
    let mut json = BenchJson::new(
        "fig13_scaling",
        "mean exec-time variance vs cluster size (8..256 decode instances)",
    );
    json.field_num("duration_s", duration);
    json.table("variance_vs_size", &t);
    json.write_or_die();
    println!(
        "paper claims: (1) rescheduling improves load balance at every size; (2) \
         prediction stays close to oracle as the cluster scales; (3) scheduler \
         decision time stays below 300 ms even at 256 instances"
    );
}
