//! Fig. 12: runtime traces — max decode-instance KV usage over time, the
//! 99% threshold line, OOM occurrences, and rescheduling-event ticks, per
//! system. Paper reading: vLLM saturates and repeatedly OOMs; STAR w/o
//! pred reduces OOMs; STAR w/ pred and Oracle stay below the threshold.
//!
//! TSV traces are written to artifacts/fig12_<system>.tsv for plotting.

use star::bench::output::BenchJson;
use star::bench::scenarios::{paper_scenarios, run_scenario, scaled, small_cluster, trace_for};
use star::bench::Table;
use star::workload::Dataset;

fn main() {
    let n = scaled(400);
    let rps = 0.14; // push the small cluster into the OOM regime
    let out_dir = star::runtime::artifacts_dir(None).ok();
    let mut json = BenchJson::new(
        "fig12_traces",
        "KV saturation + OOM behaviour over time, small cluster, tight memory",
    );
    json.field_int("requests", n as i64).field_num("rps", rps);

    let mut summary = Table::new(
        "Fig 12 summary: KV saturation + OOM behaviour, small cluster",
        &[
            "System",
            "peak max-KV (%)",
            "time >99% cap (%)",
            "OOMs",
            "migrations",
        ],
    );
    for sc in paper_scenarios() {
        let mut exp = small_cluster(Dataset::ShareGpt, rps, 41);
        exp.cluster.kv_capacity_tokens = 72_000; // tight: the Fig 12 regime
        exp.record_traces = true;
        let trace = trace_for(&exp, n);
        let report = run_scenario(sc, exp, false, &trace);

        let series = report.recorder.max_kv_series(3);
        let peak = series.iter().map(|s| s.1).fold(0.0, f64::max);
        let over = series.iter().filter(|s| s.1 > 0.99).count() as f64
            / series.len().max(1) as f64;
        summary.row(&[
            sc.name.to_string(),
            format!("{:.1}", peak * 100.0),
            format!("{:.1}", over * 100.0),
            report.oom_events.to_string(),
            report.migrations.to_string(),
        ]);

        // compact trace print: 16 samples of max-KV + event ticks
        let mut t = Table::new(
            &format!("Fig 12 trace — {}", sc.name),
            &["t(s)", "max KV (%)", "events"],
        );
        let t_end = series.last().map(|s| s.0).unwrap_or(0.0);
        let migs = report.recorder.migration_times();
        let ooms = report.recorder.oom_times();
        for b in 0..16 {
            let lo = t_end * b as f64 / 16.0;
            let hi = t_end * (b + 1) as f64 / 16.0;
            let mx = series
                .iter()
                .filter(|(t, _)| *t >= lo && *t < hi)
                .map(|(_, v)| *v)
                .fold(0.0, f64::max);
            let n_m = migs.iter().filter(|&&t| t >= lo && t < hi).count();
            let n_o = ooms.iter().filter(|(t, _)| *t >= lo && *t < hi).count();
            let mut ev = String::new();
            if n_m > 0 {
                ev.push_str(&format!("{n_m} resched "));
            }
            if n_o > 0 {
                ev.push_str(&format!("{n_o} OOM"));
            }
            t.row(&[format!("{lo:.0}"), format!("{:.1}", mx * 100.0), ev]);
        }
        t.print();
        json.table(
            &format!(
                "trace_{}",
                sc.name.to_lowercase().replace([' ', '/'], "_")
            ),
            &t,
        );

        if let Some(dir) = &out_dir {
            let path = dir.join(format!(
                "fig12_{}.tsv",
                sc.name.to_lowercase().replace([' ', '/'], "_")
            ));
            if report.recorder.write_tsv(&path).is_ok() {
                println!("trace TSV -> {}", path.display());
            }
        }
    }
    summary.print();
    json.table("summary", &summary);
    json.write_or_die();
    println!(
        "paper claim: vLLM sits near saturation with repeated OOMs; STAR w/o pred cuts \
         them; STAR w/ pred + Oracle stay below the 99% threshold throughout"
    );
}
