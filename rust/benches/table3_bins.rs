//! Table 3: prediction-accuracy sensitivity — full predictor vs the
//! paper's non-uniform 6/4/2-bin quantizations vs no prediction, on the
//! large cluster. Paper reading: 6-bin retains most of the benefit;
//! 2-bin is nearly indistinguishable from no prediction.

use star::bench::output::BenchJson;
use star::bench::scenarios::{large_cluster, scaled, sim_params, trace_for};
use star::bench::Table;
use star::metrics::Slo;
use star::sim::Simulator;
use star::workload::Dataset;

fn main() {
    let n = scaled(400);
    let rps = 0.35; // near the knee (paper used 0.20 on its hardware)
    let slo = Slo {
        ttft_s: 1.0,
        tpot_s: 0.025,
    };
    let settings: Vec<(&str, &str)> = vec![
        ("Full", "oracle"),
        ("6-bin", "binned6"),
        ("4-bin", "binned4"),
        ("2-bin", "binned2"),
        ("No pred.", "none"),
    ];

    let mut t = Table::new(
        "Table 3: prediction-granularity sensitivity (large cluster, near-knee rps)",
        &["Setting", "Exec. Var.", "P99 TPOT (ms)", "Goodput", "Goodput Gain"],
    );
    let mut base_goodput = None;
    let mut rows = Vec::new();
    for (name, kind) in settings {
        let mut exp = large_cluster(Dataset::ShareGpt, rps, 61);
        exp.rescheduler.enabled = true;
        exp.predictor = kind.to_string();
        let trace = trace_for(&exp, n);
        let report = Simulator::new(sim_params(exp, true), &trace).run();
        let m = report.metrics();
        let g = m.goodput(slo);
        if name == "No pred." {
            base_goodput = Some(g);
        }
        rows.push((
            name.to_string(),
            report.exec_var.sample_mean(),
            m.p99_tpot_ms(),
            g,
        ));
    }
    let base = base_goodput.unwrap_or(0.0);
    for (name, ev, tpot, g) in rows {
        let gain = if base > 0.0 {
            format!("{:+.2}%", 100.0 * (g / base - 1.0))
        } else {
            "-".into()
        };
        t.row(&[
            name,
            format!("{ev:.3}"),
            format!("{tpot:.2}"),
            format!("{g:.4}"),
            gain,
        ]);
    }
    t.print();
    let mut json = BenchJson::new(
        "table3_bins",
        "prediction-granularity sensitivity: full vs 6/4/2-bin vs none",
    );
    json.field_int("requests", n as i64).field_num("rps", rps);
    json.table("table3", &t);
    json.write_or_die();
    println!(
        "paper: Full 0.163/26.49/0.157; 6-bin keeps most of the benefit; \
         2-bin ~= No pred. (0.302 vs 0.322 exec var). The *ordering* and the \
         6-bin~=Full / 2-bin~=None equivalences are the claims under test."
    );
}
