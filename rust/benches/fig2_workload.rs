//! Fig. 2 + Table 2: output-length distribution of the synthetic traces,
//! checked against the paper's published ShareGPT/Alpaca statistics.

use star::bench::output::BenchJson;
use star::bench::scenarios::scaled;
use star::bench::Table;
use star::workload::{Dataset, TraceGen, TraceStats};

fn main() {
    let n = scaled(50_000);

    // Table 2 reproduction
    let mut t = Table::new(
        "Table 2: workload statistics (paper values in parentheses)",
        &["Workload", "Metric", "Mean", "Std", "P50", "P90", "P95"],
    );
    let paper: &[(&str, [f64; 5], [f64; 5])] = &[
        (
            "sharegpt",
            [305.0, 1053.0, 36.0, 920.0, 1609.0],
            [7542.0, 12008.0, 1536.0, 32670.0, 32679.0],
        ),
        (
            "alpaca",
            [11.0, 4.0, 10.0, 15.0, 18.0],
            [8596.0, 13354.0, 987.0, 32690.0, 32691.0],
        ),
    ];
    for (name, p_in, p_out) in paper {
        let ds = Dataset::parse(name).unwrap();
        let trace = TraceGen::new(ds, 1.0).generate(n, 7);
        let st = TraceStats::from_requests(&trace);
        for (metric, s, p) in [("Input", &st.input, p_in), ("Output", &st.output, p_out)] {
            t.row(&[
                name.to_string(),
                metric.to_string(),
                format!("{:.0} ({:.0})", s.mean, p[0]),
                format!("{:.0} ({:.0})", s.std, p[1]),
                format!("{:.0} ({:.0})", s.p50, p[2]),
                format!("{:.0} ({:.0})", s.p90, p[3]),
                format!("{:.0} ({:.0})", s.p95, p[4]),
            ]);
        }
    }
    t.print();

    // Fig. 2: output length histogram (fraction per band)
    let trace = TraceGen::new(Dataset::ShareGpt, 1.0).generate(n, 7);
    let mut h = Table::new(
        "Fig 2: ShareGPT output-length distribution",
        &["band", "fraction", "paper-note"],
    );
    let bands: &[(&str, u32, u32, &str)] = &[
        ("<1K", 0, 1_024, "29.2% < 1K in the paper"),
        ("1-8K", 1_024, 8_192, ""),
        ("8-16K", 8_192, 16_384, ""),
        ("16-30K", 16_384, 30_720, ""),
        (">30K", 30_720, u32::MAX, "17.3% > 30K in the paper"),
    ];
    for (name, lo, hi, note) in bands {
        let frac = trace
            .iter()
            .filter(|r| r.output_len >= *lo && r.output_len < *hi)
            .count() as f64
            / trace.len() as f64;
        h.row(&[
            name.to_string(),
            format!("{:.1}%", frac * 100.0),
            note.to_string(),
        ]);
    }
    h.print();

    let mut json = BenchJson::new(
        "fig2_workload",
        "Table 2 / Fig 2: synthetic trace statistics vs the paper's published values",
    );
    json.field_int("requests_per_dataset", n as i64);
    json.table("table2", &t);
    json.table("fig2_histogram", &h);
    json.write_or_die();
}
