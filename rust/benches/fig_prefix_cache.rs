//! Prefix-cache sweep (beyond the paper): retention budget vs hit rate
//! and later-turn TTFT on the session-heavy scenarios (multi_round,
//! diurnal_chat).
//!
//! Every run uses `session_affinity` dispatch so the only variable is the
//! cache: the `none` row is the pre-cache baseline (affinity degrades to
//! its inner policy when no request carries a preference), then the
//! `predictive` policy is swept across budgets, with `lru`/`ttl` at the
//! middle budget for a policy comparison. The claim under test: warm
//! cache + affinity routing collapses TTFT for turns ≥ 2 of a session
//! (they prefill only the new suffix), and the effect grows with budget
//! until the working set fits. Emits `BENCH_prefix_cache.json`.

use std::collections::HashSet;

use star::bench::output::BenchJson;
use star::bench::scenarios::{smoke, ScenarioRegistry};
use star::bench::Table;
use star::config::ExperimentConfig;
use star::coordinator::PolicyRegistry;
use star::sim::{SimParams, SimReport, Simulator};

struct RunRow {
    label: String,
    report: SimReport,
}

fn base_exp(scenario: &str, rps: f64, policy: &str, budget: u64) -> ExperimentConfig {
    let mut exp = ExperimentConfig::default();
    exp.cluster.n_prefill = 2;
    exp.cluster.n_decode = 6;
    exp.cluster.rps = rps;
    exp.cluster.kv_capacity_tokens = 96_000;
    exp.cluster.max_batch = 48;
    exp.cluster.seed = 17;
    exp.scenario_name = Some(scenario.to_string());
    exp.dispatch_policy = "session_affinity".to_string();
    exp.kvcache.policy = policy.to_string();
    if budget > 0 {
        exp.kvcache.budget_tokens = budget;
    }
    exp.kvcache.ttl_s = 120.0;
    exp
}

fn run_one(label: &str, exp: ExperimentConfig, duration: f64) -> RunRow {
    let spec = ScenarioRegistry::with_builtins()
        .build(exp.scenario_name.as_deref().unwrap(), &exp)
        .expect("builtin scenario");
    let trace = spec.generate_for(duration, exp.cluster.seed);
    let params = SimParams {
        exp,
        max_sim_time: duration * 20.0,
        ..Default::default()
    };
    let report = Simulator::with_scenario(params, trace, &PolicyRegistry::with_builtins())
        .expect("builtin policies")
        .run();
    RunRow {
        label: label.to_string(),
        report,
    }
}

/// Mean TTFT (ms) over session turns ≥ 2 — the turns a warm prefix cache
/// can serve with a suffix-only prefill. Returns (mean_ms, n).
fn later_turn_ttft_ms(report: &SimReport) -> (f64, usize) {
    let later: HashSet<u64> = report
        .session_chains
        .iter()
        .flat_map(|c| c.iter().skip(1).copied())
        .collect();
    let mut sum = 0.0;
    let mut n = 0usize;
    for l in &report.completed {
        if !later.contains(&l.id) {
            continue;
        }
        if let Some(t) = l.ttft() {
            sum += t * 1e3;
            n += 1;
        }
    }
    (if n == 0 { f64::NAN } else { sum / n as f64 }, n)
}

fn main() {
    let duration = if smoke() { 120.0 } else { 1200.0 };
    let rps = if smoke() { 0.3 } else { 0.6 };
    let budgets: [(u64, &str); 3] = [(8_000, "8k"), (32_000, "32k"), (96_000, "96k")];

    let mut json = BenchJson::new(
        "prefix_cache",
        "prefix-cache budget sweep: hit rate and later-turn TTFT under \
         session_affinity dispatch on session-heavy scenarios",
    );
    json.field_num("duration_s", duration);
    json.field_num("rps", rps);

    for scenario in ["multi_round", "diurnal_chat"] {
        let mut rows: Vec<RunRow> = Vec::new();
        rows.push(run_one(
            "no cache",
            base_exp(scenario, rps, "none", 0),
            duration,
        ));
        for (budget, tag) in budgets {
            rows.push(run_one(
                &format!("predictive @{tag}"),
                base_exp(scenario, rps, "predictive", budget),
                duration,
            ));
        }
        for policy in ["lru", "ttl"] {
            rows.push(run_one(
                &format!("{policy} @32k"),
                base_exp(scenario, rps, policy, 32_000),
                duration,
            ));
        }

        let mut t = Table::new(
            &format!("Prefix cache — {scenario}: budget vs hit rate and later-turn TTFT"),
            &[
                "cache",
                "hit rate",
                "tokens reused",
                "later-turn TTFT (ms)",
                "later turns",
                "P99 TTFT (ms)",
                "completed",
                "failed",
            ],
        );
        let mut none_later = f64::NAN;
        let mut warm_later = f64::NAN;
        let mut warm_hit_rate = 0.0;
        for row in &rows {
            let m = row.report.metrics();
            let (later_ms, later_n) = later_turn_ttft_ms(&row.report);
            if row.label == "no cache" {
                none_later = later_ms;
            }
            if row.label == "predictive @96k" {
                warm_later = later_ms;
                warm_hit_rate = row.report.cache.hit_rate();
            }
            t.row(&[
                row.label.clone(),
                format!("{:.3}", row.report.cache.hit_rate()),
                row.report.cache.tokens_reused.to_string(),
                format!("{later_ms:.1}"),
                later_n.to_string(),
                format!("{:.1}", m.p99_ttft_ms()),
                row.report.completed.len().to_string(),
                row.report.n_failed.to_string(),
            ]);
            println!(
                "[{scenario}] {}: {} | later-turn TTFT {later_ms:.1} ms over {later_n} turns",
                row.label,
                row.report.cache.summary()
            );
        }
        t.print();
        json.table(&format!("{scenario}_results"), &t);
        json.field_num(&format!("{scenario}_later_ttft_none_ms"), none_later);
        json.field_num(&format!("{scenario}_later_ttft_warm_ms"), warm_later);
        json.field_num(&format!("{scenario}_warm_hit_rate"), warm_hit_rate);
    }
    json.write_or_die();
    println!(
        "claim: with session_affinity dispatch and a warm prefix cache, later \
         session turns prefill only their new suffix — later-turn TTFT drops \
         vs `--cache none`, and the drop grows with the retention budget"
    );
}
