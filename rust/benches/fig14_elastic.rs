//! Fig. 14 (beyond the paper): elastic instance pool vs static P/D
//! splits under the drifting workload scenarios (diurnal_chat,
//! bursty_mixed).
//!
//! Every static split of an 8-instance budget is run next to the elastic
//! policies (`queue_pressure`, `predictive`) starting from a middling
//! split with role flips only (`elastic.max_total = 0`, so the
//! comparison is budget-fair). The claim under test: a pool that
//! re-roles itself off the predictive load signal matches or beats the
//! best frozen split on per-class goodput, because no single split is
//! right for both the peak and the trough of a drifting workload.
//! Emits `BENCH_elastic.json` (goodput, P99 TTFT/TPOT, scale-action
//! count, and the instance-count timeline per elastic run).

use star::bench::output::BenchJson;
use star::bench::scenarios::{llm_native_rel_err, smoke, ScenarioRegistry};
use star::bench::Table;
use star::config::ExperimentConfig;
use star::coordinator::PolicyRegistry;
use star::sim::{SimParams, SimReport, Simulator};
use star::workload::SloByClass;

/// Fixed instance budget shared by every run.
const TOTAL: usize = 8;

struct RunRow {
    label: String,
    report: SimReport,
    slos: SloByClass,
    duration_planned: f64,
}

fn base_exp(
    prefill: usize,
    decode: usize,
    scaling: &str,
    rps: f64,
    scenario: &str,
) -> ExperimentConfig {
    let mut exp = ExperimentConfig::default();
    exp.cluster.n_prefill = prefill;
    exp.cluster.n_decode = decode;
    exp.cluster.rps = rps;
    exp.cluster.kv_capacity_tokens = 96_000;
    exp.cluster.max_batch = 48;
    exp.cluster.seed = 14;
    exp.predictor_rel_err = llm_native_rel_err();
    exp.scenario_name = Some(scenario.to_string());
    exp.scaling_policy = scaling.to_string();
    exp.elastic.scale_interval_s = 5.0;
    exp.elastic.cooldown_s = 15.0;
    exp.elastic.flip_delay_s = 2.0;
    exp.elastic.max_total = 0; // flips only: budget-fair comparison
    exp
}

fn run_one(label: &str, exp: ExperimentConfig, duration: f64) -> RunRow {
    let spec = ScenarioRegistry::with_builtins()
        .build(exp.scenario_name.as_deref().unwrap(), &exp)
        .expect("builtin scenario");
    let slos = spec.slos();
    let trace = spec.generate_for(duration, exp.cluster.seed);
    let params = SimParams {
        exp,
        max_sim_time: duration * 20.0,
        ..Default::default()
    };
    let report = Simulator::with_scenario(params, trace, &PolicyRegistry::with_builtins())
        .expect("builtin policies")
        .run();
    RunRow {
        label: label.to_string(),
        report,
        slos,
        duration_planned: duration,
    }
}

fn timeline_json(report: &SimReport) -> String {
    let mut s = String::from("[");
    for (i, p) in report.pool_timeline.iter().enumerate() {
        if i > 0 {
            s.push_str(", ");
        }
        s.push_str(&format!(
            "[{:.1}, {}, {}]",
            p.t, p.prefill_active, p.decode_active
        ));
    }
    s.push(']');
    s
}

fn main() {
    let duration = if smoke() { 120.0 } else { 1800.0 };
    let rps = if smoke() { 0.3 } else { 0.6 };

    let mut json = BenchJson::new(
        "elastic",
        "elastic instance pool (flip-only, fixed 8-instance budget) vs static \
         P/D splits under drifting scenarios",
    );
    json.field_num("duration_s", duration);
    json.field_num("rps", rps);
    json.field_int("total_instances", TOTAL as i64);

    for scenario in ["diurnal_chat", "bursty_mixed"] {
        let mut rows: Vec<RunRow> = Vec::new();
        for prefill in [1usize, 2, 3, 4] {
            let decode = TOTAL - prefill;
            rows.push(run_one(
                &format!("static {prefill}p/{decode}d"),
                base_exp(prefill, decode, "static", rps, scenario),
                duration,
            ));
        }
        for scaling in ["queue_pressure", "predictive"] {
            rows.push(run_one(
                &format!("elastic {scaling} (from 2p/6d)"),
                base_exp(2, TOTAL - 2, scaling, rps, scenario),
                duration,
            ));
        }

        let mut t = Table::new(
            &format!("Fig 14 — {scenario}: static splits vs elastic policies"),
            &[
                "system",
                "goodput (req/s)",
                "P99 TTFT (ms)",
                "P99 TPOT (ms)",
                "completed",
                "failed",
                "scale actions",
                "final pool",
            ],
        );
        let mut best_static = f64::MIN;
        let mut predictive_goodput = f64::MIN;
        for row in &rows {
            let m = row.report.metrics();
            let goodput = m.goodput_by_class(&row.slos);
            if row.label.starts_with("static") {
                best_static = best_static.max(goodput);
            }
            if row.label.contains("predictive") {
                predictive_goodput = goodput;
            }
            let final_pool = row
                .report
                .pool_timeline
                .last()
                .map(|p| format!("{}p/{}d", p.prefill_active, p.decode_active))
                .unwrap_or_else(|| "-".to_string());
            t.row(&[
                row.label.clone(),
                format!("{goodput:.4}"),
                format!("{:.1}", m.p99_ttft_ms()),
                format!("{:.2}", m.p99_tpot_ms()),
                row.report.completed.len().to_string(),
                row.report.n_failed.to_string(),
                row.report.scale_actions.len().to_string(),
                final_pool,
            ]);
            println!(
                "[{scenario}] {}: goodput {goodput:.4} req/s over {:.0}s plan",
                row.label, row.duration_planned
            );
        }
        t.print();
        json.table(&format!("{scenario}_results"), &t);
        json.field_num(&format!("{scenario}_best_static_goodput"), best_static);
        json.field_num(&format!("{scenario}_predictive_goodput"), predictive_goodput);
        for row in &rows {
            if !row.label.starts_with("static") {
                let key = if row.label.contains("predictive") {
                    format!("{scenario}_timeline_predictive")
                } else {
                    format!("{scenario}_timeline_queue_pressure")
                };
                json.field_raw(&key, &timeline_json(&row.report));
                let actions: Vec<String> = row
                    .report
                    .scale_actions
                    .iter()
                    .map(|r| format!("\"{:.1}s {}\"", r.t, r.action))
                    .collect();
                json.field_raw(
                    &format!("{key}_actions"),
                    &format!("[{}]", actions.join(", ")),
                );
            }
        }
    }
    json.write_or_die();
    println!(
        "claim: under drifting load the predictive elastic pool should match or \
         beat the best static split's goodput (no frozen split fits both the \
         peak and the trough)"
    );
}
