//! Table 4: reprediction-interval tradeoff (paper §5.3 + §6.5) — every
//! iteration vs every 20 vs every 100 vs none, on the large cluster.
//! Paper reading: k=20 wins; k=1 pays prediction overhead and triggers
//! jittery migrations; k=100 goes stale.

use star::bench::output::BenchJson;
use star::bench::scenarios::{large_cluster, scaled, sim_params, trace_for};
use star::bench::Table;
use star::metrics::Slo;
use star::sim::Simulator;
use star::workload::Dataset;

fn main() {
    let n = scaled(400);
    let rps = 0.35; // near the knee (paper used 0.20 on its hardware)
    let slo = Slo {
        ttft_s: 1.0,
        tpot_s: 0.025,
    };
    let settings: Vec<(&str, Option<u32>)> = vec![
        ("1 iter", Some(1)),
        ("20 iter", Some(20)),
        ("100 iter", Some(100)),
        ("No pred.", None),
    ];
    let mut t = Table::new(
        "Table 4: prediction-interval tradeoff (large cluster, near-knee rps)",
        &["Interval", "Exec. Var.", "P99 TPOT (ms)", "Goodput", "Goodput Gain", "migrations"],
    );
    let mut rows = Vec::new();
    let mut base = 0.0;
    for (name, k) in settings {
        let mut exp = large_cluster(Dataset::ShareGpt, rps, 71);
        exp.rescheduler.enabled = true;
        match k {
            Some(k) => {
                // the simulated LLM-native predictor pays per-call latency
                exp.predictor = "llm_native".to_string();
                exp.rescheduler.predict_every_iters = k;
            }
            None => exp.predictor = "none".to_string(),
        }
        let trace = trace_for(&exp, n);
        let report = Simulator::new(sim_params(exp, true), &trace).run();
        let m = report.metrics();
        let g = m.goodput(slo);
        if name == "No pred." {
            base = g;
        }
        rows.push((
            name.to_string(),
            report.exec_var.sample_mean(),
            m.p99_tpot_ms(),
            g,
            report.migrations,
        ));
    }
    for (name, ev, tpot, g, migs) in rows {
        let gain = if base > 0.0 {
            format!("{:+.2}%", 100.0 * (g / base - 1.0))
        } else {
            "-".into()
        };
        t.row(&[
            name,
            format!("{ev:.3}"),
            format!("{tpot:.2}"),
            format!("{g:.4}"),
            gain,
            migs.to_string(),
        ]);
    }
    t.print();
    let mut json = BenchJson::new(
        "table4_interval",
        "reprediction-interval tradeoff: every 1/20/100 iterations vs none",
    );
    json.field_int("requests", n as i64).field_num("rps", rps);
    json.table("table4", &t);
    json.write_or_die();
    println!(
        "paper: 20-iter interval is best (goodput 0.157 vs 0.148 @1 / 0.145 @100 / \
         0.142 none); the inverted-U over k is the claim under test"
    );
}
