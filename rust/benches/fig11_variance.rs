//! Fig. 11: execution-time variance across decode instances over time on
//! the small cluster (1 prefill + 3 decode), for the four systems. Paper
//! reading: vLLM shows bursty variance; rescheduling suppresses it;
//! prediction brings it close to the oracle (paper: 0.78 ms^2 average).

use star::bench::output::BenchJson;
use star::bench::scenarios::{paper_scenarios, run_scenario, scaled, small_cluster, trace_for};
use star::bench::Table;
use star::workload::Dataset;

fn main() {
    let n = scaled(400);
    let rps = 0.12;
    let scs = paper_scenarios();
    let mut series: Vec<Vec<(f64, f64)>> = Vec::new();
    let mut avgs = Vec::new();
    for sc in &scs {
        let exp = small_cluster(Dataset::ShareGpt, rps, 31);
        let trace = trace_for(&exp, n);
        let report = run_scenario(*sc, exp, false, &trace);
        series.push(report.exec_var.series().to_vec());
        avgs.push((sc.name, report.exec_var.sample_mean(), report.oom_events));
    }

    // time-bucketed table (18 rows)
    let t_end = series
        .iter()
        .filter_map(|s| s.last().map(|x| x.0))
        .fold(0.0, f64::max);
    let mut t = Table::new(
        "Fig 11: exec-time variance (ms^2) over time, small cluster, ShareGPT",
        &["t(s)", "vLLM", "STAR w/o pred", "STAR w/ pred", "STAR Oracle"],
    );
    let buckets = 18;
    for b in 0..buckets {
        let lo = t_end * b as f64 / buckets as f64;
        let hi = t_end * (b + 1) as f64 / buckets as f64;
        let mut row = vec![format!("{lo:.0}")];
        for s in &series {
            let vals: Vec<f64> = s
                .iter()
                .filter(|(t, _)| *t >= lo && *t < hi)
                .map(|(_, v)| *v)
                .collect();
            row.push(if vals.is_empty() {
                "-".into()
            } else {
                format!("{:.2}", vals.iter().sum::<f64>() / vals.len() as f64)
            });
        }
        t.row(&row);
    }
    t.print();

    let mut summary = Table::new(
        "Fig 11 summary: average execution-time variance",
        &["System", "mean exec-var (ms^2)", "OOMs"],
    );
    for (name, avg, ooms) in &avgs {
        summary.row(&[name.to_string(), format!("{avg:.3}"), ooms.to_string()]);
    }
    summary.print();
    let v = avgs[0].1;
    let o = avgs[3].1;
    let p = avgs[2].1;
    println!(
        "variance: vLLM {v:.2} -> STAR w/ pred {p:.2} -> oracle {o:.2} ms^2 \
         (paper: prediction lands close to oracle; oracle avg 0.78 ms^2 on 4090D)"
    );
    let mut json = BenchJson::new(
        "fig11_variance",
        "exec-time variance over time on the small cluster, four systems",
    );
    json.field_int("requests", n as i64).field_num("rps", rps);
    json.table("variance_over_time", &t);
    json.table("summary", &summary);
    json.write_or_die();
}
