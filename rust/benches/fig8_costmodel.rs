//! Fig. 8: decode iteration time and KV memory vs number of batched
//! tokens — measured on the REAL stack (star-pico through PJRT), not the
//! simulator. The linear fit calibrates the simulator's `cpu_measured`
//! cost profile (written to artifacts/costmodel_cpu.txt).

use std::time::Instant;

use star::bench::output::{write_skipped, BenchJson};
use star::bench::scenarios::smoke;
use star::bench::Table;
use star::costmodel::fit_linear;
use star::runtime::{artifacts_dir, StarRuntime};

fn main() {
    let dir = match artifacts_dir(None) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("SKIP fig8: {e}");
            write_skipped("fig8_costmodel", &format!("artifacts not built: {e}"));
            return;
        }
    };
    let rt = match StarRuntime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP fig8: artifacts load failed: {e}");
            write_skipped("fig8_costmodel", &format!("artifacts load failed: {e}"));
            return;
        }
    };
    let bucket = *rt.meta.decode_buckets.last().unwrap();
    let reps = if smoke() {
        3
    } else if std::env::var("STAR_BENCH_FAST").is_ok() {
        5
    } else {
        20
    };

    // Build a full batch where every sequence has `len` tokens of KV, then
    // time one decode step. Total batched tokens = bucket * len.
    let pre = rt.prefill(b"\x01Qcalibration?").expect("prefill");
    let mut table = Table::new(
        "Fig 8: decode-iteration cost vs batched tokens (star-pico, PJRT CPU)",
        &["batched_tokens", "iter_ms", "kv_mbytes"],
    );
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let lens = [16, 64, 128, 256, 384, 512, 638];
    for &len in &lens {
        let mut kv = rt.new_kv_buffer(bucket);
        for slot in 0..bucket {
            rt.copy_kv_slot(&pre.kv, 1, 0, &mut kv, bucket, slot).unwrap();
        }
        let tokens: Vec<i32> = (0..bucket).map(|i| (i % 200 + 32) as i32).collect();
        let pos = vec![len as i32; bucket];
        // warmup
        let out = rt.decode_step(bucket, &tokens, &pos, &kv).unwrap();
        kv = out.kv;
        let t0 = Instant::now();
        for _ in 0..reps {
            let out = rt.decode_step(bucket, &tokens, &pos, &kv).unwrap();
            kv = out.kv;
        }
        let ms = t0.elapsed().as_secs_f64() / reps as f64 * 1e3;
        let batched = bucket * len;
        let kv_mb = batched as f64 * rt.meta.kv_bytes_per_token as f64 / 1e6;
        table.row(&[
            batched.to_string(),
            format!("{ms:.3}"),
            format!("{kv_mb:.2}"),
        ]);
        xs.push(batched as f64);
        ys.push(ms / 1e3);
    }
    table.print();

    let (a, b, r2) = fit_linear(&xs, &ys);
    println!(
        "linear fit: iter_s = {a:.6} + {b:.3e} * tokens   (r^2 = {r2:.4})"
    );
    println!(
        "paper claim: iteration time is linear in batched tokens; r^2 >= 0.95 \
         reproduces the Fig 8 left panel shape => {}",
        if r2 >= 0.95 { "PASS" } else { "MARGINAL" }
    );
    println!(
        "memory: exactly linear by construction ({} bytes/token), Fig 8 right panel",
        rt.meta.kv_bytes_per_token
    );

    // calibration output for the simulator's measured profile
    let path = dir.join("costmodel_cpu.txt");
    let body = format!("base_s={a:.9}\nper_token_s={b:.3e}\nr2={r2:.6}\n");
    std::fs::write(&path, body).expect("write calibration");
    println!("calibration written to {}", path.display());

    let mut json = BenchJson::new(
        "fig8_costmodel",
        "decode iteration time vs batched tokens on the real stack (linear-fit calibration)",
    );
    json.table("iter_cost", &table);
    json.field_num("fit_base_s", a)
        .field_num("fit_per_token_s", b)
        .field_num("fit_r2", r2)
        .field_int("reps", reps as i64);
    json.write_or_die();
}
