//! Fig. 3: per-instance decode-step latency over time under the two
//! dispatch-only baselines (round-robin, current-load) with NO decode
//! rescheduling — the motivating imbalance. The paper's reading: initial
//! balance degrades as long-output requests accumulate on one instance.

use star::bench::output::BenchJson;
use star::bench::scenarios::{scaled, sim_params, small_cluster};
use star::bench::Table;
use star::sim::Simulator;
use star::workload::{Dataset, TraceGen};

fn main() {
    let n = scaled(300);
    let rps = 0.1; // paper Fig 3 setting
    let mut json = BenchJson::new(
        "fig3_imbalance",
        "per-instance decode-step latency over time under dispatch-only baselines",
    );
    json.field_int("requests", n as i64).field_num("rps", rps);
    for dispatch in ["round_robin", "current_load"] {
        let mut exp = small_cluster(Dataset::ShareGpt, rps, 11);
        exp.rescheduler.enabled = false;
        exp.predictor = "none".to_string();
        exp.record_traces = true;
        exp.dispatch_policy = dispatch.to_string();
        let trace = TraceGen::new(Dataset::ShareGpt, rps).generate(n, 11);
        let params = sim_params(exp, false);
        // reconstruct per-instance decode latency over time from the
        // KV samples (tokens -> iteration time through the cost model)
        let cost = params.decode_cost;
        let report = Simulator::new(params, &trace).run();
        let mut t = Table::new(
            &format!(
                "Fig 3{}: per-instance decode-step latency (ms) over time — {}",
                if dispatch == "round_robin" { "a" } else { "b" },
                dispatch
            ),
            &["t(s)", "inst0", "inst1", "inst2", "spread(max-min)"],
        );
        let mut cur = [0.0f64; 3];
        let mut next_print = 0.0;
        let mut max_spread: f64 = 0.0;
        for row in report.recorder.rows() {
            if let star::metrics::TraceEvent::KvSample {
                instance,
                tokens,
                batch,
                ..
            } = row.event
            {
                if instance < 3 {
                    cur[instance] = cost.iter_time(tokens, batch) * 1e3;
                }
                let spread =
                    cur.iter().cloned().fold(0.0, f64::max) - cur.iter().cloned().fold(1e18, f64::min);
                max_spread = max_spread.max(spread);
                if row.t >= next_print {
                    t.row(&[
                        format!("{:.0}", row.t),
                        format!("{:.2}", cur[0]),
                        format!("{:.2}", cur[1]),
                        format!("{:.2}", cur[2]),
                        format!("{:.2}", spread),
                    ]);
                    next_print = row.t + report.duration / 18.0;
                }
            }
        }
        t.print();
        println!(
            "{}: exec-time variance (mean) {:.2} ms^2 | max latency spread {:.2} ms | OOMs {}",
            dispatch,
            report.exec_var.sample_mean(),
            max_spread,
            report.oom_events
        );
        println!(
            "paper claim: both dispatch-only policies diverge over time (TPOT spikes on \
             the instance holding long requests)\n"
        );
        json.table(&format!("latency_{dispatch}"), &t);
        json.field_num(
            &format!("mean_exec_var_ms2_{dispatch}"),
            report.exec_var.sample_mean(),
        );
        json.field_num(&format!("max_spread_ms_{dispatch}"), max_spread);
        json.field_int(&format!("ooms_{dispatch}"), report.oom_events as i64);
    }
    json.write_or_die();
}
