//! Fig. 10: end-to-end throughput / goodput / P99 TPOT vs request rate on
//! ShareGPT and Alpaca, for the paper's four systems. Paper headline:
//! up to 2.63x goodput and -75.1% P99 TPOT vs the vLLM (dispatch-only)
//! baseline, largest gains at high load.
//!
//! Scenario extension: the same large cluster is re-run under the
//! `bursty_mixed` workload scenario (on/off MMPP arrivals over the
//! chat/reasoning/summarization class mix) and the per-class goodput
//! lands in the same `BENCH_fig10_end2end.json` as the stationary
//! numbers — bursty class-mixed traffic is where the aggregate goodput
//! hides per-class SLO violations.

use star::bench::output::BenchJson;
use star::bench::scenarios::{
    large_cluster, paper_scenarios, run_scenario, run_scenario_trace, scaled, trace_for,
    ScenarioRegistry,
};
use star::bench::Table;
use star::metrics::Slo;
use star::workload::Dataset;

fn main() {
    let n = scaled(400);
    let slo = Slo {
        ttft_s: 1.0,
        tpot_s: 0.025, // paper: 25 ms for the 7B model
    };
    let mut json = BenchJson::new(
        "fig10_end2end",
        "end-to-end throughput/goodput/P99 TPOT vs rps, stationary + bursty_mixed scenario",
    );
    json.field_int("requests", n as i64);
    for dataset in [Dataset::ShareGpt, Dataset::Alpaca] {
        // brackets our substrate's KV-bound equilibrium (~0.375 rps for
        // 6 decode instances) the way the paper's grid brackets theirs
        let rps_grid = [0.15, 0.25, 0.35, 0.45];
        let mut thr = Table::new(
            &format!("Fig 10 ({}, large cluster): throughput (req/s)", dataset.name()),
            &["rps", "vLLM", "STAR w/o pred", "STAR w/ pred", "STAR Oracle"],
        );
        let mut good = Table::new(
            &format!("Fig 10 ({}): goodput (req/s, SLO 1s TTFT / 25ms TPOT)", dataset.name()),
            &["rps", "vLLM", "STAR w/o pred", "STAR w/ pred", "STAR Oracle"],
        );
        let mut tpot = Table::new(
            &format!("Fig 10 ({}): P99 TPOT (ms)", dataset.name()),
            &["rps", "vLLM", "STAR w/o pred", "STAR w/ pred", "STAR Oracle"],
        );
        let mut ooms = Table::new(
            &format!("Fig 10 ({}): OOM events", dataset.name()),
            &["rps", "vLLM", "STAR w/o pred", "STAR w/ pred", "STAR Oracle"],
        );
        let mut headline: Vec<(f64, f64, f64, f64)> = Vec::new(); // rps, good_vllm, good_star, tpot ratio
        for &rps in &rps_grid {
            let exp = large_cluster(dataset, rps, 23);
            let trace = trace_for(&exp, n);
            let mut r_thr = vec![format!("{rps:.2}")];
            let mut r_good = vec![format!("{rps:.2}")];
            let mut r_tpot = vec![format!("{rps:.2}")];
            let mut r_oom = vec![format!("{rps:.2}")];
            let mut gp = Vec::new();
            let mut tp = Vec::new();
            for sc in paper_scenarios() {
                let report = run_scenario(sc, exp.clone(), true, &trace);
                let m = report.metrics();
                r_thr.push(format!("{:.4}", m.throughput()));
                r_good.push(format!("{:.4}", m.goodput(slo)));
                r_tpot.push(format!("{:.2}", m.p99_tpot_ms()));
                r_oom.push(report.oom_events.to_string());
                gp.push(m.goodput(slo));
                tp.push(m.p99_tpot_ms());
            }
            thr.row(&r_thr);
            good.row(&r_good);
            tpot.row(&r_tpot);
            ooms.row(&r_oom);
            headline.push((rps, gp[0], gp[2], tp[2] / tp[0]));
        }
        thr.print();
        good.print();
        tpot.print();
        ooms.print();
        json.table(&format!("{}_throughput", dataset.name()), &thr);
        json.table(&format!("{}_goodput", dataset.name()), &good);
        json.table(&format!("{}_p99_tpot_ms", dataset.name()), &tpot);
        json.table(&format!("{}_ooms", dataset.name()), &ooms);
        for (rps, g_v, g_s, t_ratio) in headline {
            if g_v > 0.0 {
                println!(
                    "{} rps {rps:.2}: goodput STARw/pred / vLLM = {:.2}x (paper: up to 2.63x); \
                     P99 TPOT ratio = {:.2} (paper: -75.1%)",
                    dataset.name(),
                    g_s / g_v,
                    t_ratio
                );
            } else {
                println!(
                    "{} rps {rps:.2}: vLLM goodput 0 — STAR w/ pred {:.4} req/s",
                    dataset.name(),
                    g_s
                );
            }
        }
        println!();
    }

    // ---- bursty_mixed scenario re-run (same cluster, near-knee rps) ----
    let rps = 0.35;
    let exp = large_cluster(Dataset::ShareGpt, rps, 23);
    let spec = ScenarioRegistry::with_builtins()
        .build("bursty_mixed", &exp)
        .expect("builtin scenario");
    let strace = spec.generate(n, exp.cluster.seed);
    let slos = spec.slos();
    let mut burst = Table::new(
        "Fig 10 (bursty_mixed scenario, large cluster, 0.35 rps mean): per-system",
        &[
            "system",
            "goodput(agg SLO)",
            "goodput(per-class SLO)",
            "P99 TPOT (ms)",
            "OOMs",
            "chat gp",
            "reasoning gp",
            "summarization gp",
        ],
    );
    for sc in paper_scenarios() {
        let report = run_scenario_trace(sc, exp.clone(), true, &strace);
        let m = report.metrics();
        let mut row = vec![
            sc.name.to_string(),
            format!("{:.4}", m.goodput(slo)),
            format!("{:.4}", m.goodput_by_class(&slos)),
            format!("{:.2}", m.p99_tpot_ms()),
            report.oom_events.to_string(),
        ];
        let per_class = report.class_metrics(&slos);
        for class in star::workload::RequestClass::ALL {
            let cell = per_class
                .iter()
                .find(|c| c.class == class)
                .map(|c| format!("{:.4}", c.goodput))
                .unwrap_or_else(|| "-".to_string());
            row.push(cell);
        }
        burst.row(&row);
        println!("[bursty_mixed] {}:", sc.name);
        println!("{}", report.class_summary(&slos));
    }
    burst.print();
    json.field_str("bursty_scenario", &spec.name);
    json.field_num("bursty_mean_rps", spec.arrival.mean_rps());
    json.table("bursty_mixed", &burst);
    json.write_or_die();
    println!(
        "scenario claim under test: under bursty class-mixed arrivals the aggregate \
         goodput hides per-class SLO violations — the per-class columns expose them"
    );
}
