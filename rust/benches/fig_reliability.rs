//! Reliability under failure injection × heterogeneous fleets (beyond
//! the paper): goodput and P99 TPOT as decode instances crash and
//! recover, across fleet hardware mixes.
//!
//! Grid: three fleet mixes (uniform; `degraded` with a slow/small middle
//! instance; `mixed_gen` pairing a fast/small generation with a
//! slow/roomy one) × three failure intensities (none; MTBF 600 s;
//! MTBF 240 s), all with MTTR 30 s. The claims under test:
//!
//! 1. accounting closes — every arrived request is completed or
//!    terminally failed, with `reliability.lost` a subset of the
//!    failures (crash-displaced requests re-queue through the normal
//!    recompute path and finish);
//! 2. goodput degrades gracefully with failure rate rather than
//!    collapsing (re-queue + recovery keep the fleet serving);
//! 3. the same-seed failure schedule is deterministic, so rows are
//!    reproducible run to run.
//!
//! Emits `BENCH_reliability.json` (goodput, P99 TPOT, completion
//! accounting, and the full reliability counters per cell).

use star::bench::output::BenchJson;
use star::bench::scenarios::{scaled, sim_params, small_cluster};
use star::bench::Table;
use star::metrics::Slo;
use star::sim::Simulator;
use star::workload::{Dataset, FaultConfig, FleetSpec, TraceGen};

fn fleet_mix(name: &str) -> Option<FleetSpec> {
    match name {
        "uniform" => None,
        // one degraded mid-fleet card: slower and smaller
        "degraded" => Some(FleetSpec::from_mults(&[1.0, 0.7, 1.0], &[1.0, 0.8, 1.2])),
        // two generations: fast/small alternating with slow/roomy
        "mixed_gen" => Some(FleetSpec::from_mults(&[1.0, 0.5], &[1.0, 2.0])),
        other => panic!("unknown fleet mix {other}"),
    }
}

fn main() {
    let n = scaled(400);
    let rps = 0.2;
    let seed = 29;
    let mut json = BenchJson::new(
        "reliability",
        "goodput and P99 TPOT under failure injection across fleet hardware mixes",
    );
    json.field_int("requests", n as i64).field_num("rps", rps);

    let mut accounting_ok = true;
    for mix in ["uniform", "degraded", "mixed_gen"] {
        let mut t = Table::new(
            &format!("Reliability — fleet mix `{mix}`"),
            &[
                "failures (MTBF)",
                "goodput (req/s)",
                "P99 TPOT (ms)",
                "completed",
                "failed",
                "crashes",
                "requeued",
                "lost",
                "kv dropped",
            ],
        );
        for (label, mtbf_s) in [("none", 0.0), ("mtbf 600s", 600.0), ("mtbf 240s", 240.0)] {
            let mut exp = small_cluster(Dataset::ShareGpt, rps, seed);
            exp.fleet = fleet_mix(mix);
            if mtbf_s > 0.0 {
                exp.faults = Some(FaultConfig {
                    mtbf_s,
                    mttr_s: 30.0,
                    max_failures: 6,
                    script: Vec::new(),
                });
            }
            let trace = TraceGen::new(Dataset::ShareGpt, rps).generate(n, seed);
            let report = Simulator::new(sim_params(exp, false), &trace).run();
            let m = report.metrics();
            let goodput = m.goodput(Slo::default());
            let rel = &report.reliability;
            // claim 1: the books close — crash-displaced requests either
            // complete after re-queue or are counted in n_failed (lost is
            // a subset of n_failed, never a third bucket)
            let closes = report.completed.len() + report.n_failed == report.n_requests
                && rel.lost <= report.n_failed;
            accounting_ok &= closes;
            t.row(&[
                label.to_string(),
                format!("{goodput:.4}"),
                format!("{:.2}", m.p99_tpot_ms()),
                report.completed.len().to_string(),
                report.n_failed.to_string(),
                rel.failures.to_string(),
                rel.requeued.to_string(),
                rel.lost.to_string(),
                rel.kv_tokens_dropped.to_string(),
            ]);
            let key = format!("{mix}_{}", label.replace(' ', "_"));
            json.field_num(&format!("goodput_{key}"), goodput);
            json.field_num(&format!("p99_tpot_ms_{key}"), m.p99_tpot_ms());
            json.field_int(&format!("failures_{key}"), rel.failures as i64);
            json.field_int(&format!("requeued_{key}"), rel.requeued as i64);
            json.field_int(&format!("lost_{key}"), rel.lost as i64);
            if !rel.is_empty() {
                println!("[{mix} / {label}] {}", rel.summary());
            }
            if !closes {
                eprintln!(
                    "[{mix} / {label}] ACCOUNTING HOLE: completed {} + failed {} != arrived {} \
                     (lost {})",
                    report.completed.len(),
                    report.n_failed,
                    report.n_requests,
                    rel.lost
                );
            }
        }
        t.print();
        json.table(&format!("{mix}_results"), &t);
    }
    json.field_bool("accounting_closes", accounting_ok);
    json.write_or_die();
    println!(
        "claim: goodput degrades gracefully with failure rate (re-queue + recovery \
         keep serving) and request accounting closes in every cell"
    );
    if !accounting_ok {
        std::process::exit(1);
    }
}
