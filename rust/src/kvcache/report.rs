//! Cache-effectiveness accounting shared by both drivers: the simulator
//! folds one [`CacheReport`] into its `SimReport`, the live server into
//! its `ServeOutcome`, and `star simulate` prints the same summary line
//! for either — hit rate and reuse volume are the numbers the prefix-cache
//! bench sweeps, so they live next to the cache instead of being
//! recomputed per driver.

/// Counters for one run of the prefix-cache subsystem. All zeros (and
/// `enabled == false`) under the `none` policy.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CacheReport {
    /// Was a real (non-`none`) cache policy active?
    pub enabled: bool,
    /// Follow-up turns that found a usable prefix.
    pub hits: u64,
    /// Follow-up turns that found nothing (or an unusable entry).
    pub misses: u64,
    /// Entries dropped because their TTL lapsed before reuse.
    pub expired: u64,
    /// Entries dropped for budget/capacity pressure or instance drains.
    pub evictions: u64,
    /// Prefixes retained at turn completion.
    pub insertions: u64,
    /// Σ prompt tokens whose prefill was skipped by hits.
    pub tokens_reused: u64,
    /// Hits routed away from the holding instance where moving the prefix
    /// over the fabric beat recomputing it (costmodel comparison).
    pub transfer_decisions: u64,
    /// Hits routed away where recomputing the prefix was cheaper.
    pub recompute_decisions: u64,
}

impl CacheReport {
    /// Hits / (hits + misses); 0 when no follow-up consulted the cache.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// One-line summary printed by `star simulate` for cache-enabled runs.
    pub fn summary(&self) -> String {
        format!(
            "prefix cache: {} hits / {} misses ({:.1}% hit rate) | {} tokens reused | \
             {} insertions | {} evictions (+{} expired) | off-instance hits: {} transferred, \
             {} recomputed",
            self.hits,
            self.misses,
            100.0 * self.hit_rate(),
            self.tokens_reused,
            self.insertions,
            self.evictions,
            self.expired,
            self.transfer_decisions,
            self.recompute_decisions,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate_handles_empty_and_mixed() {
        let mut r = CacheReport::default();
        assert_eq!(r.hit_rate(), 0.0);
        r.hits = 3;
        r.misses = 1;
        assert!((r.hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn summary_mentions_every_counter() {
        let r = CacheReport {
            enabled: true,
            hits: 5,
            misses: 2,
            expired: 1,
            evictions: 3,
            insertions: 7,
            tokens_reused: 1234,
            transfer_decisions: 1,
            recompute_decisions: 2,
        };
        let s = r.summary();
        for needle in ["5 hits", "2 misses", "1234 tokens reused", "3 evictions", "+1 expired"] {
            assert!(s.contains(needle), "missing `{needle}`: {s}");
        }
    }
}
