//! The prefix-cache layer: retained completed-turn KV per session, under
//! a per-instance token budget, with policy-ordered eviction.
//!
//! Ownership split (mirrors the predictor subsystem): this layer owns the
//! entry map and every counter; the drivers own *placement* — they decide
//! when a completed turn is offered (`insert`), when a follow-up consults
//! the cache (`take`), and when an instance's entries must flush
//! (`evict_instance`, the drain-then-flip interaction). Cached bytes are
//! mirrored into `ClusterState::cached_tokens` by the caller so dispatch,
//! admission, memory-pressure rescheduling, and the elastic scaler all
//! see idle KV competing honestly with active requests.
//!
//! Determinism: the entry map is a `BTreeMap` keyed by session id and
//! every eviction scan breaks priority ties on session id, so identical
//! call sequences produce identical evictions — the property the sim's
//! same-seed trace tests rely on.

use std::collections::BTreeMap;

use super::policy::{CachePolicy, CachedPrefix};
use super::report::CacheReport;
use crate::predictor::Prediction;
use crate::{InstanceId, Time};

/// Session-keyed prefix store. One live prefix per session; a newer
/// turn's insert supersedes the old entry.
pub struct PrefixCache {
    policy: Box<dyn CachePolicy>,
    /// Max cached tokens per instance.
    budget_tokens: u64,
    ttl_s: f64,
    entries: BTreeMap<u32, CachedPrefix>,
    /// Σ cached tokens per instance (grown on demand: elastic pools add
    /// instances mid-run).
    per_instance: Vec<u64>,
    report: CacheReport,
}

impl PrefixCache {
    pub fn new(policy: Box<dyn CachePolicy>, budget_tokens: u64, ttl_s: f64) -> PrefixCache {
        let enabled = policy.enabled();
        PrefixCache {
            policy,
            budget_tokens,
            ttl_s,
            entries: BTreeMap::new(),
            per_instance: Vec::new(),
            report: CacheReport {
                enabled,
                ..Default::default()
            },
        }
    }

    /// Is a real (non-`none`) policy active? When false every method is a
    /// no-op, keeping the disabled path bit-for-bit inert.
    pub fn enabled(&self) -> bool {
        self.policy.enabled()
    }

    pub fn policy_name(&self) -> &str {
        self.policy.name()
    }

    pub fn ttl_s(&self) -> f64 {
        self.ttl_s
    }

    /// Offer a completed turn's prefix for retention. `hard_cap_tokens`
    /// is the instance's physical headroom for cached bytes right now
    /// (capacity − active KV − inbound reservations): the cache may evict
    /// its own entries to fit under `min(budget, hard_cap)`, but never
    /// displaces live requests. Returns whether the prefix was stored.
    pub fn insert(
        &mut self,
        session: u32,
        instance: InstanceId,
        tokens: u64,
        now: Time,
        return_delay: Option<Prediction>,
        hard_cap_tokens: u64,
    ) -> bool {
        if !self.enabled() || tokens == 0 {
            return false;
        }
        // a newer turn supersedes any stale entry for the session
        if let Some(old) = self.entries.remove(&session) {
            self.sub_tokens(old.instance, old.tokens);
        }
        let entry = CachedPrefix {
            session,
            instance,
            tokens,
            stored_at: now,
            return_delay,
        };
        if !self.policy.admits(&entry, self.ttl_s) {
            return false;
        }
        let limit = self.budget_tokens.min(hard_cap_tokens);
        if tokens > limit {
            return false;
        }
        while self.cached_on(instance) + tokens > limit {
            if self.evict_worst_on(instance, now).is_none() {
                return false; // unreachable: tokens <= limit
            }
        }
        self.ensure_len(instance);
        self.per_instance[instance] += tokens;
        self.entries.insert(session, entry);
        self.report.insertions += 1;
        true
    }

    /// Remove and return the session's prefix if present and unexpired.
    /// Counts expiry internally; the CALLER classifies the outcome as a
    /// hit ([`Self::note_hit`]) or miss ([`Self::note_miss`]) once it has
    /// checked viability (lifecycle, admissibility) of the holding
    /// instance.
    pub fn take(&mut self, session: u32, now: Time) -> Option<CachedPrefix> {
        if !self.enabled() {
            return None;
        }
        let e = *self.entries.get(&session)?;
        self.entries.remove(&session);
        self.sub_tokens(e.instance, e.tokens);
        if self.policy.uses_ttl() && now - e.stored_at > self.ttl_s {
            self.report.expired += 1;
            return None;
        }
        Some(e)
    }

    /// Borrow the session's entry without removing it.
    pub fn peek(&self, session: u32) -> Option<&CachedPrefix> {
        self.entries.get(&session)
    }

    /// Sweep expired entries (scheduler-tick housekeeping). No-op for
    /// policies without a TTL.
    pub fn expire(&mut self, now: Time) {
        if !self.enabled() || !self.policy.uses_ttl() {
            return;
        }
        let dead: Vec<u32> = self
            .entries
            .values()
            .filter(|e| now - e.stored_at > self.ttl_s)
            .map(|e| e.session)
            .collect();
        for s in dead {
            if let Some(e) = self.entries.remove(&s) {
                self.sub_tokens(e.instance, e.tokens);
                self.report.expired += 1;
            }
        }
    }

    /// Flush every entry held by `instance` (drain-then-flip: a draining
    /// instance must not retire holding prefixes). Returns tokens freed.
    pub fn evict_instance(&mut self, instance: InstanceId) -> u64 {
        let dead: Vec<u32> = self
            .entries
            .values()
            .filter(|e| e.instance == instance)
            .map(|e| e.session)
            .collect();
        let mut freed = 0;
        for s in dead {
            if let Some(e) = self.entries.remove(&s) {
                self.sub_tokens(e.instance, e.tokens);
                self.report.evictions += 1;
                freed += e.tokens;
            }
        }
        freed
    }

    /// Evict policy-ordered victims on `instance` until at least
    /// `need_tokens` are freed (admission pressure: live requests always
    /// win over idle prefixes). Returns tokens actually freed.
    pub fn evict_for_headroom(
        &mut self,
        instance: InstanceId,
        need_tokens: u64,
        now: Time,
    ) -> u64 {
        let mut freed = 0;
        while freed < need_tokens {
            match self.evict_worst_on(instance, now) {
                Some(t) => freed += t,
                None => break,
            }
        }
        freed
    }

    /// Σ cached tokens on `instance`. O(1).
    pub fn cached_on(&self, instance: InstanceId) -> u64 {
        self.per_instance.get(instance).copied().unwrap_or(0)
    }

    /// Σ cached tokens across the pool.
    pub fn total_cached(&self) -> u64 {
        self.per_instance.iter().sum()
    }

    pub fn n_entries(&self) -> usize {
        self.entries.len()
    }

    /// Every live entry (deterministic session-id order) — the sim's
    /// reference-snapshot rebuild recomputes per-instance cached totals
    /// from this.
    pub fn entries(&self) -> impl Iterator<Item = &CachedPrefix> {
        self.entries.values()
    }

    pub fn note_hit(&mut self, tokens_reused: u64) {
        self.report.hits += 1;
        self.report.tokens_reused += tokens_reused;
    }

    pub fn note_miss(&mut self) {
        self.report.misses += 1;
    }

    /// A taken entry the caller could not use (holding instance drained /
    /// inadmissible): its bytes are already released; account the drop.
    pub fn note_evicted(&mut self) {
        self.report.evictions += 1;
    }

    pub fn note_transfer(&mut self) {
        self.report.transfer_decisions += 1;
    }

    pub fn note_recompute(&mut self) {
        self.report.recompute_decisions += 1;
    }

    pub fn report(&self) -> CacheReport {
        self.report.clone()
    }

    /// Worst-priority victim on `instance` (ties: lowest session id).
    fn evict_worst_on(&mut self, instance: InstanceId, now: Time) -> Option<u64> {
        let mut worst: Option<(f64, u32)> = None;
        for e in self.entries.values() {
            if e.instance != instance {
                continue;
            }
            let p = self.policy.victim_priority(e, now);
            let better = match worst {
                None => true,
                Some((wp, ws)) => p > wp || (p == wp && e.session < ws),
            };
            if better {
                worst = Some((p, e.session));
            }
        }
        let (_, session) = worst?;
        let e = self.entries.remove(&session)?;
        self.sub_tokens(e.instance, e.tokens);
        self.report.evictions += 1;
        Some(e.tokens)
    }

    fn ensure_len(&mut self, instance: InstanceId) {
        if self.per_instance.len() <= instance {
            self.per_instance.resize(instance + 1, 0);
        }
    }

    fn sub_tokens(&mut self, instance: InstanceId, tokens: u64) {
        self.ensure_len(instance);
        debug_assert!(
            self.per_instance[instance] >= tokens,
            "cached-token accounting underflow on instance {instance}"
        );
        self.per_instance[instance] = self.per_instance[instance].saturating_sub(tokens);
    }
}

#[cfg(test)]
mod tests {
    use super::super::policy::{
        LruCachePolicy, NoneCachePolicy, PredictiveCachePolicy, TtlCachePolicy,
    };
    use super::*;

    fn lru(budget: u64) -> PrefixCache {
        PrefixCache::new(Box::new(LruCachePolicy), budget, 60.0)
    }

    #[test]
    fn none_policy_is_inert() {
        let mut c = PrefixCache::new(Box::new(NoneCachePolicy), 1_000_000, 60.0);
        assert!(!c.enabled());
        assert!(!c.insert(1, 0, 100, 0.0, None, u64::MAX));
        assert!(c.take(1, 1.0).is_none());
        assert_eq!(c.total_cached(), 0);
        let r = c.report();
        assert!(!r.enabled);
        assert_eq!(r, CacheReport::default());
    }

    #[test]
    fn insert_take_roundtrip_tracks_per_instance_totals() {
        let mut c = lru(10_000);
        assert!(c.insert(7, 2, 300, 1.0, None, u64::MAX));
        assert_eq!(c.cached_on(2), 300);
        assert_eq!(c.total_cached(), 300);
        let e = c.take(7, 2.0).expect("entry present");
        assert_eq!((e.instance, e.tokens), (2, 300));
        assert_eq!(c.total_cached(), 0);
        assert!(c.take(7, 2.0).is_none(), "take removes");
    }

    #[test]
    fn budget_pressure_evicts_oldest_first() {
        let mut c = lru(500);
        assert!(c.insert(1, 0, 200, 1.0, None, u64::MAX));
        assert!(c.insert(2, 0, 200, 2.0, None, u64::MAX));
        // 200 + 200 + 200 > 500: session 1 (oldest) must go
        assert!(c.insert(3, 0, 200, 3.0, None, u64::MAX));
        assert!(c.take(1, 4.0).is_none(), "oldest evicted");
        assert!(c.peek(2).is_some());
        assert!(c.peek(3).is_some());
        assert_eq!(c.report().evictions, 1);
        // budgets are per instance: another instance is unaffected
        assert!(c.insert(4, 1, 400, 4.0, None, u64::MAX));
        assert_eq!(c.cached_on(1), 400);
    }

    #[test]
    fn hard_cap_blocks_and_oversized_prefixes_are_refused() {
        let mut c = lru(10_000);
        assert!(!c.insert(1, 0, 600, 1.0, None, 500), "over physical headroom");
        assert!(!c.insert(2, 0, 20_000, 1.0, None, u64::MAX), "over budget");
        assert_eq!(c.report().insertions, 0);
    }

    #[test]
    fn ttl_expires_on_take_and_sweep() {
        let mut c = PrefixCache::new(Box::new(TtlCachePolicy), 10_000, 10.0);
        assert!(c.insert(1, 0, 100, 0.0, None, u64::MAX));
        assert!(c.insert(2, 0, 100, 5.0, None, u64::MAX));
        assert!(c.take(1, 11.0).is_none(), "expired on take");
        assert_eq!(c.report().expired, 1);
        c.expire(16.0);
        assert!(c.peek(2).is_none(), "swept");
        assert_eq!(c.report().expired, 2);
        assert_eq!(c.total_cached(), 0);
    }

    #[test]
    fn predictive_keeps_soon_returning_sessions_under_pressure() {
        let mut c = PrefixCache::new(Box::new(PredictiveCachePolicy::new(0.9)), 500, 60.0);
        assert!(c.insert(1, 0, 300, 0.0, Some(Prediction::exact(40.0)), u64::MAX));
        // session 2 returns sooner; pressure must evict session 1 (latest
        // forecast return), not the newcomer
        assert!(c.insert(2, 0, 300, 1.0, Some(Prediction::exact(3.0)), u64::MAX));
        assert!(c.peek(2).is_some());
        assert!(c.peek(1).is_none());
        // sessions that will not return inside the TTL are never stored
        assert!(!c.insert(3, 1, 100, 2.0, Some(Prediction::exact(500.0)), u64::MAX));
        assert!(!c.insert(4, 1, 100, 2.0, None, u64::MAX));
    }

    #[test]
    fn evict_instance_flushes_only_that_instance() {
        let mut c = lru(10_000);
        c.insert(1, 0, 100, 1.0, None, u64::MAX);
        c.insert(2, 0, 200, 2.0, None, u64::MAX);
        c.insert(3, 1, 400, 3.0, None, u64::MAX);
        assert_eq!(c.evict_instance(0), 300);
        assert_eq!(c.cached_on(0), 0);
        assert_eq!(c.cached_on(1), 400);
        assert_eq!(c.report().evictions, 2);
    }

    #[test]
    fn evict_for_headroom_frees_at_least_the_need() {
        let mut c = lru(10_000);
        c.insert(1, 0, 100, 1.0, None, u64::MAX);
        c.insert(2, 0, 200, 2.0, None, u64::MAX);
        let freed = c.evict_for_headroom(0, 80, 3.0);
        assert!(freed >= 80, "freed {freed}");
        assert_eq!(c.n_entries(), 1, "must not flush more than needed");
        assert!(c.peek(2).is_some(), "newest entry survives");
    }
}
