//! String-keyed cache-policy construction, mirroring
//! `coordinator::PolicyRegistry` / `predictor::PredictorRegistry`: the
//! single place where cache-policy names meet types. Config files
//! (`[kvcache] policy = "..."`), the CLI (`--cache`), benches, and tests
//! all go through [`CachePolicyRegistry::build`]; `star list` prints
//! [`CachePolicyRegistry::names`].

use std::collections::BTreeMap;

use super::policy::{
    CachePolicy, LruCachePolicy, NoneCachePolicy, PredictiveCachePolicy, TtlCachePolicy,
};
use crate::{Error, Result};

/// Everything a cache-policy builder may draw on. One context type keeps
/// the registry signature stable as policies grow knobs.
#[derive(Clone, Copy, Debug)]
pub struct CacheContext {
    /// Estimate quantile for the predictive policy's return-delay
    /// forecasts (shared convention with `[predictor] conservative_q`).
    pub conservative_q: f64,
}

impl Default for CacheContext {
    fn default() -> Self {
        CacheContext { conservative_q: 0.9 }
    }
}

type CacheBuilder = Box<dyn Fn(&CacheContext) -> Result<Box<dyn CachePolicy>> + Send + Sync>;

/// Registry of named cache-policy builders. Names are normalized
/// (lowercase, `-` → `_`) and may be aliased (`off` → `none`).
#[derive(Default)]
pub struct CachePolicyRegistry {
    builders: BTreeMap<String, CacheBuilder>,
    aliases: BTreeMap<String, String>,
}

/// Name normalization shared with lookups (lowercase, `-` → `_`).
fn normalize(name: &str) -> String {
    name.to_ascii_lowercase().replace('-', "_")
}

impl CachePolicyRegistry {
    /// An empty registry (for fully custom policy sets).
    pub fn new() -> CachePolicyRegistry {
        CachePolicyRegistry::default()
    }

    /// The built-in set: `none` (`off`), `lru`, `ttl`, `predictive`.
    pub fn with_builtins() -> CachePolicyRegistry {
        let mut r = CachePolicyRegistry::new();
        r.register("none", |_| Ok(Box::new(NoneCachePolicy)));
        r.register("lru", |_| Ok(Box::new(LruCachePolicy)));
        r.register("ttl", |_| Ok(Box::new(TtlCachePolicy)));
        r.register("predictive", |ctx| {
            Ok(Box::new(PredictiveCachePolicy::new(ctx.conservative_q)))
        });
        r.alias("off", "none");
        r
    }

    /// Register (or replace) a policy builder under `name`.
    pub fn register<F>(&mut self, name: &str, builder: F)
    where
        F: Fn(&CacheContext) -> Result<Box<dyn CachePolicy>> + Send + Sync + 'static,
    {
        self.builders.insert(normalize(name), Box::new(builder));
    }

    /// Make `alias` resolve to `canonical`. A direct registration under an
    /// alias-colliding name wins over the alias (same rule as the policy
    /// registry).
    pub fn alias(&mut self, alias: &str, canonical: &str) {
        self.aliases.insert(normalize(alias), normalize(canonical));
    }

    fn lookup(&self, name: &str) -> Option<&CacheBuilder> {
        let n = normalize(name);
        if let Some(b) = self.builders.get(&n) {
            return Some(b);
        }
        self.aliases.get(&n).and_then(|canon| self.builders.get(canon))
    }

    pub fn has(&self, name: &str) -> bool {
        self.lookup(name).is_some()
    }

    /// Construct the named policy; unknown names error with the
    /// registered canonical list.
    pub fn build(&self, name: &str, ctx: &CacheContext) -> Result<Box<dyn CachePolicy>> {
        match self.lookup(name) {
            Some(b) => b(ctx),
            None => Err(Error::config(format!(
                "unknown cache policy `{name}` (known: {})",
                self.names().join("|")
            ))),
        }
    }

    /// Registered canonical policy names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.builders.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_every_builtin_by_canonical_name_and_alias() {
        let reg = CachePolicyRegistry::with_builtins();
        for name in ["none", "lru", "ttl", "predictive", "off", "LRU", "Predictive"] {
            let p = reg
                .build(name, &CacheContext::default())
                .unwrap_or_else(|e| panic!("builtin `{name}` must build: {e}"));
            assert!(p.name().is_ascii());
        }
    }

    #[test]
    fn display_names_are_registry_keys() {
        let reg = CachePolicyRegistry::with_builtins();
        for name in reg.names() {
            let p = reg.build(&name, &CacheContext::default()).unwrap();
            assert_eq!(p.name(), name, "display name must be the registry key");
        }
    }

    #[test]
    fn every_builtin_is_registered() {
        // new builtins cannot silently miss registration: this list is
        // asserted verbatim (and `star list` prints the same registry,
        // covered in tests/cli_errors.rs)
        let reg = CachePolicyRegistry::with_builtins();
        assert_eq!(reg.names(), vec!["lru", "none", "predictive", "ttl"]);
    }

    #[test]
    fn unknown_names_error_with_known_list() {
        let reg = CachePolicyRegistry::with_builtins();
        let e = reg
            .build("magic", &CacheContext::default())
            .unwrap_err()
            .to_string();
        assert!(e.contains("unknown cache policy `magic`"), "{e}");
        assert!(e.contains("lru"), "{e}");
        assert!(e.contains("predictive"), "{e}");
        assert!(!reg.has("magic"));
        assert!(reg.has("off"));
    }

    #[test]
    fn third_party_registration_and_override() {
        let mut reg = CachePolicyRegistry::with_builtins();
        reg.register("aggressive_lru", |_| Ok(Box::new(LruCachePolicy)));
        assert!(reg.has("aggressive-LRU"));
        // direct registration under an alias-colliding name shadows it
        reg.register("off", |_| Ok(Box::new(LruCachePolicy)));
        let p = reg.build("off", &CacheContext::default()).unwrap();
        assert_eq!(p.name(), "lru");
    }
}
