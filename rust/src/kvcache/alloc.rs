//! Paged KV-cache manager (PagedAttention-style block allocator).
//!
//! Each decode instance owns one [`KvCacheManager`]: requests allocate
//! fixed-size token blocks as they generate; when an append cannot be
//! served the instance experiences the paper's **Issue 1** OOM — victims
//! must be evicted and their KV recomputed elsewhere. The manager also
//! answers the rescheduler's memory-safety query (Alg. 1 line 21:
//! `N_t(B_t,0) + N̂(r) <= C_mem`).

use std::collections::BTreeMap;

use crate::{Error, RequestId, Result};

/// Tokens per block (vLLM default is 16).
pub const DEFAULT_BLOCK_TOKENS: u32 = 16;

/// Paged allocator for one instance's KV memory.
#[derive(Clone, Debug)]
pub struct KvCacheManager {
    block_tokens: u32,
    capacity_blocks: usize,
    free_blocks: usize,
    /// request -> (blocks held, tokens stored)
    allocs: BTreeMap<RequestId, KvAlloc>,
    /// Running Σ tokens over `allocs` so [`Self::used_tokens`] is O(1)
    /// (it sits on the admission hot path).
    used_tokens: u64,
    /// high-water mark for reporting
    peak_used_blocks: usize,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct KvAlloc {
    pub blocks: usize,
    pub tokens: u64,
}

impl KvCacheManager {
    pub fn new(capacity_tokens: u64, block_tokens: u32) -> Self {
        let capacity_blocks = (capacity_tokens / block_tokens as u64) as usize;
        KvCacheManager {
            block_tokens,
            capacity_blocks,
            free_blocks: capacity_blocks,
            allocs: BTreeMap::new(),
            used_tokens: 0,
            peak_used_blocks: 0,
        }
    }

    fn blocks_for(&self, tokens: u64) -> usize {
        tokens.div_ceil(self.block_tokens as u64) as usize
    }

    /// Admit a request with `tokens` already materialized (prefill KV or a
    /// migrated-in cache). Fails with [`Error::KvOom`] if it does not fit.
    pub fn admit(&mut self, id: RequestId, tokens: u64, instance: usize) -> Result<()> {
        assert!(
            !self.allocs.contains_key(&id),
            "request {id} admitted twice"
        );
        let need = self.blocks_for(tokens);
        if need > self.free_blocks {
            return Err(Error::KvOom {
                instance,
                need,
                free: self.free_blocks,
            });
        }
        self.free_blocks -= need;
        self.allocs.insert(
            id,
            KvAlloc {
                blocks: need,
                tokens,
            },
        );
        self.used_tokens += tokens;
        self.note_peak();
        Ok(())
    }

    /// Append one generated token; may allocate a new block.
    pub fn append_token(&mut self, id: RequestId, instance: usize) -> Result<()> {
        let alloc = self
            .allocs
            .get_mut(&id)
            .unwrap_or_else(|| panic!("append for unknown request {id}"));
        alloc.tokens += 1;
        let need = alloc.tokens.div_ceil(self.block_tokens as u64) as usize;
        if need > alloc.blocks {
            if self.free_blocks == 0 {
                // roll back the token count: the caller handles the OOM
                alloc.tokens -= 1;
                return Err(Error::KvOom {
                    instance,
                    need: 1,
                    free: 0,
                });
            }
            self.free_blocks -= 1;
            alloc.blocks += 1;
            self.note_peak();
        }
        self.used_tokens += 1;
        Ok(())
    }

    /// Release a request's blocks (completion, migration-out, or eviction).
    pub fn release(&mut self, id: RequestId) -> Option<KvAlloc> {
        let alloc = self.allocs.remove(&id)?;
        self.free_blocks += alloc.blocks;
        self.used_tokens -= alloc.tokens;
        Some(alloc)
    }

    /// Would a request with `tokens` KV fit right now?
    pub fn would_fit(&self, tokens: u64) -> bool {
        self.blocks_for(tokens) <= self.free_blocks
    }

    /// Memory-safety headroom in tokens (free blocks * block size).
    pub fn free_tokens(&self) -> u64 {
        self.free_blocks as u64 * self.block_tokens as u64
    }

    pub fn capacity_tokens(&self) -> u64 {
        self.capacity_blocks as u64 * self.block_tokens as u64
    }

    /// Total tokens stored across requests. O(1).
    pub fn used_tokens(&self) -> u64 {
        self.used_tokens
    }

    /// Fraction of block capacity in use (Fig. 12's y-axis).
    pub fn usage_frac(&self) -> f64 {
        if self.capacity_blocks == 0 {
            return 0.0;
        }
        (self.capacity_blocks - self.free_blocks) as f64 / self.capacity_blocks as f64
    }

    pub fn peak_usage_frac(&self) -> f64 {
        if self.capacity_blocks == 0 {
            return 0.0;
        }
        self.peak_used_blocks as f64 / self.capacity_blocks as f64
    }

    pub fn n_requests(&self) -> usize {
        self.allocs.len()
    }

    pub fn tokens_of(&self, id: RequestId) -> Option<u64> {
        self.allocs.get(&id).map(|a| a.tokens)
    }

    /// Pick eviction victims to free at least `need_blocks` blocks.
    /// Policy: evict the *smallest* requests first — recompute-on-OOM must
    /// replay the victim's whole history, so the cheapest victims minimize
    /// wasted work (mirrors vLLM preempting the least-progress sequences;
    /// evicting the largest request to free one block thrashes: it regrows
    /// and evicts others in turn).
    pub fn eviction_victims(&self, need_blocks: usize) -> Vec<RequestId> {
        let mut by_size: Vec<(&RequestId, &KvAlloc)> = self.allocs.iter().collect();
        by_size.sort_by(|a, b| a.1.blocks.cmp(&b.1.blocks).then(a.0.cmp(b.0)));
        let mut freed = 0;
        let mut victims = Vec::new();
        for (id, alloc) in by_size {
            if freed >= need_blocks {
                break;
            }
            victims.push(*id);
            freed += alloc.blocks;
        }
        victims
    }

    fn note_peak(&mut self) {
        let used = self.capacity_blocks - self.free_blocks;
        if used > self.peak_used_blocks {
            self.peak_used_blocks = used;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(cap_tokens: u64) -> KvCacheManager {
        KvCacheManager::new(cap_tokens, 16)
    }

    #[test]
    fn admit_and_release_roundtrip() {
        let mut m = mgr(1600); // 100 blocks
        m.admit(1, 100, 0).unwrap(); // 7 blocks
        assert_eq!(m.used_tokens(), 100);
        assert_eq!(m.n_requests(), 1);
        let a = m.release(1).unwrap();
        assert_eq!(a.tokens, 100);
        assert_eq!(m.free_tokens(), 1600);
    }

    #[test]
    fn append_allocates_blocks_lazily() {
        let mut m = mgr(160); // 10 blocks
        m.admit(1, 16, 0).unwrap(); // exactly 1 block
        assert_eq!(m.free_tokens(), 144);
        m.append_token(1, 0).unwrap(); // 17 tokens -> 2 blocks
        assert_eq!(m.free_tokens(), 128);
        for _ in 0..15 {
            m.append_token(1, 0).unwrap(); // fills block 2, no new alloc
        }
        assert_eq!(m.free_tokens(), 128);
    }

    #[test]
    fn oom_on_admit_when_full() {
        let mut m = mgr(160);
        m.admit(1, 150, 3).unwrap();
        let err = m.admit(2, 32, 3).unwrap_err();
        match err {
            Error::KvOom { instance, .. } => assert_eq!(instance, 3),
            e => panic!("unexpected {e}"),
        }
    }

    #[test]
    fn oom_on_append_rolls_back() {
        let mut m = mgr(32); // 2 blocks
        m.admit(1, 32, 0).unwrap();
        let before = m.tokens_of(1).unwrap();
        assert!(m.append_token(1, 0).is_err());
        assert_eq!(m.tokens_of(1).unwrap(), before, "rollback failed");
    }

    #[test]
    fn would_fit_matches_admit() {
        let mut m = mgr(160);
        assert!(m.would_fit(160));
        assert!(!m.would_fit(161));
        m.admit(1, 80, 0).unwrap();
        assert!(m.would_fit(80));
        assert!(!m.would_fit(81)); // 80 used = 5 blocks, 5 free
    }

    #[test]
    fn eviction_prefers_cheapest() {
        let mut m = mgr(1600);
        m.admit(1, 500, 0).unwrap();
        m.admit(2, 100, 0).unwrap();
        m.admit(3, 300, 0).unwrap();
        // smallest first: minimal recompute work lost per freed block
        let v = m.eviction_victims(1);
        assert_eq!(v[0], 2, "cheapest request should be first victim");
        // needing more blocks walks up the size order (7 + 19 blocks)
        let v = m.eviction_victims(25);
        assert_eq!(v, vec![2, 3]);
    }

    #[test]
    fn usage_frac_and_peak() {
        let mut m = mgr(160);
        assert_eq!(m.usage_frac(), 0.0);
        m.admit(1, 80, 0).unwrap();
        assert!((m.usage_frac() - 0.5).abs() < 1e-12);
        m.release(1);
        assert_eq!(m.usage_frac(), 0.0);
        assert!((m.peak_usage_frac() - 0.5).abs() < 1e-12);
    }
}
