//! Retention policies for the prefix cache: who gets cached at turn
//! completion, and who gets evicted first under budget pressure.
//!
//! Policies are deliberately small and pure — the [`PrefixCache`] layer
//! owns the entry map, budgets, and counters; a policy only answers
//! "keep this?" and "evict whom first?". The `predictive` policy is where
//! the PR 5 prediction signal meets the PR 3 session scripts: a session's
//! return delay (its next turn's think time) is carried as a
//! [`Prediction`], and admission reads it at a conservative quantile, so
//! an uncertain think-time estimate must promise a *soon* return before
//! its prefix may occupy budget.
//!
//! [`PrefixCache`]: super::PrefixCache

use crate::predictor::Prediction;
use crate::{InstanceId, Time};

/// One retained prefix: the completed turns of a session, resident on one
/// instance, reusable iff the session's next turn lands there (or the
/// transfer-vs-recompute comparison moves it).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CachedPrefix {
    /// Session this prefix belongs to (one live prefix per session).
    pub session: u32,
    /// Decode instance holding the KV blocks.
    pub instance: InstanceId,
    /// Prefix length in tokens (prior prompt + generated history).
    pub tokens: u64,
    /// When the prefix was retained (TTL / LRU clock).
    pub stored_at: Time,
    /// Forecast of the session's return delay in seconds after
    /// `stored_at` (the next turn's think time), `None` when the session
    /// has no known successor turn. Carried as a [`Prediction`] so an
    /// uncertain estimate is scored at a conservative quantile.
    pub return_delay: Option<Prediction>,
}

impl CachedPrefix {
    /// Conservative (quantile-`q`) estimate of when the session returns.
    pub fn expected_return_at(&self, q: f64) -> Option<Time> {
        self.return_delay
            .map(|p| self.stored_at + p.quantile(q).max(0.0))
    }
}

/// Retention strategy. Object-safe; registered by string in the
/// [`CachePolicyRegistry`](super::CachePolicyRegistry).
pub trait CachePolicy: Send {
    /// Registry name this policy answers to (diagnostics + reports).
    fn name(&self) -> &str;

    /// `false` turns the whole subsystem off (`none`): no lookups, no
    /// insertions, no events — the inert baseline the determinism tests
    /// compare against.
    fn enabled(&self) -> bool {
        true
    }

    /// Do TTL sweeps expire this policy's entries?
    fn uses_ttl(&self) -> bool;

    /// Retain `entry` at turn completion? (`ttl_s` is the configured
    /// lifetime, so predictive admission can refuse sessions that will
    /// not return inside it.)
    fn admits(&self, entry: &CachedPrefix, ttl_s: f64) -> bool;

    /// Eviction priority under budget pressure: HIGHER evicts first.
    /// Only ordering within one policy matters; ties are broken by the
    /// cache layer on session id for determinism.
    fn victim_priority(&self, entry: &CachedPrefix, now: Time) -> f64;
}

/// The off switch: nothing is ever cached.
#[derive(Clone, Debug, Default)]
pub struct NoneCachePolicy;

impl CachePolicy for NoneCachePolicy {
    fn name(&self) -> &str {
        "none"
    }

    fn enabled(&self) -> bool {
        false
    }

    fn uses_ttl(&self) -> bool {
        false
    }

    fn admits(&self, _entry: &CachedPrefix, _ttl_s: f64) -> bool {
        false
    }

    fn victim_priority(&self, _entry: &CachedPrefix, _now: Time) -> f64 {
        0.0
    }
}

/// Least-recently-stored eviction, no expiry: prefixes live until budget
/// pressure pushes the oldest out.
#[derive(Clone, Debug, Default)]
pub struct LruCachePolicy;

impl CachePolicy for LruCachePolicy {
    fn name(&self) -> &str {
        "lru"
    }

    fn uses_ttl(&self) -> bool {
        false
    }

    fn admits(&self, _entry: &CachedPrefix, _ttl_s: f64) -> bool {
        true
    }

    fn victim_priority(&self, entry: &CachedPrefix, now: Time) -> f64 {
        now - entry.stored_at
    }
}

/// LRU ordering plus a hard lifetime: entries older than `kvcache.ttl_s`
/// are swept even with budget to spare (idle KV is not free — it competes
/// with admissions through the cluster-state aggregate).
#[derive(Clone, Debug, Default)]
pub struct TtlCachePolicy;

impl CachePolicy for TtlCachePolicy {
    fn name(&self) -> &str {
        "ttl"
    }

    fn uses_ttl(&self) -> bool {
        true
    }

    fn admits(&self, _entry: &CachedPrefix, _ttl_s: f64) -> bool {
        true
    }

    fn victim_priority(&self, entry: &CachedPrefix, now: Time) -> f64 {
        now - entry.stored_at
    }
}

/// Prediction-driven retention: only sessions forecast to return within
/// the TTL are cached, and under pressure the entry whose return is
/// farthest away is evicted first — the budget chases the sessions most
/// likely to convert cached bytes into a hit.
#[derive(Clone, Debug)]
pub struct PredictiveCachePolicy {
    /// Estimate quantile for return-delay forecasts (conservative: an
    /// uncertain delay reads as long, same convention as the elastic
    /// scaler's demand signal).
    q: f64,
}

impl PredictiveCachePolicy {
    pub fn new(conservative_q: f64) -> Self {
        PredictiveCachePolicy {
            q: conservative_q.clamp(0.5, 1.0),
        }
    }
}

impl CachePolicy for PredictiveCachePolicy {
    fn name(&self) -> &str {
        "predictive"
    }

    fn uses_ttl(&self) -> bool {
        true
    }

    fn admits(&self, entry: &CachedPrefix, ttl_s: f64) -> bool {
        match entry.return_delay {
            Some(p) => p.quantile(self.q).max(0.0) <= ttl_s,
            // no known successor turn: the prefix cannot be reused
            None => false,
        }
    }

    fn victim_priority(&self, entry: &CachedPrefix, now: Time) -> f64 {
        // farthest forecast return evicts first; unknown returns (which
        // admission normally refuses) evict before any forecast one
        entry.expected_return_at(self.q).unwrap_or(f64::MAX) - now
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(session: u32, stored_at: Time, delay: Option<f64>) -> CachedPrefix {
        CachedPrefix {
            session,
            instance: 0,
            tokens: 100,
            stored_at,
            return_delay: delay.map(Prediction::exact),
        }
    }

    #[test]
    fn none_is_fully_inert() {
        let p = NoneCachePolicy;
        assert!(!p.enabled());
        assert!(!p.admits(&entry(1, 0.0, Some(1.0)), 60.0));
    }

    #[test]
    fn lru_and_ttl_prioritize_oldest() {
        for p in [&LruCachePolicy as &dyn CachePolicy, &TtlCachePolicy] {
            let old = entry(1, 10.0, None);
            let new = entry(2, 50.0, None);
            assert!(p.admits(&old, 60.0));
            assert!(
                p.victim_priority(&old, 100.0) > p.victim_priority(&new, 100.0),
                "{}: oldest must evict first",
                p.name()
            );
        }
        assert!(!LruCachePolicy.uses_ttl());
        assert!(TtlCachePolicy.uses_ttl());
    }

    #[test]
    fn predictive_admits_only_soon_returning_sessions() {
        let p = PredictiveCachePolicy::new(0.9);
        assert!(p.admits(&entry(1, 0.0, Some(5.0)), 60.0));
        assert!(!p.admits(&entry(2, 0.0, Some(120.0)), 60.0), "returns after TTL");
        assert!(!p.admits(&entry(3, 0.0, None), 60.0), "no successor turn");
        // uncertainty pushes the conservative quantile past the TTL
        let uncertain = CachedPrefix {
            return_delay: Some(Prediction::new(50.0, 30.0, 0)),
            ..entry(4, 0.0, None)
        };
        assert!(!p.admits(&uncertain, 60.0), "p90 of N(50, 30) > 60");
    }

    #[test]
    fn predictive_evicts_farthest_return_first() {
        let p = PredictiveCachePolicy::new(0.9);
        let soon = entry(1, 0.0, Some(5.0));
        let late = entry(2, 0.0, Some(50.0));
        assert!(p.victim_priority(&late, 1.0) > p.victim_priority(&soon, 1.0));
        assert!(p.victim_priority(&entry(3, 0.0, None), 1.0) > p.victim_priority(&late, 1.0));
    }
}
