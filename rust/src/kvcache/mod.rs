//! KV-cache subsystem: the per-instance paged allocator plus the
//! cross-turn prefix cache.
//!
//! Layer diagram (DESIGN.md §13):
//!
//! ```text
//!   drivers (sim / serve)
//!        │  insert at turn completion · take on follow-up · flush on drain
//!        ▼
//!   PrefixCache  ──policy──▶  CachePolicy (none | lru | ttl | predictive)
//!        │  cached-token totals mirrored into ClusterState::cached_tokens
//!        ▼
//!   KvCacheManager (paged allocator, one per decode instance)
//! ```
//!
//! * [`KvCacheManager`] ([`alloc`]) — PagedAttention-style block
//!   allocator for *active* requests; OOM on exhaustion is the paper's
//!   Issue-1 cascade.
//! * [`PrefixCache`] ([`prefix`]) — retains completed-turn KV per
//!   session under a configurable budget so a session's next turn
//!   prefills only its new suffix (collapsed TTFT for later turns of
//!   multi-round workloads).
//! * [`CachePolicy`] ([`policy`]) — retention strategy; `predictive`
//!   scores sessions by forecast return delay (PR 3 session scripts ×
//!   PR 5 prediction signal).
//! * [`CachePolicyRegistry`] ([`registry`]) — string-keyed construction
//!   (`[kvcache] policy` / `--cache`), printed by `star list`.
//! * [`CacheReport`] ([`report`]) — hit/miss/eviction/reuse counters both
//!   drivers surface.

pub mod alloc;
pub mod policy;
pub mod prefix;
pub mod registry;
pub mod report;

pub use alloc::{KvAlloc, KvCacheManager, DEFAULT_BLOCK_TOKENS};
pub use policy::{
    CachePolicy, CachedPrefix, LruCachePolicy, NoneCachePolicy, PredictiveCachePolicy,
    TtlCachePolicy,
};
pub use prefix::PrefixCache;
pub use registry::{CacheContext, CachePolicyRegistry};
pub use report::CacheReport;
