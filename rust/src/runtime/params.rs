//! Loader for the AOT parameter dump (`artifacts/params/manifest.txt` +
//! raw little-endian f32 `.bin` files written by `aot.dump_params`).

use std::path::Path;

use super::tensor::HostTensor;
use crate::{Error, Result};

/// All model + predictor parameters in manifest order, as literals ready
/// to prepend to executable arguments.
pub struct ParamSet {
    /// (name, tensor) in manifest order.
    pub entries: Vec<(String, HostTensor)>,
}

impl ParamSet {
    /// Names with the given prefix ("lm." or "pred."), manifest order.
    pub fn with_prefix(&self, prefix: &str) -> Vec<&HostTensor> {
        self.entries
            .iter()
            .filter(|(n, _)| n.starts_with(prefix))
            .map(|(_, t)| t)
            .collect()
    }

    pub fn literals_with_prefix(&self, prefix: &str) -> Result<Vec<xla::Literal>> {
        self.with_prefix(prefix)
            .into_iter()
            .map(|t| t.to_literal())
            .collect()
    }

    pub fn total_elems(&self) -> usize {
        self.entries.iter().map(|(_, t)| t.len()).sum()
    }
}

/// Read manifest + bins from `dir/params/`.
pub fn load_params(dir: &Path) -> Result<ParamSet> {
    let pdir = dir.join("params");
    let manifest = pdir.join("manifest.txt");
    let text = std::fs::read_to_string(&manifest)
        .map_err(|e| Error::artifact(format!("{}: {e}", manifest.display())))?;
    let mut entries = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split('\t');
        let (name, dtype, shape_s) = (
            parts
                .next()
                .ok_or_else(|| Error::artifact("manifest: missing name"))?,
            parts
                .next()
                .ok_or_else(|| Error::artifact("manifest: missing dtype"))?,
            parts
                .next()
                .ok_or_else(|| Error::artifact("manifest: missing shape"))?,
        );
        if dtype != "f32" {
            return Err(Error::artifact(format!(
                "param {name}: unsupported dtype {dtype}"
            )));
        }
        let shape: Vec<i64> = shape_s
            .split('x')
            .map(|d| {
                d.parse()
                    .map_err(|_| Error::artifact(format!("param {name}: bad shape {shape_s}")))
            })
            .collect::<Result<_>>()?;
        let bytes = std::fs::read(pdir.join(format!("{name}.bin")))
            .map_err(|e| Error::artifact(format!("param {name}: {e}")))?;
        if bytes.len() % 4 != 0 {
            return Err(Error::artifact(format!(
                "param {name}: byte length {} not f32-aligned",
                bytes.len()
            )));
        }
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        entries.push((name.to_string(), HostTensor::f32(&shape, data)?));
    }
    if entries.is_empty() {
        return Err(Error::artifact("manifest.txt is empty"));
    }
    Ok(ParamSet { entries })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_artifacts_if_present() {
        // integration-ish: only runs when `make artifacts` has been run
        let Ok(dir) = crate::runtime::artifacts_dir(None) else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let ps = load_params(&dir).unwrap();
        assert!(ps.total_elems() > 100_000, "suspiciously few params");
        let lm = ps.with_prefix("lm.");
        let pred = ps.with_prefix("pred.");
        assert_eq!(lm.len(), 12, "lm param count (see model.PARAM_NAMES)");
        assert_eq!(pred.len(), 8, "predictor param count");
        // embedding is [256, 128]
        assert_eq!(ps.entries[0].1.shape(), &[256, 128]);
    }
}
