//! Parser for `artifacts/model_meta.txt` — the dimensions the AOT
//! artifacts were baked with (written by `python/compile/aot.py`).

use std::collections::HashMap;
use std::path::Path;

use crate::{Error, Result};

/// star-pico model dimensions (must match python/compile/configs.py).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub ffn_dim: usize,
    pub max_prompt: usize,
    pub max_seq: usize,
    pub max_output: usize,
    pub decode_buckets: Vec<usize>,
    pub predictor_buckets: Vec<usize>,
    pub kv_bytes_per_token: u64,
    pub eos: u8,
    pub bos: u8,
    pub predictor_d_in: usize,
}

impl ModelMeta {
    pub fn load(dir: &Path) -> Result<ModelMeta> {
        let path = dir.join("model_meta.txt");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::artifact(format!("{}: {e}", path.display())))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<ModelMeta> {
        let mut kv = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| Error::artifact(format!("bad meta line `{line}`")))?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let get = |k: &str| -> Result<&String> {
            kv.get(k)
                .ok_or_else(|| Error::artifact(format!("model_meta missing `{k}`")))
        };
        let num = |k: &str| -> Result<usize> {
            get(k)?
                .parse()
                .map_err(|_| Error::artifact(format!("model_meta `{k}` not a number")))
        };
        let list = |k: &str| -> Result<Vec<usize>> {
            get(k)?
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| Error::artifact(format!("bad list in `{k}`")))
                })
                .collect()
        };
        Ok(ModelMeta {
            vocab: num("vocab")?,
            d_model: num("d_model")?,
            n_layers: num("n_layers")?,
            n_heads: num("n_heads")?,
            head_dim: num("head_dim")?,
            ffn_dim: num("ffn_dim")?,
            max_prompt: num("max_prompt")?,
            max_seq: num("max_seq")?,
            max_output: num("max_output")?,
            decode_buckets: list("decode_buckets")?,
            predictor_buckets: list("predictor_buckets")?,
            kv_bytes_per_token: num("kv_bytes_per_token")? as u64,
            eos: num("eos")? as u8,
            bos: num("bos")? as u8,
            predictor_d_in: num("predictor_d_in")?,
        })
    }

    /// Elements in one request's KV cache slice [L, 2, H, Smax, Dh].
    pub fn kv_elems_per_slot(&self) -> usize {
        self.n_layers * 2 * self.n_heads * self.max_seq * self.head_dim
    }

    /// Elements in a batched KV buffer [L, 2, B, H, Smax, Dh].
    pub fn kv_elems(&self, bucket: usize) -> usize {
        self.kv_elems_per_slot() * bucket
    }

    /// Smallest decode bucket that fits `n` sequences.
    pub fn bucket_for(&self, n: usize) -> Option<usize> {
        self.decode_buckets.iter().copied().find(|&b| b >= n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "vocab=256\nd_model=128\nn_layers=4\nn_heads=4\n\
        head_dim=32\nffn_dim=512\nmax_prompt=128\nmax_seq=640\nmax_output=512\n\
        decode_buckets=1,2,4,8\npredictor_buckets=1,2,4,8,16\n\
        kv_bytes_per_token=4096\neos=0\nbos=1\npredictor_d_in=128\n";

    #[test]
    fn parses_sample() {
        let m = ModelMeta::parse(SAMPLE).unwrap();
        assert_eq!(m.d_model, 128);
        assert_eq!(m.decode_buckets, vec![1, 2, 4, 8]);
        assert_eq!(m.kv_elems_per_slot(), 4 * 2 * 4 * 640 * 32);
        assert_eq!(m.bucket_for(3), Some(4));
        assert_eq!(m.bucket_for(9), None);
    }

    #[test]
    fn missing_key_is_error() {
        assert!(ModelMeta::parse("vocab=256\n").is_err());
    }
}
