//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `artifacts/params/*.bin`) and executes star-pico from the rust request
//! path. Python never runs here — this is the L3 side of the AOT bridge
//! (see `python/compile/aot.py` and /opt/xla-example/load_hlo for the
//! interchange-format rationale: HLO *text*, not serialized protos).

mod meta;
mod models;
mod params;
mod tensor;

pub use meta::ModelMeta;
pub use models::{DecodeOutput, PrefillOutput, StarRuntime};
pub use params::{load_params, ParamSet};
pub use tensor::HostTensor;

use std::path::{Path, PathBuf};

use crate::{Error, Result};

/// Locate the artifacts directory: explicit arg > $STAR_ARTIFACTS >
/// ./artifacts relative to the workspace root.
pub fn artifacts_dir(explicit: Option<&str>) -> Result<PathBuf> {
    if let Some(p) = explicit {
        let pb = PathBuf::from(p);
        if pb.join("model_meta.txt").exists() {
            return Ok(pb);
        }
        return Err(Error::artifact(format!(
            "{p} does not contain model_meta.txt (run `make artifacts`)"
        )));
    }
    if let Ok(env) = std::env::var("STAR_ARTIFACTS") {
        return artifacts_dir(Some(&env));
    }
    for candidate in ["artifacts", "../artifacts", "../../artifacts"] {
        let pb = PathBuf::from(candidate);
        if pb.join("model_meta.txt").exists() {
            return Ok(pb);
        }
    }
    Err(Error::artifact(
        "artifacts/ not found; run `make artifacts` or set STAR_ARTIFACTS",
    ))
}

/// Compile one HLO-text artifact on a PJRT client.
pub fn compile_hlo(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    if !path.exists() {
        return Err(Error::artifact(format!(
            "{} missing (run `make artifacts`)",
            path.display()
        )));
    }
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str()
            .ok_or_else(|| Error::artifact("non-utf8 artifact path"))?,
    )?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}
