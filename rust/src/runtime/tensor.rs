//! Minimal host tensor: shape + flat f32/i32 storage with Literal
//! round-trips. Keeps the engine code free of raw `xla::Literal` plumbing.

use crate::{Error, Result};

/// A host-side tensor of f32 or i32.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<i64>, data: Vec<f32> },
    I32 { shape: Vec<i64>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: &[i64], data: Vec<f32>) -> Result<HostTensor> {
        check_len(shape, data.len())?;
        Ok(HostTensor::F32 {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn i32(shape: &[i64], data: Vec<i32>) -> Result<HostTensor> {
        check_len(shape, data.len())?;
        Ok(HostTensor::I32 {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn zeros_f32(shape: &[i64]) -> HostTensor {
        let n: i64 = shape.iter().product();
        HostTensor::F32 {
            shape: shape.to_vec(),
            data: vec![0.0; n as usize],
        }
    }

    pub fn shape(&self) -> &[i64] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => Err(Error::artifact("expected f32 tensor")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => Err(Error::artifact("expected i32 tensor")),
        }
    }

    /// Convert to an XLA literal (copies).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            HostTensor::F32 { shape, data } => Ok(xla::Literal::vec1(data).reshape(shape)?),
            HostTensor::I32 { shape, data } => Ok(xla::Literal::vec1(data).reshape(shape)?),
        }
    }

    /// Read back an f32 literal.
    pub fn from_f32_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        Ok(HostTensor::F32 {
            shape: shape.dims().to_vec(),
            data: lit.to_vec::<f32>()?,
        })
    }
}

fn check_len(shape: &[i64], len: usize) -> Result<()> {
    let n: i64 = shape.iter().product();
    if n as usize != len {
        return Err(Error::artifact(format!(
            "shape {shape:?} wants {n} elements, got {len}"
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(HostTensor::f32(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::f32(&[2, 3], vec![0.0; 5]).is_err());
        let t = HostTensor::zeros_f32(&[4, 4]);
        assert_eq!(t.len(), 16);
    }

    #[test]
    fn type_accessors() {
        let f = HostTensor::f32(&[2], vec![1.0, 2.0]).unwrap();
        assert!(f.as_f32().is_ok());
        assert!(f.as_i32().is_err());
    }
}
