//! StarRuntime: the compiled star-pico executables + typed entrypoints.

use std::path::Path;

use super::meta::ModelMeta;
use super::params::{load_params, ParamSet};
use super::tensor::HostTensor;
use crate::{Error, Result};

/// Output of one prefill pass (one request).
#[derive(Clone, Debug)]
pub struct PrefillOutput {
    /// Next-token logits of the last prompt token, [vocab].
    pub logits: Vec<f32>,
    /// The request's padded KV slice [L, 2, 1, H, Smax, Dh].
    pub kv: HostTensor,
    /// Last-token last-layer hidden state [d_model] (predictor input).
    pub hidden: Vec<f32>,
}

/// Output of one batched decode step.
#[derive(Clone, Debug)]
pub struct DecodeOutput {
    /// [bucket * vocab] row-major logits.
    pub logits: Vec<f32>,
    /// Updated KV buffer [L, 2, B, H, Smax, Dh].
    pub kv: HostTensor,
    /// [bucket * d_model] hidden states (predictor inputs).
    pub hidden: Vec<f32>,
}

/// Compiled model bundle: PJRT client + executables for every entrypoint
/// the artifacts provide, plus the parameter literals (uploaded per call;
/// the perf-optimized path keeps them device-resident — see bench notes).
pub struct StarRuntime {
    pub meta: ModelMeta,
    client: xla::PjRtClient,
    prefill_exe: xla::PjRtLoadedExecutable,
    /// (bucket, executable), ascending bucket.
    decode_exes: Vec<(usize, xla::PjRtLoadedExecutable)>,
    predictor_exes: Vec<(usize, xla::PjRtLoadedExecutable)>,
    lm_params: Vec<xla::Literal>,
    pred_params: Vec<xla::Literal>,
    pub params: ParamSet,
}

// SAFETY: the PJRT C API is documented thread-safe for compilation and
// execution (the CPU client internally synchronizes); the Literal inputs
// are only read. The `xla` crate just doesn't annotate its wrappers.
unsafe impl Send for StarRuntime {}
unsafe impl Sync for StarRuntime {}

impl StarRuntime {
    /// Load every artifact and compile all entrypoints (one-time cost).
    pub fn load(dir: &Path) -> Result<StarRuntime> {
        let meta = ModelMeta::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let prefill_exe = super::compile_hlo(&client, &dir.join("prefill.hlo.txt"))?;
        let mut decode_exes = Vec::new();
        for &b in &meta.decode_buckets {
            decode_exes.push((
                b,
                super::compile_hlo(&client, &dir.join(format!("decode_b{b}.hlo.txt")))?,
            ));
        }
        let mut predictor_exes = Vec::new();
        for &b in &meta.predictor_buckets {
            predictor_exes.push((
                b,
                super::compile_hlo(&client, &dir.join(format!("predictor_b{b}.hlo.txt")))?,
            ));
        }
        let params = load_params(dir)?;
        let lm_params = params.literals_with_prefix("lm.")?;
        let pred_params = params.literals_with_prefix("pred.")?;
        Ok(StarRuntime {
            meta,
            client,
            prefill_exe,
            decode_exes,
            predictor_exes,
            lm_params,
            pred_params,
            params,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Run prefill over a prompt (token bytes). Pads to `max_prompt`.
    pub fn prefill(&self, prompt: &[u8]) -> Result<PrefillOutput> {
        let p = self.meta.max_prompt;
        if prompt.is_empty() || prompt.len() > p {
            return Err(Error::coordinator(format!(
                "prompt length {} out of range 1..={p}",
                prompt.len()
            )));
        }
        let mut toks = vec![0i32; p];
        for (i, &b) in prompt.iter().enumerate() {
            toks[i] = b as i32;
        }
        let tokens = HostTensor::i32(&[1, p as i64], toks)?.to_literal()?;
        let plen = HostTensor::i32(&[1], vec![prompt.len() as i32])?.to_literal()?;

        // params passed by reference: no 3.4 MB Literal clone per call
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(self.lm_params.len() + 2);
        args.extend(self.lm_params.iter());
        args.push(&tokens);
        args.push(&plen);
        let result = self.prefill_exe.execute::<&xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let (logits, kv, hidden) = result.to_tuple3()?;
        Ok(PrefillOutput {
            logits: logits.to_vec::<f32>()?,
            kv: HostTensor::from_f32_literal(&kv)?,
            hidden: hidden.to_vec::<f32>()?,
        })
    }

    /// One decode step at the given bucket size.
    ///
    /// `tokens[b]` = token to process for slot b (garbage for idle slots),
    /// `pos[b]` = its position (current length), `kv` = the batched cache.
    pub fn decode_step(
        &self,
        bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        kv: &HostTensor,
    ) -> Result<DecodeOutput> {
        let exe = self
            .decode_exes
            .iter()
            .find(|(b, _)| *b == bucket)
            .map(|(_, e)| e)
            .ok_or_else(|| Error::coordinator(format!("no decode bucket {bucket}")))?;
        if tokens.len() != bucket || pos.len() != bucket {
            return Err(Error::coordinator(format!(
                "decode bucket {bucket}: got {} tokens / {} pos",
                tokens.len(),
                pos.len()
            )));
        }
        let m = &self.meta;
        let expect = m.kv_elems(bucket);
        if kv.len() != expect {
            return Err(Error::coordinator(format!(
                "kv buffer has {} elems, bucket {bucket} needs {expect}",
                kv.len()
            )));
        }
        let t_lit = HostTensor::i32(&[bucket as i64], tokens.to_vec())?.to_literal()?;
        let p_lit = HostTensor::i32(&[bucket as i64], pos.to_vec())?.to_literal()?;
        let kv_lit = kv.to_literal()?;

        // STAR_PERF_CLONE_PARAMS=1 reinstates the pre-optimization
        // clone-per-call path so the §Perf before/after in EXPERIMENTS.md
        // stays reproducible.
        let result = if std::env::var_os("STAR_PERF_CLONE_PARAMS").is_some() {
            let mut owned: Vec<xla::Literal> = self.lm_params.to_vec();
            owned.push(t_lit);
            owned.push(p_lit);
            owned.push(kv_lit);
            exe.execute::<xla::Literal>(&owned)?[0][0].to_literal_sync()?
        } else {
            let mut args: Vec<&xla::Literal> =
                Vec::with_capacity(self.lm_params.len() + 3);
            args.extend(self.lm_params.iter());
            args.push(&t_lit);
            args.push(&p_lit);
            args.push(&kv_lit);
            exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?
        };
        let (logits, kv_out, hidden) = result.to_tuple3()?;
        Ok(DecodeOutput {
            logits: logits.to_vec::<f32>()?,
            kv: HostTensor::from_f32_literal(&kv_out)?,
            hidden: hidden.to_vec::<f32>()?,
        })
    }

    /// Remaining-length prediction for a batch of hidden states.
    /// `hidden` is [n * d_model] row-major; n is padded to a bucket.
    pub fn predict_remaining(&self, hidden: &[f32]) -> Result<Vec<f32>> {
        let d = self.meta.predictor_d_in;
        if hidden.is_empty() || hidden.len() % d != 0 {
            return Err(Error::coordinator(format!(
                "hidden length {} not a multiple of d={d}",
                hidden.len()
            )));
        }
        let n = hidden.len() / d;
        let bucket = self
            .predictor_exes
            .iter()
            .map(|(b, _)| *b)
            .find(|&b| b >= n)
            .ok_or_else(|| Error::coordinator(format!("no predictor bucket >= {n}")))?;
        let exe = &self
            .predictor_exes
            .iter()
            .find(|(b, _)| *b == bucket)
            .unwrap()
            .1;
        let mut padded = hidden.to_vec();
        padded.resize(bucket * d, 0.0);
        let h_lit = HostTensor::f32(&[bucket as i64, d as i64], padded)?.to_literal()?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(self.pred_params.len() + 1);
        args.extend(self.pred_params.iter());
        args.push(&h_lit);
        let result = exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let mut v = out.to_vec::<f32>()?;
        v.truncate(n);
        Ok(v)
    }

    /// Fresh zeroed KV buffer for a decode bucket.
    pub fn new_kv_buffer(&self, bucket: usize) -> HostTensor {
        let m = &self.meta;
        HostTensor::zeros_f32(&[
            m.n_layers as i64,
            2,
            bucket as i64,
            m.n_heads as i64,
            m.max_seq as i64,
            m.head_dim as i64,
        ])
    }

    /// Copy one request's KV slice (slot `src_slot` of `src`) into slot
    /// `dst_slot` of `dst`. Used for batch compaction, bucket growth, and
    /// migration-in. Layout: [L, 2, B, H, S, Dh], so a slot is strided.
    pub fn copy_kv_slot(
        &self,
        src: &HostTensor,
        src_bucket: usize,
        src_slot: usize,
        dst: &mut HostTensor,
        dst_bucket: usize,
        dst_slot: usize,
    ) -> Result<()> {
        let m = &self.meta;
        let inner = m.n_heads * m.max_seq * m.head_dim; // per (l, kv, slot)
        let (HostTensor::F32 { data: s, .. }, HostTensor::F32 { data: d, .. }) =
            (src, &mut *dst)
        else {
            return Err(Error::artifact("kv buffers must be f32"));
        };
        if src_slot >= src_bucket || dst_slot >= dst_bucket {
            return Err(Error::coordinator("kv slot out of range".to_string()));
        }
        for l in 0..m.n_layers {
            for kvh in 0..2 {
                let s_base = ((l * 2 + kvh) * src_bucket + src_slot) * inner;
                let d_base = ((l * 2 + kvh) * dst_bucket + dst_slot) * inner;
                d[d_base..d_base + inner].copy_from_slice(&s[s_base..s_base + inner]);
            }
        }
        Ok(())
    }

    /// Extract one slot into a standalone [L,2,1,H,S,Dh] tensor (the
    /// migration payload).
    pub fn extract_kv_slot(
        &self,
        src: &HostTensor,
        src_bucket: usize,
        src_slot: usize,
    ) -> Result<HostTensor> {
        let m = &self.meta;
        let mut out = self.new_kv_buffer(1);
        self.copy_kv_slot(src, src_bucket, src_slot, &mut out, 1, 0)?;
        let _ = m;
        Ok(out)
    }
}
