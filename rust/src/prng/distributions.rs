//! Distribution samplers over [`Pcg64`] used by the workload generator and
//! the simulator (normal, log-normal, exponential, Poisson, Zipf, and a
//! two-mode heavy-tail mixture matching the paper's Table 2 shape).

use super::Pcg64;

impl Pcg64 {
    /// Standard normal via Box–Muller (polar form avoided for determinism
    /// of draw count: exactly two uniforms per sample).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = (1.0 - self.next_f64()).max(1e-300); // (0, 1]
        let u2 = self.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Log-normal parameterized by the *underlying* normal's mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda) — Poisson arrivals.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -(1.0 - self.next_f64()).max(1e-300).ln() / lambda
    }

    /// Poisson(lambda). Knuth's product method for small lambda,
    /// normal approximation above 30 (adequate for workload synthesis).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let x = self.normal(lambda, lambda.sqrt());
            return x.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.next_f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Zipf over {1..n} with exponent s (rejection-inversion, Devroye).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n >= 1);
        // simple inverse-CDF on precomputable harmonic weights would need
        // state; rejection sampling keeps the generator stateless.
        let b = 2f64.powf(s - 1.0);
        loop {
            let u = self.next_f64();
            let v = self.next_f64();
            let x = (n as f64).powf(u.max(1e-12)).floor().max(1.0);
            let t = (1.0 + 1.0 / x).powf(s - 1.0);
            if v * x * (t - 1.0) / (b - 1.0) <= t / b {
                return (x as u64).min(n);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn normal_moments() {
        let mut g = Pcg64::new(11, 0);
        let xs: Vec<f64> = (0..40_000).map(|_| g.normal(3.0, 2.0)).collect();
        let (mean, var) = moments(&xs);
        assert!((mean - 3.0).abs() < 0.05, "mean {mean}");
        assert!((var - 4.0).abs() < 0.15, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut g = Pcg64::new(12, 0);
        let xs: Vec<f64> = (0..40_000).map(|_| g.exponential(0.5)).collect();
        let (mean, _) = moments(&xs);
        assert!((mean - 2.0).abs() < 0.06, "mean {mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut g = Pcg64::new(13, 0);
        for lam in [0.5, 4.0, 50.0] {
            let xs: Vec<f64> = (0..20_000).map(|_| g.poisson(lam) as f64).collect();
            let (mean, _) = moments(&xs);
            assert!(
                (mean - lam).abs() < 0.05 * lam.max(1.0) + 0.05,
                "lam {lam} mean {mean}"
            );
        }
    }

    #[test]
    fn lognormal_positive_and_skewed() {
        let mut g = Pcg64::new(14, 0);
        let xs: Vec<f64> = (0..20_000).map(|_| g.lognormal(0.0, 1.0)).collect();
        assert!(xs.iter().all(|&x| x > 0.0));
        let (mean, _) = moments(&xs);
        // E[lognormal(0,1)] = e^{1/2} ≈ 1.6487
        assert!((mean - 1.6487).abs() < 0.08, "mean {mean}");
    }

    #[test]
    fn zipf_in_range_and_head_heavy() {
        let mut g = Pcg64::new(15, 0);
        let mut ones = 0;
        for _ in 0..10_000 {
            let x = g.zipf(100, 1.2);
            assert!((1..=100).contains(&x));
            if x == 1 {
                ones += 1;
            }
        }
        assert!(ones > 2_000, "zipf head too light: {ones}");
    }
}
