//! Deterministic PRNG + distribution samplers (offline substitute for the
//! `rand` crate family — see DESIGN.md §1).
//!
//! PCG64 (O'Neill 2014, PCG-XSL-RR 128/64) — small state, excellent
//! statistical quality, and splittable enough for per-component streams:
//! every subsystem derives its own [`Pcg64`] from a seed + stream id, so
//! experiments are reproducible regardless of thread interleaving.

mod distributions;

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

/// PCG-XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Distinct streams
    /// with the same seed are statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64 | 0xda3e39cb94b95bdb) << 1) | 1;
        let mut g = Pcg64 {
            state: 0,
            inc,
        };
        g.state = g.state.wrapping_mul(PCG_MULT).wrapping_add(g.inc);
        g.state = g.state.wrapping_add(seed as u128);
        g.state = g.state.wrapping_mul(PCG_MULT).wrapping_add(g.inc);
        g
    }

    /// Derive a child generator (stable: depends only on current state).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15);
        Pcg64::new(seed, tag)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi] inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_f64_in_unit_interval() {
        let mut g = Pcg64::new(7, 0);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = g.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut g = Pcg64::new(3, 9);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[g.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut g = Pcg64::new(1, 1);
        let mut v: Vec<u32> = (0..50).collect();
        g.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
