//! Hand-rolled CLI argument parser (offline substitute for clap).
//!
//! Grammar: `star <subcommand> [--key value]... [--flag]... [positional]...`
//! Flags are declared by the caller; unknown flags are errors with a hint.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Parsed command line: subcommand, options, positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positionals: Vec<String>,
}

/// Declarative spec used for validation + help text.
#[derive(Clone, Debug, Default)]
pub struct Spec {
    pub name: &'static str,
    pub about: &'static str,
    /// (name, value placeholder, help)
    pub options: Vec<(&'static str, &'static str, &'static str)>,
    /// (name, help)
    pub flags: Vec<(&'static str, &'static str)>,
}

impl Spec {
    pub fn render_help(&self) -> String {
        let mut s = format!("{}\n  {}\n\noptions:\n", self.name, self.about);
        for (n, ph, h) in &self.options {
            s.push_str(&format!("  --{n} <{ph}>  {h}\n"));
        }
        for (n, h) in &self.flags {
            s.push_str(&format!("  --{n}  {h}\n"));
        }
        s
    }
}

impl Args {
    /// Parse raw argv (without the binary name) against a spec.
    pub fn parse(argv: &[String], spec: &Spec) -> Result<Args> {
        let mut out = Args::default();
        let known_opts: Vec<&str> = spec.options.iter().map(|o| o.0).collect();
        let known_flags: Vec<&str> = spec.flags.iter().map(|f| f.0).collect();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                // --key=value form
                if let Some((k, v)) = name.split_once('=') {
                    if known_opts.contains(&k) {
                        out.opts.insert(k.to_string(), v.to_string());
                        continue;
                    }
                    if known_flags.contains(&k) {
                        return Err(Error::Cli(format!(
                            "flag --{k} takes no value (got `{v}`)"
                        )));
                    }
                    return Err(Error::Cli(format!(
                        "unknown option --{k}\n\n{}",
                        spec.render_help()
                    )));
                }
                if known_flags.contains(&name) {
                    // repeated flags are idempotent, not an error
                    if !out.flags.iter().any(|f| f == name) {
                        out.flags.push(name.to_string());
                    }
                    continue;
                }
                if known_opts.contains(&name) {
                    let v = it.next().ok_or_else(|| {
                        Error::Cli(format!("option --{name} expects a value"))
                    })?;
                    out.opts.insert(name.to_string(), v.clone());
                    continue;
                }
                return Err(Error::Cli(format!(
                    "unknown flag --{name}\n\n{}",
                    spec.render_help()
                )));
            }
            out.positionals.push(arg.clone());
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Cli(format!("--{name} expects a number, got `{v}`"))),
        }
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Cli(format!("--{name} expects an integer, got `{v}`"))),
        }
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Cli(format!("--{name} expects an integer, got `{v}`"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Spec {
        Spec {
            name: "star",
            about: "test",
            options: vec![("rps", "f64", ""), ("out", "path", "")],
            flags: vec![("verbose", "")],
        }
    }

    fn argv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = Args::parse(
            &argv(&["serve", "--rps", "0.2", "--verbose", "extra"]),
            &spec(),
        )
        .unwrap();
        assert_eq!(a.subcommand, "serve");
        assert_eq!(a.opt("rps"), Some("0.2"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals, vec!["extra"]);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(&argv(&["x", "--rps=0.5"]), &spec()).unwrap();
        assert!((a.opt_f64("rps", 0.0).unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn unknown_flag_is_error() {
        assert!(Args::parse(&argv(&["x", "--nope"]), &spec()).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&argv(&["x", "--rps"]), &spec()).is_err());
    }

    #[test]
    fn flag_with_value_is_a_clear_error() {
        let e = Args::parse(&argv(&["x", "--verbose=1"]), &spec()).unwrap_err();
        let msg = e.to_string();
        assert!(
            msg.contains("flag --verbose takes no value"),
            "misleading error: {msg}"
        );
        // genuinely unknown --key=value still reports unknown option
        let e = Args::parse(&argv(&["x", "--nope=1"]), &spec()).unwrap_err();
        assert!(e.to_string().contains("unknown option --nope"));
    }

    #[test]
    fn repeated_flags_dedupe() {
        let a = Args::parse(
            &argv(&["x", "--verbose", "--verbose", "--verbose"]),
            &spec(),
        )
        .unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.flags.len(), 1, "flags must be stored once: {:?}", a.flags);
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse(&argv(&["x", "--rps", "abc"]), &spec()).unwrap();
        assert!(a.opt_f64("rps", 0.0).is_err());
        assert_eq!(a.opt_f64("out", 7.0).unwrap(), 7.0);
    }
}
