//! Prefill→decode dispatch policies (paper §2.2's baselines plus STAR's
//! prediction-aware variant used at hand-off time).

use super::ClusterSnapshot;
use crate::InstanceId;

/// Which prefill→decode assignment policy to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// vLLM-style round-robin [paper ref 34]: even request *counts*,
    /// oblivious to per-request workload.
    RoundRobin,
    /// Current-load balancing [FlowKV, ref 20]: pick the instance with the
    /// smallest current KV token load.
    CurrentLoad,
    /// STAR hand-off: pick the instance with the smallest *projected*
    /// load = current + predicted remaining work of its active requests,
    /// considering the incoming request's own predicted length.
    PredictedLoad,
}

impl DispatchPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().replace('-', "_").as_str() {
            "round_robin" | "rr" => Some(DispatchPolicy::RoundRobin),
            "current_load" | "load" => Some(DispatchPolicy::CurrentLoad),
            "predicted_load" | "predicted" => Some(DispatchPolicy::PredictedLoad),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin => "round_robin",
            DispatchPolicy::CurrentLoad => "current_load",
            DispatchPolicy::PredictedLoad => "predicted_load",
        }
    }
}

/// Stateful dispatcher (round-robin needs a cursor).
#[derive(Clone, Debug)]
pub struct Dispatcher {
    pub policy: DispatchPolicy,
    rr_cursor: usize,
}

impl Dispatcher {
    pub fn new(policy: DispatchPolicy) -> Self {
        Dispatcher {
            policy,
            rr_cursor: 0,
        }
    }

    /// Choose a decode instance for a request arriving from prefill.
    ///
    /// `incoming_tokens` = the request's prompt KV size; `incoming_pred` =
    /// predicted output length from the prefill-time prediction (None when
    /// prediction is off). Instances that cannot fit the prompt KV are
    /// skipped; if none fit, the least-loaded instance is returned anyway
    /// (admission will queue or OOM there, mirroring vLLM behaviour).
    pub fn choose(
        &mut self,
        snapshot: &ClusterSnapshot,
        incoming_tokens: u64,
        incoming_pred: Option<f64>,
    ) -> InstanceId {
        let n = snapshot.instances.len();
        assert!(n > 0, "dispatch with no decode instances");
        let fits = |idx: usize| snapshot.instances[idx].free_tokens() >= incoming_tokens;

        match self.policy {
            DispatchPolicy::RoundRobin => {
                for off in 0..n {
                    let idx = (self.rr_cursor + off) % n;
                    if fits(idx) {
                        self.rr_cursor = (idx + 1) % n;
                        return snapshot.instances[idx].id;
                    }
                }
                let idx = self.rr_cursor % n;
                self.rr_cursor = (idx + 1) % n;
                snapshot.instances[idx].id
            }
            DispatchPolicy::CurrentLoad => {
                Self::argmin(snapshot, fits, |iv| iv.effective_used() as f64)
            }
            DispatchPolicy::PredictedLoad => {
                let pred = incoming_pred.unwrap_or(0.0);
                Self::argmin(snapshot, fits, |iv| {
                    let future: f64 = iv
                        .requests
                        .iter()
                        .map(|r| r.tokens as f64 + r.remaining_or(0.0))
                        .sum();
                    future + iv.inbound_reserved_tokens as f64 + pred
                })
            }
        }
    }

    fn argmin<F, G>(snapshot: &ClusterSnapshot, fits: F, score: G) -> InstanceId
    where
        F: Fn(usize) -> bool,
        G: Fn(&super::InstanceView) -> f64,
    {
        let mut best: Option<(f64, InstanceId)> = None;
        let mut best_any: Option<(f64, InstanceId)> = None;
        for (idx, iv) in snapshot.instances.iter().enumerate() {
            let s = score(iv);
            if best_any.map(|(b, _)| s < b).unwrap_or(true) {
                best_any = Some((s, iv.id));
            }
            if fits(idx) && best.map(|(b, _)| s < b).unwrap_or(true) {
                best = Some((s, iv.id));
            }
        }
        best.or(best_any).expect("non-empty instance list").1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::{inst, req};
    use crate::coordinator::ClusterSnapshot;

    fn snap3(loads: [u64; 3]) -> ClusterSnapshot {
        ClusterSnapshot {
            instances: loads
                .iter()
                .enumerate()
                .map(|(i, &l)| inst(i, vec![req(i as u64 + 1, l, None)], 10_000))
                .collect(),
            tokens_per_interval: 10.0,
        }
    }

    #[test]
    fn round_robin_cycles() {
        let snap = snap3([0, 0, 0]);
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin);
        let picks: Vec<_> = (0..6).map(|_| d.choose(&snap, 10, None)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn round_robin_skips_full_instances() {
        let mut snap = snap3([0, 0, 0]);
        snap.instances[0].inbound_reserved_tokens = 10_000; // full
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin);
        assert_eq!(d.choose(&snap, 10, None), 1);
        assert_eq!(d.choose(&snap, 10, None), 2);
        assert_eq!(d.choose(&snap, 10, None), 1);
    }

    #[test]
    fn current_load_picks_least_loaded() {
        let snap = snap3([500, 100, 300]);
        let mut d = Dispatcher::new(DispatchPolicy::CurrentLoad);
        assert_eq!(d.choose(&snap, 10, None), 1);
    }

    #[test]
    fn predicted_load_sees_future_work() {
        // instance 0: small now but huge remaining; instance 1: bigger now
        // but nearly done.
        let snap = ClusterSnapshot {
            instances: vec![
                inst(0, vec![req(1, 100, Some(5_000.0))], 100_000),
                inst(1, vec![req(2, 400, Some(10.0))], 100_000),
            ],
            tokens_per_interval: 10.0,
        };
        let mut cur = Dispatcher::new(DispatchPolicy::CurrentLoad);
        let mut pred = Dispatcher::new(DispatchPolicy::PredictedLoad);
        assert_eq!(cur.choose(&snap, 10, None), 0, "current-load is fooled");
        assert_eq!(pred.choose(&snap, 10, None), 1, "predicted-load is not");
    }

    #[test]
    fn overflow_falls_back_to_least_loaded() {
        let snap = snap3([9_995, 9_999, 9_997]);
        let mut d = Dispatcher::new(DispatchPolicy::CurrentLoad);
        // nothing fits 100 tokens; least-loaded wins anyway
        assert_eq!(d.choose(&snap, 100, None), 0);
    }

    #[test]
    fn parse_names() {
        assert_eq!(
            DispatchPolicy::parse("round-robin"),
            Some(DispatchPolicy::RoundRobin)
        );
        assert_eq!(
            DispatchPolicy::parse("current_load"),
            Some(DispatchPolicy::CurrentLoad)
        );
        assert_eq!(DispatchPolicy::parse("nope"), None);
    }
}
