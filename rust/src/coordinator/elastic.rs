//! Elastic instance-pool subsystem: predictive autoscaling and
//! prefill↔decode role flipping.
//!
//! The paper's rescheduler moves *requests* inside a fixed decode pool;
//! this module moves the *pool* itself. Arrow (arXiv:2505.11916) and DOPD
//! (arXiv:2511.20982) both show that a frozen prefill:decode split leaves
//! goodput on the table once the workload drifts — exactly the bursty /
//! diurnal scenarios the scenario registry synthesizes. The length
//! predictor already gives a forward-looking aggregate load signal
//! (Σ predicted remaining tokens), so the `predictive` policy drives the
//! P/D ratio off the same quantity Algorithm 1 balances.
//!
//! Shape of the subsystem:
//!
//! * every instance carries a [`Lifecycle`]: `Provisioning → Active →
//!   Draining → Retired`. Draining instances accept no dispatches and no
//!   migration arrivals; once their residents finish or migrate out, the
//!   driver fires its drain-complete path and the instance either retires
//!   or re-roles (flip) after a modeled warm-up delay;
//! * an object-safe [`ScalingPolicy`] decides [`ScalingAction`]s once per
//!   scale interval from a borrowed [`ClusterView`] (decode side) plus
//!   [`PoolStats`] (prefill side + rates). Policies are registered by
//!   string in the `PolicyRegistry` next to dispatch/reschedule;
//! * the [`ElasticGuard`] clamps decisions to the configured floors,
//!   enforces one in-flight transition at a time, and applies a cooldown
//!   — policies stay simple and the drivers stay deterministic;
//! * both drivers execute the same decisions through `ControlLoop::scale`:
//!   the simulator via `ScaleTick`/`InstanceReady`/`DrainComplete` events,
//!   the live server by retiring/spawning decode-instance threads and
//!   resizing the prefill worker pool.

use std::fmt;

use super::cluster_state::ClusterView;
use super::policy::PolicyConfig;
use crate::config::ElasticConfig;
use crate::{InstanceId, Time};

/// Lifecycle of one pool instance. `Active` is the only state that
/// accepts dispatches or migration arrivals.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Lifecycle {
    /// Spawning / warming up; becomes `Active` after the modeled delay.
    /// The builtin drivers represent warm-ups as *pool counters*
    /// ([`PoolStats::prefill_provisioning`] / `decode_provisioning`) and
    /// materialize the instance slot only when it turns Active, so they
    /// never construct this variant themselves — it exists for drivers
    /// and hand-built views that do materialize warming slots (policies
    /// and the guard already treat it as unschedulable).
    Provisioning,
    #[default]
    Active,
    /// No new work; residents finish or migrate out, then the instance
    /// retires or flips role.
    Draining,
    /// Out of the pool (slot kept so instance ids stay stable).
    Retired,
    /// Crashed (fault injection): unschedulable, out of every pool count
    /// until an `InstanceRecovered` event flips it back to `Active`. A
    /// failed slot frees headroom under `max_total`, which is what lets
    /// the elastic guard provision replacement capacity.
    Failed,
}

/// Which pool an action targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolRole {
    Prefill,
    Decode,
}

impl fmt::Display for PoolRole {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            PoolRole::Prefill => "prefill",
            PoolRole::Decode => "decode",
        })
    }
}

/// One pool-shape change decided by a [`ScalingPolicy`]. Decode-side
/// targets are named explicitly (policies see decode instances through the
/// [`ClusterView`]); prefill-side selection is the executor's (policies
/// cannot see inside the prefill pool, the executor picks the least-loaded
/// active worker).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScalingAction {
    /// Drain the least-loaded active prefill instance and re-role it as a
    /// decode instance (after the flip warm-up).
    FlipToDecode,
    /// Drain decode instance `decode`; once empty it re-roles as a
    /// prefill instance (after the flip warm-up).
    FlipToPrefill { decode: InstanceId },
    /// Add a brand-new instance of `role` (full provision warm-up).
    Provision { role: PoolRole },
    /// Drain and remove one instance of `role` (executor picks the
    /// least-loaded active one).
    Retire { role: PoolRole },
}

impl fmt::Display for ScalingAction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScalingAction::FlipToDecode => write!(f, "flip_to_decode"),
            ScalingAction::FlipToPrefill { decode } => write!(f, "flip_to_prefill({decode})"),
            ScalingAction::Provision { role } => write!(f, "provision({role})"),
            ScalingAction::Retire { role } => write!(f, "retire({role})"),
        }
    }
}

/// One executed scaling action, timestamped — the scale-action trace
/// (determinism tests compare these verbatim; the elastic bench emits
/// them as the instance-count timeline's annotations).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScaleRecord {
    pub t: Time,
    pub action: ScalingAction,
}

/// Pool-side inputs a [`ScalingPolicy`] consumes next to the decode-side
/// [`ClusterView`]: pool composition by lifecycle, prefill backlog, and
/// the measured rates that turn backlogs into instance counts.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    pub now: Time,
    pub prefill_active: usize,
    pub prefill_draining: usize,
    pub prefill_provisioning: usize,
    pub decode_active: usize,
    pub decode_draining: usize,
    pub decode_provisioning: usize,
    /// Requests waiting for (or running) prefill.
    pub prefill_queued_reqs: usize,
    /// Σ prompt/KV tokens of those requests.
    pub prefill_queued_tokens: u64,
    /// EWMA of the token arrival rate into prefill (tokens/s) — the
    /// "incoming prefill work" side of the predictive signal.
    pub arrival_tokens_per_s: f64,
    /// EWMA of per-instance prefill service rate (tokens/s); 0 until
    /// measured.
    pub prefill_tokens_per_s: f64,
}

impl PoolStats {
    /// Every instance currently owned by the pool, any lifecycle.
    pub fn total_instances(&self) -> usize {
        self.prefill_active
            + self.prefill_draining
            + self.prefill_provisioning
            + self.decode_active
            + self.decode_draining
            + self.decode_provisioning
    }

    /// Any transition (drain or warm-up) still in flight?
    pub fn transition_in_flight(&self) -> bool {
        self.prefill_draining
            + self.prefill_provisioning
            + self.decode_draining
            + self.decode_provisioning
            > 0
    }
}

/// Shared per-interval rate meter behind [`PoolStats`]'s measured
/// rates. Both drivers fold the same counters through the same blend
/// (0.5/0.5 EWMA, first tick seeds raw, prefill rate only updates on
/// non-zero samples), so the predictive signal is defined once — a
/// driver-local reimplementation drifting would silently break
/// sim-vs-live comparability.
#[derive(Clone, Debug, Default)]
pub struct RateMeter {
    arrival_tokens: u64,
    prefill_tokens: u64,
    arrival_rate_ewma: f64,
    prefill_rate_ewma: f64,
    ticks: u64,
}

impl RateMeter {
    /// Tokens entering the prefill stage (count every admission to the
    /// queue, recomputes included — they are prefill work).
    pub fn on_arrival(&mut self, tokens: u64) {
        self.arrival_tokens += tokens;
    }

    /// Tokens that completed prefill.
    pub fn on_prefill_done(&mut self, tokens: u64) {
        self.prefill_tokens += tokens;
    }

    /// Fold the interval's counters into the EWMAs and reset them.
    /// `dt` is the elapsed interval; `active_prefill` normalizes the
    /// service rate per instance.
    pub fn tick(&mut self, dt: f64, active_prefill: usize) {
        let dt = dt.max(1e-9);
        let arr = self.arrival_tokens as f64 / dt;
        self.arrival_rate_ewma = if self.ticks == 0 {
            arr
        } else {
            0.5 * self.arrival_rate_ewma + 0.5 * arr
        };
        let pf = self.prefill_tokens as f64 / dt / active_prefill.max(1) as f64;
        if pf > 0.0 {
            self.prefill_rate_ewma = if self.prefill_rate_ewma <= 0.0 {
                pf
            } else {
                0.5 * self.prefill_rate_ewma + 0.5 * pf
            };
        }
        self.arrival_tokens = 0;
        self.prefill_tokens = 0;
        self.ticks += 1;
    }

    pub fn arrival_tokens_per_s(&self) -> f64 {
        self.arrival_rate_ewma
    }

    pub fn prefill_tokens_per_s(&self) -> f64 {
        self.prefill_rate_ewma
    }
}

/// Pool-reshaping strategy, invoked once per scale interval. Pure with
/// respect to its inputs: the caller (via [`ElasticGuard`] and the
/// driver) validates and executes the returned actions.
pub trait ScalingPolicy {
    /// Registry name this policy answers to (diagnostics + reports).
    fn name(&self) -> &str;

    /// Propose pool-shape changes, best-first. The guard keeps at most
    /// the first valid one.
    fn decide(&mut self, view: &ClusterView<'_>, pool: &PoolStats) -> Vec<ScalingAction>;
}

// ---------------------------------------------------------------------
// shared decode-side signals

/// The active decode instance cheapest to drain: least projected work
/// (+ inbound reservations), ties broken by lowest id. Shared by the
/// builtin policies and by both drivers' `Retire { Decode }` executors.
pub fn emptiest_active_decode(view: &ClusterView<'_>) -> Option<InstanceId> {
    let mut best: Option<(f64, InstanceId)> = None;
    for iv in view.instances() {
        if !iv.is_schedulable() {
            continue;
        }
        let w = iv.predicted_work() + iv.inbound_reserved_tokens() as f64;
        let better = match best {
            None => true,
            Some((bw, bid)) => w < bw || (w == bw && iv.id() < bid),
        };
        if better {
            best = Some((w, iv.id()));
        }
    }
    best.map(|(_, id)| id)
}

/// Best destination for a resident leaving a draining instance: the
/// active instance with the most free KV that can re-admit `tokens`
/// under the admission watermark with a batch slot available (ties on
/// lowest id). The draining source is never schedulable, so it excludes
/// itself. Shared by both drivers' drain-out paths.
pub fn drain_destination(
    view: &ClusterView<'_>,
    tokens: u64,
    max_batch: usize,
) -> Option<InstanceId> {
    use super::cluster_state::admission_watermark;
    let mut best: Option<(u64, InstanceId)> = None;
    for iv in view.instances() {
        if !iv.is_schedulable() || iv.batch_size() >= max_batch {
            continue;
        }
        if iv.effective_used() + tokens > admission_watermark(iv.kv_capacity_tokens()) {
            continue;
        }
        let free = iv.free_tokens();
        if best.map(|(bf, _)| free > bf).unwrap_or(true) {
            best = Some((free, iv.id()));
        }
    }
    best.map(|(_, id)| id)
}

/// Mean effective KV occupancy fraction over active decode instances.
fn active_kv_frac(view: &ClusterView<'_>) -> f64 {
    let (mut used, mut cap) = (0.0f64, 0.0f64);
    for iv in view.instances() {
        if iv.is_schedulable() {
            used += iv.effective_used() as f64;
            cap += iv.kv_capacity_tokens() as f64;
        }
    }
    if cap <= 0.0 {
        0.0
    } else {
        used / cap
    }
}

// ---------------------------------------------------------------------
// builtin policies

/// Today's behavior: the pool never changes shape. The default, and the
/// regression baseline (`--scaling static` must reproduce frozen-pool
/// reports bit-for-bit).
#[derive(Clone, Debug, Default)]
pub struct StaticScaling;

impl ScalingPolicy for StaticScaling {
    fn name(&self) -> &str {
        "static"
    }

    fn decide(&mut self, _view: &ClusterView<'_>, _pool: &PoolStats) -> Vec<ScalingAction> {
        Vec::new()
    }
}

/// Reactive flipper: compares prefill-queue depth against decode KV
/// headroom and flips toward whichever side is drowning *now*. Knobs
/// (via `PolicyConfig::params`):
///
/// * `queue_pressure.queue_hi` — queued prefill tokens per active prefill
///   instance that marks prefill as overloaded (default 4096)
/// * `queue_pressure.kv_hi` — mean decode KV fraction above which decode
///   needs capacity (default 0.85)
/// * `queue_pressure.kv_lo` — mean decode KV fraction below which decode
///   can afford to give an instance away (default 0.5)
#[derive(Clone, Debug)]
pub struct QueuePressureScaling {
    queue_hi: f64,
    kv_hi: f64,
    kv_lo: f64,
}

impl QueuePressureScaling {
    pub fn from_config(cfg: &PolicyConfig) -> Self {
        QueuePressureScaling {
            queue_hi: cfg.param_or("queue_pressure.queue_hi", 4096.0).max(1.0),
            kv_hi: cfg.param_or("queue_pressure.kv_hi", 0.85).clamp(0.05, 1.0),
            kv_lo: cfg.param_or("queue_pressure.kv_lo", 0.5).clamp(0.0, 1.0),
        }
    }
}

impl ScalingPolicy for QueuePressureScaling {
    fn name(&self) -> &str {
        "queue_pressure"
    }

    fn decide(&mut self, view: &ClusterView<'_>, pool: &PoolStats) -> Vec<ScalingAction> {
        if pool.decode_active == 0 || pool.prefill_active == 0 {
            return Vec::new();
        }
        let kv_frac = active_kv_frac(view);
        let queue_per = pool.prefill_queued_tokens as f64 / pool.prefill_active as f64;
        // decode side drowning while prefill has slack: take a prefill
        if kv_frac >= self.kv_hi && queue_per < self.queue_hi / 2.0 {
            let role = PoolRole::Decode;
            return vec![ScalingAction::FlipToDecode, ScalingAction::Provision { role }];
        }
        // prefill backlog growing while decode has KV slack: give one back
        if queue_per >= self.queue_hi && kv_frac <= self.kv_lo {
            let mut out = Vec::new();
            if let Some(di) = emptiest_active_decode(view) {
                out.push(ScalingAction::FlipToPrefill { decode: di });
            }
            let role = PoolRole::Prefill;
            out.push(ScalingAction::Provision { role });
            return out;
        }
        Vec::new()
    }
}

/// Predictive flipper — the ARES signal applied to the pool shape: the
/// decode side's *future* KV demand is Σ (current tokens + predicted
/// remaining) over its residents, and the prefill side's demand is the
/// queued prompt tokens plus the arrival-rate lookahead. Each side is
/// converted to a needed instance count and the pool flips toward the
/// deficit before it materializes (the reactive policy waits for the
/// queue or the KV meter to actually fill). Knobs:
///
/// * `predictive.target_kv_frac` — plan decode capacity so projected KV
///   stays below this fraction (default 0.7)
/// * `predictive.lookahead_s` — horizon for converting arrival rate into
///   prefill work (default 15 s)
/// * `predictive.kv_hi` — urgent decode-add threshold on *current*
///   occupancy, independent of the projection (default 0.85)
/// * `predictive.kv_lo` — only below this current occupancy may decode
///   shed an instance (default 0.45)
///
/// Capacity planning is OOM-avoidance, so the projected demand is read at
/// the *conservative* estimate quantile
/// (`Prediction::quantile(conservative_q)`, p90 by default, configured
/// via `[predictor] conservative_q`): an uncertain remaining length must
/// be planned for as if long, or the pool under-provisions exactly when
/// the predictor is least sure.
#[derive(Clone, Debug)]
pub struct PredictiveScaling {
    target_kv_frac: f64,
    lookahead_s: f64,
    kv_hi: f64,
    kv_lo: f64,
    /// Estimate quantile of the projected-demand signal.
    q: f64,
}

impl PredictiveScaling {
    pub fn from_config(cfg: &PolicyConfig) -> Self {
        PredictiveScaling {
            target_kv_frac: cfg
                .param_or("predictive.target_kv_frac", 0.7)
                .clamp(0.05, 1.0),
            lookahead_s: cfg.param_or("predictive.lookahead_s", 15.0).max(1e-3),
            kv_hi: cfg.param_or("predictive.kv_hi", 0.85).clamp(0.05, 1.0),
            kv_lo: cfg.param_or("predictive.kv_lo", 0.45).clamp(0.0, 1.0),
            q: cfg.conservative_q,
        }
    }

    /// Decode instances needed so Σ (tokens + quantile-q predicted
    /// remaining) fits under `target_kv_frac` of per-instance capacity.
    fn needed_decode(&self, view: &ClusterView<'_>) -> usize {
        let (mut projected, mut cap_sum, mut n) = (0.0f64, 0.0f64, 0usize);
        for iv in view.instances() {
            if iv.is_schedulable() {
                projected += iv.predicted_work_q(self.q) + iv.inbound_reserved_tokens() as f64;
                cap_sum += iv.kv_capacity_tokens() as f64;
                n += 1;
            }
        }
        if n == 0 || cap_sum <= 0.0 {
            return 1;
        }
        let cap_per = cap_sum / n as f64;
        (projected / (self.target_kv_frac * cap_per)).ceil().max(1.0) as usize
    }

    /// Prefill instances needed to clear the queue plus the lookahead's
    /// incoming tokens within the lookahead.
    fn needed_prefill(&self, pool: &PoolStats) -> usize {
        if pool.prefill_tokens_per_s <= 0.0 {
            // no service-rate measurement yet: hold the current shape
            return pool.prefill_active.max(1);
        }
        let queued = pool.prefill_queued_tokens as f64;
        let work = queued + pool.arrival_tokens_per_s * self.lookahead_s;
        let per_inst = pool.prefill_tokens_per_s * self.lookahead_s;
        (work / per_inst).ceil().max(1.0) as usize
    }
}

impl ScalingPolicy for PredictiveScaling {
    fn name(&self) -> &str {
        "predictive"
    }

    fn decide(&mut self, view: &ClusterView<'_>, pool: &PoolStats) -> Vec<ScalingAction> {
        if pool.decode_active == 0 || pool.prefill_active == 0 {
            return Vec::new();
        }
        let kv_frac = active_kv_frac(view);
        let needed_decode = self.needed_decode(view);
        let needed_prefill = self.needed_prefill(pool);

        // decode deficit (projected or already urgent): grow decode,
        // preferably by taking a surplus prefill
        if kv_frac >= self.kv_hi || pool.decode_active < needed_decode {
            let prefill_surplus = pool.prefill_active > needed_prefill;
            let mut out = Vec::new();
            if prefill_surplus || kv_frac >= self.kv_hi {
                out.push(ScalingAction::FlipToDecode);
            }
            let role = PoolRole::Decode;
            out.push(ScalingAction::Provision { role });
            return out;
        }
        // prefill deficit while decode has verified slack: flip one back
        if pool.prefill_active < needed_prefill
            && pool.decode_active > needed_decode
            && kv_frac <= self.kv_lo
        {
            let mut out = Vec::new();
            if let Some(di) = emptiest_active_decode(view) {
                out.push(ScalingAction::FlipToPrefill { decode: di });
            }
            let role = PoolRole::Prefill;
            out.push(ScalingAction::Provision { role });
            return out;
        }
        Vec::new()
    }
}

// ---------------------------------------------------------------------
// guard

/// Clamps a policy's proposals to what the pool may actually do: floors
/// from [`ElasticConfig`], at most one action per tick, no new action
/// while a transition is still in flight, and a cooldown after each
/// executed action. Keeping this out of the policies means every policy
/// (builtin or third-party) inherits the same safety envelope.
#[derive(Clone, Debug)]
pub struct ElasticGuard {
    cfg: ElasticConfig,
    last_action_t: Option<Time>,
}

impl ElasticGuard {
    pub fn new(cfg: ElasticConfig) -> ElasticGuard {
        ElasticGuard {
            cfg,
            last_action_t: None,
        }
    }

    pub fn config(&self) -> &ElasticConfig {
        &self.cfg
    }

    /// Validate `actions` best-first and keep the first admissible one
    /// (empty if none). Records the admission time for the cooldown.
    pub fn admit(
        &mut self,
        actions: Vec<ScalingAction>,
        view: &ClusterView<'_>,
        pool: &PoolStats,
    ) -> Vec<ScalingAction> {
        if actions.is_empty() {
            return actions;
        }
        if pool.transition_in_flight() {
            return Vec::new();
        }
        if let Some(t) = self.last_action_t {
            if pool.now - t < self.cfg.cooldown_s {
                return Vec::new();
            }
        }
        for a in actions {
            let ok = match a {
                ScalingAction::FlipToDecode => pool.prefill_active > self.cfg.min_prefill,
                ScalingAction::FlipToPrefill { decode } => {
                    pool.decode_active > self.cfg.min_decode
                        && decode < view.n_instances()
                        && view.instance(decode).lifecycle() == Lifecycle::Active
                }
                ScalingAction::Provision { .. } => {
                    self.cfg.max_total > 0 && pool.total_instances() < self.cfg.max_total
                }
                ScalingAction::Retire { role: PoolRole::Prefill } => {
                    pool.prefill_active > self.cfg.min_prefill
                }
                ScalingAction::Retire { role: PoolRole::Decode } => {
                    pool.decode_active > self.cfg.min_decode
                }
            };
            if ok {
                self.last_action_t = Some(pool.now);
                return vec![a];
            }
        }
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::{inst, req};
    use crate::coordinator::ClusterSnapshot;

    fn snap(loads: &[u64], cap: u64) -> ClusterSnapshot {
        ClusterSnapshot {
            instances: loads
                .iter()
                .enumerate()
                .map(|(i, &l)| inst(i, vec![req(i as u64 + 1, l, Some(100.0))], cap))
                .collect(),
            tokens_per_interval: 10.0,
        }
    }

    fn pool(prefill: usize, decode: usize) -> PoolStats {
        PoolStats {
            now: 100.0,
            prefill_active: prefill,
            decode_active: decode,
            ..Default::default()
        }
    }

    #[test]
    fn static_never_acts() {
        let s = snap(&[90_000, 90_000], 100_000);
        let mut p = StaticScaling;
        assert!(p.decide(&s.view(), &pool(2, 2)).is_empty());
    }

    #[test]
    fn queue_pressure_flips_toward_the_drowning_side() {
        let mut p = QueuePressureScaling::from_config(&PolicyConfig::default());
        // decode hot (95% KV), prefill idle: wants a decode instance
        let hot = snap(&[95_000, 95_000], 100_000);
        let acts = p.decide(&hot.view(), &pool(2, 2));
        assert_eq!(acts.first(), Some(&ScalingAction::FlipToDecode));
        // prefill backlogged, decode cold: gives the emptiest decode back
        let cold = snap(&[30_000, 10_000], 100_000);
        let mut st = pool(1, 2);
        st.prefill_queued_tokens = 50_000;
        let acts = p.decide(&cold.view(), &st);
        assert_eq!(
            acts.first(),
            Some(&ScalingAction::FlipToPrefill { decode: 1 }),
            "must pick the least-loaded decode instance"
        );
        // balanced: nothing
        let mid = snap(&[60_000, 60_000], 100_000);
        assert!(p.decide(&mid.view(), &pool(2, 2)).is_empty());
    }

    #[test]
    fn predictive_reads_the_projected_signal() {
        let mut p = PredictiveScaling::from_config(&PolicyConfig::default());
        // current occupancy is low but predicted remaining is huge:
        // projected demand (2 × (20k + 200k) = 440k) needs ~7 instances
        // at 0.7 × 100k — predictive flips BEFORE the KV meter fills
        let s = ClusterSnapshot {
            instances: vec![
                inst(0, vec![req(1, 20_000, Some(200_000.0))], 100_000),
                inst(1, vec![req(2, 20_000, Some(200_000.0))], 100_000),
            ],
            tokens_per_interval: 10.0,
        };
        let mut st = pool(3, 2);
        st.prefill_tokens_per_s = 10_000.0; // prefill has measured slack
        let acts = p.decide(&s.view(), &st);
        assert_eq!(acts.first(), Some(&ScalingAction::FlipToDecode));
        // nearly-done work, starved prefill: flip one back
        let s = ClusterSnapshot {
            instances: vec![
                inst(0, vec![req(1, 10_000, Some(100.0))], 100_000),
                inst(1, vec![req(2, 1_000, Some(100.0))], 100_000),
                inst(2, vec![req(3, 10_000, Some(100.0))], 100_000),
            ],
            tokens_per_interval: 10.0,
        };
        let mut st = pool(1, 3);
        st.prefill_queued_tokens = 400_000;
        st.arrival_tokens_per_s = 20_000.0;
        st.prefill_tokens_per_s = 10_000.0;
        let acts = p.decide(&s.view(), &st);
        assert_eq!(acts.first(), Some(&ScalingAction::FlipToPrefill { decode: 1 }));
    }

    #[test]
    fn guard_enforces_floors_cooldown_and_single_transition() {
        let cfg = ElasticConfig {
            cooldown_s: 10.0,
            ..Default::default()
        };
        let mut g = ElasticGuard::new(cfg);
        let s = snap(&[100, 100], 100_000);
        let flip_out = vec![ScalingAction::FlipToDecode];
        let role = PoolRole::Decode;
        let provision = vec![ScalingAction::Provision { role }];
        // floor: cannot flip the last prefill instance away
        let acts = g.admit(flip_out.clone(), &s.view(), &pool(1, 2));
        assert!(acts.is_empty());
        // falls through to the next admissible proposal
        let both = vec![
            ScalingAction::FlipToDecode,
            ScalingAction::FlipToPrefill { decode: 0 },
        ];
        let acts = g.admit(both, &s.view(), &pool(1, 2));
        assert_eq!(acts, vec![ScalingAction::FlipToPrefill { decode: 0 }]);
        // cooldown: the very next tick is rejected
        let mut st = pool(2, 2);
        st.now = 105.0;
        assert!(g.admit(flip_out.clone(), &s.view(), &st).is_empty());
        let mut st = pool(2, 2);
        st.now = 111.0;
        assert_eq!(g.admit(flip_out.clone(), &s.view(), &st), flip_out);
        // an in-flight transition blocks everything
        let mut st = pool(4, 4);
        st.now = 1000.0;
        st.decode_draining = 1;
        assert!(g.admit(flip_out.clone(), &s.view(), &st).is_empty());
        // provisioning is disabled while max_total == 0
        let mut st = pool(4, 4);
        st.now = 2000.0;
        assert!(g.admit(provision.clone(), &s.view(), &st).is_empty());
        // ... and capped when enabled
        let capped = ElasticConfig {
            max_total: 9,
            cooldown_s: 0.0,
            ..Default::default()
        };
        let mut g = ElasticGuard::new(capped);
        let mut st = pool(4, 4);
        st.now = 3000.0;
        assert_eq!(g.admit(provision.clone(), &s.view(), &st).len(), 1);
        let mut st = pool(4, 5);
        st.now = 4000.0;
        assert!(g.admit(provision.clone(), &s.view(), &st).is_empty());
    }

    #[test]
    fn guard_rejects_flipping_a_non_active_decode() {
        let relaxed = ElasticConfig {
            cooldown_s: 0.0,
            ..Default::default()
        };
        let mut g = ElasticGuard::new(relaxed);
        let mut s = snap(&[100, 100, 100], 100_000);
        s.instances[1].lifecycle = Lifecycle::Draining;
        // draining target: invalid; out-of-range target: invalid
        for bad in [1usize, 7usize] {
            let acts = g.admit(
                vec![ScalingAction::FlipToPrefill { decode: bad }],
                &s.view(),
                &pool(2, 3),
            );
            assert!(acts.is_empty(), "target {bad} must be rejected");
        }
        let ok = vec![ScalingAction::FlipToPrefill { decode: 2 }];
        assert_eq!(g.admit(ok.clone(), &s.view(), &pool(2, 3)), ok);
    }

    #[test]
    fn rate_meter_blends_and_seeds() {
        let mut m = RateMeter::default();
        m.on_arrival(1000);
        m.on_prefill_done(500);
        m.tick(10.0, 1);
        assert!((m.arrival_tokens_per_s() - 100.0).abs() < 1e-9, "first tick seeds raw");
        assert!((m.prefill_tokens_per_s() - 50.0).abs() < 1e-9);
        // second tick blends 0.5/0.5; a zero prefill sample leaves the
        // service-rate estimate untouched (no work ≠ zero speed)
        m.on_arrival(3000);
        m.tick(10.0, 1);
        assert!((m.arrival_tokens_per_s() - 200.0).abs() < 1e-9);
        assert!((m.prefill_tokens_per_s() - 50.0).abs() < 1e-9);
        // per-instance normalization
        m.on_prefill_done(3000);
        m.tick(10.0, 3);
        assert!((m.prefill_tokens_per_s() - 75.0).abs() < 1e-9, "0.5*50 + 0.5*100");
    }

    #[test]
    fn action_display_is_stable() {
        assert_eq!(ScalingAction::FlipToDecode.to_string(), "flip_to_decode");
        let flip = ScalingAction::FlipToPrefill { decode: 3 };
        assert_eq!(flip.to_string(), "flip_to_prefill(3)");
        let role = PoolRole::Decode;
        assert_eq!(ScalingAction::Provision { role }.to_string(), "provision(decode)");
        let role = PoolRole::Prefill;
        assert_eq!(ScalingAction::Retire { role }.to_string(), "retire(prefill)");
    }
}
