//! The STAR coordinator: prefill→decode dispatch policies and the
//! decode-phase rescheduler (paper §5, Algorithm 1), behind a pluggable
//! policy API.
//!
//! Policy code is pure — it consumes borrowed [`ClusterView`]s and returns
//! decisions — and both drivers (the live serving runtime `crate::serve`
//! and the event-driven simulator `crate::sim`) execute it through the
//! same [`ControlLoop`], which is what makes the large-scale simulation
//! results (Fig. 13) meaningful for the real system. Views are normally
//! backed by the incremental [`ClusterState`] (O(1) aggregates maintained
//! at each mutation point); a hand-assembled [`ClusterSnapshot`] remains
//! the compatibility materialization (`snapshot.view()`) for tests and
//! third-party policy harnesses.
//!
//! Strategies are constructed by name via [`PolicyRegistry`]; see
//! [`policy`] for the trait surface and `DESIGN.md` §5 for the
//! how-to-add-a-policy recipe.

pub mod cluster_state;
pub mod control_loop;
pub mod elastic;
pub mod future_load;
pub mod policy;
pub mod rescheduler;

pub use cluster_state::{
    admission_watermark, ClusterState, ClusterView, HardwareProfile, InstanceRef, InstanceStats,
    ShardAggregate, ShardRollup,
};
pub use control_loop::ControlLoop;
pub use elastic::{
    ElasticGuard, Lifecycle, PoolRole, PoolStats, RateMeter, ScaleRecord, ScalingAction,
    ScalingPolicy,
};
pub use future_load::{FutureLoad, WorkerReport};
pub use policy::{
    DispatchPolicy, IncomingRequest, PolicyConfig, PolicyRegistry, ReschedulePolicy,
};
pub use rescheduler::{MigrationDecision, Rescheduler, ReschedulerStats};

// the uncertainty-aware prediction signal policies consume (re-exported so
// policy code and tests reach it without crossing into `crate::predictor`)
pub use crate::predictor::Prediction;

use crate::{InstanceId, RequestId};

/// Scheduler-visible state of one active decode request.
#[derive(Clone, Debug)]
pub struct RequestView {
    pub id: RequestId,
    /// Current token count N(r): prompt + generated so far (KV footprint).
    pub tokens: u64,
    /// Predicted remaining generation length N̂(r) with its uncertainty,
    /// if prediction is on.
    pub predicted_remaining: Option<Prediction>,
    /// Set while the request is being migrated (excluded from candidates).
    pub migrating: bool,
}

impl RequestView {
    /// Mean remaining estimate (the balancing view); without prediction
    /// the scheduler must assume "unknown", modeled as a configurable
    /// default.
    pub fn remaining_or(&self, default: f64) -> f64 {
        self.predicted_remaining.map_or(default, |p| p.mean)
    }

    /// Quantile-`q` remaining estimate — the conservative view the
    /// OOM-avoidance and migration-target checks consume (p90 by
    /// default; see `[predictor] conservative_q`).
    pub fn remaining_q(&self, q: f64, default: f64) -> f64 {
        self.predicted_remaining.map_or(default, |p| p.quantile(q))
    }
}

/// Scheduler-visible state of one decode instance.
#[derive(Clone, Debug)]
pub struct InstanceView {
    pub id: InstanceId,
    pub requests: Vec<RequestView>,
    pub kv_capacity_tokens: u64,
    /// Tokens reserved by migrations already in flight toward this
    /// instance (prevents racing two migrations into the same headroom).
    pub inbound_reserved_tokens: u64,
    /// Idle prefix-cache KV retained on this instance for session reuse
    /// (`kvcache::PrefixCache`); 0 with the cache off. Included in
    /// [`Self::effective_used`] so cached bytes compete with admissions.
    pub cached_tokens: u64,
    /// Elastic-pool lifecycle; hand-built snapshots default to `Active`
    /// (a frozen pool is all-Active). Non-Active instances accept no
    /// dispatches and no migration arrivals.
    pub lifecycle: Lifecycle,
    /// Hardware class for heterogeneous fleets; hand-built snapshots
    /// default to the uniform profile `{speed_mult: 1, mem_mult: 1}`.
    pub hardware: HardwareProfile,
}

impl InstanceView {
    /// Current token load N_i(B_i) (paper: Σ_r N(r)).
    pub fn token_load(&self) -> u64 {
        self.requests.iter().map(|r| r.tokens).sum()
    }

    pub fn effective_used(&self) -> u64 {
        self.token_load() + self.inbound_reserved_tokens + self.cached_tokens
    }

    pub fn free_tokens(&self) -> u64 {
        self.kv_capacity_tokens.saturating_sub(self.effective_used())
    }
}

/// A fully materialized point-in-time view of every decode instance.
/// Policies consume [`ClusterView`]s; this owned form is kept as the
/// compatibility path — assemble one by hand (tests, third-party
/// harnesses) and pass `snapshot.view()` to any policy.
#[derive(Clone, Debug, Default)]
pub struct ClusterSnapshot {
    pub instances: Vec<InstanceView>,
    /// Expected tokens generated per request per scheduling interval
    /// (interval_s / avg_iter_time): the time base for future-load sim.
    pub tokens_per_interval: f64,
}

impl ClusterSnapshot {
    pub fn n_instances(&self) -> usize {
        self.instances.len()
    }

    pub fn total_tokens(&self) -> u64 {
        self.instances.iter().map(|i| i.token_load()).sum()
    }

    /// Current cross-instance token-load variance σ₀² (paper Eq. 3).
    pub fn current_variance(&self) -> f64 {
        let loads: Vec<f64> = self
            .instances
            .iter()
            .map(|i| i.token_load() as f64)
            .collect();
        crate::metrics::snapshot_variance(&loads)
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    pub fn req(id: RequestId, tokens: u64, rem: Option<f64>) -> RequestView {
        RequestView {
            id,
            tokens,
            predicted_remaining: rem.map(Prediction::exact),
            migrating: false,
        }
    }

    pub fn inst(id: InstanceId, reqs: Vec<RequestView>, cap: u64) -> InstanceView {
        InstanceView {
            id,
            requests: reqs,
            kv_capacity_tokens: cap,
            inbound_reserved_tokens: 0,
            cached_tokens: 0,
            lifecycle: Lifecycle::default(),
            hardware: HardwareProfile::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::*;
    use super::*;

    #[test]
    fn token_load_sums_requests() {
        let i = inst(0, vec![req(1, 100, None), req(2, 50, None)], 1000);
        assert_eq!(i.token_load(), 150);
        assert_eq!(i.free_tokens(), 850);
    }

    #[test]
    fn inbound_reservation_reduces_headroom() {
        let mut i = inst(0, vec![req(1, 100, None)], 1000);
        i.inbound_reserved_tokens = 800;
        assert_eq!(i.free_tokens(), 100);
    }

    #[test]
    fn snapshot_variance_zero_when_balanced() {
        let s = ClusterSnapshot {
            instances: vec![
                inst(0, vec![req(1, 100, None)], 1000),
                inst(1, vec![req(2, 100, None)], 1000),
            ],
            tokens_per_interval: 10.0,
        };
        assert_eq!(s.current_variance(), 0.0);
        assert_eq!(s.total_tokens(), 200);
    }
}
