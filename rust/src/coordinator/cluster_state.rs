//! Incremental cluster state: the scheduler's source of truth, maintained
//! by O(1) deltas instead of rebuilt per decision.
//!
//! Before this module existed, every dispatch and every scheduler tick
//! materialized a full [`ClusterSnapshot`] from driver internals —
//! O(instances × requests) per decision, which is exactly the cost the
//! Fig. 13 large-scale runs (up to 256 decode instances, ≥50k requests)
//! cannot afford. [`ClusterState`] owns the per-instance aggregates the
//! policies consume (active KV tokens, batch size, summed predicted
//! remaining work, inbound-migration reservations, EWMA iteration time)
//! and is updated at the existing mutation points: admission, token
//! append, release, migration start/finish, and prediction refresh.
//!
//! Policies never see the state type directly; they receive a borrowed
//! [`ClusterView`], which is also constructible from a [`ClusterSnapshot`]
//! — the compatibility path for tests and third-party policies that
//! assemble snapshots by hand (`snapshot.view()`). `bench_sim_core`
//! quantifies the gap between the two paths.

use std::collections::BTreeMap;

use super::elastic::Lifecycle;
use super::{ClusterSnapshot, InstanceView, RequestView};
use crate::predictor::{normal_quantile, Prediction};
use crate::{InstanceId, RequestId};

/// Per-instance hardware class for heterogeneous fleets. A profile scales
/// the *modeled* execution substrate, not the policy code: `speed_mult`
/// divides the simulated decode iteration time (2.0 = twice as fast) and
/// `mem_mult` scales the instance's KV capacity at construction. The
/// default `{1.0, 1.0}` is a uniform fleet — every pre-existing scenario
/// is unchanged. Policies read the profile through [`InstanceRef`] (the
/// `hardware_aware` dispatch places long-prediction requests on
/// big-memory instances and normalizes load by speed class).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HardwareProfile {
    /// Relative decode speed (>0): modeled iteration time is divided by
    /// this, so 0.5 is a half-speed (degraded / older-generation) card.
    pub speed_mult: f64,
    /// Relative KV memory (>0): capacity is scaled by this at
    /// construction (then rounded to whole blocks by the allocator).
    pub mem_mult: f64,
}

impl Default for HardwareProfile {
    fn default() -> Self {
        HardwareProfile {
            speed_mult: 1.0,
            mem_mult: 1.0,
        }
    }
}

/// KV-token admission watermark (vLLM-style 10% growth headroom): an
/// instance admits a request only while `used + need` stays below this
/// fraction of capacity. Shared by the drivers' admission control and by
/// the reschedulers' destination-feasibility checks — a migration whose
/// KV footprint cannot pass the watermark on the destination could never
/// be re-admitted there and must not be decided in the first place.
pub fn admission_watermark(cap_tokens: u64) -> u64 {
    cap_tokens * 9 / 10
}

/// Per-instance aggregates plus the active-request membership list.
/// Membership is indexed (id → slot via [`ClusterState::index`]) so
/// release is O(1) swap-remove, not an O(batch) scan.
#[derive(Clone, Debug)]
pub struct InstanceStats {
    pub id: InstanceId,
    kv_capacity_tokens: u64,
    requests: Vec<RequestView>,
    /// Σ tokens over active requests (== [`InstanceView::token_load`]).
    active_tokens: u64,
    /// Σ predicted-remaining *means* over active requests (0 for
    /// unpredicted requests).
    predicted_sum: f64,
    /// Σ predicted-remaining *sigmas* over active requests — makes the
    /// quantile aggregate [`Self::predicted_work_q`] O(1)
    /// (Σ quantile_q(r) = Σ mean + z(q)·Σ σ).
    sigma_sum: f64,
    /// Tokens promised to migrations in flight toward this instance.
    inbound_reserved_tokens: u64,
    /// Idle prefix-cache KV resident on this instance (completed session
    /// turns retained for reuse, see `kvcache::PrefixCache`). Counted in
    /// [`Self::effective_used`] so admission, memory-pressure rescheduling,
    /// and the elastic scaler see cached bytes competing honestly with
    /// live requests. Always 0 under the `none` cache policy.
    cached_tokens: u64,
    ewma_iter_ms: f64,
    iters: u64,
    /// Elastic-pool lifecycle; only `Active` instances accept dispatches
    /// or migration arrivals (see `coordinator::elastic`).
    lifecycle: Lifecycle,
    /// Hardware class (heterogeneous fleets); default = uniform.
    hardware: HardwareProfile,
}

impl InstanceStats {
    fn new(id: InstanceId, kv_capacity_tokens: u64) -> Self {
        InstanceStats {
            id,
            kv_capacity_tokens,
            requests: Vec::new(),
            active_tokens: 0,
            predicted_sum: 0.0,
            sigma_sum: 0.0,
            inbound_reserved_tokens: 0,
            cached_tokens: 0,
            ewma_iter_ms: 0.0,
            iters: 0,
            lifecycle: Lifecycle::Active,
            hardware: HardwareProfile::default(),
        }
    }

    /// Current token load N_i(B_i), maintained incrementally.
    #[inline]
    pub fn token_load(&self) -> u64 {
        self.active_tokens
    }

    #[inline]
    pub fn batch_size(&self) -> usize {
        self.requests.len()
    }

    #[inline]
    pub fn kv_capacity_tokens(&self) -> u64 {
        self.kv_capacity_tokens
    }

    #[inline]
    pub fn inbound_reserved_tokens(&self) -> u64 {
        self.inbound_reserved_tokens
    }

    #[inline]
    pub fn cached_tokens(&self) -> u64 {
        self.cached_tokens
    }

    #[inline]
    pub fn effective_used(&self) -> u64 {
        self.active_tokens + self.inbound_reserved_tokens + self.cached_tokens
    }

    #[inline]
    pub fn free_tokens(&self) -> u64 {
        self.kv_capacity_tokens.saturating_sub(self.effective_used())
    }

    /// Projected work Σ (tokens + predicted remaining mean), the
    /// `predicted_load` dispatch score, in O(1).
    #[inline]
    pub fn predicted_work(&self) -> f64 {
        self.active_tokens as f64 + self.predicted_sum.max(0.0)
    }

    /// Quantile-`q` projected work: Σ tokens + Σ quantile_q(remaining)
    /// = tokens + (Σ mean + z(q)·Σ σ), in O(1). Intended for q ≥ 0.5
    /// (the conservative OOM-avoidance view); at q = 0.5 it equals
    /// [`Self::predicted_work`].
    #[inline]
    pub fn predicted_work_q(&self, q: f64) -> f64 {
        let proj = self.predicted_sum + normal_quantile(q) * self.sigma_sum;
        self.active_tokens as f64 + proj.max(0.0)
    }

    #[inline]
    pub fn ewma_iter_ms(&self) -> f64 {
        self.ewma_iter_ms
    }

    #[inline]
    pub fn iters(&self) -> u64 {
        self.iters
    }

    #[inline]
    pub fn requests(&self) -> &[RequestView] {
        &self.requests
    }

    #[inline]
    pub fn lifecycle(&self) -> Lifecycle {
        self.lifecycle
    }

    #[inline]
    pub fn hardware(&self) -> HardwareProfile {
        self.hardware
    }

    /// May this instance receive dispatches / migration arrivals?
    #[inline]
    pub fn is_schedulable(&self) -> bool {
        self.lifecycle == Lifecycle::Active
    }
}

/// Incremental cluster-state store shared by both drivers. All mutators
/// are O(1) (amortized, for the membership vectors); all aggregate reads
/// are O(1).
#[derive(Clone, Debug)]
pub struct ClusterState {
    instances: Vec<InstanceStats>,
    /// request id → (instance index, slot in its membership vector).
    index: BTreeMap<RequestId, (usize, usize)>,
    /// Scheduling interval (time base of `tokens_per_interval`).
    interval_s: f64,
    /// Assumed iteration time until any instance has measured one.
    seed_avg_iter_s: f64,
    /// Lower clamp on the average iteration time (driver-specific).
    iter_floor_s: f64,
    /// Σ ewma_iter_ms over instances with ewma > 0, and their count —
    /// makes `avg_iter_s` O(1).
    busy_ewma_sum_ms: f64,
    busy_count: usize,
}

impl ClusterState {
    pub fn new(
        n_instances: usize,
        kv_capacity_tokens: u64,
        interval_s: f64,
        seed_avg_iter_s: f64,
        iter_floor_s: f64,
    ) -> ClusterState {
        ClusterState {
            instances: (0..n_instances)
                .map(|id| InstanceStats::new(id, kv_capacity_tokens))
                .collect(),
            index: BTreeMap::new(),
            interval_s,
            seed_avg_iter_s,
            iter_floor_s,
            busy_ewma_sum_ms: 0.0,
            busy_count: 0,
        }
    }

    #[inline]
    pub fn n_instances(&self) -> usize {
        self.instances.len()
    }

    #[inline]
    pub fn stats(&self, di: usize) -> &InstanceStats {
        &self.instances[di]
    }

    /// Active requests of one instance (the simulator's decode batch).
    #[inline]
    pub fn active(&self, di: usize) -> &[RequestView] {
        &self.instances[di].requests
    }

    #[inline]
    pub fn contains(&self, id: RequestId) -> bool {
        self.index.contains_key(&id)
    }

    // -- mutation points ------------------------------------------------

    /// A request enters an instance's running batch.
    pub fn admit(
        &mut self,
        di: usize,
        id: RequestId,
        tokens: u64,
        predicted_remaining: Option<Prediction>,
    ) {
        debug_assert!(
            !self.index.contains_key(&id),
            "request {id} admitted twice into cluster state"
        );
        let inst = &mut self.instances[di];
        self.index.insert(id, (di, inst.requests.len()));
        inst.requests.push(RequestView {
            id,
            tokens,
            predicted_remaining,
            migrating: false,
        });
        inst.active_tokens += tokens;
        inst.predicted_sum += predicted_remaining.map_or(0.0, |p| p.mean);
        inst.sigma_sum += predicted_remaining.map_or(0.0, |p| p.sigma);
    }

    /// One generated token appended to `id`'s KV.
    pub fn append_token(&mut self, id: RequestId) {
        let &(di, slot) = self.index.get(&id).expect("append for untracked request");
        let inst = &mut self.instances[di];
        inst.requests[slot].tokens += 1;
        inst.active_tokens += 1;
    }

    /// Refresh `id`'s predicted remaining length (reprediction).
    pub fn set_prediction(&mut self, id: RequestId, predicted_remaining: Option<Prediction>) {
        let &(di, slot) = self.index.get(&id).expect("prediction for untracked request");
        let inst = &mut self.instances[di];
        let old = inst.requests[slot].predicted_remaining;
        inst.requests[slot].predicted_remaining = predicted_remaining;
        inst.predicted_sum +=
            predicted_remaining.map_or(0.0, |p| p.mean) - old.map_or(0.0, |p| p.mean);
        inst.sigma_sum +=
            predicted_remaining.map_or(0.0, |p| p.sigma) - old.map_or(0.0, |p| p.sigma);
    }

    /// Mark/unmark a tracked request as mid-migration (it stays in the
    /// batch view — live-serving semantics, where the slot holds the
    /// request until the KV is extracted). Untracked ids are ignored.
    pub fn set_migrating(&mut self, id: RequestId, migrating: bool) {
        if let Some(&(di, slot)) = self.index.get(&id) {
            self.instances[di].requests[slot].migrating = migrating;
        }
    }

    /// Remove a request from its batch (completion, eviction, or
    /// simulator-style migration start). O(1) swap-remove.
    pub fn release(&mut self, id: RequestId) -> Option<RequestView> {
        let (di, slot) = self.index.remove(&id)?;
        let inst = &mut self.instances[di];
        let view = inst.requests.swap_remove(slot);
        if let Some(moved) = inst.requests.get(slot) {
            self.index.insert(moved.id, (di, slot));
        }
        inst.active_tokens -= view.tokens;
        inst.predicted_sum -= view.predicted_remaining.map_or(0.0, |p| p.mean);
        inst.sigma_sum -= view.predicted_remaining.map_or(0.0, |p| p.sigma);
        Some(view)
    }

    /// Reserve headroom at `di` for a migration in flight toward it.
    pub fn reserve_inbound(&mut self, di: usize, tokens: u64) {
        self.instances[di].inbound_reserved_tokens += tokens;
    }

    /// Release a reservation made by [`Self::reserve_inbound`].
    pub fn release_inbound(&mut self, di: usize, tokens: u64) {
        let inst = &mut self.instances[di];
        debug_assert!(
            inst.inbound_reserved_tokens >= tokens,
            "releasing more inbound reservation than held on instance {}",
            inst.id
        );
        inst.inbound_reserved_tokens = inst.inbound_reserved_tokens.saturating_sub(tokens);
    }

    /// A completed-turn prefix was retained on `di` (its KV blocks stay
    /// resident while the session is away). Mirrors
    /// `kvcache::PrefixCache` insertions.
    pub fn add_cached(&mut self, di: usize, tokens: u64) {
        self.instances[di].cached_tokens += tokens;
    }

    /// A retained prefix left `di` (hit, eviction, expiry, or drain
    /// flush). Mirrors `kvcache::PrefixCache` removals.
    pub fn sub_cached(&mut self, di: usize, tokens: u64) {
        let inst = &mut self.instances[di];
        debug_assert!(
            inst.cached_tokens >= tokens,
            "releasing more cached tokens than held on instance {}",
            inst.id
        );
        inst.cached_tokens = inst.cached_tokens.saturating_sub(tokens);
    }

    /// Simulator-style migration start: the request leaves the source
    /// batch immediately and its current KV footprint is reserved on the
    /// destination. Returns the reserved token count.
    pub fn begin_migration(&mut self, id: RequestId, dst: usize) -> Option<u64> {
        let view = self.release(id)?;
        self.reserve_inbound(dst, view.tokens);
        Some(view.tokens)
    }

    /// Migration KV transfer finished: drop the destination reservation
    /// (the request re-enters through admission).
    pub fn finish_migration(&mut self, dst: usize, tokens: u64) {
        self.release_inbound(dst, tokens);
    }

    /// Record one scheduled decode iteration of length `iter_s` (EWMA
    /// 0.9/0.1, seeded by the first sample — unless the instance joined
    /// mid-run with a cluster-median seed ([`Self::add_instance`]), which
    /// the first real sample then *blends into* rather than overwrites).
    pub fn record_iteration(&mut self, di: usize, iter_s: f64) {
        let ms = iter_s * 1e3;
        let new = if self.instances[di].ewma_iter_ms <= 0.0 {
            ms
        } else {
            0.9 * self.instances[di].ewma_iter_ms + 0.1 * ms
        };
        self.set_iter_ewma(di, new);
    }

    /// An iteration completed (advances the EWMA seeding state).
    pub fn complete_iteration(&mut self, di: usize) {
        self.instances[di].iters += 1;
    }

    /// Overwrite an instance's EWMA iteration time (live driver: the
    /// instance thread measures and reports it).
    pub fn set_iter_ewma(&mut self, di: usize, ewma_ms: f64) {
        let old = self.instances[di].ewma_iter_ms;
        if old > 0.0 {
            self.busy_ewma_sum_ms -= old;
        } else if ewma_ms > 0.0 {
            self.busy_count += 1;
        }
        if ewma_ms > 0.0 {
            self.busy_ewma_sum_ms += ewma_ms;
        } else if old > 0.0 {
            self.busy_count -= 1;
        }
        self.instances[di].ewma_iter_ms = ewma_ms;
    }

    pub fn set_capacity(&mut self, di: usize, kv_capacity_tokens: u64) {
        self.instances[di].kv_capacity_tokens = kv_capacity_tokens;
    }

    /// Set an instance's elastic lifecycle (drives schedulability).
    pub fn set_lifecycle(&mut self, di: usize, lifecycle: Lifecycle) {
        self.instances[di].lifecycle = lifecycle;
    }

    /// Set an instance's hardware class (heterogeneous fleets). The
    /// profile is descriptive state for policies; capacity/iteration
    /// scaling is applied by the drivers at construction.
    pub fn set_profile(&mut self, di: usize, hardware: HardwareProfile) {
        self.instances[di].hardware = hardware;
    }

    #[inline]
    pub fn lifecycle(&self, di: usize) -> Lifecycle {
        self.instances[di].lifecycle
    }

    /// Register a decode instance joining mid-run (elastic provision or
    /// prefill→decode flip). Its iteration-time EWMA is seeded from the
    /// cluster *median* of instances with live measurements — a fresh
    /// instance must not fall back to the global construction-time
    /// `initial_avg_iter_s` when the cluster already knows better.
    /// Returns the new instance's id.
    pub fn add_instance(&mut self, kv_capacity_tokens: u64) -> InstanceId {
        let id = self.instances.len();
        self.instances
            .push(InstanceStats::new(id, kv_capacity_tokens));
        if let Some(m) = self.median_busy_ewma_ms() {
            self.set_iter_ewma(id, m);
        }
        id
    }

    /// Median EWMA iteration time (ms) over instances with at least one
    /// measurement; `None` before any instance has measured.
    pub fn median_busy_ewma_ms(&self) -> Option<f64> {
        let mut busy: Vec<f64> = self
            .instances
            .iter()
            .filter(|s| s.ewma_iter_ms > 0.0)
            .map(|s| s.ewma_iter_ms)
            .collect();
        if busy.is_empty() {
            return None;
        }
        busy.sort_by(|a, b| a.total_cmp(b));
        let n = busy.len();
        Some(if n % 2 == 1 {
            busy[n / 2]
        } else {
            0.5 * (busy[n / 2 - 1] + busy[n / 2])
        })
    }

    /// Replace one instance's membership wholesale from an authoritative
    /// report (live driver reconciliation). O(reported slots).
    pub fn sync_instance(&mut self, di: usize, requests: Vec<RequestView>) {
        // drop index entries that still point at this instance
        for r in &self.instances[di].requests {
            if self.index.get(&r.id).map(|&(i, _)| i) == Some(di) {
                self.index.remove(&r.id);
            }
        }
        let inst = &mut self.instances[di];
        inst.active_tokens = requests.iter().map(|r| r.tokens).sum();
        inst.predicted_sum = requests
            .iter()
            .map(|r| r.predicted_remaining.map_or(0.0, |p| p.mean))
            .sum();
        inst.sigma_sum = requests
            .iter()
            .map(|r| r.predicted_remaining.map_or(0.0, |p| p.sigma))
            .sum();
        inst.requests = requests;
        for (slot, r) in self.instances[di].requests.iter().enumerate() {
            self.index.insert(r.id, (di, slot));
        }
    }

    // -- derived aggregates ---------------------------------------------

    /// Mean EWMA iteration time over instances that have measured one;
    /// the construction-time seed until then. O(1).
    pub fn avg_iter_s(&self) -> f64 {
        if self.busy_count == 0 {
            self.seed_avg_iter_s
        } else {
            (self.busy_ewma_sum_ms / self.busy_count as f64) / 1e3
        }
    }

    /// Expected tokens generated per request per scheduling interval —
    /// the time base the future-load projections run on.
    pub fn tokens_per_interval(&self) -> f64 {
        self.interval_s / self.avg_iter_s().max(self.iter_floor_s)
    }

    /// Borrowed, allocation-free view for policy decisions.
    #[inline]
    pub fn view(&self) -> ClusterView<'_> {
        ClusterView {
            src: ViewSrc::State(self),
        }
    }

    /// Compatibility materialization: the full [`ClusterSnapshot`] this
    /// state denotes. O(instances × requests) — for tests, third-party
    /// consumers, and the `bench_sim_core` baseline; the hot paths use
    /// [`Self::view`].
    pub fn snapshot(&self) -> ClusterSnapshot {
        ClusterSnapshot {
            instances: self
                .instances
                .iter()
                .map(|s| InstanceView {
                    id: s.id,
                    requests: s.requests.clone(),
                    kv_capacity_tokens: s.kv_capacity_tokens,
                    inbound_reserved_tokens: s.inbound_reserved_tokens,
                    cached_tokens: s.cached_tokens,
                    lifecycle: s.lifecycle,
                    hardware: s.hardware,
                })
                .collect(),
            tokens_per_interval: self.tokens_per_interval(),
        }
    }

    /// Differential check: first discrepancy between this incremental
    /// state and a from-scratch `reference` snapshot, or `None` when they
    /// agree. Membership is compared as id-sets (orders legitimately
    /// differ); float aggregates use a relative epsilon (delta updates
    /// accumulate rounding the from-scratch sum does not).
    pub fn consistency_diff(&self, reference: &ClusterSnapshot) -> Option<String> {
        if reference.instances.len() != self.instances.len() {
            return Some(format!(
                "instance count: state {} vs reference {}",
                self.instances.len(),
                reference.instances.len()
            ));
        }
        for (s, r) in self.instances.iter().zip(&reference.instances) {
            if s.id != r.id {
                return Some(format!("instance id {} vs {}", s.id, r.id));
            }
            if s.kv_capacity_tokens != r.kv_capacity_tokens {
                return Some(format!(
                    "instance {}: capacity {} vs {}",
                    s.id, s.kv_capacity_tokens, r.kv_capacity_tokens
                ));
            }
            if s.inbound_reserved_tokens != r.inbound_reserved_tokens {
                return Some(format!(
                    "instance {}: inbound reserved {} vs {}",
                    s.id, s.inbound_reserved_tokens, r.inbound_reserved_tokens
                ));
            }
            if s.cached_tokens != r.cached_tokens {
                return Some(format!(
                    "instance {}: cached tokens {} vs {}",
                    s.id, s.cached_tokens, r.cached_tokens
                ));
            }
            if s.lifecycle != r.lifecycle {
                return Some(format!(
                    "instance {}: lifecycle {:?} vs {:?}",
                    s.id, s.lifecycle, r.lifecycle
                ));
            }
            if s.hardware != r.hardware {
                return Some(format!(
                    "instance {}: hardware {:?} vs {:?}",
                    s.id, s.hardware, r.hardware
                ));
            }
            if s.requests.len() != r.requests.len() {
                return Some(format!(
                    "instance {}: batch size {} vs {}",
                    s.id,
                    s.requests.len(),
                    r.requests.len()
                ));
            }
            let mut mine: Vec<&RequestView> = s.requests.iter().collect();
            let mut theirs: Vec<&RequestView> = r.requests.iter().collect();
            mine.sort_by_key(|v| v.id);
            theirs.sort_by_key(|v| v.id);
            for (a, b) in mine.iter().zip(&theirs) {
                if a.id != b.id || a.tokens != b.tokens || a.migrating != b.migrating {
                    return Some(format!("instance {}: request {:?} vs {:?}", s.id, a, b));
                }
                let close = |x: f64, y: f64| (x - y).abs() <= 1e-9;
                let agree = match (a.predicted_remaining, b.predicted_remaining) {
                    (None, None) => true,
                    (Some(pa), Some(pb)) => close(pa.mean, pb.mean) && close(pa.sigma, pb.sigma),
                    _ => false,
                };
                if !agree {
                    return Some(format!(
                        "instance {}: request {} prediction {:?} vs {:?}",
                        s.id, a.id, a.predicted_remaining, b.predicted_remaining
                    ));
                }
            }
            // aggregates vs from-scratch sums over the reference
            let load: u64 = r.requests.iter().map(|v| v.tokens).sum();
            if s.active_tokens != load {
                return Some(format!(
                    "instance {}: active_tokens {} vs recomputed {}",
                    s.id, s.active_tokens, load
                ));
            }
            let pred: f64 = r
                .requests
                .iter()
                .map(|v| v.predicted_remaining.map_or(0.0, |p| p.mean))
                .sum();
            if (s.predicted_sum - pred).abs() > 1e-6 * pred.abs().max(1.0) {
                return Some(format!(
                    "instance {}: predicted_sum {} vs recomputed {}",
                    s.id, s.predicted_sum, pred
                ));
            }
            let sig: f64 = r
                .requests
                .iter()
                .map(|v| v.predicted_remaining.map_or(0.0, |p| p.sigma))
                .sum();
            if (s.sigma_sum - sig).abs() > 1e-6 * sig.abs().max(1.0) {
                return Some(format!(
                    "instance {}: sigma_sum {} vs recomputed {}",
                    s.id, s.sigma_sum, sig
                ));
            }
        }
        // EWMA aggregate vs recomputation
        let busy: Vec<f64> = self
            .instances
            .iter()
            .filter(|s| s.ewma_iter_ms > 0.0)
            .map(|s| s.ewma_iter_ms)
            .collect();
        let want = if busy.is_empty() {
            self.seed_avg_iter_s
        } else {
            busy.iter().sum::<f64>() / busy.len() as f64 / 1e3
        };
        let got = self.avg_iter_s();
        if (got - want).abs() > 1e-6 * want.abs().max(1e-12) {
            return Some(format!("avg_iter_s {got} vs recomputed {want}"));
        }
        None
    }

    // -- shard-sliced views (sim::shard epoch merge) --------------------

    /// Aggregate the instance group `{ i : i % n_shards == shard }` —
    /// one shard's slice of the decode fleet, as the epoch barrier sees
    /// it. Instances are visited in ascending id order, so the float
    /// sums are deterministic.
    pub fn shard_aggregate(&self, shard: usize, n_shards: usize) -> ShardAggregate {
        debug_assert!(n_shards >= 1 && shard < n_shards);
        let mut agg = ShardAggregate {
            shard,
            ..Default::default()
        };
        for s in self.instances.iter().skip(shard).step_by(n_shards) {
            agg.instances += 1;
            match s.lifecycle {
                Lifecycle::Active => agg.active += 1,
                Lifecycle::Draining => agg.draining += 1,
                _ => {}
            }
            agg.batch += s.batch_size();
            agg.token_load += s.token_load();
            agg.free_tokens += s.free_tokens();
            agg.cached_tokens += s.cached_tokens();
            agg.predicted_work += s.predicted_work();
        }
        agg
    }

    /// Per-shard aggregates merged in fixed shard order (shard 0 first)
    /// — the deterministic epoch merge the sharded simulator runs
    /// before every `ControlLoop` decision. The same partition with the
    /// same state always produces the same rollup, independent of event
    /// arrival order inside the shards.
    pub fn shard_rollup(&self, n_shards: usize) -> ShardRollup {
        let shards: Vec<ShardAggregate> = (0..n_shards)
            .map(|s| self.shard_aggregate(s, n_shards))
            .collect();
        let mut total = ShardAggregate {
            shard: usize::MAX,
            ..Default::default()
        };
        for a in &shards {
            total.instances += a.instances;
            total.active += a.active;
            total.draining += a.draining;
            total.batch += a.batch;
            total.token_load += a.token_load;
            total.free_tokens += a.free_tokens;
            total.cached_tokens += a.cached_tokens;
            total.predicted_work += a.predicted_work;
        }
        ShardRollup { shards, total }
    }
}

/// One shard's aggregate of the decode fleet (the instance group
/// `id % n_shards == shard`): the numbers the coordinator needs from a
/// shard at an epoch barrier, without touching per-request state.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ShardAggregate {
    /// Shard id (`usize::MAX` on the merged total, which has no single
    /// home shard).
    pub shard: usize,
    /// Instances in this shard's slice (all lifecycles).
    pub instances: usize,
    /// `Active` instances.
    pub active: usize,
    /// `Draining` instances.
    pub draining: usize,
    /// Σ batch size over the slice.
    pub batch: usize,
    /// Σ active KV tokens over the slice.
    pub token_load: u64,
    /// Σ free tokens (capacity − effective use) over the slice.
    pub free_tokens: u64,
    /// Σ idle prefix-cache tokens over the slice.
    pub cached_tokens: u64,
    /// Σ predicted work (tokens + predicted remaining mean).
    pub predicted_work: f64,
}

/// Deterministic merge of all shard aggregates: per-shard rows in fixed
/// shard order plus their fold. Built by [`ClusterState::shard_rollup`]
/// at every scheduling epoch of the sharded simulator.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardRollup {
    /// One aggregate per shard, indexed by shard id.
    pub shards: Vec<ShardAggregate>,
    /// Fold of `shards` in ascending shard order.
    pub total: ShardAggregate,
}

// ---------------------------------------------------------------------
// borrowed views

/// What a policy sees: either the incremental state (hot path) or a
/// materialized snapshot (compatibility path). Cheap to copy.
#[derive(Clone, Copy)]
pub struct ClusterView<'a> {
    src: ViewSrc<'a>,
}

#[derive(Clone, Copy)]
enum ViewSrc<'a> {
    State(&'a ClusterState),
    Snap(&'a ClusterSnapshot),
}

impl ClusterSnapshot {
    /// View a hand-assembled snapshot — the compatibility entry point for
    /// tests and third-party policy harnesses.
    #[inline]
    pub fn view(&self) -> ClusterView<'_> {
        ClusterView {
            src: ViewSrc::Snap(self),
        }
    }
}

impl InstanceView {
    /// View one hand-assembled instance (compatibility path).
    #[inline]
    pub fn view(&self) -> InstanceRef<'_> {
        InstanceRef(RefSrc::Snap(self))
    }
}

impl<'a> ClusterView<'a> {
    pub fn n_instances(&self) -> usize {
        match self.src {
            ViewSrc::State(s) => s.instances.len(),
            ViewSrc::Snap(s) => s.instances.len(),
        }
    }

    pub fn tokens_per_interval(&self) -> f64 {
        match self.src {
            ViewSrc::State(s) => s.tokens_per_interval(),
            ViewSrc::Snap(s) => s.tokens_per_interval,
        }
    }

    pub fn instance(&self, idx: usize) -> InstanceRef<'a> {
        match self.src {
            ViewSrc::State(s) => InstanceRef(RefSrc::State(&s.instances[idx])),
            ViewSrc::Snap(s) => InstanceRef(RefSrc::Snap(&s.instances[idx])),
        }
    }

    pub fn instances(&self) -> impl Iterator<Item = InstanceRef<'a>> + '_ {
        (0..self.n_instances()).map(|i| self.instance(i))
    }

    /// Materialize the full snapshot (compatibility; allocates).
    pub fn materialize(&self) -> ClusterSnapshot {
        match self.src {
            ViewSrc::State(s) => s.snapshot(),
            ViewSrc::Snap(s) => s.clone(),
        }
    }
}

/// One instance as a policy sees it. Aggregate accessors are O(1) when
/// backed by [`ClusterState`] and recomputed when backed by a snapshot.
#[derive(Clone, Copy)]
pub struct InstanceRef<'a>(RefSrc<'a>);

#[derive(Clone, Copy)]
enum RefSrc<'a> {
    State(&'a InstanceStats),
    Snap(&'a InstanceView),
}

impl<'a> InstanceRef<'a> {
    pub fn id(&self) -> InstanceId {
        match self.0 {
            RefSrc::State(s) => s.id,
            RefSrc::Snap(s) => s.id,
        }
    }

    pub fn requests(&self) -> &'a [RequestView] {
        match self.0 {
            RefSrc::State(s) => &s.requests,
            RefSrc::Snap(s) => &s.requests,
        }
    }

    pub fn kv_capacity_tokens(&self) -> u64 {
        match self.0 {
            RefSrc::State(s) => s.kv_capacity_tokens,
            RefSrc::Snap(s) => s.kv_capacity_tokens,
        }
    }

    pub fn inbound_reserved_tokens(&self) -> u64 {
        match self.0 {
            RefSrc::State(s) => s.inbound_reserved_tokens,
            RefSrc::Snap(s) => s.inbound_reserved_tokens,
        }
    }

    /// Idle prefix-cache KV resident on this instance (0 with the cache
    /// off); already included in [`Self::effective_used`].
    pub fn cached_tokens(&self) -> u64 {
        match self.0 {
            RefSrc::State(s) => s.cached_tokens,
            RefSrc::Snap(s) => s.cached_tokens,
        }
    }

    pub fn token_load(&self) -> u64 {
        match self.0 {
            RefSrc::State(s) => s.token_load(),
            RefSrc::Snap(s) => s.token_load(),
        }
    }

    pub fn batch_size(&self) -> usize {
        match self.0 {
            RefSrc::State(s) => s.batch_size(),
            RefSrc::Snap(s) => s.requests.len(),
        }
    }

    pub fn effective_used(&self) -> u64 {
        match self.0 {
            RefSrc::State(s) => s.effective_used(),
            RefSrc::Snap(s) => s.effective_used(),
        }
    }

    pub fn free_tokens(&self) -> u64 {
        match self.0 {
            RefSrc::State(s) => s.free_tokens(),
            RefSrc::Snap(s) => s.free_tokens(),
        }
    }

    /// Σ (tokens + predicted remaining mean) — the `predicted_load` score.
    pub fn predicted_work(&self) -> f64 {
        match self.0 {
            RefSrc::State(s) => s.predicted_work(),
            RefSrc::Snap(s) => s
                .requests
                .iter()
                .map(|r| r.tokens as f64 + r.remaining_or(0.0))
                .sum(),
        }
    }

    /// Quantile-`q` projected work: tokens + (Σ mean + z(q)·Σ σ), the
    /// conservative planning view `elastic::predictive` consumes. O(1) on
    /// state-backed views; the snapshot path computes the identical
    /// formula, so the two backings agree exactly.
    pub fn predicted_work_q(&self, q: f64) -> f64 {
        match self.0 {
            RefSrc::State(s) => s.predicted_work_q(q),
            RefSrc::Snap(s) => {
                let (mean, sigma) = s.requests.iter().fold((0.0f64, 0.0f64), |(m, sg), r| {
                    match r.predicted_remaining {
                        Some(p) => (m + p.mean, sg + p.sigma),
                        None => (m, sg),
                    }
                });
                let proj = mean + crate::predictor::normal_quantile(q) * sigma;
                s.token_load() as f64 + proj.max(0.0)
            }
        }
    }

    /// Elastic-pool lifecycle (hand-built snapshots default to `Active`).
    pub fn lifecycle(&self) -> Lifecycle {
        match self.0 {
            RefSrc::State(s) => s.lifecycle,
            RefSrc::Snap(s) => s.lifecycle,
        }
    }

    /// Hardware class (hand-built snapshots default to the uniform
    /// profile, so homogeneous-fleet policies never notice the field).
    pub fn hardware(&self) -> HardwareProfile {
        match self.0 {
            RefSrc::State(s) => s.hardware,
            RefSrc::Snap(s) => s.hardware,
        }
    }

    /// May this instance receive dispatches / migration arrivals? Every
    /// placement decision (dispatch, migration destination) must respect
    /// this — a `Draining` instance finishes its residents and nothing
    /// else.
    pub fn is_schedulable(&self) -> bool {
        self.lifecycle() == Lifecycle::Active
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> ClusterState {
        ClusterState::new(3, 10_000, 1.0, 0.02, 1e-6)
    }

    /// Exact (zero-spread) prediction literal for the admission tests.
    fn pr(v: f64) -> Option<Prediction> {
        Some(Prediction::exact(v))
    }

    #[test]
    fn admit_append_release_roundtrip() {
        let mut st = state();
        st.admit(0, 1, 100, pr(50.0));
        st.admit(0, 2, 200, None);
        assert_eq!(st.stats(0).token_load(), 300);
        assert_eq!(st.stats(0).batch_size(), 2);
        assert!((st.stats(0).predicted_work() - 350.0).abs() < 1e-9);
        st.append_token(1);
        assert_eq!(st.stats(0).token_load(), 301);
        let v = st.release(1).unwrap();
        assert_eq!(v.tokens, 101);
        assert_eq!(st.stats(0).token_load(), 200);
        assert_eq!(st.stats(0).batch_size(), 1);
        assert!(!st.contains(1));
        assert!(st.contains(2));
    }

    #[test]
    fn swap_remove_keeps_index_coherent() {
        let mut st = state();
        for id in 0..5u64 {
            st.admit(1, id, 10 + id, None);
        }
        st.release(0); // request 4 swaps into slot 0
        st.append_token(4);
        let r4 = st.active(1).iter().find(|r| r.id == 4).unwrap();
        assert_eq!(r4.tokens, 15);
        assert_eq!(st.stats(1).token_load(), 11 + 12 + 13 + 15);
    }

    #[test]
    fn migration_moves_reservation_not_load() {
        let mut st = state();
        st.admit(0, 7, 500, pr(100.0));
        let moved = st.begin_migration(7, 2).unwrap();
        assert_eq!(moved, 500);
        assert_eq!(st.stats(0).token_load(), 0);
        assert_eq!(st.stats(2).token_load(), 0);
        assert_eq!(st.stats(2).inbound_reserved_tokens(), 500);
        assert_eq!(st.stats(2).free_tokens(), 9_500);
        st.finish_migration(2, moved);
        assert_eq!(st.stats(2).inbound_reserved_tokens(), 0);
        // re-admission on the destination completes the move
        st.admit(2, 7, 500, pr(100.0));
        assert_eq!(st.stats(2).token_load(), 500);
    }

    #[test]
    fn prediction_refresh_is_a_delta() {
        let mut st = state();
        st.admit(0, 1, 100, pr(40.0));
        st.set_prediction(1, pr(90.0));
        assert!((st.stats(0).predicted_work() - 190.0).abs() < 1e-9);
        st.set_prediction(1, None);
        assert!((st.stats(0).predicted_work() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn avg_iter_tracks_busy_instances_only() {
        let mut st = state();
        assert!((st.avg_iter_s() - 0.02).abs() < 1e-12, "seed before data");
        st.record_iteration(0, 0.010);
        assert!((st.avg_iter_s() - 0.010).abs() < 1e-12);
        st.record_iteration(1, 0.030);
        assert!((st.avg_iter_s() - 0.020).abs() < 1e-12);
        st.complete_iteration(0);
        st.record_iteration(0, 0.020); // EWMA: 0.9*10 + 0.1*20 = 11 ms
        assert!((st.stats(0).ewma_iter_ms() - 11.0).abs() < 1e-9);
    }

    #[test]
    fn view_and_snapshot_agree() {
        let mut st = state();
        st.admit(0, 1, 100, pr(50.0));
        st.admit(1, 2, 300, None);
        st.reserve_inbound(2, 64);
        let snap = st.snapshot();
        assert!(st.consistency_diff(&snap).is_none());
        let v = st.view();
        let sv = snap.view();
        for i in 0..3 {
            assert_eq!(v.instance(i).token_load(), sv.instance(i).token_load());
            assert_eq!(v.instance(i).free_tokens(), sv.instance(i).free_tokens());
            assert_eq!(
                v.instance(i).inbound_reserved_tokens(),
                sv.instance(i).inbound_reserved_tokens()
            );
            assert!(
                (v.instance(i).predicted_work() - sv.instance(i).predicted_work()).abs() < 1e-9
            );
        }
        assert_eq!(v.n_instances(), sv.n_instances());
        assert!((v.tokens_per_interval() - sv.tokens_per_interval()).abs() < 1e-9);
    }

    #[test]
    fn cached_tokens_compete_through_effective_used() {
        let mut st = state();
        st.admit(0, 1, 100, None);
        st.add_cached(0, 4_000);
        assert_eq!(st.stats(0).cached_tokens(), 4_000);
        assert_eq!(st.stats(0).effective_used(), 4_100);
        assert_eq!(st.stats(0).free_tokens(), 10_000 - 4_100);
        // the snapshot path carries the same aggregate
        let snap = st.snapshot();
        assert!(st.consistency_diff(&snap).is_none());
        assert_eq!(snap.view().instance(0).cached_tokens(), 4_000);
        assert_eq!(snap.view().instance(0).effective_used(), 4_100);
        // drift in the mirrored total is caught
        let mut bad = st.snapshot();
        bad.instances[0].cached_tokens = 0;
        assert!(st.consistency_diff(&bad).is_some());
        st.sub_cached(0, 4_000);
        assert_eq!(st.stats(0).effective_used(), 100);
    }

    #[test]
    fn shard_aggregates_partition_the_fleet() {
        let mut st = ClusterState::new(7, 10_000, 1.0, 0.02, 1e-6);
        for id in 0..7u64 {
            st.admit(id as usize, id, 100 + id, pr(10.0 * (id + 1) as f64));
        }
        st.set_lifecycle(3, Lifecycle::Draining);
        st.set_lifecycle(5, Lifecycle::Failed);
        st.add_cached(2, 1_000);
        for n in [1usize, 2, 3, 4, 7] {
            let roll = st.shard_rollup(n);
            assert_eq!(roll.shards.len(), n);
            // every instance lands in exactly one shard slice
            assert_eq!(roll.total.instances, 7, "n={n}");
            assert_eq!(roll.total.active, 5, "n={n}");
            assert_eq!(roll.total.draining, 1, "n={n}");
            assert_eq!(roll.total.batch, 7, "n={n}");
            let direct_load: u64 = (0..7).map(|i| st.stats(i).token_load()).sum();
            let direct_free: u64 = (0..7).map(|i| st.stats(i).free_tokens()).sum();
            let direct_work: f64 = (0..7).map(|i| st.stats(i).predicted_work()).sum();
            assert_eq!(roll.total.token_load, direct_load, "n={n}");
            assert_eq!(roll.total.free_tokens, direct_free, "n={n}");
            assert_eq!(roll.total.cached_tokens, 1_000, "n={n}");
            assert!((roll.total.predicted_work - direct_work).abs() < 1e-9, "n={n}");
            for (s, a) in roll.shards.iter().enumerate() {
                assert_eq!(a.shard, s);
                let ids: Vec<usize> = (s..7).step_by(n).collect();
                assert_eq!(a.instances, ids.len());
            }
        }
    }

    #[test]
    fn shard_rollup_is_reproducible() {
        let mut st = state();
        st.admit(0, 1, 100, pr(50.0));
        st.admit(1, 2, 300, None);
        let a = st.shard_rollup(2);
        let b = st.shard_rollup(2);
        assert_eq!(a, b, "same state + partition must merge identically");
        assert_eq!(a.shards[0].shard, 0);
        assert_eq!(a.total.shard, usize::MAX);
    }

    #[test]
    fn consistency_diff_catches_drift() {
        let mut st = state();
        st.admit(0, 1, 100, None);
        let mut snap = st.snapshot();
        snap.instances[0].requests[0].tokens = 101;
        assert!(st.consistency_diff(&snap).is_some());
    }

    #[test]
    fn new_instance_seeds_ewma_from_cluster_median() {
        let mut st = state();
        // no measurements yet: a new instance starts unmeasured and the
        // cluster average stays on the construction-time seed
        let a = st.add_instance(10_000);
        assert_eq!(a, 3);
        assert_eq!(st.stats(a).ewma_iter_ms(), 0.0);
        assert!(st.median_busy_ewma_ms().is_none());
        // three live measurements: median of {10, 30, 80} = 30 ms
        st.record_iteration(0, 0.010);
        st.record_iteration(1, 0.030);
        st.record_iteration(2, 0.080);
        let b = st.add_instance(10_000);
        assert!((st.stats(b).ewma_iter_ms() - 30.0).abs() < 1e-9);
        // the seeded value participates in avg_iter_s immediately
        let avg = st.avg_iter_s();
        assert!((avg - (10.0 + 30.0 + 80.0 + 30.0) / 4.0 / 1e3).abs() < 1e-12);
        // the first real sample BLENDS into the seed (0.9·30 + 0.1·50)
        st.record_iteration(b, 0.050);
        assert!((st.stats(b).ewma_iter_ms() - 32.0).abs() < 1e-9);
        // even-count median: {10, 30} -> 20 ms
        let mut st = state();
        st.record_iteration(0, 0.010);
        st.record_iteration(1, 0.030);
        assert!((st.median_busy_ewma_ms().unwrap() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn lifecycle_flows_through_views_and_snapshots() {
        use crate::coordinator::elastic::Lifecycle;
        let mut st = state();
        assert!(st.view().instance(1).is_schedulable());
        st.set_lifecycle(1, Lifecycle::Draining);
        assert_eq!(st.lifecycle(1), Lifecycle::Draining);
        assert!(!st.view().instance(1).is_schedulable());
        let snap = st.snapshot();
        assert_eq!(snap.instances[1].lifecycle, Lifecycle::Draining);
        assert!(!snap.view().instance(1).is_schedulable());
        assert!(st.consistency_diff(&snap).is_none());
        // lifecycle drift is caught by the differential check
        let mut bad = st.snapshot();
        bad.instances[1].lifecycle = Lifecycle::Active;
        assert!(st.consistency_diff(&bad).is_some());
    }

    #[test]
    fn hardware_profile_flows_through_views_and_snapshots() {
        let mut st = state();
        assert_eq!(st.stats(0).hardware(), HardwareProfile::default());
        let degraded = HardwareProfile {
            speed_mult: 0.5,
            mem_mult: 0.75,
        };
        st.set_profile(0, degraded);
        assert_eq!(st.view().instance(0).hardware(), degraded);
        assert_eq!(st.view().instance(1).hardware(), HardwareProfile::default());
        let snap = st.snapshot();
        assert_eq!(snap.instances[0].hardware, degraded);
        assert_eq!(snap.view().instance(0).hardware(), degraded);
        assert!(st.consistency_diff(&snap).is_none());
        // profile drift is caught by the differential check
        let mut bad = st.snapshot();
        bad.instances[0].hardware = HardwareProfile::default();
        assert!(st.consistency_diff(&bad).is_some());
    }

    #[test]
    fn sync_instance_reconciles_membership() {
        let mut st = state();
        st.admit(0, 1, 100, None);
        st.admit(0, 2, 200, pr(10.0));
        st.sync_instance(
            0,
            vec![
                RequestView {
                    id: 2,
                    tokens: 250,
                    predicted_remaining: pr(5.0),
                    migrating: true,
                },
                RequestView {
                    id: 3,
                    tokens: 40,
                    predicted_remaining: None,
                    migrating: false,
                },
            ],
        );
        assert!(!st.contains(1));
        assert_eq!(st.stats(0).token_load(), 290);
        assert!((st.stats(0).predicted_work() - 295.0).abs() < 1e-9);
        let snap = st.snapshot();
        assert!(st.consistency_diff(&snap).is_none());
        // a request that moved instances: the new owner's sync wins, the
        // old owner's later sync must not evict the fresh index entry
        st.admit(1, 9, 10, None);
        let moved = RequestView {
            id: 9,
            tokens: 12,
            predicted_remaining: None,
            migrating: false,
        };
        st.sync_instance(2, vec![moved]);
        st.sync_instance(1, vec![]);
        assert!(st.contains(9));
        st.append_token(9);
        assert_eq!(st.stats(2).token_load(), 13);
    }
}
