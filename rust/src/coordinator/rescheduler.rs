//! The STAR decode rescheduler — paper Algorithm 1.
//!
//! Three phases per scheduling interval:
//!   1. **Instance classification** (lines 11–16): overloaded = weighted
//!      future workload above `(1+θ)·w̄`; underloaded = *current* load
//!      below the same threshold (asymmetric by design: sources are picked
//!      on where load is going, targets on where memory is now).
//!   2. **Candidate enumeration** (lines 17–23): per (src,dst) pair keep
//!      requests whose predicted remaining work amortizes the migration
//!      (`N̂(r) > C_mig/T̄_exec`) and whose arrival keeps the target
//!      memory-safe over the horizon.
//!   3. **Best-feasible selection** (lines 24–34): evaluate each candidate
//!      by the reduction of time-weighted token-load variance (Eq. 4),
//!      computed incrementally in O(H) per candidate from the worker-side
//!      pre-simulations (the paper's optimized complexity).
//!
//! One normalization departure from the paper's notation: we divide the
//! weighted workload by Σβ so `w_i` stays in token units and is directly
//! comparable with the current-load threshold of line 15 (the paper mixes
//! the two scales implicitly).

use std::time::Instant;

use super::cluster_state::{admission_watermark, ClusterView, InstanceRef};
use super::future_load::{beta_schedule, FutureLoad, WorkerReport};
use super::policy::{PolicyConfig, ReschedulePolicy};
use crate::config::ReschedulerConfig;
use crate::costmodel::MigrationCostModel;
use crate::{InstanceId, RequestId};

/// One migration chosen by the rescheduler.
#[derive(Clone, Debug, PartialEq)]
pub struct MigrationDecision {
    pub request: RequestId,
    pub src: InstanceId,
    pub dst: InstanceId,
    /// KV tokens to transfer (current N(r)).
    pub kv_tokens: u64,
    /// Expected reduction of the Eq. 4 objective.
    pub var_reduction: f64,
}

/// Operational counters (exposed by benches; §5.2's <300 ms claim is
/// checked against `last_decision_us`).
#[derive(Clone, Debug, Default)]
pub struct ReschedulerStats {
    pub intervals: u64,
    pub migrations: u64,
    pub candidates_evaluated: u64,
    pub last_decision_us: u64,
    pub max_decision_us: u64,
}

/// The scheduler-side of Algorithm 1. Pure w.r.t. the snapshot: the caller
/// (live runtime or simulator) executes the returned decisions.
#[derive(Clone, Debug)]
pub struct Rescheduler {
    pub cfg: ReschedulerConfig,
    betas: Vec<f64>,
    beta_sum: f64,
    pub migration: MigrationCostModel,
    /// Average decode iteration time T̄_exec (updated by the caller from
    /// measurements; seeds from [`ReschedulerConfig::initial_avg_iter_s`]).
    pub avg_iter_s: f64,
    /// Whether predictions are available (Alg. 1 `usePrediction`).
    pub use_prediction: bool,
    /// Assumed remaining length when prediction is off but a number is
    /// still needed for the amortization check (seeds from
    /// [`ReschedulerConfig::default_remaining`]; the caller refines it to
    /// the workload's running mean output length).
    pub default_remaining: f64,
    /// Estimate quantile the balancing objective reads (0.5 = mean; see
    /// `[predictor] balance_q`).
    pub balance_q: f64,
    /// Estimate quantile the memory-safety checks read (p90 by default;
    /// see `[predictor] conservative_q`).
    pub conservative_q: f64,
    pub stats: ReschedulerStats,
}

impl Rescheduler {
    pub fn new(cfg: ReschedulerConfig, migration: MigrationCostModel, use_prediction: bool) -> Self {
        let betas = beta_schedule(cfg.horizon, cfg.beta_decay);
        let beta_sum: f64 = betas.iter().sum();
        let avg_iter_s = cfg.initial_avg_iter_s;
        let default_remaining = cfg.default_remaining;
        Rescheduler {
            cfg,
            betas,
            beta_sum: beta_sum.max(1e-12),
            migration,
            avg_iter_s,
            use_prediction,
            default_remaining,
            balance_q: 0.5,
            conservative_q: 0.9,
            stats: ReschedulerStats::default(),
        }
    }

    /// Build from a [`PolicyConfig`] — the registry path, which also
    /// wires the configured estimate quantiles in.
    pub fn from_config(cfg: &PolicyConfig) -> Self {
        let mut rs = Rescheduler::new(cfg.rescheduler.clone(), cfg.migration, cfg.use_prediction);
        rs.balance_q = cfg.balance_q;
        rs.conservative_q = cfg.conservative_q;
        rs
    }

    /// Run one scheduling interval over a cluster view; returns up to
    /// `max_migrations_per_interval` migrations, best-first.
    pub fn decide(&mut self, view: &ClusterView<'_>) -> Vec<MigrationDecision> {
        // ANALYZE-OK: R2 profiles the solver (max_decision_us), never sim time
        let t0 = Instant::now();
        self.stats.intervals += 1;
        let mut decisions = Vec::new();

        // retired / still-provisioning instances are not part of the
        // working set: their zero loads would drag w̄ down and flag half
        // the cluster as overloaded. Draining instances stay in as
        // *sources* (shedding their residents is exactly what a drain
        // wants) but are never targets (see `underloaded` below).
        let insts: Vec<InstanceRef<'_>> = view
            .instances()
            .filter(|iv| {
                matches!(
                    iv.lifecycle(),
                    crate::coordinator::Lifecycle::Active | crate::coordinator::Lifecycle::Draining
                )
            })
            .collect();
        let g = view.tokens_per_interval();
        let default_rem = if self.use_prediction {
            None
        } else {
            Some(self.default_remaining)
        };
        let mut reports: Vec<WorkerReport> = insts
            .iter()
            .map(|v| {
                WorkerReport::compute(
                    v,
                    g,
                    &self.betas,
                    default_rem,
                    self.balance_q,
                    self.conservative_q,
                )
            })
            .collect();

        // requests already chosen this interval: the views cannot be
        // updated between rounds (only the reports are), so a later round
        // must not re-select a request that is already on its way out
        let mut decided: Vec<RequestId> = Vec::new();
        for _round in 0..self.cfg.max_migrations_per_interval {
            match self.decide_one(&insts, g, &reports, &decided) {
                None => break,
                Some(d) => {
                    // apply the move to the reports so a second migration in
                    // the same interval sees the updated projection
                    self.apply_to_reports(&insts, g, &mut reports, &d);
                    decided.push(d.request);
                    decisions.push(d);
                    self.stats.migrations += 1;
                }
            }
        }

        let us = t0.elapsed().as_micros() as u64;
        self.stats.last_decision_us = us;
        self.stats.max_decision_us = self.stats.max_decision_us.max(us);
        decisions
    }

    /// Phases 1–3 for a single best migration.
    fn decide_one(
        &mut self,
        insts: &[InstanceRef<'_>],
        g: f64,
        reports: &[WorkerReport],
        decided: &[RequestId],
    ) -> Option<MigrationDecision> {
        let n = reports.len();
        if n < 2 {
            return None;
        }

        // ---- Phase 1: instance classification (normalized to tokens) ----
        // without prediction the scheduler can only trust the current
        // state (paper: "based on current state only"); with prediction
        // w_i folds in the β-weighted projected loads.
        let w: Vec<f64> = if self.use_prediction {
            reports.iter().map(|r| r.weighted / self.beta_sum).collect()
        } else {
            reports.iter().map(|r| r.current_tokens as f64).collect()
        };
        let w_bar = w.iter().sum::<f64>() / n as f64;
        if w_bar <= 0.0 {
            return None;
        }
        let threshold = (1.0 + self.cfg.theta) * w_bar;
        // memory-pressure trigger (the OOM-prevention half of the paper's
        // Issue 1): an instance whose (predicted) peak load approaches its
        // KV capacity is overloaded regardless of the cluster average —
        // prediction sees the growth *before* it materializes.
        let mem_hot = |i: usize| -> bool {
            let rep = &reports[i];
            // OOM-avoidance reads the conservative aggregate trace: an
            // instance whose p90 projection crosses the line is hot even
            // when the mean projection is still comfortable
            let level = if self.use_prediction {
                rep.load_hi.iter().cloned().fold(0.0, f64::max)
            } else {
                rep.load[0]
            };
            level > 0.85 * rep.kv_capacity_tokens as f64
        };
        let overloaded: Vec<usize> = (0..n)
            .filter(|&i| w[i] > threshold || mem_hot(i))
            .collect();
        let underloaded: Vec<usize> = (0..n)
            .filter(|&i| {
                insts[i].is_schedulable()
                    && (reports[i].current_tokens as f64) < threshold
                    && !mem_hot(i)
            })
            .collect();
        if overloaded.is_empty() || underloaded.is_empty() {
            return None;
        }

        // ---- precompute per-step sums for O(H) candidate evaluation ----
        let horizon = self.cfg.horizon;
        let mut sum = vec![0.0; horizon + 1];
        let mut sumsq = vec![0.0; horizon + 1];
        for rep in reports {
            for t in 0..=horizon {
                sum[t] += rep.load[t];
                sumsq[t] += rep.load[t] * rep.load[t];
            }
        }
        // objective weights: t=0 gets weight 1 (σ₀² term of Eq. 4)
        let weight = |t: usize| if t == 0 { 1.0 } else { self.betas[t - 1] };
        let var_at = |t: usize, sumsq_t: f64| {
            let mean = sum[t] / n as f64;
            (sumsq_t / n as f64 - mean * mean).max(0.0)
        };
        let base_obj: f64 = (0..=horizon)
            .map(|t| weight(t) * var_at(t, sumsq[t]))
            .sum();

        // migration amortization bound (Alg. 1 line 20)
        let min_remaining = |kv_tokens: u64| {
            self.migration
                .overhead_iterations(kv_tokens, self.avg_iter_s)
        };

        // ---- Phases 2+3 fused: enumerate, filter, evaluate ----
        let mut best: Option<MigrationDecision> = None;
        for &s in &overloaded {
            for &t_i in &underloaded {
                if s == t_i {
                    continue;
                }
                let dst_rep = &reports[t_i];
                let dst_cap = dst_rep.kv_capacity_tokens as f64 * (1.0 - self.cfg.mem_safety_frac);
                for r in insts[s].requests() {
                    if r.migrating || decided.contains(&r.id) {
                        continue;
                    }
                    let rem = if self.use_prediction {
                        match r.predicted_remaining {
                            Some(p) => p.mean,
                            None => continue, // not yet predicted
                        }
                    } else {
                        self.default_remaining
                    };
                    // line 20: remaining work must amortize the transfer
                    // (judged on the mean — the balanced expectation)
                    if rem <= min_remaining(r.tokens) {
                        continue;
                    }
                    // the destination must be able to actually re-admit
                    // the arriving KV (driver admission watermark); a
                    // migration that can never be admitted would be
                    // failed terminally on delivery
                    if r.tokens > admission_watermark(dst_rep.kv_capacity_tokens) {
                        continue;
                    }
                    // line 21: target memory safety over the horizon — the
                    // request arrives with N(r) KV and grows by up to g·H,
                    // capped by the CONSERVATIVE quantile of its predicted
                    // remaining (an uncertain length must not be assumed
                    // short when banking on the destination's headroom)
                    let rem_hi = if self.use_prediction {
                        r.remaining_q(self.conservative_q, rem)
                    } else {
                        rem
                    };
                    let growth = rem_hi.min(g * horizon as f64);
                    let peak_dst = dst_rep
                        .load_hi
                        .iter()
                        .cloned()
                        .fold(0.0, f64::max)
                        + dst_rep.inbound_reserved_tokens as f64
                        + r.tokens as f64
                        + growth;
                    if peak_dst > dst_cap {
                        continue;
                    }

                    self.stats.candidates_evaluated += 1;

                    // O(H) incremental objective with r moved s -> t_i
                    // (balancing view: the mean quantile)
                    let fl = FutureLoad::of_request(
                        r,
                        g,
                        horizon,
                        if self.use_prediction {
                            None
                        } else {
                            Some(self.default_remaining)
                        },
                        self.balance_q,
                    );
                    let eval_horizon = if self.use_prediction { horizon } else { 0 };
                    let mut obj = 0.0;
                    for t in 0..=horizon {
                        let c = fl.trace[t];
                        let ls = reports[s].load[t];
                        let lt = reports[t_i].load[t];
                        let new_sumsq = sumsq[t] - ls * ls - lt * lt
                            + (ls - c) * (ls - c)
                            + (lt + c) * (lt + c);
                        if t <= eval_horizon {
                            obj += weight(t) * var_at(t, new_sumsq);
                        }
                    }
                    // when prediction is off the objective is σ₀² only
                    // (Alg. 1 line 32: CurrentVariance)
                    let base = if self.use_prediction {
                        base_obj
                    } else {
                        var_at(0, sumsq[0])
                    };
                    let reduction = base - obj;
                    if reduction > 1e-9
                        && best
                            .as_ref()
                            .map(|b| reduction > b.var_reduction)
                            .unwrap_or(true)
                    {
                        best = Some(MigrationDecision {
                            request: r.id,
                            src: insts[s].id(),
                            dst: insts[t_i].id(),
                            kv_tokens: r.tokens,
                            var_reduction: reduction,
                        });
                    }
                }
            }
        }
        best
    }

    /// Mutate the worker reports to reflect an accepted migration, so a
    /// second decision in the same interval uses updated projections.
    fn apply_to_reports(
        &self,
        insts: &[InstanceRef<'_>],
        g: f64,
        reports: &mut [WorkerReport],
        d: &MigrationDecision,
    ) {
        let (mut s_idx, mut d_idx) = (None, None);
        for (i, iv) in insts.iter().enumerate() {
            if iv.id() == d.src {
                s_idx = Some(i);
            }
            if iv.id() == d.dst {
                d_idx = Some(i);
            }
        }
        let (s_idx, d_idx) = (s_idx.unwrap(), d_idx.unwrap());
        let r = insts[s_idx]
            .requests()
            .iter()
            .find(|r| r.id == d.request)
            .expect("decision request present");
        let default_rem = if self.use_prediction {
            None
        } else {
            Some(self.default_remaining)
        };
        let fl = FutureLoad::of_request(r, g, self.cfg.horizon, default_rem, self.balance_q);
        let fh = FutureLoad::of_request(r, g, self.cfg.horizon, default_rem, self.conservative_q);
        for t in 0..fl.trace.len() {
            reports[s_idx].load[t] -= fl.trace[t];
            reports[d_idx].load[t] += fl.trace[t];
            reports[s_idx].load_hi[t] -= fh.trace[t];
            reports[d_idx].load_hi[t] += fh.trace[t];
        }
        reports[s_idx].current_tokens = reports[s_idx].current_tokens.saturating_sub(d.kv_tokens);
        reports[d_idx].current_tokens += d.kv_tokens;
        let recompute = |rep: &mut WorkerReport, betas: &[f64]| {
            rep.weighted = betas
                .iter()
                .enumerate()
                .map(|(i, b)| b * rep.load[i + 1])
                .sum();
        };
        recompute(&mut reports[s_idx], &self.betas);
        recompute(&mut reports[d_idx], &self.betas);
    }
}

/// The STAR algorithm behind the pluggable policy surface: registered as
/// `"star"` in [`PolicyRegistry::with_builtins`].
///
/// [`PolicyRegistry::with_builtins`]: super::policy::PolicyRegistry::with_builtins
impl ReschedulePolicy for Rescheduler {
    fn name(&self) -> &str {
        "star"
    }

    fn decide(&mut self, view: &ClusterView<'_>) -> Vec<MigrationDecision> {
        Rescheduler::decide(self, view)
    }

    fn stats(&self) -> ReschedulerStats {
        self.stats.clone()
    }

    fn observe_avg_iter_s(&mut self, avg_iter_s: f64) {
        self.avg_iter_s = avg_iter_s;
    }

    fn observe_default_remaining(&mut self, tokens: f64) {
        self.default_remaining = tokens;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::{inst, req};
    use crate::coordinator::ClusterSnapshot;

    fn cfg() -> ReschedulerConfig {
        ReschedulerConfig {
            horizon: 4,
            beta_decay: 0.7,
            theta: 0.1,
            ..Default::default()
        }
    }

    fn mig() -> MigrationCostModel {
        // fast link: 1 token of KV = 1 byte so overhead is negligible
        MigrationCostModel {
            bandwidth_bps: 1e12,
            latency_s: 1e-4,
            bytes_per_token: 1,
        }
    }

    fn snapshot(loads: &[Vec<(u64, u64, f64)>]) -> ClusterSnapshot {
        // per instance: list of (req id, tokens, remaining)
        ClusterSnapshot {
            instances: loads
                .iter()
                .enumerate()
                .map(|(i, reqs)| {
                    inst(
                        i,
                        reqs.iter()
                            .map(|&(id, tok, rem)| req(id, tok, Some(rem)))
                            .collect(),
                        1_000_000,
                    )
                })
                .collect(),
            tokens_per_interval: 50.0,
        }
    }

    #[test]
    fn balanced_cluster_no_migration() {
        let snap = snapshot(&[
            vec![(1, 1000, 500.0)],
            vec![(2, 1000, 500.0)],
            vec![(3, 1000, 500.0)],
        ]);
        let mut rs = Rescheduler::new(cfg(), mig(), true);
        assert!(rs.decide(&snap.view()).is_empty());
    }

    #[test]
    fn overloaded_instance_sheds_to_underloaded() {
        let snap = snapshot(&[
            vec![(1, 3000, 4000.0), (2, 3000, 4000.0)],
            vec![(3, 500, 100.0)],
            vec![(4, 600, 100.0)],
        ]);
        let mut rs = Rescheduler::new(cfg(), mig(), true);
        let ds = rs.decide(&snap.view());
        assert_eq!(ds.len(), 1);
        let d = &ds[0];
        assert_eq!(d.src, 0);
        assert!(d.dst == 1 || d.dst == 2);
        assert!(d.var_reduction > 0.0);
    }

    #[test]
    fn draining_instances_are_sources_never_targets() {
        use crate::coordinator::Lifecycle;
        let mut snap = snapshot(&[
            vec![(1, 3000, 4000.0), (2, 3000, 4000.0)],
            vec![(3, 500, 100.0)],
            vec![(4, 600, 100.0)],
        ]);
        snap.instances[1].lifecycle = Lifecycle::Draining;
        let mut rs = Rescheduler::new(cfg(), mig(), true);
        let ds = rs.decide(&snap.view());
        assert_eq!(ds.len(), 1);
        assert_eq!(ds[0].dst, 2, "the draining instance must not receive work");
        // an overloaded source that is itself draining still sheds
        let mut snap = snapshot(&[
            vec![(1, 3000, 4000.0), (2, 3000, 4000.0)],
            vec![(3, 500, 100.0)],
        ]);
        snap.instances[0].lifecycle = Lifecycle::Draining;
        let mut rs = Rescheduler::new(cfg(), mig(), true);
        let ds = rs.decide(&snap.view());
        assert_eq!(ds.len(), 1);
        assert_eq!((ds[0].src, ds[0].dst), (0, 1));
        // retired slots are invisible to classification
        let mut snap = snapshot(&[
            vec![(1, 3000, 4000.0), (2, 3000, 4000.0)],
            vec![(3, 500, 100.0)],
            vec![],
        ]);
        snap.instances[2].lifecycle = Lifecycle::Retired;
        let mut rs = Rescheduler::new(cfg(), mig(), true);
        for d in rs.decide(&snap.view()) {
            assert_ne!(d.dst, 2, "retired slot must never be a target");
        }
    }

    #[test]
    fn near_complete_requests_not_migrated() {
        // the only movable request is nearly done: migration cannot amortize
        let mut m = mig();
        m.bandwidth_bps = 1e3; // very slow link
        m.bytes_per_token = 1000;
        let snap = snapshot(&[
            vec![(1, 5000, 3.0)], // 3 tokens left
            vec![(2, 100, 50.0)],
        ]);
        let mut rs = Rescheduler::new(cfg(), m, true);
        assert!(rs.decide(&snap.view()).is_empty());
    }

    #[test]
    fn memory_unsafe_target_rejected() {
        let mut snap = snapshot(&[
            vec![(1, 3000, 4000.0), (2, 3000, 4000.0)],
            vec![(3, 500, 100.0)],
        ]);
        snap.instances[1].kv_capacity_tokens = 3400; // cannot take 3000+growth
        let mut rs = Rescheduler::new(cfg(), mig(), true);
        assert!(rs.decide(&snap.view()).is_empty());
    }

    #[test]
    fn migrating_requests_excluded() {
        let mut snap = snapshot(&[
            vec![(1, 6000, 4000.0)],
            vec![(2, 100, 50.0)],
        ]);
        snap.instances[0].requests[0].migrating = true;
        let mut rs = Rescheduler::new(cfg(), mig(), true);
        assert!(rs.decide(&snap.view()).is_empty());
    }

    #[test]
    fn without_prediction_uses_current_variance() {
        let snap = snapshot(&[
            vec![(1, 4000, 10_000.0), (2, 2000, 10.0)],
            vec![(3, 500, 10.0)],
        ]);
        let mut rs = Rescheduler::new(cfg(), mig(), false);
        let ds = rs.decide(&snap.view());
        assert_eq!(ds.len(), 1);
        // current-variance objective moves the request that best balances
        // *current* tokens: moving 2000 gives loads (4000, 2500) vs moving
        // 4000 giving (2000, 4500); the former is better.
        assert_eq!(ds[0].request, 2);
    }

    #[test]
    fn with_prediction_prefers_long_remaining() {
        // two equal-size requests; one nearly done, one with huge remaining.
        // Future-aware selection should move the long one (the short one's
        // load disappears on its own).
        let snap = snapshot(&[
            vec![(1, 3000, 10_000.0), (2, 3000, 60.0)],
            vec![(3, 500, 10.0)],
        ]);
        let mut rs = Rescheduler::new(cfg(), mig(), true);
        let ds = rs.decide(&snap.view());
        assert_eq!(ds.len(), 1);
        assert_eq!(ds[0].request, 1, "should migrate the long-remaining request");
    }

    #[test]
    fn multi_migration_interval_updates_reports() {
        let mut c = cfg();
        c.max_migrations_per_interval = 2;
        let snap = snapshot(&[
            vec![(1, 3000, 4000.0), (2, 3000, 4000.0), (3, 3000, 4000.0)],
            vec![(4, 100, 50.0)],
            vec![(5, 100, 50.0)],
        ]);
        let mut rs = Rescheduler::new(c, mig(), true);
        let ds = rs.decide(&snap.view());
        assert_eq!(ds.len(), 2);
        // the two moves must go to different targets (reports updated)
        assert_ne!(ds[0].dst, ds[1].dst);
    }

    #[test]
    fn stats_track_decisions() {
        let snap = snapshot(&[
            vec![(1, 3000, 4000.0), (2, 3000, 4000.0)],
            vec![(3, 100, 50.0)],
        ]);
        let mut rs = Rescheduler::new(cfg(), mig(), true);
        let _ = rs.decide(&snap.view());
        assert_eq!(rs.stats.intervals, 1);
        assert!(rs.stats.candidates_evaluated > 0);
        assert!(rs.stats.migrations <= 1);
    }
}
