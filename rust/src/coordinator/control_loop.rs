//! The shared scheduling control loop.
//!
//! Both drivers — the live server (`crate::serve`) and the event-driven
//! simulator (`crate::sim`) — used to wire up their own dispatcher +
//! rescheduler and duplicate the glue between them. [`ControlLoop`] owns
//! that glue once: it holds the boxed [`DispatchPolicy`] and
//! [`ReschedulePolicy`], forwards the runtime observations (measured
//! iteration time, workload mean output length), and gates rescheduling on
//! the experiment's master switch. Because both drivers execute this exact
//! type, a policy evaluated in simulation (paper Fig. 13) is the policy
//! the live system runs.

use super::cluster_state::ClusterView;
use super::policy::{DispatchPolicy, IncomingRequest, PolicyConfig, PolicyRegistry, ReschedulePolicy};
use super::rescheduler::{MigrationDecision, ReschedulerStats};
use crate::config::ExperimentConfig;
use crate::costmodel::MigrationCostModel;
use crate::{InstanceId, Result};

/// One dispatch policy + one reschedule policy, driven identically by the
/// live runtime and the simulator.
pub struct ControlLoop {
    dispatch: Box<dyn DispatchPolicy>,
    reschedule: Box<dyn ReschedulePolicy>,
    /// Master switch (`rescheduler.enabled`): when off, [`Self::reschedule`]
    /// is a no-op and the "vLLM baseline" behaviour falls out.
    rescheduling_enabled: bool,
}

impl ControlLoop {
    pub fn new(
        dispatch: Box<dyn DispatchPolicy>,
        reschedule: Box<dyn ReschedulePolicy>,
        rescheduling_enabled: bool,
    ) -> ControlLoop {
        ControlLoop {
            dispatch,
            reschedule,
            rescheduling_enabled,
        }
    }

    /// Build both policies by name from the experiment config — the one
    /// construction path every driver uses.
    pub fn from_experiment(
        exp: &ExperimentConfig,
        migration: MigrationCostModel,
        registry: &PolicyRegistry,
    ) -> Result<ControlLoop> {
        let cfg = PolicyConfig::from_experiment(exp, migration);
        let dispatch = registry.build_dispatch(&exp.dispatch_policy, &cfg)?;
        let reschedule = registry.build_reschedule(&exp.reschedule_policy, &cfg)?;
        Ok(ControlLoop::new(
            dispatch,
            reschedule,
            exp.rescheduler.enabled,
        ))
    }

    /// Place a request arriving from prefill (or re-dispatched after OOM
    /// recompute) onto a decode instance. The view is normally borrowed
    /// from the driver's incremental [`ClusterState`] — no materialization
    /// on the per-request hot path.
    ///
    /// [`ClusterState`]: crate::coordinator::ClusterState
    pub fn dispatch(
        &mut self,
        view: &ClusterView<'_>,
        incoming: &IncomingRequest,
    ) -> InstanceId {
        self.dispatch.choose(view, incoming)
    }

    /// Run one scheduling interval; empty when rescheduling is disabled.
    /// The caller executes the returned migrations (and is responsible for
    /// capacity reservations on the targets).
    pub fn reschedule(&mut self, view: &ClusterView<'_>) -> Vec<MigrationDecision> {
        if !self.rescheduling_enabled {
            return Vec::new();
        }
        self.reschedule.decide(view)
    }

    /// Feed the measured average decode iteration time to the reschedule
    /// policy (T̄_exec in Alg. 1's amortization bound).
    pub fn observe_avg_iter_s(&mut self, avg_iter_s: f64) {
        self.reschedule.observe_avg_iter_s(avg_iter_s);
    }

    /// Feed the workload's running mean remaining-output estimate (used
    /// when per-request predictions are unavailable).
    pub fn observe_default_remaining(&mut self, tokens: f64) {
        self.reschedule.observe_default_remaining(tokens);
    }

    pub fn rescheduling_enabled(&self) -> bool {
        self.rescheduling_enabled
    }

    pub fn dispatch_name(&self) -> &str {
        self.dispatch.name()
    }

    pub fn reschedule_name(&self) -> &str {
        self.reschedule.name()
    }

    /// Reschedule-policy counters for reports.
    pub fn stats(&self) -> ReschedulerStats {
        self.reschedule.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::{inst, req};
    use crate::coordinator::ClusterSnapshot;

    fn exp() -> ExperimentConfig {
        ExperimentConfig::default()
    }

    fn skewed() -> ClusterSnapshot {
        ClusterSnapshot {
            instances: vec![
                inst(
                    0,
                    vec![req(1, 3000, Some(4000.0)), req(2, 3000, Some(4000.0))],
                    1_000_000,
                ),
                inst(1, vec![req(3, 500, Some(100.0))], 1_000_000),
            ],
            tokens_per_interval: 50.0,
        }
    }

    #[test]
    fn from_experiment_builds_default_policies() {
        let reg = PolicyRegistry::with_builtins();
        let mut c =
            ControlLoop::from_experiment(&exp(), MigrationCostModel::new_25gbps(1), &reg).unwrap();
        assert_eq!(c.dispatch_name(), "current_load");
        assert_eq!(c.reschedule_name(), "star");
        assert!(c.rescheduling_enabled());
        let skew = skewed();
        let id = c.dispatch(
            &skew.view(),
            &IncomingRequest {
                id: 9,
                tokens: 10,
                predicted_remaining: None,
            },
        );
        assert_eq!(id, 1, "current_load picks the lighter instance");
    }

    #[test]
    fn disabled_rescheduling_short_circuits() {
        let reg = PolicyRegistry::with_builtins();
        let mut e = exp();
        e.rescheduler.enabled = false;
        let mut c =
            ControlLoop::from_experiment(&e, MigrationCostModel::new_25gbps(1), &reg).unwrap();
        assert!(c.reschedule(&skewed().view()).is_empty());
        assert_eq!(c.stats().intervals, 0, "policy must not even be invoked");
    }

    #[test]
    fn unknown_policy_names_surface_as_errors() {
        let reg = PolicyRegistry::with_builtins();
        let mut e = exp();
        e.dispatch_policy = "definitely_not_registered".to_string();
        assert!(
            ControlLoop::from_experiment(&e, MigrationCostModel::new_25gbps(1), &reg).is_err()
        );
    }

    #[test]
    fn observations_reach_the_policy() {
        let reg = PolicyRegistry::with_builtins();
        let mut e = exp();
        e.reschedule_policy = "star".to_string();
        let mut c = ControlLoop::from_experiment(
            &e,
            MigrationCostModel {
                bandwidth_bps: 1e12,
                latency_s: 1e-4,
                bytes_per_token: 1,
            },
            &reg,
        )
        .unwrap();
        c.observe_avg_iter_s(0.05);
        c.observe_default_remaining(250.0);
        // still functions end-to-end after observations
        let ds = c.reschedule(&skewed().view());
        assert!(ds.len() <= 1);
        assert_eq!(c.stats().intervals, 1);
    }
}
