//! The shared scheduling control loop.
//!
//! Both drivers — the live server (`crate::serve`) and the event-driven
//! simulator (`crate::sim`) — used to wire up their own dispatcher +
//! rescheduler and duplicate the glue between them. [`ControlLoop`] owns
//! that glue once: it holds the boxed [`DispatchPolicy`] and
//! [`ReschedulePolicy`], forwards the runtime observations (measured
//! iteration time, workload mean output length), and gates rescheduling on
//! the experiment's master switch. Because both drivers execute this exact
//! type, a policy evaluated in simulation (paper Fig. 13) is the policy
//! the live system runs.

use super::cluster_state::ClusterView;
use super::elastic::{ElasticGuard, PoolStats, ScalingAction, ScalingPolicy, StaticScaling};
use super::policy::{DispatchPolicy, IncomingRequest, PolicyConfig, PolicyRegistry, ReschedulePolicy};
use super::rescheduler::{MigrationDecision, ReschedulerStats};
use crate::config::{ElasticConfig, ExperimentConfig};
use crate::costmodel::MigrationCostModel;
use crate::obs::AttributionLog;
use crate::{InstanceId, Result, Time};

/// One dispatch policy + one reschedule policy + one scaling policy,
/// driven identically by the live runtime and the simulator.
pub struct ControlLoop {
    dispatch: Box<dyn DispatchPolicy>,
    reschedule: Box<dyn ReschedulePolicy>,
    /// Master switch (`rescheduler.enabled`): when off, [`Self::reschedule`]
    /// is a no-op and the "vLLM baseline" behaviour falls out.
    rescheduling_enabled: bool,
    /// Elastic-pool policy; `static` (the default) makes [`Self::scale`] a
    /// guaranteed no-op, preserving frozen-pool behaviour exactly.
    scaling: Box<dyn ScalingPolicy>,
    guard: ElasticGuard,
    /// Decision-attribution log (`[obs] enabled`): every dispatch /
    /// reschedule / scale / cache decision is recorded here with its
    /// policy name and work proxy. Disabled (the default) every record
    /// call is a no-op, so the hot path pays one branch.
    obs: AttributionLog,
    /// Scheduling epochs observed: incremented by the driver at every
    /// epoch barrier (scheduler / scale tick merge in the sharded
    /// simulator) right before this loop decides. Diagnostic only — it
    /// never feeds a decision, so counting epochs is trajectory-neutral.
    epochs: u64,
}

impl ControlLoop {
    /// Dispatch + reschedule with a frozen pool (`static` scaling) — the
    /// pre-elastic constructor, kept for tests and embedders.
    pub fn new(
        dispatch: Box<dyn DispatchPolicy>,
        reschedule: Box<dyn ReschedulePolicy>,
        rescheduling_enabled: bool,
    ) -> ControlLoop {
        Self::with_scaling(
            dispatch,
            reschedule,
            rescheduling_enabled,
            Box::new(StaticScaling),
            ElasticConfig::default(),
        )
    }

    pub fn with_scaling(
        dispatch: Box<dyn DispatchPolicy>,
        reschedule: Box<dyn ReschedulePolicy>,
        rescheduling_enabled: bool,
        scaling: Box<dyn ScalingPolicy>,
        elastic: ElasticConfig,
    ) -> ControlLoop {
        ControlLoop {
            dispatch,
            reschedule,
            rescheduling_enabled,
            scaling,
            guard: ElasticGuard::new(elastic),
            obs: AttributionLog::default(),
            epochs: 0,
        }
    }

    /// Mark one scheduling epoch: the driver calls this at every epoch
    /// barrier, after shard aggregates are merged and before any
    /// decision of this tick runs.
    pub fn note_epoch(&mut self) {
        self.epochs += 1;
    }

    /// Scheduling epochs observed so far (shard-merge barriers crossed).
    pub fn epoch_merges(&self) -> u64 {
        self.epochs
    }

    /// Build all three policies by name from the experiment config — the
    /// one construction path every driver uses.
    pub fn from_experiment(
        exp: &ExperimentConfig,
        migration: MigrationCostModel,
        registry: &PolicyRegistry,
    ) -> Result<ControlLoop> {
        let cfg = PolicyConfig::from_experiment(exp, migration);
        let dispatch = registry.build_dispatch(&exp.dispatch_policy, &cfg)?;
        let reschedule = registry.build_reschedule(&exp.reschedule_policy, &cfg)?;
        let scaling = registry.build_scaling(&exp.scaling_policy, &cfg)?;
        let mut loop_ = ControlLoop::with_scaling(
            dispatch,
            reschedule,
            exp.rescheduler.enabled,
            scaling,
            exp.elastic.clone(),
        );
        loop_.obs = AttributionLog::new(exp.obs.enabled);
        Ok(loop_)
    }

    /// Place a request arriving from prefill (or re-dispatched after OOM
    /// recompute) onto a decode instance. The view is normally borrowed
    /// from the driver's incremental [`ClusterState`] — no materialization
    /// on the per-request hot path.
    ///
    /// [`ClusterState`]: crate::coordinator::ClusterState
    pub fn dispatch(
        &mut self,
        view: &ClusterView<'_>,
        incoming: &IncomingRequest,
    ) -> InstanceId {
        let chosen = self.dispatch.choose(view, incoming);
        self.obs.record_dispatch(
            self.dispatch.name(),
            incoming.id,
            view.n_instances() as u64,
            chosen,
        );
        chosen
    }

    /// Run one scheduling interval; empty when rescheduling is disabled.
    /// The caller executes the returned migrations (and is responsible for
    /// capacity reservations on the targets).
    pub fn reschedule(&mut self, view: &ClusterView<'_>) -> Vec<MigrationDecision> {
        if !self.rescheduling_enabled {
            return Vec::new();
        }
        let scanned_before = self.reschedule.stats().candidates_evaluated;
        let decisions = self.reschedule.decide(view);
        if self.obs.enabled() {
            let scanned = self
                .reschedule
                .stats()
                .candidates_evaluated
                .saturating_sub(scanned_before);
            self.obs.record_reschedule_tick(
                self.reschedule.name(),
                scanned,
                decisions.len() as u64,
            );
            for d in &decisions {
                self.obs.record_migration(self.reschedule.name(), d.request, d.dst);
            }
        }
        decisions
    }

    /// Feed the measured average decode iteration time to the reschedule
    /// policy (T̄_exec in Alg. 1's amortization bound).
    pub fn observe_avg_iter_s(&mut self, avg_iter_s: f64) {
        self.reschedule.observe_avg_iter_s(avg_iter_s);
    }

    /// Feed the workload's running mean remaining-output estimate (used
    /// when per-request predictions are unavailable).
    pub fn observe_default_remaining(&mut self, tokens: f64) {
        self.reschedule.observe_default_remaining(tokens);
    }

    /// Run one scale interval: ask the scaling policy for pool-shape
    /// changes and clamp them through the [`ElasticGuard`] (floors, one
    /// in-flight transition, cooldown). Empty under the builtin `static`
    /// policy — [`StaticScaling::decide`] returns nothing by
    /// construction, so `--scaling static` reproduces frozen-pool runs
    /// exactly (and a third-party policy registered under any name,
    /// including `static`, still gets its `decide` call). The caller
    /// executes the returned actions (the simulator via its elastic
    /// events, the live server on its threads).
    pub fn scale(&mut self, view: &ClusterView<'_>, pool: &PoolStats) -> Vec<ScalingAction> {
        let proposed = self.scaling.decide(view, pool);
        let admitted = if proposed.is_empty() {
            proposed
        } else {
            self.guard.admit(proposed, view, pool)
        };
        self.obs.record_scale(
            self.scaling.name(),
            view.n_instances() as u64,
            admitted.len() as u64,
        );
        admitted
    }

    /// Best-effort indicator that the pool may change shape (the builtin
    /// `static` policy never acts). Display/diagnostics only — `scale`
    /// itself always consults the policy.
    pub fn elastic_enabled(&self) -> bool {
        self.scaling.name() != "static"
    }

    pub fn rescheduling_enabled(&self) -> bool {
        self.rescheduling_enabled
    }

    pub fn dispatch_name(&self) -> &str {
        self.dispatch.name()
    }

    pub fn reschedule_name(&self) -> &str {
        self.reschedule.name()
    }

    pub fn scaling_name(&self) -> &str {
        self.scaling.name()
    }

    /// Elastic mechanics (intervals, delays, floors) the drivers execute
    /// against.
    pub fn elastic_config(&self) -> &ElasticConfig {
        self.guard.config()
    }

    /// Reschedule-policy counters for reports.
    pub fn stats(&self) -> ReschedulerStats {
        self.reschedule.stats()
    }

    /// Stamp the decision clock: every attribution record until the
    /// next call carries this time. Drivers call it once per event /
    /// loop iteration; a no-op-cheap f64 store when obs is off.
    #[inline]
    pub fn set_decision_time(&mut self, t: Time) {
        self.obs.set_now(t);
    }

    /// The attribution log (e.g. for prefix-cache consult records and
    /// the live server's measured-µs cost notes).
    pub fn attribution_mut(&mut self) -> &mut AttributionLog {
        &mut self.obs
    }

    pub fn attribution(&self) -> &AttributionLog {
        &self.obs
    }

    /// Move the log out for the run report (leaves a disabled default).
    pub fn take_attribution(&mut self) -> AttributionLog {
        std::mem::take(&mut self.obs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::{inst, req};
    use crate::coordinator::ClusterSnapshot;

    fn exp() -> ExperimentConfig {
        ExperimentConfig::default()
    }

    fn skewed() -> ClusterSnapshot {
        ClusterSnapshot {
            instances: vec![
                inst(
                    0,
                    vec![req(1, 3000, Some(4000.0)), req(2, 3000, Some(4000.0))],
                    1_000_000,
                ),
                inst(1, vec![req(3, 500, Some(100.0))], 1_000_000),
            ],
            tokens_per_interval: 50.0,
        }
    }

    #[test]
    fn from_experiment_builds_default_policies() {
        let reg = PolicyRegistry::with_builtins();
        let mut c =
            ControlLoop::from_experiment(&exp(), MigrationCostModel::new_25gbps(1), &reg).unwrap();
        assert_eq!(c.dispatch_name(), "current_load");
        assert_eq!(c.reschedule_name(), "star");
        assert!(c.rescheduling_enabled());
        let skew = skewed();
        let id = c.dispatch(
            &skew.view(),
            &IncomingRequest {
                id: 9,
                tokens: 10,
                predicted_remaining: None,
                preferred_instance: None,
            },
        );
        assert_eq!(id, 1, "current_load picks the lighter instance");
    }

    #[test]
    fn disabled_rescheduling_short_circuits() {
        let reg = PolicyRegistry::with_builtins();
        let mut e = exp();
        e.rescheduler.enabled = false;
        let mut c =
            ControlLoop::from_experiment(&e, MigrationCostModel::new_25gbps(1), &reg).unwrap();
        assert!(c.reschedule(&skewed().view()).is_empty());
        assert_eq!(c.stats().intervals, 0, "policy must not even be invoked");
    }

    #[test]
    fn unknown_policy_names_surface_as_errors() {
        let reg = PolicyRegistry::with_builtins();
        let mut e = exp();
        e.dispatch_policy = "definitely_not_registered".to_string();
        assert!(
            ControlLoop::from_experiment(&e, MigrationCostModel::new_25gbps(1), &reg).is_err()
        );
    }

    #[test]
    fn scale_is_inert_under_static_and_acts_under_pressure() {
        use crate::coordinator::elastic::{PoolStats, ScalingAction};
        let reg = PolicyRegistry::with_builtins();
        let mut e = exp();
        let mut c =
            ControlLoop::from_experiment(&e, MigrationCostModel::new_25gbps(1), &reg).unwrap();
        assert!(!c.elastic_enabled());
        assert_eq!(c.scaling_name(), "static");
        let pool = PoolStats {
            prefill_active: 2,
            decode_active: 2,
            ..Default::default()
        };
        assert!(c.scale(&skewed().view(), &pool).is_empty());

        // queue_pressure over a hot cluster flips a prefill into decode
        e.scaling_policy = "queue_pressure".to_string();
        let mut c =
            ControlLoop::from_experiment(&e, MigrationCostModel::new_25gbps(1), &reg).unwrap();
        assert!(c.elastic_enabled());
        let hot = ClusterSnapshot {
            instances: vec![
                inst(0, vec![req(1, 95_000, Some(100.0))], 100_000),
                inst(1, vec![req(2, 95_000, Some(100.0))], 100_000),
            ],
            tokens_per_interval: 50.0,
        };
        let acts = c.scale(&hot.view(), &pool);
        assert_eq!(acts, vec![ScalingAction::FlipToDecode]);
        // guard cooldown: immediately after, nothing more
        assert!(c.scale(&hot.view(), &pool).is_empty());
    }

    #[test]
    fn attribution_records_decisions_when_enabled() {
        use crate::obs::DecisionKind;
        let reg = PolicyRegistry::with_builtins();
        let mut e = exp();
        e.obs.enabled = true;
        let mut c =
            ControlLoop::from_experiment(&e, MigrationCostModel::new_25gbps(1), &reg).unwrap();
        c.set_decision_time(1.5);
        let incoming = IncomingRequest {
            id: 9,
            tokens: 10,
            predicted_remaining: None,
            preferred_instance: None,
        };
        let _ = c.dispatch(&skewed().view(), &incoming);
        let _ = c.reschedule(&skewed().view());
        let pool = PoolStats {
            prefill_active: 1,
            decode_active: 2,
            ..Default::default()
        };
        let _ = c.scale(&skewed().view(), &pool);
        let log = c.attribution();
        assert!(log.len() >= 3, "dispatch + reschedule tick + scale");
        let d = &log.records()[0];
        assert_eq!(d.kind, DecisionKind::Dispatch);
        assert_eq!(d.policy, "current_load");
        assert_eq!(d.request, Some(9));
        assert_eq!(d.candidates, 2);
        assert!((d.t - 1.5).abs() < 1e-12, "decision time stamped");
        assert!(log
            .records()
            .iter()
            .any(|r| r.kind == DecisionKind::Scale && r.policy == "static"));
        // take_attribution moves the log out for the report
        let taken = c.take_attribution();
        assert!(!taken.is_empty());
        assert!(c.attribution().is_empty());
    }

    #[test]
    fn attribution_is_off_by_default() {
        let reg = PolicyRegistry::with_builtins();
        let mut c =
            ControlLoop::from_experiment(&exp(), MigrationCostModel::new_25gbps(1), &reg).unwrap();
        let incoming = IncomingRequest {
            id: 1,
            tokens: 10,
            predicted_remaining: None,
            preferred_instance: None,
        };
        let _ = c.dispatch(&skewed().view(), &incoming);
        let _ = c.reschedule(&skewed().view());
        assert!(c.attribution().is_empty(), "default-off path records nothing");
    }

    #[test]
    fn observations_reach_the_policy() {
        let reg = PolicyRegistry::with_builtins();
        let mut e = exp();
        e.reschedule_policy = "star".to_string();
        let mut c = ControlLoop::from_experiment(
            &e,
            MigrationCostModel {
                bandwidth_bps: 1e12,
                latency_s: 1e-4,
                bytes_per_token: 1,
            },
            &reg,
        )
        .unwrap();
        c.observe_avg_iter_s(0.05);
        c.observe_default_remaining(250.0);
        // still functions end-to-end after observations
        let ds = c.reschedule(&skewed().view());
        assert!(ds.len() <= 1);
        assert_eq!(c.stats().intervals, 1);
    }
}
