//! The paper's three dispatch strategies (§2.2 baselines + STAR's
//! prediction-aware hand-off), ported onto the [`DispatchPolicy`] trait,
//! plus the no-op rescheduler used as the "vLLM" baseline.

use super::{DispatchPolicy, IncomingRequest, PolicyConfig, ReschedulePolicy};
use crate::coordinator::cluster_state::{admission_watermark, ClusterView, InstanceRef};
use crate::coordinator::rescheduler::{MigrationDecision, ReschedulerStats};
use crate::InstanceId;

/// Shared fit-or-fallback argmin over *schedulable* (lifecycle-Active)
/// instances: prefer the best-scoring one that can hold
/// `incoming_tokens`; if nothing fits, return the best-scoring
/// schedulable instance anyway (admission will queue or OOM there,
/// mirroring vLLM). Only when the pool has zero schedulable instances —
/// which the elastic guard's `min_decode` floor prevents in both drivers
/// — does the fallback consider draining/retired slots, preserving the
/// "always return an instance" contract for hand-built views.
pub(super) fn argmin_with_fallback<G>(
    view: &ClusterView<'_>,
    incoming_tokens: u64,
    score: G,
) -> InstanceId
where
    G: Fn(&InstanceRef<'_>) -> f64,
{
    assert!(view.n_instances() > 0, "dispatch with no decode instances");
    let mut best: Option<(f64, InstanceId)> = None;
    let mut best_any: Option<(f64, InstanceId)> = None;
    let mut best_unschedulable: Option<(f64, InstanceId)> = None;
    for iv in view.instances() {
        let s = score(&iv);
        if !iv.is_schedulable() {
            if best_unschedulable.map(|(b, _)| s < b).unwrap_or(true) {
                best_unschedulable = Some((s, iv.id()));
            }
            continue;
        }
        if best_any.map(|(b, _)| s < b).unwrap_or(true) {
            best_any = Some((s, iv.id()));
        }
        if iv.free_tokens() >= incoming_tokens && best.map(|(b, _)| s < b).unwrap_or(true) {
            best = Some((s, iv.id()));
        }
    }
    best.or(best_any)
        .or(best_unschedulable)
        .expect("non-empty instance list")
        .1
}

/// vLLM-style round-robin [paper ref 34]: even request *counts*, oblivious
/// to per-request workload. Skips instances that cannot fit the incoming
/// KV; when nothing fits, places at the cursor anyway.
#[derive(Clone, Debug, Default)]
pub struct RoundRobinDispatch {
    cursor: usize,
}

impl RoundRobinDispatch {
    pub fn new() -> Self {
        RoundRobinDispatch { cursor: 0 }
    }
}

impl DispatchPolicy for RoundRobinDispatch {
    fn name(&self) -> &str {
        "round_robin"
    }

    fn choose(&mut self, view: &ClusterView<'_>, incoming: &IncomingRequest) -> InstanceId {
        let n = view.n_instances();
        assert!(n > 0, "dispatch with no decode instances");
        for off in 0..n {
            let idx = (self.cursor + off) % n;
            let iv = view.instance(idx);
            if iv.is_schedulable() && iv.free_tokens() >= incoming.tokens {
                self.cursor = (idx + 1) % n;
                return iv.id();
            }
        }
        // nothing fits: place at the next schedulable slot from the cursor
        for off in 0..n {
            let idx = (self.cursor + off) % n;
            if view.instance(idx).is_schedulable() {
                self.cursor = (idx + 1) % n;
                return view.instance(idx).id();
            }
        }
        // zero schedulable instances (hand-built views only; the elastic
        // guard's min_decode floor prevents this in the drivers)
        let idx = self.cursor % n;
        self.cursor = (idx + 1) % n;
        view.instance(idx).id()
    }
}

/// Current-load balancing [FlowKV, ref 20]: pick the instance with the
/// smallest current KV token load (including in-flight reservations).
#[derive(Clone, Debug, Default)]
pub struct CurrentLoadDispatch;

impl DispatchPolicy for CurrentLoadDispatch {
    fn name(&self) -> &str {
        "current_load"
    }

    fn choose(&mut self, view: &ClusterView<'_>, incoming: &IncomingRequest) -> InstanceId {
        argmin_with_fallback(view, incoming.tokens, |iv| iv.effective_used() as f64)
    }
}

/// STAR hand-off: pick the instance with the smallest *projected* load =
/// current + predicted remaining work of its active requests, considering
/// the incoming request's own predicted length.
#[derive(Clone, Debug, Default)]
pub struct PredictedLoadDispatch;

impl DispatchPolicy for PredictedLoadDispatch {
    fn name(&self) -> &str {
        "predicted_load"
    }

    fn choose(&mut self, view: &ClusterView<'_>, incoming: &IncomingRequest) -> InstanceId {
        let pred = incoming.predicted_remaining.map_or(0.0, |p| p.mean);
        // predicted_work is an O(1) aggregate on state-backed views — the
        // hand-off decision no longer walks the instance's batch
        argmin_with_fallback(view, incoming.tokens, |iv| {
            iv.predicted_work() + iv.inbound_reserved_tokens() as f64 + pred
        })
    }
}

/// Prefix-cache-aware hand-off: a follow-up turn whose session prefix is
/// retained on some instance ([`IncomingRequest::preferred_instance`])
/// goes back to that instance, so its prefill covers only the new suffix
/// and no KV moves over the fabric. The preference is honored only while
/// the holder is lifecycle-Active and the request clears its admission
/// watermark; otherwise — and for every request without a cached prefix —
/// the policy degrades to `current_load`'s effective-used argmin (the
/// driver then runs the transfer-vs-recompute costmodel comparison for
/// whatever instance wins).
#[derive(Clone, Debug, Default)]
pub struct SessionAffinityDispatch;

impl DispatchPolicy for SessionAffinityDispatch {
    fn name(&self) -> &str {
        "session_affinity"
    }

    fn choose(&mut self, view: &ClusterView<'_>, incoming: &IncomingRequest) -> InstanceId {
        if let Some(pi) = incoming.preferred_instance {
            if pi < view.n_instances() {
                let iv = view.instance(pi);
                // the cached prefix is already inside effective_used, so
                // the watermark check double-counts it against the suffix;
                // that is the conservative direction (never admit past it)
                if iv.is_schedulable()
                    && iv.effective_used() + incoming.tokens
                        <= admission_watermark(iv.kv_capacity_tokens())
                {
                    return iv.id();
                }
            }
        }
        argmin_with_fallback(view, incoming.tokens, |iv| iv.effective_used() as f64)
    }
}

/// Heterogeneous-fleet placement over the per-instance
/// [`HardwareProfile`]: requests predicted to run long (mean remaining ≥
/// `hardware_aware.long_tokens`, default 1024) chase *memory* — they go
/// to the instance with the most free KV tokens, which on a mixed fleet
/// is the big-`mem_mult` class — while everything else balances
/// *speed-normalized* load (`effective_used / speed_mult`), so a
/// half-speed instance is treated as twice as full. On a uniform fleet
/// the short-request rule degrades to `current_load` exactly and the
/// long-request rule to most-free-first, both reasonable defaults.
///
/// [`HardwareProfile`]: crate::coordinator::HardwareProfile
#[derive(Clone, Debug)]
pub struct HardwareAwareDispatch {
    /// Predicted-remaining threshold (tokens) above which a request is
    /// placed for memory instead of speed.
    long_tokens: f64,
}

impl HardwareAwareDispatch {
    pub fn from_config(cfg: &PolicyConfig) -> Self {
        HardwareAwareDispatch {
            long_tokens: cfg.param_or("hardware_aware.long_tokens", 1024.0),
        }
    }
}

impl DispatchPolicy for HardwareAwareDispatch {
    fn name(&self) -> &str {
        "hardware_aware"
    }

    fn choose(&mut self, view: &ClusterView<'_>, incoming: &IncomingRequest) -> InstanceId {
        let pred = incoming.predicted_remaining.map_or(0.0, |p| p.mean);
        if pred >= self.long_tokens {
            // long generation: room to grow beats raw speed — the KV
            // footprint, not the iteration time, is what kills it
            argmin_with_fallback(view, incoming.tokens, |iv| -(iv.free_tokens() as f64))
        } else {
            // short request: speed-normalized load (a 0.5× instance
            // counts as twice as loaded; speed_mult is validated > 0)
            argmin_with_fallback(view, incoming.tokens, |iv| {
                iv.effective_used() as f64 / iv.hardware().speed_mult
            })
        }
    }
}

/// Never migrates: the dispatch-only "vLLM" baseline, and the policy the
/// control loop runs when rescheduling is disabled by config.
#[derive(Clone, Debug, Default)]
pub struct NoopReschedule {
    stats: ReschedulerStats,
}

impl NoopReschedule {
    pub fn new() -> Self {
        NoopReschedule::default()
    }
}

impl ReschedulePolicy for NoopReschedule {
    fn name(&self) -> &str {
        "none"
    }

    fn decide(&mut self, _view: &ClusterView<'_>) -> Vec<MigrationDecision> {
        self.stats.intervals += 1;
        Vec::new()
    }

    fn stats(&self) -> ReschedulerStats {
        self.stats.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::{inst, req};
    use crate::coordinator::ClusterSnapshot;

    fn incoming(tokens: u64, pred: Option<f64>) -> IncomingRequest {
        IncomingRequest {
            id: 0,
            tokens,
            predicted_remaining: pred.map(crate::predictor::Prediction::exact),
            preferred_instance: None,
        }
    }

    fn incoming_at(tokens: u64, preferred: InstanceId) -> IncomingRequest {
        IncomingRequest {
            preferred_instance: Some(preferred),
            ..incoming(tokens, None)
        }
    }

    fn snap3(loads: [u64; 3]) -> ClusterSnapshot {
        ClusterSnapshot {
            instances: loads
                .iter()
                .enumerate()
                .map(|(i, &l)| inst(i, vec![req(i as u64 + 1, l, None)], 10_000))
                .collect(),
            tokens_per_interval: 10.0,
        }
    }

    #[test]
    fn round_robin_cycles() {
        let snap = snap3([0, 0, 0]);
        let mut d = RoundRobinDispatch::new();
        let picks: Vec<_> = (0..6).map(|_| d.choose(&snap.view(), &incoming(10, None))).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn round_robin_cursor_wraps_around() {
        // the cursor must wrap modulo n and stay fair across many cycles,
        // not drift or overflow
        let snap = snap3([0, 0, 0]);
        let mut d = RoundRobinDispatch::new();
        let mut counts = [0usize; 3];
        for _ in 0..3 * 100 {
            counts[d.choose(&snap.view(), &incoming(10, None))] += 1;
        }
        assert_eq!(counts, [100, 100, 100]);
        // after an exact number of cycles the cursor is back at 0
        assert_eq!(d.choose(&snap.view(), &incoming(10, None)), 0);
    }

    #[test]
    fn round_robin_skips_full_instances() {
        let mut snap = snap3([0, 0, 0]);
        snap.instances[0].inbound_reserved_tokens = 10_000; // full
        let mut d = RoundRobinDispatch::new();
        assert_eq!(d.choose(&snap.view(), &incoming(10, None)), 1);
        assert_eq!(d.choose(&snap.view(), &incoming(10, None)), 2);
        assert_eq!(d.choose(&snap.view(), &incoming(10, None)), 1);
    }

    #[test]
    fn round_robin_no_fit_places_at_cursor() {
        // everything over capacity: the cursor position is still returned
        // and the cursor advances, keeping the overflow spread fair
        let snap = snap3([10_000, 10_000, 10_000]);
        let mut d = RoundRobinDispatch::new();
        assert_eq!(d.choose(&snap.view(), &incoming(100, None)), 0);
        assert_eq!(d.choose(&snap.view(), &incoming(100, None)), 1);
        assert_eq!(d.choose(&snap.view(), &incoming(100, None)), 2);
        assert_eq!(d.choose(&snap.view(), &incoming(100, None)), 0);
    }

    #[test]
    fn current_load_picks_least_loaded() {
        let snap = snap3([500, 100, 300]);
        let mut d = CurrentLoadDispatch;
        assert_eq!(d.choose(&snap.view(), &incoming(10, None)), 1);
    }

    #[test]
    fn current_load_no_fit_falls_back_to_least_loaded() {
        // nothing fits 100 tokens; least-loaded wins anyway
        let snap = snap3([9_995, 9_999, 9_997]);
        let mut d = CurrentLoadDispatch;
        assert_eq!(d.choose(&snap.view(), &incoming(100, None)), 0);
    }

    #[test]
    fn predicted_load_no_fit_falls_back_to_least_projected() {
        let snap = ClusterSnapshot {
            instances: vec![
                inst(0, vec![req(1, 9_995, Some(5_000.0))], 10_000),
                inst(1, vec![req(2, 9_999, Some(10.0))], 10_000),
            ],
            tokens_per_interval: 10.0,
        };
        let mut d = PredictedLoadDispatch;
        // neither fits; instance 1 has the smaller projected load
        assert_eq!(d.choose(&snap.view(), &incoming(100, None)), 1);
    }

    #[test]
    fn predicted_load_sees_future_work() {
        // instance 0: small now but huge remaining; instance 1: bigger now
        // but nearly done.
        let snap = ClusterSnapshot {
            instances: vec![
                inst(0, vec![req(1, 100, Some(5_000.0))], 100_000),
                inst(1, vec![req(2, 400, Some(10.0))], 100_000),
            ],
            tokens_per_interval: 10.0,
        };
        let mut cur = CurrentLoadDispatch;
        let mut pred = PredictedLoadDispatch;
        assert_eq!(
            cur.choose(&snap.view(), &incoming(10, None)),
            0,
            "current-load is fooled"
        );
        assert_eq!(
            pred.choose(&snap.view(), &incoming(10, None)),
            1,
            "predicted-load is not"
        );
    }

    #[test]
    fn dispatch_skips_non_active_instances() {
        use crate::coordinator::Lifecycle;
        // instance 1 is the emptiest but draining: every dispatch policy
        // must skip it
        let mut snap = snap3([500, 0, 300]);
        snap.instances[1].lifecycle = Lifecycle::Draining;
        let mut cur = CurrentLoadDispatch;
        assert_eq!(cur.choose(&snap.view(), &incoming(10, None)), 2);
        let mut pred = PredictedLoadDispatch;
        assert_eq!(pred.choose(&snap.view(), &incoming(10, None)), 2);
        let mut rr = RoundRobinDispatch::new();
        let picks: Vec<_> = (0..4)
            .map(|_| rr.choose(&snap.view(), &incoming(10, None)))
            .collect();
        assert_eq!(picks, vec![0, 2, 0, 2], "round robin cycles over active only");
        // nothing fits anywhere: still lands on a schedulable instance
        let mut snap = snap3([10_000, 0, 10_000]);
        snap.instances[1].lifecycle = Lifecycle::Retired;
        let mut cur = CurrentLoadDispatch;
        let id = cur.choose(&snap.view(), &incoming(500, None));
        assert!(id == 0 || id == 2, "must not fall back to a retired slot");
    }

    #[test]
    fn session_affinity_honors_preference_with_headroom() {
        // instance 2 is busier than 1 but holds the session's prefix
        let snap = snap3([500, 100, 3_000]);
        let mut d = SessionAffinityDispatch;
        assert_eq!(d.choose(&snap.view(), &incoming_at(50, 2)), 2);
        // no preference: degrades to the current-load argmin
        assert_eq!(d.choose(&snap.view(), &incoming(50, None)), 1);
    }

    #[test]
    fn session_affinity_falls_back_when_holder_cannot_take_it() {
        use crate::coordinator::Lifecycle;
        // holder past the admission watermark (9000 of 10000)
        let snap = snap3([500, 100, 8_990]);
        let mut d = SessionAffinityDispatch;
        assert_eq!(d.choose(&snap.view(), &incoming_at(50, 2)), 1);
        // holder draining
        let mut snap = snap3([500, 100, 300]);
        snap.instances[2].lifecycle = Lifecycle::Draining;
        assert_eq!(d.choose(&snap.view(), &incoming_at(50, 2)), 1);
        // holder id out of range (stale preference after pool shrink)
        let snap = snap3([500, 100, 300]);
        assert_eq!(d.choose(&snap.view(), &incoming_at(50, 7)), 1);
    }

    #[test]
    fn session_affinity_counts_cached_bytes_against_the_watermark() {
        // idle cached KV pushes the holder past the watermark exactly like
        // active load would
        let mut snap = snap3([500, 100, 300]);
        snap.instances[2].cached_tokens = 8_700;
        let mut d = SessionAffinityDispatch;
        assert_eq!(d.choose(&snap.view(), &incoming_at(50, 2)), 1);
    }

    #[test]
    fn hardware_aware_routes_long_to_memory_and_short_to_speed() {
        use crate::coordinator::HardwareProfile;
        let mut d = HardwareAwareDispatch::from_config(&PolicyConfig::default());
        // fleet: instance 0 fast but small, instance 1 slow but roomy
        let mut snap = ClusterSnapshot {
            instances: vec![
                inst(0, vec![req(1, 1_000, None)], 10_000),
                inst(1, vec![req(2, 1_000, None)], 40_000),
            ],
            tokens_per_interval: 10.0,
        };
        snap.instances[0].hardware = HardwareProfile {
            speed_mult: 2.0,
            mem_mult: 0.25,
        };
        snap.instances[1].hardware = HardwareProfile {
            speed_mult: 0.5,
            mem_mult: 1.0,
        };
        // long prediction chases free memory: instance 1
        assert_eq!(d.choose(&snap.view(), &incoming(10, Some(5_000.0))), 1);
        // short prediction balances speed-normalized load: 1000/2 = 500
        // on the fast instance vs 1000/0.5 = 2000 on the slow one
        assert_eq!(d.choose(&snap.view(), &incoming(10, Some(50.0))), 0);
        // no prediction counts as short (degrades toward current_load)
        assert_eq!(d.choose(&snap.view(), &incoming(10, None)), 0);
        // the threshold is a policy param
        let mut cfg = PolicyConfig::default();
        cfg.params
            .insert("hardware_aware.long_tokens".to_string(), 40.0);
        let mut d = HardwareAwareDispatch::from_config(&cfg);
        assert_eq!(
            d.choose(&snap.view(), &incoming(10, Some(50.0))),
            1,
            "a 50-token prediction is long once the threshold drops to 40"
        );
    }

    #[test]
    fn noop_reschedule_never_migrates() {
        let snap = snap3([9_000, 0, 0]);
        let mut rs = NoopReschedule::new();
        assert!(rs.decide(&snap.view()).is_empty());
        assert_eq!(rs.stats().intervals, 1);
        assert_eq!(rs.stats().migrations, 0);
    }
}
