//! SLO-aware dispatch: deadline-headroom-weighted placement (à la "Taming
//! Request Imbalance", see PAPERS.md).
//!
//! Rationale: decode iteration time is linear in batched tokens (paper
//! Fig. 8), so an instance's *normalized projected token load* is a direct
//! proxy for the TPOT its requests will see; KV occupancy is a proxy for
//! admission delay (TTFT) and OOM-recompute risk. Each instance gets a
//! deadline-headroom score combining the two, and the request goes to the
//! instance with the most headroom left. Unlike [`PredictedLoadDispatch`],
//! remaining work is truncated at an SLO horizon: work that lands beyond
//! the horizon cannot break a near-term deadline and must not repel
//! placements.
//!
//! [`PredictedLoadDispatch`]: super::PredictedLoadDispatch

use super::builtin::argmin_with_fallback;
use super::{DispatchPolicy, IncomingRequest, PolicyConfig};
use crate::coordinator::cluster_state::{ClusterView, InstanceRef};
use crate::InstanceId;

/// Deadline-headroom-weighted dispatch. Knobs (via `PolicyConfig::params`):
///
/// * `slo_aware.mem_weight`   — weight of immediate KV occupancy (default 1.0)
/// * `slo_aware.load_weight`  — weight of horizon-truncated projected work
///   (default 1.0)
/// * `slo_aware.horizon_tokens` — lookahead in tokens; remaining work past
///   this does not count against near-term deadlines (default 4096)
///
/// Remaining-work estimates are consumed at the configured *balancing*
/// quantile (`[predictor] balance_q`, mean by default) — placement is a
/// balancing decision, not a memory-safety one.
#[derive(Clone, Debug)]
pub struct SloAwareDispatch {
    mem_weight: f64,
    load_weight: f64,
    horizon_tokens: f64,
    q: f64,
}

impl SloAwareDispatch {
    pub fn from_config(cfg: &PolicyConfig) -> Self {
        SloAwareDispatch {
            mem_weight: cfg.param_or("slo_aware.mem_weight", 1.0),
            load_weight: cfg.param_or("slo_aware.load_weight", 1.0),
            horizon_tokens: cfg.param_or("slo_aware.horizon_tokens", 4096.0).max(1.0),
            q: cfg.balance_q,
        }
    }

    /// Pressure score: higher = less deadline headroom. Both terms are
    /// normalized by instance capacity so heterogeneous instances compare
    /// fairly (a half-full big instance beats a half-full small one on
    /// absolute headroom).
    fn pressure(&self, iv: &InstanceRef<'_>, incoming: &IncomingRequest) -> f64 {
        let cap = iv.kv_capacity_tokens().max(1) as f64;
        let mem = (iv.effective_used() + incoming.tokens) as f64 / cap;
        let committed: f64 = iv
            .requests()
            .iter()
            .map(|r| r.tokens as f64 + r.remaining_q(self.q, 0.0).min(self.horizon_tokens))
            .sum::<f64>()
            + iv.inbound_reserved_tokens() as f64
            + incoming.tokens as f64
            + incoming
                .predicted_remaining
                .map_or(0.0, |p| p.quantile(self.q))
                .min(self.horizon_tokens);
        self.mem_weight * mem + self.load_weight * (committed / cap)
    }
}

impl DispatchPolicy for SloAwareDispatch {
    fn name(&self) -> &str {
        "slo_aware"
    }

    fn choose(&mut self, view: &ClusterView<'_>, incoming: &IncomingRequest) -> InstanceId {
        argmin_with_fallback(view, incoming.tokens, |iv| self.pressure(iv, incoming))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::{inst, req};
    use crate::coordinator::ClusterSnapshot;

    fn policy() -> SloAwareDispatch {
        SloAwareDispatch::from_config(&PolicyConfig::default())
    }

    fn incoming(tokens: u64, pred: Option<f64>) -> IncomingRequest {
        IncomingRequest {
            id: 0,
            tokens,
            predicted_remaining: pred.map(crate::predictor::Prediction::exact),
            preferred_instance: None,
        }
    }

    #[test]
    fn horizon_truncates_far_future_work() {
        // instance 0 holds one very long request (most of it beyond the
        // horizon); instance 1 holds several that all finish inside it.
        // Within-horizon committed work: inst0 = 1000 + 4096 (truncated);
        // inst1 = 3 * (1000 + 2000) = 9000 > 5096, so the long-tail
        // instance has MORE deadline headroom and should win.
        let snap = ClusterSnapshot {
            instances: vec![
                inst(0, vec![req(1, 1000, Some(100_000.0))], 100_000),
                inst(
                    1,
                    vec![
                        req(2, 1000, Some(2_000.0)),
                        req(3, 1000, Some(2_000.0)),
                        req(4, 1000, Some(2_000.0)),
                    ],
                    100_000,
                ),
            ],
            tokens_per_interval: 10.0,
        };
        let mut d = policy();
        assert_eq!(d.choose(&snap.view(), &incoming(10, None)), 0);
        // a pure predicted-load policy is repelled by the long tail
        let mut pl = super::super::PredictedLoadDispatch;
        assert_eq!(pl.choose(&snap.view(), &incoming(10, None)), 1);
    }

    #[test]
    fn normalizes_by_capacity() {
        // equal absolute load, but instance 1 has 4x the capacity: its
        // relative pressure is lower
        let snap = ClusterSnapshot {
            instances: vec![
                inst(0, vec![req(1, 5_000, Some(100.0))], 20_000),
                inst(1, vec![req(2, 5_000, Some(100.0))], 80_000),
            ],
            tokens_per_interval: 10.0,
        };
        let mut d = policy();
        assert_eq!(d.choose(&snap.view(), &incoming(10, None)), 1);
    }

    #[test]
    fn no_fit_falls_back_to_least_pressure() {
        let snap = ClusterSnapshot {
            instances: vec![
                inst(0, vec![req(1, 9_990, Some(10.0))], 10_000),
                inst(1, vec![req(2, 9_999, Some(10.0))], 10_000),
            ],
            tokens_per_interval: 10.0,
        };
        let mut d = policy();
        assert_eq!(d.choose(&snap.view(), &incoming(100, None)), 0);
    }

    #[test]
    fn knobs_come_from_config() {
        let mut cfg = PolicyConfig::default();
        cfg.params.insert("slo_aware.horizon_tokens".into(), 50.0);
        let d = SloAwareDispatch::from_config(&cfg);
        assert_eq!(d.horizon_tokens, 50.0);
    }
}
