//! The pluggable scheduling-policy API.
//!
//! Everything the coordinator decides — which decode instance receives a
//! request at prefill→decode hand-off, and which requests migrate between
//! decode instances mid-generation — goes through two object-safe traits:
//!
//! * [`DispatchPolicy`] — hand-off placement (paper §2.2's baselines and
//!   anything smarter);
//! * [`ReschedulePolicy`] — the per-interval migration decision (paper
//!   Algorithm 1 and alternatives).
//!
//! Policies are constructed **by name** through a [`PolicyRegistry`], so
//! config files, CLI flags, and bench scenarios never enumerate concrete
//! types, and third parties can register new strategies without touching
//! coordinator internals. The live server and the simulator both drive
//! policies through the shared [`ControlLoop`], which is what makes
//! simulated results (paper Fig. 13) transfer to the real system.
//!
//! See `DESIGN.md` §5 for the "add a policy in three steps" recipe.
//!
//! [`ControlLoop`]: crate::coordinator::ControlLoop

mod builtin;
mod mem_pressure;
mod registry;
mod slo;

pub use builtin::{
    CurrentLoadDispatch, HardwareAwareDispatch, NoopReschedule, PredictedLoadDispatch,
    RoundRobinDispatch, SessionAffinityDispatch,
};
pub use mem_pressure::MemoryPressureRescheduler;
pub use registry::PolicyRegistry;
pub use slo::SloAwareDispatch;

use std::collections::BTreeMap;

use super::cluster_state::ClusterView;
use super::rescheduler::{MigrationDecision, ReschedulerStats};
use crate::config::{ExperimentConfig, ReschedulerConfig};
use crate::costmodel::MigrationCostModel;
use crate::predictor::Prediction;
use crate::{InstanceId, RequestId};

/// A request at prefill→decode hand-off time, as a dispatch policy sees it.
#[derive(Clone, Copy, Debug)]
pub struct IncomingRequest {
    pub id: RequestId,
    /// KV tokens the request brings with it (prompt, plus any generated
    /// tokens when re-dispatching after OOM recompute or migration).
    pub tokens: u64,
    /// Predicted output length from the prefill-time prediction
    /// (None when prediction is off or not yet available).
    pub predicted_remaining: Option<Prediction>,
    /// Instance holding this request's cached session prefix, if any
    /// (`kvcache::PrefixCache` hit). A preference, not a constraint:
    /// `session_affinity` honors it while the holder is schedulable and
    /// has headroom; every other policy ignores it.
    pub preferred_instance: Option<InstanceId>,
}

/// Prefill→decode placement strategy. Implementations may keep internal
/// state (round-robin keeps a cursor) but must be pure with respect to the
/// view: the caller executes the returned placement.
///
/// Contract: always return an instance id present in the view, even
/// when nothing fits — admission control on the instance queues or OOMs,
/// mirroring vLLM behaviour. Helpers in this module implement the standard
/// "skip instances that cannot fit, fall back to least-loaded" shape.
///
/// The [`ClusterView`] is normally borrowed straight from the drivers'
/// incremental [`ClusterState`]; policies written against a hand-built
/// [`ClusterSnapshot`] pass `snapshot.view()` instead.
///
/// [`ClusterState`]: crate::coordinator::ClusterState
/// [`ClusterSnapshot`]: crate::coordinator::ClusterSnapshot
pub trait DispatchPolicy {
    /// Registry name this policy answers to (diagnostics + reports).
    fn name(&self) -> &str;

    /// Choose a decode instance for `incoming`.
    fn choose(&mut self, view: &ClusterView<'_>, incoming: &IncomingRequest) -> InstanceId;
}

/// Decode-phase rescheduling strategy, invoked once per scheduling
/// interval. Pure with respect to the view: the caller (live runtime
/// or simulator) executes the returned migrations.
pub trait ReschedulePolicy {
    /// Registry name this policy answers to (diagnostics + reports).
    fn name(&self) -> &str;

    /// Run one scheduling interval; returns migrations best-first, at most
    /// `max_migrations_per_interval` of them.
    fn decide(&mut self, view: &ClusterView<'_>) -> Vec<MigrationDecision>;

    /// Operational counters for reports and the §5.2 decision-time claim.
    fn stats(&self) -> ReschedulerStats;

    /// Measured average decode iteration time T̄_exec (the drivers feed
    /// EWMA measurements in before every interval). Default: ignore.
    fn observe_avg_iter_s(&mut self, _avg_iter_s: f64) {}

    /// Running estimate of remaining output length to assume for requests
    /// without a prediction (drivers feed the workload mean in). Default:
    /// ignore.
    fn observe_default_remaining(&mut self, _tokens: f64) {}
}

/// Everything a policy builder may draw on. One config type keeps the
/// registry signature stable as policies grow knobs: well-known structured
/// fields plus a free-form numeric `params` map for policy-specific tuning
/// (populated from `[policy]` config keys, e.g. `slo_aware.mem_weight`).
#[derive(Clone, Debug)]
pub struct PolicyConfig {
    pub rescheduler: ReschedulerConfig,
    pub migration: MigrationCostModel,
    /// Whether length predictions are available (Alg. 1 `usePrediction`).
    pub use_prediction: bool,
    /// Estimate quantile for balancing objectives (`[predictor]
    /// balance_q`, default 0.5 = the mean).
    pub balance_q: f64,
    /// Estimate quantile for OOM-avoidance / migration-target checks
    /// (`[predictor] conservative_q`, default 0.9).
    pub conservative_q: f64,
    /// Policy-specific numeric knobs, keyed `<policy>.<knob>`.
    pub params: BTreeMap<String, f64>,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        PolicyConfig {
            rescheduler: ReschedulerConfig::default(),
            migration: MigrationCostModel::new_25gbps(128 * 1024),
            use_prediction: true,
            balance_q: 0.5,
            conservative_q: 0.9,
            params: BTreeMap::new(),
        }
    }
}

impl PolicyConfig {
    /// Assemble the policy inputs an experiment implies.
    pub fn from_experiment(exp: &ExperimentConfig, migration: MigrationCostModel) -> PolicyConfig {
        PolicyConfig {
            rescheduler: exp.rescheduler.clone(),
            migration,
            use_prediction: exp.predictor_uses_prediction(),
            balance_q: exp.predictor_balance_q,
            conservative_q: exp.predictor_conservative_q,
            params: exp.policy_params.clone(),
        }
    }

    /// Numeric knob lookup with a documented default.
    pub fn param_or(&self, key: &str, default: f64) -> f64 {
        self.params.get(key).copied().unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_lookup_falls_back() {
        let mut cfg = PolicyConfig::default();
        assert_eq!(cfg.param_or("slo_aware.mem_weight", 1.5), 1.5);
        cfg.params.insert("slo_aware.mem_weight".to_string(), 0.25);
        assert_eq!(cfg.param_or("slo_aware.mem_weight", 1.5), 0.25);
    }

    #[test]
    fn from_experiment_inherits_prediction_flag_and_quantiles() {
        let mut exp = ExperimentConfig::default();
        exp.predictor = "none".to_string();
        let cfg = PolicyConfig::from_experiment(&exp, MigrationCostModel::new_25gbps(1));
        assert!(!cfg.use_prediction);
        let mut exp = ExperimentConfig::default();
        exp.predictor = "llm_native".to_string();
        exp.predictor_conservative_q = 0.95;
        exp.predictor_balance_q = 0.4;
        let cfg = PolicyConfig::from_experiment(&exp, MigrationCostModel::new_25gbps(1));
        assert!(cfg.use_prediction);
        assert_eq!(cfg.conservative_q, 0.95);
        assert_eq!(cfg.balance_q, 0.4);
    }
}
