//! String-keyed policy construction: the single place where policy names
//! meet policy types. Config files, CLI flags, bench scenarios, and tests
//! all go through [`PolicyRegistry::build_dispatch`] /
//! [`PolicyRegistry::build_reschedule`]; third-party code extends the set
//! with [`PolicyRegistry::register_dispatch`] /
//! [`PolicyRegistry::register_reschedule`] without touching coordinator
//! internals.

use std::collections::BTreeMap;

use super::{
    CurrentLoadDispatch, DispatchPolicy, HardwareAwareDispatch, MemoryPressureRescheduler,
    NoopReschedule, PolicyConfig, PredictedLoadDispatch, ReschedulePolicy, RoundRobinDispatch,
    SessionAffinityDispatch, SloAwareDispatch,
};
use crate::coordinator::elastic::{
    PredictiveScaling, QueuePressureScaling, ScalingPolicy, StaticScaling,
};
use crate::coordinator::rescheduler::Rescheduler;
use crate::{Error, Result};

type DispatchBuilder = Box<dyn Fn(&PolicyConfig) -> Result<Box<dyn DispatchPolicy>> + Send + Sync>;
type RescheduleBuilder =
    Box<dyn Fn(&PolicyConfig) -> Result<Box<dyn ReschedulePolicy>> + Send + Sync>;
type ScalingBuilder = Box<dyn Fn(&PolicyConfig) -> Result<Box<dyn ScalingPolicy>> + Send + Sync>;

/// Registry of named policy builders. Names are normalized (lowercase,
/// `-` → `_`) and may be aliased, so `--dispatch round-robin`, `rr`, and
/// `round_robin` all resolve to the same builder.
#[derive(Default)]
pub struct PolicyRegistry {
    dispatch: BTreeMap<String, DispatchBuilder>,
    reschedule: BTreeMap<String, RescheduleBuilder>,
    scaling: BTreeMap<String, ScalingBuilder>,
    aliases: BTreeMap<String, String>,
}

fn normalize(name: &str) -> String {
    name.to_ascii_lowercase().replace('-', "_")
}

impl PolicyRegistry {
    /// An empty registry (for fully custom policy sets).
    pub fn new() -> PolicyRegistry {
        PolicyRegistry::default()
    }

    /// The built-in policy set:
    ///
    /// dispatch — `round_robin` (`rr`), `current_load` (`load`),
    /// `predicted_load` (`predicted`), `slo_aware` (`slo`),
    /// `session_affinity` (`affinity`), `hardware_aware` (`hw`);
    /// reschedule — `star`, `memory_pressure` (`mem_pressure`),
    /// `none` (`noop`, `off`);
    /// scaling — `static` (`fixed`), `queue_pressure` (`qp`),
    /// `predictive`.
    pub fn with_builtins() -> PolicyRegistry {
        let mut r = PolicyRegistry::new();
        r.register_dispatch("round_robin", |_| Ok(Box::new(RoundRobinDispatch::new())));
        r.register_dispatch("current_load", |_| Ok(Box::new(CurrentLoadDispatch)));
        r.register_dispatch("predicted_load", |_| Ok(Box::new(PredictedLoadDispatch)));
        r.register_dispatch("slo_aware", |cfg| {
            Ok(Box::new(SloAwareDispatch::from_config(cfg)))
        });
        r.register_dispatch("session_affinity", |_| Ok(Box::new(SessionAffinityDispatch)));
        r.register_dispatch("hardware_aware", |cfg| {
            Ok(Box::new(HardwareAwareDispatch::from_config(cfg)))
        });
        r.register_reschedule("star", |cfg| Ok(Box::new(Rescheduler::from_config(cfg))));
        r.register_reschedule("memory_pressure", |cfg| {
            Ok(Box::new(MemoryPressureRescheduler::from_config(cfg)))
        });
        r.register_reschedule("none", |_| Ok(Box::new(NoopReschedule::new())));
        r.register_scaling("static", |_| Ok(Box::new(StaticScaling)));
        r.register_scaling("queue_pressure", |cfg| {
            Ok(Box::new(QueuePressureScaling::from_config(cfg)))
        });
        r.register_scaling("predictive", |cfg| {
            Ok(Box::new(PredictiveScaling::from_config(cfg)))
        });
        r.alias("fixed", "static");
        r.alias("qp", "queue_pressure");
        r.alias("rr", "round_robin");
        r.alias("load", "current_load");
        r.alias("predicted", "predicted_load");
        r.alias("slo", "slo_aware");
        r.alias("affinity", "session_affinity");
        r.alias("hw", "hardware_aware");
        r.alias("mem_pressure", "memory_pressure");
        r.alias("noop", "none");
        r.alias("off", "none");
        r
    }

    /// Register (or replace) a dispatch-policy builder under `name`.
    pub fn register_dispatch<F>(&mut self, name: &str, builder: F)
    where
        F: Fn(&PolicyConfig) -> Result<Box<dyn DispatchPolicy>> + Send + Sync + 'static,
    {
        self.dispatch.insert(normalize(name), Box::new(builder));
    }

    /// Register (or replace) a reschedule-policy builder under `name`.
    pub fn register_reschedule<F>(&mut self, name: &str, builder: F)
    where
        F: Fn(&PolicyConfig) -> Result<Box<dyn ReschedulePolicy>> + Send + Sync + 'static,
    {
        self.reschedule.insert(normalize(name), Box::new(builder));
    }

    /// Register (or replace) a scaling-policy builder under `name`.
    pub fn register_scaling<F>(&mut self, name: &str, builder: F)
    where
        F: Fn(&PolicyConfig) -> Result<Box<dyn ScalingPolicy>> + Send + Sync + 'static,
    {
        self.scaling.insert(normalize(name), Box::new(builder));
    }

    /// Make `alias` resolve to `canonical` in both namespaces.
    pub fn alias(&mut self, alias: &str, canonical: &str) {
        self.aliases.insert(normalize(alias), normalize(canonical));
    }

    /// Look `name` up in one namespace: a direct registration always wins
    /// over an alias, so `register_*` under an alias-colliding name really
    /// does replace what the name builds, and an alias pointing into the
    /// *other* namespace can never hijack a lookup.
    fn lookup<'a, T>(&self, map: &'a BTreeMap<String, T>, name: &str) -> Option<&'a T> {
        let n = normalize(name);
        if let Some(b) = map.get(&n) {
            return Some(b);
        }
        self.aliases.get(&n).and_then(|canon| map.get(canon))
    }

    pub fn has_dispatch(&self, name: &str) -> bool {
        self.lookup(&self.dispatch, name).is_some()
    }

    pub fn has_reschedule(&self, name: &str) -> bool {
        self.lookup(&self.reschedule, name).is_some()
    }

    pub fn has_scaling(&self, name: &str) -> bool {
        self.lookup(&self.scaling, name).is_some()
    }

    /// Construct the named dispatch policy.
    pub fn build_dispatch(&self, name: &str, cfg: &PolicyConfig) -> Result<Box<dyn DispatchPolicy>> {
        match self.lookup(&self.dispatch, name) {
            Some(b) => b(cfg),
            None => Err(Error::config(format!(
                "unknown dispatch policy `{name}` (known: {})",
                self.dispatch_names().join("|")
            ))),
        }
    }

    /// Construct the named reschedule policy.
    pub fn build_reschedule(
        &self,
        name: &str,
        cfg: &PolicyConfig,
    ) -> Result<Box<dyn ReschedulePolicy>> {
        match self.lookup(&self.reschedule, name) {
            Some(b) => b(cfg),
            None => Err(Error::config(format!(
                "unknown reschedule policy `{name}` (known: {})",
                self.reschedule_names().join("|")
            ))),
        }
    }

    /// Construct the named scaling policy.
    pub fn build_scaling(&self, name: &str, cfg: &PolicyConfig) -> Result<Box<dyn ScalingPolicy>> {
        match self.lookup(&self.scaling, name) {
            Some(b) => b(cfg),
            None => Err(Error::config(format!(
                "unknown scaling policy `{name}` (known: {})",
                self.scaling_names().join("|")
            ))),
        }
    }

    /// Registered canonical dispatch names, sorted.
    pub fn dispatch_names(&self) -> Vec<String> {
        self.dispatch.keys().cloned().collect()
    }

    /// Registered canonical reschedule names, sorted.
    pub fn reschedule_names(&self) -> Vec<String> {
        self.reschedule.keys().cloned().collect()
    }

    /// Registered canonical scaling names, sorted.
    pub fn scaling_names(&self) -> Vec<String> {
        self.scaling.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::{inst, req};
    use crate::coordinator::{ClusterSnapshot, ClusterView, IncomingRequest};

    fn snap() -> ClusterSnapshot {
        ClusterSnapshot {
            instances: vec![
                inst(0, vec![req(1, 500, None)], 10_000),
                inst(1, vec![req(2, 100, None)], 10_000),
            ],
            tokens_per_interval: 10.0,
        }
    }

    #[test]
    fn builds_every_builtin_by_name_and_alias() {
        let reg = PolicyRegistry::with_builtins();
        let cfg = PolicyConfig::default();
        for name in ["round_robin", "rr", "Round-Robin", "current_load", "load",
                     "predicted_load", "predicted", "slo_aware", "slo",
                     "session_affinity", "affinity", "hardware_aware", "hw"] {
            let mut p = reg.build_dispatch(name, &cfg).unwrap();
            let id = p.choose(&snap().view(), &IncomingRequest {
                id: 0,
                tokens: 10,
                predicted_remaining: None,
                preferred_instance: None,
            });
            assert!(id < 2, "{name} returned bogus instance");
        }
        for name in ["star", "memory_pressure", "mem_pressure", "none", "noop", "off"] {
            let mut p = reg.build_reschedule(name, &cfg).unwrap();
            let _ = p.decide(&snap().view());
            assert_eq!(p.stats().intervals, 1, "{name} must count intervals");
        }
    }

    #[test]
    fn builds_every_builtin_scaling_policy() {
        use crate::coordinator::elastic::PoolStats;
        let reg = PolicyRegistry::with_builtins();
        let cfg = PolicyConfig::default();
        for name in ["static", "fixed", "queue_pressure", "qp", "Queue-Pressure", "predictive"] {
            let mut p = reg.build_scaling(name, &cfg).unwrap();
            let pool = PoolStats {
                prefill_active: 1,
                decode_active: 2,
                ..Default::default()
            };
            // must not panic; static/fixed must do nothing
            let acts = p.decide(&snap().view(), &pool);
            if p.name() == "static" {
                assert!(acts.is_empty());
            }
        }
        assert!(reg.has_scaling("predictive"));
        assert!(!reg.has_scaling("bogus"));
        let e = reg.build_scaling("bogus", &cfg).unwrap_err().to_string();
        assert!(e.contains("unknown scaling policy `bogus`"), "{e}");
        assert!(e.contains("queue_pressure"), "{e}");
        assert_eq!(
            reg.scaling_names(),
            vec!["predictive", "queue_pressure", "static"]
        );
    }

    #[test]
    fn unknown_names_error_with_known_list() {
        let reg = PolicyRegistry::with_builtins();
        let cfg = PolicyConfig::default();
        let e = reg.build_dispatch("nope", &cfg).unwrap_err().to_string();
        assert!(e.contains("unknown dispatch policy `nope`"), "{e}");
        assert!(e.contains("current_load"), "{e}");
        let e = reg.build_reschedule("nope", &cfg).unwrap_err().to_string();
        assert!(e.contains("star"), "{e}");
    }

    #[test]
    fn third_party_registration_and_override() {
        let mut reg = PolicyRegistry::with_builtins();
        struct Pin(usize);
        impl crate::coordinator::DispatchPolicy for Pin {
            fn name(&self) -> &str {
                "pin"
            }
            fn choose(&mut self, _s: &ClusterView<'_>, _i: &IncomingRequest) -> usize {
                self.0
            }
        }
        reg.register_dispatch("pin", |_| Ok(Box::new(Pin(1))));
        let mut p = reg
            .build_dispatch("pin", &PolicyConfig::default())
            .unwrap();
        let id = p.choose(&snap().view(), &IncomingRequest {
            id: 9,
            tokens: 1,
            predicted_remaining: None,
            preferred_instance: None,
        });
        assert_eq!(id, 1);
        assert!(reg.has_dispatch("pin"));
        assert!(!reg.has_dispatch("unpin"));

        // a direct registration under an alias-colliding name wins over
        // the alias ("load" aliases current_load in the builtins)
        reg.register_dispatch("load", |_| Ok(Box::new(Pin(0))));
        let mut p = reg.build_dispatch("load", &PolicyConfig::default()).unwrap();
        let id = p.choose(
            &snap().view(),
            &IncomingRequest {
                id: 1,
                tokens: 1,
                predicted_remaining: None,
                preferred_instance: None,
            },
        );
        assert_eq!(id, 0, "direct registration must shadow the alias");

        // a dispatch alias must not hijack the reschedule namespace
        reg.register_reschedule("slo", |_| {
            Ok(Box::new(crate::coordinator::policy::NoopReschedule::new()))
        });
        assert!(reg.has_reschedule("slo"));
        reg.build_reschedule("slo", &PolicyConfig::default())
            .expect("reschedule registered under a dispatch-alias name");
    }

    #[test]
    fn star_reschedules_through_the_trait() {
        let reg = PolicyRegistry::with_builtins();
        let mut cfg = PolicyConfig::default();
        cfg.rescheduler.horizon = 4;
        cfg.migration = crate::costmodel::MigrationCostModel {
            bandwidth_bps: 1e12,
            latency_s: 1e-4,
            bytes_per_token: 1,
        };
        let mut star = reg.build_reschedule("star", &cfg).unwrap();
        let s = ClusterSnapshot {
            instances: vec![
                inst(
                    0,
                    vec![req(1, 3000, Some(4000.0)), req(2, 3000, Some(4000.0))],
                    1_000_000,
                ),
                inst(1, vec![req(3, 500, Some(100.0))], 1_000_000),
            ],
            tokens_per_interval: 50.0,
        };
        let ds = star.decide(&s.view());
        assert_eq!(ds.len(), 1);
        assert_eq!(ds[0].src, 0);
        assert_eq!(star.stats().migrations, 1);
    }
}
