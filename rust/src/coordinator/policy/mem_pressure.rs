//! Memory-pressure rescheduler: migrates on *projected KV-OOM* rather than
//! on load variance.
//!
//! The STAR rescheduler (Algorithm 1) optimizes the time-weighted variance
//! objective; OOM avoidance falls out of its memory-safety constraint.
//! This policy inverts the priorities: it only acts when an instance's
//! projected KV occupancy over the horizon crosses a trigger fraction of
//! capacity, then sheds the requests whose projected footprint contributes
//! most to the peak. A cluster can be perfectly variance-balanced and
//! still OOM when capacities are heterogeneous or growth is concentrated —
//! this policy covers exactly that gap (the paper's Issue 1, without the
//! Eq. 4 objective).

use std::time::Instant;

use super::{PolicyConfig, ReschedulePolicy};
use crate::config::ReschedulerConfig;
use crate::coordinator::cluster_state::{admission_watermark, ClusterView, InstanceRef};
use crate::coordinator::future_load::{beta_schedule, FutureLoad, WorkerReport};
use crate::coordinator::rescheduler::{MigrationDecision, ReschedulerStats};
use crate::costmodel::MigrationCostModel;

/// KV-OOM-avoidance rescheduler. Knobs (via `PolicyConfig::params`):
///
/// * `memory_pressure.trigger_frac` — projected-peak fraction of capacity
///   that marks an instance as at risk (default 0.85). Targets must stay
///   below it after receiving a migration.
///
/// Every projection here is a memory-safety question, so remaining-length
/// estimates are consumed at the configured *conservative* quantile
/// (`Prediction::quantile(conservative_q)`, p90 by default): an uncertain
/// length must be assumed long before this policy banks on headroom.
#[derive(Clone, Debug)]
pub struct MemoryPressureRescheduler {
    cfg: ReschedulerConfig,
    migration: MigrationCostModel,
    use_prediction: bool,
    trigger_frac: f64,
    avg_iter_s: f64,
    default_remaining: f64,
    balance_q: f64,
    conservative_q: f64,
    betas: Vec<f64>,
    stats: ReschedulerStats,
}

impl MemoryPressureRescheduler {
    pub fn from_config(cfg: &PolicyConfig) -> Self {
        let betas = beta_schedule(cfg.rescheduler.horizon, cfg.rescheduler.beta_decay);
        MemoryPressureRescheduler {
            trigger_frac: cfg
                .param_or("memory_pressure.trigger_frac", 0.85)
                .clamp(0.05, 1.0),
            avg_iter_s: cfg.rescheduler.initial_avg_iter_s,
            default_remaining: cfg.rescheduler.default_remaining,
            balance_q: cfg.balance_q,
            conservative_q: cfg.conservative_q,
            use_prediction: cfg.use_prediction,
            migration: cfg.migration,
            cfg: cfg.rescheduler.clone(),
            betas,
            stats: ReschedulerStats::default(),
        }
    }

    /// Projected peak occupancy of a report (shared definition with the
    /// STAR memory-safety check).
    fn peak(rep: &WorkerReport) -> f64 {
        rep.projected_peak()
    }

    /// One migration. Every instance projected past the trigger is a
    /// potential source, hottest first — a stuck hottest instance (nothing
    /// movable, no feasible target) must not starve relief for the next
    /// one over the line.
    fn decide_one(
        &mut self,
        insts: &[InstanceRef<'_>],
        g: f64,
        reports: &[WorkerReport],
        decided: &[crate::RequestId],
    ) -> Option<MigrationDecision> {
        let n = reports.len();
        if n < 2 {
            return None;
        }
        let frac = |i: usize| Self::peak(&reports[i]) / reports[i].kv_capacity_tokens.max(1) as f64;
        let mut sources: Vec<usize> = (0..n).filter(|&i| frac(i) > self.trigger_frac).collect();
        sources.sort_by(|&a, &b| frac(b).total_cmp(&frac(a)));
        sources
            .into_iter()
            .find_map(|src| self.decide_for_source(insts, g, reports, src, decided))
    }

    /// Best migration off one over-trigger source, or None if nothing
    /// movable has a feasible target.
    ///
    /// Candidate ranking is by *exact* peak relief (source projected peak
    /// with vs. without the request's load trace) and is order-independent:
    /// prefer the cheapest request (fewest KV tokens to transfer) whose
    /// relief alone brings the source back under the trigger; if none
    /// suffices, take the largest relief.
    fn decide_for_source(
        &mut self,
        insts: &[InstanceRef<'_>],
        g: f64,
        reports: &[WorkerReport],
        src: usize,
        decided: &[crate::RequestId],
    ) -> Option<MigrationDecision> {
        let n = reports.len();
        let horizon = self.cfg.horizon;
        let default_rem = if self.use_prediction {
            None
        } else {
            Some(self.default_remaining)
        };
        let src_rep = &reports[src];
        let safe_level = self.trigger_frac * src_rep.kv_capacity_tokens as f64;

        // (kv_tokens, decision) of the cheapest sufficient candidate
        let mut best_sufficient: Option<(u64, MigrationDecision)> = None;
        // (relief, decision) of the best insufficient fallback
        let mut best_any: Option<(f64, MigrationDecision)> = None;
        for r in insts[src].requests() {
            // the views cannot change between same-interval rounds, so a
            // request already chosen this interval must be skipped here
            if r.migrating || decided.contains(&r.id) {
                continue;
            }
            let rem = match (self.use_prediction, r.predicted_remaining) {
                (true, Some(p)) => p.mean,
                (true, None) => continue, // not yet predicted
                (false, _) => self.default_remaining,
            };
            // migration must amortize (same bound as Alg. 1 line 20;
            // judged on the mean — the balanced expectation)
            if rem <= self.migration.overhead_iterations(r.tokens, self.avg_iter_s) {
                continue;
            }
            // peak math is all OOM-avoidance: conservative quantile
            let fl = FutureLoad::of_request(r, g, horizon, default_rem, self.conservative_q);
            // exact peak relief: source peak with vs. without this request
            let peak_without = src_rep
                .load_hi
                .iter()
                .zip(&fl.trace)
                .map(|(l, c)| l - c)
                .fold(0.0, f64::max)
                + src_rep.inbound_reserved_tokens as f64;
            let relief = Self::peak(src_rep) - peak_without;
            if relief <= 0.0 {
                continue;
            }
            let sufficient = peak_without <= safe_level;
            // skip the target search when this candidate cannot improve on
            // the current best in its class
            let beats_sufficient = best_sufficient
                .as_ref()
                .map(|(kv, _)| r.tokens < *kv)
                .unwrap_or(true);
            let beats_any = best_any
                .as_ref()
                .map(|(rel, _)| relief > *rel)
                .unwrap_or(true);
            let worth_trying = if sufficient {
                beats_sufficient
            } else {
                best_sufficient.is_none() && beats_any
            };
            if !worth_trying {
                continue;
            }
            // safest feasible target: lowest post-move projected fraction,
            // and it must stay below the trigger itself
            let fl_peak = fl.trace.iter().cloned().fold(0.0, f64::max);
            let mut target: Option<(f64, usize)> = None;
            for t in 0..n {
                if t == src || !insts[t].is_schedulable() {
                    continue;
                }
                self.stats.candidates_evaluated += 1;
                // the target must be able to re-admit the arriving KV
                // (driver admission watermark), whatever trigger_frac is
                if r.tokens > admission_watermark(reports[t].kv_capacity_tokens) {
                    continue;
                }
                let cap = reports[t].kv_capacity_tokens as f64;
                let after_peak = Self::peak(&reports[t]) + fl_peak;
                let safe_cap = cap * (1.0 - self.cfg.mem_safety_frac);
                let after_frac = after_peak / cap.max(1.0);
                if after_peak > safe_cap || after_frac >= self.trigger_frac {
                    continue;
                }
                if target.map(|(f, _)| after_frac < f).unwrap_or(true) {
                    target = Some((after_frac, t));
                }
            }
            if let Some((_, dst)) = target {
                let decision = MigrationDecision {
                    request: r.id,
                    src: insts[src].id(),
                    dst: insts[dst].id(),
                    kv_tokens: r.tokens,
                    // objective here is "projected peak tokens averted",
                    // not a variance delta; still monotone in usefulness
                    var_reduction: relief,
                };
                if sufficient {
                    best_sufficient = Some((r.tokens, decision));
                } else {
                    best_any = Some((relief, decision));
                }
            }
        }
        best_sufficient
            .map(|(_, d)| d)
            .or(best_any.map(|(_, d)| d))
    }

    /// Replay an accepted move onto the reports so a second decision in
    /// the same interval sees the updated projections.
    fn apply_to_reports(
        &self,
        insts: &[InstanceRef<'_>],
        g: f64,
        reports: &mut [WorkerReport],
        d: &MigrationDecision,
    ) {
        let find = |id| {
            insts
                .iter()
                .position(|iv| iv.id() == id)
                .expect("decision instance present")
        };
        let (s_idx, d_idx) = (find(d.src), find(d.dst));
        let r = insts[s_idx]
            .requests()
            .iter()
            .find(|r| r.id == d.request)
            .expect("decision request present");
        let default_rem = if self.use_prediction {
            None
        } else {
            Some(self.default_remaining)
        };
        let fl = FutureLoad::of_request(r, g, self.cfg.horizon, default_rem, self.balance_q);
        let fh = FutureLoad::of_request(r, g, self.cfg.horizon, default_rem, self.conservative_q);
        for t in 0..fl.trace.len() {
            reports[s_idx].load[t] -= fl.trace[t];
            reports[d_idx].load[t] += fl.trace[t];
            reports[s_idx].load_hi[t] -= fh.trace[t];
            reports[d_idx].load_hi[t] += fh.trace[t];
        }
        reports[s_idx].current_tokens = reports[s_idx].current_tokens.saturating_sub(d.kv_tokens);
        reports[d_idx].current_tokens += d.kv_tokens;
    }
}

impl ReschedulePolicy for MemoryPressureRescheduler {
    fn name(&self) -> &str {
        "memory_pressure"
    }

    fn decide(&mut self, view: &ClusterView<'_>) -> Vec<MigrationDecision> {
        // ANALYZE-OK: R2 profiles the solver (max_decision_us), never sim time
        let t0 = Instant::now();
        self.stats.intervals += 1;
        // same working-set rule as the STAR rescheduler: draining
        // instances remain sources (shedding helps the drain), retired /
        // provisioning slots are out entirely
        let insts: Vec<InstanceRef<'_>> = view
            .instances()
            .filter(|iv| {
                matches!(
                    iv.lifecycle(),
                    crate::coordinator::Lifecycle::Active | crate::coordinator::Lifecycle::Draining
                )
            })
            .collect();
        let g = view.tokens_per_interval();
        let default_rem = if self.use_prediction {
            None
        } else {
            Some(self.default_remaining)
        };
        let mut reports: Vec<WorkerReport> = insts
            .iter()
            .map(|v| {
                WorkerReport::compute(
                    v,
                    g,
                    &self.betas,
                    default_rem,
                    self.balance_q,
                    self.conservative_q,
                )
            })
            .collect();

        let mut decisions = Vec::new();
        let mut decided: Vec<crate::RequestId> = Vec::new();
        for _ in 0..self.cfg.max_migrations_per_interval {
            match self.decide_one(&insts, g, &reports, &decided) {
                None => break,
                Some(d) => {
                    self.apply_to_reports(&insts, g, &mut reports, &d);
                    decided.push(d.request);
                    decisions.push(d);
                    self.stats.migrations += 1;
                }
            }
        }

        let us = t0.elapsed().as_micros() as u64;
        self.stats.last_decision_us = us;
        self.stats.max_decision_us = self.stats.max_decision_us.max(us);
        decisions
    }

    fn stats(&self) -> ReschedulerStats {
        self.stats.clone()
    }

    fn observe_avg_iter_s(&mut self, avg_iter_s: f64) {
        self.avg_iter_s = avg_iter_s;
    }

    fn observe_default_remaining(&mut self, tokens: f64) {
        self.default_remaining = tokens;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::{inst, req};
    use crate::coordinator::ClusterSnapshot;

    fn policy() -> MemoryPressureRescheduler {
        let mut cfg = PolicyConfig::default();
        cfg.rescheduler.horizon = 4;
        cfg.migration = MigrationCostModel {
            bandwidth_bps: 1e12,
            latency_s: 1e-4,
            bytes_per_token: 1,
        };
        MemoryPressureRescheduler::from_config(&cfg)
    }

    #[test]
    fn below_trigger_never_migrates() {
        // plenty of headroom everywhere, even with skewed loads (a
        // variance policy WOULD act here)
        let snap = ClusterSnapshot {
            instances: vec![
                inst(0, vec![req(1, 30_000, Some(4_000.0))], 100_000),
                inst(1, vec![req(2, 1_000, Some(100.0))], 100_000),
            ],
            tokens_per_interval: 50.0,
        };
        let mut rs = policy();
        assert!(rs.decide(&snap.view()).is_empty());
        assert_eq!(rs.stats().intervals, 1);
    }

    #[test]
    fn projected_oom_triggers_migration_despite_balanced_loads() {
        // equal current loads (zero variance) but instance 0 has half the
        // capacity: its projected occupancy crosses the trigger
        let mut snap = ClusterSnapshot {
            instances: vec![
                inst(0, vec![req(1, 40_000, Some(20_000.0))], 50_000),
                inst(1, vec![req(2, 40_000, Some(200.0))], 200_000),
            ],
            tokens_per_interval: 1_000.0,
        };
        snap.instances[0].requests.push(req(3, 2_000, Some(20_000.0)));
        let mut rs = policy();
        let ds = rs.decide(&snap.view());
        assert_eq!(ds.len(), 1);
        assert_eq!(ds[0].src, 0);
        assert_eq!(ds[0].dst, 1);
        assert!(ds[0].var_reduction > 0.0);
    }

    #[test]
    fn prefers_cheapest_sufficient_relief() {
        // either request's removal brings the source back under the
        // trigger; the policy must pick the cheaper transfer (request 2,
        // 18K tokens) rather than whichever happens to be listed first
        let snap = ClusterSnapshot {
            instances: vec![
                inst(
                    0,
                    vec![req(1, 30_000, Some(30_000.0)), req(2, 18_000, Some(50.0))],
                    50_000,
                ),
                inst(1, vec![req(3, 1_000, Some(100.0))], 200_000),
            ],
            tokens_per_interval: 1_000.0,
        };
        let mut rs = policy();
        let ds = rs.decide(&snap.view());
        assert_eq!(ds.len(), 1);
        assert_eq!(ds[0].request, 2, "cheapest sufficient move wins");
        assert!(ds[0].var_reduction > 0.0);
        // same snapshot with the requests listed in the other order must
        // pick the same request (order independence)
        let mut swapped = snap.clone();
        swapped.instances[0].requests.reverse();
        let ds2 = policy().decide(&swapped.view());
        assert_eq!(ds2.len(), 1);
        assert_eq!(ds2[0].request, 2);
    }

    #[test]
    fn falls_back_to_largest_relief_when_nothing_suffices() {
        // projected peak 80K on a 50K instance (trigger level 42.5K): no
        // single move clears the trigger, so the largest peak relief wins
        // (30K request over the 8K one)
        let snap = ClusterSnapshot {
            instances: vec![
                inst(
                    0,
                    vec![
                        req(1, 30_000, Some(30_000.0)),
                        req(2, 30_000, Some(30_000.0)),
                        req(3, 8_000, Some(30_000.0)),
                    ],
                    50_000,
                ),
                inst(1, vec![req(4, 1_000, Some(100.0))], 500_000),
            ],
            tokens_per_interval: 1_000.0,
        };
        let mut rs = policy();
        let ds = rs.decide(&snap.view());
        assert_eq!(ds.len(), 1);
        assert_eq!(ds[0].request, 1, "largest relief, first on ties");
    }

    #[test]
    fn stuck_hottest_source_does_not_starve_the_next_one() {
        // instance 0 is hottest but its only request is mid-migration;
        // instance 1 is also over the trigger and CAN shed — it must not
        // be starved by the stuck argmax
        let mut snap = ClusterSnapshot {
            instances: vec![
                inst(0, vec![req(1, 49_000, Some(10_000.0))], 50_000),
                inst(1, vec![req(2, 44_000, Some(10_000.0))], 50_000),
                inst(2, vec![req(3, 1_000, Some(100.0))], 500_000),
            ],
            tokens_per_interval: 1_000.0,
        };
        snap.instances[0].requests[0].migrating = true;
        let mut rs = policy();
        let ds = rs.decide(&snap.view());
        assert_eq!(ds.len(), 1);
        assert_eq!(ds[0].src, 1);
        assert_eq!(ds[0].dst, 2);
        assert_eq!(ds[0].request, 2);
    }

    #[test]
    fn unsafe_targets_rejected() {
        // the only other instance is itself near the trigger: no move
        let snap = ClusterSnapshot {
            instances: vec![
                inst(0, vec![req(1, 48_000, Some(10_000.0))], 50_000),
                inst(1, vec![req(2, 45_000, Some(10_000.0))], 56_000),
            ],
            tokens_per_interval: 1_000.0,
        };
        let mut rs = policy();
        assert!(rs.decide(&snap.view()).is_empty());
    }

    #[test]
    fn near_complete_requests_not_migrated() {
        let mut cfg = PolicyConfig::default();
        cfg.rescheduler.horizon = 4;
        cfg.migration = MigrationCostModel {
            bandwidth_bps: 1e3, // very slow link
            latency_s: 1e-4,
            bytes_per_token: 1_000,
        };
        let mut rs = MemoryPressureRescheduler::from_config(&cfg);
        let snap = ClusterSnapshot {
            instances: vec![
                inst(0, vec![req(1, 48_000, Some(3.0))], 50_000),
                inst(1, vec![req(2, 1_000, Some(100.0))], 200_000),
            ],
            tokens_per_interval: 1_000.0,
        };
        assert!(rs.decide(&snap.view()).is_empty());
    }

    #[test]
    fn respects_max_migrations_per_interval() {
        let mut cfg = PolicyConfig::default();
        cfg.rescheduler.horizon = 4;
        cfg.rescheduler.max_migrations_per_interval = 2;
        cfg.migration = MigrationCostModel {
            bandwidth_bps: 1e12,
            latency_s: 1e-4,
            bytes_per_token: 1,
        };
        let mut rs = MemoryPressureRescheduler::from_config(&cfg);
        let snap = ClusterSnapshot {
            instances: vec![
                inst(
                    0,
                    vec![
                        req(1, 20_000, Some(30_000.0)),
                        req(2, 20_000, Some(30_000.0)),
                        req(3, 8_000, Some(30_000.0)),
                    ],
                    50_000,
                ),
                inst(1, vec![req(4, 1_000, Some(100.0))], 500_000),
            ],
            tokens_per_interval: 1_000.0,
        };
        let ds = rs.decide(&snap.view());
        assert!(ds.len() <= 2);
        assert!(!ds.is_empty());
        assert_eq!(rs.stats().migrations as usize, ds.len());
    }
}
