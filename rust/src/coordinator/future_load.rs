//! Worker-side future-load pre-simulation (paper §5.2 "Algorithm Design").
//!
//! Each worker projects its own token load over the prediction horizon H
//! once per scheduling interval — O(R·H) — so the scheduler's per-candidate
//! evaluation is O(H) via incremental source/target updates. This is the
//! paper's optimized complexity `O(n + |O|·|U|·R_max·H)`.
//!
//! Projection model (the same one the paper's simulator uses): during one
//! scheduling interval every active request generates `g ≈ interval /
//! avg_iter_time` tokens; a request with predicted remaining N̂(r) ≤ g·t
//! has completed by step t and frees its KV, contributing 0.

use super::cluster_state::InstanceRef;
use super::RequestView;

/// Per-request projected contribution to instance load at steps 0..=H.
/// `trace[t]` = tokens this request holds at future step t.
#[derive(Clone, Debug)]
pub struct FutureLoad {
    pub trace: Vec<f64>,
}

impl FutureLoad {
    /// Project one request. `g` = tokens per interval, `default_remaining`
    /// = assumed remaining when prediction is off (paper "w/o prediction":
    /// the scheduler only trusts current state, so the projection holds
    /// the request's load flat).
    pub fn of_request(r: &RequestView, g: f64, horizon: usize, default_remaining: Option<f64>) -> FutureLoad {
        let mut trace = Vec::with_capacity(horizon + 1);
        trace.push(r.tokens as f64);
        match r.predicted_remaining.or(default_remaining) {
            Some(rem) => {
                for t in 1..=horizon {
                    let gen = g * t as f64;
                    if gen >= rem {
                        trace.push(0.0); // completed and freed
                    } else {
                        trace.push(r.tokens as f64 + gen);
                    }
                }
            }
            None => {
                // prediction off: assume the request persists at current
                // load + growth (no completion knowledge)
                for t in 1..=horizon {
                    trace.push(r.tokens as f64 + g * t as f64);
                }
            }
        }
        FutureLoad { trace }
    }
}

/// What a worker reports to the scheduler each interval: its identity,
/// the H-step aggregate load trace, and per-request projections (needed
/// only for requests that become migration candidates).
#[derive(Clone, Debug)]
pub struct WorkerReport {
    pub instance: usize,
    /// Aggregate projected load: `load[t]` = Σ_r trace_r[t], t in 0..=H.
    pub load: Vec<f64>,
    /// Weighted workload w_i = Σ_{t=1..H} β_t · load[t] (Alg. 1 line 13).
    pub weighted: f64,
    pub current_tokens: u64,
    pub kv_capacity_tokens: u64,
    pub inbound_reserved_tokens: u64,
}

impl WorkerReport {
    /// Compute a report from an instance view — the "worker-side
    /// pre-simulation" step. `betas[t-1]` weights future step t.
    pub fn compute(
        view: &InstanceRef<'_>,
        g: f64,
        betas: &[f64],
        default_remaining: Option<f64>,
    ) -> WorkerReport {
        let horizon = betas.len();
        let mut load = vec![0.0; horizon + 1];
        for r in view.requests() {
            let fl = FutureLoad::of_request(r, g, horizon, default_remaining);
            for (t, v) in fl.trace.iter().enumerate() {
                load[t] += v;
            }
        }
        let weighted = betas
            .iter()
            .enumerate()
            .map(|(i, b)| b * load[i + 1])
            .sum();
        WorkerReport {
            instance: view.id(),
            load,
            weighted,
            current_tokens: view.token_load(),
            kv_capacity_tokens: view.kv_capacity_tokens(),
            inbound_reserved_tokens: view.inbound_reserved_tokens(),
        }
    }

    /// Projected peak KV occupancy over the horizon, tokens: the load
    /// trace maximum plus capacity already promised to in-flight
    /// migrations. The single definition both the STAR memory-safety
    /// check and the memory-pressure trigger rest on.
    pub fn projected_peak(&self) -> f64 {
        self.load.iter().cloned().fold(0.0, f64::max) + self.inbound_reserved_tokens as f64
    }

    /// Projected free KV headroom at the *worst* point of the horizon
    /// (used for the target-side memory-safety check, Alg. 1 line 21).
    pub fn min_free_over_horizon(&self) -> f64 {
        self.kv_capacity_tokens as f64 - self.projected_peak()
    }
}

/// Geometric β schedule β_t = decay^t, t = 1..=H (Eq. 4's weights).
pub fn beta_schedule(horizon: usize, decay: f64) -> Vec<f64> {
    (1..=horizon).map(|t| decay.powi(t as i32)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::{inst, req};

    #[test]
    fn future_load_completes_and_frees() {
        let r = req(1, 100, Some(25.0));
        let fl = FutureLoad::of_request(&r, 10.0, 4, None);
        // t=0: 100; t=1: 110; t=2: 120; t=3 (gen=30 >= 25): 0
        assert_eq!(fl.trace, vec![100.0, 110.0, 120.0, 0.0, 0.0]);
    }

    #[test]
    fn future_load_without_prediction_grows_flat() {
        let r = req(1, 100, None);
        let fl = FutureLoad::of_request(&r, 10.0, 2, None);
        assert_eq!(fl.trace, vec![100.0, 110.0, 120.0]);
    }

    #[test]
    fn report_aggregates_requests() {
        let v = inst(0, vec![req(1, 100, Some(1000.0)), req(2, 50, Some(5.0))], 10_000);
        let betas = beta_schedule(2, 0.5);
        let rep = WorkerReport::compute(&v.view(), 10.0, &betas, None);
        // t=0: 150; t=1: 110+0(done: 10>=5)=110; t=2: 120
        assert_eq!(rep.load, vec![150.0, 110.0, 120.0]);
        let expect_w = 0.5 * 110.0 + 0.25 * 120.0;
        assert!((rep.weighted - expect_w).abs() < 1e-9);
        assert_eq!(rep.current_tokens, 150);
    }

    #[test]
    fn min_free_accounts_for_peak_and_reservations() {
        let mut v = inst(0, vec![req(1, 100, Some(1000.0))], 200);
        v.inbound_reserved_tokens = 50;
        let rep = WorkerReport::compute(&v.view(), 30.0, &beta_schedule(2, 1.0), None);
        // peak load = 160 at t=2, +50 reserved => free = 200-210 = -10
        assert!((rep.min_free_over_horizon() - (-10.0)).abs() < 1e-9);
    }

    #[test]
    fn beta_schedule_geometric() {
        let b = beta_schedule(3, 0.7);
        assert!((b[0] - 0.7).abs() < 1e-12);
        assert!((b[1] - 0.49).abs() < 1e-12);
        assert!((b[2] - 0.343).abs() < 1e-12);
    }
}
