//! Worker-side future-load pre-simulation (paper §5.2 "Algorithm Design").
//!
//! Each worker projects its own token load over the prediction horizon H
//! once per scheduling interval — O(R·H) — so the scheduler's per-candidate
//! evaluation is O(H) via incremental source/target updates. This is the
//! paper's optimized complexity `O(n + |O|·|U|·R_max·H)`.
//!
//! Projection model (the same one the paper's simulator uses): during one
//! scheduling interval every active request generates `g ≈ interval /
//! avg_iter_time` tokens; a request with predicted remaining N̂(r) ≤ g·t
//! has completed by step t and frees its KV, contributing 0.
//!
//! Predictions carry uncertainty ([`Prediction`]), so every projection is
//! taken at a *quantile* of the remaining-length estimate: the balancing
//! objective uses the mean (`balance_q`, 0.5 by default), while the
//! OOM-avoidance checks read the conservative aggregate trace
//! (`conservative_q`, p90 by default) — a request whose length is
//! uncertain must be assumed to hold its KV longer before a memory-safety
//! decision banks on the space.
//!
//! [`Prediction`]: crate::predictor::Prediction

use super::cluster_state::InstanceRef;
use super::RequestView;

/// Per-request projected contribution to instance load at steps 0..=H.
/// `trace[t]` = tokens this request holds at future step t.
#[derive(Clone, Debug)]
pub struct FutureLoad {
    pub trace: Vec<f64>,
}

impl FutureLoad {
    /// Project one request at quantile `q` of its remaining-length
    /// estimate. `g` = tokens per interval, `default_remaining` = assumed
    /// remaining when prediction is off (paper "w/o prediction": the
    /// scheduler only trusts current state, so the projection holds the
    /// request's load flat).
    pub fn of_request(
        r: &RequestView,
        g: f64,
        horizon: usize,
        default_remaining: Option<f64>,
        q: f64,
    ) -> FutureLoad {
        let mut trace = Vec::with_capacity(horizon + 1);
        trace.push(r.tokens as f64);
        let rem = r
            .predicted_remaining
            .map(|p| p.quantile(q))
            .or(default_remaining);
        match rem {
            Some(rem) => {
                for t in 1..=horizon {
                    let gen = g * t as f64;
                    if gen >= rem {
                        trace.push(0.0); // completed and freed
                    } else {
                        trace.push(r.tokens as f64 + gen);
                    }
                }
            }
            None => {
                // prediction off: assume the request persists at current
                // load + growth (no completion knowledge)
                for t in 1..=horizon {
                    trace.push(r.tokens as f64 + g * t as f64);
                }
            }
        }
        FutureLoad { trace }
    }
}

/// What a worker reports to the scheduler each interval: its identity,
/// the H-step aggregate load traces, and per-request projections (needed
/// only for requests that become migration candidates).
#[derive(Clone, Debug)]
pub struct WorkerReport {
    pub instance: usize,
    /// Aggregate projected load at the *balancing* quantile (mean by
    /// default): `load[t]` = Σ_r trace_r[t], t in 0..=H.
    pub load: Vec<f64>,
    /// Aggregate projected load at the *conservative* quantile (p90 by
    /// default) — the OOM-avoidance view behind [`Self::projected_peak`].
    /// Pointwise ≥ `load`; equal when every estimate is exact (σ = 0).
    pub load_hi: Vec<f64>,
    /// Weighted workload w_i = Σ_{t=1..H} β_t · load[t] (Alg. 1 line 13).
    pub weighted: f64,
    pub current_tokens: u64,
    pub kv_capacity_tokens: u64,
    pub inbound_reserved_tokens: u64,
}

impl WorkerReport {
    /// Compute a report from an instance view — the "worker-side
    /// pre-simulation" step. `betas[t-1]` weights future step t;
    /// `balance_q` / `conservative_q` select the estimate quantiles of the
    /// two aggregate traces.
    pub fn compute(
        view: &InstanceRef<'_>,
        g: f64,
        betas: &[f64],
        default_remaining: Option<f64>,
        balance_q: f64,
        conservative_q: f64,
    ) -> WorkerReport {
        let horizon = betas.len();
        let mut load = vec![0.0; horizon + 1];
        let mut load_hi = vec![0.0; horizon + 1];
        let same_q = (balance_q - conservative_q).abs() < 1e-12;
        for r in view.requests() {
            let fl = FutureLoad::of_request(r, g, horizon, default_remaining, balance_q);
            for (t, v) in fl.trace.iter().enumerate() {
                load[t] += v;
            }
            // σ = 0 (or equal quantiles) makes the traces identical; skip
            // the second projection then
            if same_q || r.predicted_remaining.map_or(true, |p| p.sigma <= 0.0) {
                for (t, v) in fl.trace.iter().enumerate() {
                    load_hi[t] += v;
                }
            } else {
                let fh = FutureLoad::of_request(r, g, horizon, default_remaining, conservative_q);
                for (t, v) in fh.trace.iter().enumerate() {
                    load_hi[t] += v;
                }
            }
        }
        let weighted = betas
            .iter()
            .enumerate()
            .map(|(i, b)| b * load[i + 1])
            .sum();
        WorkerReport {
            instance: view.id(),
            load,
            load_hi,
            weighted,
            current_tokens: view.token_load(),
            kv_capacity_tokens: view.kv_capacity_tokens(),
            inbound_reserved_tokens: view.inbound_reserved_tokens(),
        }
    }

    /// Projected peak KV occupancy over the horizon, tokens: the
    /// *conservative* load-trace maximum plus capacity already promised to
    /// in-flight migrations. The single definition both the STAR
    /// memory-safety check and the memory-pressure trigger rest on.
    pub fn projected_peak(&self) -> f64 {
        self.load_hi.iter().cloned().fold(0.0, f64::max) + self.inbound_reserved_tokens as f64
    }

    /// Projected free KV headroom at the *worst* point of the horizon
    /// (used for the target-side memory-safety check, Alg. 1 line 21).
    pub fn min_free_over_horizon(&self) -> f64 {
        self.kv_capacity_tokens as f64 - self.projected_peak()
    }
}

/// Geometric β schedule β_t = decay^t, t = 1..=H (Eq. 4's weights).
pub fn beta_schedule(horizon: usize, decay: f64) -> Vec<f64> {
    (1..=horizon).map(|t| decay.powi(t as i32)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::{inst, req};
    use crate::predictor::Prediction;

    #[test]
    fn future_load_completes_and_frees() {
        let r = req(1, 100, Some(25.0));
        let fl = FutureLoad::of_request(&r, 10.0, 4, None, 0.5);
        // t=0: 100; t=1: 110; t=2: 120; t=3 (gen=30 >= 25): 0
        assert_eq!(fl.trace, vec![100.0, 110.0, 120.0, 0.0, 0.0]);
    }

    #[test]
    fn future_load_without_prediction_grows_flat() {
        let r = req(1, 100, None);
        let fl = FutureLoad::of_request(&r, 10.0, 2, None, 0.5);
        assert_eq!(fl.trace, vec![100.0, 110.0, 120.0]);
    }

    #[test]
    fn conservative_quantile_holds_kv_longer() {
        // mean 25, σ 10: p90 ≈ 37.8, so at g=10 the request frees one
        // step LATER under the conservative view
        let mut r = req(1, 100, None);
        r.predicted_remaining = Some(Prediction::new(25.0, 10.0, 0));
        let lo = FutureLoad::of_request(&r, 10.0, 4, None, 0.5);
        let hi = FutureLoad::of_request(&r, 10.0, 4, None, 0.9);
        assert_eq!(lo.trace, vec![100.0, 110.0, 120.0, 0.0, 0.0]);
        assert_eq!(hi.trace, vec![100.0, 110.0, 120.0, 130.0, 0.0]);
        for (l, h) in lo.trace.iter().zip(&hi.trace) {
            assert!(h >= l, "conservative trace must dominate pointwise");
        }
    }

    #[test]
    fn report_aggregates_requests() {
        let v = inst(0, vec![req(1, 100, Some(1000.0)), req(2, 50, Some(5.0))], 10_000);
        let betas = beta_schedule(2, 0.5);
        let rep = WorkerReport::compute(&v.view(), 10.0, &betas, None, 0.5, 0.9);
        // t=0: 150; t=1: 110+0(done: 10>=5)=110; t=2: 120
        assert_eq!(rep.load, vec![150.0, 110.0, 120.0]);
        // exact predictions: the conservative trace is identical
        assert_eq!(rep.load_hi, rep.load);
        let expect_w = 0.5 * 110.0 + 0.25 * 120.0;
        assert!((rep.weighted - expect_w).abs() < 1e-9);
        assert_eq!(rep.current_tokens, 150);
    }

    #[test]
    fn report_separates_balance_and_conservative_views() {
        // one uncertain request (mean 5, σ 20): under the mean it is done
        // by t=1 (g=10 ≥ 5); at p90 (≈ 30.6) it survives through t=3
        let mut r = req(1, 100, None);
        r.predicted_remaining = Some(Prediction::new(5.0, 20.0, 0));
        let v = inst(0, vec![r], 10_000);
        let rep = WorkerReport::compute(&v.view(), 10.0, &beta_schedule(4, 1.0), None, 0.5, 0.9);
        assert_eq!(rep.load, vec![100.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(rep.load_hi, vec![100.0, 110.0, 120.0, 130.0, 0.0]);
        // the peak definition reads the conservative trace
        assert!((rep.projected_peak() - 130.0).abs() < 1e-9);
        // the weighted (balancing) workload reads the mean trace
        assert!(rep.weighted.abs() < 1e-9);
    }

    #[test]
    fn min_free_accounts_for_peak_and_reservations() {
        let mut v = inst(0, vec![req(1, 100, Some(1000.0))], 200);
        v.inbound_reserved_tokens = 50;
        let rep = WorkerReport::compute(&v.view(), 30.0, &beta_schedule(2, 1.0), None, 0.5, 0.9);
        // peak load = 160 at t=2, +50 reserved => free = 200-210 = -10
        assert!((rep.min_free_over_horizon() - (-10.0)).abs() < 1e-9);
    }

    #[test]
    fn beta_schedule_geometric() {
        let b = beta_schedule(3, 0.7);
        assert!((b[0] - 0.7).abs() < 1e-12);
        assert!((b[1] - 0.49).abs() < 1e-12);
        assert!((b[2] - 0.343).abs() < 1e-12);
    }
}
