//! Trace statistics — the Table 2 analog printer (bench `fig2_workload`).

use super::Request;

/// Summary statistics of one length column (input or output).
#[derive(Clone, Debug, Default)]
pub struct LenStats {
    pub mean: f64,
    pub std: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub max: f64,
}

impl LenStats {
    pub fn from_values(vals: &[f64]) -> LenStats {
        if vals.is_empty() {
            return LenStats::default();
        }
        let mut v = vals.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len() as f64;
        let mean = v.iter().sum::<f64>() / n;
        let var = v.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        let q = |p: f64| v[((p * (v.len() - 1) as f64).round() as usize).min(v.len() - 1)];
        LenStats {
            mean,
            std: var.sqrt(),
            p50: q(0.50),
            p90: q(0.90),
            p95: q(0.95),
            max: *v.last().unwrap(),
        }
    }
}

/// Input + output stats for a trace (rows of the paper's Table 2).
#[derive(Clone, Debug, Default)]
pub struct TraceStats {
    pub input: LenStats,
    pub output: LenStats,
    pub n: usize,
}

impl TraceStats {
    pub fn from_requests(reqs: &[Request]) -> TraceStats {
        let ins: Vec<f64> = reqs.iter().map(|r| r.prompt_len as f64).collect();
        let outs: Vec<f64> = reqs.iter().map(|r| r.output_len as f64).collect();
        TraceStats {
            input: LenStats::from_values(&ins),
            output: LenStats::from_values(&outs),
            n: reqs.len(),
        }
    }

    /// Render rows in the paper's Table 2 layout.
    pub fn render(&self, name: &str) -> String {
        let row = |metric: &str, s: &LenStats| {
            format!(
                "| {name} | {metric} | {:.0} | {:.0} | {:.0} | {:.0} | {:.0} |",
                s.mean, s.std, s.p50, s.p90, s.p95
            )
        };
        format!(
            "{}\n{}",
            row("Input", &self.input),
            row("Output", &self.output)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Dataset, TraceGen};

    #[test]
    fn percentiles_ordered() {
        let reqs = TraceGen::new(Dataset::ShareGpt, 1.0).generate(5000, 0);
        let st = TraceStats::from_requests(&reqs);
        assert!(st.output.p50 <= st.output.p90);
        assert!(st.output.p90 <= st.output.p95);
        assert!(st.output.p95 <= st.output.max);
        assert!(st.input.p50 <= st.input.p90);
    }

    #[test]
    fn empty_trace_is_zeroed() {
        let st = LenStats::from_values(&[]);
        assert_eq!(st.mean, 0.0);
        assert_eq!(st.max, 0.0);
    }

    #[test]
    fn single_value() {
        let st = LenStats::from_values(&[42.0]);
        assert_eq!(st.p50, 42.0);
        assert_eq!(st.std, 0.0);
    }
}
