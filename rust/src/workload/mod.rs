//! Workload synthesis: request traces with the paper's length structure.
//!
//! The paper drives everything from ShareGPT / Alpaca traces run through
//! DeepSeek-R1-Distill-Qwen-7B with a 32K output cap (Table 2). Neither
//! dataset nor model is available offline, so we synthesize traces whose
//! *distributional shape* matches Table 2: a log-normal body plus a heavy
//! "reasoning" mode pinned near the output cap (the paper's "17.3% of
//! requests exceed 30K tokens"). `stats()` prints the Table-2 analog so the
//! fit is auditable (bench `fig2_workload`).
//!
//! Two scales (DESIGN.md §5): `paper` (32K cap, simulator) and `pico`
//! (512 cap, real execution through star-pico).

mod arrival;
mod classes;
mod scenario;
mod stats;

pub use arrival::{ArrivalProcess, ArrivalSampler};
pub use classes::{ClassMix, ClassSpec, RequestClass, SloByClass};
pub use scenario::{
    FaultConfig, FaultEvent, FleetSpec, ScenarioSpec, ScenarioTrace, SessionPlan, SessionProfile,
    SessionTurn,
};
pub use stats::{LenStats, TraceStats};

use crate::prng::Pcg64;
use crate::{RequestId, Time};

/// One request of a trace. `output_len` is ground truth: policies must not
/// read it (only the oracle predictor may).
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: RequestId,
    pub arrival: Time,
    pub prompt_len: u32,
    /// Ground-truth total output length (tokens). Hidden from policies.
    pub output_len: u32,
    /// Corpus tag (drives prompt synthesis for the live LM path).
    pub tag: u8,
    /// Workload class (known at arrival; drives per-class SLOs/metrics).
    pub class: RequestClass,
}

/// Named dataset shapes from the paper's Table 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dataset {
    /// ShareGPT: mid-size prompts, P50 output 1536, ~18% near cap.
    ShareGpt,
    /// Alpaca: tiny prompts, P50 output ~987, ~25% near cap.
    Alpaca,
}

impl Dataset {
    pub fn parse(s: &str) -> Option<Dataset> {
        match s.to_ascii_lowercase().as_str() {
            "sharegpt" => Some(Dataset::ShareGpt),
            "alpaca" => Some(Dataset::Alpaca),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Dataset::ShareGpt => "sharegpt",
            Dataset::Alpaca => "alpaca",
        }
    }

    /// Valid names for CLI / config error messages.
    pub const NAMES: [&'static str; 2] = ["sharegpt", "alpaca"];
}

/// Length-distribution parameters at *paper scale* (32K cap).
#[derive(Clone, Debug)]
pub struct LengthModel {
    /// log-normal body of output length: underlying mu/sigma.
    pub out_mu: f64,
    pub out_sigma: f64,
    /// fraction of requests in the near-cap "long reasoning" mode.
    pub cap_frac: f64,
    /// cap mode is uniform in [cap_lo_frac * cap, cap].
    pub cap_lo_frac: f64,
    /// output cap (paper: 32K).
    pub cap: u32,
    /// prompt log-normal mu/sigma and cap.
    pub in_mu: f64,
    pub in_sigma: f64,
    pub in_cap: u32,
}

impl LengthModel {
    /// Fitted to Table 2, ShareGPT row (verified by `fig2_workload`).
    pub fn sharegpt() -> Self {
        LengthModel {
            // solved from Table 2: p50 = 1536 with 18% cap mass =>
            // mu + 0.28 sigma = ln 1536; mean 7542 => mu + sigma^2/2 = 7.70
            out_mu: 7.01,
            out_sigma: 1.18,
            cap_frac: 0.18,
            cap_lo_frac: 0.92,
            cap: 32_768,
            // input P50 36, heavy tail (P90 920); sigma trades P90 vs mean
            in_mu: 3.58,
            in_sigma: 2.2,
            in_cap: 32_768,
        }
    }

    /// Fitted to Table 2, Alpaca row.
    pub fn alpaca() -> Self {
        LengthModel {
            // p50 987 with 25% cap mass => mu + 0.43 sigma = ln 987;
            // mean 8596 => body mean ~1050 => sigma ~= 1.0
            out_mu: 6.46,
            out_sigma: 1.0,
            cap_frac: 0.25,
            cap_lo_frac: 0.92,
            cap: 32_768,
            in_mu: 2.35,
            in_sigma: 0.35,
            in_cap: 2_048,
        }
    }

    pub fn for_dataset(ds: Dataset) -> Self {
        match ds {
            Dataset::ShareGpt => Self::sharegpt(),
            Dataset::Alpaca => Self::alpaca(),
        }
    }

    /// Sample an output length at paper scale.
    pub fn sample_output(&self, rng: &mut Pcg64) -> u32 {
        if rng.coin(self.cap_frac) {
            let lo = (self.cap as f64 * self.cap_lo_frac) as u64;
            rng.range_u64(lo, self.cap as u64) as u32
        } else {
            let x = rng.lognormal(self.out_mu, self.out_sigma);
            (x.round() as u64).clamp(1, self.cap as u64) as u32
        }
    }

    /// Sample a prompt length at paper scale.
    pub fn sample_prompt(&self, rng: &mut Pcg64) -> u32 {
        let x = rng.lognormal(self.in_mu, self.in_sigma);
        (x.round() as u64).clamp(1, self.in_cap as u64) as u32
    }

    /// Rescale a sampled (prompt, output) pair from this model's paper
    /// scale to the pico real-execution domain, when one is given. The
    /// single definition shared by [`TraceGen`] and
    /// [`crate::workload::ScenarioSpec`], so sim and serve see identical
    /// lengths.
    pub fn rescale(&self, pico: Option<(u32, u32)>, prompt: u32, output: u32) -> (u32, u32) {
        match pico {
            None => (prompt, output),
            Some((mp, mo)) => {
                let p = ((prompt as f64) * (mp as f64) / (self.in_cap as f64))
                    .round()
                    .max(1.0) as u32;
                let o = ((output as f64) * (mo as f64) / (self.cap as f64))
                    .round()
                    .max(1.0) as u32;
                (p.min(mp), o.min(mo))
            }
        }
    }

    /// 16-band tag of a paper-scale output length (drives prompt synthesis
    /// for the live LM path: the tag byte selects the expected-length
    /// band).
    pub fn tag_band(&self, output: u32) -> u8 {
        (output as f64 / self.cap.max(1) as f64 * 15.0)
            .round()
            .clamp(0.0, 15.0) as u8
    }
}

/// Trace generator: Poisson arrivals at `rps`, lengths from [`LengthModel`],
/// optionally rescaled to the pico (real-execution) domain.
#[derive(Clone, Debug)]
pub struct TraceGen {
    pub model: LengthModel,
    pub rps: f64,
    /// If set, rescale lengths from paper scale to (max_prompt, max_output).
    pub pico_scale: Option<(u32, u32)>,
}

impl TraceGen {
    pub fn new(ds: Dataset, rps: f64) -> Self {
        TraceGen {
            model: LengthModel::for_dataset(ds),
            rps,
            pico_scale: None,
        }
    }

    /// Rescale to the real-execution domain (star-pico budgets).
    pub fn pico(mut self, max_prompt: u32, max_output: u32) -> Self {
        self.pico_scale = Some((max_prompt, max_output));
        self
    }

    /// Generate `n` requests with Poisson arrivals starting at t=0.
    pub fn generate(&self, n: usize, seed: u64) -> Vec<Request> {
        let mut rng = Pcg64::new(seed, WORKLOAD_STREAM);
        let mut t = 0.0;
        let mut out = Vec::with_capacity(n);
        for id in 0..n {
            t += rng.exponential(self.rps.max(1e-9));
            let prompt = self.model.sample_prompt(&mut rng);
            let output = self.model.sample_output(&mut rng);
            let (prompt_len, output_len) = self.model.rescale(self.pico_scale, prompt, output);
            out.push(Request {
                id: id as RequestId,
                arrival: t,
                prompt_len,
                output_len,
                tag: self.model.tag_band(output),
                class: RequestClass::Chat,
            });
        }
        out
    }

    /// Generate requests over a fixed duration (seconds).
    pub fn generate_for(&self, duration: Time, seed: u64) -> Vec<Request> {
        let mut rng = Pcg64::new(seed, WORKLOAD_STREAM);
        let mut t = 0.0;
        let mut out = Vec::new();
        let mut id: RequestId = 0;
        loop {
            t += rng.exponential(self.rps.max(1e-9));
            if t > duration {
                return out;
            }
            let prompt = self.model.sample_prompt(&mut rng);
            let output = self.model.sample_output(&mut rng);
            let (prompt_len, output_len) = self.model.rescale(self.pico_scale, prompt, output);
            out.push(Request {
                id,
                arrival: t,
                prompt_len,
                output_len,
                tag: self.model.tag_band(output),
                class: RequestClass::Chat,
            });
            id += 1;
        }
    }
}

/// PRNG stream id for workload generation ("WLOAD").
const WORKLOAD_STREAM: u64 = 0x574c_4f41_44;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_increasing_and_rate_close() {
        let gen = TraceGen::new(Dataset::ShareGpt, 2.0);
        let reqs = gen.generate(4000, 1);
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let rate = reqs.len() as f64 / reqs.last().unwrap().arrival;
        assert!((rate - 2.0).abs() < 0.15, "rate {rate}");
    }

    #[test]
    fn sharegpt_shape_matches_table2() {
        // Table 2 targets (paper): output P50 1536, P90 ~32670;
        // ~18% near cap; mean ~7542.
        let gen = TraceGen::new(Dataset::ShareGpt, 1.0);
        let reqs = gen.generate(20_000, 2);
        let st = TraceStats::from_requests(&reqs);
        assert!(
            (1_100.0..2_100.0).contains(&st.output.p50),
            "p50 {}",
            st.output.p50
        );
        assert!(st.output.p90 > 30_000.0, "p90 {}", st.output.p90);
        assert!(
            (5_500.0..9_500.0).contains(&st.output.mean),
            "mean {}",
            st.output.mean
        );
        let near_cap = reqs.iter().filter(|r| r.output_len > 30_000).count();
        let frac = near_cap as f64 / reqs.len() as f64;
        assert!((0.14..0.24).contains(&frac), "cap frac {frac}");
    }

    #[test]
    fn pico_rescale_bounds() {
        let gen = TraceGen::new(Dataset::ShareGpt, 1.0).pico(128, 512);
        for r in gen.generate(5000, 3) {
            assert!((1..=128).contains(&r.prompt_len));
            assert!((1..=512).contains(&r.output_len));
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let gen = TraceGen::new(Dataset::Alpaca, 0.5);
        assert_eq!(gen.generate(100, 9), gen.generate(100, 9));
        assert_ne!(gen.generate(100, 9), gen.generate(100, 10));
    }

    #[test]
    fn duration_bounded() {
        let gen = TraceGen::new(Dataset::Alpaca, 5.0);
        let reqs = gen.generate_for(100.0, 4);
        assert!(reqs.iter().all(|r| r.arrival <= 100.0));
        assert!(reqs.len() > 300, "expected ~500, got {}", reqs.len());
    }
}
