//! Pluggable arrival processes (the first scenario-diversity axis).
//!
//! The paper's evaluation drives everything from stationary Poisson
//! arrivals, but decode imbalance only bites under bursty, non-stationary
//! traffic (see "Inference without Interference", arXiv:2401.11181). This
//! module generalizes trace synthesis over four processes:
//!
//! * [`ArrivalProcess::Poisson`] — the stationary baseline;
//! * [`ArrivalProcess::OnOff`] — a two-state Markov-modulated Poisson
//!   process (MMPP-2): exponential ON/OFF phase durations with a distinct
//!   rate per phase, the classic bursty-traffic model;
//! * [`ArrivalProcess::Diurnal`] — a non-homogeneous Poisson process with
//!   a raised-cosine rate ramp (Lewis–Shedler thinning), the slow
//!   day/night load swing;
//! * [`ArrivalProcess::Replay`] — arrival times replayed from a file
//!   (one timestamp per line), for real production traces.
//!
//! All processes are deterministic given a [`Pcg64`] and expose their
//! long-run mean rate through [`ArrivalProcess::mean_rps`] so tests can
//! assert distribution shape (`tests/scenarios.rs`).

use std::path::Path;

use crate::prng::Pcg64;
use crate::{Error, Result, Time};

/// A request arrival process: produces a non-decreasing time series.
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Stationary Poisson arrivals at `rps`.
    Poisson { rps: f64 },
    /// MMPP-2 burst model: Poisson at `rps_on` during ON phases and
    /// `rps_off` during OFF phases; phase durations are exponential with
    /// means `mean_on_s` / `mean_off_s`.
    OnOff {
        rps_on: f64,
        rps_off: f64,
        mean_on_s: f64,
        mean_off_s: f64,
    },
    /// Non-homogeneous Poisson with rate
    /// `base + (peak - base) * (1 - cos(2πt/period)) / 2`
    /// (starts at `base_rps`, crests at `peak_rps` mid-period).
    Diurnal {
        base_rps: f64,
        peak_rps: f64,
        period_s: f64,
    },
    /// Replay a recorded arrival-time series (seconds, sorted ascending).
    Replay { times: Vec<Time> },
}

impl ArrivalProcess {
    /// Long-run mean arrival rate (requests per second).
    pub fn mean_rps(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rps } => *rps,
            ArrivalProcess::OnOff {
                rps_on,
                rps_off,
                mean_on_s,
                mean_off_s,
            } => {
                let cycle = mean_on_s + mean_off_s;
                if cycle <= 0.0 {
                    0.0
                } else {
                    (rps_on * mean_on_s + rps_off * mean_off_s) / cycle
                }
            }
            ArrivalProcess::Diurnal {
                base_rps, peak_rps, ..
            } => (base_rps + peak_rps) / 2.0,
            ArrivalProcess::Replay { times } => match times.last() {
                Some(&last) if last > 0.0 => times.len() as f64 / last,
                _ => 0.0,
            },
        }
    }

    /// Short name for logs / summaries.
    pub fn kind_name(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson { .. } => "poisson",
            ArrivalProcess::OnOff { .. } => "onoff",
            ArrivalProcess::Diurnal { .. } => "diurnal",
            ArrivalProcess::Replay { .. } => "replay",
        }
    }

    pub fn validate(&self) -> Result<()> {
        match self {
            ArrivalProcess::Poisson { rps } => {
                if *rps <= 0.0 {
                    return Err(Error::config("arrival: poisson rps must be > 0"));
                }
            }
            ArrivalProcess::OnOff {
                rps_on,
                rps_off,
                mean_on_s,
                mean_off_s,
            } => {
                if *rps_on <= 0.0 {
                    return Err(Error::config("arrival: onoff rps_on must be > 0"));
                }
                if *rps_off < 0.0 {
                    return Err(Error::config("arrival: onoff rps_off must be >= 0"));
                }
                if *mean_on_s <= 0.0 || *mean_off_s <= 0.0 {
                    return Err(Error::config("arrival: onoff phase means must be > 0"));
                }
            }
            ArrivalProcess::Diurnal {
                base_rps,
                peak_rps,
                period_s,
            } => {
                if *base_rps < 0.0 || *peak_rps <= 0.0 {
                    return Err(Error::config(
                        "arrival: diurnal needs base_rps >= 0 and peak_rps > 0",
                    ));
                }
                if peak_rps < base_rps {
                    return Err(Error::config("arrival: diurnal peak_rps must be >= base_rps"));
                }
                if *period_s <= 0.0 {
                    return Err(Error::config("arrival: diurnal period_s must be > 0"));
                }
            }
            ArrivalProcess::Replay { times } => {
                if times.is_empty() {
                    return Err(Error::config("arrival: replay needs at least one time"));
                }
                if times.iter().any(|t| !t.is_finite() || *t < 0.0) {
                    return Err(Error::config("arrival: replay times must be finite and >= 0"));
                }
            }
        }
        Ok(())
    }

    /// Load a replay trace: one arrival time (seconds) per line; blank
    /// lines and `#` comments ignored. Times are sorted to be forgiving of
    /// unordered logs.
    pub fn from_file(path: &Path) -> Result<ArrivalProcess> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::config(format!("arrival replay {}: {e}", path.display())))?;
        let mut times = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let field = line.trim();
            if field.is_empty() || field.starts_with('#') {
                continue;
            }
            let first = field.split_whitespace().next().unwrap_or("");
            let t: f64 = first.parse().map_err(|_| {
                Error::config(format!(
                    "arrival replay {}: line {} is not a time: `{field}`",
                    path.display(),
                    lineno + 1
                ))
            })?;
            times.push(t);
        }
        times.sort_by(|a, b| a.partial_cmp(b).expect("finite times"));
        let p = ArrivalProcess::Replay { times };
        p.validate()?;
        Ok(p)
    }

    /// Stateful sampler over this process.
    pub fn sampler(&self) -> ArrivalSampler<'_> {
        ArrivalSampler {
            process: self,
            t: 0.0,
            on: true,
            phase_end: 0.0,
            started: false,
            replay_idx: 0,
        }
    }

    /// First `n` arrival times (fewer for an exhausted replay trace).
    pub fn sample(&self, n: usize, rng: &mut Pcg64) -> Vec<Time> {
        let mut s = self.sampler();
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            match s.next_arrival(rng) {
                Some(t) => out.push(t),
                None => break,
            }
        }
        out
    }

    /// All arrivals in `[0, duration]`.
    pub fn sample_for(&self, duration: Time, rng: &mut Pcg64) -> Vec<Time> {
        let mut s = self.sampler();
        let mut out = Vec::new();
        while let Some(t) = s.next_arrival(rng) {
            if t > duration {
                break;
            }
            out.push(t);
        }
        out
    }
}

/// Incremental arrival generator (see [`ArrivalProcess::sampler`]).
#[derive(Clone, Debug)]
pub struct ArrivalSampler<'a> {
    process: &'a ArrivalProcess,
    t: Time,
    /// OnOff: currently in the ON phase.
    on: bool,
    /// OnOff: end time of the current phase.
    phase_end: Time,
    /// OnOff: first phase duration has been drawn.
    started: bool,
    /// Replay: next index to emit.
    replay_idx: usize,
}

impl ArrivalSampler<'_> {
    /// Next arrival time, or `None` when a replay trace is exhausted
    /// (synthetic processes never end).
    pub fn next_arrival(&mut self, rng: &mut Pcg64) -> Option<Time> {
        match self.process {
            ArrivalProcess::Poisson { rps } => {
                self.t += rng.exponential(rps.max(1e-9));
                Some(self.t)
            }
            ArrivalProcess::OnOff {
                rps_on,
                rps_off,
                mean_on_s,
                mean_off_s,
            } => {
                if !self.started {
                    self.started = true;
                    self.phase_end = rng.exponential(1.0 / mean_on_s.max(1e-9));
                }
                loop {
                    let rate = if self.on { *rps_on } else { *rps_off };
                    if rate > 1e-12 {
                        let gap = rng.exponential(rate);
                        if self.t + gap <= self.phase_end {
                            self.t += gap;
                            return Some(self.t);
                        }
                    }
                    // no arrival before the boundary: jump there and
                    // switch phase (exponential gaps are memoryless, so
                    // redrawing in the new phase is exact)
                    self.t = self.phase_end;
                    self.on = !self.on;
                    let mean = if self.on { *mean_on_s } else { *mean_off_s };
                    self.phase_end = self.t + rng.exponential(1.0 / mean.max(1e-9));
                }
            }
            ArrivalProcess::Diurnal {
                base_rps,
                peak_rps,
                period_s,
            } => {
                // Lewis–Shedler thinning against the peak rate
                let peak = peak_rps.max(1e-9);
                loop {
                    self.t += rng.exponential(peak);
                    let phase = 2.0 * std::f64::consts::PI * self.t / period_s.max(1e-9);
                    let lam = base_rps + (peak_rps - base_rps) * 0.5 * (1.0 - phase.cos());
                    if rng.next_f64() * peak <= lam {
                        return Some(self.t);
                    }
                }
            }
            ArrivalProcess::Replay { times } => {
                let v = times.get(self.replay_idx).copied();
                self.replay_idx += 1;
                v
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn realized_rate(times: &[Time]) -> f64 {
        match times.last() {
            Some(&last) if last > 0.0 => times.len() as f64 / last,
            _ => 0.0,
        }
    }

    #[test]
    fn poisson_rate_and_determinism() {
        let p = ArrivalProcess::Poisson { rps: 3.0 };
        let mut rng = Pcg64::new(1, 7);
        let a = p.sample(10_000, &mut rng);
        let mut rng2 = Pcg64::new(1, 7);
        let b = p.sample(10_000, &mut rng2);
        assert_eq!(a, b);
        let rate = realized_rate(&a);
        assert!((rate - 3.0).abs() < 0.15, "rate {rate}");
    }

    #[test]
    fn onoff_mean_rate_matches_formula() {
        let p = ArrivalProcess::OnOff {
            rps_on: 50.0,
            rps_off: 5.0,
            mean_on_s: 5.0,
            mean_off_s: 5.0,
        };
        assert!((p.mean_rps() - 27.5).abs() < 1e-12);
        let mut rng = Pcg64::new(2, 7);
        let a = p.sample(30_000, &mut rng);
        for w in a.windows(2) {
            assert!(w[1] >= w[0]);
        }
        // MMPP realized rate has high variance (the phase durations are
        // exponential too): ~7% relative std at this trace length, so a
        // 20% (~3 sigma) tolerance keeps the deterministic seed safe
        let rate = realized_rate(&a);
        assert!(
            (rate - p.mean_rps()).abs() < 0.20 * p.mean_rps(),
            "rate {rate} vs mean {}",
            p.mean_rps()
        );
    }

    #[test]
    fn onoff_is_actually_bursty() {
        // coefficient of variation of inter-arrival gaps must exceed the
        // Poisson value (1.0) by a clear margin
        let p = ArrivalProcess::OnOff {
            rps_on: 40.0,
            rps_off: 0.0,
            mean_on_s: 2.0,
            mean_off_s: 6.0,
        };
        let mut rng = Pcg64::new(3, 7);
        let a = p.sample(20_000, &mut rng);
        let gaps: Vec<f64> = a.windows(2).map(|w| w[1] - w[0]).collect();
        let n = gaps.len() as f64;
        let mean = gaps.iter().sum::<f64>() / n;
        let var = gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>() / n;
        let cv = var.sqrt() / mean;
        assert!(cv > 1.5, "on/off trace not bursty: cv {cv:.2}");
    }

    #[test]
    fn diurnal_mean_rate_and_modulation() {
        let p = ArrivalProcess::Diurnal {
            base_rps: 5.0,
            peak_rps: 15.0,
            period_s: 50.0,
        };
        assert!((p.mean_rps() - 10.0).abs() < 1e-12);
        let mut rng = Pcg64::new(4, 7);
        let a = p.sample(30_000, &mut rng);
        let rate = realized_rate(&a);
        assert!((rate - 10.0).abs() < 1.0, "rate {rate}");
        // the first quarter-period (trough) must be visibly sparser than
        // the half-period crest
        let quarter = a.iter().filter(|&&t| t % 50.0 < 12.5).count() as f64;
        let crest = a
            .iter()
            .filter(|&&t| {
                let ph = t % 50.0;
                (12.5..37.5).contains(&ph)
            })
            .count() as f64;
        assert!(
            crest > quarter * 1.5,
            "no diurnal modulation: trough-quarter {quarter}, crest-half {crest}"
        );
    }

    #[test]
    fn replay_roundtrip_via_file() {
        let path = std::env::temp_dir().join("star_arrival_replay_test.txt");
        std::fs::write(&path, "# trace\n0.5\n1.25\n\n2.0 extra-column\n").unwrap();
        let p = ArrivalProcess::from_file(&path).unwrap();
        let mut rng = Pcg64::new(0, 0);
        assert_eq!(p.sample(10, &mut rng), vec![0.5, 1.25, 2.0]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn replay_rejects_garbage() {
        let path = std::env::temp_dir().join("star_arrival_replay_bad.txt");
        std::fs::write(&path, "0.5\nnot-a-number\n").unwrap();
        assert!(ArrivalProcess::from_file(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn validation_rejects_bad_parameters() {
        assert!(ArrivalProcess::Poisson { rps: 0.0 }.validate().is_err());
        assert!(ArrivalProcess::OnOff {
            rps_on: 0.0,
            rps_off: 0.0,
            mean_on_s: 1.0,
            mean_off_s: 1.0
        }
        .validate()
        .is_err());
        assert!(ArrivalProcess::Diurnal {
            base_rps: 2.0,
            peak_rps: 1.0,
            period_s: 10.0
        }
        .validate()
        .is_err());
        assert!(ArrivalProcess::Replay { times: vec![] }.validate().is_err());
    }
}
