//! Request classes (the third scenario-diversity axis): chat / reasoning /
//! summarization traffic with per-class length models and per-class SLO
//! targets, after the mixed-downstream-workload setting of "Inference
//! without Interference" (arXiv:2401.11181). Aggregate goodput hides
//! per-class SLO violations; [`SloByClass`] + the per-class report in
//! `sim::report` expose them.

use super::LengthModel;
use crate::metrics::Slo;
use crate::prng::Pcg64;
use crate::{Error, Result};

/// Downstream workload class of a request. Known at arrival time (the
/// application declares it) — unlike the realized output length, policies
/// MAY read it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum RequestClass {
    /// Interactive chat: short prompts, short outputs, tight latency SLO.
    #[default]
    Chat,
    /// Long-form reasoning: heavy near-cap output mode, relaxed SLO.
    Reasoning,
    /// Summarization: long prompts, short outputs, loose TTFT.
    Summarization,
}

impl RequestClass {
    pub const ALL: [RequestClass; 3] = [
        RequestClass::Chat,
        RequestClass::Reasoning,
        RequestClass::Summarization,
    ];

    pub fn name(self) -> &'static str {
        match self {
            RequestClass::Chat => "chat",
            RequestClass::Reasoning => "reasoning",
            RequestClass::Summarization => "summarization",
        }
    }

    pub fn parse(s: &str) -> Result<RequestClass> {
        match s.to_ascii_lowercase().as_str() {
            "chat" => Ok(RequestClass::Chat),
            "reasoning" => Ok(RequestClass::Reasoning),
            "summarization" | "summary" => Ok(RequestClass::Summarization),
            other => Err(Error::config(format!(
                "unknown request class `{other}` (known: chat|reasoning|summarization)"
            ))),
        }
    }

    /// Dense index for per-class arrays ([`SloByClass`]).
    pub fn index(self) -> usize {
        match self {
            RequestClass::Chat => 0,
            RequestClass::Reasoning => 1,
            RequestClass::Summarization => 2,
        }
    }
}

/// One class's workload profile: arrival share, length model, SLO target.
#[derive(Clone, Debug)]
pub struct ClassSpec {
    pub class: RequestClass,
    /// Relative arrival weight within a [`ClassMix`].
    pub weight: f64,
    pub lengths: LengthModel,
    pub slo: Slo,
}

impl ClassSpec {
    /// Interactive chat: log-normal outputs around ~250 tokens, prompts
    /// around ~200; SLO = paper default (1 s TTFT / 25 ms TPOT).
    pub fn chat() -> Self {
        ClassSpec {
            class: RequestClass::Chat,
            weight: 0.6,
            lengths: LengthModel {
                out_mu: 5.5,
                out_sigma: 0.8,
                cap_frac: 0.01,
                cap_lo_frac: 0.92,
                cap: 4_096,
                in_mu: 5.3,
                in_sigma: 0.9,
                in_cap: 8_192,
            },
            slo: Slo {
                ttft_s: 1.0,
                tpot_s: 0.025,
            },
        }
    }

    /// Long-form reasoning: the ShareGPT-style heavy near-cap output mode,
    /// with a relaxed SLO (users wait for chains of thought).
    pub fn reasoning() -> Self {
        ClassSpec {
            class: RequestClass::Reasoning,
            weight: 0.25,
            lengths: LengthModel {
                out_mu: 7.0,
                out_sigma: 1.1,
                cap_frac: 0.30,
                cap_lo_frac: 0.92,
                cap: 32_768,
                in_mu: 4.0,
                in_sigma: 1.0,
                in_cap: 8_192,
            },
            slo: Slo {
                ttft_s: 2.0,
                tpot_s: 0.050,
            },
        }
    }

    /// Summarization: long documents in, short summaries out; TTFT is
    /// dominated by the long prefill, so its SLO is loose there but tight
    /// on decode pacing.
    pub fn summarization() -> Self {
        ClassSpec {
            class: RequestClass::Summarization,
            weight: 0.15,
            lengths: LengthModel {
                out_mu: 5.7,
                out_sigma: 0.6,
                cap_frac: 0.0,
                cap_lo_frac: 0.92,
                cap: 2_048,
                in_mu: 8.3,
                in_sigma: 0.8,
                in_cap: 32_768,
            },
            slo: Slo {
                ttft_s: 3.0,
                tpot_s: 0.025,
            },
        }
    }

    pub fn builtin(class: RequestClass) -> Self {
        match class {
            RequestClass::Chat => Self::chat(),
            RequestClass::Reasoning => Self::reasoning(),
            RequestClass::Summarization => Self::summarization(),
        }
    }

    /// Legacy single-class profile: a Table-2 dataset shape labelled
    /// `Chat`, judged against the paper's default SLO.
    pub fn dataset(ds: super::Dataset) -> Self {
        ClassSpec {
            class: RequestClass::Chat,
            weight: 1.0,
            lengths: LengthModel::for_dataset(ds),
            slo: Slo::default(),
        }
    }

    /// Sanity-check a (possibly config-overridden) class profile before
    /// any sampling: a zero cap would panic inside `sample_output`'s
    /// `clamp(1, cap)` mid-run instead of erroring at config time.
    pub fn validate(&self) -> Result<()> {
        let name = self.class.name();
        let l = &self.lengths;
        if l.cap == 0 || l.in_cap == 0 {
            return Err(Error::config(format!(
                "class {name}: length caps must be > 0"
            )));
        }
        if !(0.0..=1.0).contains(&l.cap_frac) || !(0.0..=1.0).contains(&l.cap_lo_frac) {
            return Err(Error::config(format!(
                "class {name}: cap_frac/cap_lo_frac must be in [0,1]"
            )));
        }
        if !l.out_mu.is_finite() || !l.in_mu.is_finite() {
            return Err(Error::config(format!(
                "class {name}: length-model mu must be finite"
            )));
        }
        if !(0.0..).contains(&l.out_sigma) || !(0.0..).contains(&l.in_sigma) {
            return Err(Error::config(format!(
                "class {name}: length-model sigma must be >= 0"
            )));
        }
        if self.slo.ttft_s <= 0.0 || self.slo.tpot_s <= 0.0 {
            return Err(Error::config(format!(
                "class {name}: SLO targets must be > 0"
            )));
        }
        Ok(())
    }
}

/// Weighted mixture of class profiles.
#[derive(Clone, Debug)]
pub struct ClassMix {
    specs: Vec<ClassSpec>,
    total_weight: f64,
}

impl ClassMix {
    pub fn new(specs: Vec<ClassSpec>) -> Result<ClassMix> {
        if specs.is_empty() {
            return Err(Error::config("class mix needs at least one class"));
        }
        if specs.iter().any(|s| s.weight <= 0.0 || !s.weight.is_finite()) {
            return Err(Error::config("class weights must be finite and > 0"));
        }
        for (i, a) in specs.iter().enumerate() {
            if specs[..i].iter().any(|b| b.class == a.class) {
                return Err(Error::config(format!(
                    "class `{}` appears twice in the mix",
                    a.class.name()
                )));
            }
        }
        let total_weight = specs.iter().map(|s| s.weight).sum();
        Ok(ClassMix {
            specs,
            total_weight,
        })
    }

    pub fn single(spec: ClassSpec) -> ClassMix {
        ClassMix {
            total_weight: spec.weight.max(1e-12),
            specs: vec![spec],
        }
    }

    /// The default three-class production mix (60/25/15).
    pub fn mixed_default() -> ClassMix {
        ClassMix::new(vec![
            ClassSpec::chat(),
            ClassSpec::reasoning(),
            ClassSpec::summarization(),
        ])
        .expect("builtin mix is valid")
    }

    pub fn specs(&self) -> &[ClassSpec] {
        &self.specs
    }

    pub fn spec_of(&self, class: RequestClass) -> Option<&ClassSpec> {
        self.specs.iter().find(|s| s.class == class)
    }

    /// Draw a class spec with probability proportional to its weight.
    pub fn sample(&self, rng: &mut Pcg64) -> &ClassSpec {
        let mut u = rng.next_f64() * self.total_weight;
        for s in &self.specs {
            u -= s.weight;
            if u <= 0.0 {
                return s;
            }
        }
        self.specs.last().expect("non-empty mix")
    }

    /// Per-class SLO lookup table; classes absent from the mix keep the
    /// default SLO.
    pub fn slos(&self) -> SloByClass {
        let mut by = SloByClass::uniform(Slo::default());
        for s in &self.specs {
            by = by.with(s.class, s.slo);
        }
        by
    }
}

/// Per-class SLO lookup: goodput judges each request against the target of
/// ITS class, not a single aggregate SLO.
#[derive(Clone, Copy, Debug)]
pub struct SloByClass {
    slos: [Slo; 3],
}

impl SloByClass {
    pub fn uniform(slo: Slo) -> SloByClass {
        SloByClass { slos: [slo; 3] }
    }

    pub fn with(mut self, class: RequestClass, slo: Slo) -> SloByClass {
        self.slos[class.index()] = slo;
        self
    }

    pub fn get(&self, class: RequestClass) -> Slo {
        self.slos[class.index()]
    }
}

impl Default for SloByClass {
    fn default() -> Self {
        SloByClass::uniform(Slo::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_parse_roundtrip() {
        for c in RequestClass::ALL {
            assert_eq!(RequestClass::parse(c.name()).unwrap(), c);
        }
        let err = RequestClass::parse("video").unwrap_err().to_string();
        assert!(err.contains("chat|reasoning|summarization"), "{err}");
    }

    #[test]
    fn mix_sampling_respects_weights() {
        let mix = ClassMix::mixed_default();
        let mut rng = Pcg64::new(5, 3);
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            counts[mix.sample(&mut rng).class.index()] += 1;
        }
        let frac = |i: usize| counts[i] as f64 / 20_000.0;
        assert!((frac(0) - 0.60).abs() < 0.03, "chat {}", frac(0));
        assert!((frac(1) - 0.25).abs() < 0.03, "reasoning {}", frac(1));
        assert!((frac(2) - 0.15).abs() < 0.03, "summarization {}", frac(2));
    }

    #[test]
    fn mix_rejects_duplicates_and_bad_weights() {
        assert!(ClassMix::new(vec![]).is_err());
        let mut dup = vec![ClassSpec::chat(), ClassSpec::chat()];
        dup[1].weight = 0.1;
        assert!(ClassMix::new(dup).is_err());
        let mut bad = vec![ClassSpec::chat()];
        bad[0].weight = 0.0;
        assert!(ClassMix::new(bad).is_err());
    }

    #[test]
    fn slo_lookup_defaults_and_overrides() {
        let by = ClassMix::mixed_default().slos();
        assert!((by.get(RequestClass::Reasoning).tpot_s - 0.050).abs() < 1e-12);
        assert!((by.get(RequestClass::Chat).ttft_s - 1.0).abs() < 1e-12);
        let single = ClassMix::single(ClassSpec::chat()).slos();
        // absent classes fall back to the default SLO
        let fallback = single.get(RequestClass::Summarization).ttft_s;
        assert!((fallback - Slo::default().ttft_s).abs() < 1e-12);
    }
}
