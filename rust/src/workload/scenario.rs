//! Scenario synthesis: arrival process × class mix × multi-round sessions.
//!
//! A [`ScenarioSpec`] is the full workload shape of one experiment. Its
//! [`ScenarioSpec::generate`] output is a [`ScenarioTrace`]: the initial
//! request arrivals plus a [`SessionPlan`] of precomputed follow-up turns.
//! Follow-up turns model multi-round conversations ("Efficient Multi-round
//! LLM Inference over Disaggregated Serving", arXiv:2602.14516): turn k+1
//! arrives a think-time after turn k *completes*, and its prompt includes
//! the accumulated history (previous prompt + previous output + fresh user
//! text). Because completion times are dynamic, the drivers — not the
//! generator — realize follow-up arrivals: the simulator through its
//! `SessionFollowUp` event, the live server through the same plan, so both
//! replay the identical per-turn schedule.

use super::arrival::ArrivalProcess;
use super::classes::{ClassMix, ClassSpec, RequestClass, SloByClass};
use super::Request;
use crate::coordinator::HardwareProfile;
use crate::prng::Pcg64;
use crate::{RequestId, Result, Time};

/// PRNG stream id for scenario generation ("SCEN").
const SCENARIO_STREAM: u64 = 0x5343_454e;

/// One scripted instance failure: decode instance `instance` goes down at
/// simulation time `at` and recovers `down_s` later (`down_s <= 0` =
/// permanent — the instance never comes back).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultEvent {
    pub at: Time,
    pub instance: usize,
    pub down_s: f64,
}

/// Failure-injection plan for a scenario: a deterministic script plus an
/// optional stochastic process (per-decode-instance exponential
/// inter-failure times with mean `mtbf_s`, downtimes with mean `mttr_s`,
/// drawn from a dedicated PRNG stream off the run seed — same seed ⇒
/// identical failure times). Faults target decode instances only; the
/// prefill side is modeled as a shared stateless worker pool.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultConfig {
    /// Mean time between failures per decode instance (s); `<= 0`
    /// disables the stochastic process (scripted faults still fire).
    pub mtbf_s: f64,
    /// Mean downtime per stochastic failure (s); must be > 0 while the
    /// stochastic process is on.
    pub mttr_s: f64,
    /// Cap on the number of stochastic failures over the run (keeps a
    /// short-MTBF smoke run from thrashing forever).
    pub max_failures: usize,
    /// Scripted failures, executed verbatim on top of the stochastic plan.
    pub script: Vec<FaultEvent>,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            mtbf_s: 0.0,
            mttr_s: 30.0,
            max_failures: 8,
            script: Vec::new(),
        }
    }
}

impl FaultConfig {
    /// Does this plan inject any faults at all?
    pub fn enabled(&self) -> bool {
        self.mtbf_s > 0.0 || !self.script.is_empty()
    }

    pub fn validate(&self) -> Result<()> {
        if self.mtbf_s > 0.0 && self.mttr_s <= 0.0 {
            return Err(crate::Error::config(
                "faults.mttr_s must be > 0 while faults.mtbf_s enables the stochastic process",
            ));
        }
        for (i, f) in self.script.iter().enumerate() {
            if !f.at.is_finite() || f.at < 0.0 {
                return Err(crate::Error::config(format!(
                    "faults.script[{i}].at must be a finite time >= 0"
                )));
            }
            if !f.down_s.is_finite() {
                return Err(crate::Error::config(format!(
                    "faults.script[{i}].down_s must be finite"
                )));
            }
        }
        Ok(())
    }
}

/// Heterogeneous decode-fleet shape: hardware profiles cycled over decode
/// instance ids (`profiles[id % len]`), including instances the elastic
/// pool provisions mid-run — a replacement joins with the profile of the
/// slot position it lands on.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetSpec {
    pub profiles: Vec<HardwareProfile>,
}

impl FleetSpec {
    /// Build from parallel multiplier lists (shorter list is cycled).
    pub fn from_mults(speed_mults: &[f64], mem_mults: &[f64]) -> FleetSpec {
        let n = speed_mults.len().max(mem_mults.len()).max(1);
        let pick = |v: &[f64], i: usize| if v.is_empty() { 1.0 } else { v[i % v.len()] };
        FleetSpec {
            profiles: (0..n)
                .map(|i| HardwareProfile {
                    speed_mult: pick(speed_mults, i),
                    mem_mult: pick(mem_mults, i),
                })
                .collect(),
        }
    }

    /// Profile of decode instance `id` (cycled).
    pub fn profile(&self, id: usize) -> HardwareProfile {
        if self.profiles.is_empty() {
            HardwareProfile::default()
        } else {
            self.profiles[id % self.profiles.len()]
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.profiles.is_empty() {
            return Err(crate::Error::config("fleet.profiles must be non-empty"));
        }
        for (i, p) in self.profiles.iter().enumerate() {
            if !(p.speed_mult.is_finite() && p.speed_mult > 0.0) {
                return Err(crate::Error::config(format!(
                    "fleet profile {i}: speed_mult must be finite and > 0"
                )));
            }
            if !(p.mem_mult.is_finite() && p.mem_mult > 0.0) {
                return Err(crate::Error::config(format!(
                    "fleet profile {i}: mem_mult must be finite and > 0"
                )));
            }
        }
        Ok(())
    }
}

/// Multi-round session shape.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionProfile {
    /// Probability that an initial request starts a multi-round session.
    pub session_frac: f64,
    /// Total turns per session, uniform in `[min_turns, max_turns]`.
    pub min_turns: u32,
    pub max_turns: u32,
    /// Mean think time between a turn's completion and the next turn's
    /// arrival (exponential).
    pub think_mean_s: f64,
    /// Accumulated-history cap: follow-up prompts never exceed this.
    pub max_context_tokens: u32,
}

impl Default for SessionProfile {
    fn default() -> Self {
        SessionProfile {
            session_frac: 0.5,
            min_turns: 2,
            max_turns: 4,
            think_mean_s: 5.0,
            max_context_tokens: 32_768,
        }
    }
}

impl SessionProfile {
    pub fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.session_frac) {
            return Err(crate::Error::config("session.frac must be in [0,1]"));
        }
        if self.min_turns < 2 || self.max_turns < self.min_turns {
            return Err(crate::Error::config(
                "session turns need 2 <= min_turns <= max_turns",
            ));
        }
        if self.think_mean_s <= 0.0 {
            return Err(crate::Error::config("session.think_mean_s must be > 0"));
        }
        if self.max_context_tokens == 0 {
            return Err(crate::Error::config("session.max_context must be > 0"));
        }
        Ok(())
    }
}

/// One precomputed follow-up turn of a session.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionTurn {
    /// Prompt length INCLUDING accumulated history tokens.
    pub prompt_len: u32,
    pub output_len: u32,
    /// Delay between the previous turn's completion and this arrival.
    pub think_time_s: f64,
    pub class: RequestClass,
    pub tag: u8,
}

/// The session side of a [`ScenarioTrace`]: per-session scripts of
/// follow-up turns, plus which initial request opens which session.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SessionPlan {
    /// `scripts[s]` = follow-up turns (turn 2, 3, …) of session `s`.
    pub scripts: Vec<Vec<SessionTurn>>,
    /// `(initial request id, session index)` pairs.
    pub first_turns: Vec<(RequestId, u32)>,
}

impl SessionPlan {
    pub fn is_empty(&self) -> bool {
        self.scripts.is_empty()
    }

    /// Total follow-up requests this plan will spawn if every turn's
    /// predecessor completes.
    pub fn total_follow_ups(&self) -> usize {
        self.scripts.iter().map(|s| s.len()).sum()
    }
}

/// A fully-specified workload scenario.
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    /// Registry / display name ("stationary", "bursty_mixed", "custom"…).
    pub name: String,
    pub arrival: ArrivalProcess,
    pub classes: ClassMix,
    pub sessions: Option<SessionProfile>,
    /// If set, rescale lengths to the pico (real-execution) domain
    /// `(max_prompt, max_output)` — mirrors `TraceGen::pico`.
    pub pico_scale: Option<(u32, u32)>,
    /// Failure-injection plan carried alongside the workload (the
    /// simulator realizes it as `InstanceFailure` events).
    pub faults: Option<FaultConfig>,
    /// Heterogeneous decode-fleet shape; `None` = uniform hardware.
    pub fleet: Option<FleetSpec>,
}

/// A generated scenario workload: initial arrivals + session plan, plus
/// the environment shape (faults, fleet) the spec carried.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioTrace {
    pub requests: Vec<Request>,
    pub sessions: SessionPlan,
    pub faults: Option<FaultConfig>,
    pub fleet: Option<FleetSpec>,
}

impl ScenarioTrace {
    /// Wrap a plain request trace (no sessions) — the compatibility path
    /// every pre-scenario caller goes through.
    pub fn from_requests(requests: Vec<Request>) -> ScenarioTrace {
        ScenarioTrace {
            requests,
            sessions: SessionPlan::default(),
            faults: None,
            fleet: None,
        }
    }

    /// Initial requests plus every planned follow-up turn.
    pub fn total_planned(&self) -> usize {
        self.requests.len() + self.sessions.total_follow_ups()
    }
}

impl ScenarioSpec {
    /// The legacy single-class stationary workload (what `TraceGen`
    /// produced): Poisson arrivals over one dataset-shaped class.
    pub fn stationary(dataset: super::Dataset, rps: f64) -> ScenarioSpec {
        ScenarioSpec {
            name: "stationary".to_string(),
            arrival: ArrivalProcess::Poisson { rps },
            classes: ClassMix::single(ClassSpec::dataset(dataset)),
            sessions: None,
            pico_scale: None,
            faults: None,
            fleet: None,
        }
    }

    /// Rescale to the real-execution domain (star-pico budgets).
    pub fn pico(mut self, max_prompt: u32, max_output: u32) -> ScenarioSpec {
        self.pico_scale = Some((max_prompt, max_output));
        self
    }

    pub fn validate(&self) -> Result<()> {
        self.arrival.validate()?;
        for spec in self.classes.specs() {
            spec.validate()?;
        }
        if let Some(s) = &self.sessions {
            s.validate()?;
        }
        if let Some(f) = &self.faults {
            f.validate()?;
        }
        if let Some(f) = &self.fleet {
            f.validate()?;
        }
        Ok(())
    }

    /// Per-class SLO targets of this scenario.
    pub fn slos(&self) -> SloByClass {
        self.classes.slos()
    }

    /// Generate `n` initial requests (sessions add follow-up turns on
    /// top). Deterministic: same seed ⇒ identical trace.
    pub fn generate(&self, n: usize, seed: u64) -> ScenarioTrace {
        let mut rng = Pcg64::new(seed, SCENARIO_STREAM);
        let times = self.arrival.sample(n, &mut rng);
        self.build(&times, &mut rng)
    }

    /// Generate all initial requests arriving in `[0, duration]` seconds.
    pub fn generate_for(&self, duration: Time, seed: u64) -> ScenarioTrace {
        let mut rng = Pcg64::new(seed, SCENARIO_STREAM);
        let times = self.arrival.sample_for(duration, &mut rng);
        self.build(&times, &mut rng)
    }

    fn build(&self, times: &[Time], rng: &mut Pcg64) -> ScenarioTrace {
        let mut requests = Vec::with_capacity(times.len());
        let mut plan = SessionPlan::default();
        for (id, &t) in times.iter().enumerate() {
            let spec = self.classes.sample(rng);
            let prompt_raw = spec.lengths.sample_prompt(rng);
            let output_raw = spec.lengths.sample_output(rng);
            let (prompt_len, output_len) =
                spec.lengths.rescale(self.pico_scale, prompt_raw, output_raw);
            requests.push(Request {
                id: id as RequestId,
                arrival: t,
                prompt_len,
                output_len,
                tag: spec.lengths.tag_band(output_raw),
                class: spec.class,
            });
            if let Some(sp) = &self.sessions {
                // draw the session coin for every request so the arrival /
                // length streams stay aligned regardless of the outcome
                if sp.session_frac > 0.0 && rng.coin(sp.session_frac) {
                    let total_turns =
                        rng.range_u64(sp.min_turns as u64, sp.max_turns as u64) as u32;
                    let script =
                        self.build_script(sp, spec, prompt_len, output_len, total_turns, rng);
                    if !script.is_empty() {
                        plan.first_turns.push((id as RequestId, plan.scripts.len() as u32));
                        plan.scripts.push(script);
                    }
                }
            }
        }
        ScenarioTrace {
            requests,
            sessions: plan,
            faults: self.faults.clone(),
            fleet: self.fleet.clone(),
        }
    }

    /// Follow-up turns 2..=total for one session: each prompt carries the
    /// accumulated history of everything before it.
    fn build_script(
        &self,
        sp: &SessionProfile,
        spec: &ClassSpec,
        first_prompt: u32,
        first_output: u32,
        total_turns: u32,
        rng: &mut Pcg64,
    ) -> Vec<SessionTurn> {
        let max_ctx = match self.pico_scale {
            Some((mp, _)) => sp.max_context_tokens.min(mp),
            None => sp.max_context_tokens,
        };
        let mut script = Vec::new();
        let mut ctx = first_prompt.saturating_add(first_output);
        for _ in 1..total_turns {
            let fresh_raw = spec.lengths.sample_prompt(rng);
            let out_raw = spec.lengths.sample_output(rng);
            let (fresh, output_len) = spec.lengths.rescale(self.pico_scale, fresh_raw, out_raw);
            let prompt_len = ctx.saturating_add(fresh).clamp(1, max_ctx);
            let think_time_s = rng.exponential(1.0 / sp.think_mean_s.max(1e-9));
            script.push(SessionTurn {
                prompt_len,
                output_len,
                think_time_s,
                class: spec.class,
                tag: spec.lengths.tag_band(out_raw),
            });
            ctx = prompt_len.saturating_add(output_len);
        }
        script
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Dataset;

    fn session_spec() -> ScenarioSpec {
        ScenarioSpec {
            name: "test_sessions".to_string(),
            arrival: ArrivalProcess::Poisson { rps: 1.0 },
            classes: ClassMix::mixed_default(),
            sessions: Some(SessionProfile {
                session_frac: 0.7,
                min_turns: 2,
                max_turns: 4,
                think_mean_s: 3.0,
                max_context_tokens: 60_000,
            }),
            pico_scale: None,
            faults: None,
            fleet: None,
        }
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let spec = session_spec();
        assert_eq!(spec.generate(200, 9), spec.generate(200, 9));
        assert_ne!(spec.generate(200, 9), spec.generate(200, 10));
    }

    #[test]
    fn session_prompts_grow_with_history() {
        let spec = session_spec();
        let trace = spec.generate(400, 3);
        assert!(!trace.sessions.is_empty(), "session_frac 0.7 must open sessions");
        assert!(trace.sessions.total_follow_ups() > 0);
        for &(rid, s) in &trace.sessions.first_turns {
            let first = &trace.requests[rid as usize];
            let script = &trace.sessions.scripts[s as usize];
            let mut prev_ctx = first.prompt_len + first.output_len;
            for turn in script {
                assert!(
                    turn.prompt_len >= prev_ctx.min(60_000),
                    "turn prompt {} must include history {}",
                    turn.prompt_len,
                    prev_ctx
                );
                assert!(turn.prompt_len <= 60_000);
                assert!(turn.think_time_s > 0.0);
                prev_ctx = turn.prompt_len + turn.output_len;
            }
        }
    }

    #[test]
    fn stationary_matches_trace_gen_shape() {
        let spec = ScenarioSpec::stationary(Dataset::ShareGpt, 2.0);
        let trace = spec.generate(4_000, 1);
        assert!(trace.sessions.is_empty());
        for w in trace.requests.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let rate = trace.requests.len() as f64 / trace.requests.last().unwrap().arrival;
        assert!((rate - 2.0).abs() < 0.2, "rate {rate}");
        assert!(trace.requests.iter().all(|r| r.class == RequestClass::Chat));
    }

    #[test]
    fn mixed_classes_all_present() {
        let spec = ScenarioSpec {
            sessions: None,
            ..session_spec()
        };
        let trace = spec.generate(2_000, 4);
        for class in RequestClass::ALL {
            let n = trace.requests.iter().filter(|r| r.class == class).count();
            assert!(n > 100, "class {} underrepresented: {n}", class.name());
        }
    }

    #[test]
    fn pico_scale_bounds_all_turns() {
        let spec = session_spec().pico(128, 512);
        let trace = spec.generate(500, 6);
        for r in &trace.requests {
            assert!((1..=128).contains(&r.prompt_len));
            assert!((1..=512).contains(&r.output_len));
        }
        for script in &trace.sessions.scripts {
            for turn in script {
                assert!((1..=128).contains(&turn.prompt_len));
                assert!((1..=512).contains(&turn.output_len));
            }
        }
    }

    #[test]
    fn fault_and_fleet_validation() {
        let mut f = FaultConfig::default();
        assert!(!f.enabled());
        assert!(f.validate().is_ok());
        f.mtbf_s = 60.0;
        f.mttr_s = 0.0;
        assert!(f.enabled());
        assert!(f.validate().is_err());
        f.mttr_s = 10.0;
        assert!(f.validate().is_ok());
        f.script.push(FaultEvent {
            at: -1.0,
            instance: 0,
            down_s: 5.0,
        });
        assert!(f.validate().is_err());

        let fleet = FleetSpec::from_mults(&[1.0, 2.0], &[1.5]);
        assert!(fleet.validate().is_ok());
        assert_eq!(fleet.profiles.len(), 2);
        assert_eq!(fleet.profile(1).speed_mult, 2.0);
        assert_eq!(fleet.profile(2), fleet.profile(0));
        assert!(FleetSpec { profiles: vec![] }.validate().is_err());
        let bad = FleetSpec::from_mults(&[0.0], &[1.0]);
        assert!(bad.validate().is_err());

        let mut spec = session_spec();
        spec.fleet = Some(FleetSpec { profiles: vec![] });
        assert!(spec.validate().is_err());
    }

    #[test]
    fn trace_carries_faults_and_fleet() {
        let mut spec = session_spec();
        spec.faults = Some(FaultConfig {
            mtbf_s: 120.0,
            ..Default::default()
        });
        spec.fleet = Some(FleetSpec::from_mults(&[1.0, 0.5], &[1.0, 2.0]));
        let trace = spec.generate(50, 7);
        assert_eq!(trace.faults, spec.faults);
        assert_eq!(trace.fleet, spec.fleet);
        assert!(ScenarioTrace::from_requests(vec![]).faults.is_none());
    }

    #[test]
    fn profile_validation() {
        let mut p = SessionProfile::default();
        p.session_frac = 1.5;
        assert!(p.validate().is_err());
        let mut p = SessionProfile::default();
        p.min_turns = 1;
        assert!(p.validate().is_err());
        let mut p = SessionProfile::default();
        p.max_turns = 1;
        assert!(p.validate().is_err());
        assert!(SessionProfile::default().validate().is_ok());
    }
}
