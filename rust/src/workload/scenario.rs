//! Scenario synthesis: arrival process × class mix × multi-round sessions.
//!
//! A [`ScenarioSpec`] is the full workload shape of one experiment. Its
//! [`ScenarioSpec::generate`] output is a [`ScenarioTrace`]: the initial
//! request arrivals plus a [`SessionPlan`] of precomputed follow-up turns.
//! Follow-up turns model multi-round conversations ("Efficient Multi-round
//! LLM Inference over Disaggregated Serving", arXiv:2602.14516): turn k+1
//! arrives a think-time after turn k *completes*, and its prompt includes
//! the accumulated history (previous prompt + previous output + fresh user
//! text). Because completion times are dynamic, the drivers — not the
//! generator — realize follow-up arrivals: the simulator through its
//! `SessionFollowUp` event, the live server through the same plan, so both
//! replay the identical per-turn schedule.

use super::arrival::ArrivalProcess;
use super::classes::{ClassMix, ClassSpec, RequestClass, SloByClass};
use super::Request;
use crate::prng::Pcg64;
use crate::{RequestId, Result, Time};

/// PRNG stream id for scenario generation ("SCEN").
const SCENARIO_STREAM: u64 = 0x5343_454e;

/// Multi-round session shape.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionProfile {
    /// Probability that an initial request starts a multi-round session.
    pub session_frac: f64,
    /// Total turns per session, uniform in `[min_turns, max_turns]`.
    pub min_turns: u32,
    pub max_turns: u32,
    /// Mean think time between a turn's completion and the next turn's
    /// arrival (exponential).
    pub think_mean_s: f64,
    /// Accumulated-history cap: follow-up prompts never exceed this.
    pub max_context_tokens: u32,
}

impl Default for SessionProfile {
    fn default() -> Self {
        SessionProfile {
            session_frac: 0.5,
            min_turns: 2,
            max_turns: 4,
            think_mean_s: 5.0,
            max_context_tokens: 32_768,
        }
    }
}

impl SessionProfile {
    pub fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.session_frac) {
            return Err(crate::Error::config("session.frac must be in [0,1]"));
        }
        if self.min_turns < 2 || self.max_turns < self.min_turns {
            return Err(crate::Error::config(
                "session turns need 2 <= min_turns <= max_turns",
            ));
        }
        if self.think_mean_s <= 0.0 {
            return Err(crate::Error::config("session.think_mean_s must be > 0"));
        }
        if self.max_context_tokens == 0 {
            return Err(crate::Error::config("session.max_context must be > 0"));
        }
        Ok(())
    }
}

/// One precomputed follow-up turn of a session.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionTurn {
    /// Prompt length INCLUDING accumulated history tokens.
    pub prompt_len: u32,
    pub output_len: u32,
    /// Delay between the previous turn's completion and this arrival.
    pub think_time_s: f64,
    pub class: RequestClass,
    pub tag: u8,
}

/// The session side of a [`ScenarioTrace`]: per-session scripts of
/// follow-up turns, plus which initial request opens which session.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SessionPlan {
    /// `scripts[s]` = follow-up turns (turn 2, 3, …) of session `s`.
    pub scripts: Vec<Vec<SessionTurn>>,
    /// `(initial request id, session index)` pairs.
    pub first_turns: Vec<(RequestId, u32)>,
}

impl SessionPlan {
    pub fn is_empty(&self) -> bool {
        self.scripts.is_empty()
    }

    /// Total follow-up requests this plan will spawn if every turn's
    /// predecessor completes.
    pub fn total_follow_ups(&self) -> usize {
        self.scripts.iter().map(|s| s.len()).sum()
    }
}

/// A fully-specified workload scenario.
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    /// Registry / display name ("stationary", "bursty_mixed", "custom"…).
    pub name: String,
    pub arrival: ArrivalProcess,
    pub classes: ClassMix,
    pub sessions: Option<SessionProfile>,
    /// If set, rescale lengths to the pico (real-execution) domain
    /// `(max_prompt, max_output)` — mirrors `TraceGen::pico`.
    pub pico_scale: Option<(u32, u32)>,
}

/// A generated scenario workload: initial arrivals + session plan.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioTrace {
    pub requests: Vec<Request>,
    pub sessions: SessionPlan,
}

impl ScenarioTrace {
    /// Wrap a plain request trace (no sessions) — the compatibility path
    /// every pre-scenario caller goes through.
    pub fn from_requests(requests: Vec<Request>) -> ScenarioTrace {
        ScenarioTrace {
            requests,
            sessions: SessionPlan::default(),
        }
    }

    /// Initial requests plus every planned follow-up turn.
    pub fn total_planned(&self) -> usize {
        self.requests.len() + self.sessions.total_follow_ups()
    }
}

impl ScenarioSpec {
    /// The legacy single-class stationary workload (what `TraceGen`
    /// produced): Poisson arrivals over one dataset-shaped class.
    pub fn stationary(dataset: super::Dataset, rps: f64) -> ScenarioSpec {
        ScenarioSpec {
            name: "stationary".to_string(),
            arrival: ArrivalProcess::Poisson { rps },
            classes: ClassMix::single(ClassSpec::dataset(dataset)),
            sessions: None,
            pico_scale: None,
        }
    }

    /// Rescale to the real-execution domain (star-pico budgets).
    pub fn pico(mut self, max_prompt: u32, max_output: u32) -> ScenarioSpec {
        self.pico_scale = Some((max_prompt, max_output));
        self
    }

    pub fn validate(&self) -> Result<()> {
        self.arrival.validate()?;
        for spec in self.classes.specs() {
            spec.validate()?;
        }
        if let Some(s) = &self.sessions {
            s.validate()?;
        }
        Ok(())
    }

    /// Per-class SLO targets of this scenario.
    pub fn slos(&self) -> SloByClass {
        self.classes.slos()
    }

    /// Generate `n` initial requests (sessions add follow-up turns on
    /// top). Deterministic: same seed ⇒ identical trace.
    pub fn generate(&self, n: usize, seed: u64) -> ScenarioTrace {
        let mut rng = Pcg64::new(seed, SCENARIO_STREAM);
        let times = self.arrival.sample(n, &mut rng);
        self.build(&times, &mut rng)
    }

    /// Generate all initial requests arriving in `[0, duration]` seconds.
    pub fn generate_for(&self, duration: Time, seed: u64) -> ScenarioTrace {
        let mut rng = Pcg64::new(seed, SCENARIO_STREAM);
        let times = self.arrival.sample_for(duration, &mut rng);
        self.build(&times, &mut rng)
    }

    fn build(&self, times: &[Time], rng: &mut Pcg64) -> ScenarioTrace {
        let mut requests = Vec::with_capacity(times.len());
        let mut plan = SessionPlan::default();
        for (id, &t) in times.iter().enumerate() {
            let spec = self.classes.sample(rng);
            let prompt_raw = spec.lengths.sample_prompt(rng);
            let output_raw = spec.lengths.sample_output(rng);
            let (prompt_len, output_len) =
                spec.lengths.rescale(self.pico_scale, prompt_raw, output_raw);
            requests.push(Request {
                id: id as RequestId,
                arrival: t,
                prompt_len,
                output_len,
                tag: spec.lengths.tag_band(output_raw),
                class: spec.class,
            });
            if let Some(sp) = &self.sessions {
                // draw the session coin for every request so the arrival /
                // length streams stay aligned regardless of the outcome
                if sp.session_frac > 0.0 && rng.coin(sp.session_frac) {
                    let total_turns =
                        rng.range_u64(sp.min_turns as u64, sp.max_turns as u64) as u32;
                    let script =
                        self.build_script(sp, spec, prompt_len, output_len, total_turns, rng);
                    if !script.is_empty() {
                        plan.first_turns.push((id as RequestId, plan.scripts.len() as u32));
                        plan.scripts.push(script);
                    }
                }
            }
        }
        ScenarioTrace {
            requests,
            sessions: plan,
        }
    }

    /// Follow-up turns 2..=total for one session: each prompt carries the
    /// accumulated history of everything before it.
    fn build_script(
        &self,
        sp: &SessionProfile,
        spec: &ClassSpec,
        first_prompt: u32,
        first_output: u32,
        total_turns: u32,
        rng: &mut Pcg64,
    ) -> Vec<SessionTurn> {
        let max_ctx = match self.pico_scale {
            Some((mp, _)) => sp.max_context_tokens.min(mp),
            None => sp.max_context_tokens,
        };
        let mut script = Vec::new();
        let mut ctx = first_prompt.saturating_add(first_output);
        for _ in 1..total_turns {
            let fresh_raw = spec.lengths.sample_prompt(rng);
            let out_raw = spec.lengths.sample_output(rng);
            let (fresh, output_len) = spec.lengths.rescale(self.pico_scale, fresh_raw, out_raw);
            let prompt_len = ctx.saturating_add(fresh).clamp(1, max_ctx);
            let think_time_s = rng.exponential(1.0 / sp.think_mean_s.max(1e-9));
            script.push(SessionTurn {
                prompt_len,
                output_len,
                think_time_s,
                class: spec.class,
                tag: spec.lengths.tag_band(out_raw),
            });
            ctx = prompt_len.saturating_add(output_len);
        }
        script
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Dataset;

    fn session_spec() -> ScenarioSpec {
        ScenarioSpec {
            name: "test_sessions".to_string(),
            arrival: ArrivalProcess::Poisson { rps: 1.0 },
            classes: ClassMix::mixed_default(),
            sessions: Some(SessionProfile {
                session_frac: 0.7,
                min_turns: 2,
                max_turns: 4,
                think_mean_s: 3.0,
                max_context_tokens: 60_000,
            }),
            pico_scale: None,
        }
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let spec = session_spec();
        assert_eq!(spec.generate(200, 9), spec.generate(200, 9));
        assert_ne!(spec.generate(200, 9), spec.generate(200, 10));
    }

    #[test]
    fn session_prompts_grow_with_history() {
        let spec = session_spec();
        let trace = spec.generate(400, 3);
        assert!(!trace.sessions.is_empty(), "session_frac 0.7 must open sessions");
        assert!(trace.sessions.total_follow_ups() > 0);
        for &(rid, s) in &trace.sessions.first_turns {
            let first = &trace.requests[rid as usize];
            let script = &trace.sessions.scripts[s as usize];
            let mut prev_ctx = first.prompt_len + first.output_len;
            for turn in script {
                assert!(
                    turn.prompt_len >= prev_ctx.min(60_000),
                    "turn prompt {} must include history {}",
                    turn.prompt_len,
                    prev_ctx
                );
                assert!(turn.prompt_len <= 60_000);
                assert!(turn.think_time_s > 0.0);
                prev_ctx = turn.prompt_len + turn.output_len;
            }
        }
    }

    #[test]
    fn stationary_matches_trace_gen_shape() {
        let spec = ScenarioSpec::stationary(Dataset::ShareGpt, 2.0);
        let trace = spec.generate(4_000, 1);
        assert!(trace.sessions.is_empty());
        for w in trace.requests.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let rate = trace.requests.len() as f64 / trace.requests.last().unwrap().arrival;
        assert!((rate - 2.0).abs() < 0.2, "rate {rate}");
        assert!(trace.requests.iter().all(|r| r.class == RequestClass::Chat));
    }

    #[test]
    fn mixed_classes_all_present() {
        let spec = ScenarioSpec {
            sessions: None,
            ..session_spec()
        };
        let trace = spec.generate(2_000, 4);
        for class in RequestClass::ALL {
            let n = trace.requests.iter().filter(|r| r.class == class).count();
            assert!(n > 100, "class {} underrepresented: {n}", class.name());
        }
    }

    #[test]
    fn pico_scale_bounds_all_turns() {
        let spec = session_spec().pico(128, 512);
        let trace = spec.generate(500, 6);
        for r in &trace.requests {
            assert!((1..=128).contains(&r.prompt_len));
            assert!((1..=512).contains(&r.output_len));
        }
        for script in &trace.sessions.scripts {
            for turn in script {
                assert!((1..=128).contains(&turn.prompt_len));
                assert!((1..=512).contains(&turn.output_len));
            }
        }
    }

    #[test]
    fn profile_validation() {
        let mut p = SessionProfile::default();
        p.session_frac = 1.5;
        assert!(p.validate().is_err());
        let mut p = SessionProfile::default();
        p.min_turns = 1;
        assert!(p.validate().is_err());
        let mut p = SessionProfile::default();
        p.max_turns = 1;
        assert!(p.validate().is_err());
        assert!(SessionProfile::default().validate().is_ok());
    }
}
