//! `star` — CLI launcher for the STAR serving stack.
//!
//! Subcommands:
//!   check           load + smoke-test the AOT artifacts
//!   workload        print Table-2-style statistics for a synthetic trace
//!   simulate        run the event-driven cluster simulator (paper §6.3)
//!   serve           run the live PD-disaggregated server on star-pico
//!   list            print registered dispatch/reschedule/scaling
//!                   policies and workload scenarios
//!   validate-bench  assert BENCH_*.json files parse and carry
//!                   schema_version (the ci.sh --smoke gate)
//!   analyze         dependency-free determinism/safety lint over
//!                   rust/src (rules R1-R6, DESIGN.md §14); nonzero
//!                   exit on findings
//!   trace           run the simulator with observability forced on and
//!                   inspect the result: summarize | slo-violations |
//!                   export (--format chrome|jsonl)
//!
//! Most options can also be set from a TOML config (`--config path`) with
//! CLI flags winning.

use std::sync::Arc;

use star::bench::scenarios::{resolve_scenario, ScenarioRegistry};
use star::cli::{Args, Spec};
use star::config::{Config, ExperimentConfig, PredictorKind};
use star::coordinator::PolicyRegistry;
use star::metrics::Slo;
use star::predictor::PredictorRegistry;
use star::runtime::{artifacts_dir, StarRuntime};
use star::serve::{LiveRequest, ServeParams, Server};
use star::sim::{SimParams, Simulator};
use star::workload::{Dataset, ScenarioTrace, TraceGen, TraceStats};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let spec = spec();
    let args = match Args::parse(&argv, &spec) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_str() {
        "check" => run_check(&args),
        "workload" => run_workload(&args),
        "simulate" => run_simulate(&args),
        "serve" => run_serve(&args),
        "list" => run_list(),
        "validate-bench" => run_validate_bench(&args),
        "analyze" => run_analyze(&args),
        "trace" => run_trace(&args),
        "" | "help" => {
            println!("{}", spec.render_help());
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand `{other}`\n\n{}", spec.render_help());
            std::process::exit(2);
        }
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e}");
        1
    });
    std::process::exit(code);
}

fn spec() -> Spec {
    Spec {
        name: "star",
        about: "STAR: decode-phase rescheduling for LLM inference (HPDC'26 reproduction)",
        options: vec![
            ("config", "path", "TOML config file"),
            ("set", "k=v", "override one config key (comma-separated list)"),
            ("artifacts", "dir", "artifacts directory (default: ./artifacts)"),
            ("dataset", "name", "sharegpt|alpaca (default sharegpt)"),
            ("rps", "f", "request rate per second"),
            ("requests", "n", "number of requests"),
            ("decode", "n", "decode instances"),
            ("prefill", "n", "prefill instances"),
            ("kv-capacity", "tokens", "KV capacity per decode instance"),
            ("policy", "name", "baseline: vllm | star | star-nopred | oracle"),
            (
                "dispatch",
                "name",
                "round_robin | current_load | predicted_load | slo_aware",
            ),
            (
                "reschedule",
                "name",
                "star | memory_pressure | none (registry name)",
            ),
            (
                "scaling",
                "name",
                "elastic pool policy: static | queue_pressure | predictive",
            ),
            (
                "scenario",
                "name",
                "workload scenario: stationary | bursty_mixed | diurnal_chat | multi_round \
                 | degraded_fleet | mixed_gen",
            ),
            ("predictor", "name", "none|oracle|llm_native|2bin|4bin|6bin"),
            (
                "cache",
                "name",
                "prefix-cache policy: none | lru | ttl | predictive",
            ),
            ("interval", "s", "rescheduler interval seconds"),
            ("seed", "n", "PRNG seed"),
            (
                "shards",
                "n",
                "sim event-loop shards (default 1; any n is trajectory-identical)",
            ),
            ("duration", "s", "trace duration (simulate)"),
            ("trace-out", "path", "write event trace TSV"),
            (
                "rules",
                "ids",
                "analyze: comma-separated rule subset (R1..R7 or slugs)",
            ),
            (
                "format",
                "fmt",
                "trace export format: chrome | jsonl (default chrome)",
            ),
            (
                "require",
                "names",
                "validate-bench: comma-separated bench names that must all be \
                 present among the given files (a deleted bench fails the gate)",
            ),
        ],
        flags: vec![
            ("verbose", "chatty progress"),
            ("traces", "record runtime traces"),
            ("list-rules", "analyze: print the rule catalog and exit"),
            (
                "fail-on-lost",
                "simulate: exit nonzero if failure injection lost any request",
            ),
            (
                "validate-state",
                "simulate/trace: assert incremental state (and the shard \
                 rollup) against a from-scratch rebuild after every event",
            ),
        ],
    }
}

/// Map a `--policy` name onto (rescheduler enabled, predictor name).
fn policy_of(args: &Args) -> Result<(bool, Option<&'static str>), star::Error> {
    match args.opt("policy") {
        None => Ok((true, None)),
        Some("vllm") => Ok((false, Some("none"))),
        Some("star-nopred") => Ok((true, Some("none"))),
        Some("star") => Ok((true, None)),
        Some("oracle") => Ok((true, Some("oracle"))),
        Some(other) => Err(star::Error::Cli(format!(
            "unknown policy `{other}` (vllm|star|star-nopred|oracle)"
        ))),
    }
}

fn experiment_of(args: &Args) -> Result<ExperimentConfig, star::Error> {
    let mut cfg = match args.opt("config") {
        Some(p) => Config::from_file(std::path::Path::new(p))?,
        None => Config::from_str("")?,
    };
    if let Some(sets) = args.opt("set") {
        for kv in sets.split(',') {
            cfg.set_kv(kv)?;
        }
    }
    let mut exp = ExperimentConfig::from_config(&cfg)?;
    if let Some(d) = args.opt("dataset") {
        exp.cluster.dataset = Dataset::parse(d).ok_or_else(|| bad_dataset(d))?;
    }
    exp.cluster.rps = args.opt_f64("rps", exp.cluster.rps)?;
    exp.cluster.n_requests = args.opt_usize("requests", exp.cluster.n_requests)?;
    exp.cluster.n_decode = args.opt_usize("decode", exp.cluster.n_decode)?;
    exp.cluster.n_prefill = args.opt_usize("prefill", exp.cluster.n_prefill)?;
    exp.cluster.kv_capacity_tokens =
        args.opt_u64("kv-capacity", exp.cluster.kv_capacity_tokens)?;
    exp.cluster.seed = args.opt_u64("seed", exp.cluster.seed)?;
    exp.shards = args.opt_usize("shards", exp.shards)?;
    exp.rescheduler.interval_s = args.opt_f64("interval", exp.rescheduler.interval_s)?;
    let (resched, pred) = policy_of(args)?;
    exp.rescheduler.enabled = resched;
    if let Some(p) = pred {
        exp.predictor = p.to_string();
    }
    if let Some(p) = args.opt("predictor") {
        // any registered predictor name; validate() rejects unknown ones
        // with the registry's candidate list
        exp.predictor = p.to_string();
    }
    // canonicalize alias spellings of the builtins ("4bin" → "binned4")
    // so every surface — --verbose echo, bench JSON, scorecard output —
    // shows the registry key; unknown names pass through for validate()
    // to reject with the candidate list
    if let Ok(kind) = PredictorKind::parse(&exp.predictor) {
        exp.predictor = kind.name();
    }
    if let Some(d) = args.opt("dispatch") {
        exp.dispatch_policy = d.to_string();
    }
    if let Some(r) = args.opt("reschedule") {
        exp.reschedule_policy = r.to_string();
    }
    if let Some(s) = args.opt("scaling") {
        exp.scaling_policy = s.to_string();
    }
    if let Some(c) = args.opt("cache") {
        exp.kvcache.policy = c.to_string();
    }
    // [workload.*] table defaults derive from cluster.rps / dataset:
    // rebuild the scenario so the CLI overrides above are honored (flags
    // win, as documented), instead of freezing config-parse-time values
    exp.rebuild_scenario(&cfg)?;
    if let Some(s) = args.opt("scenario") {
        // the CLI name overrides any [workload.*] tables from --config
        exp.scenario_name = Some(s.to_string());
        exp.scenario = None;
        let reg = ScenarioRegistry::with_builtins();
        if !reg.has(s) {
            return Err(star::Error::Cli(format!(
                "unknown scenario `{s}` (known: {})",
                reg.names().join("|")
            )));
        }
    }
    exp.record_traces = args.flag("traces") || args.opt("trace-out").is_some();
    // validate() surfaces unknown --dispatch/--reschedule names with the
    // full registry list — never silently fall back to a default policy
    exp.validate()?;
    Ok(exp)
}

/// Unknown-dataset error listing the valid names (the old message was a
/// bare "bad dataset" that left users guessing).
fn bad_dataset(d: &str) -> star::Error {
    star::Error::Cli(format!(
        "unknown dataset `{d}` (known: {})",
        Dataset::NAMES.join("|")
    ))
}

fn run_check(args: &Args) -> Result<(), star::Error> {
    let dir = artifacts_dir(args.opt("artifacts"))?;
    println!("artifacts: {}", dir.display());
    let rt = StarRuntime::load(&dir)?;
    println!("platform:  {}", rt.platform());
    println!(
        "model:     star-pico d={} L={} H={} ctx={} vocab={}",
        rt.meta.d_model, rt.meta.n_layers, rt.meta.n_heads, rt.meta.max_seq, rt.meta.vocab
    );
    println!(
        "params:    {} tensors, {} elems",
        rt.params.entries.len(),
        rt.params.total_elems()
    );
    let out = rt.prefill(b"\x01Qhello?")?;
    println!(
        "prefill OK: {} logits, hidden[0..4] = {:?}",
        out.logits.len(),
        &out.hidden[..4]
    );
    let mut kv = rt.new_kv_buffer(1);
    rt.copy_kv_slot(&out.kv, 1, 0, &mut kv, 1, 0)?;
    let d = rt.decode_step(1, &[65], &[8], &kv)?;
    println!("decode  OK: logits[0..4] = {:?}", &d.logits[..4]);
    let p = rt.predict_remaining(&out.hidden)?;
    println!("predict OK: remaining ~ {:.1} tokens", p[0]);
    Ok(())
}

fn run_workload(args: &Args) -> Result<(), star::Error> {
    let name = args.opt_or("dataset", "sharegpt");
    let ds = Dataset::parse(name).ok_or_else(|| bad_dataset(name))?;
    let n = args.opt_usize("requests", 20_000)?;
    let rps = args.opt_f64("rps", 1.0)?;
    let seed = args.opt_u64("seed", 0)?;
    let trace = TraceGen::new(ds, rps).generate(n, seed);
    let st = TraceStats::from_requests(&trace);
    println!("| Workload | Metric | Mean | Std | P50 | P90 | P95 |");
    println!("|----------|--------|------|-----|-----|-----|-----|");
    println!("{}", st.render(ds.name()));
    let long = trace.iter().filter(|r| r.output_len > 30_000).count();
    println!(
        "\n{} requests; {:.1}% generate >30K tokens (paper: 17.3% for ShareGPT)",
        n,
        100.0 * long as f64 / n as f64
    );
    Ok(())
}

fn run_simulate(args: &Args) -> Result<(), star::Error> {
    let exp = experiment_of(args)?;
    let verbose = args.flag("verbose");
    let scenario = resolve_scenario(&exp)?;
    let strace = match &scenario {
        Some(spec) => match args.opt("duration") {
            Some(_) => spec.generate_for(args.opt_f64("duration", 2000.0)?, exp.cluster.seed),
            None => spec.generate(exp.cluster.n_requests, exp.cluster.seed),
        },
        None => {
            let gen = TraceGen::new(exp.cluster.dataset, exp.cluster.rps);
            let trace = match args.opt("duration") {
                Some(_) => gen.generate_for(args.opt_f64("duration", 2000.0)?, exp.cluster.seed),
                None => gen.generate(exp.cluster.n_requests, exp.cluster.seed),
            };
            ScenarioTrace::from_requests(trace)
        }
    };
    if verbose {
        println!(
            "simulating {} requests (+{} session follow-ups) on {} decode instances \
             (scenario={} dispatch={} reschedule={} resched={} predictor={})",
            strace.requests.len(),
            strace.sessions.total_follow_ups(),
            exp.cluster.n_decode,
            scenario.as_ref().map_or("legacy", |s| s.name.as_str()),
            exp.dispatch_policy,
            exp.reschedule_policy,
            exp.rescheduler.enabled,
            exp.predictor
        );
    }
    let faults_on = exp.faults.is_some() || strace.faults.is_some();
    let params = SimParams {
        exp,
        validate_state: args.flag("validate-state"),
        ..Default::default()
    };
    let report = Simulator::with_scenario(params, strace, &PolicyRegistry::with_builtins())?.run();
    println!("{}", report.summary(Slo::default()));
    if report.cache.enabled {
        println!("{}", report.cache.summary());
    }
    if faults_on || !report.reliability.is_empty() {
        println!("{}", report.reliability.summary());
    }
    if let Some(spec) = &scenario {
        // per-class TTFT/TPOT percentiles + goodput against each class's
        // own SLO — the violations the aggregate line hides
        let per_class = report.class_summary(&spec.slos());
        if !per_class.is_empty() {
            println!("{per_class}");
        }
        if !report.session_chains.is_empty() {
            let realized: usize = report
                .session_chains
                .iter()
                .map(|c| c.len().saturating_sub(1))
                .sum();
            println!(
                "sessions: {} chains, {} follow-up turns realized",
                report.session_chains.len(),
                realized
            );
        }
    }
    println!(
        "scheduler: {} intervals, {} candidates, max decision {} us",
        report.scheduler_stats.intervals,
        report.scheduler_stats.candidates_evaluated,
        report.scheduler_stats.max_decision_us
    );
    if !report.scorecard.is_empty() {
        println!(
            "predictor calibration (signed error / MAE per progress bucket):\n{}",
            report.scorecard.summary()
        );
    }
    if let Some(path) = args.opt("trace-out") {
        report.recorder.write_tsv(std::path::Path::new(path))?;
        println!("trace written to {path}");
    }
    // soak-gate contract: lost requests (crash-displaced work that could
    // not be re-queued under the admission watermark) fail the run
    if args.flag("fail-on-lost") && report.reliability.lost > 0 {
        return Err(star::Error::Cli(format!(
            "--fail-on-lost: {} request(s) lost to instance failures",
            report.reliability.lost
        )));
    }
    Ok(())
}

/// `star list` — the registered policy, predictor, and scenario names,
/// from the same registries the CLI/config resolve against (so the
/// printed lists are the valid values for `--dispatch`/`--reschedule`/
/// `--scaling`/`--predictor`/`--scenario` by construction).
fn run_list() -> Result<(), star::Error> {
    let reg = PolicyRegistry::with_builtins();
    println!("dispatch policies:   {}", reg.dispatch_names().join(" "));
    println!("reschedule policies: {}", reg.reschedule_names().join(" "));
    println!("scaling policies:    {}", reg.scaling_names().join(" "));
    let predictors = PredictorRegistry::with_builtins();
    println!("predictors:          {}", predictors.names().join(" "));
    let caches = star::kvcache::CachePolicyRegistry::with_builtins();
    println!("cache policies:      {}", caches.names().join(" "));
    let scenarios = ScenarioRegistry::with_builtins();
    println!("scenarios:           {}", scenarios.names().join(" "));
    Ok(())
}

/// `star validate-bench [--require a,b] BENCH_a.json [BENCH_b.json ...]`
/// — the smoke-gate assertion that every emitted bench JSON parses and
/// carries the shared writer's `schema_version`. `--require` names bench
/// outputs that must all be present among the given files (matched as
/// `BENCH_<name>.json` basenames), so a bench that was deleted, renamed,
/// or silently stopped emitting fails the gate instead of shrinking it.
fn run_validate_bench(args: &Args) -> Result<(), star::Error> {
    if args.positionals.is_empty() {
        return Err(star::Error::Cli(
            "validate-bench expects at least one BENCH_*.json path".into(),
        ));
    }
    for path in &args.positionals {
        let text = std::fs::read_to_string(path)
            .map_err(|e| star::Error::Cli(format!("{path}: {e}")))?;
        star::bench::json::validate_bench_json(&text)
            .map_err(|e| star::Error::Cli(format!("{path}: {e}")))?;
        println!("OK {path}");
    }
    if let Some(req) = args.opt("require") {
        let basenames: Vec<String> = args
            .positionals
            .iter()
            .filter_map(|p| std::path::Path::new(p).file_name().and_then(|f| f.to_str()))
            .map(|f| f.to_string())
            .collect();
        let required: Vec<&str> = req
            .split(',')
            .map(str::trim)
            .filter(|n| !n.is_empty())
            .collect();
        let missing: Vec<&str> = required
            .iter()
            .copied()
            .filter(|n| !basenames.iter().any(|b| b == &format!("BENCH_{n}.json")))
            .collect();
        if !missing.is_empty() {
            return Err(star::Error::Cli(format!(
                "validate-bench --require: missing expected bench output(s): {} \
                 (a bench was deleted, renamed, or did not emit its JSON)",
                missing.join(", ")
            )));
        }
        println!(
            "validate-bench: all {} required bench(es) present",
            required.len()
        );
    }
    println!("validate-bench: {} file(s) OK", args.positionals.len());
    Ok(())
}

/// `star analyze [--rules R1,R4] [root]` — the determinism/safety lint
/// pass (DESIGN.md §14). Scans `rust/src` by default (any source root can
/// be passed as a positional — the fixture-corpus tests do), prints one
/// machine-readable line per finding (`path:line: Rn rule-name: message |
/// snippet`), and fails with the finding count when any exist.
fn run_analyze(args: &Args) -> Result<(), star::Error> {
    if args.flag("list-rules") {
        for r in star::analyze::RULES {
            println!("{} {}: {}", r.id, r.name, r.summary);
        }
        return Ok(());
    }
    let rules = star::analyze::resolve_rules(args.opt("rules"))?;
    let root = match args.positionals.first() {
        Some(p) => std::path::PathBuf::from(p),
        None => ["rust/src", "src"]
            .iter()
            .map(std::path::PathBuf::from)
            .find(|p| p.is_dir())
            .ok_or_else(|| {
                star::Error::Cli(
                    "cannot find rust/src from the current directory; \
                     pass the source root as a positional"
                        .into(),
                )
            })?,
    };
    let findings = star::analyze::analyze_tree(&root, &rules)?;
    for f in &findings {
        println!("{}", f.render());
    }
    println!(
        "analyze: {} finding(s) ({} rule(s) over {})",
        findings.len(),
        rules.len(),
        root.display()
    );
    if findings.is_empty() {
        Ok(())
    } else {
        Err(star::Error::Cli(format!(
            "analyze found {} violation(s)",
            findings.len()
        )))
    }
}

/// `star trace <summarize|slo-violations|export> [--format chrome|jsonl]`
/// — the observability surface (DESIGN.md §16). Runs the simulator with
/// `[obs] enabled = true` forced on, then inspects the resulting
/// `SimReport.obs`:
///
///   summarize       flight-recorder occupancy, metric counters and
///                   latency histograms, per-policy decision attribution
///   slo-violations  for every completed request that missed the SLO and
///                   was span-sampled: its full span timeline plus every
///                   scheduler decision that touched it
///   export          Chrome-trace JSON (load in Perfetto / chrome://tracing)
///                   or JSONL to stdout; status lines go to stderr so the
///                   payload stays byte-clean
///
/// Action and format are validated *before* the run so a typo fails fast.
fn run_trace(args: &Args) -> Result<(), star::Error> {
    let action = args
        .positionals
        .first()
        .map(String::as_str)
        .unwrap_or("summarize");
    if !matches!(action, "summarize" | "slo-violations" | "export") {
        return Err(star::Error::Cli(format!(
            "unknown trace action `{action}` (known: summarize|slo-violations|export)"
        )));
    }
    let format = args.opt_or("format", "chrome");
    if !matches!(format, "chrome" | "jsonl") {
        return Err(star::Error::Cli(format!(
            "unknown trace export format `{format}` (known: chrome|jsonl)"
        )));
    }
    let mut exp = experiment_of(args)?;
    // `star trace` IS the observability surface: force the [obs] table on
    // (sampling knobs still honor the config / --set overrides)
    exp.obs.enabled = true;
    let scenario = resolve_scenario(&exp)?;
    let strace = match &scenario {
        Some(spec) => match args.opt("duration") {
            Some(_) => spec.generate_for(args.opt_f64("duration", 2000.0)?, exp.cluster.seed),
            None => spec.generate(exp.cluster.n_requests, exp.cluster.seed),
        },
        None => {
            let gen = TraceGen::new(exp.cluster.dataset, exp.cluster.rps);
            let trace = match args.opt("duration") {
                Some(_) => gen.generate_for(args.opt_f64("duration", 2000.0)?, exp.cluster.seed),
                None => gen.generate(exp.cluster.n_requests, exp.cluster.seed),
            };
            ScenarioTrace::from_requests(trace)
        }
    };
    let params = SimParams {
        exp,
        validate_state: args.flag("validate-state"),
        ..Default::default()
    };
    let report = Simulator::with_scenario(params, strace, &PolicyRegistry::with_builtins())?.run();
    match action {
        "summarize" => {
            // ObsReport::summary() already renders spans / counters /
            // histograms / per-policy decision aggregates
            println!("{}", report.obs.summary());
        }
        "slo-violations" => {
            let slo = Slo::default();
            let violating: Vec<_> = report
                .completed
                .iter()
                .filter(|r| !r.meets_slo(slo))
                .collect();
            println!(
                "slo-violations: {} of {} completed request(s) miss the SLO \
                 (TTFT {:.2} s / TPOT {:.3} s)",
                violating.len(),
                report.completed.len(),
                slo.ttft_s,
                slo.tpot_s,
            );
            let mut shown = 0usize;
            for r in &violating {
                // only span-sampled requests carry a timeline; the header
                // count above still reflects every violation
                let Some(span) = report.obs.spans.span_of(r.id) else {
                    continue;
                };
                shown += 1;
                println!(
                    "\nrequest {}  ttft={}  mean_tpot={}  migrations={}  oom={}",
                    r.id,
                    r.ttft().map_or("-".to_string(), |t| format!("{t:.3}s")),
                    r.mean_tpot.map_or("-".to_string(), |t| format!("{t:.4}s")),
                    r.migrations,
                    r.hit_oom,
                );
                println!("  spans: {}", span.timeline());
                for d in report.obs.decisions.for_request(r.id) {
                    println!(
                        "  decision t={:.3} {:<10} policy={} candidates={} actions={} \
                         chosen={} cost_us={}",
                        d.t,
                        d.kind.name(),
                        d.policy,
                        d.candidates,
                        d.actions,
                        d.chosen.map_or("-".to_string(), |i| i.to_string()),
                        d.cost_us,
                    );
                }
            }
            println!(
                "\n{} of {} violating request(s) were span-sampled \
                 (raise [obs] sample_rate / ring_capacity to see more)",
                shown,
                violating.len(),
            );
        }
        _ => {
            let text = match format {
                "chrome" => {
                    let t = star::obs::chrome_trace(&report.obs);
                    // self-check: the export must be valid JSON before we
                    // hand it to Perfetto / chrome://tracing
                    star::bench::json::parse(&t).map_err(|e| {
                        star::Error::Cli(format!("chrome export failed self-validation: {e}"))
                    })?;
                    t
                }
                _ => star::obs::jsonl(&report.obs),
            };
            print!("{text}");
            eprintln!("trace export: {} byte(s) of {format} written to stdout", text.len());
        }
    }
    Ok(())
}

fn run_serve(args: &Args) -> Result<(), star::Error> {
    let mut exp = experiment_of(args)?;
    // live defaults sized for star-pico instead of the paper cluster
    if args.opt("kv-capacity").is_none() {
        exp.cluster.kv_capacity_tokens = 1600;
    }
    if args.opt("requests").is_none() {
        exp.cluster.n_requests = 24;
    }
    if args.opt("rps").is_none() {
        exp.cluster.rps = 1.0;
    }
    exp.cluster.max_batch = exp.cluster.max_batch.min(8);
    let dir = artifacts_dir(args.opt("artifacts"))?;
    let rt = Arc::new(StarRuntime::load(&dir)?);
    // scenario runs replay the same schedule as the simulator: identical
    // initial trace (pico-scaled) plus the session plan, whose follow-up
    // turns the server realizes on each turn's live completion
    let scenario = resolve_scenario(&exp)?;
    let (live, sessions) = match scenario {
        Some(spec) => {
            let spec = spec.pico(rt.meta.max_prompt as u32 - 8, rt.meta.max_output as u32);
            let strace = spec.generate(exp.cluster.n_requests, exp.cluster.seed);
            let live: Vec<LiveRequest> = strace
                .requests
                .iter()
                .map(|r| LiveRequest::from_trace(r, rt.meta.max_prompt))
                .collect();
            (live, strace.sessions)
        }
        None => {
            let gen = TraceGen::new(exp.cluster.dataset, exp.cluster.rps)
                .pico(rt.meta.max_prompt as u32 - 8, rt.meta.max_output as u32);
            let trace = gen.generate(exp.cluster.n_requests, exp.cluster.seed);
            let live: Vec<LiveRequest> = trace
                .iter()
                .map(|r| LiveRequest::from_trace(r, rt.meta.max_prompt))
                .collect();
            (live, star::workload::SessionPlan::default())
        }
    };
    let params = ServeParams {
        exp,
        sessions,
        ..Default::default()
    };
    let server = Server::new(rt, params);
    let out = server.run(live)?;
    let slo = Slo {
        ttft_s: 2.0,
        tpot_s: 0.060,
    };
    println!(
        "completed {} | wall {:.1}s | throughput {:.3} req/s | goodput {:.3} req/s | \
         P99 TPOT {:.2} ms | OOMs {} | migrations {}",
        out.metrics.completed.len(),
        out.wall_s,
        out.metrics.throughput(),
        out.metrics.goodput(slo),
        out.metrics.p99_tpot_ms(),
        out.oom_events,
        out.migrations
    );
    if out.cache.enabled {
        println!("{}", out.cache.summary());
    }
    if let Some(path) = args.opt("trace-out") {
        out.recorder.write_tsv(std::path::Path::new(path))?;
        println!("trace written to {path}");
    }
    Ok(())
}
