//! Decode cost model (paper §5.2, Fig. 8): iteration time and memory are
//! both linear in the number of batched tokens, which is what lets the
//! scheduler unify "workload" as a token count.
//!
//! The simulator consumes a [`DecodeCostModel`]; the live stack *measures*
//! one via [`fit_linear`] on (batched_tokens, seconds) pairs collected by
//! the `fig8_costmodel` bench, and the paper-scale profile anchors to the
//! published 18.23 ms @ 50% KV occupancy on an RTX 4090D.

/// Linear decode-iteration time model: `t(x) = base + per_token * x`
/// where x = total tokens across the running batch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DecodeCostModel {
    /// Fixed per-iteration cost (kernel launch, dequeue, sampling), seconds.
    pub base_s: f64,
    /// Marginal cost per batched token (KV read bandwidth), seconds.
    pub per_token_s: f64,
    /// Per-request fixed overhead within a batch (projections), seconds.
    pub per_seq_s: f64,
}

impl DecodeCostModel {
    /// Iteration latency for a batch with `tokens` total tokens across
    /// `seqs` sequences.
    #[inline]
    pub fn iter_time(&self, tokens: u64, seqs: usize) -> f64 {
        self.base_s + self.per_token_s * tokens as f64 + self.per_seq_s * seqs as f64
    }

    /// Paper-scale profile: DeepSeek-R1-Distill-Qwen-7B on RTX 4090D.
    /// Anchor (paper §5.3): 18.23 ms per iteration at 50% KV occupancy.
    /// With the small-cluster config (~48K tokens of KV at 50%), that
    /// yields ~0.35 us/token; base covers launch+sampling overhead.
    pub fn paper_4090d() -> Self {
        let occupancy_tokens = 48_000.0 * 0.5;
        let base_s = 2.0e-3;
        let per_token_s = (18.23e-3 - base_s) / occupancy_tokens;
        DecodeCostModel {
            base_s,
            per_token_s,
            per_seq_s: 2.0e-5,
        }
    }

    /// Large-cluster profile (H800): ~3x the 4090D token bandwidth.
    pub fn paper_h800() -> Self {
        let m = Self::paper_4090d();
        DecodeCostModel {
            base_s: 1.5e-3,
            per_token_s: m.per_token_s / 3.0,
            per_seq_s: 1.0e-5,
        }
    }
}

/// Prefill cost model: one compute-bound pass, superlinear in prompt
/// length (attention is O(p^2) but FFN O(p) dominates at short p).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrefillCostModel {
    pub base_s: f64,
    pub per_token_s: f64,
    pub per_token_sq_s: f64,
}

impl PrefillCostModel {
    #[inline]
    pub fn time(&self, prompt_tokens: u64) -> f64 {
        let p = prompt_tokens as f64;
        self.base_s + self.per_token_s * p + self.per_token_sq_s * p * p
    }

    /// Anchored to DistServe-style numbers: ~1s TTFT budget for 4K prompts.
    pub fn paper_4090d() -> Self {
        PrefillCostModel {
            base_s: 5.0e-3,
            per_token_s: 1.2e-4,
            per_token_sq_s: 6.0e-9,
        }
    }
}

/// KV memory model: bytes per cached token (fixed for a model config).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KvMemoryModel {
    pub bytes_per_token: u64,
    pub capacity_bytes: u64,
}

impl KvMemoryModel {
    pub fn capacity_tokens(&self) -> u64 {
        self.capacity_bytes / self.bytes_per_token
    }

    /// Paper small cluster: 4090D 24 GB, 7B model W8A8; the paper reports
    /// 32K-token requests fitting with batch; KV ~ 0.18 MB/token for
    /// 7B-class models => ~2 KB/token/layer... we use the derived value
    /// that yields ~96K tokens of KV per instance.
    pub fn paper_4090d() -> Self {
        KvMemoryModel {
            bytes_per_token: 128 * 1024, // fp8 KV, 28 layers, d~3.5K
            capacity_bytes: 12u64 << 30, // KV share of 24 GB
        }
    }
}

/// Migration cost model (paper §5.4): asynchronous KV transfer over the
/// inter-instance fabric, overlapped with decode.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MigrationCostModel {
    /// Link bandwidth in bytes/second (paper Fig. 13: 25 Gbps).
    pub bandwidth_bps: f64,
    /// Fixed handoff latency (connection + pause/resume), seconds.
    pub latency_s: f64,
    pub bytes_per_token: u64,
}

impl MigrationCostModel {
    pub fn new_25gbps(bytes_per_token: u64) -> Self {
        MigrationCostModel {
            bandwidth_bps: 25.0e9 / 8.0,
            latency_s: 5.0e-3,
            bytes_per_token,
        }
    }

    /// Wall time to transfer `tokens` of KV cache.
    #[inline]
    pub fn transfer_time(&self, tokens: u64) -> f64 {
        self.latency_s + (tokens * self.bytes_per_token) as f64 / self.bandwidth_bps
    }

    /// Migration overhead expressed in decode iterations (Alg. 1 line 20:
    /// a candidate must have `N̂(r) > C_mig / T̄_exec` remaining tokens for
    /// the move to amortize).
    #[inline]
    pub fn overhead_iterations(&self, tokens: u64, avg_iter_s: f64) -> f64 {
        if avg_iter_s <= 0.0 {
            return f64::INFINITY;
        }
        self.transfer_time(tokens) / avg_iter_s
    }
}

/// Ordinary least squares fit of y = a + b x; returns (a, b, r2).
/// Used to calibrate [`DecodeCostModel`] from measured iteration times.
pub fn fit_linear(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
    let b = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    let a = my - b * mx;
    let ss_tot: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| (y - (a + b * x)).powi(2))
        .sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iter_time_linear_in_tokens() {
        let m = DecodeCostModel {
            base_s: 1e-3,
            per_token_s: 1e-6,
            per_seq_s: 0.0,
        };
        let t1 = m.iter_time(1000, 4);
        let t2 = m.iter_time(2000, 4);
        let t3 = m.iter_time(3000, 4);
        assert!(((t2 - t1) - (t3 - t2)).abs() < 1e-15);
    }

    #[test]
    fn paper_anchor_matches() {
        let m = DecodeCostModel::paper_4090d();
        let t = m.iter_time(24_000, 0);
        assert!((t - 18.23e-3).abs() < 1e-4, "t {t}");
    }

    #[test]
    fn fit_recovers_known_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64 * 100.0).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 0.002 + 3e-6 * x).collect();
        let (a, b, r2) = fit_linear(&xs, &ys);
        assert!((a - 0.002).abs() < 1e-9);
        assert!((b - 3e-6).abs() < 1e-12);
        assert!(r2 > 0.999999);
    }

    #[test]
    fn migration_time_scales_with_tokens() {
        let m = MigrationCostModel::new_25gbps(128 * 1024);
        let t_short = m.transfer_time(1_000);
        let t_long = m.transfer_time(30_000);
        assert!(t_long > t_short * 10.0);
        // 30K tokens * 128KB = 3.84 GB over 25 Gbps ~ 1.23 s + latency
        assert!((t_long - (5e-3 + 3.932e9 / 3.125e9)).abs() < 0.01, "{t_long}");
    }

    #[test]
    fn overhead_iterations_guard() {
        let m = MigrationCostModel::new_25gbps(1024);
        assert!(m.overhead_iterations(100, 0.0).is_infinite());
        let it = m.overhead_iterations(10_000, 0.018);
        assert!(it > 0.0 && it.is_finite());
    }
}
