//! `star analyze` — dependency-free static analysis over the scheduling
//! core (DESIGN.md §14).
//!
//! Every benchmark claim in this reproduction rests on bit-for-bit
//! deterministic replay; the rules here are the invariants that keep it
//! that way, enforced mechanically instead of by review:
//!
//! * **R1** `no-hash-collections` — no `HashMap`/`HashSet` in the
//!   determinism-critical dirs (`sim/`, `coordinator/`, `serve/`,
//!   `kvcache/`, `obs/`): iteration order is per-instance random and can
//!   fabricate goodput deltas the size of the ones being measured. Use
//!   `BTreeMap`.
//! * **R2** `no-wall-clock` — no `Instant::now`/`SystemTime`/`thread_rng`
//!   in the simulated core (`sim/`, `coordinator/`, `kvcache/`,
//!   `workload/`, `obs/`): time and randomness must flow through the event
//!   clock and [`crate::prng`]. The live `serve/` layer is real time and
//!   exempt.
//! * **R3** `unsafe-allowlist` — `unsafe` only in allowlisted files, and
//!   every occurrence preceded by a `// SAFETY:` comment.
//! * **R4** `no-bare-unwrap` — no `.unwrap()` in `sim/` + `serve/`
//!   non-test code; `.expect("invariant")` names what broke.
//! * **R5** `event-coverage` — every [`crate::sim::Event`] variant must be
//!   matched in `sim/engine.rs` AND listed in its `VALIDATED_EVENTS`
//!   coverage const, so a new event cannot dodge the invariant checker.
//! * **R6** `trace-event-coverage` — every
//!   [`crate::metrics::TraceEvent`] variant must be handled by the span
//!   assembler in `obs/spans.rs`, so a newly recorded trace event cannot
//!   silently vanish from `star trace` timelines.
//! * **R7** `no-shared-mutable-static` — no `static mut`, no
//!   `lazy_static!`/`thread_local!`, and no statics typed
//!   `OnceLock`/`Mutex`/`RefCell`/`Atomic*`-style in `sim/` +
//!   `coordinator/`: the sharded simulation core must keep all mutable
//!   state inside the per-run `Simulator`, or a shard could observe
//!   another run's (or another shard's) writes and break deterministic
//!   replay.
//!
//! Findings are one line each (`path:line: Rn rule-name: message | snippet`),
//! and the CLI exits nonzero when any exist. Intentional exceptions carry a
//! `// ANALYZE-OK: Rn reason` waiver on the finding line or the line above.

pub mod lexer;
pub mod rules;

use std::path::{Path, PathBuf};

use lexer::{lex, Tok, TokKind};

use crate::{Error, Result};

/// One rule violation.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Path as displayed (scan root + relative path).
    pub file: String,
    /// 1-indexed line.
    pub line: u32,
    /// Rule id, e.g. `"R1"`.
    pub rule: &'static str,
    /// Rule slug, e.g. `"no-hash-collections"`.
    pub rule_name: &'static str,
    pub message: String,
    /// The trimmed source line.
    pub snippet: String,
}

impl Finding {
    /// The machine-readable one-line form the CLI prints.
    pub fn render(&self) -> String {
        format!(
            "{}:{}: {} {}: {} | {}",
            self.file, self.line, self.rule, self.rule_name, self.message, self.snippet
        )
    }
}

/// Catalog entry for one rule.
#[derive(Clone, Copy, Debug)]
pub struct RuleInfo {
    pub id: &'static str,
    pub name: &'static str,
    pub summary: &'static str,
}

/// The rule catalog, in report order. `star analyze --list-rules` prints
/// this; `--rules` names validate against it.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "R1",
        name: "no-hash-collections",
        summary: "no HashMap/HashSet in sim/, coordinator/, serve/, kvcache/, obs/ \
                  (iteration-order nondeterminism); use BTreeMap or waive",
    },
    RuleInfo {
        id: "R2",
        name: "no-wall-clock",
        summary: "no Instant::now/SystemTime/thread_rng in sim/, coordinator/, \
                  kvcache/, workload/, obs/ (time flows through the event clock and prng)",
    },
    RuleInfo {
        id: "R3",
        name: "unsafe-allowlist",
        summary: "`unsafe` only in allowlisted files, each occurrence preceded \
                  by a // SAFETY: comment",
    },
    RuleInfo {
        id: "R4",
        name: "no-bare-unwrap",
        summary: "no bare .unwrap() in sim/ + serve/ non-test code; use \
                  .expect(\"invariant\") or waive",
    },
    RuleInfo {
        id: "R5",
        name: "event-coverage",
        summary: "every sim Event variant is matched in sim/engine.rs and named \
                  in its VALIDATED_EVENTS coverage list",
    },
    RuleInfo {
        id: "R6",
        name: "trace-event-coverage",
        summary: "every TraceEvent variant recorded by metrics/recorder.rs is \
                  handled by the obs/spans.rs span assembler",
    },
    RuleInfo {
        id: "R7",
        name: "no-shared-mutable-static",
        summary: "no `static mut`, lazy_static!/thread_local!, or statics typed \
                  OnceLock/Mutex/RefCell/Atomic* in sim/ + coordinator/ (all \
                  mutable state lives in the per-run Simulator; shared globals \
                  would leak across shards and runs)",
    },
];

/// Resolve a `--rules R1,R4` spec against the catalog. `None` means all.
/// Unknown ids fail with the candidate list (the repo-wide CLI idiom).
pub fn resolve_rules(spec: Option<&str>) -> Result<Vec<&'static str>> {
    let Some(spec) = spec else {
        return Ok(RULES.iter().map(|r| r.id).collect());
    };
    let mut out = Vec::new();
    for name in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let hit = RULES
            .iter()
            .find(|r| r.id.eq_ignore_ascii_case(name) || r.name == name);
        match hit {
            Some(r) => {
                if !out.contains(&r.id) {
                    out.push(r.id);
                }
            }
            None => {
                let known: Vec<&str> = RULES.iter().map(|r| r.id).collect();
                return Err(Error::Cli(format!(
                    "unknown analyze rule `{name}` (known: {})",
                    known.join("|")
                )));
            }
        }
    }
    if out.is_empty() {
        return Err(Error::Cli("--rules selected no rules".into()));
    }
    Ok(out)
}

/// A lexed source file plus the line-level facts rules consume.
pub struct SourceFile {
    /// Path relative to the scan root, `/`-separated (rules match on this).
    pub rel: String,
    /// Path as displayed in findings.
    pub display: String,
    pub toks: Vec<Tok>,
    /// Parallel to `toks`: inside a `#[cfg(test)]` / `#[test]` region?
    pub in_test: Vec<bool>,
    /// Lines carrying a `// SAFETY:` comment.
    safety_lines: Vec<u32>,
    /// `// ANALYZE-OK:` waivers: (line, rule id or None for all rules).
    waivers: Vec<(u32, Option<String>)>,
    /// Raw source lines, for snippets.
    lines: Vec<String>,
}

impl SourceFile {
    pub fn parse(rel: &str, display: &str, src: &str) -> SourceFile {
        let toks = lex(src);
        let in_test = mark_test_regions(&toks);
        let mut safety_lines = Vec::new();
        let mut waivers = Vec::new();
        for t in &toks {
            if t.kind != TokKind::LineComment {
                continue;
            }
            let body = t.text.trim_start();
            if body.starts_with("SAFETY:") {
                safety_lines.push(t.line);
            }
            if let Some(rest) = body.strip_prefix("ANALYZE-OK:") {
                // `// ANALYZE-OK: R2 reason…` waives one rule; a bare
                // `// ANALYZE-OK: reason…` waives every rule on the line
                let first = rest.trim_start().split_whitespace().next().unwrap_or("");
                let rule = RULES
                    .iter()
                    .find(|r| r.id.eq_ignore_ascii_case(first))
                    .map(|r| r.id.to_string());
                waivers.push((t.line, rule));
            }
        }
        SourceFile {
            rel: rel.to_string(),
            display: display.to_string(),
            toks,
            in_test,
            safety_lines,
            waivers,
            lines: src.lines().map(str::to_string).collect(),
        }
    }

    /// Is a finding of `rule` at `line` waived? A waiver covers its own
    /// line (trailing comment) and the line below (comment above the code).
    pub fn waived(&self, rule: &str, line: u32) -> bool {
        self.waivers.iter().any(|(wl, wr)| {
            let line_hit = *wl == line || wl + 1 == line;
            let rule_hit = match wr.as_deref() {
                None => true,
                Some(r) => r == rule,
            };
            line_hit && rule_hit
        })
    }

    /// Is there a `// SAFETY:` comment on `line` or within the 4 lines
    /// above it (multi-line justifications span several comment lines)?
    pub fn safety_commented(&self, line: u32) -> bool {
        self.safety_lines
            .iter()
            .any(|&sl| sl <= line && sl + 4 >= line)
    }

    pub fn snippet(&self, line: u32) -> String {
        self.lines
            .get(line as usize - 1)
            .map(|l| l.trim().to_string())
            .unwrap_or_default()
    }

    fn finding(&self, rule: &RuleInfo, line: u32, message: String) -> Finding {
        Finding {
            file: self.display.clone(),
            line,
            rule: rule.id,
            rule_name: rule.name,
            message,
            snippet: self.snippet(line),
        }
    }

    /// Emit a finding unless the line is waived.
    pub(crate) fn push_finding(
        &self,
        out: &mut Vec<Finding>,
        rule: &RuleInfo,
        line: u32,
        message: String,
    ) {
        if !self.waived(rule.id, line) {
            out.push(self.finding(rule, line, message));
        }
    }
}

/// Mark the token spans of test-only code: an item annotated `#[cfg(test)]`
/// (or any `cfg(...)` mentioning `test`, e.g. `all(test, …)`) or `#[test]`,
/// through its matching closing brace. Rules R1/R4 scope to non-test code.
fn mark_test_regions(toks: &[Tok]) -> Vec<bool> {
    let mut in_test = vec![false; toks.len()];
    let mut i = 0;
    while i < toks.len() {
        if !(toks[i].is_punct('#') && toks.get(i + 1).is_some_and(|t| t.is_punct('['))) {
            i += 1;
            continue;
        }
        // collect the attribute `#[ … ]`
        let attr_start = i;
        let mut j = i + 2;
        let mut depth = 1usize;
        let mut idents: Vec<&str> = Vec::new();
        while j < toks.len() && depth > 0 {
            if toks[j].is_punct('[') {
                depth += 1;
            } else if toks[j].is_punct(']') {
                depth -= 1;
            } else if toks[j].kind == TokKind::Ident {
                idents.push(&toks[j].text);
            }
            j += 1;
        }
        let is_test_attr = idents.first() == Some(&"test")
            || (idents.first() == Some(&"cfg") && idents.iter().any(|s| *s == "test"));
        if !is_test_attr {
            i = j;
            continue;
        }
        // skip any further attributes between this one and the item
        let mut k = j;
        while k + 1 < toks.len() && toks[k].is_punct('#') && toks[k + 1].is_punct('[') {
            let mut d = 1usize;
            k += 2;
            while k < toks.len() && d > 0 {
                if toks[k].is_punct('[') {
                    d += 1;
                } else if toks[k].is_punct(']') {
                    d -= 1;
                }
                k += 1;
            }
        }
        // the item: everything to the matching `}` of its first brace, or
        // to a `;` for brace-less items (`#[cfg(test)] use …;`)
        while k < toks.len() && !toks[k].is_punct('{') && !toks[k].is_punct(';') {
            k += 1;
        }
        if k < toks.len() && toks[k].is_punct('{') {
            let mut d = 1usize;
            k += 1;
            while k < toks.len() && d > 0 {
                if toks[k].is_punct('{') {
                    d += 1;
                } else if toks[k].is_punct('}') {
                    d -= 1;
                }
                k += 1;
            }
        } else if k < toks.len() {
            k += 1; // consume the `;`
        }
        for flag in in_test.iter_mut().take(k.min(toks.len())).skip(attr_start) {
            *flag = true;
        }
        i = k;
    }
    in_test
}

/// Collect every `.rs` file under `root`, sorted by relative path so the
/// report (and the exit code) is deterministic across filesystems.
pub fn collect_sources(root: &Path) -> Result<Vec<SourceFile>> {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
        let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .collect();
        entries.sort();
        for p in entries {
            if p.is_dir() {
                walk(&p, out)?;
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
        Ok(())
    }
    if !root.is_dir() {
        return Err(Error::Cli(format!(
            "analyze root `{}` is not a directory",
            root.display()
        )));
    }
    let mut paths = Vec::new();
    walk(root, &mut paths).map_err(Error::Io)?;
    let mut files = Vec::new();
    for p in paths {
        let rel = p
            .strip_prefix(root)
            .unwrap_or(&p)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = std::fs::read_to_string(&p).map_err(Error::Io)?;
        files.push(SourceFile::parse(&rel, &p.display().to_string(), &src));
    }
    Ok(files)
}

/// Run `rule_ids` over a source tree. Findings are sorted by
/// (file, line, rule) — stable output for CI diffing.
pub fn analyze_tree(root: &Path, rule_ids: &[&str]) -> Result<Vec<Finding>> {
    let files = collect_sources(root)?;
    let mut findings = Vec::new();
    for id in rule_ids {
        match *id {
            "R1" => rules::check_hash_collections(&files, &mut findings),
            "R2" => rules::check_wall_clock(&files, &mut findings),
            "R3" => rules::check_unsafe(&files, &mut findings),
            "R4" => rules::check_bare_unwrap(&files, &mut findings),
            "R5" => rules::check_event_coverage(&files, &mut findings),
            "R6" => rules::check_trace_event_coverage(&files, &mut findings),
            "R7" => rules::check_shared_mutable_static(&files, &mut findings),
            other => {
                let known: Vec<&str> = RULES.iter().map(|r| r.id).collect();
                return Err(Error::Cli(format!(
                    "unknown analyze rule `{other}` (known: {})",
                    known.join("|")
                )));
            }
        }
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(findings)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn file(src: &str) -> SourceFile {
        SourceFile::parse("sim/x.rs", "sim/x.rs", src)
    }

    #[test]
    fn test_regions_cover_cfg_test_mods() {
        let f = file(
            "fn live() {}\n\
             #[cfg(test)]\n\
             mod tests {\n\
                 fn helper() {}\n\
             }\n\
             fn also_live() {}\n",
        );
        let by_name = |name: &str| {
            f.toks
                .iter()
                .zip(&f.in_test)
                .find(|(t, _)| t.is_ident(name))
                .map(|(_, in_t)| *in_t)
                .unwrap()
        };
        assert!(!by_name("live"));
        assert!(by_name("helper"));
        assert!(!by_name("also_live"));
    }

    #[test]
    fn test_regions_cover_test_fns_and_braceless_items() {
        let f = file(
            "#[test]\n\
             #[ignore]\n\
             fn t() { let x = 1; }\n\
             #[cfg(test)]\n\
             use std::collections::HashMap;\n\
             fn live() {}\n",
        );
        let flags: Vec<(String, bool)> = f
            .toks
            .iter()
            .zip(&f.in_test)
            .filter(|(t, _)| t.kind == TokKind::Ident)
            .map(|(t, in_t)| (t.text.clone(), *in_t))
            .collect();
        assert!(flags.contains(&("x".to_string(), true)));
        assert!(flags.contains(&("HashMap".to_string(), true)));
        assert!(flags.contains(&("live".to_string(), false)));
    }

    #[test]
    fn waivers_cover_own_and_next_line() {
        let f = file(
            "// ANALYZE-OK: R1 justified\n\
             let m = HashMap::new();\n\
             let n = HashMap::new();\n",
        );
        assert!(f.waived("R1", 1));
        assert!(f.waived("R1", 2));
        assert!(!f.waived("R1", 3));
        assert!(!f.waived("R4", 2), "rule-scoped waiver is rule-specific");
    }

    #[test]
    fn bare_waiver_covers_all_rules() {
        let f = file("let m = x.unwrap(); // ANALYZE-OK: proven above\n");
        assert!(f.waived("R1", 1));
        assert!(f.waived("R4", 1));
    }

    #[test]
    fn safety_comment_window() {
        let f = file(
            "// SAFETY: the pointer is valid for the\n\
             // lifetime of the arena it came from\n\
             unsafe { work() }\n\n\n\n\n\
             unsafe { other() }\n",
        );
        assert!(f.safety_commented(3));
        assert!(!f.safety_commented(8));
    }

    #[test]
    fn rule_resolution_accepts_ids_and_slugs_rejects_unknown() {
        assert_eq!(resolve_rules(None).unwrap().len(), RULES.len());
        assert_eq!(resolve_rules(Some("R1,R4")).unwrap(), vec!["R1", "R4"]);
        assert_eq!(resolve_rules(Some("no-bare-unwrap")).unwrap(), vec!["R4"]);
        let err = resolve_rules(Some("R9")).unwrap_err().to_string();
        assert!(err.contains("unknown analyze rule `R9`"), "{err}");
        assert!(err.contains("R1|R2|R3|R4|R5"), "{err}");
    }

    #[test]
    fn finding_render_is_one_machine_readable_line() {
        let f = file("let m = HashMap::new();\n");
        let r = &RULES[0];
        let mut out = Vec::new();
        f.push_finding(&mut out, r, 1, "HashMap in determinism-critical code".into());
        let line = out[0].render();
        assert!(line.starts_with("sim/x.rs:1: R1 no-hash-collections:"), "{line}");
        assert!(line.ends_with("| let m = HashMap::new();"), "{line}");
    }
}
