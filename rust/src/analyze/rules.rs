//! The rule implementations behind `star analyze` (catalog in
//! [`super::RULES`], rationale in DESIGN.md §14). Each rule is a pure
//! function over lexed [`SourceFile`]s: R1–R4 scan token streams
//! file-by-file; R5 is a cross-file rule relating `sim/events.rs` to
//! `sim/engine.rs`, and R6 relates `metrics/recorder.rs` to
//! `obs/spans.rs` with the same variant-extraction technique.

use super::{Finding, RuleInfo, SourceFile, RULES};
use crate::analyze::lexer::TokKind;

fn rule(id: &str) -> &'static RuleInfo {
    RULES
        .iter()
        .find(|r| r.id == id)
        .expect("rule id in catalog")
}

fn in_dirs(file: &SourceFile, dirs: &[&str]) -> bool {
    dirs.iter().any(|d| file.rel.starts_with(d))
}

/// R1: `HashMap`/`HashSet` named anywhere in non-test code of the
/// determinism-critical dirs. A token-level pass cannot prove *iteration*,
/// so the rule bans the types outright — `BTreeMap` costs O(log n) on maps
/// that hold at most a few thousand requests, and a justified non-iterated
/// use can carry an `// ANALYZE-OK: R1` waiver.
pub fn check_hash_collections(files: &[SourceFile], out: &mut Vec<Finding>) {
    let r = rule("R1");
    for f in files {
        if !in_dirs(f, &["sim/", "coordinator/", "serve/", "kvcache/", "obs/"]) {
            continue;
        }
        for (t, &in_test) in f.toks.iter().zip(&f.in_test) {
            if in_test || t.kind != TokKind::Ident {
                continue;
            }
            if t.text == "HashMap" || t.text == "HashSet" {
                f.push_finding(
                    out,
                    r,
                    t.line,
                    format!(
                        "`{}` in determinism-critical code (iteration order is \
                         per-instance random; use BTreeMap/BTreeSet)",
                        t.text
                    ),
                );
            }
        }
    }
}

/// R2: wall-clock time or OS randomness in the simulated core. Flags
/// `Instant::now` call sites (a bare `use std::time::Instant` that is
/// never `now()`ed is harmless), plus any mention of `SystemTime` or
/// `thread_rng`.
pub fn check_wall_clock(files: &[SourceFile], out: &mut Vec<Finding>) {
    let r = rule("R2");
    for f in files {
        if !in_dirs(f, &["sim/", "coordinator/", "kvcache/", "workload/", "obs/"]) {
            continue;
        }
        let toks = &f.toks;
        for (i, (t, &in_test)) in toks.iter().zip(&f.in_test).enumerate() {
            if in_test || t.kind != TokKind::Ident {
                continue;
            }
            let hit = match t.text.as_str() {
                "SystemTime" | "thread_rng" => Some(t.text.clone()),
                "Instant" => {
                    let now_call = toks.get(i + 1).is_some_and(|a| a.is_punct(':'))
                        && toks.get(i + 2).is_some_and(|a| a.is_punct(':'))
                        && toks.get(i + 3).is_some_and(|a| a.is_ident("now"));
                    now_call.then(|| "Instant::now".to_string())
                }
                _ => None,
            };
            if let Some(what) = hit {
                f.push_finding(
                    out,
                    r,
                    t.line,
                    format!(
                        "`{what}` in the simulated core (sim time/randomness must \
                         flow through the event clock and prng)"
                    ),
                );
            }
        }
    }
}

/// Files allowed to contain `unsafe`. The PR-7 audit found exactly one
/// real site in the tree — the `Send`/`Sync` impls for the PJRT runtime
/// in `runtime/models.rs`. (The issue's original list also named
/// `coordinator/rescheduler.rs` and `coordinator/policy/mem_pressure.rs`,
/// but those only contain "unsafe" inside test *function names* — the
/// identifier-substring false positive this lexer exists to avoid.)
pub const UNSAFE_ALLOWLIST: &[&str] = &["runtime/models.rs"];

/// R3: every `unsafe` keyword must sit in an allowlisted file AND carry a
/// `// SAFETY:` comment on the preceding lines.
pub fn check_unsafe(files: &[SourceFile], out: &mut Vec<Finding>) {
    let r = rule("R3");
    for f in files {
        for (t, &in_test) in f.toks.iter().zip(&f.in_test) {
            if in_test || !t.is_ident("unsafe") {
                continue;
            }
            if !UNSAFE_ALLOWLIST.contains(&f.rel.as_str()) {
                f.push_finding(
                    out,
                    r,
                    t.line,
                    format!(
                        "`unsafe` outside the allowlist ({})",
                        UNSAFE_ALLOWLIST.join(", ")
                    ),
                );
            } else if !f.safety_commented(t.line) {
                f.push_finding(
                    out,
                    r,
                    t.line,
                    "`unsafe` without a // SAFETY: comment on the preceding lines".into(),
                );
            }
        }
    }
}

/// R4: bare `.unwrap()` in `sim/` + `serve/` non-test code. A panic there
/// should name the broken invariant (`.expect("…")`), not a line number.
/// `unwrap_or`/`unwrap_or_else` are different identifiers and never match.
pub fn check_bare_unwrap(files: &[SourceFile], out: &mut Vec<Finding>) {
    let r = rule("R4");
    for f in files {
        if !in_dirs(f, &["sim/", "serve/"]) {
            continue;
        }
        let toks = &f.toks;
        for (i, (t, &in_test)) in toks.iter().zip(&f.in_test).enumerate() {
            if in_test || !t.is_ident("unwrap") {
                continue;
            }
            let bare_call = i > 0
                && toks[i - 1].is_punct('.')
                && toks.get(i + 1).is_some_and(|a| a.is_punct('('))
                && toks.get(i + 2).is_some_and(|a| a.is_punct(')'));
            if bare_call {
                f.push_finding(
                    out,
                    r,
                    t.line,
                    "bare `.unwrap()` (use .expect(\"invariant\") so a panic names \
                     what broke)"
                        .into(),
                );
            }
        }
    }
}

/// R5: cross-file event-coverage rule. Parses the `enum Event` variants
/// out of `sim/events.rs` and requires each to (a) appear as an
/// `Event::<Variant>` match in `sim/engine.rs` and (b) be named in the
/// engine's `VALIDATED_EVENTS` coverage const — the list
/// `assert_state_consistent` checks at runtime — so a newly added event
/// cannot dodge the invariant checker.
pub fn check_event_coverage(files: &[SourceFile], out: &mut Vec<Finding>) {
    let r = rule("R5");
    let Some(events) = files.iter().find(|f| f.rel == "sim/events.rs") else {
        return; // not a tree with a sim layer; nothing to enforce
    };
    let Some(engine) = files.iter().find(|f| f.rel == "sim/engine.rs") else {
        return;
    };
    let variants = enum_variants(events, "Event");
    if variants.is_empty() {
        return;
    }

    // (a) `Event :: Variant` token sequences anywhere in the engine
    let mut matched: Vec<&str> = Vec::new();
    let toks = &engine.toks;
    for i in 0..toks.len() {
        if toks[i].is_ident("Event")
            && toks.get(i + 1).is_some_and(|t| t.is_punct(':'))
            && toks.get(i + 2).is_some_and(|t| t.is_punct(':'))
        {
            if let Some(v) = toks.get(i + 3) {
                if v.kind == TokKind::Ident {
                    matched.push(&v.text);
                }
            }
        }
    }

    // (b) string literals inside the VALIDATED_EVENTS const
    let mut listed: Vec<&str> = Vec::new();
    let mut coverage_line = None;
    if let Some(start) = toks.iter().position(|t| t.is_ident("VALIDATED_EVENTS")) {
        coverage_line = Some(toks[start].line);
        if let Some(open) = toks[start..].iter().position(|t| t.is_punct('[')) {
            let mut depth = 0usize;
            for t in &toks[start + open..] {
                if t.is_punct('[') {
                    depth += 1;
                } else if t.is_punct(']') {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if t.kind == TokKind::Str {
                    listed.push(&t.text);
                }
            }
        }
    }

    for (name, line) in &variants {
        if !matched.iter().any(|m| m == name) {
            events.push_finding(
                out,
                r,
                *line,
                format!("Event::{name} is never matched in sim/engine.rs"),
            );
        }
        if coverage_line.is_none() {
            continue; // reported once below
        }
        if !listed.iter().any(|l| l == name) {
            engine.push_finding(
                out,
                r,
                coverage_line.unwrap_or(1),
                format!("Event::{name} missing from the VALIDATED_EVENTS coverage list"),
            );
        }
    }
    if coverage_line.is_none() {
        engine.push_finding(
            out,
            r,
            1,
            "sim/engine.rs has no VALIDATED_EVENTS coverage list".into(),
        );
    }
}

/// R6: cross-file trace-event-coverage rule. Parses the `TraceEvent`
/// variants out of `metrics/recorder.rs` and requires each to appear as a
/// `TraceEvent::<Variant>` match in the span assembler (`obs/spans.rs`) —
/// the flight recorder is assembled from trace rows, so an event kind the
/// assembler never handles silently vanishes from every `star trace`
/// timeline. Same lexer technique as R5.
pub fn check_trace_event_coverage(files: &[SourceFile], out: &mut Vec<Finding>) {
    let r = rule("R6");
    let Some(recorder) = files.iter().find(|f| f.rel == "metrics/recorder.rs") else {
        return; // not a tree with the trace-recorder layer; nothing to enforce
    };
    let Some(spans) = files.iter().find(|f| f.rel == "obs/spans.rs") else {
        return;
    };
    let variants = enum_variants(recorder, "TraceEvent");
    if variants.is_empty() {
        return;
    }
    // `TraceEvent :: Variant` token sequences anywhere in the assembler
    let toks = &spans.toks;
    let mut handled: Vec<&str> = Vec::new();
    for i in 0..toks.len() {
        if toks[i].is_ident("TraceEvent")
            && toks.get(i + 1).is_some_and(|t| t.is_punct(':'))
            && toks.get(i + 2).is_some_and(|t| t.is_punct(':'))
        {
            if let Some(v) = toks.get(i + 3) {
                if v.kind == TokKind::Ident {
                    handled.push(&v.text);
                }
            }
        }
    }
    for (name, line) in &variants {
        if !handled.iter().any(|h| h == name) {
            recorder.push_finding(
                out,
                r,
                *line,
                format!("TraceEvent::{name} is never handled by the obs/spans.rs span assembler"),
            );
        }
    }
}

/// Interior-mutability wrappers that turn a `static` into a shared
/// mutable global (the `Atomic*` family is matched by prefix).
const SHARED_MUTABLE_TYPES: &[&str] = &[
    "OnceLock", "OnceCell", "LazyLock", "LazyCell", "Mutex", "RwLock", "RefCell", "Cell",
    "UnsafeCell",
];

/// R7: shared mutable globals in the sharded simulation core (`sim/` +
/// `coordinator/`). Three shapes are banned in non-test code: `static
/// mut` items, `lazy_static!`/`thread_local!` globals, and `static`
/// items whose type names an interior-mutability wrapper
/// (`OnceLock`, `Mutex`, `RefCell`, `Atomic*`, …). All mutable state
/// must live inside the per-run `Simulator`/`ClusterState`, or one
/// shard (or one run) could observe another's writes and break
/// deterministic replay. `&'static str` and friends never match — the
/// lexer emits lifetimes as their own token kind, so an `Ident` reading
/// "static" is always the item keyword.
pub fn check_shared_mutable_static(files: &[SourceFile], out: &mut Vec<Finding>) {
    let r = rule("R7");
    for f in files {
        if !in_dirs(f, &["sim/", "coordinator/"]) {
            continue;
        }
        let toks = &f.toks;
        for (i, (t, &in_test)) in toks.iter().zip(&f.in_test).enumerate() {
            if in_test || t.kind != TokKind::Ident {
                continue;
            }
            match t.text.as_str() {
                "lazy_static" | "thread_local" => {
                    f.push_finding(
                        out,
                        r,
                        t.line,
                        format!(
                            "`{}!` global in the sharded core (keep mutable state \
                             inside the per-run Simulator)",
                            t.text
                        ),
                    );
                }
                "static" => {
                    if toks.get(i + 1).is_some_and(|a| a.is_ident("mut")) {
                        f.push_finding(
                            out,
                            r,
                            t.line,
                            "`static mut` in the sharded core (unsynchronized shared \
                             mutable state breaks deterministic replay)"
                                .into(),
                        );
                        continue;
                    }
                    // scan the item's type tokens, stopping at the
                    // initializer (`=`), the terminator (`;`), or a body
                    // brace — anything past those is not the static's type
                    let mut j = i + 1;
                    while let Some(a) = toks.get(j) {
                        if a.is_punct('=') || a.is_punct(';') || a.is_punct('{') {
                            break;
                        }
                        if a.kind == TokKind::Ident
                            && (SHARED_MUTABLE_TYPES.contains(&a.text.as_str())
                                || a.text.starts_with("Atomic"))
                        {
                            f.push_finding(
                                out,
                                r,
                                t.line,
                                format!(
                                    "static of interior-mutable type `{}` (a shared \
                                     mutable global; keep state inside the per-run \
                                     Simulator)",
                                    a.text
                                ),
                            );
                            break;
                        }
                        j += 1;
                    }
                }
                _ => {}
            }
        }
    }
}

/// Extract `(variant, line)` pairs from `enum <name> { … }`. Variants are
/// the identifiers at brace depth 1 that open a field list or end the arm
/// (`Name {…}`, `Name(…)`, `Name,`, `Name }`); identifiers inside variant
/// payloads sit at depth ≥ 2 or behind `(`/`<` and are skipped.
fn enum_variants<'f>(file: &'f SourceFile, name: &str) -> Vec<(&'f str, u32)> {
    let toks = &file.toks;
    let mut i = 0;
    // find `enum <name>` then its opening `{`
    loop {
        match toks[i..].iter().position(|t| t.is_ident("enum")) {
            None => return Vec::new(),
            Some(off) => {
                i += off + 1;
                if toks.get(i).is_some_and(|t| t.is_ident(name)) {
                    break;
                }
            }
        }
    }
    while i < toks.len() && !toks[i].is_punct('{') {
        i += 1;
    }
    let mut variants = Vec::new();
    let mut depth = 0usize; // brace depth relative to the enum body
    let mut paren = 0usize;
    let mut bracket = 0usize; // `#[…]` variant attributes
    let mut expect_variant = true; // at depth 1, after `{` or a top-level `,`
    for t in &toks[i..] {
        if t.is_punct('{') {
            depth += 1;
            if depth == 2 {
                expect_variant = false; // entering a struct-variant body
            }
            continue;
        }
        if t.is_punct('}') {
            if depth == 1 {
                break; // end of the enum
            }
            depth -= 1;
            continue;
        }
        if t.is_punct('(') {
            paren += 1;
            continue;
        }
        if t.is_punct(')') {
            paren = paren.saturating_sub(1);
            continue;
        }
        if t.is_punct('[') {
            bracket += 1;
            continue;
        }
        if t.is_punct(']') {
            bracket = bracket.saturating_sub(1);
            continue;
        }
        if depth != 1 || paren > 0 || bracket > 0 {
            continue;
        }
        if t.is_punct(',') {
            expect_variant = true;
            continue;
        }
        if expect_variant && t.kind == TokKind::Ident {
            variants.push((t.text.as_str(), t.line));
            expect_variant = false;
        }
    }
    variants
}

#[cfg(test)]
mod tests {
    use super::*;

    fn file(rel: &str, src: &str) -> SourceFile {
        SourceFile::parse(rel, rel, src)
    }

    #[test]
    fn enum_variants_handles_all_arm_shapes() {
        let f = file(
            "sim/events.rs",
            "pub enum Event {\n\
                 Plain,\n\
                 Tuple(u64, usize),\n\
                 Struct { field: u64, other: bool },\n\
                 #[allow(dead_code)]\n\
                 Attributed,\n\
                 Last { x: u64 }\n\
             }\n",
        );
        let names: Vec<&str> = enum_variants(&f, "Event").iter().map(|(n, _)| *n).collect();
        assert_eq!(names, vec!["Plain", "Tuple", "Struct", "Attributed", "Last"]);
    }

    #[test]
    fn r1_scopes_to_critical_dirs() {
        let critical = file("sim/a.rs", "use std::collections::HashMap;\n");
        let elsewhere = file("runtime/meta.rs", "use std::collections::HashMap;\n");
        let mut out = Vec::new();
        check_hash_collections(&[critical, elsewhere], &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].file, "sim/a.rs");
        assert_eq!(out[0].rule, "R1");
    }

    #[test]
    fn r2_requires_the_now_call_for_instant() {
        let f = file(
            "coordinator/x.rs",
            "use std::time::Instant;\n\
             fn f(at: Instant) {}\n\
             fn g() { let t = Instant::now(); }\n",
        );
        let mut out = Vec::new();
        check_wall_clock(&[f], &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].line, 3);
    }

    #[test]
    fn r3_distinguishes_allowlist_from_missing_safety() {
        let outside = file("kvcache/x.rs", "fn f() { unsafe { g() } }\n");
        let allowed_no_comment = file("runtime/models.rs", "unsafe impl Send for X {}\n");
        let allowed_ok = file(
            "runtime/models.rs",
            "// SAFETY: single-threaded PJRT handle, externally synchronized\n\
             unsafe impl Send for X {}\n",
        );
        let mut out = Vec::new();
        check_unsafe(&[outside, allowed_no_comment, allowed_ok], &mut out);
        assert_eq!(out.len(), 2, "{out:?}");
        assert!(out[0].message.contains("outside the allowlist"));
        assert!(out[1].message.contains("SAFETY"));
    }

    #[test]
    fn r4_only_bare_unwrap_calls_match() {
        let f = file(
            "serve/x.rs",
            "fn f(x: Option<u32>) -> u32 {\n\
                 let a = x.unwrap_or(0);\n\
                 let b = x.unwrap_or_else(|| 1);\n\
                 let c = x.expect(\"checked above\");\n\
                 x.unwrap()\n\
             }\n",
        );
        let mut out = Vec::new();
        check_bare_unwrap(&[f], &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].line, 5);
    }

    #[test]
    fn r5_flags_unmatched_and_unlisted_variants() {
        let events = file(
            "sim/events.rs",
            "pub enum Event { Tick, Arrive { id: u64 }, Finish(u64) }\n",
        );
        let engine = file(
            "sim/engine.rs",
            "pub const VALIDATED_EVENTS: &[&str] = &[\"Tick\", \"Arrive\"];\n\
             fn run(ev: Event) {\n\
                 match ev {\n\
                     Event::Tick => {}\n\
                     Event::Arrive { id } => drop(id),\n\
                     _ => {}\n\
                 }\n\
             }\n",
        );
        let mut out = Vec::new();
        check_event_coverage(&[events, engine], &mut out);
        assert_eq!(out.len(), 2, "{out:?}");
        assert!(out.iter().any(|f| f.message.contains("never matched")
            && f.message.contains("Finish")
            && f.file == "sim/events.rs"));
        assert!(out.iter().any(|f| f.message.contains("VALIDATED_EVENTS")
            && f.message.contains("Finish")
            && f.file == "sim/engine.rs"));
    }

    #[test]
    fn r6_flags_unhandled_trace_event_variants() {
        let recorder = file(
            "metrics/recorder.rs",
            "pub enum TraceEvent {\n\
                 Arrived { request: u64 },\n\
                 Finished { request: u64, instance: usize },\n\
                 KvSample { instance: usize },\n\
             }\n",
        );
        let spans = file(
            "obs/spans.rs",
            "fn absorb(ev: &TraceEvent) {\n\
                 match ev {\n\
                     TraceEvent::Arrived { request } => drop(request),\n\
                     TraceEvent::Finished { .. } => {}\n\
                     _ => {}\n\
                 }\n\
             }\n",
        );
        let mut out = Vec::new();
        check_trace_event_coverage(&[recorder, spans], &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].rule, "R6");
        assert_eq!(out[0].file, "metrics/recorder.rs");
        assert_eq!(out[0].line, 4);
        assert!(out[0].message.contains("KvSample"), "{out:?}");
    }

    #[test]
    fn r6_is_silent_when_every_variant_is_handled_or_layer_is_absent() {
        let recorder = file("metrics/recorder.rs", "pub enum TraceEvent { Tick }\n");
        let spans = file(
            "obs/spans.rs",
            "fn absorb(ev: &TraceEvent) { match ev { TraceEvent::Tick => {} } }\n",
        );
        let mut out = Vec::new();
        check_trace_event_coverage(&[recorder, spans], &mut out);
        assert!(out.is_empty(), "{out:?}");
        // a tree without the obs layer (e.g. the R1-R5 fixture dirs alone)
        // is not a violation
        let lone = file("metrics/recorder.rs", "pub enum TraceEvent { Tick }\n");
        check_trace_event_coverage(&[lone], &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn r7_flags_the_three_global_shapes_and_scopes_to_core_dirs() {
        let bad = file(
            "sim/shard_state.rs",
            "static mut COUNTER: u64 = 0;\n\
             static CACHE: OnceLock<Vec<u64>> = OnceLock::new();\n\
             static HITS: std::sync::atomic::AtomicU64 = AtomicU64::new(0);\n\
             lazy_static! { static ref TABLE: Vec<u64> = Vec::new(); }\n",
        );
        let elsewhere = file("runtime/meta.rs", "static mut COUNTER: u64 = 0;\n");
        let mut out = Vec::new();
        check_shared_mutable_static(&[bad, elsewhere], &mut out);
        assert_eq!(out.len(), 4, "{out:?}");
        assert!(out.iter().all(|f| f.file == "sim/shard_state.rs"));
        assert!(out.iter().any(|f| f.message.contains("static mut")));
        assert!(out.iter().any(|f| f.message.contains("OnceLock")));
        assert!(out
            .iter()
            .any(|f| f.message.contains("Atomic") || f.message.contains("AtomicU64")));
        assert!(out.iter().any(|f| f.message.contains("lazy_static")));
    }

    #[test]
    fn r7_ignores_immutable_statics_lifetimes_and_tests() {
        let f = file(
            "coordinator/ok.rs",
            "static NAMES: &[&'static str] = &[\"a\"];\n\
             fn f(s: &'static str) -> &'static str { s }\n\
             #[cfg(test)]\n\
             mod tests {\n\
                 static mut SCRATCH: u64 = 0;\n\
             }\n",
        );
        let mut out = Vec::new();
        check_shared_mutable_static(&[f], &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn r5_reports_a_missing_coverage_list_once() {
        let events = file("sim/events.rs", "pub enum Event { Tick }\n");
        let engine = file(
            "sim/engine.rs",
            "fn run(ev: Event) { match ev { Event::Tick => {} } }\n",
        );
        let mut out = Vec::new();
        check_event_coverage(&[events, engine], &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].message.contains("no VALIDATED_EVENTS"));
    }
}
