//! Minimal Rust lexer for the `star analyze` pass (offline substitute for
//! `syn`, in the same spirit as the hand-rolled JSON parser in
//! [`crate::bench::json`]). It produces just enough structure for the
//! rule engine: identifiers/keywords, punctuation, literals, and line
//! comments (kept, because `// SAFETY:` and `// ANALYZE-OK:` waivers live
//! there). It is *not* a full lexer — no token trees, no macro expansion —
//! but it is exact about the things a grep is not: string/char/comment
//! contents never produce identifier tokens, raw strings are skipped
//! whole, and `'a` lifetimes are distinguished from `'a'` char literals.

/// Token classes the rule engine consumes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`unsafe` is one token, `memory_unsafe_x` another).
    Ident,
    Num,
    /// String literal (plain, raw, or byte). `text` is the *content*.
    Str,
    Char,
    Lifetime,
    /// `//`-comment; `text` is everything after the `//`.
    LineComment,
    /// Single punctuation character.
    Punct,
}

/// One lexed token with its 1-indexed source line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == 1 && self.text.as_bytes()[0] == c as u8
    }
}

struct Lexer<'a> {
    bytes: &'a [u8],
    pos: usize,
    line: u32,
}

/// Lex a source file. Never fails: unterminated constructs simply run to
/// end of input (the analyzer lints real, compiling code; graceful
/// degradation beats a parse error on a fixture).
pub fn lex(src: &str) -> Vec<Tok> {
    let mut lx = Lexer {
        bytes: src.as_bytes(),
        pos: 0,
        line: 1,
    };
    let mut toks = Vec::new();
    while let Some(t) = lx.next_tok() {
        toks.push(t);
    }
    toks
}

impl Lexer<'_> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn peek_at(&self, off: usize) -> Option<u8> {
        self.bytes.get(self.pos + off).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        if b == b'\n' {
            self.line += 1;
        }
        Some(b)
    }

    fn next_tok(&mut self) -> Option<Tok> {
        loop {
            let b = self.peek()?;
            match b {
                b' ' | b'\t' | b'\r' | b'\n' => {
                    self.bump();
                }
                b'/' if self.peek_at(1) == Some(b'/') => return Some(self.line_comment()),
                b'/' if self.peek_at(1) == Some(b'*') => self.block_comment(),
                b'"' => return Some(self.string(b'"')),
                b'\'' => return Some(self.quote()),
                b'r' | b'b' if self.raw_string_ahead() => return Some(self.raw_string()),
                b'b' if self.peek_at(1) == Some(b'"') => {
                    self.bump(); // `b` prefix, then a plain string
                    return Some(self.string(b'"'));
                }
                _ if b == b'_' || b.is_ascii_alphabetic() => return Some(self.ident()),
                _ if b.is_ascii_digit() => return Some(self.number()),
                _ => {
                    let line = self.line;
                    self.bump();
                    return Some(Tok {
                        kind: TokKind::Punct,
                        text: (b as char).to_string(),
                        line,
                    });
                }
            }
        }
    }

    fn line_comment(&mut self) -> Tok {
        let line = self.line;
        self.bump();
        self.bump(); // the `//`
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b == b'\n' {
                break;
            }
            self.bump();
        }
        Tok {
            kind: TokKind::LineComment,
            text: String::from_utf8_lossy(&self.bytes[start..self.pos]).into_owned(),
            line,
        }
    }

    fn block_comment(&mut self) {
        self.bump();
        self.bump(); // the `/*`
        let mut depth = 1usize;
        while depth > 0 {
            match (self.peek(), self.peek_at(1)) {
                (Some(b'/'), Some(b'*')) => {
                    depth += 1;
                    self.bump();
                    self.bump();
                }
                (Some(b'*'), Some(b'/')) => {
                    depth -= 1;
                    self.bump();
                    self.bump();
                }
                (Some(_), _) => {
                    self.bump();
                }
                (None, _) => break,
            }
        }
    }

    fn string(&mut self, quote: u8) -> Tok {
        let line = self.line;
        self.bump(); // opening quote
        let mut text = String::new();
        while let Some(b) = self.peek() {
            if b == quote {
                self.bump();
                break;
            }
            if b == b'\\' {
                self.bump();
                if let Some(esc) = self.bump() {
                    text.push(esc as char);
                }
                continue;
            }
            self.bump();
            text.push(b as char);
        }
        Tok {
            kind: TokKind::Str,
            text,
            line,
        }
    }

    /// `r"…"`, `r#"…"#`, `br##"…"##` — a prefix at the current position?
    fn raw_string_ahead(&self) -> bool {
        let mut off = 1; // past the leading r/b
        if self.peek() == Some(b'b') {
            if self.peek_at(1) != Some(b'r') {
                return false;
            }
            off = 2;
        }
        loop {
            match self.peek_at(off) {
                Some(b'#') => off += 1,
                Some(b'"') => return true,
                _ => return false,
            }
        }
    }

    fn raw_string(&mut self) -> Tok {
        let line = self.line;
        if self.peek() == Some(b'b') {
            self.bump();
        }
        self.bump(); // `r`
        let mut hashes = 0usize;
        while self.peek() == Some(b'#') {
            hashes += 1;
            self.bump();
        }
        self.bump(); // opening `"`
        let start = self.pos;
        let mut end = self.pos;
        'scan: while let Some(b) = self.peek() {
            if b == b'"' {
                // candidate close: `"` followed by `hashes` hash marks
                for h in 0..hashes {
                    if self.peek_at(1 + h) != Some(b'#') {
                        end = self.pos + 1;
                        self.bump();
                        continue 'scan;
                    }
                }
                end = self.pos;
                self.bump();
                for _ in 0..hashes {
                    self.bump();
                }
                break;
            }
            self.bump();
            end = self.pos;
        }
        Tok {
            kind: TokKind::Str,
            text: String::from_utf8_lossy(&self.bytes[start..end]).into_owned(),
            line,
        }
    }

    /// `'` starts either a char literal or a lifetime. A lifetime is `'`
    /// followed by an identifier with NO closing quote (`'a`, `'static`);
    /// anything escaped or quote-closed is a char (`'a'`, `'\n'`, `'\''`).
    fn quote(&mut self) -> Tok {
        let line = self.line;
        let is_lifetime = match self.peek_at(1) {
            Some(c) if c == b'_' || c.is_ascii_alphabetic() => {
                // scan the identifier; a `'` right after makes it a char
                let mut off = 2;
                while let Some(c2) = self.peek_at(off) {
                    if c2 == b'_' || c2.is_ascii_alphanumeric() {
                        off += 1;
                    } else {
                        break;
                    }
                }
                self.peek_at(off) != Some(b'\'')
            }
            _ => false,
        };
        if is_lifetime {
            self.bump(); // `'`
            let start = self.pos;
            while let Some(c) = self.peek() {
                if c == b'_' || c.is_ascii_alphanumeric() {
                    self.bump();
                } else {
                    break;
                }
            }
            return Tok {
                kind: TokKind::Lifetime,
                text: String::from_utf8_lossy(&self.bytes[start..self.pos]).into_owned(),
                line,
            };
        }
        self.string(b'\'');
        Tok {
            kind: TokKind::Char,
            text: String::new(),
            line,
        }
    }

    fn ident(&mut self) -> Tok {
        let line = self.line;
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c == b'_' || c.is_ascii_alphanumeric() {
                self.bump();
            } else {
                break;
            }
        }
        Tok {
            kind: TokKind::Ident,
            text: String::from_utf8_lossy(&self.bytes[start..self.pos]).into_owned(),
            line,
        }
    }

    fn number(&mut self) -> Tok {
        let line = self.line;
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_alphanumeric() || c == b'_' {
                self.bump();
            } else if c == b'.' {
                // `1.5` continues the number; `0..n` does not (range)
                match self.peek_at(1) {
                    Some(d) if d.is_ascii_digit() => {
                        self.bump();
                    }
                    _ => break,
                }
            } else {
                break;
            }
        }
        Tok {
            kind: TokKind::Num,
            text: String::from_utf8_lossy(&self.bytes[start..self.pos]).into_owned(),
            line,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_are_whole_words() {
        // the reason this lexer exists: a grep for `unsafe` matches the
        // test fn name below, the lexer does not
        let toks = lex("fn memory_unsafe_target_rejected() { unsafe {} }");
        let unsafe_toks: Vec<_> = toks.iter().filter(|t| t.is_ident("unsafe")).collect();
        assert_eq!(unsafe_toks.len(), 1);
        assert!(toks.iter().any(|t| t.is_ident("memory_unsafe_target_rejected")));
    }

    #[test]
    fn strings_and_comments_hide_their_contents() {
        let src = r#"
            let a = "HashMap in a string";
            /* HashMap in a block comment */
            // HashMap in a line comment
            let b = 'H';
        "#;
        let toks = lex(src);
        assert!(!toks.iter().any(|t| t.is_ident("HashMap")));
        assert_eq!(
            toks.iter().filter(|t| t.kind == TokKind::LineComment).count(),
            1
        );
    }

    #[test]
    fn raw_strings_are_single_tokens() {
        let toks = lex(r###"let x = r#"unsafe { "nested" }"#; let y = 1;"###);
        let strs: Vec<_> = toks.iter().filter(|t| t.kind == TokKind::Str).collect();
        assert_eq!(strs.len(), 1);
        assert!(strs[0].text.contains("unsafe"));
        assert!(!toks.iter().any(|t| t.is_ident("unsafe")));
        assert!(toks.iter().any(|t| t.is_ident("y")), "lexing resumes after");
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let toks = kinds("fn f<'a>(x: &'a str) -> char { 'x' }");
        assert!(toks.contains(&(TokKind::Lifetime, "a".to_string())));
        assert_eq!(
            toks.iter().filter(|(k, _)| *k == TokKind::Char).count(),
            1
        );
    }

    #[test]
    fn escaped_quote_char_is_not_a_lifetime() {
        let toks = lex(r"let q = '\''; let l: &'static str = x;");
        assert_eq!(toks.iter().filter(|t| t.kind == TokKind::Char).count(), 1);
        assert!(toks
            .iter()
            .any(|t| t.kind == TokKind::Lifetime && t.text == "static"));
    }

    #[test]
    fn lines_are_tracked() {
        let toks = lex("a\nb\n\nc");
        let lines: Vec<u32> = toks.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 4]);
    }

    #[test]
    fn range_expressions_do_not_swallow_idents() {
        let toks = lex("for i in 0..bucket { }");
        assert!(toks.iter().any(|t| t.is_ident("bucket")));
        assert!(toks.iter().any(|t| t.kind == TokKind::Num && t.text == "0"));
    }
}
