//! Crate-wide error type (hand-rolled `Display`/`From` impls so the crate
//! has no proc-macro dependency and builds fully offline).

use std::fmt;

/// Unified error for runtime, config, and coordination failures.
#[derive(Debug)]
pub enum Error {
    /// PJRT / XLA failures surfaced from the `xla` crate.
    Xla(xla::Error),

    /// Artifact files missing or malformed (run `make artifacts`).
    Artifact(String),

    /// Configuration parse or validation failure.
    Config(String),

    /// KV-cache capacity exhausted on an instance (paper Issue 1).
    KvOom {
        instance: usize,
        need: usize,
        free: usize,
    },

    /// Request routing / lifecycle violation (bug or shutdown race).
    Coordinator(String),

    /// I/O with context.
    Io(std::io::Error),

    /// CLI usage error.
    Cli(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(e) => write!(f, "xla: {e}"),
            Error::Artifact(m) => write!(f, "artifact: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::KvOom {
                instance,
                need,
                free,
            } => write!(
                f,
                "kv cache OOM on instance {instance}: need {need} blocks, free {free}"
            ),
            Error::Coordinator(m) => write!(f, "coordinator: {m}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Cli(m) => write!(f, "cli: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Xla(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn coordinator(msg: impl Into<String>) -> Self {
        Error::Coordinator(msg.into())
    }
}
