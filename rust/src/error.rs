//! Crate-wide error type.

use thiserror::Error;

/// Unified error for runtime, config, and coordination failures.
#[derive(Error, Debug)]
pub enum Error {
    /// PJRT / XLA failures surfaced from the `xla` crate.
    #[error("xla: {0}")]
    Xla(#[from] xla::Error),

    /// Artifact files missing or malformed (run `make artifacts`).
    #[error("artifact: {0}")]
    Artifact(String),

    /// Configuration parse or validation failure.
    #[error("config: {0}")]
    Config(String),

    /// KV-cache capacity exhausted on an instance (paper Issue 1).
    #[error("kv cache OOM on instance {instance}: need {need} blocks, free {free}")]
    KvOom {
        instance: usize,
        need: usize,
        free: usize,
    },

    /// Request routing / lifecycle violation (bug or shutdown race).
    #[error("coordinator: {0}")]
    Coordinator(String),

    /// I/O with context.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    /// CLI usage error.
    #[error("cli: {0}")]
    Cli(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn coordinator(msg: impl Into<String>) -> Self {
        Error::Coordinator(msg.into())
    }
}
