//! Configuration system: a TOML-subset parser (offline substitute for
//! serde+toml, DESIGN.md §1) plus the typed experiment configs.
//!
//! Supported syntax: `[section.sub]` headers, `key = value` with string
//! ("…"), integer, float, bool, and flat arrays of those; `#` comments.

mod parser;
mod types;

pub use parser::{parse_toml, Value};
pub use types::{
    ClusterConfig, ElasticConfig, ExperimentConfig, KvCacheConfig, ObsConfig, PredictorKind,
    ReschedulerConfig,
};

use std::collections::BTreeMap;
use std::path::Path;

use crate::{Error, Result};

/// Flat view of a parsed config: dotted-path -> value.
#[derive(Clone, Debug, Default)]
pub struct Config {
    map: BTreeMap<String, Value>,
}

impl Config {
    pub fn from_str(text: &str) -> Result<Config> {
        Ok(Config {
            map: parse_toml(text)?,
        })
    }

    pub fn from_file(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::config(format!("{}: {e}", path.display())))?;
        Self::from_str(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    /// Merge `other` on top of `self` (other wins).
    pub fn overlay(&mut self, other: Config) {
        for (k, v) in other.map {
            self.map.insert(k, v);
        }
    }

    /// Set a dotted key from a CLI `--set key=value` string.
    pub fn set_kv(&mut self, spec: &str) -> Result<()> {
        let (k, v) = spec
            .split_once('=')
            .ok_or_else(|| Error::config(format!("--set expects key=value, got `{spec}`")))?;
        self.map
            .insert(k.trim().to_string(), Value::parse_scalar(v.trim()));
        Ok(())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        match self.map.get(key) {
            Some(Value::Str(s)) => s,
            _ => default,
        }
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        match self.map.get(key) {
            Some(Value::Int(v)) => *v,
            Some(Value::Float(v)) => *v as i64,
            _ => default,
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        match self.map.get(key) {
            Some(Value::Float(v)) => *v,
            Some(Value::Int(v)) => *v as f64,
            _ => default,
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.map.get(key) {
            Some(Value::Bool(v)) => *v,
            _ => default,
        }
    }

    pub fn f64_list(&self, key: &str) -> Vec<f64> {
        match self.map.get(key) {
            Some(Value::Array(xs)) => xs
                .iter()
                .filter_map(|v| match v {
                    Value::Int(i) => Some(*i as f64),
                    Value::Float(f) => Some(*f),
                    _ => None,
                })
                .collect(),
            _ => Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let cfg = Config::from_str(
            r#"
# experiment
[cluster]
decode_instances = 3
rps = 0.17
dataset = "sharegpt"
[rescheduler]
enabled = true
theta = 0.15
betas = [1.0, 0.5, 0.25]
"#,
        )
        .unwrap();
        assert_eq!(cfg.i64_or("cluster.decode_instances", 0), 3);
        assert!((cfg.f64_or("cluster.rps", 0.0) - 0.17).abs() < 1e-12);
        assert_eq!(cfg.str_or("cluster.dataset", ""), "sharegpt");
        assert!(cfg.bool_or("rescheduler.enabled", false));
        assert_eq!(cfg.f64_list("rescheduler.betas"), vec![1.0, 0.5, 0.25]);
    }

    #[test]
    fn overlay_and_set() {
        let mut a = Config::from_str("[x]\nv = 1\nw = 2\n").unwrap();
        let b = Config::from_str("[x]\nv = 9\n").unwrap();
        a.overlay(b);
        assert_eq!(a.i64_or("x.v", 0), 9);
        assert_eq!(a.i64_or("x.w", 0), 2);
        a.set_kv("x.v=42").unwrap();
        assert_eq!(a.i64_or("x.v", 0), 42);
        assert!(a.set_kv("nonsense").is_err());
    }

    #[test]
    fn defaults_on_missing() {
        let cfg = Config::from_str("").unwrap();
        assert_eq!(cfg.i64_or("a.b", 7), 7);
        assert_eq!(cfg.str_or("a.c", "x"), "x");
    }
}
