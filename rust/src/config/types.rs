//! Typed experiment configuration assembled from a [`super::Config`].

use std::collections::BTreeMap;

use super::{Config, Value};
use crate::workload::{
    ArrivalProcess, ClassMix, ClassSpec, Dataset, FaultConfig, FaultEvent, FleetSpec,
    ScenarioSpec, SessionProfile,
};
use crate::{Error, Result};

/// The live serving path's typed view of a predictor selection. The
/// authoritative selector is the registry *name* string
/// (`ExperimentConfig::predictor`, resolved through
/// `predictor::PredictorRegistry`); this enum is what the decode-instance
/// threads match on to pick their execution path (runtime MLP vs
/// forced-length oracle), derived from the name via [`Self::parse`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PredictorKind {
    /// No prediction: classification uses current load only
    /// (the paper's "STAR w/o prediction").
    None,
    /// Exact remaining lengths (the paper's "STAR Oracle").
    Oracle,
    /// Oracle quantized to n non-uniform bins (paper Table 3: 2/4/6).
    Binned(u8),
    /// The trained LLM-native MLP (live runtime: through the HLO
    /// predictor artifact; simulator: oracle + calibrated relative noise).
    LlmNative,
    /// LLM-native + online per-progress-bucket bias correction (the
    /// simulator's `debiased` builtin; the live path runs the MLP
    /// uncorrected).
    Debiased,
}

impl PredictorKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().replace('-', "_").as_str() {
            "none" => Ok(PredictorKind::None),
            "oracle" => Ok(PredictorKind::Oracle),
            "llm_native" | "native" => Ok(PredictorKind::LlmNative),
            "debiased" => Ok(PredictorKind::Debiased),
            other => {
                let n = other
                    .strip_prefix("binned")
                    .or(other.strip_suffix("bin").map(|n| n.trim_matches('_')));
                if let Some(n) = n {
                    let n: u8 = n
                        .parse()
                        .map_err(|_| Error::config(format!("bad predictor `{other}`")))?;
                    Ok(PredictorKind::Binned(n))
                } else {
                    Err(Error::config(format!(
                        "unknown predictor `{other}` \
                         (none|oracle|llm_native|debiased|binned2|binned4|binned6)"
                    )))
                }
            }
        }
    }

    /// Canonical registry key (matches `PredictorRegistry::with_builtins`
    /// names — the satellite invariant: display names ARE registry keys).
    pub fn name(&self) -> String {
        match self {
            PredictorKind::None => "none".into(),
            PredictorKind::Oracle => "oracle".into(),
            PredictorKind::Binned(n) => format!("binned{n}"),
            PredictorKind::LlmNative => "llm_native".into(),
            PredictorKind::Debiased => "debiased".into(),
        }
    }

    pub fn uses_prediction(&self) -> bool {
        !matches!(self, PredictorKind::None)
    }
}

/// STAR rescheduler parameters (paper Alg. 1 + §5.3).
#[derive(Clone, Debug)]
pub struct ReschedulerConfig {
    /// Master switch ("vLLM" baseline = false).
    pub enabled: bool,
    /// Scheduling interval in seconds (scheduler loop, Alg. 1 line 3).
    pub interval_s: f64,
    /// Overload threshold theta (Alg. 1 lines 14-15).
    pub theta: f64,
    /// Prediction horizon H in scheduler intervals.
    pub horizon: usize,
    /// Geometric decay of the time weights beta_t = beta_decay^t (Eq. 4).
    pub beta_decay: f64,
    /// Reprediction interval in decode iterations (paper §5.3, k=20).
    pub predict_every_iters: u32,
    /// Max migrations per scheduling interval (paper: best single move).
    pub max_migrations_per_interval: usize,
    /// Safety margin on the target's memory check (fraction of capacity
    /// kept free over the horizon, Alg. 1 line 21).
    pub mem_safety_frac: f64,
    /// Seed for the average decode iteration time T̄_exec before any
    /// measurement exists (drivers overwrite it with EWMA measurements
    /// every interval). Default 0.02 s ≈ the paper's 18.23 ms RTX 4090D
    /// iteration at 50% KV occupancy (§5.3).
    pub initial_avg_iter_s: f64,
    /// Remaining output length assumed for a request with no prediction
    /// (Alg. 1 without `usePrediction` still needs a number for the
    /// migration-amortization check). Default 1000 tokens ≈ half the
    /// ShareGPT mean realized output; drivers refine it online from the
    /// workload's running mean.
    pub default_remaining: f64,
}

impl Default for ReschedulerConfig {
    fn default() -> Self {
        ReschedulerConfig {
            enabled: true,
            interval_s: 1.0,
            theta: 0.15,
            horizon: 8,
            beta_decay: 0.7,
            predict_every_iters: 20,
            max_migrations_per_interval: 1,
            mem_safety_frac: 0.01,
            initial_avg_iter_s: 0.02,
            default_remaining: 1000.0,
        }
    }
}

/// Elastic instance-pool parameters (`coordinator::elastic`): how fast
/// the pool may change shape and how far it may shrink. The scaling
/// *policy* itself is named by `ExperimentConfig::scaling_policy`
/// (config key `policy.scaling`, CLI `--scaling`).
#[derive(Clone, Debug)]
pub struct ElasticConfig {
    /// Seconds between scaling decisions (the drivers' ScaleTick).
    pub scale_interval_s: f64,
    /// Modeled warm-up of a freshly provisioned instance (weights load,
    /// CUDA graphs, allocator pools) before it accepts work.
    pub provision_delay_s: f64,
    /// Modeled re-role time of a drained instance flipping prefill↔decode
    /// (smaller than a cold provision: weights stay resident).
    pub flip_delay_s: f64,
    /// Pool-size floors a scaling decision may never cross.
    pub min_prefill: usize,
    pub min_decode: usize,
    /// Hard cap on total instances for `Provision` actions; 0 disables
    /// provisioning entirely (the pool can only re-role, never grow) —
    /// the fair setting for fixed-budget comparisons.
    pub max_total: usize,
    /// Minimum seconds between two executed scaling actions (thrash
    /// damper; one in-flight transition already blocks new ones).
    pub cooldown_s: f64,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            scale_interval_s: 5.0,
            provision_delay_s: 10.0,
            flip_delay_s: 2.0,
            min_prefill: 1,
            min_decode: 1,
            max_total: 0,
            cooldown_s: 10.0,
        }
    }
}

impl ElasticConfig {
    pub fn validate(&self) -> Result<()> {
        if self.scale_interval_s <= 0.0 {
            return Err(Error::config("elastic.scale_interval_s must be > 0"));
        }
        if self.provision_delay_s < 0.0 || self.flip_delay_s < 0.0 {
            return Err(Error::config("elastic delays must be >= 0"));
        }
        if self.min_prefill == 0 || self.min_decode == 0 {
            return Err(Error::config(
                "elastic.min_prefill / min_decode must be >= 1",
            ));
        }
        if self.cooldown_s < 0.0 {
            return Err(Error::config("elastic.cooldown_s must be >= 0"));
        }
        Ok(())
    }
}

/// Prefix-cache parameters (`[kvcache]` table; `kvcache::PrefixCache`).
#[derive(Clone, Debug)]
pub struct KvCacheConfig {
    /// Retention policy, by registry name (config key `kvcache.policy`,
    /// CLI `--cache`), resolved against `kvcache::CachePolicyRegistry`.
    /// `"none"` — the default — turns the subsystem off entirely: no
    /// lookups, no insertions, no events, traces bit-for-bit identical to
    /// pre-cache builds.
    pub policy: String,
    /// Per-instance budget for idle cached prefixes, in KV tokens.
    pub budget_tokens: u64,
    /// Lifetime of a cached prefix for TTL-based policies, seconds.
    pub ttl_s: f64,
}

impl Default for KvCacheConfig {
    fn default() -> Self {
        KvCacheConfig {
            policy: "none".to_string(),
            budget_tokens: 200_000,
            ttl_s: 60.0,
        }
    }
}

impl KvCacheConfig {
    /// Is a real (non-`none`) policy selected? (Alias-aware: `off` is the
    /// `none` builtin.)
    pub fn enabled(&self) -> bool {
        !matches!(
            self.policy.to_ascii_lowercase().replace('-', "_").as_str(),
            "none" | "off"
        )
    }

    /// `tick_s` is the scheduler interval: TTL sweeps run on the
    /// scheduler tick, so a TTL shorter than one tick could never be
    /// enforced and is rejected rather than silently rounded up.
    pub fn validate(&self, tick_s: f64) -> Result<()> {
        let reg = crate::kvcache::CachePolicyRegistry::with_builtins();
        if !reg.has(&self.policy) {
            return Err(Error::config(format!(
                "unknown cache policy `{}` (known: {})",
                self.policy,
                reg.names().join("|")
            )));
        }
        if !self.enabled() {
            return Ok(());
        }
        if self.budget_tokens == 0 {
            return Err(Error::config(
                "kvcache.budget_tokens must be > 0 (a zero budget can cache nothing; \
                 use policy = \"none\" to disable the cache)",
            ));
        }
        if self.ttl_s < tick_s {
            return Err(Error::config(format!(
                "kvcache.ttl_s ({}) must be >= the scheduler tick \
                 (rescheduler.interval_s = {}): a TTL shorter than one scheduler \
                 tick can never be enforced",
                self.ttl_s, tick_s
            )));
        }
        Ok(())
    }
}

/// Observability parameters (`[obs]` table; `crate::obs`, `star trace`).
#[derive(Clone, Debug)]
pub struct ObsConfig {
    /// Master switch. Off (the default) the subsystem is a strict
    /// no-op: drivers record nothing and their outputs are bit-for-bit
    /// identical to a build without it.
    pub enabled: bool,
    /// Seconds between registry time-series samples in both drivers
    /// (sim event clock / serve wall timer). Replaces the old
    /// hardcoded sampling cadence; must be > 0.
    pub sample_every_s: f64,
    /// Flight-recorder bound: retained spans beyond this are dropped
    /// oldest-first (and counted).
    pub ring_capacity: usize,
    /// Head-based span sampling probability in [0, 1]; the decision is
    /// a pure function of (seed, request id) on a dedicated PRNG
    /// stream, so same seed ⇒ identical retained set.
    pub sample_rate: f64,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            enabled: false,
            sample_every_s: 1.0,
            ring_capacity: 4096,
            sample_rate: 1.0,
        }
    }
}

impl ObsConfig {
    pub fn validate(&self) -> Result<()> {
        if !(self.sample_every_s > 0.0) {
            return Err(Error::config("obs.sample_every_s must be > 0"));
        }
        if self.ring_capacity == 0 {
            return Err(Error::config("obs.ring_capacity must be >= 1"));
        }
        if !(0.0..=1.0).contains(&self.sample_rate) {
            return Err(Error::config("obs.sample_rate must be in [0, 1]"));
        }
        Ok(())
    }
}

/// Cluster + workload shape for one experiment run.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub n_prefill: usize,
    pub n_decode: usize,
    /// KV capacity per decode instance, tokens.
    pub kv_capacity_tokens: u64,
    pub block_tokens: u32,
    /// Max concurrent sequences per decode batch.
    pub max_batch: usize,
    pub dataset: Dataset,
    pub rps: f64,
    /// Requests to generate (run ends when all complete).
    pub n_requests: usize,
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        // paper small cluster: 1 prefill + 3 decode
        ClusterConfig {
            n_prefill: 1,
            n_decode: 3,
            kv_capacity_tokens: 96_000,
            block_tokens: 16,
            max_batch: 64,
            dataset: Dataset::ShareGpt,
            rps: 0.1,
            n_requests: 200,
            seed: 0,
        }
    }
}

/// Fully-resolved experiment config.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub cluster: ClusterConfig,
    pub rescheduler: ReschedulerConfig,
    /// Remaining-length predictor, by registry name (config key
    /// `predictor.kind`, CLI `--predictor`), resolved against
    /// `predictor::PredictorRegistry` — the same string-selection surface
    /// as the scheduling policies.
    pub predictor: String,
    /// Relative noise of the simulated LLM-native predictor (calibrated
    /// from artifacts/predictor_eval.tsv MAE / mean-remaining).
    pub predictor_rel_err: f64,
    /// Estimate quantile the OOM-avoidance / migration-target checks
    /// consume (`predictor.conservative_q`, default 0.9 — p90).
    pub predictor_conservative_q: f64,
    /// Estimate quantile the balancing objectives consume
    /// (`predictor.balance_q`, default 0.5 — the mean).
    pub predictor_balance_q: f64,
    pub record_traces: bool,
    /// Dispatch policy, by registry name (config key `policy.dispatch`).
    pub dispatch_policy: String,
    /// Reschedule policy, by registry name (config key `policy.reschedule`).
    pub reschedule_policy: String,
    /// Scaling policy, by registry name (config key `policy.scaling`,
    /// CLI `--scaling`). `"static"` = today's frozen pool (the default).
    pub scaling_policy: String,
    /// Elastic-pool mechanics (`[elastic]` table).
    pub elastic: ElasticConfig,
    /// Prefix-cache subsystem (`[kvcache]` table, CLI `--cache`).
    pub kvcache: KvCacheConfig,
    /// Observability subsystem (`[obs]` table, `star trace`).
    pub obs: ObsConfig,
    /// Policy-specific numeric knobs: every numeric `policy.*` config key
    /// except the two names above, with the `policy.` prefix stripped
    /// (e.g. `policy.slo_aware.mem_weight = 2.0`).
    pub policy_params: BTreeMap<String, f64>,
    /// Named workload scenario (config key `workload.scenario` or CLI
    /// `--scenario`), resolved against the scenario registry
    /// (`bench::scenarios::ScenarioRegistry`) by the drivers. Explicit
    /// `[workload.*]` tables ([`Self::scenario`]) take precedence.
    pub scenario_name: Option<String>,
    /// Fully-specified scenario assembled from `[workload.arrival]`,
    /// `[workload.class.*]`, and `[workload.session]` tables. `None` =
    /// legacy stationary single-class synthesis from `cluster.dataset` /
    /// `cluster.rps`.
    pub scenario: Option<ScenarioSpec>,
    /// Failure-injection plan (`[faults]` table). Takes precedence over a
    /// plan carried by a named scenario's trace.
    pub faults: Option<FaultConfig>,
    /// Heterogeneous decode-fleet shape (`[fleet]` table). Takes
    /// precedence over a fleet carried by a named scenario's trace.
    pub fleet: Option<FleetSpec>,
    /// Simulation event-loop shards (`[sim] shards`, CLI `--shards`):
    /// the cluster is partitioned into `shards` instance groups, each
    /// with its own event queue, merged deterministically at every pop
    /// (see `sim::shard`). Any value yields the same trajectory as
    /// `1` (the serial default) — the knob trades queue sizes for merge
    /// width at scale.
    pub shards: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            cluster: ClusterConfig::default(),
            rescheduler: ReschedulerConfig::default(),
            predictor: "oracle".to_string(),
            predictor_rel_err: 0.0,
            predictor_conservative_q: 0.9,
            predictor_balance_q: 0.5,
            record_traces: false,
            dispatch_policy: "current_load".to_string(),
            reschedule_policy: "star".to_string(),
            scaling_policy: "static".to_string(),
            elastic: ElasticConfig::default(),
            kvcache: KvCacheConfig::default(),
            obs: ObsConfig::default(),
            policy_params: BTreeMap::new(),
            scenario_name: None,
            scenario: None,
            faults: None,
            fleet: None,
            shards: 1,
        }
    }
}

impl ExperimentConfig {
    pub fn from_config(cfg: &Config) -> Result<ExperimentConfig> {
        let dataset = Dataset::parse(cfg.str_or("cluster.dataset", "sharegpt"))
            .ok_or_else(|| Error::config("cluster.dataset must be sharegpt|alpaca"))?;
        let d = ClusterConfig::default();
        let cluster = ClusterConfig {
            n_prefill: cfg.i64_or("cluster.n_prefill", d.n_prefill as i64) as usize,
            n_decode: cfg.i64_or("cluster.n_decode", d.n_decode as i64) as usize,
            kv_capacity_tokens: cfg.i64_or("cluster.kv_capacity_tokens", d.kv_capacity_tokens as i64)
                as u64,
            block_tokens: cfg.i64_or("cluster.block_tokens", d.block_tokens as i64) as u32,
            max_batch: cfg.i64_or("cluster.max_batch", d.max_batch as i64) as usize,
            dataset,
            rps: cfg.f64_or("cluster.rps", d.rps),
            n_requests: cfg.i64_or("cluster.n_requests", d.n_requests as i64) as usize,
            seed: cfg.i64_or("cluster.seed", d.seed as i64) as u64,
        };
        let rd = ReschedulerConfig::default();
        let rescheduler = ReschedulerConfig {
            enabled: cfg.bool_or("rescheduler.enabled", rd.enabled),
            interval_s: cfg.f64_or("rescheduler.interval_s", rd.interval_s),
            theta: cfg.f64_or("rescheduler.theta", rd.theta),
            horizon: cfg.i64_or("rescheduler.horizon", rd.horizon as i64) as usize,
            beta_decay: cfg.f64_or("rescheduler.beta_decay", rd.beta_decay),
            predict_every_iters: cfg.i64_or(
                "rescheduler.predict_every_iters",
                rd.predict_every_iters as i64,
            ) as u32,
            max_migrations_per_interval: cfg.i64_or(
                "rescheduler.max_migrations_per_interval",
                rd.max_migrations_per_interval as i64,
            ) as usize,
            mem_safety_frac: cfg.f64_or("rescheduler.mem_safety_frac", rd.mem_safety_frac),
            initial_avg_iter_s: cfg.f64_or("rescheduler.initial_avg_iter_s", rd.initial_avg_iter_s),
            default_remaining: cfg.f64_or("rescheduler.default_remaining", rd.default_remaining),
        };
        let predictor = cfg.str_or("predictor.kind", "oracle").to_string();
        let ed = ExperimentConfig::default();
        let mut policy_params = BTreeMap::new();
        for key in cfg.keys() {
            let Some(knob) = key.strip_prefix("policy.") else {
                continue;
            };
            if knob == "dispatch" || knob == "reschedule" || knob == "scaling" {
                continue;
            }
            match cfg.get(key) {
                Some(Value::Int(v)) => {
                    policy_params.insert(knob.to_string(), *v as f64);
                }
                Some(Value::Float(v)) => {
                    policy_params.insert(knob.to_string(), *v);
                }
                _ => {
                    return Err(Error::config(format!(
                        "policy knob `{key}` must be numeric"
                    )));
                }
            }
        }
        let scenario_name = match cfg.get("workload.scenario") {
            Some(Value::Str(s)) => Some(s.clone()),
            Some(_) => return Err(Error::config("workload.scenario must be a string")),
            None => None,
        };
        let scenario = scenario_from_config(cfg, &cluster)?;
        let eld = ElasticConfig::default();
        // counts are range-checked as i64 BEFORE the usize cast: a
        // negative value would otherwise wrap to ~2^64 and turn the
        // guard floors (or the max_total provisioning cap) into silent
        // nonsense instead of a config error
        let min_prefill = cfg.i64_or("elastic.min_prefill", eld.min_prefill as i64);
        let min_decode = cfg.i64_or("elastic.min_decode", eld.min_decode as i64);
        let max_total = cfg.i64_or("elastic.max_total", eld.max_total as i64);
        if min_prefill < 1 || min_decode < 1 {
            return Err(Error::config(
                "elastic.min_prefill / min_decode must be >= 1",
            ));
        }
        if max_total < 0 {
            return Err(Error::config(
                "elastic.max_total must be >= 0 (0 disables provisioning)",
            ));
        }
        let elastic = ElasticConfig {
            scale_interval_s: cfg.f64_or("elastic.scale_interval_s", eld.scale_interval_s),
            provision_delay_s: cfg.f64_or("elastic.provision_delay_s", eld.provision_delay_s),
            flip_delay_s: cfg.f64_or("elastic.flip_delay_s", eld.flip_delay_s),
            min_prefill: min_prefill as usize,
            min_decode: min_decode as usize,
            max_total: max_total as usize,
            cooldown_s: cfg.f64_or("elastic.cooldown_s", eld.cooldown_s),
        };
        // the budget is range-checked as i64 BEFORE the u64 cast — same
        // rationale as the elastic counts: a negative budget would wrap
        // to ~2^64 and read as "unbounded" instead of erroring
        let kd = KvCacheConfig::default();
        let budget = cfg.i64_or("kvcache.budget_tokens", kd.budget_tokens as i64);
        if budget < 1 {
            return Err(Error::config(
                "kvcache.budget_tokens must be >= 1 (a zero or negative budget can \
                 cache nothing; use kvcache.policy = \"none\" to disable the cache)",
            ));
        }
        let kvcache = KvCacheConfig {
            policy: cfg.str_or("kvcache.policy", &kd.policy).to_string(),
            budget_tokens: budget as u64,
            ttl_s: cfg.f64_or("kvcache.ttl_s", kd.ttl_s),
        };
        // ring_capacity is range-checked as i64 BEFORE the usize cast —
        // same rationale as the elastic counts and the cache budget
        let od = ObsConfig::default();
        let ring_capacity = cfg.i64_or("obs.ring_capacity", od.ring_capacity as i64);
        if ring_capacity < 1 {
            return Err(Error::config("obs.ring_capacity must be >= 1"));
        }
        let obs = ObsConfig {
            enabled: cfg.bool_or("obs.enabled", od.enabled),
            sample_every_s: cfg.f64_or("obs.sample_every_s", od.sample_every_s),
            ring_capacity: ring_capacity as usize,
            sample_rate: cfg.f64_or("obs.sample_rate", od.sample_rate),
        };
        let faults = faults_from_config(cfg)?;
        let fleet = fleet_from_config(cfg)?;
        // shard count is range-checked as i64 BEFORE the usize cast —
        // same rationale as the elastic counts: a negative value would
        // wrap to an absurd shard count instead of erroring
        let shards = cfg.i64_or("sim.shards", ed.shards as i64);
        if shards < 1 {
            return Err(Error::config("sim.shards must be >= 1"));
        }
        Ok(ExperimentConfig {
            cluster,
            rescheduler,
            predictor,
            predictor_rel_err: cfg.f64_or("predictor.rel_err", 0.25),
            predictor_conservative_q: cfg
                .f64_or("predictor.conservative_q", ed.predictor_conservative_q),
            predictor_balance_q: cfg.f64_or("predictor.balance_q", ed.predictor_balance_q),
            record_traces: cfg.bool_or("experiment.record_traces", false),
            dispatch_policy: cfg.str_or("policy.dispatch", &ed.dispatch_policy).to_string(),
            reschedule_policy: cfg
                .str_or("policy.reschedule", &ed.reschedule_policy)
                .to_string(),
            scaling_policy: cfg.str_or("policy.scaling", &ed.scaling_policy).to_string(),
            elastic,
            kvcache,
            obs,
            policy_params,
            scenario_name,
            scenario,
            faults,
            fleet,
            shards: shards as usize,
        })
    }

    /// Re-assemble [`Self::scenario`] from `cfg`'s `[workload.*]` tables
    /// against the CURRENT cluster settings. Drivers call this after
    /// applying CLI overrides (`--rps`, `--dataset`): table defaults
    /// derived from `cluster.rps` / `cluster.dataset` must track the
    /// final values, not the ones frozen at config-parse time ("CLI flags
    /// win").
    pub fn rebuild_scenario(&mut self, cfg: &Config) -> Result<()> {
        self.scenario = scenario_from_config(cfg, &self.cluster)?;
        Ok(())
    }

    /// Whether the configured predictor produces estimates at all
    /// (Alg. 1 `usePrediction`): everything except the `none` builtin.
    pub fn predictor_uses_prediction(&self) -> bool {
        self.predictor.to_ascii_lowercase().replace('-', "_") != "none"
    }

    pub fn validate(&self) -> Result<()> {
        if self.cluster.n_decode == 0 {
            return Err(Error::config("need at least one decode instance"));
        }
        if self.cluster.n_prefill == 0 {
            return Err(Error::config("need at least one prefill instance"));
        }
        if !(0.0..=1.0).contains(&self.rescheduler.beta_decay) {
            return Err(Error::config("beta_decay must be in [0,1]"));
        }
        if self.rescheduler.theta < 0.0 {
            return Err(Error::config("theta must be >= 0"));
        }
        if self.cluster.block_tokens == 0 {
            return Err(Error::config("block_tokens must be > 0"));
        }
        if self.rescheduler.initial_avg_iter_s <= 0.0 {
            return Err(Error::config("initial_avg_iter_s must be > 0"));
        }
        if self.rescheduler.default_remaining <= 0.0 {
            return Err(Error::config("default_remaining must be > 0"));
        }
        if self.shards == 0 {
            return Err(Error::config("sim.shards must be >= 1"));
        }
        if let Some(spec) = &self.scenario {
            spec.validate()?;
        }
        if let Some(f) = &self.faults {
            f.validate()?;
        }
        if let Some(f) = &self.fleet {
            f.validate()?;
        }
        for (key, q) in [
            ("predictor.conservative_q", self.predictor_conservative_q),
            ("predictor.balance_q", self.predictor_balance_q),
        ] {
            if !(q > 0.0 && q < 1.0) {
                return Err(Error::config(format!("{key} must be in (0, 1), got {q}")));
            }
        }
        // the OOM-avoidance view must dominate the balancing view
        // (load_hi pointwise >= load is what every memory-safety check
        // rests on); an inverted pair would silently under-protect
        if self.predictor_conservative_q < self.predictor_balance_q {
            return Err(Error::config(format!(
                "predictor.conservative_q ({}) must be >= predictor.balance_q ({})",
                self.predictor_conservative_q, self.predictor_balance_q
            )));
        }
        // the predictor name resolves against the *builtin* predictor
        // registry here — same rule as the policies below: custom
        // registries bypass validate() and surface unknown names when the
        // driver builds the predictor (Simulator::with_registries).
        let pred_reg = crate::predictor::PredictorRegistry::with_builtins();
        if !pred_reg.has(&self.predictor) {
            return Err(Error::config(format!(
                "unknown predictor `{}` (known: {})",
                self.predictor,
                pred_reg.names().join("|")
            )));
        }
        // policy names are resolved against the *builtin* registry here;
        // custom registries bypass validate() and surface unknown names
        // when the ControlLoop is built.
        let reg = crate::coordinator::PolicyRegistry::with_builtins();
        if !reg.has_dispatch(&self.dispatch_policy) {
            return Err(Error::config(format!(
                "unknown dispatch policy `{}` (known: {})",
                self.dispatch_policy,
                reg.dispatch_names().join("|")
            )));
        }
        if !reg.has_reschedule(&self.reschedule_policy) {
            return Err(Error::config(format!(
                "unknown reschedule policy `{}` (known: {})",
                self.reschedule_policy,
                reg.reschedule_names().join("|")
            )));
        }
        if !reg.has_scaling(&self.scaling_policy) {
            return Err(Error::config(format!(
                "unknown scaling policy `{}` (known: {})",
                self.scaling_policy,
                reg.scaling_names().join("|")
            )));
        }
        self.elastic.validate()?;
        self.kvcache.validate(self.rescheduler.interval_s)?;
        self.obs.validate()?;
        // knob keys are `<policy>.<knob>`; a typoed or aliased policy
        // prefix would otherwise be silently ignored and the default knob
        // value used — in a reproduction codebase the knob values ARE the
        // experiment. Policies read knobs by exact canonical key, so the
        // prefix must be the canonical name (aliases are fine for the
        // `dispatch`/`reschedule` selectors, not here).
        for key in self.policy_params.keys() {
            let prefix = key.split('.').next().unwrap_or(key);
            let canonical = reg.dispatch_names().iter().any(|n| n == prefix)
                || reg.reschedule_names().iter().any(|n| n == prefix)
                || reg.scaling_names().iter().any(|n| n == prefix);
            if !canonical {
                return Err(Error::config(format!(
                    "policy knob `{key}` must be prefixed with a canonical \
                     policy name (dispatch: {}; reschedule: {}; scaling: {})",
                    reg.dispatch_names().join("|"),
                    reg.reschedule_names().join("|"),
                    reg.scaling_names().join("|")
                )));
            }
        }
        Ok(())
    }
}

impl Default for PredictorKind {
    fn default() -> Self {
        PredictorKind::Oracle
    }
}

/// Assemble a [`ScenarioSpec`] from the `[workload.*]` tables, or `None`
/// when no such table is present (the legacy stationary path). Class
/// tables start from the builtin per-class profiles and override fields;
/// classes without a table are absent from the mix.
fn scenario_from_config(cfg: &Config, cluster: &ClusterConfig) -> Result<Option<ScenarioSpec>> {
    let has_prefix = |p: &str| cfg.keys().any(|k| k.starts_with(p));
    if !has_prefix("workload.arrival.")
        && !has_prefix("workload.class.")
        && !has_prefix("workload.session.")
    {
        return Ok(None);
    }

    let kind = cfg
        .str_or("workload.arrival.kind", "poisson")
        .to_ascii_lowercase();
    let rps = cfg.f64_or("workload.arrival.rps", cluster.rps);
    let arrival = match kind.as_str() {
        "poisson" => ArrivalProcess::Poisson { rps },
        "onoff" | "on_off" | "bursty" => ArrivalProcess::OnOff {
            rps_on: cfg.f64_or("workload.arrival.rps_on", rps * 2.5),
            rps_off: cfg.f64_or("workload.arrival.rps_off", rps * 0.25),
            mean_on_s: cfg.f64_or("workload.arrival.mean_on_s", 20.0),
            mean_off_s: cfg.f64_or("workload.arrival.mean_off_s", 40.0),
        },
        "diurnal" => ArrivalProcess::Diurnal {
            base_rps: cfg.f64_or("workload.arrival.base_rps", rps * 0.5),
            peak_rps: cfg.f64_or("workload.arrival.peak_rps", rps * 1.5),
            period_s: cfg.f64_or("workload.arrival.period_s", 600.0),
        },
        "replay" => {
            let path = cfg.str_or("workload.arrival.path", "");
            if path.is_empty() {
                return Err(Error::config(
                    "workload.arrival.path is required for kind = \"replay\"",
                ));
            }
            ArrivalProcess::from_file(std::path::Path::new(path))?
        }
        other => {
            return Err(Error::config(format!(
                "unknown workload.arrival.kind `{other}` (known: poisson|onoff|diurnal|replay)"
            )))
        }
    };

    // unknown class-table names fail loudly (same rule as --scenario /
    // --dataset / arrival.kind): a typoed or aliased table would
    // otherwise be silently dropped and the run would use a different
    // workload than configured. Canonical names only — aliases accepted
    // by `RequestClass::parse` would still be skipped by the loop below.
    for full_key in cfg.keys() {
        let Some(rest) = full_key.strip_prefix("workload.class.") else {
            continue;
        };
        let name = rest.split('.').next().unwrap_or(rest);
        if !crate::workload::RequestClass::ALL
            .iter()
            .any(|c| c.name() == name)
        {
            return Err(Error::config(format!(
                "unknown workload.class table `{name}` (known: chat|reasoning|summarization)"
            )));
        }
    }
    let mut specs = Vec::new();
    for class in crate::workload::RequestClass::ALL {
        let prefix = format!("workload.class.{}.", class.name());
        if !has_prefix(&prefix) {
            continue;
        }
        let key = |k: &str| format!("{prefix}{k}");
        let mut s = ClassSpec::builtin(class);
        s.weight = cfg.f64_or(&key("weight"), s.weight);
        s.slo.ttft_s = cfg.f64_or(&key("slo_ttft_s"), s.slo.ttft_s);
        s.slo.tpot_s = cfg.f64_or(&key("slo_tpot_s"), s.slo.tpot_s);
        s.lengths.out_mu = cfg.f64_or(&key("out_mu"), s.lengths.out_mu);
        s.lengths.out_sigma = cfg.f64_or(&key("out_sigma"), s.lengths.out_sigma);
        s.lengths.cap_frac = cfg.f64_or(&key("cap_frac"), s.lengths.cap_frac);
        s.lengths.in_mu = cfg.f64_or(&key("in_mu"), s.lengths.in_mu);
        s.lengths.in_sigma = cfg.f64_or(&key("in_sigma"), s.lengths.in_sigma);
        // caps are cast to u32: reject values a bare `as u32` would wrap
        // (negative) or that panic downstream (zero makes clamp(1, cap)
        // assert in sample_output)
        let cap = cfg.i64_or(&key("cap"), s.lengths.cap as i64);
        let in_cap = cfg.i64_or(&key("in_cap"), s.lengths.in_cap as i64);
        if !(1..=u32::MAX as i64).contains(&cap) || !(1..=u32::MAX as i64).contains(&in_cap) {
            return Err(Error::config(format!(
                "workload.class.{}: cap/in_cap must be in [1, {}]",
                class.name(),
                u32::MAX
            )));
        }
        s.lengths.cap = cap as u32;
        s.lengths.in_cap = in_cap as u32;
        specs.push(s);
    }
    let classes = if specs.is_empty() {
        ClassMix::single(ClassSpec::dataset(cluster.dataset))
    } else {
        ClassMix::new(specs)?
    };

    let sessions = if has_prefix("workload.session.")
        && cfg.bool_or("workload.session.enabled", true)
    {
        let d = SessionProfile::default();
        Some(SessionProfile {
            session_frac: cfg.f64_or("workload.session.frac", d.session_frac),
            min_turns: cfg.i64_or("workload.session.min_turns", d.min_turns as i64) as u32,
            max_turns: cfg.i64_or("workload.session.max_turns", d.max_turns as i64) as u32,
            think_mean_s: cfg.f64_or("workload.session.think_mean_s", d.think_mean_s),
            max_context_tokens: cfg
                .i64_or("workload.session.max_context", d.max_context_tokens as i64)
                as u32,
        })
    } else {
        None
    };

    let spec = ScenarioSpec {
        name: "custom".to_string(),
        arrival,
        classes,
        sessions,
        pico_scale: None,
        // faults / fleet live at the experiment level (`[faults]` /
        // `[fleet]` tables, see `faults_from_config`), not inside the
        // custom workload tables
        faults: None,
        fleet: None,
    };
    spec.validate()?;
    Ok(Some(spec))
}

/// Assemble a [`FaultConfig`] from the `[faults]` table, or `None` when
/// absent. `faults.script` is a comma-separated list of scripted
/// failures, each an `at:instance:down_s` triple (e.g.
/// `"30:0:15, 90:2:0"` — instance 2's crash is permanent).
fn faults_from_config(cfg: &Config) -> Result<Option<FaultConfig>> {
    if !cfg.keys().any(|k| k.starts_with("faults.")) {
        return Ok(None);
    }
    let fd = FaultConfig::default();
    // range-checked as i64 BEFORE the usize cast, same rationale as the
    // elastic counts above
    let max_failures = cfg.i64_or("faults.max_failures", fd.max_failures as i64);
    if max_failures < 0 {
        return Err(Error::config("faults.max_failures must be >= 0"));
    }
    let mut script = Vec::new();
    match cfg.get("faults.script") {
        None => {}
        Some(Value::Str(s)) => {
            for part in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
                let fields: Vec<&str> = part.split(':').map(str::trim).collect();
                let parsed = if fields.len() == 3 {
                    match (
                        fields[0].parse::<f64>(),
                        fields[1].parse::<usize>(),
                        fields[2].parse::<f64>(),
                    ) {
                        (Ok(at), Ok(instance), Ok(down_s)) => Some(FaultEvent {
                            at,
                            instance,
                            down_s,
                        }),
                        _ => None,
                    }
                } else {
                    None
                };
                match parsed {
                    Some(ev) => script.push(ev),
                    None => {
                        return Err(Error::config(format!(
                            "faults.script entry `{part}` must be an \
                             `at:instance:down_s` triple (e.g. \"30:0:15\")"
                        )))
                    }
                }
            }
        }
        Some(_) => {
            return Err(Error::config(
                "faults.script must be a string of `at:instance:down_s` triples",
            ))
        }
    }
    let faults = FaultConfig {
        mtbf_s: cfg.f64_or("faults.mtbf_s", fd.mtbf_s),
        mttr_s: cfg.f64_or("faults.mttr_s", fd.mttr_s),
        max_failures: max_failures as usize,
        script,
    };
    faults.validate()?;
    Ok(Some(faults))
}

/// Assemble a [`FleetSpec`] from the `[fleet]` table, or `None` when
/// absent. `speed_mults` / `mem_mults` are comma-separated float lists
/// cycled over decode instance ids; the shorter list repeats.
fn fleet_from_config(cfg: &Config) -> Result<Option<FleetSpec>> {
    if !cfg.keys().any(|k| k.starts_with("fleet.")) {
        return Ok(None);
    }
    let list = |key: &str| -> Result<Vec<f64>> {
        match cfg.get(key) {
            None => Ok(Vec::new()),
            Some(Value::Str(s)) => s
                .split(',')
                .map(str::trim)
                .filter(|t| !t.is_empty())
                .map(|t| {
                    t.parse::<f64>()
                        .map_err(|_| Error::config(format!("{key}: `{t}` is not a number")))
                })
                .collect(),
            Some(_) => Err(Error::config(format!(
                "{key} must be a comma-separated string of floats (e.g. \"1.0, 0.5\")"
            ))),
        }
    };
    let speed = list("fleet.speed_mults")?;
    let mem = list("fleet.mem_mults")?;
    if speed.is_empty() && mem.is_empty() {
        return Err(Error::config(
            "a [fleet] table needs fleet.speed_mults and/or fleet.mem_mults",
        ));
    }
    let fleet = FleetSpec::from_mults(&speed, &mem);
    fleet.validate()?;
    Ok(Some(fleet))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predictor_parse_all() {
        assert_eq!(PredictorKind::parse("none").unwrap(), PredictorKind::None);
        assert_eq!(PredictorKind::parse("Oracle").unwrap(), PredictorKind::Oracle);
        assert_eq!(
            PredictorKind::parse("llm_native").unwrap(),
            PredictorKind::LlmNative
        );
        assert_eq!(PredictorKind::parse("6bin").unwrap(), PredictorKind::Binned(6));
        // registry-canonical spellings parse too, and names round-trip to
        // the registry keys (no `6bin`/`llm_native(sim,σ=…)` leakage)
        assert_eq!(
            PredictorKind::parse("binned4").unwrap(),
            PredictorKind::Binned(4)
        );
        assert_eq!(
            PredictorKind::parse("debiased").unwrap(),
            PredictorKind::Debiased
        );
        for k in [
            PredictorKind::None,
            PredictorKind::Oracle,
            PredictorKind::Binned(6),
            PredictorKind::LlmNative,
            PredictorKind::Debiased,
        ] {
            assert_eq!(PredictorKind::parse(&k.name()).unwrap(), k);
            assert!(
                crate::predictor::PredictorRegistry::with_builtins().has(&k.name()),
                "{} must be a registry key",
                k.name()
            );
        }
        assert!(PredictorKind::parse("magic").is_err());
    }

    #[test]
    fn experiment_from_config_defaults() {
        let cfg = Config::from_str("").unwrap();
        let exp = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(exp.cluster.n_decode, 3);
        assert!(exp.rescheduler.enabled);
        exp.validate().unwrap();
    }

    #[test]
    fn experiment_from_config_overrides() {
        let cfg = Config::from_str(
            "[cluster]\nn_decode = 8\ndataset = \"alpaca\"\n[predictor]\nkind = \"4bin\"\n",
        )
        .unwrap();
        let exp = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(exp.cluster.n_decode, 8);
        assert_eq!(exp.cluster.dataset, Dataset::Alpaca);
        assert_eq!(exp.predictor, "4bin");
        exp.validate().expect("4bin aliases the binned4 builtin");
    }

    #[test]
    fn predictor_name_and_quantiles_parse_and_validate() {
        let cfg = Config::from_str(
            "[predictor]\nkind = \"debiased\"\nrel_err = 0.4\n\
             conservative_q = 0.95\nbalance_q = 0.5\n",
        )
        .unwrap();
        let exp = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(exp.predictor, "debiased");
        assert!((exp.predictor_rel_err - 0.4).abs() < 1e-12);
        assert!((exp.predictor_conservative_q - 0.95).abs() < 1e-12);
        exp.validate().unwrap();
        // unknown predictor names fail validation WITH the registry list
        let mut exp = ExperimentConfig::default();
        exp.predictor = "crystal_ball".to_string();
        let err = exp.validate().unwrap_err().to_string();
        assert!(err.contains("unknown predictor `crystal_ball`"), "{err}");
        assert!(err.contains("binned4"), "{err}");
        assert!(err.contains("llm_native"), "{err}");
        // degenerate quantiles are rejected
        for bad in [0.0, 1.0, -0.5, 1.5] {
            let mut exp = ExperimentConfig::default();
            exp.predictor_conservative_q = bad;
            assert!(exp.validate().is_err(), "conservative_q {bad} must fail");
        }
        // an inverted pair (conservative below balance) is rejected too:
        // it would flip the load_hi >= load dominance the memory-safety
        // checks rest on
        let mut exp = ExperimentConfig::default();
        exp.predictor_conservative_q = 0.4;
        exp.predictor_balance_q = 0.6;
        let err = exp.validate().unwrap_err().to_string();
        assert!(err.contains("must be >= predictor.balance_q"), "{err}");
        // the `none` builtin is the only no-prediction selection
        let mut exp = ExperimentConfig::default();
        assert!(exp.predictor_uses_prediction());
        exp.predictor = "None".to_string();
        assert!(!exp.predictor_uses_prediction());
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut exp = ExperimentConfig::default();
        exp.cluster.n_decode = 0;
        assert!(exp.validate().is_err());
        let mut exp = ExperimentConfig::default();
        exp.rescheduler.beta_decay = 1.5;
        assert!(exp.validate().is_err());
        let mut exp = ExperimentConfig::default();
        exp.dispatch_policy = "bogus".to_string();
        assert!(exp.validate().is_err());
        let mut exp = ExperimentConfig::default();
        exp.reschedule_policy = "bogus".to_string();
        assert!(exp.validate().is_err());
        // typoed knob prefixes are rejected, valid ones accepted
        let mut exp = ExperimentConfig::default();
        exp.policy_params
            .insert("slo_awre.mem_weight".to_string(), 2.0);
        assert!(exp.validate().is_err());
        // aliased knob prefixes are rejected too: policies read knobs by
        // exact canonical key, so an alias would be silently ignored
        let mut exp = ExperimentConfig::default();
        exp.policy_params
            .insert("mem_pressure.trigger_frac".to_string(), 0.9);
        assert!(exp.validate().is_err());
        let mut exp = ExperimentConfig::default();
        exp.policy_params
            .insert("memory_pressure.trigger_frac".to_string(), 0.9);
        exp.validate().unwrap();
    }

    #[test]
    fn policy_section_parses_names_and_knobs() {
        let cfg = Config::from_str(
            "[policy]\ndispatch = \"slo_aware\"\nreschedule = \"memory_pressure\"\n\
             [policy.slo_aware]\nmem_weight = 2.0\n\
             [policy.memory_pressure]\ntrigger_frac = 0.9\n",
        )
        .unwrap();
        let exp = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(exp.dispatch_policy, "slo_aware");
        assert_eq!(exp.reschedule_policy, "memory_pressure");
        assert_eq!(exp.policy_params.get("slo_aware.mem_weight"), Some(&2.0));
        assert_eq!(
            exp.policy_params.get("memory_pressure.trigger_frac"),
            Some(&0.9)
        );
        exp.validate().unwrap();
    }

    #[test]
    fn workload_tables_build_a_scenario() {
        use crate::workload::{ArrivalProcess, RequestClass};
        let cfg = Config::from_str(
            "[workload]\nscenario = \"bursty_mixed\"\n\
             [workload.arrival]\nkind = \"onoff\"\nrps_on = 2.0\nrps_off = 0.1\n\
             mean_on_s = 10\nmean_off_s = 30\n\
             [workload.class.chat]\nweight = 0.7\nslo_tpot_s = 0.030\n\
             [workload.class.reasoning]\nweight = 0.3\n\
             [workload.session]\nfrac = 0.4\nmax_turns = 5\n",
        )
        .unwrap();
        let exp = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(exp.scenario_name.as_deref(), Some("bursty_mixed"));
        let spec = exp.scenario.as_ref().expect("workload tables present");
        assert_eq!(
            spec.arrival,
            ArrivalProcess::OnOff {
                rps_on: 2.0,
                rps_off: 0.1,
                mean_on_s: 10.0,
                mean_off_s: 30.0,
            }
        );
        assert_eq!(spec.classes.specs().len(), 2);
        let chat = spec.classes.spec_of(RequestClass::Chat).unwrap();
        assert!((chat.weight - 0.7).abs() < 1e-12);
        assert!((chat.slo.tpot_s - 0.030).abs() < 1e-12);
        let sessions = spec.sessions.as_ref().unwrap();
        assert!((sessions.session_frac - 0.4).abs() < 1e-12);
        assert_eq!(sessions.max_turns, 5);
        exp.validate().unwrap();
    }

    #[test]
    fn workload_tables_absent_means_no_scenario() {
        let cfg = Config::from_str("[cluster]\nrps = 0.5\n").unwrap();
        let exp = ExperimentConfig::from_config(&cfg).unwrap();
        assert!(exp.scenario.is_none());
        assert!(exp.scenario_name.is_none());
    }

    #[test]
    fn bad_arrival_kind_is_rejected_with_names() {
        let cfg = Config::from_str("[workload.arrival]\nkind = \"lunar\"\n").unwrap();
        let err = ExperimentConfig::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("poisson|onoff|diurnal|replay"), "{err}");
    }

    #[test]
    fn unknown_class_table_is_rejected_with_names() {
        // typo
        let cfg = Config::from_str("[workload.class.reasonning]\nweight = 0.5\n").unwrap();
        let err = ExperimentConfig::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("unknown workload.class table `reasonning`"), "{err}");
        assert!(err.contains("chat|reasoning|summarization"), "{err}");
        // alias: RequestClass::parse accepts "summary", but the table
        // loop probes canonical names only — must error, not silently drop
        let cfg = Config::from_str("[workload.class.summary]\nweight = 0.5\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn degenerate_class_caps_error_instead_of_panicking() {
        for bad in ["cap = 0", "cap = -1", "in_cap = 0"] {
            let cfg =
                Config::from_str(&format!("[workload.class.chat]\n{bad}\n")).unwrap();
            let err = ExperimentConfig::from_config(&cfg).unwrap_err().to_string();
            assert!(err.contains("cap/in_cap"), "{bad}: {err}");
        }
        // out-of-band SLO / sigma values are caught by spec validation
        let cfg =
            Config::from_str("[workload.class.chat]\nslo_tpot_s = 0.0\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        let cfg =
            Config::from_str("[workload.class.chat]\nout_sigma = -1.0\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn elastic_table_and_scaling_key_parse_and_validate() {
        let cfg = Config::from_str(
            "[policy]\nscaling = \"predictive\"\n\
             [elastic]\nscale_interval_s = 2.5\nmin_decode = 2\nmax_total = 12\n",
        )
        .unwrap();
        let exp = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(exp.scaling_policy, "predictive");
        assert!((exp.elastic.scale_interval_s - 2.5).abs() < 1e-12);
        assert_eq!(exp.elastic.min_decode, 2);
        assert_eq!(exp.elastic.max_total, 12);
        exp.validate().unwrap();
        // defaults: static scaling, frozen totals
        let exp = ExperimentConfig::from_config(&Config::from_str("").unwrap()).unwrap();
        assert_eq!(exp.scaling_policy, "static");
        assert_eq!(exp.elastic.max_total, 0);
        // unknown scaling names and degenerate elastic values are rejected
        let mut exp = ExperimentConfig::default();
        exp.scaling_policy = "bogus".to_string();
        let err = exp.validate().unwrap_err().to_string();
        assert!(err.contains("unknown scaling policy"), "{err}");
        let mut exp = ExperimentConfig::default();
        exp.elastic.min_decode = 0;
        assert!(exp.validate().is_err());
        // negative counts are rejected at parse time, not wrapped by the
        // usize cast into absurd floors/caps
        for bad in [
            "[elastic]\nmin_decode = -1\n",
            "[elastic]\nmin_prefill = 0\n",
            "[elastic]\nmax_total = -1\n",
        ] {
            let cfg = Config::from_str(bad).unwrap();
            assert!(
                ExperimentConfig::from_config(&cfg).is_err(),
                "`{bad}` must be rejected"
            );
        }
        let mut exp = ExperimentConfig::default();
        exp.elastic.scale_interval_s = 0.0;
        assert!(exp.validate().is_err());
        // scaling-policy knobs pass the canonical-prefix check
        let mut exp = ExperimentConfig::default();
        exp.policy_params
            .insert("predictive.kv_hi".to_string(), 0.9);
        exp.validate().unwrap();
    }

    #[test]
    fn kvcache_table_parses_and_validates() {
        let cfg = Config::from_str(
            "[kvcache]\npolicy = \"predictive\"\nbudget_tokens = 50000\nttl_s = 30.0\n",
        )
        .unwrap();
        let exp = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(exp.kvcache.policy, "predictive");
        assert_eq!(exp.kvcache.budget_tokens, 50_000);
        assert!((exp.kvcache.ttl_s - 30.0).abs() < 1e-12);
        assert!(exp.kvcache.enabled());
        exp.validate().unwrap();
        // defaults: cache off, sane budget/TTL
        let exp = ExperimentConfig::from_config(&Config::from_str("").unwrap()).unwrap();
        assert_eq!(exp.kvcache.policy, "none");
        assert!(!exp.kvcache.enabled());
        exp.validate().unwrap();
        // the `off` alias counts as disabled too
        let mut exp = ExperimentConfig::default();
        exp.kvcache.policy = "off".to_string();
        assert!(!exp.kvcache.enabled());
        exp.validate().unwrap();
    }

    #[test]
    fn degenerate_kvcache_configs_are_rejected() {
        // zero/negative budgets fail at parse time, before the u64 cast
        for bad in [
            "[kvcache]\nbudget_tokens = 0\n",
            "[kvcache]\nbudget_tokens = -5\n",
        ] {
            let cfg = Config::from_str(bad).unwrap();
            let err = ExperimentConfig::from_config(&cfg).unwrap_err().to_string();
            assert!(err.contains("kvcache.budget_tokens"), "`{bad}`: {err}");
        }
        // unknown policy names fail validation WITH the registry list
        let mut exp = ExperimentConfig::default();
        exp.kvcache.policy = "bogus".to_string();
        let err = exp.validate().unwrap_err().to_string();
        assert!(err.contains("unknown cache policy `bogus`"), "{err}");
        assert!(err.contains("lru"), "{err}");
        assert!(err.contains("predictive"), "{err}");
        // a TTL shorter than one scheduler tick can never be enforced
        let mut exp = ExperimentConfig::default();
        exp.kvcache.policy = "ttl".to_string();
        exp.kvcache.ttl_s = 0.5;
        exp.rescheduler.interval_s = 1.0;
        let err = exp.validate().unwrap_err().to_string();
        assert!(err.contains("scheduler tick"), "{err}");
        // ...but with the cache off the same TTL is fine (inert subsystem
        // must not constrain unrelated knobs)
        let mut exp = ExperimentConfig::default();
        exp.kvcache.ttl_s = 0.5;
        exp.validate().unwrap();
        // zero budget on a hand-built enabled config is caught too
        let mut exp = ExperimentConfig::default();
        exp.kvcache.policy = "lru".to_string();
        exp.kvcache.budget_tokens = 0;
        assert!(exp.validate().is_err());
    }

    #[test]
    fn obs_table_parses_and_validates() {
        let cfg = Config::from_str(
            "[obs]\nenabled = true\nsample_every_s = 0.5\nring_capacity = 128\n\
             sample_rate = 0.25\n",
        )
        .unwrap();
        let exp = ExperimentConfig::from_config(&cfg).unwrap();
        assert!(exp.obs.enabled);
        assert!((exp.obs.sample_every_s - 0.5).abs() < 1e-12);
        assert_eq!(exp.obs.ring_capacity, 128);
        assert!((exp.obs.sample_rate - 0.25).abs() < 1e-12);
        exp.validate().unwrap();
        // defaults: off, 1 s cadence, sane ring
        let exp = ExperimentConfig::from_config(&Config::from_str("").unwrap()).unwrap();
        assert!(!exp.obs.enabled);
        assert!((exp.obs.sample_every_s - 1.0).abs() < 1e-12);
        assert_eq!(exp.obs.ring_capacity, 4096);
        exp.validate().unwrap();
    }

    #[test]
    fn degenerate_obs_configs_are_rejected() {
        // non-positive ring capacities fail at parse time, before the
        // usize cast could wrap them
        for bad in ["[obs]\nring_capacity = 0\n", "[obs]\nring_capacity = -4\n"] {
            let cfg = Config::from_str(bad).unwrap();
            let err = ExperimentConfig::from_config(&cfg).unwrap_err().to_string();
            assert!(err.contains("obs.ring_capacity"), "`{bad}`: {err}");
        }
        // degenerate cadence / rate fail validation
        let mut exp = ExperimentConfig::default();
        exp.obs.sample_every_s = 0.0;
        let err = exp.validate().unwrap_err().to_string();
        assert!(err.contains("obs.sample_every_s"), "{err}");
        let mut exp = ExperimentConfig::default();
        exp.obs.sample_every_s = -1.0;
        assert!(exp.validate().is_err());
        for bad in [-0.1, 1.1] {
            let mut exp = ExperimentConfig::default();
            exp.obs.sample_rate = bad;
            let err = exp.validate().unwrap_err().to_string();
            assert!(err.contains("obs.sample_rate"), "rate {bad}: {err}");
        }
    }

    #[test]
    fn faults_and_fleet_tables_parse_and_validate() {
        let cfg = Config::from_str(
            "[faults]\nmtbf_s = 300\nmttr_s = 20\nmax_failures = 3\n\
             script = \"30:0:15, 90:2:0\"\n\
             [fleet]\nspeed_mults = \"1.0, 0.5\"\nmem_mults = \"1.0, 2.0\"\n",
        )
        .unwrap();
        let exp = ExperimentConfig::from_config(&cfg).unwrap();
        let f = exp.faults.as_ref().expect("[faults] table present");
        assert!((f.mtbf_s - 300.0).abs() < 1e-12);
        assert!((f.mttr_s - 20.0).abs() < 1e-12);
        assert_eq!(f.max_failures, 3);
        assert_eq!(
            f.script,
            vec![
                FaultEvent { at: 30.0, instance: 0, down_s: 15.0 },
                FaultEvent { at: 90.0, instance: 2, down_s: 0.0 },
            ]
        );
        assert!(f.enabled());
        let fl = exp.fleet.as_ref().expect("[fleet] table present");
        assert_eq!(fl.profiles.len(), 2);
        assert!((fl.profile(1).speed_mult - 0.5).abs() < 1e-12);
        assert!((fl.profile(1).mem_mult - 2.0).abs() < 1e-12);
        exp.validate().unwrap();
        // absent tables stay None
        let exp = ExperimentConfig::from_config(&Config::from_str("").unwrap()).unwrap();
        assert!(exp.faults.is_none() && exp.fleet.is_none());
        // malformed script entries / degenerate values are rejected
        for bad in [
            "[faults]\nscript = \"30:0\"\n",
            "[faults]\nscript = \"x:0:5\"\n",
            "[faults]\nmax_failures = -1\n",
            "[faults]\nmtbf_s = 60\nmttr_s = 0\n",
            "[fleet]\nspeed_mults = \"1.0, nope\"\n",
            "[fleet]\nspeed_mults = \"0.0\"\n",
            "[fleet]\nmem_mults = \"\"\n",
        ] {
            let cfg = Config::from_str(bad).unwrap();
            assert!(
                ExperimentConfig::from_config(&cfg).is_err(),
                "`{bad}` must be rejected"
            );
        }
    }

    #[test]
    fn rescheduler_seed_constants_are_configurable() {
        let cfg = Config::from_str(
            "[rescheduler]\ninitial_avg_iter_s = 0.05\ndefault_remaining = 400\n",
        )
        .unwrap();
        let exp = ExperimentConfig::from_config(&cfg).unwrap();
        assert!((exp.rescheduler.initial_avg_iter_s - 0.05).abs() < 1e-12);
        assert!((exp.rescheduler.default_remaining - 400.0).abs() < 1e-12);
        // defaults documented in ReschedulerConfig
        let d = ReschedulerConfig::default();
        assert!((d.initial_avg_iter_s - 0.02).abs() < 1e-12);
        assert!((d.default_remaining - 1000.0).abs() < 1e-12);
    }
}
