//! Minimal TOML-subset parser. Line-oriented: sections, scalar keys,
//! flat arrays, `#` comments. Intentionally NOT full TOML (no nested
//! tables inline, no multiline strings, no dates) — the configs this
//! project needs are flat.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// A parsed scalar or flat array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    /// Best-effort scalar parse (used for CLI `--set` overrides).
    pub fn parse_scalar(s: &str) -> Value {
        let t = s.trim();
        if let Some(stripped) = t.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
            return Value::Str(stripped.to_string());
        }
        match t {
            "true" => return Value::Bool(true),
            "false" => return Value::Bool(false),
            _ => {}
        }
        if let Ok(i) = t.parse::<i64>() {
            return Value::Int(i);
        }
        if let Ok(f) = t.parse::<f64>() {
            return Value::Float(f);
        }
        Value::Str(t.to_string())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // a `#` inside a quoted string does not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(raw: &str, line_no: usize) -> Result<Value> {
    let t = raw.trim();
    if t.is_empty() {
        return Err(Error::config(format!("line {line_no}: empty value")));
    }
    if t.starts_with('[') {
        if !t.ends_with(']') {
            return Err(Error::config(format!("line {line_no}: unterminated array")));
        }
        let inner = &t[1..t.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(&part, line_no)?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Some(stripped) = t.strip_prefix('"') {
        let Some(body) = stripped.strip_suffix('"') else {
            return Err(Error::config(format!(
                "line {line_no}: unterminated string"
            )));
        };
        return Ok(Value::Str(body.replace("\\\"", "\"").replace("\\n", "\n")));
    }
    match t {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = t.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = t.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(Error::config(format!(
        "line {line_no}: cannot parse value `{t}` (bare strings must be quoted)"
    )))
}

/// Split an array body on commas that are not inside quotes.
fn split_top_level(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                parts.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

/// Parse TOML-subset text into a dotted-path map.
pub fn parse_toml(text: &str) -> Result<BTreeMap<String, Value>> {
    let mut map = BTreeMap::new();
    let mut section = String::new();
    for (idx, raw_line) in text.lines().enumerate() {
        let line_no = idx + 1;
        let line = strip_comment(raw_line).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(h) = line.strip_prefix('[') {
            let Some(name) = h.strip_suffix(']') else {
                return Err(Error::config(format!(
                    "line {line_no}: malformed section header"
                )));
            };
            let name = name.trim();
            if name.is_empty() {
                return Err(Error::config(format!("line {line_no}: empty section")));
            }
            section = name.to_string();
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            return Err(Error::config(format!(
                "line {line_no}: expected `key = value`, got `{line}`"
            )));
        };
        let key = k.trim();
        if key.is_empty() {
            return Err(Error::config(format!("line {line_no}: empty key")));
        }
        let path = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        map.insert(path, parse_value(v, line_no)?);
    }
    Ok(map)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        let m = parse_toml("a = 1\nb = 2.5\nc = \"hi\"\nd = true\n").unwrap();
        assert_eq!(m["a"], Value::Int(1));
        assert_eq!(m["b"], Value::Float(2.5));
        assert_eq!(m["c"], Value::Str("hi".into()));
        assert_eq!(m["d"], Value::Bool(true));
    }

    #[test]
    fn comments_and_sections() {
        let m = parse_toml("# top\n[s.t]\nx = 3 # trailing\ny = \"a # b\"\n").unwrap();
        assert_eq!(m["s.t.x"], Value::Int(3));
        assert_eq!(m["s.t.y"], Value::Str("a # b".into()));
    }

    #[test]
    fn arrays_mixed() {
        let m = parse_toml("xs = [1, 2.5, \"s\", true]\nempty = []\n").unwrap();
        match &m["xs"] {
            Value::Array(v) => {
                assert_eq!(v.len(), 4);
                assert_eq!(v[0], Value::Int(1));
                assert_eq!(v[3], Value::Bool(true));
            }
            _ => panic!(),
        }
        assert_eq!(m["empty"], Value::Array(vec![]));
    }

    #[test]
    fn errors_are_reported_with_line() {
        for bad in ["= 1", "[unterminated", "x = [1,2", "x = bare", "x ="] {
            let err = parse_toml(bad).unwrap_err();
            assert!(err.to_string().contains("line 1"), "{bad} -> {err}");
        }
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let m = parse_toml("a = -3\nb = 1e-4\nc = -2.5e2\n").unwrap();
        assert_eq!(m["a"], Value::Int(-3));
        assert_eq!(m["b"], Value::Float(1e-4));
        assert_eq!(m["c"], Value::Float(-250.0));
    }
}
