//! Shared experiment scenarios for the paper-reproduction benches: the
//! four evaluated systems (paper §6.1 baselines), the two cluster shapes,
//! and the **named workload-scenario registry** (`--scenario`,
//! [`ScenarioRegistry`]) that selects arrival process × class mix ×
//! session shape by string, mirroring `coordinator::PolicyRegistry`.

use std::collections::BTreeMap;

use crate::config::ExperimentConfig;
use crate::coordinator::PolicyRegistry;
use crate::costmodel::{DecodeCostModel, MigrationCostModel, PrefillCostModel};
use crate::sim::{SimParams, SimReport, Simulator};
use crate::workload::{
    ArrivalProcess, ClassMix, ClassSpec, Dataset, FaultConfig, FleetSpec, Request, ScenarioSpec,
    ScenarioTrace, SessionProfile, TraceGen,
};
use crate::{Error, Result};

/// One evaluated system from the paper's §6.1 baseline list. The
/// predictor is a `PredictorRegistry` name.
#[derive(Clone, Copy, Debug)]
pub struct Scenario {
    pub name: &'static str,
    pub rescheduling: bool,
    pub predictor: &'static str,
}

/// The paper's four systems, in presentation order.
pub fn paper_scenarios() -> Vec<Scenario> {
    vec![
        Scenario {
            name: "vLLM",
            rescheduling: false,
            predictor: "none",
        },
        Scenario {
            name: "STAR w/o pred",
            rescheduling: true,
            predictor: "none",
        },
        Scenario {
            name: "STAR w/ pred",
            rescheduling: true,
            predictor: "llm_native",
        },
        Scenario {
            name: "STAR Oracle",
            rescheduling: true,
            predictor: "oracle",
        },
    ]
}

/// Paper small cluster: 1 prefill + 3 decode RTX 4090D.
pub fn small_cluster(dataset: Dataset, rps: f64, seed: u64) -> ExperimentConfig {
    let mut exp = ExperimentConfig::default();
    exp.cluster.n_prefill = 1;
    exp.cluster.n_decode = 3;
    exp.cluster.dataset = dataset;
    exp.cluster.rps = rps;
    exp.cluster.seed = seed;
    exp.cluster.kv_capacity_tokens = 96_000;
    exp.cluster.max_batch = 48;
    exp.predictor_rel_err = llm_native_rel_err();
    exp
}

/// Paper large cluster: 2 prefill + 6 decode H800.
pub fn large_cluster(dataset: Dataset, rps: f64, seed: u64) -> ExperimentConfig {
    let mut exp = small_cluster(dataset, rps, seed);
    exp.cluster.n_prefill = 2;
    exp.cluster.n_decode = 6;
    exp.cluster.kv_capacity_tokens = 160_000;
    exp.cluster.max_batch = 64;
    exp
}

/// Simulator substrate for a cluster profile. Policies ride along in
/// `exp.dispatch_policy` / `exp.reschedule_policy` (registry names).
pub fn sim_params(exp: ExperimentConfig, h800: bool) -> SimParams {
    SimParams {
        exp,
        decode_cost: if h800 {
            DecodeCostModel::paper_h800()
        } else {
            DecodeCostModel::paper_4090d()
        },
        prefill_cost: PrefillCostModel::paper_4090d(),
        migration: MigrationCostModel::new_25gbps(128 * 1024),
        max_sim_time: 100_000.0,
        ..Default::default()
    }
}

/// Run one scenario over a trace.
pub fn run_scenario(
    scenario: Scenario,
    mut exp: ExperimentConfig,
    h800: bool,
    trace: &[Request],
) -> SimReport {
    exp.rescheduler.enabled = scenario.rescheduling;
    exp.predictor = scenario.predictor.to_string();
    Simulator::new(sim_params(exp, h800), trace).run()
}

/// Generate the standard trace for a cluster config.
pub fn trace_for(exp: &ExperimentConfig, n: usize) -> Vec<Request> {
    TraceGen::new(exp.cluster.dataset, exp.cluster.rps).generate(n, exp.cluster.seed)
}

/// Relative error of the simulated LLM-native predictor, calibrated from
/// the build-time evaluation when available (MAE / mean remaining length);
/// falls back to the paper-informed default 0.5.
pub fn llm_native_rel_err() -> f64 {
    let Ok(dir) = crate::runtime::artifacts_dir(None) else {
        return 0.5;
    };
    let Ok(text) = std::fs::read_to_string(dir.join("predictor_eval.tsv")) else {
        return 0.5;
    };
    let mut mae = None;
    let mut mean_len = None;
    for line in text.lines() {
        let f: Vec<&str> = line.split('\t').collect();
        if f.len() >= 5 && f[0] == "table1" && f[1] == "llm_native" {
            mae = f[4].parse::<f64>().ok();
        }
        if f.len() >= 3 && f[0] == "dataset" && f[1] == "output_len_mean" {
            mean_len = f[2].parse::<f64>().ok();
        }
    }
    match (mae, mean_len) {
        // mean *remaining* over a uniform sample of the trajectory is
        // roughly half the mean total length
        (Some(m), Some(l)) if l > 0.0 => (m / (l / 2.0)).clamp(0.05, 1.5),
        _ => 0.5,
    }
}

/// CI smoke mode (`ci.sh --smoke` exports `STAR_BENCH_SMOKE=1`): every
/// bench runs at drastically reduced scale (≤2k requests, ≤8 instances)
/// so the whole suite plus JSON validation finishes in minutes.
/// `STAR_BENCH_SMOKE=0` (or empty) means OFF, matching ci.sh's check —
/// an explicit opt-out must not silently produce smoke-scale numbers.
pub fn smoke() -> bool {
    matches!(std::env::var("STAR_BENCH_SMOKE"), Ok(v) if !v.is_empty() && v != "0")
}

/// Bench-size knob: `STAR_BENCH_SMOKE=1` shrinks run lengths ~10x (hard
/// cap 2k), `STAR_BENCH_FAST=1` ~5x.
pub fn scaled(n: usize) -> usize {
    if smoke() {
        (n / 10).clamp(20, 2_000)
    } else if std::env::var("STAR_BENCH_FAST").is_ok() {
        (n / 5).max(20)
    } else {
        n
    }
}

/// Run one paper-system scenario over a full workload-scenario trace
/// (sessions included) — the scenario-diversity counterpart of
/// [`run_scenario`].
pub fn run_scenario_trace(
    scenario: Scenario,
    mut exp: ExperimentConfig,
    h800: bool,
    trace: &ScenarioTrace,
) -> SimReport {
    exp.rescheduler.enabled = scenario.rescheduling;
    exp.predictor = scenario.predictor.to_string();
    Simulator::with_scenario(
        sim_params(exp, h800),
        trace.clone(),
        &PolicyRegistry::with_builtins(),
    )
    .expect("builtin policy construction")
    .run()
}

// ---------------------------------------------------------------------
// named workload scenarios

type ScenarioBuilder = fn(&ExperimentConfig) -> ScenarioSpec;

/// String-keyed registry of workload scenarios, mirroring
/// [`PolicyRegistry`]: benches, tests, and the CLI (`--scenario`) select
/// scenarios by name. Builders read the experiment's `cluster.rps` /
/// `cluster.dataset` so one name scales across cluster shapes.
pub struct ScenarioRegistry {
    builders: BTreeMap<String, ScenarioBuilder>,
}

impl ScenarioRegistry {
    /// Registry with the builtin scenario set.
    pub fn with_builtins() -> ScenarioRegistry {
        let mut r = ScenarioRegistry {
            builders: BTreeMap::new(),
        };
        r.register("stationary", build_stationary);
        r.register("bursty_mixed", build_bursty_mixed);
        r.register("diurnal_chat", build_diurnal_chat);
        r.register("multi_round", build_multi_round);
        r.register("degraded_fleet", build_degraded_fleet);
        r.register("mixed_gen", build_mixed_gen);
        r
    }

    pub fn register(&mut self, name: &str, builder: ScenarioBuilder) {
        self.builders.insert(name.to_string(), builder);
    }

    pub fn names(&self) -> Vec<String> {
        self.builders.keys().cloned().collect()
    }

    pub fn has(&self, name: &str) -> bool {
        self.builders.contains_key(name)
    }

    pub fn build(&self, name: &str, exp: &ExperimentConfig) -> Result<ScenarioSpec> {
        match self.builders.get(name) {
            Some(b) => {
                let mut spec = b(exp);
                spec.name = name.to_string();
                spec.validate()?;
                Ok(spec)
            }
            None => Err(Error::config(format!(
                "unknown scenario `{name}` (known: {})",
                self.names().join("|")
            ))),
        }
    }
}

/// Resolve an experiment's workload scenario: explicit `[workload.*]`
/// tables win, then a registry name (`--scenario` / `workload.scenario`),
/// else `None` (legacy stationary `TraceGen` synthesis).
pub fn resolve_scenario(exp: &ExperimentConfig) -> Result<Option<ScenarioSpec>> {
    if let Some(spec) = &exp.scenario {
        spec.validate()?;
        return Ok(Some(spec.clone()));
    }
    if let Some(name) = &exp.scenario_name {
        return ScenarioRegistry::with_builtins().build(name, exp).map(Some);
    }
    Ok(None)
}

fn build_stationary(exp: &ExperimentConfig) -> ScenarioSpec {
    ScenarioSpec::stationary(exp.cluster.dataset, exp.cluster.rps)
}

/// On/off bursts over the three-class production mix. Rates are chosen so
/// the long-run mean equals `cluster.rps`:
/// (2.5·rps·20 s + 0.25·rps·40 s) / 60 s = rps.
fn build_bursty_mixed(exp: &ExperimentConfig) -> ScenarioSpec {
    let rps = exp.cluster.rps;
    ScenarioSpec {
        name: "bursty_mixed".to_string(),
        arrival: ArrivalProcess::OnOff {
            rps_on: rps * 2.5,
            rps_off: rps * 0.25,
            mean_on_s: 20.0,
            mean_off_s: 40.0,
        },
        classes: ClassMix::mixed_default(),
        sessions: None,
        pico_scale: None,
        faults: None,
        fleet: None,
    }
}

/// Slow diurnal ramp (mean = `cluster.rps`) over a chat-heavy mix.
fn build_diurnal_chat(exp: &ExperimentConfig) -> ScenarioSpec {
    let rps = exp.cluster.rps;
    let mut chat = ClassSpec::chat();
    chat.weight = 0.8;
    let mut summ = ClassSpec::summarization();
    summ.weight = 0.2;
    ScenarioSpec {
        name: "diurnal_chat".to_string(),
        arrival: ArrivalProcess::Diurnal {
            base_rps: rps * 0.5,
            peak_rps: rps * 1.5,
            period_s: 600.0,
        },
        classes: ClassMix::new(vec![chat, summ]).expect("builtin mix"),
        sessions: None,
        pico_scale: None,
        faults: None,
        fleet: None,
    }
}

/// Multi-round conversations over the mixed classes: 60% of initial
/// requests open a 2–4 turn session whose later turns re-arrive with the
/// accumulated context (arXiv:2602.14516's setting).
fn build_multi_round(exp: &ExperimentConfig) -> ScenarioSpec {
    ScenarioSpec {
        name: "multi_round".to_string(),
        arrival: ArrivalProcess::Poisson {
            rps: exp.cluster.rps,
        },
        classes: ClassMix::mixed_default(),
        sessions: Some(SessionProfile {
            session_frac: 0.6,
            min_turns: 2,
            max_turns: 4,
            think_mean_s: 5.0,
            max_context_tokens: 32_768,
        }),
        pico_scale: None,
        faults: None,
        fleet: None,
    }
}

/// Reliability scenario: a heterogeneous fleet (one slow, one
/// small-memory class mixed into the baseline) under stochastic fault
/// injection — instances crash with a 10-minute MTBF and come back
/// ~45 s later. The soak gate runs this across seeds and asserts zero
/// lost requests.
fn build_degraded_fleet(exp: &ExperimentConfig) -> ScenarioSpec {
    ScenarioSpec {
        name: "degraded_fleet".to_string(),
        arrival: ArrivalProcess::Poisson {
            rps: exp.cluster.rps,
        },
        classes: ClassMix::mixed_default(),
        sessions: None,
        pico_scale: None,
        faults: Some(FaultConfig {
            mtbf_s: 600.0,
            mttr_s: 45.0,
            max_failures: 4,
            script: vec![],
        }),
        fleet: Some(FleetSpec::from_mults(&[1.0, 0.7, 1.0], &[1.0, 0.8, 1.2])),
    }
}

/// Two hardware generations side by side (last-gen at half speed but
/// double memory), no faults: exercises hardware-aware dispatch and
/// speed-normalized EWMAs in isolation.
fn build_mixed_gen(exp: &ExperimentConfig) -> ScenarioSpec {
    ScenarioSpec {
        name: "mixed_gen".to_string(),
        arrival: ArrivalProcess::Poisson {
            rps: exp.cluster.rps,
        },
        classes: ClassMix::mixed_default(),
        sessions: None,
        pico_scale: None,
        faults: None,
        fleet: Some(FleetSpec::from_mults(&[1.0, 0.5], &[1.0, 2.0])),
    }
}
