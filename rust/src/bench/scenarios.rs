//! Shared experiment scenarios for the paper-reproduction benches: the
//! four evaluated systems (paper §6.1 baselines) and the two cluster
//! shapes, so every bench runs the same definitions.

use crate::config::{ExperimentConfig, PredictorKind};
use crate::costmodel::{DecodeCostModel, MigrationCostModel, PrefillCostModel};
use crate::sim::{SimParams, SimReport, Simulator};
use crate::workload::{Dataset, Request, TraceGen};

/// One evaluated system from the paper's §6.1 baseline list.
#[derive(Clone, Copy, Debug)]
pub struct Scenario {
    pub name: &'static str,
    pub rescheduling: bool,
    pub predictor: PredictorKind,
}

/// The paper's four systems, in presentation order.
pub fn paper_scenarios() -> Vec<Scenario> {
    vec![
        Scenario {
            name: "vLLM",
            rescheduling: false,
            predictor: PredictorKind::None,
        },
        Scenario {
            name: "STAR w/o pred",
            rescheduling: true,
            predictor: PredictorKind::None,
        },
        Scenario {
            name: "STAR w/ pred",
            rescheduling: true,
            predictor: PredictorKind::LlmNative,
        },
        Scenario {
            name: "STAR Oracle",
            rescheduling: true,
            predictor: PredictorKind::Oracle,
        },
    ]
}

/// Paper small cluster: 1 prefill + 3 decode RTX 4090D.
pub fn small_cluster(dataset: Dataset, rps: f64, seed: u64) -> ExperimentConfig {
    let mut exp = ExperimentConfig::default();
    exp.cluster.n_prefill = 1;
    exp.cluster.n_decode = 3;
    exp.cluster.dataset = dataset;
    exp.cluster.rps = rps;
    exp.cluster.seed = seed;
    exp.cluster.kv_capacity_tokens = 96_000;
    exp.cluster.max_batch = 48;
    exp.predictor_rel_err = llm_native_rel_err();
    exp
}

/// Paper large cluster: 2 prefill + 6 decode H800.
pub fn large_cluster(dataset: Dataset, rps: f64, seed: u64) -> ExperimentConfig {
    let mut exp = small_cluster(dataset, rps, seed);
    exp.cluster.n_prefill = 2;
    exp.cluster.n_decode = 6;
    exp.cluster.kv_capacity_tokens = 160_000;
    exp.cluster.max_batch = 64;
    exp
}

/// Simulator substrate for a cluster profile. Policies ride along in
/// `exp.dispatch_policy` / `exp.reschedule_policy` (registry names).
pub fn sim_params(exp: ExperimentConfig, h800: bool) -> SimParams {
    SimParams {
        exp,
        decode_cost: if h800 {
            DecodeCostModel::paper_h800()
        } else {
            DecodeCostModel::paper_4090d()
        },
        prefill_cost: PrefillCostModel::paper_4090d(),
        migration: MigrationCostModel::new_25gbps(128 * 1024),
        max_sim_time: 100_000.0,
        ..Default::default()
    }
}

/// Run one scenario over a trace.
pub fn run_scenario(
    scenario: Scenario,
    mut exp: ExperimentConfig,
    h800: bool,
    trace: &[Request],
) -> SimReport {
    exp.rescheduler.enabled = scenario.rescheduling;
    exp.predictor = scenario.predictor;
    Simulator::new(sim_params(exp, h800), trace).run()
}

/// Generate the standard trace for a cluster config.
pub fn trace_for(exp: &ExperimentConfig, n: usize) -> Vec<Request> {
    TraceGen::new(exp.cluster.dataset, exp.cluster.rps).generate(n, exp.cluster.seed)
}

/// Relative error of the simulated LLM-native predictor, calibrated from
/// the build-time evaluation when available (MAE / mean remaining length);
/// falls back to the paper-informed default 0.5.
pub fn llm_native_rel_err() -> f64 {
    let Ok(dir) = crate::runtime::artifacts_dir(None) else {
        return 0.5;
    };
    let Ok(text) = std::fs::read_to_string(dir.join("predictor_eval.tsv")) else {
        return 0.5;
    };
    let mut mae = None;
    let mut mean_len = None;
    for line in text.lines() {
        let f: Vec<&str> = line.split('\t').collect();
        if f.len() >= 5 && f[0] == "table1" && f[1] == "llm_native" {
            mae = f[4].parse::<f64>().ok();
        }
        if f.len() >= 3 && f[0] == "dataset" && f[1] == "output_len_mean" {
            mean_len = f[2].parse::<f64>().ok();
        }
    }
    match (mae, mean_len) {
        // mean *remaining* over a uniform sample of the trajectory is
        // roughly half the mean total length
        (Some(m), Some(l)) if l > 0.0 => (m / (l / 2.0)).clamp(0.05, 1.5),
        _ => 0.5,
    }
}

/// Bench-size knob: `STAR_BENCH_FAST=1` shrinks run lengths ~5x.
pub fn scaled(n: usize) -> usize {
    if std::env::var("STAR_BENCH_FAST").is_ok() {
        (n / 5).max(20)
    } else {
        n
    }
}
