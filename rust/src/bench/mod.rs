//! Micro/macro benchmark harness (offline substitute for criterion).
//!
//! `benches/*.rs` are built with `harness = false` and use [`Bencher`] for
//! timed sections plus [`Table`] to print the paper's rows. Every bench
//! binary regenerates one paper table/figure (DESIGN.md §4).

pub mod json;
pub mod output;
pub mod scenarios;

use std::time::{Duration, Instant};

/// Summary statistics of one timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchStats {
    pub fn render(&self) -> String {
        format!(
            "{:<40} {:>8} iters  mean {:>10}  p50 {:>10}  p95 {:>10}",
            self.name,
            self.iters,
            fmt_duration(self.mean_s),
            fmt_duration(self.p50_s),
            fmt_duration(self.p95_s),
        )
    }
}

/// Human duration formatting (ns/us/ms/s).
pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Timed-section benchmark runner with warmup and adaptive iteration count.
pub struct Bencher {
    /// Target time to spend measuring each benchmark.
    pub budget: Duration,
    pub warmup: Duration,
    results: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            budget: Duration::from_secs(2),
            warmup: Duration::from_millis(200),
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new(budget: Duration) -> Self {
        Bencher {
            budget,
            ..Default::default()
        }
    }

    /// Time `f` repeatedly; returns and records the stats.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchStats {
        // warmup
        let w0 = Instant::now();
        let mut warm_iters = 0usize;
        while w0.elapsed() < self.warmup {
            f();
            warm_iters += 1;
        }
        // estimate per-iter cost from warmup to size the sample count
        let per_iter = (w0.elapsed().as_secs_f64() / warm_iters.max(1) as f64).max(1e-9);
        let iters = ((self.budget.as_secs_f64() / per_iter) as usize).clamp(5, 100_000);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = BenchStats {
            name: name.to_string(),
            iters,
            mean_s: samples.iter().sum::<f64>() / iters as f64,
            p50_s: samples[iters / 2],
            p95_s: samples[(iters as f64 * 0.95) as usize % iters],
            min_s: samples[0],
            max_s: *samples.last().unwrap(),
        };
        println!("{}", stats.render());
        self.results.push(stats.clone());
        stats
    }

    /// Time a single invocation of a long-running section (macro bench).
    pub fn bench_once<T, F: FnOnce() -> T>(&mut self, name: &str, f: F) -> (T, f64) {
        let t0 = Instant::now();
        let out = f();
        let dt = t0.elapsed().as_secs_f64();
        println!("{:<40} 1 run        {}", name, fmt_duration(dt));
        self.results.push(BenchStats {
            name: name.to_string(),
            iters: 1,
            mean_s: dt,
            p50_s: dt,
            p95_s: dt,
            min_s: dt,
            max_s: dt,
        });
        (out, dt)
    }

    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }
}

/// Aligned-column table printer for paper-style result tables.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    pub fn header(&self) -> &[String] {
        &self.header
    }

    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        let mut out = format!("\n== {} ==\n", self.title);
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}-|", "-".repeat(w + 2 - 1)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// TSV export (bench outputs are archived in EXPERIMENTS.md).
    pub fn to_tsv(&self) -> String {
        let mut s = self.header.join("\t");
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join("\t"));
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bencher {
            budget: Duration::from_millis(50),
            warmup: Duration::from_millis(5),
            results: vec![],
        };
        let s = b.bench("noop-ish", || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(s.iters >= 5);
        assert!(s.min_s <= s.p50_s && s.p50_s <= s.max_s);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "metric_name"]);
        t.row(&["1".into(), "x".into()]);
        t.row(&["22".into(), "yyyy".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        assert!(r.lines().count() >= 5);
        let tsv = t.to_tsv();
        assert_eq!(tsv.lines().count(), 3);
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(5e-10).contains("ns"));
        assert!(fmt_duration(5e-6).contains("us"));
        assert!(fmt_duration(5e-3).contains("ms"));
        assert!(fmt_duration(5.0).contains("s"));
    }
}
