//! Shared bench JSON writer: ALL `BENCH_*.json` emission goes through
//! [`BenchJson`], so the CI smoke gate can enforce one invariant — every
//! emitted file parses and carries `schema_version` (checked by
//! `star validate-bench`, see `super::json`).
//!
//! Output lands in the current directory (benches run from `rust/`), or
//! `$STAR_BENCH_DIR` when set.

use std::fmt::Write as _;
use std::path::PathBuf;

use super::Table;

/// Version of the shared bench-JSON envelope. Bump when the envelope
/// fields (`schema_version`/`bench`/`description`) change meaning.
pub const SCHEMA_VERSION: u64 = 1;

/// Builder for one bench's JSON output. Field order is preserved; the
/// envelope (`schema_version`, `bench`, `description`) is always first.
pub struct BenchJson {
    name: String,
    /// (key, pre-rendered JSON value)
    fields: Vec<(String, String)>,
}

impl BenchJson {
    pub fn new(name: &str, description: &str) -> BenchJson {
        let mut b = BenchJson {
            name: name.to_string(),
            fields: Vec::new(),
        };
        b.field_raw("schema_version", &SCHEMA_VERSION.to_string());
        b.field_str("bench", name);
        b.field_str("description", description);
        b
    }

    pub fn field_str(&mut self, key: &str, val: &str) -> &mut Self {
        let rendered = format!("\"{}\"", escape_json(val));
        self.fields.push((key.to_string(), rendered));
        self
    }

    pub fn field_num(&mut self, key: &str, val: f64) -> &mut Self {
        let rendered = if val.is_finite() {
            format!("{val}")
        } else {
            "null".to_string()
        };
        self.fields.push((key.to_string(), rendered));
        self
    }

    pub fn field_int(&mut self, key: &str, val: i64) -> &mut Self {
        self.fields.push((key.to_string(), val.to_string()));
        self
    }

    pub fn field_bool(&mut self, key: &str, val: bool) -> &mut Self {
        self.fields.push((key.to_string(), val.to_string()));
        self
    }

    /// Attach caller-rendered JSON (arrays / nested objects). The smoke
    /// gate re-parses the whole file, so malformed raw JSON fails CI.
    pub fn field_raw(&mut self, key: &str, raw_json: &str) -> &mut Self {
        self.fields.push((key.to_string(), raw_json.to_string()));
        self
    }

    /// Attach a printed [`Table`] as `{"title", "header", "rows"}` (rows
    /// are arrays of strings — bench tables mix numbers and annotations).
    pub fn table(&mut self, key: &str, t: &Table) -> &mut Self {
        let mut s = String::new();
        let _ = write!(s, "{{\"title\": \"{}\", \"header\": ", escape_json(&t.title));
        push_str_array(&mut s, t.header());
        s.push_str(", \"rows\": [");
        for (i, row) in t.rows().iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            push_str_array(&mut s, row);
        }
        s.push_str("]}");
        self.field_raw(key, &s)
    }

    pub fn render(&self) -> String {
        let mut out = String::from("{\n");
        for (i, (k, v)) in self.fields.iter().enumerate() {
            let _ = write!(out, "  \"{}\": {v}", escape_json(k));
            out.push_str(if i + 1 < self.fields.len() { ",\n" } else { "\n" });
        }
        out.push_str("}\n");
        out
    }

    /// Write `BENCH_<name>.json` into `$STAR_BENCH_DIR` (default: cwd).
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let dir = std::env::var("STAR_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
        let path = PathBuf::from(dir).join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.render())?;
        Ok(path)
    }

    /// Write and report, panicking on I/O failure (bench binaries have no
    /// error channel beyond their exit code).
    pub fn write_or_die(&self) {
        match self.write() {
            Ok(path) => println!("[{}] bench JSON -> {}", self.name, path.display()),
            Err(e) => panic!("write BENCH_{}.json: {e}", self.name),
        }
    }
}

/// Emit the envelope for a bench that cannot run in this environment
/// (e.g. artifacts not built): the smoke gate still sees a valid file.
pub fn write_skipped(name: &str, reason: &str) {
    let mut b = BenchJson::new(name, reason);
    b.field_bool("skipped", true);
    b.write_or_die();
}

fn push_str_array(out: &mut String, cells: &[String]) {
    out.push('[');
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        let _ = write!(out, "\"{}\"", escape_json(c));
    }
    out.push(']');
}

/// JSON string escaping (quotes, backslashes, control characters).
pub fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::json::{validate_bench_json, Json};
    use super::*;

    #[test]
    fn rendered_output_passes_the_smoke_invariant() {
        let mut b = BenchJson::new("unit_test", "writer \"self\"-test\nline2");
        b.field_num("value", 1.5)
            .field_num("nan_becomes_null", f64::NAN)
            .field_int("count", -3)
            .field_bool("flag", true)
            .field_raw("nested", "{\"a\": [1, 2]}");
        let mut t = Table::new("demo", &["col a", "col\"b"]);
        t.row(&["1".into(), "x\ty".into()]);
        b.table("table", &t);
        let text = b.render();
        validate_bench_json(&text).expect("smoke invariant");
        let v = super::super::json::parse(&text).unwrap();
        assert_eq!(v.get("bench"), Some(&Json::Str("unit_test".to_string())));
        assert_eq!(v.get("schema_version"), Some(&Json::Num(1.0)));
        assert_eq!(v.get("nan_becomes_null"), Some(&Json::Null));
        assert_eq!(v.get("count"), Some(&Json::Num(-3.0)));
        let table = v.get("table").unwrap();
        assert_eq!(table.get("title"), Some(&Json::Str("demo".to_string())));
        match table.get("rows") {
            Some(Json::Arr(rows)) => assert_eq!(rows.len(), 1),
            other => panic!("rows missing: {other:?}"),
        }
    }

    #[test]
    fn writes_to_bench_dir_and_skipped_envelope_is_valid() {
        // one test (not two) because STAR_BENCH_DIR is process-global and
        // the default harness runs tests concurrently
        let dir = std::env::temp_dir().join("star_bench_out_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::env::set_var("STAR_BENCH_DIR", &dir);
        let mut b = BenchJson::new("dir_test", "d");
        b.field_int("x", 1);
        let path = b.write().unwrap();
        write_skipped("skip_test", "artifacts not built");
        std::env::remove_var("STAR_BENCH_DIR");
        assert!(path.starts_with(&dir));
        let text = std::fs::read_to_string(&path).unwrap();
        validate_bench_json(&text).unwrap();
        let skip_text = std::fs::read_to_string(dir.join("BENCH_skip_test.json")).unwrap();
        validate_bench_json(&skip_text).unwrap();
        assert!(skip_text.contains("\"skipped\": true"));
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(dir.join("BENCH_skip_test.json")).ok();
    }
}
