//! Minimal JSON parser (offline substitute for serde_json) — just enough
//! to let the CI smoke gate (`star validate-bench`, `ci.sh --smoke`)
//! assert that every emitted `BENCH_*.json` parses and carries the shared
//! writer's `schema_version` field. Not a general-purpose library: no
//! streaming, numbers collapse to `f64`.

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
}

/// Parse a complete JSON document (trailing whitespace only).
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(format!("trailing content at byte {}", p.pos));
    }
    Ok(v)
}

/// The smoke-gate invariant: a JSON object with a numeric `schema_version`
/// and a string `bench` name — what [`super::output::BenchJson`] always
/// emits.
pub fn validate_bench_json(text: &str) -> Result<(), String> {
    let v = parse(text)?;
    let Json::Obj(_) = v else {
        return Err("top level is not an object".to_string());
    };
    match v.get("schema_version") {
        Some(Json::Num(_)) => {}
        Some(_) => return Err("schema_version is not a number".to_string()),
        None => return Err("missing schema_version field".to_string()),
    }
    match v.get("bench") {
        Some(Json::Str(_)) => Ok(()),
        Some(_) => Err("bench is not a string".to_string()),
        None => Err("missing bench field".to_string()),
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected `{}` at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected `,` or `]` at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected `,` or `}}` at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self
                        .peek()
                        .ok_or_else(|| "unterminated escape".to_string())?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err("truncated \\u escape".to_string());
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| "non-utf8 \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape `{hex}`"))?;
                            self.pos += 4;
                            // surrogate pairs are not reassembled; the
                            // replacement char is fine for validation
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => return Err(format!("bad escape `\\{}`", other as char)),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar (multi-byte safe)
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| "non-utf8 string content".to_string())?;
                    let ch = s.chars().next().expect("non-empty");
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-' {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| "non-utf8 number".to_string())?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number `{s}` at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_document() {
        let v = parse(r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "e": "x\n\"y\""}"#)
            .unwrap();
        assert_eq!(
            v.get("a"),
            Some(&Json::Arr(vec![
                Json::Num(1.0),
                Json::Num(2.5),
                Json::Num(-300.0)
            ]))
        );
        assert_eq!(v.get("b").unwrap().get("d"), Some(&Json::Null));
        assert_eq!(v.get("e"), Some(&Json::Str("x\n\"y\"".to_string())));
    }

    #[test]
    fn rejects_malformed_documents() {
        assert!(parse("{").is_err());
        assert!(parse("{\"a\": }").is_err());
        assert!(parse("[1, 2,]").is_err());
        assert!(parse("{} trailing").is_err());
        assert!(parse("{\"a\": 1e}").is_err());
    }

    #[test]
    fn bench_validation_requires_schema_version() {
        assert!(validate_bench_json(r#"{"schema_version": 1, "bench": "x"}"#).is_ok());
        assert!(validate_bench_json(r#"{"bench": "x"}"#).is_err());
        assert!(validate_bench_json(r#"{"schema_version": "1", "bench": "x"}"#).is_err());
        assert!(validate_bench_json(r#"{"schema_version": 1}"#).is_err());
        assert!(validate_bench_json("[1, 2]").is_err());
        assert!(validate_bench_json("not json").is_err());
    }

    #[test]
    fn unicode_escapes_are_tolerated() {
        let v = parse(r#"{"schema_version": 1, "bench": "Aé"}"#).unwrap();
        assert_eq!(v.get("bench"), Some(&Json::Str("Aé".to_string())));
    }
}
