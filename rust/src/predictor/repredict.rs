//! The shared reprediction engine (paper §5.3): ONE due-slot scan +
//! batch-cost accounting used by both drivers.
//!
//! Before this module, `sim::engine` and `serve::instance` each carried
//! their own copy of the "re-predict every k decode iterations" plumbing
//! — an inline counter compare in three places, with the batched-cost
//! arithmetic duplicated and free to drift. [`Repredictor`] owns the
//! schedule once: a request re-predicts every `every_iters` iterations,
//! due slots are batched into a single predictor call, and that batch's
//! latency is charged to the decode iteration it runs in.

use super::LengthPredictor;

/// The reprediction schedule shared by the simulator and the live decode
/// instance threads.
#[derive(Clone, Copy, Debug)]
pub struct Repredictor {
    every_iters: u32,
}

impl Repredictor {
    /// `every_iters` is clamped to ≥ 1 (the paper's k; k=20 default).
    pub fn new(every_iters: u32) -> Repredictor {
        Repredictor {
            every_iters: every_iters.max(1),
        }
    }

    pub fn every_iters(&self) -> u32 {
        self.every_iters
    }

    /// Is a slot whose per-request counter has just been incremented due
    /// for reprediction now? (The caller resets the counter to 0 after
    /// applying the new estimate.)
    #[inline]
    pub fn is_due(&self, iters_since_predict: u32) -> bool {
        iters_since_predict >= self.every_iters
    }

    /// Will this slot be due once the upcoming iteration's increment
    /// lands? The pre-step scan: the batched prediction's latency must be
    /// charged to the iteration it runs in (§5.3), so the simulator counts
    /// due slots *before* stepping.
    #[inline]
    pub fn due_next(&self, iters_since_predict: u32) -> bool {
        self.is_due(iters_since_predict.saturating_add(1))
    }

    /// The batched due-slot scan: keep the keys whose counters are due.
    /// Both drivers run their slot tables through this one function.
    pub fn due_slots<T>(&self, slots: impl Iterator<Item = (T, u32)>) -> Vec<T> {
        slots
            .filter(|(_, c)| self.is_due(*c))
            .map(|(t, _)| t)
            .collect()
    }

    /// Latency cost of one reprediction batch of `due` slots, seconds —
    /// zero when nothing is due (no batch is launched).
    pub fn batch_cost_s(&self, predictor: &dyn LengthPredictor, due: usize) -> f64 {
        if due == 0 {
            0.0
        } else {
            predictor.cost_s(due)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::NoisyOracle;
    use super::*;

    #[test]
    fn schedule_is_every_k_iters() {
        let r = Repredictor::new(20);
        assert!(!r.is_due(19));
        assert!(r.is_due(20));
        assert!(r.is_due(21));
        assert!(r.due_next(19), "due once the increment lands");
        assert!(!r.due_next(18));
        assert_eq!(r.every_iters(), 20);
    }

    #[test]
    fn zero_interval_clamps_to_one() {
        let r = Repredictor::new(0);
        assert_eq!(r.every_iters(), 1);
        assert!(r.is_due(1));
        assert!(!r.is_due(0));
    }

    #[test]
    fn scan_keeps_due_keys_in_order() {
        let r = Repredictor::new(5);
        let counters = vec![(0usize, 4u32), (1, 5), (2, 0), (3, 7)];
        assert_eq!(r.due_slots(counters.into_iter()), vec![1, 3]);
    }

    #[test]
    fn batch_cost_is_zero_when_empty() {
        let r = Repredictor::new(20);
        let p = NoisyOracle::new(0.3, 1);
        assert_eq!(r.batch_cost_s(&p, 0), 0.0);
        let one = r.batch_cost_s(&p, 1);
        let ten = r.batch_cost_s(&p, 10);
        assert!(one > 0.0 && ten > one, "batched cost grows with batch");
    }
}
