//! String-keyed predictor construction, mirroring
//! `coordinator::PolicyRegistry`: the single place where predictor names
//! meet predictor types. Config files (`[predictor] kind = "..."`), the
//! CLI (`--predictor`), benches, and tests all go through
//! [`PredictorRegistry::build`]; third-party code extends the set with
//! [`PredictorRegistry::register`] without touching predictor internals
//! (`Simulator::with_registries` accepts a custom registry).

use std::collections::BTreeMap;

use super::{BinnedOracle, DebiasedPredictor, LengthPredictor, NoPredictor, NoisyOracle, OraclePredictor};
use crate::{Error, Result};

/// Everything a predictor builder may draw on. One context type keeps the
/// registry signature stable as predictors grow knobs.
#[derive(Clone, Copy, Debug)]
pub struct PredictorContext {
    /// Output-length cap the trace implies (scales the paper's bin
    /// boundaries, expressed as fractions of the cap).
    pub cap: f64,
    /// Relative error of the simulated LLM-native predictor
    /// (`predictor.rel_err`).
    pub rel_err: f64,
    /// Noise seed (derived from the experiment seed by the drivers).
    pub seed: u64,
}

impl Default for PredictorContext {
    fn default() -> Self {
        PredictorContext {
            cap: 32_768.0,
            rel_err: 0.25,
            seed: 0,
        }
    }
}

type PredictorBuilder =
    Box<dyn Fn(&PredictorContext) -> Result<Box<dyn LengthPredictor>> + Send + Sync>;

/// Registry of named predictor builders. Names are normalized (lowercase,
/// `-` → `_`) and may be aliased, so `--predictor 4bin`, `4-bin`, and
/// `binned4` all resolve to the same builder.
#[derive(Default)]
pub struct PredictorRegistry {
    builders: BTreeMap<String, PredictorBuilder>,
    aliases: BTreeMap<String, String>,
}

/// Name normalization shared with lookups (lowercase, `-` → `_`).
pub fn normalize(name: &str) -> String {
    name.to_ascii_lowercase().replace('-', "_")
}

impl PredictorRegistry {
    /// An empty registry (for fully custom predictor sets).
    pub fn new() -> PredictorRegistry {
        PredictorRegistry::default()
    }

    /// The built-in predictor set: `none`, `oracle`, `binned2` (`2bin`),
    /// `binned4` (`4bin`), `binned6` (`6bin`), `llm_native` (`native`),
    /// and `debiased` (llm-native + online per-bucket bias correction).
    pub fn with_builtins() -> PredictorRegistry {
        let mut r = PredictorRegistry::new();
        r.register("none", |_| Ok(Box::new(NoPredictor)));
        r.register("oracle", |_| Ok(Box::new(OraclePredictor)));
        for n in [2u8, 4, 6] {
            r.register(&format!("binned{n}"), move |ctx| {
                Ok(Box::new(BinnedOracle::paper_bins(n, ctx.cap)))
            });
        }
        r.register("llm_native", |ctx| {
            Ok(Box::new(NoisyOracle::new(ctx.rel_err, ctx.seed)))
        });
        r.register("debiased", |ctx| {
            Ok(Box::new(DebiasedPredictor::new(ctx.rel_err, ctx.seed)))
        });
        for (alias, canon) in [
            ("2bin", "binned2"),
            ("4bin", "binned4"),
            ("6bin", "binned6"),
            // hyphenated spellings normalize to `N_bin`, so that form
            // needs its own alias entry (normalize() runs on lookups AND
            // on alias keys, but "4-bin" → "4_bin" ≠ "4bin")
            ("2_bin", "binned2"),
            ("4_bin", "binned4"),
            ("6_bin", "binned6"),
        ] {
            r.alias(alias, canon);
        }
        r.alias("native", "llm_native");
        r
    }

    /// Register (or replace) a predictor builder under `name`.
    pub fn register<F>(&mut self, name: &str, builder: F)
    where
        F: Fn(&PredictorContext) -> Result<Box<dyn LengthPredictor>> + Send + Sync + 'static,
    {
        self.builders.insert(normalize(name), Box::new(builder));
    }

    /// Make `alias` resolve to `canonical`. A direct registration under an
    /// alias-colliding name wins over the alias (same rule as the policy
    /// registry).
    pub fn alias(&mut self, alias: &str, canonical: &str) {
        self.aliases.insert(normalize(alias), normalize(canonical));
    }

    fn lookup(&self, name: &str) -> Option<&PredictorBuilder> {
        let n = normalize(name);
        if let Some(b) = self.builders.get(&n) {
            return Some(b);
        }
        self.aliases.get(&n).and_then(|canon| self.builders.get(canon))
    }

    pub fn has(&self, name: &str) -> bool {
        self.lookup(name).is_some()
    }

    /// Construct the named predictor; unknown names error with the
    /// registered canonical list.
    pub fn build(&self, name: &str, ctx: &PredictorContext) -> Result<Box<dyn LengthPredictor>> {
        match self.lookup(name) {
            Some(b) => b(ctx),
            None => Err(Error::config(format!(
                "unknown predictor `{name}` (known: {})",
                self.names().join("|")
            ))),
        }
    }

    /// Registered canonical predictor names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.builders.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{PredictInput, Prediction};
    use super::*;

    fn ctx() -> PredictorContext {
        PredictorContext {
            cap: 32_768.0,
            rel_err: 0.3,
            seed: 7,
        }
    }

    fn input(rem: u32) -> PredictInput {
        PredictInput {
            id: 1,
            generated: 0,
            true_remaining: Some(rem),
        }
    }

    #[test]
    fn builds_every_builtin_by_canonical_name_and_alias() {
        let reg = PredictorRegistry::with_builtins();
        for name in [
            "none", "oracle", "binned2", "binned4", "binned6", "llm_native", "debiased",
            // aliases + normalization
            "2bin", "4-bin", "6bin", "native", "LLM-Native", "Oracle",
        ] {
            let mut p = reg.build(name, &ctx()).unwrap_or_else(|e| {
                panic!("builtin `{name}` must build: {e}")
            });
            let _ = p.predict(&input(1000));
        }
    }

    #[test]
    fn display_names_are_registry_keys() {
        // the satellite invariant: what a predictor calls itself is the
        // key that builds it (no `llm_native(sim,σ=…)` leaking into bench
        // JSON / CLI output)
        let reg = PredictorRegistry::with_builtins();
        for name in reg.names() {
            let p = reg.build(&name, &ctx()).unwrap();
            assert_eq!(p.name(), name, "display name must be the registry key");
            assert!(
                p.name().is_ascii(),
                "predictor names must be plain ASCII: {}",
                p.name()
            );
        }
    }

    #[test]
    fn every_builtin_is_registered() {
        // new builtins cannot silently miss registration: this list is
        // asserted verbatim (and `star list` prints the same registry,
        // covered in tests/cli_errors.rs)
        let reg = PredictorRegistry::with_builtins();
        assert_eq!(
            reg.names(),
            vec![
                "binned2",
                "binned4",
                "binned6",
                "debiased",
                "llm_native",
                "none",
                "oracle",
            ]
        );
    }

    #[test]
    fn unknown_names_error_with_known_list() {
        let reg = PredictorRegistry::with_builtins();
        let e = reg.build("magic8ball", &ctx()).unwrap_err().to_string();
        assert!(e.contains("unknown predictor `magic8ball`"), "{e}");
        assert!(e.contains("binned4"), "{e}");
        assert!(e.contains("llm_native"), "{e}");
        assert!(!reg.has("magic8ball"));
        assert!(reg.has("debiased"));
    }

    #[test]
    fn third_party_registration_and_override() {
        let mut reg = PredictorRegistry::with_builtins();
        struct Fixed(f64);
        impl LengthPredictor for Fixed {
            fn predict(&mut self, _i: &PredictInput) -> Option<Prediction> {
                Some(Prediction::exact(self.0))
            }
            fn name(&self) -> String {
                "fixed".into()
            }
        }
        reg.register("fixed", |_| Ok(Box::new(Fixed(77.0))));
        let mut p = reg.build("fixed", &ctx()).unwrap();
        assert_eq!(p.predict(&input(1)).unwrap().mean, 77.0);
        // direct registration under an alias-colliding name shadows it
        reg.register("2bin", |_| Ok(Box::new(Fixed(1.0))));
        let mut p = reg.build("2bin", &ctx()).unwrap();
        assert_eq!(p.predict(&input(1)).unwrap().mean, 1.0);
    }
}
