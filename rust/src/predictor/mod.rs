//! The prediction subsystem (paper §4 + §6 ablations), first-class and
//! pluggable — the estimates that drive every rescheduling decision.
//!
//! Four layers, mirroring the policy architecture (DESIGN.md §12):
//!
//! * **registry** ([`PredictorRegistry`]) — string-keyed construction of
//!   [`LengthPredictor`]s (`none|oracle|binned2|binned4|binned6|
//!   llm_native|debiased`), selected via config `[predictor]` / CLI
//!   `--predictor`, printed by `star list`;
//! * **signal** ([`Prediction`]) — uncertainty-aware estimates
//!   `{mean, sigma, quantile(q), issued_at_iter}` carried through
//!   `ClusterState`/`ClusterView`: OOM-avoidance checks consume a
//!   conservative quantile, balancing objectives the mean;
//! * **calibration** ([`Scorecard`]) — per-progress-bucket signed error +
//!   MAE accumulated at request completion, reported in
//!   `SimReport`/`ServeOutcome` and fed back to the [`DebiasedPredictor`];
//! * **reprediction** ([`Repredictor`]) — the ONE batched due-slot scan +
//!   cost accounting shared by `sim::engine` and `serve::instance`.
//!
//! The live serving path uses the trained LLM-native MLP executed through
//! PJRT (see `crate::runtime`); the simulator uses [`OraclePredictor`] /
//! [`BinnedOracle`] / [`NoisyOracle`] exactly as the paper's large-scale
//! simulator does ("we leverage the actual remaining generation lengths
//! to simulate an oracle predictor", §6.3).

mod registry;
mod repredict;
mod scorecard;
mod signal;

pub use registry::{PredictorContext, PredictorRegistry};
pub use repredict::Repredictor;
pub use scorecard::{BucketStats, PredSample, Scorecard, PROGRESS_BUCKETS};
pub use signal::{normal_quantile, Prediction};

use crate::prng::Pcg64;
use crate::RequestId;

/// Inputs available when predicting for one request.
#[derive(Clone, Copy, Debug)]
pub struct PredictInput {
    pub id: RequestId,
    /// Tokens generated so far.
    pub generated: u32,
    /// Ground truth remaining (simulator only; None on the live path).
    pub true_remaining: Option<u32>,
}

/// A remaining-generation-length predictor (token units).
pub trait LengthPredictor: Send {
    /// Estimate remaining output length; None = no estimate available.
    fn predict(&mut self, input: &PredictInput) -> Option<Prediction>;

    /// Registry key this predictor answers to (diagnostics, bench JSON,
    /// CLI output — plain ASCII, no parameter decorations).
    fn name(&self) -> String;

    /// Latency cost of one prediction batch of size `batch` in seconds
    /// (added to the decode iteration it runs in — paper §5.3).
    fn cost_s(&self, batch: usize) -> f64 {
        // LLM-native measured: 1.33 ms @ b=1, 2.4 ms @ b=10 (Table 1),
        // scaled to our pico model (~30x smaller d): dominated by launch.
        40e-6 + 4e-6 * batch as f64
    }

    /// Completion feedback: the request's realized output length plus the
    /// prediction log the driver kept for it. Online-calibrating
    /// predictors (the `debiased` builtin) learn from this; everything
    /// else ignores it.
    fn observe_completion(&mut self, _output_len: u32, _samples: &[PredSample]) {}
}

/// "STAR w/o prediction": no estimates.
pub struct NoPredictor;

impl LengthPredictor for NoPredictor {
    fn predict(&mut self, _input: &PredictInput) -> Option<Prediction> {
        None
    }
    fn name(&self) -> String {
        "none".into()
    }
    fn cost_s(&self, _batch: usize) -> f64 {
        0.0
    }
}

/// Exact remaining lengths ("STAR Oracle"): zero-spread predictions.
pub struct OraclePredictor;

impl LengthPredictor for OraclePredictor {
    fn predict(&mut self, input: &PredictInput) -> Option<Prediction> {
        input
            .true_remaining
            .map(|r| Prediction::new(r as f64, 0.0, input.generated as u64))
    }
    fn name(&self) -> String {
        "oracle".into()
    }
    fn cost_s(&self, _batch: usize) -> f64 {
        0.0
    }
}

/// Oracle quantized to the paper's non-uniform bins (Table 3). Bins are
/// expressed as fractions of the output cap so they work at both scales;
/// at paper scale (cap = 32K) they reproduce the published boundaries:
///   2-bin: [0, 8K), [8K, 32K]
///   4-bin: [0, 4K), [4K, 8K), [8K, 16K), [16K, 32K]
///   6-bin: [0, 2K), [2K, 4K), [4K, 6K), [6K, 8K), [8K, 16K), [16K, 32K]
pub struct BinnedOracle {
    /// Ascending bin upper bounds as fractions of `cap` (last = 1.0).
    pub bounds: Vec<f64>,
    pub cap: f64,
}

impl BinnedOracle {
    pub fn paper_bins(n: u8, cap: f64) -> BinnedOracle {
        let bounds: Vec<f64> = match n {
            2 => vec![0.25, 1.0],
            4 => vec![0.125, 0.25, 0.5, 1.0],
            6 => vec![1.0 / 16.0, 2.0 / 16.0, 3.0 / 16.0, 0.25, 0.5, 1.0],
            other => {
                // uniform fallback for unusual bin counts
                (1..=other).map(|i| i as f64 / other as f64).collect()
            }
        };
        BinnedOracle { bounds, cap }
    }

    /// The bin containing `remaining`, as `(midpoint, width)` in tokens.
    /// Simple ascending scan over half-open bins `[lo, hi)` with the last
    /// bin closed at the cap: a value exactly on an interior boundary
    /// belongs to the bin it OPENS, `remaining >= cap` lands in the last
    /// bin (never a bare `cap` passthrough).
    fn quantize(&self, remaining: f64) -> (f64, f64) {
        let frac = (remaining / self.cap).clamp(0.0, 1.0);
        let mut lo = 0.0;
        for &hi in &self.bounds {
            if frac < hi {
                return ((lo + hi) / 2.0 * self.cap, (hi - lo) * self.cap);
            }
            lo = hi;
        }
        // frac sits on the top bound (clamp caps it at 1.0): closed last bin
        let hi = self.bounds.last().copied().unwrap_or(1.0);
        let lo = if self.bounds.len() >= 2 {
            self.bounds[self.bounds.len() - 2]
        } else {
            0.0
        };
        ((lo + hi) / 2.0 * self.cap, (hi - lo) * self.cap)
    }
}

impl LengthPredictor for BinnedOracle {
    fn predict(&mut self, input: &PredictInput) -> Option<Prediction> {
        input.true_remaining.map(|r| {
            let (mid, width) = self.quantize(r as f64);
            // a bin collapses everything inside it to the midpoint: model
            // the spread as uniform over the bin (σ = width / √12)
            Prediction::new(mid, width / 12f64.sqrt(), input.generated as u64)
        })
    }
    fn name(&self) -> String {
        format!("binned{}", self.bounds.len())
    }
    fn cost_s(&self, _batch: usize) -> f64 {
        0.0
    }
}

/// Oracle + multiplicative log-normal noise — the simulator's stand-in for
/// the trained LLM-native predictor. `rel_err` is calibrated from the
/// measured eval (artifacts/predictor_eval.tsv: MAE / mean remaining), and
/// the error shrinks as generation progresses, matching the Fig. 7 curve
/// (continuous prediction gets more context).
pub struct NoisyOracle {
    pub rel_err: f64,
    /// Error multiplier at progress 1.0 relative to progress 0.0.
    pub late_factor: f64,
    /// Typical total output length used to gauge progress.
    pub progress_scale: f64,
    rng: Pcg64,
}

impl NoisyOracle {
    pub fn new(rel_err: f64, seed: u64) -> NoisyOracle {
        NoisyOracle {
            rel_err,
            late_factor: 0.35,
            progress_scale: 2_000.0,
            rng: Pcg64::new(seed, 0x505245444e), // "PREDN"
        }
    }
}

impl LengthPredictor for NoisyOracle {
    fn predict(&mut self, input: &PredictInput) -> Option<Prediction> {
        let rem = input.true_remaining? as f64;
        let progress = (input.generated as f64 / self.progress_scale).min(1.0);
        let sigma_rel = self.rel_err * (1.0 - (1.0 - self.late_factor) * progress);
        let noise = self.rng.normal(0.0, sigma_rel);
        let mean = (rem * noise.exp()).max(0.0);
        // first-order spread of the log-normal estimate: σ ≈ mean · σ_rel
        Some(Prediction::new(
            mean,
            mean * sigma_rel,
            input.generated as u64,
        ))
    }
    fn name(&self) -> String {
        "llm_native".into()
    }
}

/// LLM-native (simulated) + online bias correction: subtracts the
/// per-progress-bucket mean signed error learned from completed requests
/// ([`LengthPredictor::observe_completion`] feedback, the same samples the
/// run's [`Scorecard`] accumulates). The log-normal noise model genuinely
/// over-predicts on average (E[e^N(0,σ)] = e^{σ²/2} > 1), so there is a
/// real bias to remove.
pub struct DebiasedPredictor {
    inner: NoisyOracle,
    /// Learned mean residual error per progress bucket (stochastic
    /// approximation: bias += α · residual).
    bias: [f64; PROGRESS_BUCKETS],
    n: [u64; PROGRESS_BUCKETS],
}

impl DebiasedPredictor {
    pub fn new(rel_err: f64, seed: u64) -> DebiasedPredictor {
        DebiasedPredictor {
            inner: NoisyOracle::new(rel_err, seed),
            bias: [0.0; PROGRESS_BUCKETS],
            n: [0; PROGRESS_BUCKETS],
        }
    }

    /// Learned per-bucket corrections (diagnostics / tests).
    pub fn bias_estimates(&self) -> [f64; PROGRESS_BUCKETS] {
        self.bias
    }
}

impl LengthPredictor for DebiasedPredictor {
    fn predict(&mut self, input: &PredictInput) -> Option<Prediction> {
        let raw = self.inner.predict(input)?;
        // progress at prediction time is only *estimable* (total length is
        // unknown until completion): use generated / (generated + predicted)
        let est_total = input.generated as f64 + raw.mean;
        let progress = if est_total <= 0.0 {
            0.0
        } else {
            input.generated as f64 / est_total
        };
        let b = Scorecard::bucket_of(progress);
        Some(Prediction::new(
            (raw.mean - self.bias[b]).max(0.0),
            raw.sigma,
            raw.issued_at_iter,
        ))
    }

    fn name(&self) -> String {
        "debiased".into()
    }

    fn observe_completion(&mut self, output_len: u32, samples: &[PredSample]) {
        if output_len == 0 {
            return;
        }
        for s in samples {
            let actual = output_len.saturating_sub(s.generated) as f64;
            let progress = s.generated as f64 / output_len as f64;
            let b = Scorecard::bucket_of(progress);
            self.n[b] += 1;
            // the logged samples are post-correction, so the residual
            // error integrates into the bias estimate (Robbins–Monro with
            // a floored step so late drift is still tracked)
            let alpha = (1.0 / self.n[b] as f64).max(0.02);
            self.bias[b] += alpha * (s.predicted - actual);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn input(generated: u32, rem: u32) -> PredictInput {
        PredictInput {
            id: 1,
            generated,
            true_remaining: Some(rem),
        }
    }

    #[test]
    fn oracle_is_exact() {
        let mut p = OraclePredictor;
        let pred = p.predict(&input(10, 500)).unwrap();
        assert_eq!(pred.mean, 500.0);
        assert_eq!(pred.sigma, 0.0);
        assert_eq!(pred.issued_at_iter, 10);
        assert_eq!(pred.quantile(0.9), 500.0, "zero spread: every quantile is the mean");
    }

    #[test]
    fn none_returns_none() {
        let mut p = NoPredictor;
        assert!(p.predict(&input(10, 500)).is_none());
        assert_eq!(p.cost_s(10), 0.0);
    }

    #[test]
    fn binned_6_matches_paper_boundaries() {
        let b = BinnedOracle::paper_bins(6, 32_768.0);
        // 1K remaining -> bin [0, 2K) -> midpoint 1K
        let mut p = BinnedOracle::paper_bins(6, 32_768.0);
        assert!((p.predict(&input(0, 1_000)).unwrap().mean - 1_024.0).abs() < 1.0);
        // 30K remaining -> bin [16K, 32K) -> midpoint 24K
        assert!((p.predict(&input(0, 30_000)).unwrap().mean - 24_576.0).abs() < 1.0);
        assert_eq!(b.bounds.len(), 6);
    }

    #[test]
    fn binned_2_collapses_information() {
        let mut p = BinnedOracle::paper_bins(2, 32_768.0);
        // everything below 8K predicts the same midpoint (4K)
        let a = p.predict(&input(0, 100)).unwrap();
        let b = p.predict(&input(0, 7_900)).unwrap();
        assert_eq!(a.mean, b.mean);
        assert!((a.mean - 4_096.0).abs() < 1.0);
        // the bin's spread is its width / sqrt(12)
        assert!((a.sigma - 8_192.0 / 12f64.sqrt()).abs() < 1.0);
    }

    #[test]
    fn binned_exact_boundary_lands_in_the_upper_bin() {
        // the satellite regression: a value exactly ON an interior bound
        // belongs to the bin it opens ([0,8K), [8K,32K] — 8K is upper-bin),
        // via a plain ascending scan with no float special-cases
        let mut p = BinnedOracle::paper_bins(2, 32_768.0);
        let at_bound = p.predict(&input(0, 8_192)).unwrap();
        assert!(
            (at_bound.mean - 20_480.0).abs() < 1.0,
            "8K sits in [8K, 32K], midpoint 20K — got {}",
            at_bound.mean
        );
        let below = p.predict(&input(0, 8_191)).unwrap();
        assert!((below.mean - 4_096.0).abs() < 1.0);
        // 6-bin interior bound: 8K opens [8K, 16K), midpoint 12K
        let mut p6 = BinnedOracle::paper_bins(6, 32_768.0);
        let at6 = p6.predict(&input(0, 8_192)).unwrap();
        assert!((at6.mean - 12_288.0).abs() < 1.0, "got {}", at6.mean);
    }

    #[test]
    fn binned_over_cap_lands_in_the_last_bin() {
        // remaining > cap must quantize into the closed last bin (its
        // midpoint), never fall through to a bare `cap` passthrough
        let mut p = BinnedOracle::paper_bins(2, 32_768.0);
        for rem in [32_768u32, 40_000, 1_000_000] {
            let got = p.predict(&input(0, rem)).unwrap();
            assert!(
                (got.mean - 20_480.0).abs() < 1.0,
                "remaining {rem} must hit the [8K, 32K] midpoint, got {}",
                got.mean
            );
        }
        // single-bin degenerate shape still answers sanely
        let mut one = BinnedOracle {
            bounds: vec![1.0],
            cap: 100.0,
        };
        assert!((one.predict(&input(0, 500)).unwrap().mean - 50.0).abs() < 1e-9);
    }

    #[test]
    fn noisy_oracle_centered_and_improving() {
        let mut p = NoisyOracle::new(0.4, 7);
        let early: Vec<f64> = (0..3000)
            .map(|_| (p.predict(&input(0, 1_000)).unwrap().mean - 1_000.0).abs())
            .collect();
        let late: Vec<f64> = (0..3000)
            .map(|_| (p.predict(&input(2_000, 1_000)).unwrap().mean - 1_000.0).abs())
            .collect();
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(mean(&late) < mean(&early) * 0.7, "late should be tighter");
        assert!(mean(&early) > 0.0);
    }

    #[test]
    fn noisy_oracle_reports_its_spread() {
        let mut p = NoisyOracle::new(0.4, 3);
        let pred = p.predict(&input(0, 1_000)).unwrap();
        assert!(pred.sigma > 0.0, "llm_native predictions carry uncertainty");
        assert!((pred.sigma - pred.mean * 0.4).abs() < 1e-9);
        assert!(pred.quantile(0.9) > pred.mean, "p90 sits above the mean");
        assert_eq!(p.name(), "llm_native", "no σ decoration in the name");
    }

    #[test]
    fn debiased_learns_away_the_lognormal_bias() {
        // the log-normal noise over-predicts by e^{σ²/2}; after feedback
        // from many completions the corrected estimates must be closer to
        // centered than the raw ones
        let rel = 0.5;
        let mut raw = NoisyOracle::new(rel, 11);
        let mut deb = DebiasedPredictor::new(rel, 11);
        let mean_err = |errs: &[f64]| errs.iter().sum::<f64>() / errs.len() as f64;
        let mut raw_errs = Vec::new();
        let mut deb_errs = Vec::new();
        for round in 0..3000 {
            let rem = 1_000u32;
            let r = raw.predict(&input(0, rem)).unwrap().mean - rem as f64;
            let d = deb.predict(&input(0, rem)).unwrap();
            // feed the completion back (output = rem since generated = 0)
            deb.observe_completion(
                rem,
                &[PredSample { generated: 0, predicted: d.mean }],
            );
            if round >= 1000 {
                // judge after warm-up
                raw_errs.push(r);
                deb_errs.push(d.mean - rem as f64);
            }
        }
        let rb = mean_err(&raw_errs);
        let db = mean_err(&deb_errs);
        assert!(rb > 30.0, "raw log-normal noise must over-predict: {rb}");
        assert!(
            db.abs() < rb.abs() * 0.6,
            "debiasing must cut the bias: raw {rb:.1} vs debiased {db:.1}"
        );
        assert!(deb.bias_estimates()[0] > 0.0, "learned a positive correction");
    }

    #[test]
    fn registry_build_matches_names() {
        let ctx = PredictorContext {
            cap: 512.0,
            rel_err: 0.2,
            seed: 0,
        };
        let reg = PredictorRegistry::with_builtins();
        assert_eq!(reg.build("oracle", &ctx).unwrap().name(), "oracle");
        assert_eq!(reg.build("4bin", &ctx).unwrap().name(), "binned4");
        assert_eq!(reg.build("debiased", &ctx).unwrap().name(), "debiased");
    }
}
