//! Runtime remaining-length predictors (paper §4 + §6 ablations).
//!
//! The live serving path uses [`HloPredictor`] (the trained LLM-native MLP
//! executed through PJRT — see `crate::runtime`); the simulator uses
//! [`OraclePredictor`] / [`BinnedOracle`] / [`NoisyOracle`] exactly as the
//! paper's large-scale simulator does ("we leverage the actual remaining
//! generation lengths to simulate an oracle predictor", §6.3).

use crate::config::PredictorKind;
use crate::prng::Pcg64;
use crate::RequestId;

/// Inputs available when predicting for one request.
#[derive(Clone, Copy, Debug)]
pub struct PredictInput {
    pub id: RequestId,
    /// Tokens generated so far.
    pub generated: u32,
    /// Ground truth remaining (simulator only; None on the live path).
    pub true_remaining: Option<u32>,
}

/// A remaining-generation-length predictor (token units).
pub trait LengthPredictor: Send {
    /// Estimate remaining output length; None = no estimate available.
    fn predict(&mut self, input: &PredictInput) -> Option<f64>;
    fn name(&self) -> String;
    /// Latency cost of one prediction batch of size `batch` in seconds
    /// (added to the decode iteration it runs in — paper §5.3).
    fn cost_s(&self, batch: usize) -> f64 {
        // LLM-native measured: 1.33 ms @ b=1, 2.4 ms @ b=10 (Table 1),
        // scaled to our pico model (~30x smaller d): dominated by launch.
        40e-6 + 4e-6 * batch as f64
    }
}

/// "STAR w/o prediction": no estimates.
pub struct NoPredictor;

impl LengthPredictor for NoPredictor {
    fn predict(&mut self, _input: &PredictInput) -> Option<f64> {
        None
    }
    fn name(&self) -> String {
        "none".into()
    }
    fn cost_s(&self, _batch: usize) -> f64 {
        0.0
    }
}

/// Exact remaining lengths ("STAR Oracle").
pub struct OraclePredictor;

impl LengthPredictor for OraclePredictor {
    fn predict(&mut self, input: &PredictInput) -> Option<f64> {
        input.true_remaining.map(|r| r as f64)
    }
    fn name(&self) -> String {
        "oracle".into()
    }
    fn cost_s(&self, _batch: usize) -> f64 {
        0.0
    }
}

/// Oracle quantized to the paper's non-uniform bins (Table 3). Bins are
/// expressed as fractions of the output cap so they work at both scales;
/// at paper scale (cap = 32K) they reproduce the published boundaries:
///   2-bin: [0, 8K), [8K, 32K]
///   4-bin: [0, 4K), [4K, 8K), [8K, 16K), [16K, 32K]
///   6-bin: [0, 2K), [2K, 4K), [4K, 6K), [6K, 8K), [8K, 16K), [16K, 32K]
pub struct BinnedOracle {
    /// Ascending bin upper bounds as fractions of `cap` (last = 1.0).
    pub bounds: Vec<f64>,
    pub cap: f64,
}

impl BinnedOracle {
    pub fn paper_bins(n: u8, cap: f64) -> BinnedOracle {
        let bounds: Vec<f64> = match n {
            2 => vec![0.25, 1.0],
            4 => vec![0.125, 0.25, 0.5, 1.0],
            6 => vec![1.0 / 16.0, 2.0 / 16.0, 3.0 / 16.0, 0.25, 0.5, 1.0],
            other => {
                // uniform fallback for unusual bin counts
                (1..=other).map(|i| i as f64 / other as f64).collect()
            }
        };
        BinnedOracle { bounds, cap }
    }

    /// Midpoint of the bin containing `remaining`.
    fn quantize(&self, remaining: f64) -> f64 {
        let frac = (remaining / self.cap).clamp(0.0, 1.0);
        let mut lo = 0.0;
        for &hi in &self.bounds {
            if frac < hi || (hi - 1.0).abs() < f64::EPSILON {
                if frac <= hi {
                    return (lo + hi) / 2.0 * self.cap;
                }
            }
            lo = hi;
        }
        self.cap
    }
}

impl LengthPredictor for BinnedOracle {
    fn predict(&mut self, input: &PredictInput) -> Option<f64> {
        input
            .true_remaining
            .map(|r| self.quantize(r as f64))
    }
    fn name(&self) -> String {
        format!("{}bin", self.bounds.len())
    }
    fn cost_s(&self, _batch: usize) -> f64 {
        0.0
    }
}

/// Oracle + multiplicative log-normal noise — the simulator's stand-in for
/// the trained LLM-native predictor. `rel_err` is calibrated from the
/// measured eval (artifacts/predictor_eval.tsv: MAE / mean remaining), and
/// the error shrinks as generation progresses, matching the Fig. 7 curve
/// (continuous prediction gets more context).
pub struct NoisyOracle {
    pub rel_err: f64,
    /// Error multiplier at progress 1.0 relative to progress 0.0.
    pub late_factor: f64,
    /// Typical total output length used to gauge progress.
    pub progress_scale: f64,
    rng: Pcg64,
}

impl NoisyOracle {
    pub fn new(rel_err: f64, seed: u64) -> NoisyOracle {
        NoisyOracle {
            rel_err,
            late_factor: 0.35,
            progress_scale: 2_000.0,
            rng: Pcg64::new(seed, 0x505245444e), // "PREDN"
        }
    }
}

impl LengthPredictor for NoisyOracle {
    fn predict(&mut self, input: &PredictInput) -> Option<f64> {
        let rem = input.true_remaining? as f64;
        let progress = (input.generated as f64 / self.progress_scale).min(1.0);
        let sigma = self.rel_err * (1.0 - (1.0 - self.late_factor) * progress);
        let noise = self.rng.normal(0.0, sigma);
        Some((rem * noise.exp()).max(0.0))
    }
    fn name(&self) -> String {
        format!("llm_native(sim,σ={})", self.rel_err)
    }
}

/// Build the simulator-side predictor for a config.
pub fn build_sim_predictor(
    kind: PredictorKind,
    cap: f64,
    rel_err: f64,
    seed: u64,
) -> Box<dyn LengthPredictor> {
    match kind {
        PredictorKind::None => Box::new(NoPredictor),
        PredictorKind::Oracle => Box::new(OraclePredictor),
        PredictorKind::Binned(n) => Box::new(BinnedOracle::paper_bins(n, cap)),
        PredictorKind::LlmNative => Box::new(NoisyOracle::new(rel_err, seed)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn input(generated: u32, rem: u32) -> PredictInput {
        PredictInput {
            id: 1,
            generated,
            true_remaining: Some(rem),
        }
    }

    #[test]
    fn oracle_is_exact() {
        let mut p = OraclePredictor;
        assert_eq!(p.predict(&input(10, 500)), Some(500.0));
    }

    #[test]
    fn none_returns_none() {
        let mut p = NoPredictor;
        assert_eq!(p.predict(&input(10, 500)), None);
        assert_eq!(p.cost_s(10), 0.0);
    }

    #[test]
    fn binned_6_matches_paper_boundaries() {
        let b = BinnedOracle::paper_bins(6, 32_768.0);
        // 1K remaining -> bin [0, 2K) -> midpoint 1K
        let mut p = BinnedOracle::paper_bins(6, 32_768.0);
        assert!((p.predict(&input(0, 1_000)).unwrap() - 1_024.0).abs() < 1.0);
        // 30K remaining -> bin [16K, 32K) -> midpoint 24K
        assert!((p.predict(&input(0, 30_000)).unwrap() - 24_576.0).abs() < 1.0);
        assert_eq!(b.bounds.len(), 6);
    }

    #[test]
    fn binned_2_collapses_information() {
        let mut p = BinnedOracle::paper_bins(2, 32_768.0);
        // everything below 8K predicts the same midpoint (4K)
        let a = p.predict(&input(0, 100)).unwrap();
        let b = p.predict(&input(0, 7_900)).unwrap();
        assert_eq!(a, b);
        assert!((a - 4_096.0).abs() < 1.0);
    }

    #[test]
    fn noisy_oracle_centered_and_improving() {
        let mut p = NoisyOracle::new(0.4, 7);
        let early: Vec<f64> = (0..3000)
            .map(|_| (p.predict(&input(0, 1_000)).unwrap() - 1_000.0).abs())
            .collect();
        let late: Vec<f64> = (0..3000)
            .map(|_| (p.predict(&input(2_000, 1_000)).unwrap() - 1_000.0).abs())
            .collect();
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(mean(&late) < mean(&early) * 0.7, "late should be tighter");
        assert!(mean(&early) > 0.0);
    }

    #[test]
    fn build_matches_kind() {
        assert_eq!(
            build_sim_predictor(PredictorKind::Oracle, 512.0, 0.2, 0).name(),
            "oracle"
        );
        assert_eq!(
            build_sim_predictor(PredictorKind::Binned(4), 512.0, 0.2, 0).name(),
            "4bin"
        );
    }
}
