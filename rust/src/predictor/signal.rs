//! The uncertainty-aware prediction signal.
//!
//! The predictor layer used to hand schedulers a bare `Option<f64>`, which
//! forced every consumer to treat a 6-bin guess and an oracle value as
//! equally trustworthy. [`Prediction`] carries the point estimate *and*
//! its spread, so OOM-avoidance checks can plan against a conservative
//! quantile (p90 by default) while load-balancing objectives keep using
//! the mean — the split Arrow (arXiv:2505.11916) and SLO-aware
//! disaggregated scheduling (arXiv:2605.02329) show is what makes
//! adaptive scheduling beat static splits.

/// One remaining-generation-length estimate (token units) with its
/// uncertainty. Cheap to copy; carried through `ClusterState` /
/// `ClusterView` into every policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Prediction {
    /// Point estimate of the remaining output length.
    pub mean: f64,
    /// One standard deviation of the estimate, token units. 0 for exact
    /// predictors (oracle) and for live point estimates without a
    /// calibrated spread.
    pub sigma: f64,
    /// The request's generated-token count when this estimate was issued —
    /// the reprediction clock both drivers share (staleness diagnostic).
    pub issued_at_iter: u64,
}

impl Prediction {
    pub fn new(mean: f64, sigma: f64, issued_at_iter: u64) -> Prediction {
        Prediction {
            mean,
            sigma: sigma.max(0.0),
            issued_at_iter,
        }
    }

    /// An exact (zero-spread) estimate — the compatibility constructor for
    /// tests and point-estimate producers.
    pub fn exact(mean: f64) -> Prediction {
        Prediction::new(mean, 0.0, 0)
    }

    /// Quantile `q` of the estimate under a normal error model, clamped
    /// to be non-negative (a remaining length cannot be). `quantile(0.5)`
    /// is exactly `mean` (the balancing view); `quantile(0.9)` is the
    /// conservative view the OOM-avoidance checks consume.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.sigma <= 0.0 {
            return self.mean.max(0.0);
        }
        (self.mean + normal_quantile(q) * self.sigma).max(0.0)
    }
}

/// Standard normal quantile (inverse CDF) via Acklam's rational
/// approximation (|relative error| < 1.15e-9 over (0, 1)). Inputs are
/// clamped into (0, 1); `normal_quantile(0.5)` is exactly 0.
pub fn normal_quantile(q: f64) -> f64 {
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;

    let q = q.clamp(1e-12, 1.0 - 1e-12);
    if q < P_LOW {
        let r = (-2.0 * q.ln()).sqrt();
        (((((C[0] * r + C[1]) * r + C[2]) * r + C[3]) * r + C[4]) * r + C[5])
            / ((((D[0] * r + D[1]) * r + D[2]) * r + D[3]) * r + 1.0)
    } else if q <= 1.0 - P_LOW {
        let r = q - 0.5;
        let s = r * r;
        (((((A[0] * s + A[1]) * s + A[2]) * s + A[3]) * s + A[4]) * s + A[5]) * r
            / (((((B[0] * s + B[1]) * s + B[2]) * s + B[3]) * s + B[4]) * s + 1.0)
    } else {
        let r = (-2.0 * (1.0 - q).ln()).sqrt();
        -(((((C[0] * r + C[1]) * r + C[2]) * r + C[3]) * r + C[4]) * r + C[5])
            / ((((D[0] * r + D[1]) * r + D[2]) * r + D[3]) * r + 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_is_exactly_zero() {
        assert_eq!(normal_quantile(0.5), 0.0);
    }

    #[test]
    fn known_quantiles_match_tables() {
        for (q, z) in [
            (0.90, 1.2815515655446004),
            (0.95, 1.6448536269514722),
            (0.99, 2.3263478740408408),
            (0.10, -1.2815515655446004),
            (0.025, -1.9599639845400545),
        ] {
            let got = normal_quantile(q);
            assert!(
                (got - z).abs() < 1e-6,
                "z({q}) = {got}, want {z}"
            );
        }
    }

    #[test]
    fn quantile_is_monotone_in_q() {
        let p = Prediction::new(100.0, 20.0, 0);
        let mut prev = f64::NEG_INFINITY;
        for i in 1..100 {
            let v = p.quantile(i as f64 / 100.0);
            assert!(v >= prev, "quantile not monotone at q={}", i);
            prev = v;
        }
    }

    #[test]
    fn exact_predictions_ignore_q() {
        let p = Prediction::exact(123.0);
        assert_eq!(p.quantile(0.1), 123.0);
        assert_eq!(p.quantile(0.5), 123.0);
        assert_eq!(p.quantile(0.99), 123.0);
    }

    #[test]
    fn p90_adds_about_1_28_sigma() {
        let p = Prediction::new(1000.0, 100.0, 0);
        assert!((p.quantile(0.9) - 1128.155).abs() < 0.01);
        assert!((p.quantile(0.5) - 1000.0).abs() < 1e-12);
        // clamped at zero: a deep-left quantile of a small mean
        let small = Prediction::new(10.0, 100.0, 0);
        assert_eq!(small.quantile(0.01), 0.0);
    }

    #[test]
    fn negative_sigma_is_clamped() {
        let p = Prediction::new(50.0, -3.0, 7);
        assert_eq!(p.sigma, 0.0);
        assert_eq!(p.issued_at_iter, 7);
        assert_eq!(p.quantile(0.99), 50.0);
    }
}
