//! Online calibration scorecard: signed error and MAE per progress
//! bucket, accumulated at request **completion** (only then is the actual
//! remaining length at each prediction point known — the same contract
//! the live path has, where ground truth never exists at prediction
//! time).
//!
//! The drivers log a [`PredSample`] every time a request's estimate is
//! (re)issued; at completion the samples fold into the run's
//! [`Scorecard`] (reported in `SimReport` / `ServeOutcome`) and are also
//! fed back to the predictor (`LengthPredictor::observe_completion`),
//! which is what the `debiased` builtin learns its correction from.

/// Number of generation-progress buckets ([0, 1) split evenly; the last
/// bucket is closed at 1).
pub const PROGRESS_BUCKETS: usize = 5;

/// One issued prediction, as the drivers log it: how many tokens had been
/// generated, and what remaining length was predicted. The actual
/// remaining at that point is `output_len - generated`, known at
/// completion.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PredSample {
    /// Tokens generated when the prediction was issued.
    pub generated: u32,
    /// Predicted remaining output length (mean), tokens.
    pub predicted: f64,
}

/// Accumulated error statistics of one progress bucket.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BucketStats {
    /// Number of folded prediction samples.
    pub n: u64,
    /// Σ (predicted − actual): positive = systematic over-prediction.
    pub signed_sum: f64,
    /// Σ |predicted − actual|.
    pub abs_sum: f64,
    /// Σ actual remaining — normalizes MAE into a relative error.
    pub actual_sum: f64,
}

impl BucketStats {
    /// Mean signed error (bias), tokens; 0 when empty.
    pub fn bias(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.signed_sum / self.n as f64
        }
    }

    /// Mean absolute error, tokens; 0 when empty.
    pub fn mae(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.abs_sum / self.n as f64
        }
    }

    /// MAE relative to the mean actual remaining length (the unit-free
    /// calibration number comparable to the injected `rel_err`).
    pub fn rel_mae(&self) -> f64 {
        if self.actual_sum <= 0.0 {
            0.0
        } else {
            self.abs_sum / self.actual_sum
        }
    }

    fn fold(&mut self, other: &BucketStats) {
        self.n += other.n;
        self.signed_sum += other.signed_sum;
        self.abs_sum += other.abs_sum;
        self.actual_sum += other.actual_sum;
    }
}

/// Per-progress-bucket calibration accumulator.
#[derive(Clone, Debug, Default)]
pub struct Scorecard {
    buckets: [BucketStats; PROGRESS_BUCKETS],
}

impl Scorecard {
    pub fn new() -> Scorecard {
        Scorecard::default()
    }

    /// Bucket index of a generation progress fraction in [0, 1].
    pub fn bucket_of(progress: f64) -> usize {
        ((progress.clamp(0.0, 1.0) * PROGRESS_BUCKETS as f64) as usize)
            .min(PROGRESS_BUCKETS - 1)
    }

    /// Record one (signed error, actual remaining) observation at a
    /// progress fraction.
    pub fn record(&mut self, progress: f64, signed_err: f64, actual: f64) {
        let b = &mut self.buckets[Self::bucket_of(progress)];
        b.n += 1;
        b.signed_sum += signed_err;
        b.abs_sum += signed_err.abs();
        b.actual_sum += actual.max(0.0);
    }

    /// Fold a completed request's prediction log: each sample's actual
    /// remaining is `output_len − generated`, its progress is
    /// `generated / output_len`.
    pub fn observe_completion(&mut self, output_len: u32, samples: &[PredSample]) {
        if output_len == 0 {
            return;
        }
        for s in samples {
            let actual = output_len.saturating_sub(s.generated) as f64;
            let progress = s.generated as f64 / output_len as f64;
            self.record(progress, s.predicted - actual, actual);
        }
    }

    pub fn bucket(&self, idx: usize) -> &BucketStats {
        &self.buckets[idx]
    }

    pub fn buckets(&self) -> &[BucketStats] {
        &self.buckets
    }

    /// All buckets folded into one aggregate.
    pub fn total(&self) -> BucketStats {
        let mut t = BucketStats::default();
        for b in &self.buckets {
            t.fold(b);
        }
        t
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.iter().all(|b| b.n == 0)
    }

    /// Fold another scorecard in (e.g. serve-side per-run merges).
    pub fn merge(&mut self, other: &Scorecard) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            a.fold(b);
        }
    }

    /// One row per non-empty bucket, for reports and the CLI:
    /// `progress [0.0,0.2)  n 123  bias +45.6  MAE 78.9 (12.3% rel)`.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for (i, b) in self.buckets.iter().enumerate() {
            if b.n == 0 {
                continue;
            }
            if !out.is_empty() {
                out.push('\n');
            }
            let lo = i as f64 / PROGRESS_BUCKETS as f64;
            let hi = (i + 1) as f64 / PROGRESS_BUCKETS as f64;
            out.push_str(&format!(
                "progress [{lo:.1},{hi:.1})  n {:>7}  bias {:>+9.1}  MAE {:>8.1} ({:.1}% rel)",
                b.n,
                b.bias(),
                b.mae(),
                100.0 * b.rel_mae(),
            ));
        }
        out
    }

    /// Raw JSON array (one object per bucket) for the bench writer's
    /// `field_raw` — re-parsed by the smoke gate, so it must stay valid.
    pub fn json(&self) -> String {
        let mut s = String::from("[");
        for (i, b) in self.buckets.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            let fin = |v: f64| if v.is_finite() { v } else { 0.0 };
            s.push_str(&format!(
                "{{\"bucket\": {i}, \"n\": {}, \"bias\": {}, \"mae\": {}, \"rel_mae\": {}}}",
                b.n,
                fin(b.bias()),
                fin(b.mae()),
                fin(b.rel_mae()),
            ));
        }
        s.push(']');
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_partition_progress() {
        assert_eq!(Scorecard::bucket_of(0.0), 0);
        assert_eq!(Scorecard::bucket_of(0.19), 0);
        assert_eq!(Scorecard::bucket_of(0.2), 1);
        assert_eq!(Scorecard::bucket_of(0.99), 4);
        assert_eq!(Scorecard::bucket_of(1.0), 4, "closed top bucket");
        assert_eq!(Scorecard::bucket_of(7.0), 4, "clamped");
        assert_eq!(Scorecard::bucket_of(-1.0), 0, "clamped");
    }

    #[test]
    fn completion_folds_samples_with_true_remaining() {
        let mut sc = Scorecard::new();
        // request of 100 output tokens, predicted 60 at g=0 (actual 100,
        // err -40, bucket 0) and 55 at g=50 (actual 50, err +5, bucket 2)
        sc.observe_completion(
            100,
            &[
                PredSample { generated: 0, predicted: 60.0 },
                PredSample { generated: 50, predicted: 55.0 },
            ],
        );
        let b0 = sc.bucket(0);
        assert_eq!(b0.n, 1);
        assert!((b0.bias() + 40.0).abs() < 1e-9);
        assert!((b0.mae() - 40.0).abs() < 1e-9);
        let b2 = sc.bucket(2);
        assert_eq!(b2.n, 1);
        assert!((b2.bias() - 5.0).abs() < 1e-9);
        let t = sc.total();
        assert_eq!(t.n, 2);
        assert!((t.mae() - 22.5).abs() < 1e-9);
        assert!((t.bias() + 17.5).abs() < 1e-9);
        assert!((t.rel_mae() - 45.0 / 150.0).abs() < 1e-9);
        assert!(!sc.is_empty());
        assert!(sc.summary().contains("bias"));
    }

    #[test]
    fn exact_predictions_score_zero() {
        let mut sc = Scorecard::new();
        for g in [0u32, 20, 40, 60, 80] {
            sc.observe_completion(
                100,
                &[PredSample { generated: g, predicted: (100 - g) as f64 }],
            );
        }
        let t = sc.total();
        assert_eq!(t.n, 5);
        assert_eq!(t.mae(), 0.0);
        assert_eq!(t.bias(), 0.0);
        // every bucket saw its own progress point
        for i in 0..PROGRESS_BUCKETS {
            assert_eq!(sc.bucket(i).n, 1, "bucket {i}");
        }
    }

    #[test]
    fn mae_matches_injected_noise_level() {
        // additive noise of a known scale: per-bucket MAE must recover it
        let mut sc = Scorecard::new();
        let mut rng = crate::prng::Pcg64::new(42, 0x5c0);
        let noise = 30.0;
        for _ in 0..4000 {
            let g = (rng.normal(0.0, 1.0).abs() * 20.0).min(90.0) as u32;
            let actual = (100 - g) as f64;
            let err = rng.normal(0.0, noise);
            sc.observe_completion(
                100,
                &[PredSample { generated: g, predicted: actual + err }],
            );
        }
        let t = sc.total();
        // E|N(0,σ)| = σ·√(2/π) ≈ 0.798 σ
        let expect = noise * (2.0 / std::f64::consts::PI).sqrt();
        assert!(
            (t.mae() - expect).abs() < 0.15 * expect,
            "MAE {} should be ~{expect}",
            t.mae()
        );
        assert!(
            t.bias().abs() < 0.1 * noise,
            "unbiased noise must score near-zero bias: {}",
            t.bias()
        );
    }

    #[test]
    fn merge_and_json_render() {
        let mut a = Scorecard::new();
        a.record(0.1, 5.0, 50.0);
        let mut b = Scorecard::new();
        b.record(0.1, -5.0, 50.0);
        b.record(0.9, 1.0, 10.0);
        a.merge(&b);
        assert_eq!(a.bucket(0).n, 2);
        assert_eq!(a.bucket(0).bias(), 0.0);
        assert_eq!(a.bucket(4).n, 1);
        let j = a.json();
        assert!(j.starts_with('[') && j.ends_with(']'));
        assert!(j.contains("\"rel_mae\""));
        // zero-length outputs are ignored, not a division by zero
        let mut z = Scorecard::new();
        z.observe_completion(0, &[PredSample { generated: 0, predicted: 1.0 }]);
        assert!(z.is_empty());
        assert_eq!(z.summary(), "");
    }
}
