//! Simulation outputs: everything the benches need to print the paper's
//! tables and figures, plus per-class SLO accounting for scenario runs
//! (aggregate goodput hides class-level violations — the per-class rows
//! are how a bursty mixed workload shows its tail).

use crate::coordinator::{ReschedulerStats, ScaleRecord};
use crate::kvcache::CacheReport;
use crate::metrics::{PoolSample, RequestLatency, RunMetrics, Slo, TraceRecorder, VarianceOverTime};
use crate::obs::ObsReport;
use crate::predictor::Scorecard;
use crate::workload::{RequestClass, SloByClass};
use crate::{InstanceId, RequestId, Time};

/// Fault-injection accounting for one run: what failed, what the system
/// recovered, and what it paid. All zeros (and `is_empty()`) for runs
/// without faults.
///
/// Accounting invariant: `lost` counts requests terminally failed *by a
/// crash* (their KV could not be recomputed within the admission
/// watermark) and is a subset of the report's `n_failed` — so
/// `completed + n_failed == n_requests` still accounts for every arrival.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReliabilityReport {
    /// Instance crashes executed (scripted + stochastic).
    pub failures: u64,
    /// Crashed instances that came back (`InstanceRecovered`).
    pub recoveries: u64,
    /// Requests re-queued by crashes (pending re-dispatches + batch
    /// residents sent through the recompute path).
    pub requeued: u64,
    /// Requests terminally failed by a crash (subset of `n_failed`).
    pub lost: u64,
    /// KV tokens discarded by crashes: batch-resident KV plus flushed
    /// prefix-cache entries.
    pub kv_tokens_dropped: u64,
    /// `(time, instance)` of every executed failure, in order — the
    /// trace the same-seed determinism tests compare verbatim.
    pub failure_log: Vec<(Time, InstanceId)>,
    /// Per-requeued-request delay from crash to successful re-admission
    /// into a decode batch (seconds), in admission order.
    pub requeue_delays: Vec<f64>,
}

impl ReliabilityReport {
    /// No faults were injected and nothing was lost?
    pub fn is_empty(&self) -> bool {
        self.failures == 0 && self.recoveries == 0 && self.lost == 0
    }

    /// Quantile of the crash→re-admission delay distribution (seconds);
    /// 0.0 when nothing was re-queued. Uses the crate-wide shared
    /// linear-interpolation quantile (this used to be nearest-rank,
    /// inconsistent with every other percentile in the crate).
    pub fn quantile_requeue_s(&self, q: f64) -> f64 {
        if self.requeue_delays.is_empty() {
            return 0.0;
        }
        crate::metrics::percentiles::quantile_unsorted(&self.requeue_delays, q)
    }

    /// One greppable line, printed by `star simulate` for fault runs.
    pub fn summary(&self) -> String {
        format!(
            "reliability: failures={} recoveries={} requeued={} lost={} \
             kv_dropped={} | requeue p50={:.3}s p99={:.3}s",
            self.failures,
            self.recoveries,
            self.requeued,
            self.lost,
            self.kv_tokens_dropped,
            self.quantile_requeue_s(0.50),
            self.quantile_requeue_s(0.99),
        )
    }
}

/// Result of one simulation run.
#[derive(Debug)]
pub struct SimReport {
    pub duration: Time,
    pub completed: Vec<RequestLatency>,
    pub n_failed: usize,
    pub n_requests: usize,
    pub oom_events: u64,
    pub migrations: u64,
    /// Cross-instance variance of per-iteration latency (ms^2) over time
    /// (Figs. 3, 11, 13).
    pub exec_var: VarianceOverTime,
    /// Cross-instance variance of KV token load over time.
    pub load_var: VarianceOverTime,
    pub recorder: TraceRecorder,
    /// Predictor calibration: signed error + MAE per progress bucket,
    /// accumulated at request completion (empty under `none`).
    pub scorecard: Scorecard,
    pub scheduler_stats: ReschedulerStats,
    pub per_instance_tokens: Vec<u64>,
    /// Realized multi-round session chains (request ids in turn order);
    /// empty for sessionless workloads.
    pub session_chains: Vec<Vec<RequestId>>,
    /// Elastic pool-size timeline, one sample per scale interval.
    pub pool_timeline: Vec<PoolSample>,
    /// Executed scaling actions, in decision order (the scale-action
    /// trace the determinism tests compare verbatim).
    pub scale_actions: Vec<ScaleRecord>,
    /// Prefix-cache effectiveness counters (all zeros, `enabled == false`
    /// under the `none` policy). `star simulate` prints
    /// [`CacheReport::summary`] for cache-enabled runs.
    pub cache: CacheReport,
    /// Fault-injection accounting (all zeros without faults).
    /// `star simulate` prints [`ReliabilityReport::summary`] for fault
    /// runs.
    pub reliability: ReliabilityReport,
    /// Observability output (`[obs]` table, `star trace`): sampled
    /// request spans, the metrics registry, and the decision log.
    /// Default-shaped (`enabled == false`) for obs-disabled runs.
    pub obs: ObsReport,
}

/// Per-class slice of a run: TTFT/TPOT percentiles and goodput against
/// the class's own SLO target.
#[derive(Clone, Debug)]
pub struct ClassReport {
    pub class: RequestClass,
    pub n: usize,
    pub ttft_p50_ms: f64,
    pub ttft_p99_ms: f64,
    pub tpot_p50_ms: f64,
    pub tpot_p99_ms: f64,
    /// req/s of this class meeting ITS class SLO.
    pub goodput: f64,
    pub slo: Slo,
}

impl SimReport {
    /// Convert to the shared end-to-end metrics container.
    pub fn metrics(&self) -> RunMetrics {
        RunMetrics {
            completed: self.completed.clone(),
            duration: self.duration,
            oom_events: self.oom_events,
            migrations: self.migrations,
        }
    }

    /// Per-class TTFT/TPOT percentiles + goodput, one row per class with
    /// completed requests, judged against per-class SLOs.
    pub fn class_metrics(&self, slos: &SloByClass) -> Vec<ClassReport> {
        let m = self.metrics();
        m.classes_present()
            .into_iter()
            .map(|class| {
                let cm = m.filter_class(class);
                let slo = slos.get(class);
                ClassReport {
                    class,
                    n: cm.completed.len(),
                    ttft_p50_ms: cm.quantile_ttft_ms(0.50),
                    ttft_p99_ms: cm.quantile_ttft_ms(0.99),
                    tpot_p50_ms: cm.quantile_tpot_ms(0.50),
                    tpot_p99_ms: cm.quantile_tpot_ms(0.99),
                    goodput: cm.goodput(slo),
                    slo,
                }
            })
            .collect()
    }

    /// Multi-line per-class summary (scenario runs append this to the
    /// aggregate [`Self::summary`] line).
    pub fn class_summary(&self, slos: &SloByClass) -> String {
        let mut out = String::new();
        for r in self.class_metrics(slos) {
            if !out.is_empty() {
                out.push('\n');
            }
            out.push_str(&format!(
                "class {:<14} n {:>6} | TTFT p50 {:>8.1} ms p99 {:>8.1} ms | \
                 TPOT p50 {:>7.2} ms p99 {:>7.2} ms | goodput {:.4} req/s \
                 (SLO {:.1}s TTFT / {:.0}ms TPOT)",
                r.class.name(),
                r.n,
                r.ttft_p50_ms,
                r.ttft_p99_ms,
                r.tpot_p50_ms,
                r.tpot_p99_ms,
                r.goodput,
                r.slo.ttft_s,
                r.slo.tpot_s * 1e3,
            ));
        }
        out
    }

    /// One-line summary used by examples and benches.
    pub fn summary(&self, slo: Slo) -> String {
        let m = self.metrics();
        format!(
            "completed {}/{} in {:.1}s | throughput {:.4} req/s | goodput {:.4} req/s | \
             P99 TPOT {:.2} ms | mean exec-var {:.3} ms^2 | OOMs {} | migrations {}",
            self.completed.len(),
            self.n_requests,
            self.duration,
            m.throughput(),
            m.goodput(slo),
            m.p99_tpot_ms(),
            self.exec_var.sample_mean(),
            self.oom_events,
            self.migrations,
        )
    }
}
