//! Simulation outputs: everything the benches need to print the paper's
//! tables and figures.

use crate::coordinator::ReschedulerStats;
use crate::metrics::{RequestLatency, RunMetrics, Slo, TraceRecorder, VarianceOverTime};
use crate::Time;

/// Result of one simulation run.
#[derive(Debug)]
pub struct SimReport {
    pub duration: Time,
    pub completed: Vec<RequestLatency>,
    pub n_failed: usize,
    pub n_requests: usize,
    pub oom_events: u64,
    pub migrations: u64,
    /// Cross-instance variance of per-iteration latency (ms^2) over time
    /// (Figs. 3, 11, 13).
    pub exec_var: VarianceOverTime,
    /// Cross-instance variance of KV token load over time.
    pub load_var: VarianceOverTime,
    pub recorder: TraceRecorder,
    pub scheduler_stats: ReschedulerStats,
    pub per_instance_tokens: Vec<u64>,
}

impl SimReport {
    /// Convert to the shared end-to-end metrics container.
    pub fn metrics(&self) -> RunMetrics {
        RunMetrics {
            completed: self.completed.clone(),
            duration: self.duration,
            oom_events: self.oom_events,
            migrations: self.migrations,
        }
    }

    /// One-line summary used by examples and benches.
    pub fn summary(&self, slo: Slo) -> String {
        let m = self.metrics();
        format!(
            "completed {}/{} in {:.1}s | throughput {:.4} req/s | goodput {:.4} req/s | \
             P99 TPOT {:.2} ms | mean exec-var {:.3} ms^2 | OOMs {} | migrations {}",
            self.completed.len(),
            self.n_requests,
            self.duration,
            m.throughput(),
            m.goodput(slo),
            m.p99_tpot_ms(),
            self.exec_var.sample_mean(),
            self.oom_events,
            self.migrations,
        )
    }
}
