//! Event-driven cluster simulator (paper §6.3: "we use event-driven
//! simulation to model request arrivals, decode execution, and migration
//! events; the execution time of each decode iteration is derived from
//! real system measurements").
//!
//! The simulator shares the *exact* policy code with the live runtime: a
//! [`crate::coordinator::ControlLoop`] holding the registry-built dispatch
//! and reschedule policies (`exp.dispatch_policy` / `exp.reschedule_policy`).
//! Only the execution substrate differs — decode iteration
//! times come from a [`DecodeCostModel`] calibrated by the `fig8_costmodel`
//! bench instead of PJRT execution.
//!
//! Fidelity points:
//! * decode instances run continuous batching; iteration time is linear in
//!   batched tokens (Fig. 8);
//! * per-request reprediction every `predict_every_iters` iterations, with
//!   the predictor's latency added to that iteration (paper §5.3);
//! * migrations pause only the moving request, transfer KV at link
//!   bandwidth, and resume on the target (paper §5.4 overlap);
//! * KV OOM evicts victims that must recompute their KV via a prefill
//!   pass, reproducing the paper's Issue-1 cascade.

mod engine;
mod events;
mod report;
mod shard;

pub use engine::{SimParams, Simulator, StateMode, VALIDATED_EVENTS};
pub use report::{ClassReport, ReliabilityReport, SimReport};
pub use shard::{ShardLayout, SHARD_STREAM_BASE};

use crate::metrics::RequestLatency;
use crate::predictor::{PredSample, Prediction};
use crate::workload::RequestClass;
use crate::{InstanceId, RequestId, Time};

/// Lifecycle of one simulated request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqState {
    /// Waiting for / running prefill.
    Prefill,
    /// In a decode instance's pending queue (not yet in the batch).
    Pending(InstanceId),
    /// Actively decoding on an instance.
    Decoding(InstanceId),
    /// KV in flight between instances.
    Migrating { from: InstanceId, to: InstanceId },
    /// Evicted by OOM, waiting to re-run prefill (KV recompute).
    Recomputing,
    Done,
}

/// Full simulator-side request record.
#[derive(Clone, Debug)]
pub struct SimRequest {
    pub id: RequestId,
    pub arrival: Time,
    /// Workload class (per-class SLO accounting).
    pub class: RequestClass,
    pub prompt_len: u32,
    /// Ground-truth output length (the trace's realized length).
    pub output_len: u32,
    pub generated: u32,
    pub state: ReqState,
    pub predicted_remaining: Option<Prediction>,
    pub iters_since_predict: u32,
    /// Every estimate issued for this request, folded into the run's
    /// calibration [`Scorecard`] (and fed back to the predictor) at
    /// completion — only then is the true remaining length known.
    ///
    /// [`Scorecard`]: crate::predictor::Scorecard
    pub pred_log: Vec<PredSample>,
    /// Tokens of this turn's prompt covered by a prefix-cache hit (0 on a
    /// miss or with the cache off): prefill computes and loads only
    /// `kv_tokens() - cached_prefix`; the cached blocks merge back into
    /// the allocation at admission.
    pub cached_prefix: u64,
    /// Instance whose prefix cache produced [`Self::cached_prefix`]
    /// (dispatch preference; cleared once the prefix is consumed or the
    /// hit is abandoned).
    pub prefix_hold: Option<InstanceId>,
    pub latency: RequestLatency,
    /// Last time a token was emitted (TPOT gap tracking).
    pub last_token_at: Option<Time>,
    pub tpot_sum: f64,
    pub tpot_max: f64,
}

impl SimRequest {
    pub fn remaining(&self) -> u32 {
        self.output_len.saturating_sub(self.generated)
    }

    /// Current KV token footprint: prompt + generated.
    pub fn kv_tokens(&self) -> u64 {
        self.prompt_len as u64 + self.generated as u64
    }

    /// Tokens the next prefill pass must actually compute: the full
    /// footprint minus any prefix-cache hit. `cached_prefix` is stable
    /// for the whole prefill pipeline (set before enqueue, cleared only
    /// at admission or prefix-transfer completion), so charge and release
    /// always agree.
    pub fn prefill_tokens(&self) -> u64 {
        self.kv_tokens().saturating_sub(self.cached_prefix)
    }
}
