//! Sharded simulation core: per-shard event queues with a deterministic
//! merge (DESIGN.md §17).
//!
//! The cluster's instances are partitioned into `n_shards` groups by
//! `instance % n_shards`; instance-local events (decode steps, drains,
//! faults, recoveries) live in that shard's [`EventQueue`], while
//! cluster-scoped events (arrivals, control ticks, migrations, prefix
//! transfers, session follow-ups, readiness) live in a coordinator
//! queue. [`ShardedQueue::pop`] runs a merge tournament over the queue
//! heads using exactly the per-heap comparison key
//! `(time, OrderKey, global seq)`.
//!
//! Determinism contract: sequence numbers are assigned by one *global*
//! counter at push time, so the total order `(at, key, seq)` of any
//! event set is a pure function of the push history — not of the
//! partition. Pop order (hence the whole trajectory: trace rows,
//! completions, final report) is therefore bit-for-bit identical for
//! every shard count, and `shards = 1` is exactly the serial engine.
//! Cross-shard interactions need no special casing: a migration or
//! fault re-queue pushed from shard A and consumed by shard B is just
//! an event routed to B's queue, globally ordered like every other.

use std::cmp::Ordering;

use super::events::{Event, EventQueue, OrderKey};
use crate::prng::Pcg64;
use crate::{InstanceId, Time};

/// PRNG stream-id base for per-shard streams: each shard draws from
/// `Pcg64::new(run_seed, SHARD_STREAM_BASE + shard)`, statistically
/// independent of the engine's global streams and of every other shard.
pub const SHARD_STREAM_BASE: u64 = 0x5AD0;

/// Static partition of the cluster into instance groups.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardLayout {
    n_shards: usize,
}

impl ShardLayout {
    /// A layout with `n_shards >= 1` groups (callers validate the
    /// config; a zero here is a programming error).
    pub fn new(n_shards: usize) -> Self {
        assert!(n_shards >= 1, "shard count must be >= 1");
        Self { n_shards }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Home shard of an instance: fixed modulo partition, so the
    /// mapping is stable across scale-ups and independent of event
    /// history.
    pub fn shard_of_instance(&self, instance: InstanceId) -> usize {
        instance % self.n_shards
    }

    /// Route an event: `Some(shard)` for instance-local events,
    /// `None` for cluster-scoped events handled by the coordinator
    /// queue (arrivals and control ticks have no home instance yet;
    /// migrations, prefix transfers and readiness change the partition
    /// a request or instance belongs to, so they synchronize through
    /// the coordinator as explicit inter-shard messages).
    pub(super) fn shard_of_event(&self, event: &Event) -> Option<usize> {
        match *event {
            Event::DecodeStep { instance, .. }
            | Event::DrainComplete { instance }
            | Event::InstanceFailure { instance, .. }
            | Event::InstanceRecovered { instance } => {
                Some(self.shard_of_instance(instance))
            }
            Event::Arrival { .. }
            | Event::PrefillDone { .. }
            | Event::MigrationDone { .. }
            | Event::SchedulerTick
            | Event::SessionFollowUp { .. }
            | Event::ScaleTick
            | Event::InstanceReady { .. }
            | Event::PrefixTransferDone { .. } => None,
        }
    }

    /// Per-shard PRNG stream split off the run seed. Same `(seed,
    /// shard)` always yields the same stream; distinct shards get
    /// statistically independent streams (PCG stream selection).
    pub fn shard_rng(&self, seed: u64, shard: usize) -> Pcg64 {
        debug_assert!(shard < self.n_shards);
        Pcg64::new(seed, SHARD_STREAM_BASE + shard as u64)
    }
}

/// Compare two `(time, key, seq)` ordering triples with the same total
/// order the per-queue heaps use (earliest first; NaN-free times are an
/// engine invariant, enforced at push).
fn cmp_order(x: &(Time, OrderKey, u64), y: &(Time, OrderKey, u64)) -> Ordering {
    x.0.partial_cmp(&y.0)
        .unwrap_or(Ordering::Equal)
        .then(x.1.cmp(&y.1))
        .then(x.2.cmp(&y.2))
}

/// `n_shards` per-shard [`EventQueue`]s plus a coordinator queue,
/// merged on pop. Drop-in replacement for a single `EventQueue` in the
/// engine: same `push`/`pop` surface, identical pop order for every
/// shard count (see module docs for why).
#[derive(Debug)]
pub struct ShardedQueue {
    layout: ShardLayout,
    /// Per-shard queues, indexed by shard id (fixed merge scan order).
    shards: Vec<EventQueue>,
    /// Cluster-scoped events: arrivals, ticks, cross-shard messages.
    coordinator: EventQueue,
    /// Global push counter shared by all queues — the keystone of the
    /// partition-invariance argument.
    seq: u64,
    len: usize,
}

impl ShardedQueue {
    pub fn new(layout: ShardLayout) -> Self {
        let shards = (0..layout.n_shards()).map(|_| EventQueue::new()).collect();
        Self {
            layout,
            shards,
            coordinator: EventQueue::new(),
            seq: 0,
            len: 0,
        }
    }

    pub fn layout(&self) -> ShardLayout {
        self.layout
    }

    /// Schedule `event` at `at`: assign the next global sequence
    /// number, then route to the home shard's queue (or the
    /// coordinator's for cluster-scoped events).
    pub fn push(&mut self, at: Time, event: Event) {
        self.seq += 1;
        let seq = self.seq;
        match self.layout.shard_of_event(&event) {
            Some(s) => self.shards[s].push_seq(at, seq, event),
            None => self.coordinator.push_seq(at, seq, event),
        }
        self.len += 1;
    }

    /// Pop the globally-earliest event: a merge tournament over the
    /// coordinator head and each shard head in fixed shard order. The
    /// winner is unique (global seq never repeats), so scan order only
    /// fixes the comparison sequence, not the result.
    pub fn pop(&mut self) -> Option<(Time, Event)> {
        let mut best: Option<((Time, OrderKey, u64), usize)> =
            self.coordinator.peek_order().map(|k| (k, 0));
        for (i, q) in self.shards.iter().enumerate() {
            if let Some(k) = q.peek_order() {
                let wins = match &best {
                    None => true,
                    Some((bk, _)) => cmp_order(&k, bk) == Ordering::Less,
                };
                if wins {
                    best = Some((k, i + 1));
                }
            }
        }
        let (_, which) = best?;
        self.len -= 1;
        if which == 0 {
            self.coordinator.pop()
        } else {
            self.shards[which - 1].pop()
        }
    }

    #[allow(dead_code)]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[allow(dead_code)]
    pub fn len(&self) -> usize {
        self.len
    }

    /// Events currently resident in shard `s`'s queue (bench/diagnostic
    /// visibility into partition balance).
    #[allow(dead_code)]
    pub fn shard_len(&self, s: usize) -> usize {
        self.shards[s].len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A workload of events with pairwise-distinct `(at, key)` — the
    /// regime where pop order must not depend on push order or on the
    /// partition.
    fn mixed_events() -> Vec<(Time, Event)> {
        let mut evs = Vec::new();
        for r in 0..6u64 {
            evs.push((1.0, Event::Arrival { request: r }));
        }
        for i in 0..5usize {
            evs.push((
                1.0,
                Event::DecodeStep {
                    instance: i,
                    epoch: 1,
                },
            ));
            evs.push((
                2.5,
                Event::DecodeStep {
                    instance: i,
                    epoch: 2,
                },
            ));
            evs.push((2.5, Event::DrainComplete { instance: i }));
        }
        evs.push((1.0, Event::SchedulerTick));
        evs.push((2.5, Event::ScaleTick));
        evs.push((
            2.5,
            Event::InstanceFailure {
                instance: 2,
                down_s: 5.0,
            },
        ));
        evs.push((3.0, Event::InstanceRecovered { instance: 2 }));
        evs.push((
            1.5,
            Event::MigrationDone {
                request: 3,
                from: 0,
                to: 1,
                kv_tokens: 64,
            },
        ));
        evs.push((
            1.5,
            Event::SessionFollowUp {
                session: 1,
                turn: 2,
            },
        ));
        evs
    }

    fn drain(q: &mut ShardedQueue) -> Vec<String> {
        std::iter::from_fn(|| q.pop())
            .map(|(at, e)| format!("{at:.3} {e:?}"))
            .collect()
    }

    #[test]
    fn shard_of_instance_is_modulo() {
        let l = ShardLayout::new(4);
        assert_eq!(l.shard_of_instance(0), 0);
        assert_eq!(l.shard_of_instance(5), 1);
        assert_eq!(l.shard_of_instance(7), 3);
        assert_eq!(ShardLayout::new(1).shard_of_instance(7), 0);
    }

    #[test]
    fn instance_local_events_route_to_home_shard() {
        let l = ShardLayout::new(2);
        assert_eq!(
            l.shard_of_event(&Event::DecodeStep {
                instance: 3,
                epoch: 0
            }),
            Some(1)
        );
        assert_eq!(
            l.shard_of_event(&Event::InstanceFailure {
                instance: 4,
                down_s: 1.0
            }),
            Some(0)
        );
        assert_eq!(l.shard_of_event(&Event::SchedulerTick), None);
        assert_eq!(l.shard_of_event(&Event::Arrival { request: 9 }), None);
    }

    #[test]
    fn pop_order_is_invariant_under_shard_count() {
        let evs = mixed_events();
        let mut orders = Vec::new();
        for n in [1usize, 2, 3, 4, 8] {
            let mut q = ShardedQueue::new(ShardLayout::new(n));
            for (at, e) in evs.clone() {
                q.push(at, e);
            }
            orders.push(drain(&mut q));
        }
        for o in &orders[1..] {
            assert_eq!(o, &orders[0], "pop order must not depend on shard count");
        }
    }

    #[test]
    fn shuffled_insertion_pops_identically() {
        // The satellite regression: same-timestamp ties with distinct
        // keys must pop in key order no matter the push order. Shuffle
        // the push sequence with seed-derived permutations and require
        // identical drains across shuffles AND shard counts.
        let base = mixed_events();
        let mut reference: Option<Vec<String>> = None;
        let layout = ShardLayout::new(4);
        for trial in 0..6u64 {
            let mut evs = base.clone();
            let mut rng = layout.shard_rng(99, (trial % 4) as usize);
            rng.shuffle(&mut evs);
            for n in [1usize, 2, 4] {
                let mut q = ShardedQueue::new(ShardLayout::new(n));
                for (at, e) in evs.clone() {
                    q.push(at, e);
                }
                let got = drain(&mut q);
                match &reference {
                    None => reference = Some(got),
                    Some(want) => assert_eq!(
                        &got, want,
                        "shuffle {trial} x shards {n} reordered ties"
                    ),
                }
            }
        }
    }

    #[test]
    fn sharded_pop_matches_plain_event_queue() {
        let evs = mixed_events();
        let mut plain = EventQueue::new();
        let mut sharded = ShardedQueue::new(ShardLayout::new(4));
        for (at, e) in evs {
            plain.push(at, e.clone());
            sharded.push(at, e);
        }
        let want: Vec<String> = std::iter::from_fn(|| plain.pop())
            .map(|(at, e)| format!("{at:.3} {e:?}"))
            .collect();
        assert_eq!(drain(&mut sharded), want);
    }

    #[test]
    fn len_tracks_push_and_pop() {
        let mut q = ShardedQueue::new(ShardLayout::new(2));
        assert!(q.is_empty());
        q.push(1.0, Event::SchedulerTick);
        q.push(
            1.0,
            Event::DecodeStep {
                instance: 1,
                epoch: 0,
            },
        );
        assert_eq!(q.len(), 2);
        assert_eq!(q.shard_len(0), 0);
        assert_eq!(q.shard_len(1), 1);
        let _ = q.pop();
        let _ = q.pop();
        assert!(q.is_empty());
        assert!(q.pop().is_none());
    }

    #[test]
    fn shard_rngs_are_reproducible_and_distinct() {
        let l = ShardLayout::new(4);
        let mut a = l.shard_rng(7, 0);
        let mut a2 = l.shard_rng(7, 0);
        let mut b = l.shard_rng(7, 1);
        let x = a.next_u64();
        assert_eq!(x, a2.next_u64(), "same (seed, shard) must reproduce");
        assert_ne!(x, b.next_u64(), "distinct shards must get distinct streams");
    }
}
