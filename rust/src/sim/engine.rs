//! The simulator engine: state + event handlers.

use std::collections::VecDeque;

use super::events::{Event, EventQueue};
use super::report::SimReport;
use super::{ReqState, SimRequest};
use crate::config::ExperimentConfig;
use crate::coordinator::{
    ClusterSnapshot, ControlLoop, IncomingRequest, InstanceView, PolicyRegistry, RequestView,
};
use crate::costmodel::{DecodeCostModel, MigrationCostModel, PrefillCostModel};
use crate::kvcache::KvCacheManager;
use crate::metrics::{
    RunningVariance, TraceEvent, TraceRecorder, VarianceOverTime,
};
use crate::predictor::{build_sim_predictor, LengthPredictor, PredictInput};
use crate::workload::Request;
use crate::{InstanceId, RequestId, Result, Time};

/// Substrate parameters for a simulation run. The dispatch / reschedule
/// policies are named by `exp.dispatch_policy` / `exp.reschedule_policy`
/// and built through a [`PolicyRegistry`].
#[derive(Clone, Debug)]
pub struct SimParams {
    pub exp: ExperimentConfig,
    pub decode_cost: DecodeCostModel,
    pub prefill_cost: PrefillCostModel,
    pub migration: MigrationCostModel,
    /// Hard wall on simulated time (safety against livelock).
    pub max_sim_time: Time,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            exp: ExperimentConfig::default(),
            decode_cost: DecodeCostModel::paper_4090d(),
            prefill_cost: PrefillCostModel::paper_4090d(),
            migration: MigrationCostModel::new_25gbps(128 * 1024),
            max_sim_time: 50_000.0,
        }
    }
}

struct PrefillSim {
    queue: VecDeque<RequestId>,
    busy: Option<RequestId>,
}

struct DecodeSim {
    id: InstanceId,
    kv: KvCacheManager,
    active: Vec<RequestId>,
    pending: VecDeque<RequestId>,
    /// A DecodeStep event is in flight.
    stepping: bool,
    epoch: u64,
    /// EWMA of iteration latency in ms (Fig. 3/11/13's metric).
    ewma_iter_ms: f64,
    iters: u64,
    tokens_decoded: u64,
}

/// Event-driven cluster simulator. Drive with [`Simulator::run`].
pub struct Simulator {
    pub params: SimParams,
    now: Time,
    queue: EventQueue,
    requests: Vec<SimRequest>,
    prefill: Vec<PrefillSim>,
    decode: Vec<DecodeSim>,
    control: ControlLoop,
    /// Cost-model-derived iteration time used until real EWMAs exist.
    seed_avg_iter_s: f64,
    predictor: Box<dyn LengthPredictor>,
    pub recorder: TraceRecorder,
    exec_var: VarianceOverTime,
    load_var: VarianceOverTime,
    completed: usize,
    failed: usize,
    oom_events: u64,
    migrations_started: u64,
    output_mean: RunningVariance,
}

impl Simulator {
    /// Build with the builtin policy set. Panics on unknown policy names;
    /// use [`Simulator::with_registry`] for fallible construction or
    /// custom policies.
    pub fn new(params: SimParams, trace: &[Request]) -> Simulator {
        Self::with_registry(params, trace, &PolicyRegistry::with_builtins())
            .expect("builtin policy construction")
    }

    /// Build against an explicit [`PolicyRegistry`] — the extension point
    /// for third-party policies (see `tests/policy_registry.rs`).
    pub fn with_registry(
        params: SimParams,
        trace: &[Request],
        registry: &PolicyRegistry,
    ) -> Result<Simulator> {
        let exp = &params.exp;
        let n_dec = exp.cluster.n_decode;
        let mut control = ControlLoop::from_experiment(exp, params.migration, registry)?;
        let seed_avg_iter_s = params.decode_cost.iter_time(
            exp.cluster.kv_capacity_tokens / 2,
            exp.cluster.max_batch / 2,
        );
        control.observe_avg_iter_s(seed_avg_iter_s);
        let cap = trace.iter().map(|r| r.output_len).max().unwrap_or(512) as f64;
        let predictor = build_sim_predictor(
            exp.predictor,
            cap,
            exp.predictor_rel_err,
            exp.cluster.seed ^ 0x9e37,
        );

        let mut queue = EventQueue::new();
        let mut requests = Vec::with_capacity(trace.len());
        for r in trace {
            queue.push(r.arrival, Event::Arrival { request: r.id });
            requests.push(SimRequest {
                id: r.id,
                arrival: r.arrival,
                prompt_len: r.prompt_len,
                output_len: r.output_len,
                generated: 0,
                state: ReqState::Prefill,
                predicted_remaining: None,
                iters_since_predict: 0,
                latency: crate::metrics::RequestLatency {
                    arrival: r.arrival,
                    ..Default::default()
                },
                last_token_at: None,
                tpot_sum: 0.0,
                tpot_max: 0.0,
            });
        }
        queue.push(exp.rescheduler.interval_s, Event::SchedulerTick);

        Ok(Simulator {
            control,
            seed_avg_iter_s,
            predictor,
            recorder: TraceRecorder::new(exp.record_traces),
            exec_var: VarianceOverTime::new(),
            load_var: VarianceOverTime::new(),
            now: 0.0,
            requests,
            prefill: (0..exp.cluster.n_prefill)
                .map(|_| PrefillSim {
                    queue: VecDeque::new(),
                    busy: None,
                })
                .collect(),
            decode: (0..n_dec)
                .map(|id| DecodeSim {
                    id,
                    kv: KvCacheManager::new(
                        exp.cluster.kv_capacity_tokens,
                        exp.cluster.block_tokens,
                    ),
                    active: Vec::new(),
                    pending: VecDeque::new(),
                    stepping: false,
                    epoch: 0,
                    ewma_iter_ms: 0.0,
                    iters: 0,
                    tokens_decoded: 0,
                })
                .collect(),
            queue,
            completed: 0,
            failed: 0,
            oom_events: 0,
            migrations_started: 0,
            output_mean: RunningVariance::new(),
            params,
        })
    }

    /// Run to completion (all requests done/failed) or the time cap.
    pub fn run(mut self) -> SimReport {
        while let Some((at, ev)) = self.queue.pop() {
            debug_assert!(at + 1e-9 >= self.now, "time went backwards");
            self.now = at.max(self.now);
            if self.now > self.params.max_sim_time {
                break;
            }
            match ev {
                Event::Arrival { request } => self.on_arrival(request),
                Event::PrefillDone { prefill, request } => self.on_prefill_done(prefill, request),
                Event::DecodeStep { instance, epoch } => self.on_decode_step(instance, epoch),
                Event::MigrationDone { request, from, to } => {
                    self.on_migration_done(request, from, to)
                }
                Event::SchedulerTick => self.on_scheduler_tick(),
            }
            if self.completed + self.failed == self.requests.len() {
                break;
            }
        }
        self.into_report()
    }

    // ------------------------------------------------------------------
    // arrival + prefill

    fn on_arrival(&mut self, id: RequestId) {
        self.recorder.record(self.now, TraceEvent::Arrived { request: id });
        // prefill instance selection: shortest queue (paper §2.1: by load)
        let pi = (0..self.prefill.len())
            .min_by_key(|&i| self.prefill[i].queue.len() + self.prefill[i].busy.is_some() as usize)
            .expect("at least one prefill instance");
        self.prefill[pi].queue.push_back(id);
        self.maybe_start_prefill(pi);
    }

    fn maybe_start_prefill(&mut self, pi: usize) {
        if self.prefill[pi].busy.is_some() {
            return;
        }
        let Some(id) = self.prefill[pi].queue.pop_front() else {
            return;
        };
        self.prefill[pi].busy = Some(id);
        // recompute passes re-process prompt + generated tokens
        let tokens = self.requests[id as usize].kv_tokens();
        let dt = self.params.prefill_cost.time(tokens);
        self.queue.push(
            self.now + dt,
            Event::PrefillDone {
                prefill: pi,
                request: id,
            },
        );
    }

    fn on_prefill_done(&mut self, pi: usize, id: RequestId) {
        debug_assert_eq!(self.prefill[pi].busy, Some(id));
        self.prefill[pi].busy = None;

        // initial (or refreshed, after recompute) length prediction
        let pred = {
            let r = &self.requests[id as usize];
            self.predictor.predict(&PredictInput {
                id,
                generated: r.generated,
                true_remaining: Some(r.remaining()),
            })
        };
        let r = &mut self.requests[id as usize];
        r.predicted_remaining = pred;
        r.latency.prefill_done = Some(self.now);
        self.recorder.record(
            self.now,
            TraceEvent::PrefillDone {
                request: id,
                instance: pi,
            },
        );

        // dispatch to a decode instance (the common P2D baseline layer)
        let kv_tokens = self.requests[id as usize].kv_tokens();
        let snapshot = self.snapshot();
        let di = self.control.dispatch(
            &snapshot,
            &IncomingRequest {
                id,
                tokens: kv_tokens,
                predicted_remaining: pred,
            },
        );

        if kv_tokens > self.decode[di].kv.capacity_tokens() {
            // cannot ever fit: fail the request (counted, not silently lost)
            self.requests[id as usize].state = ReqState::Done;
            self.failed += 1;
        } else {
            self.requests[id as usize].state = ReqState::Pending(di);
            self.decode[di].pending.push_back(id);
            self.kick(di);
        }
        self.maybe_start_prefill(pi);
    }

    // ------------------------------------------------------------------
    // decode

    /// Admit pending requests into the running batch and (re)schedule the
    /// next iteration if the instance has work but no step in flight.
    /// Admission is first-fit over the whole queue (vLLM-style): a huge
    /// request at the head must not starve small ones behind it.
    fn kick(&mut self, di: usize) {
        let mut idx = 0;
        while idx < self.decode[di].pending.len() {
            if self.decode[di].active.len() >= self.params.exp.cluster.max_batch {
                break;
            }
            let id = self.decode[di].pending[idx];
            let need = self.requests[id as usize].kv_tokens();
            // admission watermark (vLLM-style): keep growth headroom so
            // running requests do not immediately OOM-thrash
            let cap = self.decode[di].kv.capacity_tokens();
            let ok = self.decode[di].kv.used_tokens() + need <= cap * 9 / 10
                && self.decode[di].kv.would_fit(need);
            if ok {
                self.decode[di].pending.remove(idx);
                self.decode[di]
                    .kv
                    .admit(id, need, di)
                    .expect("would_fit checked");
                self.requests[id as usize].state = ReqState::Decoding(di);
                self.decode[di].active.push(id);
            } else {
                idx += 1;
            }
        }
        if !self.decode[di].active.is_empty() && !self.decode[di].stepping {
            self.schedule_step(di);
        }
    }

    fn schedule_step(&mut self, di: usize) {
        let d = &mut self.decode[di];
        d.stepping = true;
        d.epoch += 1;
        // prediction overhead lands on iterations where repredictions fire
        let k = self.params.exp.rescheduler.predict_every_iters.max(1);
        let mut n_pred = 0usize;
        for &id in &d.active {
            if self.requests[id as usize].iters_since_predict + 1 >= k {
                n_pred += 1;
            }
        }
        let tokens: u64 = d
            .active
            .iter()
            .map(|&id| self.requests[id as usize].kv_tokens())
            .sum();
        let mut dt = self
            .params
            .decode_cost
            .iter_time(tokens, d.active.len());
        if n_pred > 0 {
            dt += self.predictor.cost_s(n_pred);
        }
        let at = self.now + dt;
        // EWMA of iteration latency for the exec-variance metric
        let ms = dt * 1e3;
        d.ewma_iter_ms = if d.iters == 0 {
            ms
        } else {
            0.9 * d.ewma_iter_ms + 0.1 * ms
        };
        let epoch = d.epoch;
        self.queue.push(at, Event::DecodeStep { instance: di, epoch });
    }

    fn on_decode_step(&mut self, di: usize, epoch: u64) {
        if self.decode[di].epoch != epoch {
            return; // stale event (batch was rebuilt)
        }
        self.decode[di].stepping = false;
        self.decode[di].iters += 1;

        let batch: Vec<RequestId> = self.decode[di].active.clone();
        let k = self.params.exp.rescheduler.predict_every_iters.max(1);
        let mut finished: Vec<RequestId> = Vec::new();
        let mut evicted: Vec<RequestId> = Vec::new();

        for &id in &batch {
            // a request migrated out mid-iteration is paused: no token
            if !matches!(self.requests[id as usize].state, ReqState::Decoding(d) if d == di) {
                continue;
            }
            if evicted.contains(&id) {
                continue; // evicted by an earlier OOM in this same step
            }
            // KV append (may OOM -> evict victims -> retry once)
            if let Err(_) = self.decode[di].kv.append_token(id, di) {
                let victims = self.handle_oom(di, id);
                evicted.extend(victims);
                if evicted.contains(&id) {
                    continue;
                }
                if self.decode[di].kv.append_token(id, di).is_err() {
                    // nothing evictable freed room (everything else is
                    // mid-migration): this request itself recomputes
                    let vs = self.evict_requests(di, vec![id]);
                    evicted.extend(vs);
                    continue;
                }
            }
            let r = &mut self.requests[id as usize];
            r.generated += 1;
            r.iters_since_predict += 1;
            self.decode[di].tokens_decoded += 1;
            if r.latency.first_token.is_none() {
                r.latency.first_token = Some(self.now);
            }
            if let Some(prev) = r.last_token_at {
                let gap = self.now - prev;
                r.tpot_sum += gap;
                r.tpot_max = r.tpot_max.max(gap);
            }
            r.last_token_at = Some(self.now);

            if r.generated >= r.output_len {
                finished.push(id);
            } else if r.iters_since_predict >= k {
                r.iters_since_predict = 0;
                let input = PredictInput {
                    id,
                    generated: r.generated,
                    true_remaining: Some(r.output_len - r.generated),
                };
                let p = self.predictor.predict(&input);
                self.requests[id as usize].predicted_remaining = p;
            }
        }

        for id in finished {
            self.finish_request(di, id);
        }
        self.kick(di);
    }

    /// OOM on `di` while appending for `for_id`: evict the largest
    /// requests (vLLM recompute semantics) and send them back to prefill.
    /// Returns the victim list.
    fn handle_oom(&mut self, di: usize, _for_id: RequestId) -> Vec<RequestId> {
        self.oom_events += 1;
        // free a breathing-room chunk (~4% of capacity), not just one
        // block: per-block eviction re-OOMs on the very next append
        let chunk = (self.decode[di].kv.capacity_tokens()
            / (self.params.exp.cluster.block_tokens as u64 * 25)) as usize;
        // take the full cheapest-first ordering, then keep only requests
        // actively decoding HERE: a migrating request's KV is still
        // registered on the source but its lifecycle is owned by the
        // migration (evicting it would admit it twice)
        let victims: Vec<RequestId> = self
            .decode[di]
            .kv
            .eviction_victims(usize::MAX)
            .into_iter()
            .filter(|&v| matches!(self.requests[v as usize].state,
                                  ReqState::Decoding(d) if d == di))
            .scan(0usize, |freed, v| {
                if *freed >= chunk.max(1) {
                    return None;
                }
                *freed += (self.requests[v as usize].kv_tokens() as usize)
                    .div_ceil(self.params.exp.cluster.block_tokens as usize);
                Some(v)
            })
            .collect();
        self.recorder.record(
            self.now,
            TraceEvent::Oom {
                instance: di,
                victims: victims.len(),
            },
        );
        self.evict_requests(di, victims)
    }

    /// Evict `victims` from instance `di` for KV recompute: release their
    /// blocks and send them back through prefill (vLLM recompute
    /// semantics). Requests that can never fit are failed terminally.
    fn evict_requests(&mut self, di: usize, victims: Vec<RequestId>) -> Vec<RequestId> {
        let cap = self.decode[di].kv.capacity_tokens();
        let block = self.params.exp.cluster.block_tokens as u64;
        for &v in &victims {
            self.decode[di].kv.release(v);
            self.decode[di].active.retain(|&x| x != v);
            let r = &mut self.requests[v as usize];
            r.latency.hit_oom = true;
            r.last_token_at = None; // recompute stall shows up as TTFT-like gap
            if r.kv_tokens() + block >= cap {
                // cannot ever make progress on any instance of this size:
                // terminal failure (vLLM would abort the request too)
                r.state = ReqState::Done;
                self.failed += 1;
            } else {
                r.state = ReqState::Recomputing;
                // recompute = re-run prefill over prompt+generated
                self.queue.push(self.now, Event::Arrival { request: v });
            }
        }
        victims
    }

    fn finish_request(&mut self, di: usize, id: RequestId) {
        self.decode[di].kv.release(id);
        self.decode[di].active.retain(|&x| x != id);
        let r = &mut self.requests[id as usize];
        r.state = ReqState::Done;
        r.latency.finished = Some(self.now);
        r.latency.output_tokens = r.generated;
        if r.generated > 1 {
            // mean gap between consecutive tokens, including migration stalls
            r.latency.mean_tpot = Some(r.tpot_sum / (r.generated - 1) as f64);
            r.latency.max_tpot = Some(r.tpot_max);
        } else {
            r.latency.mean_tpot = Some(0.0);
            r.latency.max_tpot = Some(0.0);
        }
        self.output_mean.push(r.generated as f64);
        self.completed += 1;
        self.recorder.record(
            self.now,
            TraceEvent::Finished {
                request: id,
                instance: di,
            },
        );
    }

    // ------------------------------------------------------------------
    // rescheduling + migration

    fn snapshot(&self) -> ClusterSnapshot {
        let instances = self
            .decode
            .iter()
            .map(|d| InstanceView {
                id: d.id,
                requests: d
                    .active
                    .iter()
                    .map(|&id| {
                        let r = &self.requests[id as usize];
                        RequestView {
                            id,
                            tokens: r.kv_tokens(),
                            predicted_remaining: r.predicted_remaining,
                            migrating: matches!(r.state, ReqState::Migrating { .. }),
                        }
                    })
                    .collect(),
                kv_capacity_tokens: d.kv.capacity_tokens(),
                inbound_reserved_tokens: self.inbound_reserved(d.id),
            })
            .collect();
        let avg_iter = self.avg_iter_s();
        ClusterSnapshot {
            instances,
            tokens_per_interval: self.params.exp.rescheduler.interval_s / avg_iter.max(1e-6),
        }
    }

    fn inbound_reserved(&self, di: InstanceId) -> u64 {
        self.requests
            .iter()
            .filter_map(|r| match r.state {
                ReqState::Migrating { to, .. } if to == di => Some(r.kv_tokens()),
                _ => None,
            })
            .sum()
    }

    fn avg_iter_s(&self) -> f64 {
        let busy: Vec<f64> = self
            .decode
            .iter()
            .filter(|d| d.iters > 0)
            .map(|d| d.ewma_iter_ms / 1e3)
            .collect();
        if busy.is_empty() {
            self.seed_avg_iter_s
        } else {
            busy.iter().sum::<f64>() / busy.len() as f64
        }
    }

    fn on_scheduler_tick(&mut self) {
        // metrics snapshots (taken whether or not rescheduling is on)
        let iters: Vec<f64> = self
            .decode
            .iter()
            .map(|d| if d.active.is_empty() { 0.0 } else { d.ewma_iter_ms })
            .collect();
        self.exec_var.snapshot(self.now, &iters);
        let loads: Vec<f64> = self
            .decode
            .iter()
            .map(|d| d.kv.used_tokens() as f64)
            .collect();
        self.load_var.snapshot(self.now, &loads);
        for d in &self.decode {
            self.recorder.record(
                self.now,
                TraceEvent::KvSample {
                    instance: d.id,
                    kv_frac: d.kv.usage_frac(),
                    tokens: d.kv.used_tokens(),
                    batch: d.active.len(),
                },
            );
        }

        if self.control.rescheduling_enabled() {
            self.control.observe_avg_iter_s(self.avg_iter_s());
            if self.output_mean.count() > 10 {
                self.control
                    .observe_default_remaining(self.output_mean.mean() / 2.0);
            }
            let snapshot = self.snapshot();
            let decisions = self.control.reschedule(&snapshot);
            for d in decisions {
                self.start_migration(d.request, d.src, d.dst, d.kv_tokens);
            }
        }

        self.queue.push(
            self.now + self.params.exp.rescheduler.interval_s,
            Event::SchedulerTick,
        );
    }

    fn start_migration(&mut self, id: RequestId, from: InstanceId, to: InstanceId, kv: u64) {
        let r = &mut self.requests[id as usize];
        debug_assert!(matches!(r.state, ReqState::Decoding(d) if d == from));
        r.state = ReqState::Migrating { from, to };
        r.latency.migrations += 1;
        self.migrations_started += 1;
        // pause: out of the running batch immediately (overlap: the rest
        // of the batch keeps decoding, §5.4)
        self.decode[from].active.retain(|&x| x != id);
        self.recorder.record(
            self.now,
            TraceEvent::Migration {
                request: id,
                src: from,
                dst: to,
                kv_tokens: kv,
            },
        );
        let dt = self.params.migration.transfer_time(kv);
        self.queue.push(self.now + dt, Event::MigrationDone { request: id, from, to });
    }

    fn on_migration_done(&mut self, id: RequestId, from: InstanceId, to: InstanceId) {
        // source frees its copy only after the transfer (both sides hold
        // KV during the copy, as with NIXL)
        self.decode[from].kv.release(id);
        let r = &mut self.requests[id as usize];
        debug_assert!(matches!(r.state, ReqState::Migrating { .. }));
        r.state = ReqState::Pending(to);
        self.decode[to].pending.push_back(id);
        self.kick(to);
        self.kick(from);
    }

    // ------------------------------------------------------------------

    fn into_report(self) -> SimReport {
        let mut report = SimReport {
            duration: self.now,
            completed: Vec::new(),
            n_failed: self.failed,
            n_requests: self.requests.len(),
            oom_events: self.oom_events,
            migrations: self.migrations_started,
            exec_var: self.exec_var,
            load_var: self.load_var,
            recorder: self.recorder,
            scheduler_stats: self.control.stats(),
            per_instance_tokens: self.decode.iter().map(|d| d.tokens_decoded).collect(),
        };
        for r in self.requests {
            if matches!(r.state, ReqState::Done) && r.latency.finished.is_some() {
                report.completed.push(r.latency);
            }
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PredictorKind;
    use crate::workload::{Dataset, TraceGen};

    fn small_params(n_req: usize, rps: f64) -> (SimParams, Vec<Request>) {
        let mut exp = ExperimentConfig::default();
        exp.cluster.n_decode = 3;
        exp.cluster.n_requests = n_req;
        exp.cluster.rps = rps;
        exp.cluster.kv_capacity_tokens = 200_000;
        exp.predictor = PredictorKind::Oracle;
        let trace = TraceGen::new(Dataset::ShareGpt, rps).generate(n_req, 42);
        (
            SimParams {
                exp,
                ..Default::default()
            },
            trace,
        )
    }

    #[test]
    fn all_requests_complete() {
        let (p, trace) = small_params(40, 0.5);
        let report = Simulator::new(p, &trace).run();
        assert_eq!(report.completed.len() + report.n_failed, 40);
        assert!(report.metrics().throughput() > 0.0);
    }

    #[test]
    fn tokens_generated_match_trace() {
        let (p, trace) = small_params(20, 0.5);
        let report = Simulator::new(p, &trace).run();
        let total_out: u32 = report.completed.iter().map(|l| l.output_tokens).sum();
        let expect: u32 = trace.iter().map(|r| r.output_len).sum();
        assert_eq!(total_out, expect);
    }

    #[test]
    fn latencies_are_ordered() {
        let (p, trace) = small_params(25, 1.0);
        let report = Simulator::new(p, &trace).run();
        for l in &report.completed {
            let ft = l.first_token.unwrap();
            let fin = l.finished.unwrap();
            assert!(l.arrival <= l.prefill_done.unwrap());
            assert!(l.prefill_done.unwrap() <= ft + 1e-9);
            assert!(ft <= fin + 1e-9);
        }
    }

    #[test]
    fn rescheduling_triggers_migrations_under_skew() {
        let (mut p, trace) = small_params(60, 1.2);
        p.exp.rescheduler.enabled = true;
        p.exp.rescheduler.interval_s = 0.5;
        let report = Simulator::new(p, &trace).run();
        assert!(
            report.migrations > 0,
            "heavy-tail ShareGPT load should trigger at least one migration"
        );
    }

    #[test]
    fn disabled_rescheduler_never_migrates() {
        let (mut p, trace) = small_params(60, 1.2);
        p.exp.rescheduler.enabled = false;
        let report = Simulator::new(p, &trace).run();
        assert_eq!(report.migrations, 0);
    }

    #[test]
    fn tight_memory_produces_ooms_without_rescheduling() {
        let (mut p, trace) = small_params(60, 2.0);
        p.exp.rescheduler.enabled = false;
        p.exp.cluster.kv_capacity_tokens = 30_000; // tight
        let report = Simulator::new(p, &trace).run();
        assert!(report.oom_events > 0, "expected OOMs under tight memory");
        // OOM victims recompute and still finish
        assert_eq!(report.completed.len() + report.n_failed, 60);
    }

    #[test]
    fn deterministic_runs() {
        let (p, trace) = small_params(30, 1.0);
        let r1 = Simulator::new(p.clone(), &trace).run();
        let r2 = Simulator::new(p, &trace).run();
        assert_eq!(r1.completed.len(), r2.completed.len());
        assert!((r1.duration - r2.duration).abs() < 1e-9);
        assert_eq!(r1.migrations, r2.migrations);
    }
}
