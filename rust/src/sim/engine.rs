//! The simulator engine: state + event handlers.
//!
//! Scheduler-visible cluster state lives in a
//! [`crate::coordinator::ClusterState`] updated by O(1) deltas at every
//! mutation point (admission, token append, release, migration
//! start/finish, reprediction), so dispatch and rescheduling decisions at
//! Fig. 13 scale (256 decode instances, ≥50k requests) never rebuild a
//! full snapshot. [`StateMode::RebuildPerDecision`] preserves the old
//! from-scratch materialization as a differential baseline —
//! `benches/bench_sim_core.rs` quantifies the gap and
//! [`SimParams::validate_state`] proves the two agree after every event.

use std::collections::{BTreeMap, VecDeque};

use super::events::Event;
use super::report::{ReliabilityReport, SimReport};
use super::shard::{ShardLayout, ShardedQueue};
use super::{ReqState, SimRequest};
use crate::config::ExperimentConfig;
use crate::coordinator::{
    admission_watermark, ClusterSnapshot, ClusterState, ControlLoop, HardwareProfile,
    IncomingRequest, InstanceView, Lifecycle, PolicyRegistry, PoolRole, PoolStats, RateMeter,
    RequestView, ScaleRecord, ScalingAction, ShardRollup,
};
use crate::costmodel::{DecodeCostModel, MigrationCostModel, PrefillCostModel};
use crate::kvcache::{CacheContext, CachePolicyRegistry, KvCacheManager, PrefixCache};
use crate::metrics::{PoolSample, RunningVariance, TraceEvent, TraceRecorder, VarianceOverTime};
use crate::obs::MetricsRegistry;
use crate::predictor::{
    LengthPredictor, PredSample, PredictInput, Prediction, PredictorContext, PredictorRegistry,
    Repredictor, Scorecard,
};
use crate::prng::Pcg64;
use crate::workload::{FleetSpec, Request, ScenarioTrace, SessionPlan};
use crate::{InstanceId, RequestId, Result, Time};

/// PRNG stream id for stochastic fault injection ("FAUL") — its own
/// stream off the run seed, so enabling faults never perturbs the
/// workload, predictor, or scenario draws.
const FAULT_STREAM: u64 = 0x4641_554c;

/// How scheduling decisions read cluster state.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum StateMode {
    /// Borrow views from the incremental [`ClusterState`] (O(1) per
    /// decision; the production path).
    #[default]
    Incremental,
    /// Materialize a from-scratch [`ClusterSnapshot`] before every
    /// dispatch and scheduler tick — the pre-incremental behaviour,
    /// O(instances × requests) per decision. Kept as the differential /
    /// benchmark baseline (`bench_sim_core`).
    RebuildPerDecision,
}

/// Substrate parameters for a simulation run. The dispatch / reschedule
/// policies are named by `exp.dispatch_policy` / `exp.reschedule_policy`
/// and built through a [`PolicyRegistry`].
#[derive(Clone, Debug)]
pub struct SimParams {
    pub exp: ExperimentConfig,
    pub decode_cost: DecodeCostModel,
    pub prefill_cost: PrefillCostModel,
    pub migration: MigrationCostModel,
    /// Hard wall on simulated time (safety against livelock).
    pub max_sim_time: Time,
    /// How policies read cluster state (see [`StateMode`]).
    pub state_mode: StateMode,
    /// After every event, assert the incremental state equals a
    /// from-scratch rebuild (slow; test instrumentation).
    pub validate_state: bool,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            exp: ExperimentConfig::default(),
            decode_cost: DecodeCostModel::paper_4090d(),
            prefill_cost: PrefillCostModel::paper_4090d(),
            migration: MigrationCostModel::new_25gbps(128 * 1024),
            max_sim_time: 50_000.0,
            state_mode: StateMode::Incremental,
            validate_state: false,
        }
    }
}

struct PrefillSim {
    queue: VecDeque<RequestId>,
    busy: Option<RequestId>,
    /// Elastic lifecycle; only `Active` instances receive new requests.
    lifecycle: Lifecycle,
    /// Queued-token load: Σ kv_tokens over queue + busy (paper §2.1
    /// dispatches "by load"; a queue-length rule lets one long prompt
    /// hide an hour of work behind a short queue).
    load_tokens: u64,
    /// When this drain completes, the instance re-roles as decode.
    flip_to_decode: bool,
}

struct DecodeSim {
    id: InstanceId,
    kv: KvCacheManager,
    /// Hardware class (heterogeneous fleets): `speed_mult` divides the
    /// modeled iteration time, `mem_mult` already scaled `kv`'s capacity
    /// at construction. Mirrored into [`ClusterState`] for policies.
    profile: HardwareProfile,
    /// Dispatched but not yet admitted into the running batch. The batch
    /// itself (and every aggregate over it) lives in [`ClusterState`].
    pending: VecDeque<RequestId>,
    /// A DecodeStep event is in flight.
    stepping: bool,
    epoch: u64,
    tokens_decoded: u64,
    /// Elastic lifecycle (mirrored into [`ClusterState`] so policies see
    /// it through their views).
    lifecycle: Lifecycle,
    /// When this drain completes, the instance re-roles as prefill.
    flip_to_prefill: bool,
    /// A DrainComplete event is already queued (dedupe).
    drain_event_queued: bool,
}

/// Event-driven cluster simulator. Drive with [`Simulator::run`].
pub struct Simulator {
    pub params: SimParams,
    now: Time,
    /// Sharded event queue: per-shard heaps merged deterministically on
    /// pop (DESIGN.md §17). With `[sim] shards = 1` this degenerates to
    /// the classic single serial queue; for any shard count the pop
    /// order — and hence the whole trajectory — is identical.
    queue: ShardedQueue,
    requests: Vec<SimRequest>,
    prefill: Vec<PrefillSim>,
    decode: Vec<DecodeSim>,
    /// Incremental scheduler-visible state (batches, loads, reservations,
    /// iteration-time EWMAs) — updated by O(1) deltas alongside the
    /// authoritative per-request records above.
    state: ClusterState,
    control: ControlLoop,
    predictor: Box<dyn LengthPredictor>,
    /// Shared reprediction schedule (the SAME batched due-slot scan the
    /// live decode instances run — `predictor::Repredictor`).
    repredictor: Repredictor,
    /// Online calibration accumulator, folded at request completion.
    scorecard: Scorecard,
    pub recorder: TraceRecorder,
    exec_var: VarianceOverTime,
    load_var: VarianceOverTime,
    completed: usize,
    failed: usize,
    oom_events: u64,
    migrations_started: u64,
    output_mean: RunningVariance,
    /// Multi-round session scripts (scenario workloads; empty otherwise).
    sessions: SessionPlan,
    /// request id -> (session, index of its successor turn in the script).
    session_cursor: BTreeMap<RequestId, (u32, u32)>,
    /// Realized request-id chains per session, in turn order.
    session_chains: Vec<Vec<RequestId>>,
    /// Follow-up events scheduled but not yet fired (their request records
    /// do not exist yet, so the termination check must wait for them).
    pending_follow_ups: usize,
    // -- prefix cache --------------------------------------------------
    /// Session-prefix KV retained across turns (inert under `none`).
    prefix_cache: PrefixCache,
    /// Σ tokens of in-flight prefix holds per decode instance: a hit's
    /// reused prefix stays accounted on its holder (mirrored into
    /// [`ClusterState`]'s cached-token aggregate) from `take` until the
    /// request is admitted or the hold is abandoned.
    hold_tokens: Vec<u64>,
    // -- elastic pool state --------------------------------------------
    /// Instances warming up toward each pool (provision or flip).
    prefill_provisioning: usize,
    decode_provisioning: usize,
    /// Pool composition, sampled once per ScaleTick.
    pool_timeline: Vec<PoolSample>,
    /// Executed scaling actions (the scale-action trace).
    scale_log: Vec<ScaleRecord>,
    /// Shared arrival / prefill-service rate meter (the predictive
    /// policies' measured inputs; same definition as the live driver).
    rates: RateMeter,
    last_scale_t: Time,
    // -- fault injection -----------------------------------------------
    /// Fleet shape for heterogeneous runs: profiles cycled over decode
    /// instance ids, including elastic joins. `None` = uniform hardware.
    fleet: Option<FleetSpec>,
    /// Fault-injection accounting, folded into the report.
    reliability: ReliabilityReport,
    /// Crash time of every request re-queued by a failure, resolved into
    /// `reliability.requeue_delays` at its next successful admission.
    fault_requeue: BTreeMap<RequestId, Time>,
    // -- observability -------------------------------------------------
    /// `[obs]` metrics registry: counters/gauges/histograms plus the
    /// sampled time series. Every mutator is a no-op while disabled, so
    /// the default-off path stays bit-for-bit identical.
    registry: MetricsRegistry,
    /// Next due time of the `[obs] sample_every_s` series clock.
    next_obs_sample: Time,
}

/// Event-coverage list for the invariant checker: every [`Event`] variant
/// [`Simulator::run`] dispatches must be named here, so adding an event
/// forces a decision about which invariants it preserves. Checked at
/// runtime under `validate_state` and statically by `star analyze` R5
/// (which also requires each variant to be matched in `run`).
pub const VALIDATED_EVENTS: &[&str] = &[
    "Arrival",
    "PrefillDone",
    "DecodeStep",
    "MigrationDone",
    "SchedulerTick",
    "SessionFollowUp",
    "ScaleTick",
    "InstanceReady",
    "DrainComplete",
    "PrefixTransferDone",
    "InstanceFailure",
    "InstanceRecovered",
];

impl Simulator {
    /// Build with the builtin policy set. Panics on unknown policy names;
    /// use [`Simulator::with_registry`] for fallible construction or
    /// custom policies.
    pub fn new(params: SimParams, trace: &[Request]) -> Simulator {
        Self::with_registry(params, trace, &PolicyRegistry::with_builtins())
            .expect("builtin policy construction")
    }

    /// Build against an explicit [`PolicyRegistry`] — the extension point
    /// for third-party policies (see `tests/policy_registry.rs`).
    pub fn with_registry(
        params: SimParams,
        trace: &[Request],
        registry: &PolicyRegistry,
    ) -> Result<Simulator> {
        Self::with_scenario(params, ScenarioTrace::from_requests(trace.to_vec()), registry)
    }

    /// Build over a full scenario trace (arrival process + class mix +
    /// multi-round session plan). Follow-up turns are realized at run time
    /// through [`Event::SessionFollowUp`]: turn k+1 arrives only after
    /// turn k completes, with its prompt carrying the accumulated history.
    /// The predictor is resolved by name (`exp.predictor`) against the
    /// builtin [`PredictorRegistry`]; use [`Simulator::with_registries`]
    /// for custom predictors.
    pub fn with_scenario(
        params: SimParams,
        trace: ScenarioTrace,
        registry: &PolicyRegistry,
    ) -> Result<Simulator> {
        Self::with_registries(params, trace, registry, &PredictorRegistry::with_builtins())
    }

    /// Fully-pluggable construction: policies AND predictors resolved by
    /// name against caller-supplied registries — the extension point for
    /// third-party predictors (mirrors the policy path).
    pub fn with_registries(
        params: SimParams,
        trace: ScenarioTrace,
        registry: &PolicyRegistry,
        predictors: &PredictorRegistry,
    ) -> Result<Simulator> {
        let exp = &params.exp;
        let n_dec = exp.cluster.n_decode;
        let mut control = ControlLoop::from_experiment(exp, params.migration, registry)?;
        let seed_avg_iter_s = params.decode_cost.iter_time(
            exp.cluster.kv_capacity_tokens / 2,
            exp.cluster.max_batch / 2,
        );
        control.observe_avg_iter_s(seed_avg_iter_s);
        let cap = trace
            .requests
            .iter()
            .map(|r| r.output_len)
            .chain(
                trace
                    .sessions
                    .scripts
                    .iter()
                    .flatten()
                    .map(|t| t.output_len),
            )
            .max()
            .unwrap_or(512) as f64;
        let predictor = predictors.build(
            &exp.predictor,
            &PredictorContext {
                cap,
                rel_err: exp.predictor_rel_err,
                seed: exp.cluster.seed ^ 0x9e37,
            },
        )?;
        let cache_policy = CachePolicyRegistry::with_builtins().build(
            &exp.kvcache.policy,
            &CacheContext {
                conservative_q: exp.predictor_conservative_q,
            },
        )?;
        let prefix_cache =
            PrefixCache::new(cache_policy, exp.kvcache.budget_tokens, exp.kvcache.ttl_s);

        // `shards` is validated (>= 1) by ExperimentConfig::validate();
        // clamp anyway so hand-built configs cannot panic the layout.
        let mut queue = ShardedQueue::new(ShardLayout::new(exp.shards.max(1)));
        let mut requests = Vec::with_capacity(trace.requests.len());
        for r in &trace.requests {
            debug_assert_eq!(r.id as usize, requests.len(), "trace ids must be dense");
            queue.push(r.arrival, Event::Arrival { request: r.id });
            requests.push(SimRequest {
                id: r.id,
                arrival: r.arrival,
                class: r.class,
                prompt_len: r.prompt_len,
                output_len: r.output_len,
                generated: 0,
                state: ReqState::Prefill,
                predicted_remaining: None,
                iters_since_predict: 0,
                pred_log: Vec::new(),
                cached_prefix: 0,
                prefix_hold: None,
                latency: crate::metrics::RequestLatency {
                    id: r.id,
                    class: r.class,
                    arrival: r.arrival,
                    prompt_tokens: r.prompt_len,
                    suffix_tokens: r.prompt_len,
                    ..Default::default()
                },
                last_token_at: None,
                tpot_sum: 0.0,
                tpot_max: 0.0,
            });
        }
        queue.push(exp.rescheduler.interval_s, Event::SchedulerTick);
        // the scale tick always runs: under `static` scaling it only
        // samples the pool timeline (ControlLoop::scale is a guaranteed
        // no-op), so frozen-pool trajectories are untouched
        queue.push(exp.elastic.scale_interval_s, Event::ScaleTick);

        // fault plan: experiment-level `[faults]` wins over a plan carried
        // by the scenario trace. Scripted failures are pushed verbatim;
        // the stochastic process draws per-instance exponential
        // inter-failure gaps and downtimes from its own PRNG stream, so
        // the schedule is a pure function of (seed, faults config) —
        // same seed ⇒ identical failure times.
        let faults = exp.faults.clone().or_else(|| trace.faults.clone());
        let fleet = exp.fleet.clone().or_else(|| trace.fleet.clone());
        if let Some(fc) = &faults {
            for ev in &fc.script {
                queue.push(
                    ev.at,
                    Event::InstanceFailure {
                        instance: ev.instance,
                        down_s: ev.down_s,
                    },
                );
            }
            if fc.mtbf_s > 0.0 {
                let mut rng = Pcg64::new(exp.cluster.seed, FAULT_STREAM);
                let mut planned: Vec<(Time, usize, f64)> = Vec::new();
                for di in 0..n_dec {
                    let mut t = rng.exponential(1.0 / fc.mtbf_s);
                    while t <= params.max_sim_time {
                        let down = rng.exponential(1.0 / fc.mttr_s);
                        planned.push((t, di, down));
                        t += down + rng.exponential(1.0 / fc.mtbf_s);
                    }
                }
                // global time order (instance id breaks ties) before the
                // cap, so max_failures keeps the EARLIEST failures
                planned.sort_by(|a, b| {
                    a.0.partial_cmp(&b.0)
                        .expect("fault times are finite")
                        .then(a.1.cmp(&b.1))
                });
                planned.truncate(fc.max_failures);
                for (t, di, down) in planned {
                    queue.push(
                        t,
                        Event::InstanceFailure {
                            instance: di,
                            down_s: down,
                        },
                    );
                }
            }
        }

        let mut session_cursor = BTreeMap::new();
        let mut session_chains = vec![Vec::new(); trace.sessions.scripts.len()];
        for &(rid, s) in &trace.sessions.first_turns {
            session_cursor.insert(rid, (s, 0u32));
            session_chains[s as usize].push(rid);
        }

        let decode: Vec<DecodeSim> = (0..n_dec)
            .map(|id| {
                // heterogeneous fleets cycle hardware profiles over ids;
                // mem_mult scales the KV capacity at construction
                let profile = fleet
                    .as_ref()
                    .map_or(HardwareProfile::default(), |f| f.profile(id));
                let cap =
                    (exp.cluster.kv_capacity_tokens as f64 * profile.mem_mult).round() as u64;
                DecodeSim {
                    id,
                    kv: KvCacheManager::new(cap, exp.cluster.block_tokens),
                    profile,
                    pending: VecDeque::new(),
                    stepping: false,
                    epoch: 0,
                    tokens_decoded: 0,
                    lifecycle: Lifecycle::Active,
                    flip_to_prefill: false,
                    drain_event_queued: false,
                }
            })
            .collect();
        let mut state = ClusterState::new(
            n_dec,
            exp.cluster.kv_capacity_tokens,
            exp.rescheduler.interval_s,
            seed_avg_iter_s,
            1e-6,
        );
        for d in &decode {
            // the paged allocator rounds capacity down to whole blocks;
            // the scheduler must see the same number
            state.set_capacity(d.id, d.kv.capacity_tokens());
            state.set_profile(d.id, d.profile);
        }

        Ok(Simulator {
            control,
            predictor,
            repredictor: Repredictor::new(exp.rescheduler.predict_every_iters),
            scorecard: Scorecard::new(),
            // spans need the event rows even when plain trace recording
            // is off: obs force-enables the recorder (recording is
            // passive, so the trajectory is unchanged either way)
            recorder: TraceRecorder::new(exp.record_traces || exp.obs.enabled),
            exec_var: VarianceOverTime::new(),
            load_var: VarianceOverTime::new(),
            now: 0.0,
            requests,
            prefill: (0..exp.cluster.n_prefill)
                .map(|_| PrefillSim {
                    queue: VecDeque::new(),
                    busy: None,
                    lifecycle: Lifecycle::Active,
                    load_tokens: 0,
                    flip_to_decode: false,
                })
                .collect(),
            decode,
            state,
            queue,
            completed: 0,
            failed: 0,
            oom_events: 0,
            migrations_started: 0,
            output_mean: RunningVariance::new(),
            sessions: trace.sessions,
            session_cursor,
            session_chains,
            pending_follow_ups: 0,
            prefix_cache,
            hold_tokens: vec![0; n_dec],
            prefill_provisioning: 0,
            decode_provisioning: 0,
            pool_timeline: Vec::new(),
            scale_log: Vec::new(),
            rates: RateMeter::default(),
            last_scale_t: 0.0,
            fleet,
            reliability: ReliabilityReport::default(),
            fault_requeue: BTreeMap::new(),
            registry: MetricsRegistry::new(exp.obs.enabled),
            next_obs_sample: 0.0,
            params,
        })
    }

    /// Run to completion (all requests done/failed) or the time cap.
    pub fn run(mut self) -> SimReport {
        while let Some((at, ev)) = self.queue.pop() {
            debug_assert!(at + 1e-9 >= self.now, "time went backwards");
            self.now = at.max(self.now);
            if self.now > self.params.max_sim_time {
                break;
            }
            // obs housekeeping rides the event clock: drain the series
            // sample timer and stamp the decision-attribution clock (both
            // no-ops while `[obs] enabled = false`)
            self.drain_obs_samples();
            self.control.set_decision_time(self.now);
            if self.params.validate_state {
                // coverage list first: a new Event variant must be added
                // to VALIDATED_EVENTS (and its invariants to
                // assert_state_consistent) before it may fire. `star
                // analyze` R5 enforces the same list statically.
                assert!(
                    VALIDATED_EVENTS.contains(&ev.name()),
                    "event `{}` missing from the VALIDATED_EVENTS coverage list",
                    ev.name()
                );
            }
            match ev {
                Event::Arrival { request } => self.on_arrival(request),
                Event::PrefillDone { prefill, request } => self.on_prefill_done(prefill, request),
                Event::DecodeStep { instance, epoch } => self.on_decode_step(instance, epoch),
                Event::MigrationDone {
                    request,
                    from,
                    to,
                    kv_tokens,
                } => self.on_migration_done(request, from, to, kv_tokens),
                Event::SchedulerTick => self.on_scheduler_tick(),
                Event::SessionFollowUp { session, turn } => {
                    self.on_session_follow_up(session, turn)
                }
                Event::ScaleTick => self.on_scale_tick(),
                Event::InstanceReady { role } => self.on_instance_ready(role),
                Event::DrainComplete { instance } => self.on_drain_complete(instance),
                Event::PrefixTransferDone {
                    request,
                    from,
                    to,
                    tokens,
                } => self.on_prefix_transfer_done(request, from, to, tokens),
                Event::InstanceFailure { instance, down_s } => {
                    self.on_instance_failure(instance, down_s)
                }
                Event::InstanceRecovered { instance } => self.on_instance_recovered(instance),
            }
            if self.params.validate_state {
                self.assert_state_consistent();
            }
            // in-flight follow-up turns have no request record yet — the
            // run is only over once they have fired and completed too
            if self.completed + self.failed == self.requests.len()
                && self.pending_follow_ups == 0
            {
                break;
            }
        }
        self.into_report()
    }

    // ------------------------------------------------------------------
    // arrival + prefill

    fn on_arrival(&mut self, id: RequestId) {
        // OOM victims loop back through prefill for KV recompute; that
        // re-entry is not a fresh arrival and traces must not count it
        // twice (consumers assert arrival uniqueness).
        if matches!(self.requests[id as usize].state, ReqState::Recomputing) {
            self.recorder.record(self.now, TraceEvent::RecomputeQueued { request: id });
            self.registry.inc("recompute.queued", 1);
        } else {
            self.recorder.record(self.now, TraceEvent::Arrived { request: id });
            self.registry.inc("requests.arrived", 1);
        }
        self.rates.on_arrival(self.requests[id as usize].prefill_tokens());
        self.enqueue_prefill(id);
    }

    /// Prefill instance selection: least queued-*token* load over active
    /// instances (paper §2.1 dispatches "by load" — the old shortest-queue
    /// rule let one long prompt hide an hour of work behind a two-entry
    /// queue). Ties break on the lowest id for determinism.
    fn enqueue_prefill(&mut self, id: RequestId) {
        let tokens = self.requests[id as usize].prefill_tokens();
        let pi = (0..self.prefill.len())
            .filter(|&i| self.prefill[i].lifecycle == Lifecycle::Active)
            .min_by_key(|&i| (self.prefill[i].load_tokens, i))
            .expect("at least one active prefill instance");
        self.prefill[pi].load_tokens += tokens;
        self.prefill[pi].queue.push_back(id);
        self.maybe_start_prefill(pi);
    }

    fn maybe_start_prefill(&mut self, pi: usize) {
        if self.prefill[pi].busy.is_some() {
            return;
        }
        let Some(id) = self.prefill[pi].queue.pop_front() else {
            return;
        };
        self.prefill[pi].busy = Some(id);
        // recompute passes re-process prompt + generated tokens; a prefix
        // hit computes only the uncached suffix
        let tokens = self.requests[id as usize].prefill_tokens();
        let dt = self.params.prefill_cost.time(tokens);
        self.queue.push(
            self.now + dt,
            Event::PrefillDone {
                prefill: pi,
                request: id,
            },
        );
    }

    fn on_prefill_done(&mut self, pi: usize, id: RequestId) {
        debug_assert_eq!(self.prefill[pi].busy, Some(id));
        self.prefill[pi].busy = None;
        // prefill of a request never changes its token count (and a hold
        // is only abandoned, never created, mid-flight), so this releases
        // exactly what enqueue_prefill charged
        let done_tokens = self.requests[id as usize].prefill_tokens();
        self.prefill[pi].load_tokens -= done_tokens;
        self.rates.on_prefill_done(done_tokens);

        // initial (or refreshed, after recompute) length prediction
        let pred = {
            let r = &self.requests[id as usize];
            self.predictor.predict(&PredictInput {
                id,
                generated: r.generated,
                true_remaining: Some(r.remaining()),
            })
        };
        let r = &mut self.requests[id as usize];
        r.predicted_remaining = pred;
        if let Some(p) = pred {
            r.pred_log.push(PredSample {
                generated: r.generated,
                predicted: p.mean,
            });
        }
        r.latency.prefill_done = Some(self.now);
        self.recorder.record(
            self.now,
            TraceEvent::PrefillDone {
                request: id,
                instance: pi,
            },
        );

        // dispatch to a decode instance (the common P2D baseline layer);
        // a prefix hit prefers the instance holding its cached KV
        let kv_tokens = self.requests[id as usize].kv_tokens();
        let hold = self.requests[id as usize].prefix_hold;
        let incoming = IncomingRequest {
            id,
            tokens: kv_tokens,
            predicted_remaining: pred,
            preferred_instance: hold,
        };
        let di = self.dispatch_decode(&incoming);

        if kv_tokens > admission_watermark(self.decode[di].kv.capacity_tokens()) {
            // can never pass admission, even on an idle instance: fail the
            // request terminally (counted, not silently lost)
            self.release_hold(id);
            self.requests[id as usize].state = ReqState::Done;
            self.failed += 1;
            self.registry.inc("requests.failed", 1);
            if self.fault_requeue.remove(&id).is_some() {
                self.reliability.lost += 1;
            }
        } else if hold.is_some() && hold != Some(di) {
            // dispatched away from the prefix holder: move the cached KV
            // over the fabric or recompute it at the destination,
            // whichever the cost models say is cheaper
            self.start_prefix_transfer(id, hold.expect("checked is_some"), di);
        } else {
            self.requests[id as usize].state = ReqState::Pending(di);
            self.decode[di].pending.push_back(id);
            self.kick(di);
        }
        self.maybe_start_prefill(pi);
        self.maybe_complete_prefill_drain(pi);
    }

    /// A prefix hit was dispatched away from its holder (`from`): fire a
    /// [`Event::PrefixTransferDone`] after min(transfer, recompute) of the
    /// costmodel comparison. The request enters the pending path only
    /// once the prefix is in place at the destination.
    fn start_prefix_transfer(&mut self, id: RequestId, from: InstanceId, to: InstanceId) {
        let prefix = self.requests[id as usize].cached_prefix;
        let transfer_s = self.params.migration.transfer_time(prefix);
        let recompute_s = self.params.prefill_cost.time(prefix);
        let dt = if transfer_s <= recompute_s {
            // both sides hold the prefix during the copy (as with
            // migrations): the holder's bytes release on completion
            self.prefix_cache.note_transfer();
            transfer_s
        } else {
            // recomputing at the destination is cheaper: the holder's
            // copy is useless now, drop it immediately
            self.prefix_cache.note_recompute();
            self.requests[id as usize].prefix_hold = None;
            self.hold_tokens[from] -= prefix;
            self.sync_cached_mirror();
            recompute_s
        };
        self.queue.push(
            self.now + dt,
            Event::PrefixTransferDone {
                request: id,
                from,
                to,
                tokens: prefix,
            },
        );
    }

    /// The cached prefix is in place at the destination (copied or
    /// recomputed): release the holder's copy if it was kept for the
    /// transfer and enter the normal pending/admission path. A target
    /// that drained while the prefix was in flight re-routes to the
    /// active pool, exactly like a migration landing on a drained slot.
    fn on_prefix_transfer_done(
        &mut self,
        id: RequestId,
        from: InstanceId,
        to: InstanceId,
        tokens: u64,
    ) {
        if self.requests[id as usize].prefix_hold == Some(from) {
            self.requests[id as usize].prefix_hold = None;
            self.hold_tokens[from] -= tokens;
            self.sync_cached_mirror();
        }
        // the prefix now travels with the request and merges into its
        // full-footprint admission below
        self.requests[id as usize].cached_prefix = 0;
        let dest = if self.decode[to].lifecycle == Lifecycle::Active {
            to
        } else {
            let incoming = {
                let r = &self.requests[id as usize];
                IncomingRequest {
                    id,
                    tokens: r.kv_tokens(),
                    predicted_remaining: r.predicted_remaining,
                    preferred_instance: None,
                }
            };
            self.dispatch_decode(&incoming)
        };
        self.requests[id as usize].state = ReqState::Pending(dest);
        self.decode[dest].pending.push_back(id);
        self.kick(dest);
    }

    /// Drop a request's prefix hold (terminal failure, drain flush, or
    /// forced headroom reclaim): the holder's cached bytes are no longer
    /// promised to it. `cached_prefix` is kept so prefill-load accounting
    /// stays symmetric; it is cleared at admission.
    fn release_hold(&mut self, id: RequestId) {
        let r = &mut self.requests[id as usize];
        if let Some(x) = r.prefix_hold.take() {
            let tokens = r.cached_prefix;
            self.hold_tokens[x] -= tokens;
            self.sync_cached_mirror();
        }
    }

    /// Reconcile [`ClusterState`]'s per-instance cached-token mirror with
    /// the cache's entry totals plus in-flight holds. O(instances);
    /// called after any cache mutation (the cache may evict or supersede
    /// entries internally, so callers cannot track deltas themselves).
    fn sync_cached_mirror(&mut self) {
        for di in 0..self.decode.len() {
            let want = self.prefix_cache.cached_on(di) + self.hold_tokens[di];
            let have = self.state.stats(di).cached_tokens();
            if want > have {
                self.state.add_cached(di, want - have);
            } else if have > want {
                self.state.sub_cached(di, have - want);
            }
        }
    }

    /// Run the dispatch policy under the configured [`StateMode`]. The
    /// drain invariant rides on this: as long as any Active decode
    /// instance exists (the elastic guard's `min_decode` floor
    /// guarantees one), no dispatch may land on a Draining/Retired slot.
    fn dispatch_decode(&mut self, incoming: &IncomingRequest) -> usize {
        let di = match self.params.state_mode {
            StateMode::Incremental => self.control.dispatch(&self.state.view(), incoming),
            StateMode::RebuildPerDecision => {
                let snapshot = self.rebuild_snapshot();
                self.control.dispatch(&snapshot.view(), incoming)
            }
        };
        debug_assert!(
            self.decode[di].lifecycle == Lifecycle::Active
                || !self.decode.iter().any(|d| d.lifecycle == Lifecycle::Active),
            "dispatch landed on non-active instance {di} while active instances exist"
        );
        di
    }

    // ------------------------------------------------------------------
    // decode

    /// Admit pending requests into the running batch and (re)schedule the
    /// next iteration if the instance has work but no step in flight.
    /// Admission is first-fit over the whole queue (vLLM-style): a huge
    /// request at the head must not starve small ones behind it. Requests
    /// that can never pass the watermark fail terminally here — leaving
    /// them queued would strand them (no future event ever drains them).
    fn kick(&mut self, di: usize) {
        if self.decode[di].lifecycle == Lifecycle::Failed {
            // a crashed instance admits nothing until it recovers; its
            // pending queue (only reachable when no active instance
            // existed at dispatch time) waits for InstanceRecovered
            return;
        }
        let cap = self.decode[di].kv.capacity_tokens();
        let watermark = admission_watermark(cap);
        let max_batch = self.params.exp.cluster.max_batch;
        let mut pending = std::mem::take(&mut self.decode[di].pending);
        let mut still = VecDeque::with_capacity(pending.len());
        while let Some(id) = pending.pop_front() {
            if self.state.stats(di).batch_size() >= max_batch {
                still.push_back(id);
                continue;
            }
            let need = self.requests[id as usize].kv_tokens();
            if need > watermark {
                self.release_hold(id);
                self.requests[id as usize].state = ReqState::Done;
                self.failed += 1;
                self.registry.inc("requests.failed", 1);
                if self.fault_requeue.remove(&id).is_some() {
                    self.reliability.lost += 1;
                }
                continue;
            }
            // a request admitted on the instance holding its prefix
            // re-absorbs those cached bytes into its own footprint, so
            // they don't count against it twice
            let hold_credit = match self.requests[id as usize].prefix_hold {
                Some(h) if h == di => self.requests[id as usize].cached_prefix,
                _ => 0,
            };
            let used = self.decode[di].kv.used_tokens();
            let cached = self
                .state
                .stats(di)
                .cached_tokens()
                .saturating_sub(hold_credit);
            // idle cached prefixes always yield to live work: evict for
            // headroom before giving up on admission
            if cached > 0 && used + need + cached > watermark {
                let freed = self
                    .prefix_cache
                    .evict_for_headroom(di, used + need + cached - watermark, self.now);
                if freed > 0 {
                    self.sync_cached_mirror();
                }
            }
            let cached = self
                .state
                .stats(di)
                .cached_tokens()
                .saturating_sub(hold_credit);
            let ok = used + need + cached <= watermark && self.decode[di].kv.would_fit(need);
            if ok {
                self.decode[di]
                    .kv
                    .admit(id, need, di)
                    .expect("would_fit checked");
                if hold_credit > 0 {
                    self.requests[id as usize].prefix_hold = None;
                    self.hold_tokens[di] -= hold_credit;
                    self.sync_cached_mirror();
                }
                let r = &mut self.requests[id as usize];
                r.cached_prefix = 0; // merged into the admitted footprint
                r.state = ReqState::Decoding(di);
                self.state.admit(di, id, need, r.predicted_remaining);
                // crash-requeued request back in a batch: the outage is
                // over for it — log crash→re-admission latency
                if let Some(t0) = self.fault_requeue.remove(&id) {
                    self.reliability.requeue_delays.push(self.now - t0);
                }
            } else {
                still.push_back(id);
            }
        }
        self.decode[di].pending = still;
        if self.state.stats(di).batch_size() > 0 && !self.decode[di].stepping {
            self.schedule_step(di);
        }
    }

    fn schedule_step(&mut self, di: usize) {
        let d = &mut self.decode[di];
        d.stepping = true;
        d.epoch += 1;
        let epoch = d.epoch;
        // prediction overhead lands on iterations where repredictions fire
        // (shared pre-step due-slot scan, predictor::Repredictor)
        let n_pred = self
            .state
            .active(di)
            .iter()
            .filter(|rv| {
                self.repredictor
                    .due_next(self.requests[rv.id as usize].iters_since_predict)
            })
            .count();
        let stats = self.state.stats(di);
        let mut dt = self
            .params
            .decode_cost
            .iter_time(stats.token_load(), stats.batch_size());
        // heterogeneous fleets: faster hardware divides the modeled
        // compute time; the EWMA below sees the scaled value, so the
        // speed class is visible to variance metrics and policies
        dt /= self.decode[di].profile.speed_mult;
        // predictor overhead is host-side and does not scale with the
        // accelerator's speed class
        dt += self.repredictor.batch_cost_s(&*self.predictor, n_pred);
        let at = self.now + dt;
        // EWMA of iteration latency for the exec-variance metric
        self.state.record_iteration(di, dt);
        self.queue.push(at, Event::DecodeStep { instance: di, epoch });
    }

    fn on_decode_step(&mut self, di: usize, epoch: u64) {
        if self.decode[di].epoch != epoch {
            return; // stale event (batch was rebuilt)
        }
        self.decode[di].stepping = false;
        self.state.complete_iteration(di);

        let batch: Vec<RequestId> = self.state.active(di).iter().map(|r| r.id).collect();
        let mut finished: Vec<RequestId> = Vec::new();
        let mut evicted: Vec<RequestId> = Vec::new();

        for &id in &batch {
            // a request migrated out mid-iteration is paused: no token
            if !matches!(self.requests[id as usize].state, ReqState::Decoding(d) if d == di) {
                continue;
            }
            if evicted.contains(&id) {
                continue; // evicted by an earlier OOM in this same step
            }
            // KV append (may OOM -> evict victims -> retry once)
            if self.decode[di].kv.append_token(id, di).is_err() {
                let victims = self.handle_oom(di, id);
                evicted.extend(victims);
                if evicted.contains(&id) {
                    continue;
                }
                if self.decode[di].kv.append_token(id, di).is_err() {
                    // nothing evictable freed room (everything else is
                    // mid-migration): this request itself recomputes
                    let vs = self.evict_requests(di, vec![id]);
                    evicted.extend(vs);
                    continue;
                }
            }
            self.state.append_token(id);
            let r = &mut self.requests[id as usize];
            r.generated += 1;
            r.iters_since_predict += 1;
            self.decode[di].tokens_decoded += 1;
            if r.latency.first_token.is_none() {
                r.latency.first_token = Some(self.now);
            }
            if let Some(prev) = r.last_token_at {
                let gap = self.now - prev;
                r.tpot_sum += gap;
                r.tpot_max = r.tpot_max.max(gap);
            }
            r.last_token_at = Some(self.now);

            if r.generated >= r.output_len {
                finished.push(id);
            } else if self.repredictor.is_due(r.iters_since_predict) {
                r.iters_since_predict = 0;
                let input = PredictInput {
                    id,
                    generated: r.generated,
                    true_remaining: Some(r.output_len - r.generated),
                };
                let p = self.predictor.predict(&input);
                let r = &mut self.requests[id as usize];
                if let Some(pp) = p {
                    r.pred_log.push(PredSample {
                        generated: r.generated,
                        predicted: pp.mean,
                    });
                }
                r.predicted_remaining = p;
                self.state.set_prediction(id, p);
            }
        }

        // batch growth may encroach on idle cached bytes: the cache
        // always yields (active + cached never exceeds capacity)
        self.reclaim_cached_headroom(di);
        for id in finished {
            self.finish_request(di, id);
        }
        self.kick(di);
    }

    /// Keep the cache-accounting invariant (active KV + cached bytes ≤
    /// capacity) as the live batch grows: evict cold entries first, then
    /// abandon in-flight holds if the batch leaves them no room.
    fn reclaim_cached_headroom(&mut self, di: usize) {
        let cached = self.state.stats(di).cached_tokens();
        if cached == 0 {
            return;
        }
        let cap = self.decode[di].kv.capacity_tokens();
        let used = self.decode[di].kv.used_tokens();
        if used + cached <= cap {
            return;
        }
        let freed = self
            .prefix_cache
            .evict_for_headroom(di, used + cached - cap, self.now);
        if freed > 0 {
            self.sync_cached_mirror();
        }
        // entries exhausted and still over: abandon un-admitted holds (a
        // rare forced path; the lost prefix folds into the request's
        // eventual full-footprint admission)
        let mut over = (used + self.state.stats(di).cached_tokens()).saturating_sub(cap);
        if over == 0 {
            return;
        }
        let holders: Vec<RequestId> = self
            .requests
            .iter()
            .filter(|r| r.prefix_hold == Some(di))
            .map(|r| r.id)
            .collect();
        for id in holders {
            if over == 0 {
                break;
            }
            let tokens = self.requests[id as usize].cached_prefix;
            self.release_hold(id);
            self.prefix_cache.note_evicted();
            over = over.saturating_sub(tokens);
        }
    }

    /// OOM on `di` while appending for `for_id`: evict the largest
    /// requests (vLLM recompute semantics) and send them back to prefill.
    /// Returns the victim list.
    fn handle_oom(&mut self, di: usize, _for_id: RequestId) -> Vec<RequestId> {
        self.oom_events += 1;
        self.registry.inc("oom.events", 1);
        // free a breathing-room chunk (~4% of capacity), not just one
        // block: per-block eviction re-OOMs on the very next append
        let chunk = (self.decode[di].kv.capacity_tokens()
            / (self.params.exp.cluster.block_tokens as u64 * 25)) as usize;
        // take the full cheapest-first ordering, then keep only requests
        // actively decoding HERE: a migrating request's KV is still
        // registered on the source but its lifecycle is owned by the
        // migration (evicting it would admit it twice)
        let victims: Vec<RequestId> = self
            .decode[di]
            .kv
            .eviction_victims(usize::MAX)
            .into_iter()
            .filter(|&v| matches!(self.requests[v as usize].state,
                                  ReqState::Decoding(d) if d == di))
            .scan(0usize, |freed, v| {
                if *freed >= chunk.max(1) {
                    return None;
                }
                *freed += (self.requests[v as usize].kv_tokens() as usize)
                    .div_ceil(self.params.exp.cluster.block_tokens as usize);
                Some(v)
            })
            .collect();
        self.registry.inc("oom.victims", victims.len() as u64);
        self.recorder.record(
            self.now,
            TraceEvent::Oom {
                instance: di,
                victims: victims.len(),
            },
        );
        self.evict_requests(di, victims)
    }

    /// Evict `victims` from instance `di` for KV recompute: release their
    /// blocks and send them back through prefill (vLLM recompute
    /// semantics). Requests that can never be re-admitted are failed
    /// terminally.
    fn evict_requests(&mut self, di: usize, victims: Vec<RequestId>) -> Vec<RequestId> {
        let watermark = admission_watermark(self.decode[di].kv.capacity_tokens());
        let block = self.params.exp.cluster.block_tokens as u64;
        for &v in &victims {
            self.decode[di].kv.release(v);
            self.state.release(v);
            let r = &mut self.requests[v as usize];
            r.latency.hit_oom = true;
            r.last_token_at = None; // recompute stall shows up as TTFT-like gap
            if r.kv_tokens() + block > watermark {
                // even after recompute the admission watermark would
                // reject it on an idle instance of this size: terminal
                // failure (vLLM would abort the request too)
                r.state = ReqState::Done;
                self.failed += 1;
                self.registry.inc("requests.failed", 1);
                if self.fault_requeue.remove(&v).is_some() {
                    self.reliability.lost += 1;
                }
            } else {
                r.state = ReqState::Recomputing;
                // recompute = re-run prefill over prompt+generated
                self.queue.push(self.now, Event::Arrival { request: v });
            }
        }
        victims
    }

    fn finish_request(&mut self, di: usize, id: RequestId) {
        self.decode[di].kv.release(id);
        self.state.release(id);
        let r = &mut self.requests[id as usize];
        r.state = ReqState::Done;
        r.latency.finished = Some(self.now);
        r.latency.output_tokens = r.generated;
        // mean gap between consecutive tokens, including migration stalls
        r.latency.finalize_tpot(r.generated, r.tpot_sum, r.tpot_max);
        let generated = r.generated;
        let ttft = r.latency.first_token.map(|ft| ft - r.latency.arrival);
        let mean_tpot = (generated > 1).then(|| r.tpot_sum / (generated - 1) as f64);
        // completion is the first moment every logged estimate has a known
        // ground truth: fold the log into the calibration scorecard and
        // feed it back to the predictor (the `debiased` builtin learns
        // its per-bucket correction from exactly this)
        let log = std::mem::take(&mut r.pred_log);
        self.output_mean.push(generated as f64);
        self.completed += 1;
        self.registry.inc("requests.finished", 1);
        if let Some(t) = ttft {
            self.registry.observe("ttft_s", t);
        }
        if let Some(t) = mean_tpot {
            self.registry.observe("tpot_s", t);
        }
        if !log.is_empty() {
            self.scorecard.observe_completion(generated, &log);
            self.predictor.observe_completion(generated, &log);
        }
        self.recorder.record(
            self.now,
            TraceEvent::Finished {
                request: id,
                instance: di,
            },
        );
        self.maybe_cache_prefix(di, id);
        self.schedule_follow_up(id);
        self.maybe_drain_complete(di);
    }

    /// Offer a completed session turn's KV to the prefix cache before its
    /// blocks are recycled. The predicted return delay is the scripted
    /// think time of the session's next turn when one exists (the
    /// predictive policy's admission signal); a session at its last turn
    /// offers `None`, which only the unconditional policies retain.
    fn maybe_cache_prefix(&mut self, di: usize, id: RequestId) {
        if !self.prefix_cache.enabled() || self.decode[di].lifecycle != Lifecycle::Active {
            // drain-then-flip: a turn finishing mid-drain must not insert
            // a fresh entry after drain_decode already flushed the slot
            return;
        }
        let Some(&(s, k)) = self.session_cursor.get(&id) else {
            return; // sessionless request: no key to return under
        };
        let return_delay = self.sessions.scripts[s as usize]
            .get(k as usize)
            .map(|t| Prediction::exact(t.think_time_s));
        let tokens = self.requests[id as usize].kv_tokens();
        // physical headroom for cached bytes right now: the cache may
        // evict its own entries to fit, but never displaces live KV,
        // inbound reservations, or other requests' holds
        let hard_cap = self.decode[di]
            .kv
            .capacity_tokens()
            .saturating_sub(self.decode[di].kv.used_tokens())
            .saturating_sub(self.state.stats(di).inbound_reserved_tokens())
            .saturating_sub(self.hold_tokens[di]);
        self.prefix_cache
            .insert(s, di, tokens, self.now, return_delay, hard_cap);
        // the insert may supersede or evict entries even when it refuses
        // the new one — always reconcile
        self.sync_cached_mirror();
    }

    /// If `id` has a successor turn in its session script, schedule its
    /// arrival a think-time after this completion. Sessions whose turn
    /// fails terminally (watermark rejection / unrecoverable OOM) are
    /// abandoned: the user never saw the answer, so no follow-up.
    fn schedule_follow_up(&mut self, id: RequestId) {
        let Some(&(s, k)) = self.session_cursor.get(&id) else {
            return;
        };
        let Some(turn) = self.sessions.scripts[s as usize].get(k as usize) else {
            return;
        };
        self.pending_follow_ups += 1;
        self.queue.push(
            self.now + turn.think_time_s,
            Event::SessionFollowUp { session: s, turn: k },
        );
    }

    /// A session's next turn arrives: materialize its request record (the
    /// prompt carries the accumulated history) and route it to prefill.
    fn on_session_follow_up(&mut self, session: u32, turn_idx: u32) {
        self.pending_follow_ups -= 1;
        self.registry.inc("session.follow_ups", 1);
        let turn = self.sessions.scripts[session as usize][turn_idx as usize].clone();
        let id = self.requests.len() as RequestId;
        self.requests.push(SimRequest {
            id,
            arrival: self.now,
            class: turn.class,
            prompt_len: turn.prompt_len,
            output_len: turn.output_len,
            generated: 0,
            state: ReqState::Prefill,
            predicted_remaining: None,
            iters_since_predict: 0,
            pred_log: Vec::new(),
            cached_prefix: 0,
            prefix_hold: None,
            latency: crate::metrics::RequestLatency {
                id,
                class: turn.class,
                arrival: self.now,
                prompt_tokens: turn.prompt_len,
                suffix_tokens: turn.prompt_len,
                ..Default::default()
            },
            last_token_at: None,
            tpot_sum: 0.0,
            tpot_max: 0.0,
        });
        self.session_cursor.insert(id, (session, turn_idx + 1));
        self.session_chains[session as usize].push(id);
        // consult the prefix cache before the turn enters prefill: a hit
        // prefills only the new suffix and prefers the holding instance
        if self.prefix_cache.enabled() {
            let mut cache_hit = false;
            match self.prefix_cache.take(session, self.now) {
                Some(e) if self.decode[e.instance].lifecycle == Lifecycle::Active => {
                    let r = &mut self.requests[id as usize];
                    // at least one suffix token must remain to prefill
                    let reused = e.tokens.min(r.prompt_len.saturating_sub(1) as u64);
                    if reused > 0 {
                        r.cached_prefix = reused;
                        r.prefix_hold = Some(e.instance);
                        r.latency.suffix_tokens = r.prompt_len - reused as u32;
                        self.hold_tokens[e.instance] += reused;
                        self.prefix_cache.note_hit(reused);
                        cache_hit = true;
                    } else {
                        self.prefix_cache.note_miss();
                    }
                }
                Some(_) => {
                    // the holder left the active pool with the entry still
                    // live (defensive: drains flush eagerly) — its bytes
                    // were already released by take; count the drop
                    self.prefix_cache.note_evicted();
                    self.prefix_cache.note_miss();
                }
                None => self.prefix_cache.note_miss(),
            }
            // take removes expired entries even when it returns None
            self.sync_cached_mirror();
            self.control
                .attribution_mut()
                .record_cache(&self.params.exp.kvcache.policy, id, cache_hit);
        }
        self.on_arrival(id);
    }

    // ------------------------------------------------------------------
    // rescheduling + migration

    /// Pre-incremental from-scratch materialization: per-instance request
    /// views from the membership lists plus an O(requests) scan per
    /// instance for inbound reservations. This is the cost shape every
    /// decision paid before [`ClusterState`]; kept for
    /// [`StateMode::RebuildPerDecision`] (differential baseline).
    fn rebuild_snapshot(&self) -> ClusterSnapshot {
        let instances = (0..self.decode.len())
            .map(|di| InstanceView {
                id: self.decode[di].id,
                requests: self.state.active(di).to_vec(),
                kv_capacity_tokens: self.decode[di].kv.capacity_tokens(),
                inbound_reserved_tokens: self.inbound_reserved_scan(self.decode[di].id),
                cached_tokens: self.prefix_cache.cached_on(di) + self.hold_tokens[di],
                lifecycle: self.decode[di].lifecycle,
                hardware: self.decode[di].profile,
            })
            .collect();
        ClusterSnapshot {
            instances,
            tokens_per_interval: self.state.tokens_per_interval(),
        }
    }

    /// O(requests) reservation scan (the pre-incremental definition).
    fn inbound_reserved_scan(&self, di: InstanceId) -> u64 {
        self.requests
            .iter()
            .filter_map(|r| match r.state {
                ReqState::Migrating { to, .. } if to == di => Some(r.kv_tokens()),
                _ => None,
            })
            .sum()
    }

    /// Rebuild scheduler-visible state from the authoritative per-request
    /// records alone (independent of [`ClusterState`]'s bookkeeping).
    fn reference_snapshot(&self) -> ClusterSnapshot {
        let mut instances: Vec<InstanceView> = self
            .decode
            .iter()
            .map(|d| InstanceView {
                id: d.id,
                requests: Vec::new(),
                kv_capacity_tokens: d.kv.capacity_tokens(),
                inbound_reserved_tokens: 0,
                cached_tokens: 0,
                lifecycle: d.lifecycle,
                hardware: d.profile,
            })
            .collect();
        for r in &self.requests {
            match r.state {
                ReqState::Decoding(di) => instances[di].requests.push(RequestView {
                    id: r.id,
                    tokens: r.kv_tokens(),
                    predicted_remaining: r.predicted_remaining,
                    migrating: false,
                }),
                ReqState::Migrating { to, .. } => {
                    instances[to].inbound_reserved_tokens += r.kv_tokens()
                }
                _ => {}
            }
        }
        // cached side, rebuilt from the cache's own entry list plus a
        // scan for in-flight prefix holds — independent of the
        // incremental mirror, so drift is caught
        for e in self.prefix_cache.entries() {
            instances[e.instance].cached_tokens += e.tokens;
        }
        for r in &self.requests {
            if let Some(x) = r.prefix_hold {
                instances[x].cached_tokens += r.cached_prefix;
            }
        }
        ClusterSnapshot {
            instances,
            tokens_per_interval: self.state.tokens_per_interval(),
        }
    }

    /// Differential check behind [`SimParams::validate_state`]: the
    /// incrementally maintained state must equal a from-scratch rebuild.
    fn assert_state_consistent(&self) {
        if let Some(diff) = self.state.consistency_diff(&self.reference_snapshot()) {
            panic!(
                "incremental ClusterState diverged from from-scratch rebuild \
                 at t={:.6}: {diff}",
                self.now
            );
        }
        // cache-accounting invariant: cached bytes (entries + in-flight
        // holds) plus live KV never oversubscribe an instance. Inbound
        // reservations are promises — their bytes still live on the
        // migration source — so they are not part of the physical sum.
        for d in &self.decode {
            let cached = self.state.stats(d.id).cached_tokens();
            assert!(
                d.kv.used_tokens() + cached <= d.kv.capacity_tokens(),
                "instance {}: active {} + cached {} exceeds capacity {} at t={:.6}",
                d.id,
                d.kv.used_tokens(),
                cached,
                d.kv.capacity_tokens(),
                self.now
            );
        }
        if !self.prefix_cache.enabled() {
            assert_eq!(
                self.prefix_cache.total_cached(),
                0,
                "a disabled cache must hold nothing"
            );
        }
    }

    /// Epoch barrier (DESIGN.md §17): merge the per-shard
    /// [`ClusterState`] aggregates in fixed shard order before this
    /// tick's `ControlLoop` decisions, and stamp the loop's epoch
    /// counter. Under `validate_state` the merged totals are asserted
    /// equal to a direct global scan — the shard-sliced view may never
    /// drift from the authoritative state.
    fn epoch_barrier(&mut self) -> ShardRollup {
        let roll = self.state.shard_rollup(self.queue.layout().n_shards());
        if self.params.validate_state {
            let (mut active, mut draining) = (0usize, 0usize);
            for d in &self.decode {
                match d.lifecycle {
                    Lifecycle::Active => active += 1,
                    Lifecycle::Draining => draining += 1,
                    _ => {}
                }
            }
            assert_eq!(
                roll.total.instances,
                self.decode.len(),
                "shard slices must partition the decode fleet at t={:.6}",
                self.now
            );
            assert_eq!(
                (roll.total.active, roll.total.draining),
                (active, draining),
                "shard-rollup lifecycle counts drifted from the engine at t={:.6}",
                self.now
            );
            let load: u64 = (0..self.state.n_instances())
                .map(|i| self.state.stats(i).token_load())
                .sum();
            assert_eq!(
                roll.total.token_load, load,
                "shard-rollup token load drifted from ClusterState at t={:.6}",
                self.now
            );
        }
        self.control.note_epoch();
        roll
    }

    fn on_scheduler_tick(&mut self) {
        // epoch barrier first: the merged shard aggregates (and the
        // validate_state cross-check inside) precede every decision of
        // this tick
        let _merged = self.epoch_barrier();

        // TTL housekeeping first, so this tick's decisions read cached
        // pressure net of anything that just lapsed
        if self.prefix_cache.enabled() {
            self.prefix_cache.expire(self.now);
            self.sync_cached_mirror();
        }

        // stranded-request guard: an instance with an empty batch receives
        // no DecodeStep/MigrationDone events, so a pending request that
        // failed its first admission attempt would otherwise wait forever
        for di in 0..self.decode.len() {
            if !self.decode[di].pending.is_empty() {
                self.kick(di);
            }
        }

        // metrics snapshots (taken whether or not rescheduling is on);
        // retired and crashed slots are out of the pool and must not
        // deflate the cross-instance variance
        let iters: Vec<f64> = (0..self.decode.len())
            .filter(|&di| {
                !matches!(
                    self.decode[di].lifecycle,
                    Lifecycle::Retired | Lifecycle::Failed
                )
            })
            .map(|di| {
                let s = self.state.stats(di);
                if s.batch_size() == 0 {
                    0.0
                } else {
                    s.ewma_iter_ms()
                }
            })
            .collect();
        self.exec_var.snapshot(self.now, &iters);
        let loads: Vec<f64> = self
            .decode
            .iter()
            .filter(|d| !matches!(d.lifecycle, Lifecycle::Retired | Lifecycle::Failed))
            .map(|d| d.kv.used_tokens() as f64)
            .collect();
        self.load_var.snapshot(self.now, &loads);
        for d in &self.decode {
            if matches!(d.lifecycle, Lifecycle::Retired | Lifecycle::Failed) {
                continue;
            }
            self.recorder.record(
                self.now,
                TraceEvent::KvSample {
                    instance: d.id,
                    kv_frac: d.kv.usage_frac(),
                    tokens: d.kv.used_tokens(),
                    batch: self.state.stats(d.id).batch_size(),
                },
            );
        }

        if self.control.rescheduling_enabled() {
            self.control.observe_avg_iter_s(self.state.avg_iter_s());
            if self.output_mean.count() > 10 {
                self.control
                    .observe_default_remaining(self.output_mean.mean() / 2.0);
            }
            let decisions = match self.params.state_mode {
                StateMode::Incremental => self.control.reschedule(&self.state.view()),
                StateMode::RebuildPerDecision => {
                    let snapshot = self.rebuild_snapshot();
                    self.control.reschedule(&snapshot.view())
                }
            };
            for d in decisions {
                self.start_migration(d.request, d.src, d.dst, d.kv_tokens);
            }
        }

        self.queue.push(
            self.now + self.params.exp.rescheduler.interval_s,
            Event::SchedulerTick,
        );
    }

    fn start_migration(&mut self, id: RequestId, from: InstanceId, to: InstanceId, kv: u64) {
        let r = &mut self.requests[id as usize];
        debug_assert!(matches!(r.state, ReqState::Decoding(d) if d == from));
        r.state = ReqState::Migrating { from, to };
        r.latency.migrations += 1;
        self.migrations_started += 1;
        self.registry.inc("migrations", 1);
        // pause: out of the running batch immediately (overlap: the rest
        // of the batch keeps decoding, §5.4); its KV footprint is promised
        // to the destination until the transfer completes
        let reserved = self
            .state
            .begin_migration(id, to)
            .expect("migrating request tracked in cluster state");
        debug_assert_eq!(reserved, kv, "decision kv_tokens drifted from tracked state");
        self.recorder.record(
            self.now,
            TraceEvent::Migration {
                request: id,
                src: from,
                dst: to,
                kv_tokens: kv,
            },
        );
        let dt = self.params.migration.transfer_time(kv);
        self.queue.push(
            self.now + dt,
            Event::MigrationDone {
                request: id,
                from,
                to,
                kv_tokens: reserved,
            },
        );
    }

    fn on_migration_done(&mut self, id: RequestId, from: InstanceId, to: InstanceId, kv: u64) {
        // source frees its copy only after the transfer (both sides hold
        // KV during the copy, as with NIXL)
        self.decode[from].kv.release(id);
        debug_assert!(matches!(self.requests[id as usize].state, ReqState::Migrating { .. }));
        // release exactly what begin_migration reserved
        self.state.finish_migration(to, kv);
        // a flip decided after this migration left may have put the
        // destination into Draining: deliver to the active pool instead
        // (the KV is not yet admitted anywhere, so re-routing is free)
        let dest = if self.decode[to].lifecycle == Lifecycle::Active {
            to
        } else {
            let incoming = {
                let r = &self.requests[id as usize];
                IncomingRequest {
                    id,
                    tokens: r.kv_tokens(),
                    predicted_remaining: r.predicted_remaining,
                    preferred_instance: None,
                }
            };
            self.dispatch_decode(&incoming)
        };
        self.requests[id as usize].state = ReqState::Pending(dest);
        self.decode[dest].pending.push_back(id);
        self.kick(dest);
        self.kick(from);
        self.maybe_drain_complete(from);
        if dest != to {
            self.maybe_drain_complete(to);
        }
    }

    // ------------------------------------------------------------------
    // elastic pool (coordinator::elastic executed on sim events)

    /// Pool composition + backlog + measured rates for the scaling
    /// policy. Decode-side counts come from the epoch barrier's merged
    /// shard rollup (the `ClusterState` lifecycle mirror), not from a
    /// direct fleet scan — the sharded coordinator decides from merged
    /// aggregates, and `validate_state` proves the two agree.
    fn pool_stats(&self, merged: &ShardRollup) -> PoolStats {
        let mut ps = PoolStats {
            now: self.now,
            prefill_provisioning: self.prefill_provisioning,
            decode_provisioning: self.decode_provisioning,
            arrival_tokens_per_s: self.rates.arrival_tokens_per_s(),
            prefill_tokens_per_s: self.rates.prefill_tokens_per_s(),
            ..Default::default()
        };
        for p in &self.prefill {
            match p.lifecycle {
                Lifecycle::Active => {
                    ps.prefill_active += 1;
                    ps.prefill_queued_reqs += p.queue.len() + p.busy.is_some() as usize;
                    ps.prefill_queued_tokens += p.load_tokens;
                }
                Lifecycle::Draining => ps.prefill_draining += 1,
                _ => {}
            }
        }
        ps.decode_active = merged.total.active;
        ps.decode_draining = merged.total.draining;
        ps
    }

    /// One scale interval: refresh the rate EWMAs, push draining
    /// instances along, sample the timeline, and run the scaling policy
    /// through the control loop (a guaranteed no-op under `static`).
    fn on_scale_tick(&mut self) {
        let interval = self.control.elastic_config().scale_interval_s;
        let dt = self.now - self.last_scale_t;
        let n_active_prefill = self
            .prefill
            .iter()
            .filter(|p| p.lifecycle == Lifecycle::Active)
            .count();
        self.rates.tick(dt, n_active_prefill);
        self.last_scale_t = self.now;

        // keep drains moving: migrate residents of draining instances out
        for di in 0..self.decode.len() {
            if self.decode[di].lifecycle == Lifecycle::Draining {
                self.drain_out(di);
                self.maybe_drain_complete(di);
            }
        }

        let merged = self.epoch_barrier();
        let pool = self.pool_stats(&merged);
        self.pool_timeline.push(PoolSample {
            t: self.now,
            prefill_active: pool.prefill_active,
            decode_active: pool.decode_active,
            draining: pool.prefill_draining + pool.decode_draining,
            provisioning: pool.prefill_provisioning + pool.decode_provisioning,
        });
        let actions = match self.params.state_mode {
            StateMode::Incremental => self.control.scale(&self.state.view(), &pool),
            StateMode::RebuildPerDecision => {
                let snapshot = self.rebuild_snapshot();
                self.control.scale(&snapshot.view(), &pool)
            }
        };
        for action in actions {
            self.scale_log.push(ScaleRecord { t: self.now, action });
            self.execute_action(action);
        }
        self.queue.push(self.now + interval, Event::ScaleTick);
    }

    fn execute_action(&mut self, action: ScalingAction) {
        match action {
            ScalingAction::FlipToDecode => self.drain_prefill(true),
            ScalingAction::Retire {
                role: PoolRole::Prefill,
            } => self.drain_prefill(false),
            ScalingAction::FlipToPrefill { decode } => self.drain_decode(decode, true),
            ScalingAction::Retire {
                role: PoolRole::Decode,
            } => {
                if let Some(di) = self.emptiest_active_decode() {
                    self.drain_decode(di, false);
                }
            }
            ScalingAction::Provision { role } => {
                let delay = self.control.elastic_config().provision_delay_s;
                match role {
                    PoolRole::Prefill => self.prefill_provisioning += 1,
                    PoolRole::Decode => self.decode_provisioning += 1,
                }
                self.queue.push(self.now + delay, Event::InstanceReady { role });
            }
        }
    }

    /// The active decode instance cheapest to drain (shared heuristic
    /// with the policies and the live driver; the state view carries the
    /// same lifecycle this sim maintains).
    fn emptiest_active_decode(&self) -> Option<usize> {
        crate::coordinator::elastic::emptiest_active_decode(&self.state.view())
    }

    /// Start draining the least-loaded active prefill instance; when its
    /// current request finishes it retires (and re-roles as decode when
    /// `flip_to_decode`). Queued-but-unstarted requests re-route to the
    /// remaining active prefill pool immediately.
    fn drain_prefill(&mut self, flip_to_decode: bool) {
        let candidates: Vec<usize> = (0..self.prefill.len())
            .filter(|&i| self.prefill[i].lifecycle == Lifecycle::Active)
            .collect();
        // the guard's min_prefill floor leaves at least one OTHER active
        if candidates.len() < 2 {
            return;
        }
        let pi = candidates
            .into_iter()
            .min_by_key(|&i| (self.prefill[i].load_tokens, i))
            .expect("non-empty candidate list");
        self.prefill[pi].lifecycle = Lifecycle::Draining;
        self.prefill[pi].flip_to_decode = flip_to_decode;
        let queued: Vec<RequestId> = self.prefill[pi].queue.drain(..).collect();
        for id in queued {
            let tokens = self.requests[id as usize].prefill_tokens();
            self.prefill[pi].load_tokens -= tokens;
            self.enqueue_prefill(id);
        }
        self.maybe_complete_prefill_drain(pi);
    }

    /// A draining prefill instance with no work left retires; a flip
    /// schedules the decode-side warm-up.
    fn maybe_complete_prefill_drain(&mut self, pi: usize) {
        if self.prefill[pi].lifecycle != Lifecycle::Draining
            || self.prefill[pi].busy.is_some()
            || !self.prefill[pi].queue.is_empty()
        {
            return;
        }
        self.prefill[pi].lifecycle = Lifecycle::Retired;
        if self.prefill[pi].flip_to_decode {
            let delay = self.control.elastic_config().flip_delay_s;
            self.decode_provisioning += 1;
            let role = PoolRole::Decode;
            self.queue.push(self.now + delay, Event::InstanceReady { role });
        }
    }

    /// Start draining decode instance `di`: it accepts no dispatches and
    /// no migration arrivals from here on. Pending (never-started)
    /// requests re-dispatch to the active pool; batch residents migrate
    /// out where headroom exists (here and on every ScaleTick) or simply
    /// finish — either way no request is lost across the flip.
    fn drain_decode(&mut self, di: usize, flip_to_prefill: bool) {
        if self.decode[di].lifecycle != Lifecycle::Active {
            return; // guard-validated; defensive against custom policies
        }
        self.decode[di].lifecycle = Lifecycle::Draining;
        self.decode[di].flip_to_prefill = flip_to_prefill;
        self.state.set_lifecycle(di, Lifecycle::Draining);
        // drain-then-flip invariant: retained prefixes must not outlive
        // the drain — flush the instance's entries and abandon any
        // in-flight holds still targeting it
        if self.prefix_cache.enabled() {
            self.prefix_cache.evict_instance(di);
            let holders: Vec<RequestId> = self
                .requests
                .iter()
                .filter(|r| r.prefix_hold == Some(di))
                .map(|r| r.id)
                .collect();
            for id in holders {
                self.release_hold(id);
                self.prefix_cache.note_evicted();
            }
            self.sync_cached_mirror();
        }
        let pending: Vec<RequestId> = self.decode[di].pending.drain(..).collect();
        for id in pending {
            debug_assert!(
                matches!(self.requests[id as usize].state, ReqState::Pending(d) if d == di)
            );
            let incoming = {
                let r = &self.requests[id as usize];
                IncomingRequest {
                    id,
                    tokens: r.kv_tokens(),
                    predicted_remaining: r.predicted_remaining,
                    preferred_instance: None,
                }
            };
            let dst = self.dispatch_decode(&incoming);
            self.requests[id as usize].state = ReqState::Pending(dst);
            self.decode[dst].pending.push_back(id);
            self.kick(dst);
        }
        self.drain_out(di);
        self.maybe_drain_complete(di);
    }

    /// Migrate residents of draining instance `di` toward active
    /// instances with admission headroom (shared destination heuristic,
    /// `elastic::drain_destination`). Residents with no feasible
    /// destination keep decoding here and leave by completing.
    fn drain_out(&mut self, di: usize) {
        let max_batch = self.params.exp.cluster.max_batch;
        let residents: Vec<RequestView> = self.state.active(di).to_vec();
        for r in residents {
            if r.migrating
                || !matches!(self.requests[r.id as usize].state, ReqState::Decoding(d) if d == di)
            {
                continue;
            }
            let dst = crate::coordinator::elastic::drain_destination(
                &self.state.view(),
                r.tokens,
                max_batch,
            );
            if let Some(dst) = dst {
                self.start_migration(r.id, di, dst, r.tokens);
            }
        }
    }

    /// Queue a DrainComplete once a draining decode instance is fully
    /// empty: no batch, no pending queue, no inbound reservation.
    fn maybe_drain_complete(&mut self, di: usize) {
        if self.decode[di].lifecycle != Lifecycle::Draining || self.decode[di].drain_event_queued {
            return;
        }
        let s = self.state.stats(di);
        if s.batch_size() == 0
            && self.decode[di].pending.is_empty()
            && s.inbound_reserved_tokens() == 0
        {
            self.decode[di].drain_event_queued = true;
            self.queue.push(self.now, Event::DrainComplete { instance: di });
        }
    }

    fn on_drain_complete(&mut self, di: usize) {
        self.decode[di].drain_event_queued = false;
        if self.decode[di].lifecycle != Lifecycle::Draining {
            return; // stale (already handled)
        }
        let s = self.state.stats(di);
        if s.batch_size() != 0
            || !self.decode[di].pending.is_empty()
            || s.inbound_reserved_tokens() != 0
        {
            return; // re-armed by whatever raced in; a later check re-queues
        }
        self.decode[di].lifecycle = Lifecycle::Retired;
        self.state.set_lifecycle(di, Lifecycle::Retired);
        if self.decode[di].flip_to_prefill {
            let delay = self.control.elastic_config().flip_delay_s;
            self.prefill_provisioning += 1;
            let role = PoolRole::Prefill;
            self.queue.push(self.now + delay, Event::InstanceReady { role });
        }
    }

    /// A warmed-up instance joins its pool. Decode instances get a fresh
    /// slot at the end of the id space (retired slots are never reused,
    /// keeping instance ids stable for traces and per-instance metrics)
    /// and an iteration-time EWMA seeded from the cluster median.
    fn on_instance_ready(&mut self, role: PoolRole) {
        match role {
            PoolRole::Prefill => {
                self.prefill_provisioning -= 1;
                self.prefill.push(PrefillSim {
                    queue: VecDeque::new(),
                    busy: None,
                    lifecycle: Lifecycle::Active,
                    load_tokens: 0,
                    flip_to_decode: false,
                });
            }
            PoolRole::Decode => {
                self.decode_provisioning -= 1;
                // elastic joins keep cycling the fleet's profile pattern
                // over the (stable, never-reused) id space
                let profile = self
                    .fleet
                    .as_ref()
                    .map_or(HardwareProfile::default(), |f| f.profile(self.decode.len()));
                let exp = &self.params.exp;
                let raw_cap =
                    (exp.cluster.kv_capacity_tokens as f64 * profile.mem_mult).round() as u64;
                let kv = KvCacheManager::new(raw_cap, exp.cluster.block_tokens);
                let id = self.state.add_instance(raw_cap);
                debug_assert_eq!(id, self.decode.len(), "state and sim pools must align");
                self.state.set_capacity(id, kv.capacity_tokens());
                self.state.set_profile(id, profile);
                self.decode.push(DecodeSim {
                    id,
                    kv,
                    profile,
                    pending: VecDeque::new(),
                    stepping: false,
                    epoch: 0,
                    tokens_decoded: 0,
                    lifecycle: Lifecycle::Active,
                    flip_to_prefill: false,
                    drain_event_queued: false,
                });
                self.hold_tokens.push(0);
            }
        }
    }

    // ------------------------------------------------------------------
    // fault injection

    /// Decode instance `di` crashes. Its KV cache — batch residents,
    /// retained prefixes, in-flight holds — is gone. Pending (never
    /// admitted) requests lose nothing and re-dispatch to the active
    /// pool; batch residents go back through the prefill recompute path
    /// (the same machinery OOM eviction uses, minus the `hit_oom` mark —
    /// a crash is not memory pressure), or fail terminally when no
    /// instance of this size could ever re-admit them. Requests
    /// mid-migration are owned by the migration and ride it out: the
    /// source copy survives in the model, and `on_migration_done`
    /// re-routes around the failed destination like any non-active slot.
    /// The elastic layer provisions one replacement when `max_total`
    /// leaves headroom; `down_s > 0` schedules recovery.
    fn on_instance_failure(&mut self, di: usize, down_s: f64) {
        // a scripted plan may name an instance that was never
        // provisioned in this run; a stochastic plan may hit a slot
        // that already failed or retired — both are no-ops
        if di >= self.decode.len()
            || !matches!(
                self.decode[di].lifecycle,
                Lifecycle::Active | Lifecycle::Draining
            )
        {
            return;
        }
        self.reliability.failures += 1;
        self.registry.inc("faults.failures", 1);
        self.reliability.failure_log.push((self.now, di));
        self.decode[di].lifecycle = Lifecycle::Failed;
        self.state.set_lifecycle(di, Lifecycle::Failed);
        // a crash interrupts any drain-then-flip in progress
        self.decode[di].flip_to_prefill = false;
        self.decode[di].drain_event_queued = false;
        // any DecodeStep in flight is stale now
        self.decode[di].stepping = false;
        self.decode[di].epoch += 1;

        // flush the instance's prefix-cache entries and abandon holds
        // still targeting it (same flush drain_decode performs)
        if self.prefix_cache.enabled() {
            let flushed = self.prefix_cache.cached_on(di) + self.hold_tokens[di];
            self.reliability.kv_tokens_dropped += flushed;
            self.prefix_cache.evict_instance(di);
            let holders: Vec<RequestId> = self
                .requests
                .iter()
                .filter(|r| r.prefix_hold == Some(di))
                .map(|r| r.id)
                .collect();
            for id in holders {
                self.release_hold(id);
                self.prefix_cache.note_evicted();
            }
            self.sync_cached_mirror();
        }

        // pending requests re-dispatch (their KV was never admitted)
        let pending: Vec<RequestId> = self.decode[di].pending.drain(..).collect();
        for id in pending {
            self.reliability.requeued += 1;
            self.fault_requeue.insert(id, self.now);
            let incoming = {
                let r = &self.requests[id as usize];
                IncomingRequest {
                    id,
                    tokens: r.kv_tokens(),
                    predicted_remaining: r.predicted_remaining,
                    preferred_instance: None,
                }
            };
            let dst = self.dispatch_decode(&incoming);
            self.requests[id as usize].state = ReqState::Pending(dst);
            self.decode[dst].pending.push_back(id);
            self.kick(dst);
        }

        // batch residents lose their decoded KV and recompute it
        let residents: Vec<RequestId> = self
            .state
            .active(di)
            .iter()
            .map(|r| r.id)
            .filter(|&id| {
                matches!(self.requests[id as usize].state,
                         ReqState::Decoding(d) if d == di)
            })
            .collect();
        let watermark = admission_watermark(self.decode[di].kv.capacity_tokens());
        let block = self.params.exp.cluster.block_tokens as u64;
        let lost_before = self.reliability.lost;
        for id in residents {
            self.reliability.kv_tokens_dropped += self.requests[id as usize].kv_tokens();
            self.decode[di].kv.release(id);
            self.state.release(id);
            let r = &mut self.requests[id as usize];
            r.last_token_at = None; // the recompute stall is a crash gap
            if r.kv_tokens() + block > watermark {
                r.state = ReqState::Done;
                self.failed += 1;
                self.reliability.lost += 1;
            } else {
                r.state = ReqState::Recomputing;
                self.reliability.requeued += 1;
                self.fault_requeue.insert(id, self.now);
                self.queue.push(self.now, Event::Arrival { request: id });
            }
        }
        if self.reliability.lost > lost_before {
            self.registry
                .inc("requests.failed", self.reliability.lost - lost_before);
        }

        // emergency capacity: one replacement when the fleet cap leaves
        // headroom (static configs have max_total == 0 and ride out the
        // crash on the surviving instances)
        let max_total = self.control.elastic_config().max_total;
        if max_total > 0 {
            // fleet-wide head count, so merge the shard aggregates first
            let merged = self.epoch_barrier();
            if self.pool_stats(&merged).total_instances() < max_total {
                let action = ScalingAction::Provision {
                    role: PoolRole::Decode,
                };
                self.scale_log.push(ScaleRecord { t: self.now, action });
                self.execute_action(action);
            }
        }

        if down_s > 0.0 {
            self.queue
                .push(self.now + down_s, Event::InstanceRecovered { instance: di });
        }
    }

    /// A failed decode instance comes back, empty, as `Active`. Anything
    /// parked in its pending queue (only possible when no active
    /// instance existed at dispatch time) is kicked immediately.
    fn on_instance_recovered(&mut self, di: usize) {
        if di >= self.decode.len() || self.decode[di].lifecycle != Lifecycle::Failed {
            return;
        }
        self.reliability.recoveries += 1;
        self.registry.inc("faults.recoveries", 1);
        self.decode[di].lifecycle = Lifecycle::Active;
        self.state.set_lifecycle(di, Lifecycle::Active);
        self.kick(di);
    }

    // ------------------------------------------------------------------
    // observability (`[obs]` table, star trace)

    /// Drain the `[obs] sample_every_s` series clock up to the current
    /// event time: refresh the cluster gauges and snapshot one series
    /// point per due tick. A pure function of the event trajectory, so
    /// the series is identical across same-seed runs.
    fn drain_obs_samples(&mut self) {
        if !self.registry.enabled() {
            return;
        }
        while self.next_obs_sample <= self.now {
            let t = self.next_obs_sample;
            self.refresh_obs_gauges();
            self.registry.sample(t);
            self.next_obs_sample += self.params.exp.obs.sample_every_s;
        }
    }

    /// Point-in-time cluster gauges (sample-and-hold at event times).
    fn refresh_obs_gauges(&mut self) {
        let mut kv_used = 0u64;
        let mut batch = 0usize;
        let mut active = 0usize;
        for d in &self.decode {
            if matches!(d.lifecycle, Lifecycle::Retired | Lifecycle::Failed) {
                continue;
            }
            active += 1;
            kv_used += d.kv.used_tokens();
            batch += self.state.stats(d.id).batch_size();
        }
        let queued: usize = self
            .prefill
            .iter()
            .filter(|p| p.lifecycle == Lifecycle::Active)
            .map(|p| p.queue.len() + p.busy.is_some() as usize)
            .sum();
        self.registry.set_gauge("decode.active_instances", active as f64);
        self.registry.set_gauge("kv.used_tokens", kv_used as f64);
        self.registry.set_gauge("batch.running", batch as f64);
        self.registry.set_gauge("prefill.queued_reqs", queued as f64);
    }

    // ------------------------------------------------------------------

    fn into_report(mut self) -> SimReport {
        // one final series point at the run's end, so even short runs
        // carry the end-state snapshot
        if self.registry.enabled() {
            self.refresh_obs_gauges();
            self.registry.sample(self.now);
        }
        let obs = crate::obs::assemble_report(
            self.params.exp.obs.enabled,
            self.params.exp.cluster.seed,
            self.params.exp.obs.sample_rate,
            self.params.exp.obs.ring_capacity,
            self.recorder.rows(),
            std::mem::take(&mut self.registry),
            self.control.take_attribution(),
        );
        let mut report = SimReport {
            duration: self.now,
            completed: Vec::new(),
            n_failed: self.failed,
            n_requests: self.requests.len(),
            oom_events: self.oom_events,
            migrations: self.migrations_started,
            exec_var: self.exec_var,
            load_var: self.load_var,
            recorder: self.recorder,
            scorecard: self.scorecard,
            scheduler_stats: self.control.stats(),
            per_instance_tokens: self.decode.iter().map(|d| d.tokens_decoded).collect(),
            session_chains: self.session_chains,
            pool_timeline: self.pool_timeline,
            scale_actions: self.scale_log,
            cache: self.prefix_cache.report(),
            reliability: self.reliability,
            obs,
        };
        for r in self.requests {
            if matches!(r.state, ReqState::Done) && r.latency.finished.is_some() {
                report.completed.push(r.latency);
            }
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Dataset, TraceGen};

    fn small_params(n_req: usize, rps: f64) -> (SimParams, Vec<Request>) {
        let mut exp = ExperimentConfig::default();
        exp.cluster.n_decode = 3;
        exp.cluster.n_requests = n_req;
        exp.cluster.rps = rps;
        exp.cluster.kv_capacity_tokens = 200_000;
        exp.predictor = "oracle".to_string();
        let trace = TraceGen::new(Dataset::ShareGpt, rps).generate(n_req, 42);
        (
            SimParams {
                exp,
                ..Default::default()
            },
            trace,
        )
    }

    #[test]
    fn all_requests_complete() {
        let (p, trace) = small_params(40, 0.5);
        let report = Simulator::new(p, &trace).run();
        assert_eq!(report.completed.len() + report.n_failed, 40);
        assert!(report.metrics().throughput() > 0.0);
    }

    #[test]
    fn tokens_generated_match_trace() {
        let (p, trace) = small_params(20, 0.5);
        let report = Simulator::new(p, &trace).run();
        let total_out: u32 = report.completed.iter().map(|l| l.output_tokens).sum();
        let expect: u32 = trace.iter().map(|r| r.output_len).sum();
        assert_eq!(total_out, expect);
    }

    #[test]
    fn latencies_are_ordered() {
        let (p, trace) = small_params(25, 1.0);
        let report = Simulator::new(p, &trace).run();
        for l in &report.completed {
            let ft = l.first_token.unwrap();
            let fin = l.finished.unwrap();
            assert!(l.arrival <= l.prefill_done.unwrap());
            assert!(l.prefill_done.unwrap() <= ft + 1e-9);
            assert!(ft <= fin + 1e-9);
        }
    }

    #[test]
    fn rescheduling_triggers_migrations_under_skew() {
        let (mut p, trace) = small_params(60, 1.2);
        p.exp.rescheduler.enabled = true;
        p.exp.rescheduler.interval_s = 0.5;
        let report = Simulator::new(p, &trace).run();
        assert!(
            report.migrations > 0,
            "heavy-tail ShareGPT load should trigger at least one migration"
        );
    }

    #[test]
    fn disabled_rescheduler_never_migrates() {
        let (mut p, trace) = small_params(60, 1.2);
        p.exp.rescheduler.enabled = false;
        let report = Simulator::new(p, &trace).run();
        assert_eq!(report.migrations, 0);
    }

    #[test]
    fn tight_memory_produces_ooms_without_rescheduling() {
        let (mut p, trace) = small_params(60, 2.0);
        p.exp.rescheduler.enabled = false;
        p.exp.cluster.kv_capacity_tokens = 30_000; // tight
        let report = Simulator::new(p, &trace).run();
        assert!(report.oom_events > 0, "expected OOMs under tight memory");
        // OOM victims recompute and still finish
        assert_eq!(report.completed.len() + report.n_failed, 60);
    }

    #[test]
    fn deterministic_runs() {
        let (p, trace) = small_params(30, 1.0);
        let r1 = Simulator::new(p.clone(), &trace).run();
        let r2 = Simulator::new(p, &trace).run();
        assert_eq!(r1.completed.len(), r2.completed.len());
        assert!((r1.duration - r2.duration).abs() < 1e-9);
        assert_eq!(r1.migrations, r2.migrations);
    }

    #[test]
    fn incremental_state_validated_after_every_event() {
        // migrations + OOM recomputes + repredictions, each asserting
        // incremental state == from-scratch rebuild after every event
        let (mut p, trace) = small_params(50, 1.5);
        p.exp.rescheduler.enabled = true;
        p.exp.rescheduler.interval_s = 0.5;
        p.exp.cluster.kv_capacity_tokens = 40_000; // tight: forces OOMs
        p.validate_state = true;
        let report = Simulator::new(p, &trace).run();
        assert_eq!(report.completed.len() + report.n_failed, 50);
    }

    #[test]
    fn rebuild_mode_matches_incremental_mode() {
        // the compatibility (from-scratch) path must take the exact same
        // trajectory as the incremental path under the default policies
        let (mut p, trace) = small_params(40, 1.2);
        p.exp.rescheduler.enabled = true;
        let mut rebuild = p.clone();
        rebuild.state_mode = StateMode::RebuildPerDecision;
        let a = Simulator::new(p, &trace).run();
        let b = Simulator::new(rebuild, &trace).run();
        assert_eq!(a.completed.len(), b.completed.len());
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.oom_events, b.oom_events);
        assert!((a.duration - b.duration).abs() < 1e-9);
    }

    #[test]
    fn over_watermark_request_terminates_instead_of_stranding() {
        // one request whose KV exceeds the 90% admission watermark on an
        // otherwise idle cluster: it can never be admitted, and must fail
        // terminally instead of spinning the sim to max_sim_time
        let mut exp = ExperimentConfig::default();
        exp.cluster.n_decode = 2;
        exp.cluster.kv_capacity_tokens = 10_000; // watermark = 9000
        exp.predictor = "oracle".to_string();
        let trace = vec![Request {
            id: 0,
            arrival: 0.0,
            prompt_len: 9_500,
            output_len: 50,
            tag: 0,
            class: Default::default(),
        }];
        let params = SimParams {
            exp,
            max_sim_time: 5_000.0,
            validate_state: true,
            ..Default::default()
        };
        let report = Simulator::new(params, &trace).run();
        assert_eq!(report.n_failed, 1, "over-watermark request must fail");
        assert!(
            report.duration < 100.0,
            "sim must terminate promptly, not spin to the cap (ran {:.1}s)",
            report.duration
        );
    }

    #[test]
    fn near_watermark_request_still_completes_on_idle_cluster() {
        // just under the watermark: admissible on an idle instance; the
        // SchedulerTick re-kick guarantees it is not stranded even if its
        // first admission attempt raced with transient occupancy
        let mut exp = ExperimentConfig::default();
        exp.cluster.n_decode = 2;
        exp.cluster.kv_capacity_tokens = 10_000;
        exp.predictor = "oracle".to_string();
        let trace = vec![Request {
            id: 0,
            arrival: 0.0,
            prompt_len: 8_900,
            output_len: 40,
            tag: 0,
            class: Default::default(),
        }];
        let params = SimParams {
            exp,
            max_sim_time: 5_000.0,
            validate_state: true,
            ..Default::default()
        };
        let report = Simulator::new(params, &trace).run();
        assert_eq!(report.completed.len(), 1);
        assert_eq!(report.n_failed, 0);
    }

    #[test]
    fn session_follow_ups_arrive_only_after_prior_turn_completes() {
        use crate::workload::{ArrivalProcess, ClassMix, ClassSpec, ScenarioSpec, SessionProfile};
        let spec = ScenarioSpec {
            name: "unit_sessions".to_string(),
            arrival: ArrivalProcess::Poisson { rps: 0.5 },
            classes: ClassMix::single(ClassSpec::chat()),
            sessions: Some(SessionProfile {
                session_frac: 0.8,
                min_turns: 2,
                max_turns: 3,
                think_mean_s: 2.0,
                max_context_tokens: 16_384,
            }),
            pico_scale: None,
            faults: None,
            fleet: None,
        };
        let strace = spec.generate(30, 8);
        assert!(strace.sessions.total_follow_ups() > 0, "need sessions");
        let expected = strace.total_planned();
        let mut exp = ExperimentConfig::default();
        exp.cluster.n_decode = 3;
        exp.cluster.kv_capacity_tokens = 400_000; // roomy: nothing fails
        exp.predictor = "oracle".to_string();
        let params = SimParams {
            exp,
            ..Default::default()
        };
        let report = Simulator::with_scenario(params, strace, &PolicyRegistry::with_builtins())
            .expect("builtin policies")
            .run();
        assert_eq!(report.n_failed, 0);
        assert_eq!(
            report.completed.len(),
            expected,
            "every planned turn must be realized and completed"
        );
        let by_id: std::collections::HashMap<_, _> =
            report.completed.iter().map(|l| (l.id, l)).collect();
        let mut multi_turn = 0;
        for chain in &report.session_chains {
            for w in chain.windows(2) {
                let prev = by_id[&w[0]];
                let next = by_id[&w[1]];
                assert!(
                    next.arrival >= prev.finished.unwrap() - 1e-9,
                    "turn arrived at {} before its predecessor finished at {}",
                    next.arrival,
                    prev.finished.unwrap()
                );
                multi_turn += 1;
            }
        }
        assert!(multi_turn > 0, "no realized multi-turn chain");
    }

    #[test]
    fn prefix_cache_hits_on_session_follow_ups() {
        use crate::workload::{ArrivalProcess, ClassMix, ClassSpec, ScenarioSpec, SessionProfile};
        let spec = ScenarioSpec {
            name: "unit_cache".to_string(),
            arrival: ArrivalProcess::Poisson { rps: 0.5 },
            classes: ClassMix::single(ClassSpec::chat()),
            sessions: Some(SessionProfile {
                session_frac: 0.9,
                min_turns: 2,
                max_turns: 4,
                think_mean_s: 2.0,
                max_context_tokens: 16_384,
            }),
            pico_scale: None,
            faults: None,
            fleet: None,
        };
        let strace = spec.generate(30, 11);
        assert!(strace.sessions.total_follow_ups() > 0, "need sessions");
        let expected = strace.total_planned();
        let mut exp = ExperimentConfig::default();
        exp.cluster.n_decode = 3;
        exp.cluster.kv_capacity_tokens = 400_000;
        exp.predictor = "oracle".to_string();
        exp.dispatch_policy = "session_affinity".to_string();
        exp.kvcache.policy = "lru".to_string();
        exp.kvcache.budget_tokens = 100_000;
        exp.kvcache.ttl_s = 120.0;
        let params = SimParams {
            exp,
            validate_state: true,
            ..Default::default()
        };
        let report = Simulator::with_scenario(params, strace, &PolicyRegistry::with_builtins())
            .expect("builtin policies")
            .run();
        assert_eq!(report.n_failed, 0);
        assert_eq!(report.completed.len(), expected);
        assert!(report.cache.enabled);
        assert!(
            report.cache.hits > 0,
            "multi-turn sessions with a warm cache must hit: {}",
            report.cache.summary()
        );
        assert!(report.cache.tokens_reused > 0);
        assert!(report.cache.insertions > 0);
        // a hit prefills strictly less than its full prompt…
        assert!(
            report
                .completed
                .iter()
                .any(|l| l.suffix_tokens < l.prompt_tokens),
            "at least one completed turn must have reused a prefix"
        );
        // …and no request ever prefills more than it
        for l in &report.completed {
            assert!(l.suffix_tokens <= l.prompt_tokens, "request {}", l.id);
            assert!(l.prompt_tokens > 0, "request {}", l.id);
        }
    }

    #[test]
    fn cache_off_report_is_inert() {
        let (p, trace) = small_params(20, 0.5);
        let report = Simulator::new(p, &trace).run();
        assert!(!report.cache.enabled);
        assert_eq!(report.cache, Default::default());
    }

    #[test]
    fn prefill_selection_uses_queued_tokens_not_queue_length() {
        // 2 prefill instances; a huge prompt lands first, then three short
        // ones. The old shortest-QUEUE rule ties 1-vs-1 and parks a short
        // prompt behind the ~5 s monster; token-load selection routes all
        // three shorts to the other instance.
        let mut exp = ExperimentConfig::default();
        exp.cluster.n_prefill = 2;
        exp.cluster.n_decode = 2;
        exp.cluster.kv_capacity_tokens = 400_000;
        exp.predictor = "oracle".to_string();
        let mut trace = vec![Request {
            id: 0,
            arrival: 0.0,
            prompt_len: 20_000,
            output_len: 5,
            tag: 0,
            class: Default::default(),
        }];
        for i in 1..=3 {
            trace.push(Request {
                id: i,
                arrival: 0.001 * i as f64,
                prompt_len: 100,
                output_len: 5,
                tag: 0,
                class: Default::default(),
            });
        }
        let params = SimParams {
            exp,
            validate_state: true,
            ..Default::default()
        };
        let report = Simulator::new(params, &trace).run();
        assert_eq!(report.completed.len(), 4);
        let by_id: std::collections::HashMap<_, _> =
            report.completed.iter().map(|l| (l.id, l)).collect();
        let big_done = by_id[&0].prefill_done.unwrap();
        for i in 1..=3u64 {
            let short_done = by_id[&i].prefill_done.unwrap();
            assert!(
                short_done < big_done,
                "short request {i} finished prefill at {short_done:.3}s, after the \
                 20k-token prompt at {big_done:.3}s — it was queued behind it"
            );
        }
    }

    #[test]
    fn static_scaling_keeps_the_pool_frozen() {
        let (p, trace) = small_params(40, 1.0);
        let report = Simulator::new(p, &trace).run();
        assert!(report.scale_actions.is_empty(), "static must never act");
        for s in &report.pool_timeline {
            assert_eq!(s.prefill_active, 1);
            assert_eq!(s.decode_active, 3);
            assert_eq!(s.draining + s.provisioning, 0);
        }
        assert!(
            !report.pool_timeline.is_empty(),
            "timeline is sampled even under static scaling"
        );
    }

    #[test]
    fn recompute_does_not_double_count_arrivals() {
        let (mut p, trace) = small_params(60, 2.0);
        p.exp.rescheduler.enabled = false;
        p.exp.cluster.kv_capacity_tokens = 30_000; // tight: forces OOMs
        p.exp.record_traces = true;
        let report = Simulator::new(p, &trace).run();
        assert!(report.oom_events > 0, "test needs OOM recomputes");
        let mut arrivals = vec![0usize; 60];
        let mut recomputes = 0usize;
        for row in report.recorder.rows() {
            match row.event {
                TraceEvent::Arrived { request } => arrivals[request as usize] += 1,
                TraceEvent::RecomputeQueued { .. } => recomputes += 1,
                _ => {}
            }
        }
        assert!(recomputes > 0, "OOM victims must surface as RecomputeQueued");
        assert!(
            arrivals.iter().all(|&n| n == 1),
            "each request must arrive exactly once: {arrivals:?}"
        );
    }
}
