//! Event queue for the simulator: a min-heap on simulation time.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::coordinator::PoolRole;
use crate::{InstanceId, RequestId, Time};

/// Discrete simulation events.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// A request enters the system (goes to a prefill queue).
    Arrival { request: RequestId },
    /// A prefill instance finishes its current request.
    PrefillDone {
        prefill: InstanceId,
        request: RequestId,
    },
    /// A decode instance completes one batched iteration.
    DecodeStep { instance: InstanceId, epoch: u64 },
    /// KV transfer for a migration completes. `kv_tokens` is the exact
    /// amount reserved on the destination at migration start (released on
    /// completion — carrying it avoids recomputing it from request state,
    /// which could drift from what was actually reserved).
    MigrationDone {
        request: RequestId,
        from: InstanceId,
        to: InstanceId,
        kv_tokens: u64,
    },
    /// Periodic scheduler tick (Algorithm 1 interval).
    SchedulerTick,
    /// A multi-round session's next turn arrives (scheduled at the prior
    /// turn's completion + think time; the request record is created when
    /// this fires). `turn` indexes the session script in the
    /// [`crate::workload::SessionPlan`].
    SessionFollowUp { session: u32, turn: u32 },
    /// Elastic-pool scale interval: sample the pool, run the scaling
    /// policy through the control loop, execute at most one action.
    ScaleTick,
    /// A provisioned or flipped instance finished its modeled warm-up and
    /// joins the pool in `role`.
    InstanceReady { role: PoolRole },
    /// A draining decode instance ran out of residents (batch, pending
    /// queue and inbound reservations all empty): retire it, or re-role
    /// it if the drain was started by a flip.
    DrainComplete { instance: InstanceId },
    /// A cached session prefix finished moving (or being recomputed) for
    /// a follow-up turn that was dispatched away from the instance holding
    /// it. The fire time is min(transfer, recompute) of the costmodel
    /// comparison; `tokens` is the prefix footprint reserved on `to`.
    PrefixTransferDone {
        request: RequestId,
        from: InstanceId,
        to: InstanceId,
        tokens: u64,
    },
    /// Fault injection: decode instance `instance` crashes. Its KV cache
    /// (batch residents, prefix cache) is lost; in-flight and pending
    /// requests re-queue through the recompute path. `down_s <= 0` means
    /// the crash is permanent (no recovery is scheduled).
    InstanceFailure { instance: InstanceId, down_s: f64 },
    /// A previously failed decode instance comes back, empty, as
    /// `Active` — the fault-injection counterpart of `InstanceReady`.
    InstanceRecovered { instance: InstanceId },
}

impl Event {
    /// Variant name, as listed in the engine's `VALIDATED_EVENTS`
    /// coverage const (the invariant checker asserts membership before
    /// dispatching each event).
    pub fn name(&self) -> &'static str {
        match self {
            Event::Arrival { .. } => "Arrival",
            Event::PrefillDone { .. } => "PrefillDone",
            Event::DecodeStep { .. } => "DecodeStep",
            Event::MigrationDone { .. } => "MigrationDone",
            Event::SchedulerTick => "SchedulerTick",
            Event::SessionFollowUp { .. } => "SessionFollowUp",
            Event::ScaleTick => "ScaleTick",
            Event::InstanceReady { .. } => "InstanceReady",
            Event::DrainComplete { .. } => "DrainComplete",
            Event::PrefixTransferDone { .. } => "PrefixTransferDone",
            Event::InstanceFailure { .. } => "InstanceFailure",
            Event::InstanceRecovered { .. } => "InstanceRecovered",
        }
    }
}

#[derive(Clone, Debug)]
struct Scheduled {
    at: Time,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Scheduled {}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert for earliest-first; ties broken
        // by insertion order for determinism.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic time-ordered event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, at: Time, event: Event) {
        debug_assert!(at.is_finite(), "event at non-finite time");
        self.seq += 1;
        self.heap.push(Scheduled {
            at,
            seq: self.seq,
            event,
        });
    }

    pub fn pop(&mut self) -> Option<(Time, Event)> {
        self.heap.pop().map(|s| (s.at, s.event))
    }

    #[allow(dead_code)]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    #[allow(dead_code)]
    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, Event::SchedulerTick);
        q.push(1.0, Event::Arrival { request: 1 });
        q.push(2.0, Event::Arrival { request: 2 });
        assert_eq!(q.pop().unwrap().0, 1.0);
        assert_eq!(q.pop().unwrap().0, 2.0);
        assert_eq!(q.pop().unwrap().0, 3.0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_pop_fifo() {
        let mut q = EventQueue::new();
        q.push(1.0, Event::Arrival { request: 10 });
        q.push(1.0, Event::Arrival { request: 20 });
        match q.pop().unwrap().1 {
            Event::Arrival { request } => assert_eq!(request, 10),
            _ => panic!(),
        }
        match q.pop().unwrap().1 {
            Event::Arrival { request } => assert_eq!(request, 20),
            _ => panic!(),
        }
    }
}
